//! Serving study at testbed scale — the end-to-end validation driver for
//! the serving half (§5.5): load a small real model (optionally a trained
//! checkpoint), serve Poisson-arriving batched requests through the full
//! coordinator stack, and report latency percentiles and throughput for
//! both the monolithic single-device engine and the disaggregated
//! expert-parallel engine across worker counts and all-to-all schedules.
//!
//! ```sh
//! cargo run --release --example serve_moe -- --requests 32 --rate 50
//! ```

use ds_moe::config::{AllToAllKind, ServingConfig};
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::runtime::Manifest;
use ds_moe::server::{Engine, EpEngine};
use ds_moe::util::args::Args;
use ds_moe::util::rng::Rng;
use ds_moe::util::stats::fmt_ns;
use ds_moe::util::table::{f1, Table};

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let model = args.get("model", "moe-s-8", "model variant");
    let n_requests = args.get_usize("requests", 32, "number of requests");
    let rate = args.get_f64("rate", 100.0, "arrival rate (req/s)");
    let max_new = args.get_usize("max-new", 10, "tokens per request");
    let workers_list =
        args.get_usize_list("workers", "2,4,8", "EP worker counts to test");
    let manifest = Manifest::load(args.get("artifacts", "artifacts", ""))?;
    let corpus = Corpus::generate(CorpusConfig::default());

    // ---- monolithic engine under a Poisson open-loop workload -------------
    println!("== monolithic engine: {model}, Poisson {rate} req/s ==");
    let mut engine = Engine::new(
        &manifest,
        ServingConfig {
            model: model.clone(),
            max_new_tokens: max_new,
            ..Default::default()
        },
    )?;
    let mut rng = Rng::new(7);
    let mut arrivals: Vec<f64> = Vec::new();
    let mut t_acc = 0.0;
    for _ in 0..n_requests {
        t_acc += rng.exponential(rate);
        arrivals.push(t_acc);
    }
    let t0 = std::time::Instant::now();
    let mut submitted = 0usize;
    while submitted < n_requests || engine.active_count() > 0
        || engine.router.queue_len() > 0
    {
        let now = t0.elapsed().as_secs_f64();
        while submitted < n_requests && arrivals[submitted] <= now {
            engine.submit(corpus.prompt(submitted, 8), Some(max_new))?;
            submitted += 1;
        }
        if !engine.step()? {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
    let wall = t0.elapsed();
    let responses = engine.take_done();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let mut ttfts: Vec<u64> =
        responses.iter().map(|r| r.ttft.as_nanos() as u64).collect();
    ttfts.sort();
    println!(
        "  {} responses, {:.1} tok/s, TTFT p50 {} p99 {}",
        responses.len(),
        total_tokens as f64 / wall.as_secs_f64(),
        fmt_ns(ttfts[ttfts.len() / 2]),
        fmt_ns(ttfts[ttfts.len() * 99 / 100]),
    );
    println!(
        "  decode_step p50 {}  prefill p50 {}",
        fmt_ns(engine.metrics.percentile_ns("decode_step", 50.0)),
        fmt_ns(engine.metrics.percentile_ns("prefill", 50.0)),
    );

    // ---- expert-parallel engine across workers + schedules ----------------
    let mut t = Table::new(
        "EP engine: decode throughput by workers x all-to-all schedule",
        &["workers", "schedule", "prefill ms", "decode ms/step",
          "agg tok/s", "a2a bytes", "max imbalance"],
    );
    let batch = 8usize;
    let steps = 8usize;
    for &w in &workers_list {
        for kind in [AllToAllKind::Naive, AllToAllKind::Hierarchical] {
            let mut ep = EpEngine::new(&manifest, &model, w, kind, batch)?;
            let smax = ep.cfg.max_seq;
            let mut tokens = vec![0i32; batch * smax];
            for b in 0..batch {
                let p = corpus.prompt(b, 8);
                tokens[b * smax..b * smax + 8].copy_from_slice(&p);
            }
            let tp = std::time::Instant::now();
            let logits = ep.forward_prefill(&tokens, &vec![8; batch])?;
            let prefill_ms = tp.elapsed().as_secs_f64() * 1e3;
            let mut last: Vec<i32> =
                logits.iter().map(|r| argmax(r)).collect();
            let mut pos = vec![8i32; batch];
            let td = std::time::Instant::now();
            for _ in 0..steps {
                let logits = ep.forward_decode(&last, &pos)?;
                last = logits.iter().map(|r| argmax(r)).collect();
                for p in &mut pos {
                    *p += 1;
                }
            }
            let decode_s = td.elapsed().as_secs_f64();
            let imb = ep
                .load_stats
                .iter()
                .map(|s| s.imbalance())
                .fold(0.0, f64::max);
            t.row(&[
                w.to_string(),
                format!("{kind:?}"),
                f1(prefill_ms),
                f1(decode_s / steps as f64 * 1e3),
                f1(batch as f64 * steps as f64 / decode_s),
                ep.metrics.counter("alltoall_bytes").to_string(),
                f1(imb),
            ]);
        }
    }
    t.note("testbed workers are CPU threads; hop-count effects at paper \
            scale come from the simulator (benches/fig10_scaling)");
    t.print();
    t.save_csv("serve_moe_ep_study")?;
    Ok(())
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}
