//! Serving study at testbed scale — the end-to-end validation driver for
//! the serving half (§5.5): serve Poisson-arriving requests through the
//! engine-agnostic continuous-batching scheduler
//! (`Scheduler<M: ForwardModel>`) over **both** backends — the monolithic
//! single-device engine and the disaggregated expert-parallel engine
//! across worker counts and all-to-all schedules — and report latency
//! percentiles, throughput, and lane occupancy.
//!
//! ```sh
//! cargo run --release --example serve_moe -- --requests 32 --rate 50
//! ```

use ds_moe::config::{AllToAllKind, ServingConfig};
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::runtime::Manifest;
use ds_moe::server::{ttft_percentile, Engine, EpEngine, Scheduler};
use ds_moe::util::args::Args;
use ds_moe::util::stats::fmt_ns;
use ds_moe::util::table::{f1, Table};

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let model = args.get("model", "moe-s-8", "model variant");
    let n_requests = args.get_usize("requests", 32, "number of requests");
    let rate = args.get_f64("rate", 100.0, "arrival rate (req/s)");
    let max_new = args.get_usize("max-new", 10, "tokens per request");
    let workers_list =
        args.get_usize_list("workers", "2,4,8", "EP worker counts to test");
    let manifest = Manifest::load(args.get("artifacts", "artifacts", ""))?;
    let corpus = Corpus::generate(CorpusConfig::default());

    // ---- monolithic backend under a Poisson open-loop workload ------------
    println!("== scheduler/monolithic: {model}, Poisson {rate} req/s ==");
    let serving = ServingConfig {
        model: model.clone(),
        max_new_tokens: max_new,
        ..Default::default()
    };
    let engine = Engine::new(&manifest, serving.clone())?;
    let mut sched = Scheduler::new(engine, serving);
    let (responses, wall) = sched
        .run_poisson(n_requests, rate, max_new, 7, |i| corpus.prompt(i, 8))?;
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    println!(
        "  {} responses, {:.1} tok/s, TTFT p50 {} p99 {}",
        responses.len(),
        total_tokens as f64 / wall,
        fmt_ns(ttft_percentile(&responses, 50)),
        fmt_ns(ttft_percentile(&responses, 99)),
    );
    println!(
        "  decode_step p50 {}  prefill p50 {}  occupancy {:.1}%",
        fmt_ns(sched.metrics.percentile_ns("decode_step", 50.0)),
        fmt_ns(sched.metrics.percentile_ns("prefill", 50.0)),
        100.0 * sched.metrics.value_mean("decode_utilization"),
    );

    // ---- expert-parallel backend across workers + schedules ---------------
    let mut t = Table::new(
        "scheduler/EP: continuous batching by workers x all-to-all schedule",
        &["workers", "schedule", "tok/s", "TTFT p50", "occupancy %",
          "a2a bytes", "max imbalance"],
    );
    let batch = 8usize;
    for &w in &workers_list {
        for kind in [AllToAllKind::Naive, AllToAllKind::Hierarchical] {
            let ep = EpEngine::new(&manifest, &model, w, kind, batch)?;
            let serving = ServingConfig {
                model: model.clone(),
                workers: w,
                max_batch: batch,
                max_new_tokens: max_new,
                alltoall: kind,
                ..Default::default()
            };
            let mut sched = Scheduler::new(ep, serving);
            let (responses, wall) = sched.run_poisson(
                n_requests, rate, max_new, 7, |i| corpus.prompt(i, 8),
            )?;
            let tokens: usize =
                responses.iter().map(|r| r.tokens.len()).sum();
            let imb = sched
                .model
                .load_stats
                .iter()
                .map(|s| s.imbalance())
                .fold(0.0, f64::max);
            t.row(&[
                w.to_string(),
                format!("{kind:?}"),
                f1(tokens as f64 / wall),
                fmt_ns(ttft_percentile(&responses, 50)),
                f1(100.0 * sched.metrics.value_mean("decode_utilization")),
                sched.metrics.counter("alltoall_bytes").to_string(),
                f1(imb),
            ]);
        }
    }
    t.note("testbed workers are CPU threads; hop-count effects at paper \
            scale come from the simulator (benches/fig10_scaling)");
    t.print();
    t.save_csv("serve_moe_ep_study")?;
    Ok(())
}
