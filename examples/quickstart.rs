//! Quickstart: load the artifacts, serve a handful of requests on a tiny
//! MoE model, and print the responses.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use ds_moe::config::ServingConfig;
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::runtime::Manifest;
use ds_moe::server::{Engine, Scheduler};
use ds_moe::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    // 1. The manifest is the ABI to the AOT-compiled JAX/Pallas programs.
    let manifest = Manifest::load("artifacts")?;
    println!(
        "loaded manifest: {} models, {} shared programs",
        manifest.models.len(),
        manifest.shared.len()
    );

    // 2. Build the serving stack for the standard-MoE tiny model: the
    //    continuous-batching scheduler over the monolithic backend.
    let serving = ServingConfig {
        model: "moe-s-8".into(),
        max_new_tokens: 12,
        ..Default::default()
    };
    let mut engine =
        Scheduler::new(Engine::new(&manifest, serving.clone())?, serving);
    let cfg = engine.model.model_config().clone();
    println!(
        "serving {} — {} params, experts per layer {:?}",
        cfg.name, cfg.num_params, cfg.experts_schedule
    );

    // 3. Requests come from the synthetic corpus; the tokenizer gives them
    //    a readable surface form.
    let corpus = Corpus::generate(CorpusConfig::default());
    let tok = Tokenizer::new(cfg.vocab_size);
    for i in 0..8 {
        let prompt = corpus.prompt(i, 8);
        println!("prompt #{i}: {}", tok.decode(&prompt));
        engine.submit(prompt, Some(12))?;
    }

    // 4. Drain: the engine batches prefills, decodes continuously, retires
    //    finished sequences.
    let t0 = std::time::Instant::now();
    let responses = engine.run_until_idle()?;
    let wall = t0.elapsed();

    for r in &responses {
        println!(
            "  -> #{} ({} tokens, ttft {:?}): {}",
            r.id,
            r.tokens.len(),
            r.ttft,
            tok.decode(&r.tokens)
        );
    }
    let total: usize = responses.iter().map(|r| r.tokens.len()).sum();
    println!(
        "\n{} responses / {total} tokens in {wall:?} ({:.1} tok/s)",
        responses.len(),
        total as f64 / wall.as_secs_f64()
    );
    println!("\nmetrics:\n{}", engine.metrics.report());
    Ok(())
}
