//! Paper-scale scale study: run every simulator scenario (Figures 10–15 +
//! Table 3) in one pass and dump the latency breakdowns that explain *why*
//! each curve bends — the per-component view behind the benches.
//!
//! ```sh
//! cargo run --release --example scale_study
//! ```

use ds_moe::config::paper::{self, Variant};
use ds_moe::simulator::{self, decode_latency, Cluster, Layout, Stack};
use ds_moe::util::table::{f2, Table};

fn main() -> anyhow::Result<()> {
    for name in ["fig10", "fig11", "fig12", "fig13", "fig14", "fig15",
                 "table3"] {
        simulator::run_named(name)?;
    }

    // Component breakdown: where the time goes for the 52B model as the
    // cluster grows — the explanation for Fig 10's shapes.
    let m = paper::by_name("1.3B+MoE-128").unwrap();
    let mut t = Table::new(
        "Latency breakdown (ms): 52B MoE per decode step",
        &["GPUs", "stack", "base read", "expert read", "all-to-all",
          "kernel ovh", "compute", "total"],
    );
    for n in [8usize, 16, 32, 64] {
        for stack in [Stack::PyTorch, Stack::DeepSpeed] {
            let cl = Cluster::azure_a100(n);
            let lay = Layout { n_gpus: n, tp: 1, ep: n, expert_slice: 1 };
            let b = decode_latency(&m, Variant::Standard, stack, &cl, lay,
                                   16.0);
            t.row(&[
                n.to_string(),
                format!("{stack:?}"),
                f2(b.base_stream * 1e3),
                f2(b.expert_stream * 1e3),
                f2(b.alltoall * 1e3),
                f2(b.kernel_overhead * 1e3),
                f2(b.compute * 1e3),
                f2(b.total() * 1e3),
            ]);
        }
    }
    t.note("expert read shrinks with GPU count (data locality); the \
            baseline's naive all-to-all grows with it — the two effects \
            behind Fig 10");
    t.print();
    t.save_csv("scale_study_breakdown")?;
    Ok(())
}
