//! End-to-end training driver — regenerates the paper's training-side
//! results at testbed scale:
//!
//! * default: Figure 1 (dense vs MoE validation-loss curves) + Table 2
//!   (zero-shot evals) over a configurable variant set;
//! * `--ablation halves`   — Figure 2 (left): First-Half vs Second-Half MoE;
//! * `--ablation residual` — Figure 2 (right): Top2-MoE vs Residual-MoE;
//! * `--ablation pr`       — Figure 4: MoE-32/128 vs Pyramid vs Residual
//!   vs PR-MoE;
//! * `--compare pr`        — Table 4: PR-MoE vs standard MoE param/quality.
//!
//! Loss curves land in `bench_results/<run>.csv`; trained checkpoints in
//! `checkpoints/<model>/` (used by distill_mos.rs).
//!
//! ```sh
//! cargo run --release --example train_moe -- --steps 300
//! ```

use ds_moe::data::{Corpus, CorpusConfig, EvalSuite};
use ds_moe::runtime::Manifest;
use ds_moe::training::{LrSchedule, Trainer};
use ds_moe::util::args::Args;
use ds_moe::util::table::{f2, Table};

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let steps = args.get_usize("steps", 300, "training steps per variant");
    let eval_every = args.get_usize("eval-every", 25, "eval interval");
    let ablation = args.get("ablation", "", "halves|residual|pr");
    let compare = args.get("compare", "", "pr (Table 4 comparison)");
    let save = args.get_bool("save", true, "save checkpoints/<model>");
    let manifest = Manifest::load(args.get("artifacts", "artifacts", ""))?;

    let variants: Vec<&str> = match (ablation.as_str(), compare.as_str()) {
        ("halves", _) => vec!["moe-s-8-firsthalf", "moe-s-8-secondhalf"],
        ("residual", _) => vec!["moe-s-4-top2", "moe-s-4-residual"],
        ("pr", _) => vec!["moe-s-4", "moe-s-8", "moe-s-pyramid",
                          "moe-s-4-residual", "prmoe-s"],
        (_, "pr") => vec!["moe-s-8", "prmoe-s"],
        _ => vec!["dense-s", "dense-m", "dense-l", "moe-s-8", "prmoe-s"],
    };
    let run_name = if !ablation.is_empty() {
        format!("fig_ablation_{ablation}")
    } else if !compare.is_empty() {
        format!("table4_compare_{compare}")
    } else {
        "fig1_loss_curves".to_string()
    };

    let corpus = Corpus::generate(CorpusConfig::default());
    let suite = EvalSuite::from_corpus(&corpus, 8);

    let mut curves = Table::new(
        &format!("{run_name} — validation loss (step x variant)"),
        &std::iter::once("step")
            .chain(variants.iter().copied())
            .collect::<Vec<_>>(),
    );
    let mut evals = Table::new(
        "Zero-shot cloze accuracy per domain (Table 2 analogue)",
        &["model", "params", "valid loss", "mean acc %"],
    );

    let mut histories = Vec::new();
    for name in &variants {
        let sched = LrSchedule {
            peak: 1.5e-3,
            min: 1.5e-4,
            warmup_steps: steps / 20,
            decay_steps: steps,
        };
        let mut tr = Trainer::new(&manifest, name, sched)?;
        println!(
            "=== training {name} ({} params) for {steps} steps ===",
            tr.param_count()
        );
        let t0 = std::time::Instant::now();
        tr.run(&corpus, steps, eval_every, false)?;
        println!("    ({:?}, {:.1} steps/s)", t0.elapsed(),
                 steps as f64 / t0.elapsed().as_secs_f64());

        let valid = tr.eval(&corpus, 8)?;
        let (per_task, mean) = tr.zero_shot(&suite, 8)?;
        evals.row(&[
            name.to_string(),
            tr.param_count().to_string(),
            f2(valid),
            format!("{:.1}", 100.0 * mean),
        ]);
        for (task, acc) in &per_task {
            println!("    {task}: {:.1}%", 100.0 * acc);
        }
        if save {
            let dir = format!("checkpoints/{name}");
            tr.save(&dir)?;
            println!("    checkpoint -> {dir}");
        }
        histories.push(tr.history.clone());
    }

    // Align histories into the curves table (same eval schedule).
    if let Some(first) = histories.first() {
        for (i, pt) in first.iter().enumerate() {
            let mut row = vec![pt.step.to_string()];
            for h in &histories {
                row.push(
                    h.get(i)
                        .map(|p| f2(p.valid_loss))
                        .unwrap_or_default(),
                );
            }
            curves.row(&row);
        }
    }

    curves.print();
    evals.print();
    let p1 = curves.save_csv(&run_name)?;
    let p2 = evals.save_csv(&format!("{run_name}_evals"))?;
    println!("saved {} and {}", p1.display(), p2.display());

    // Paper-shape checks, reported not asserted (this is an example):
    if ablation == "halves" && histories.len() == 2 {
        let (fh, sh) = (&histories[0], &histories[1]);
        let (a, b) = (
            fh.last().unwrap().valid_loss,
            sh.last().unwrap().valid_loss,
        );
        println!(
            "Fig 2 (left) check — second-half MoE should win: \
             first-half {a:.4} vs second-half {b:.4} => {}",
            if b < a { "reproduced" } else { "NOT reproduced at this scale" }
        );
    }
    Ok(())
}
