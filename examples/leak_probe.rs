// RSS probe: repeated train steps, print RSS every 10.
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::runtime::Manifest;
use ds_moe::training::{LrSchedule, Trainer};

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1e6
}

fn main() {
    let m = Manifest::load("artifacts").unwrap();
    let c = Corpus::generate(CorpusConfig { train_seqs: 64, valid_seqs: 32, ..Default::default() });
    let sched = LrSchedule { peak: 1e-3, min: 1e-4, warmup_steps: 2, decay_steps: 100 };
    let mut tr = Trainer::new(&m, "dense-m", sched).unwrap();
    for s in 0..60 {
        let b = c.train_batch(s, tr.batch);
        tr.train_step(&b).unwrap();
        if s % 10 == 0 { println!("step {s}: RSS {:.0} MB", rss_mb()); }
    }
    println!("final: RSS {:.0} MB", rss_mb());
}
