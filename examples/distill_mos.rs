//! Mixture-of-Students distillation driver (§4.2): regenerates Figures 5/6
//! and Table 5 at testbed scale.
//!
//! Trains the PR-MoE teacher (or restores `checkpoints/prmoe-s` from a
//! previous `train_moe` run), then trains the depth-reduced student under
//! the three KD regimes the paper compares:
//!
//!   * from scratch (no KD)             — Table 5 row "L21"
//!   * full-run KD                      — row "KD only" (Fig 5: hurts late)
//!   * staged KD (stop at 70% of steps) — row "MoS" (Fig 6: matches teacher)
//!
//! ```sh
//! cargo run --release --example distill_mos -- --steps 300
//! ```

use ds_moe::data::{Corpus, CorpusConfig, EvalSuite};
use ds_moe::runtime::Manifest;
use ds_moe::training::{Distiller, KdMode, LrSchedule, Trainer};
use ds_moe::util::args::Args;
use ds_moe::util::table::{f2, Table};

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let steps = args.get_usize("steps", 300, "student training steps");
    let teacher_steps =
        args.get_usize("teacher-steps", 300, "teacher training steps");
    let eval_every = args.get_usize("eval-every", 25, "eval interval");
    let stop_frac = args.get_f64("kd-stop-frac", 0.7,
                                 "staged-KD stop fraction (paper ~0.7)");
    let only_mode = args.get("mode", "", "run a single mode: none|full|staged");
    let manifest = Manifest::load(args.get("artifacts", "artifacts", ""))?;

    let corpus = Corpus::generate(CorpusConfig::default());
    let suite = EvalSuite::from_corpus(&corpus, 8);
    let sched = |n: usize| LrSchedule {
        peak: 1.5e-3,
        min: 1.5e-4,
        warmup_steps: n / 20,
        decay_steps: n,
    };

    // --- teacher ----------------------------------------------------------
    let teacher_dir = std::path::PathBuf::from("checkpoints/prmoe-s");
    let teacher_valid;
    if teacher_dir.join("meta.json").exists() {
        println!("reusing trained teacher at {}", teacher_dir.display());
        let mut t = Trainer::new(&manifest, "prmoe-s", sched(1))?;
        t.restore(&teacher_dir)?;
        teacher_valid = t.eval(&corpus, 8)?;
    } else {
        println!("training PR-MoE teacher for {teacher_steps} steps");
        let mut t = Trainer::new(&manifest, "prmoe-s", sched(teacher_steps))?;
        t.run(&corpus, teacher_steps, eval_every, false)?;
        teacher_valid = t.eval(&corpus, 8)?;
        t.save(&teacher_dir)?;
    }
    println!("teacher valid loss: {teacher_valid:.4}");

    // --- students ----------------------------------------------------------
    let modes: Vec<(&str, KdMode)> = match only_mode.as_str() {
        "none" => vec![("scratch (L3, no KD)", KdMode::None)],
        "full" => vec![("full KD", KdMode::Full)],
        "staged" => vec![("staged KD (MoS)",
                          KdMode::Staged { frac: stop_frac })],
        _ => vec![
            ("scratch (L3, no KD)", KdMode::None),
            ("full KD", KdMode::Full),
            ("staged KD (MoS)", KdMode::Staged { frac: stop_frac }),
        ],
    };

    let mut table5 = Table::new(
        "Table 5 analogue — PR-MoE student under KD regimes",
        &["config", "params", "valid loss", "gap to teacher",
          "mean cloze %"],
    );
    let mut curves = Table::new(
        "Figs 5/6 — student validation curves",
        &std::iter::once("step")
            .chain(modes.iter().map(|(n, _)| *n))
            .collect::<Vec<_>>(),
    );

    let mut histories = Vec::new();
    for (label, mode) in &modes {
        println!("=== student mos-s, {label}, {steps} steps ===");
        let mut d = Distiller::new(&manifest, "mos-s", &teacher_dir,
                                   sched(steps), *mode)?;
        d.run(&corpus, steps, eval_every, false)?;
        let valid = d.student.eval(&corpus, 8)?;
        let (_, acc) = d.student.zero_shot(&suite, 8)?;
        table5.row(&[
            label.to_string(),
            d.student.param_count().to_string(),
            f2(valid),
            format!("{:+.4}", valid - teacher_valid),
            format!("{:.1}", 100.0 * acc),
        ]);
        if let KdMode::Staged { .. } = mode {
            d.student.save("checkpoints/mos-s")?;
        }
        histories.push((label.to_string(), d.student.history.clone()));
    }

    if let Some((_, first)) = histories.first() {
        for (i, pt) in first.iter().enumerate() {
            let mut row = vec![pt.step.to_string()];
            for (_, h) in &histories {
                row.push(h.get(i).map(|p| f2(p.valid_loss)).unwrap_or_default());
            }
            curves.row(&row);
        }
    }
    curves.note(&format!("teacher (prmoe-s) valid loss: {teacher_valid:.4}"));
    curves.print();
    table5.print();
    curves.save_csv("fig5_6_distill_curves")?;
    table5.save_csv("table5_students")?;

    // Paper-shape summary
    if histories.len() == 3 {
        let fin = |i: usize| histories[i].1.last().unwrap().valid_loss;
        println!(
            "\npaper-shape checks:\n  staged KD ({:.4}) <= scratch ({:.4}): {}\n  \
             staged KD within 0.05 of teacher ({:.4}): {}",
            fin(2), fin(0),
            if fin(2) <= fin(0) + 0.01 { "yes" } else { "no" },
            teacher_valid,
            if (fin(2) - teacher_valid).abs() < 0.05 { "yes" } else { "no" },
        );
    }
    Ok(())
}
