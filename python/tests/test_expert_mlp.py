"""Grouped expert FFN kernel vs reference (plain + tiled variants)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import expert_mlp, moe_layer, ref


def _params(rng, e, m, f):
    return (
        jnp.asarray(rng.randn(e, m, f).astype(np.float32) * 0.1),
        jnp.asarray(rng.randn(e, f).astype(np.float32) * 0.1),
        jnp.asarray(rng.randn(e, f, m).astype(np.float32) * 0.1),
        jnp.asarray(rng.randn(e, m).astype(np.float32) * 0.1),
    )


@settings(max_examples=20, deadline=None)
@given(
    e=st.integers(min_value=1, max_value=8),
    c=st.integers(min_value=1, max_value=16),
    m=st.sampled_from([4, 8, 16]),
    f=st.sampled_from([8, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_expert_ffn_matches_ref(e, c, m, f, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(e, c, m).astype(np.float32))
    w1, b1, w2, b2 = _params(rng, e, m, f)
    got = expert_mlp.expert_ffn(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    e=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**16),
    bc=st.sampled_from([2, 4, 8]),
    bf=st.sampled_from([8, 16]),
)
def test_expert_ffn_tiled_matches_plain(e, seed, bc, bf):
    c, m, f = 8, 16, 16  # divisible by all sampled tile sizes
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(e, c, m).astype(np.float32))
    w1, b1, w2, b2 = _params(rng, e, m, f)
    plain = expert_mlp.expert_ffn(x, w1, b1, w2, b2)
    tiled = expert_mlp.expert_ffn_tiled(x, w1, b1, w2, b2,
                                        block_c=bc, block_f=bf)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(plain),
                               rtol=1e-4, atol=1e-5)


def test_experts_are_independent():
    """Changing expert j's weights must not change expert i's output."""
    rng = np.random.RandomState(5)
    e, c, m, f = 4, 4, 8, 16
    x = jnp.asarray(rng.randn(e, c, m).astype(np.float32))
    w1, b1, w2, b2 = _params(rng, e, m, f)
    base = np.asarray(expert_mlp.expert_ffn(x, w1, b1, w2, b2))
    w1_mut = w1.at[2].set(w1[2] * 3.0)
    mut = np.asarray(expert_mlp.expert_ffn(x, w1_mut, b1, w2, b2))
    for i in range(e):
        if i == 2:
            assert not np.allclose(mut[i], base[i])
        else:
            np.testing.assert_array_equal(mut[i], base[i])


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(min_value=2, max_value=32),
    e=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_full_fused_layer_matches_ref(s, e, seed):
    m, f = 8, 16
    cap = max(1, s // e)
    rng = np.random.RandomState(seed)
    tokens = jnp.asarray(rng.randn(s, m).astype(np.float32))
    gw = jnp.asarray(rng.randn(m, e).astype(np.float32) * 0.1)
    w1, b1, w2, b2 = _params(rng, e, m, f)
    got, aux_g, _ = moe_layer.moe_layer_fused(tokens, gw, w1, b1, w2, b2, cap)
    want, aux_w = ref.moe_layer_ref(tokens, gw, w1, b1, w2, b2, cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_w), rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    s=st.integers(min_value=4, max_value=24),
    e=st.integers(min_value=3, max_value=6),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_full_fused_layer_top2_matches_ref(s, e, seed):
    m, f = 8, 16
    cap = max(2, (2 * s) // e)
    rng = np.random.RandomState(seed)
    tokens = jnp.asarray(rng.randn(s, m).astype(np.float32))
    gw = jnp.asarray(rng.randn(m, e).astype(np.float32) * 0.1)
    w1, b1, w2, b2 = _params(rng, e, m, f)
    got, _, _ = moe_layer.moe_layer_fused(tokens, gw, w1, b1, w2, b2, cap,
                                          top2=True)
    want, _ = ref.moe_layer_ref(tokens, gw, w1, b1, w2, b2, cap, top2=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_residual_moe_layer():
    """Residual-MoE = dense MLP branch + routed expert branch."""
    rng = np.random.RandomState(9)
    s, e, m, f = 16, 4, 8, 16
    tokens = jnp.asarray(rng.randn(s, m).astype(np.float32))
    gw = jnp.asarray(rng.randn(m, e).astype(np.float32) * 0.1)
    w1, b1, w2, b2 = _params(rng, e, m, f)
    mw1 = jnp.asarray(rng.randn(m, f).astype(np.float32) * 0.1)
    mb1 = jnp.asarray(rng.randn(f).astype(np.float32) * 0.1)
    mw2 = jnp.asarray(rng.randn(f, m).astype(np.float32) * 0.1)
    mb2 = jnp.asarray(rng.randn(m).astype(np.float32) * 0.1)
    out, aux, _ = moe_layer.residual_moe_layer_fused(
        tokens, mw1, mb1, mw2, mb2, gw, w1, b1, w2, b2, s)
    import jax
    dense = jnp.dot(jax.nn.gelu(jnp.dot(tokens, mw1) + mb1), mw2) + mb2
    moe, _ = ref.moe_layer_ref(tokens, gw, w1, b1, w2, b2, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense + moe),
                               rtol=1e-4, atol=1e-5)
