"""L2 model tests: variant construction, prefill/decode consistency,
pallas-vs-ref forward parity, and training-step behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model


TINY = configs.ModelConfig(
    name="tiny-test", vocab_size=64, n_layers=2, d_model=16, n_heads=2,
    d_ff=32, max_seq=16, experts_schedule=(0, 4))
TINY_RES = configs.ModelConfig(
    name="tiny-res", vocab_size=64, n_layers=2, d_model=16, n_heads=2,
    d_ff=32, max_seq=16, experts_schedule=(0, 4), residual=True)
TINY_DENSE = configs.ModelConfig(
    name="tiny-dense", vocab_size=64, n_layers=2, d_model=16, n_heads=2,
    d_ff=32, max_seq=16)


def _toks(cfg, b, s, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(
        rng.randint(0, cfg.vocab_size, (b, s)).astype(np.int32))


@pytest.mark.parametrize("cfg", [TINY, TINY_RES, TINY_DENSE])
def test_param_specs_match_init(cfg):
    specs = model.param_specs(cfg)
    flat = model.init_params(cfg, 3)
    assert len(specs) == len(flat)
    for (name, shape), arr in zip(specs, flat):
        assert tuple(arr.shape) == tuple(shape), name
    total = sum(int(np.prod(s)) for _, s in specs)
    assert total == cfg.num_params()


def test_registry_param_counts():
    for name, cfg in configs.REGISTRY.items():
        specs = model.param_specs(cfg)
        total = sum(int(np.prod(s)) for _, s in specs)
        assert total == cfg.num_params(), name


@pytest.mark.parametrize("cfg", [TINY, TINY_RES, TINY_DENSE])
def test_prefill_decode_equals_forward(cfg):
    flat = model.init_params(cfg, 0)
    B, S = 2, 6
    toks = _toks(cfg, B, S + 1)
    logits_full, _ = model.forward(flat, toks, cfg, use_pallas=False,
                                   full_capacity=True)
    logits_p, kc, vc = model.prefill(flat, toks[:, :-1], cfg,
                                     use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(logits_full)[:, :S], rtol=2e-3,
        atol=1e-4)
    pos = jnp.full((B,), S, jnp.int32)
    logits_d, _, _ = model.decode_step(flat, toks[:, -1], kc, vc, pos, cfg,
                                       use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_full)[:, -1], rtol=2e-3,
        atol=1e-4)


def test_decode_with_ragged_positions():
    """Lanes at different sequence lengths decode independently."""
    cfg = TINY
    flat = model.init_params(cfg, 1)
    B = 2
    toks = _toks(cfg, B, 8)
    # lane 0 has 4 tokens of context, lane 1 has 7
    _, kc, vc = model.prefill(flat, toks, cfg, use_pallas=False)
    pos = jnp.asarray([4, 7], jnp.int32)
    nxt = jnp.asarray([5, 9], jnp.int32)
    logits, kc2, vc2 = model.decode_step(flat, nxt, kc, vc, pos, cfg,
                                         use_pallas=False)
    # compare lane 0 against a forward over its true 5-token prefix
    seq0 = jnp.concatenate([toks[0, :4], jnp.asarray([5], jnp.int32)])
    ref, _ = model.forward(flat, seq0[None, :], cfg, use_pallas=False,
                           full_capacity=True)
    # build the same 5-length prefill+decode for a batch of B by masking is
    # complex; instead check lane 0 logits match the B=1 decode path
    _, kc1, vc1 = model.prefill(flat, toks[:1], cfg, use_pallas=False)
    l1, _, _ = model.decode_step(flat, jnp.asarray([5], jnp.int32), kc1, vc1,
                                 jnp.asarray([4], jnp.int32), cfg,
                                 use_pallas=False)
    np.testing.assert_allclose(np.asarray(logits)[0], np.asarray(l1)[0],
                               rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ref)[0, -1], np.asarray(l1)[0],
                               rtol=2e-3, atol=1e-4)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_pallas_forward_matches_ref(seed):
    cfg = TINY_RES
    flat = model.init_params(cfg, seed % 7)
    toks = _toks(cfg, 2, 8, seed)
    a, _ = model.forward(flat, toks, cfg, use_pallas=True,
                         full_capacity=True)
    b, _ = model.forward(flat, toks, cfg, use_pallas=False,
                         full_capacity=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=1e-4)


def test_train_step_decreases_loss_all_variant_kinds():
    for cfg in [TINY, TINY_RES, TINY_DENSE]:
        flat = model.init_params(cfg, 0)
        m = [jnp.zeros_like(p) for p in flat]
        v = [jnp.zeros_like(p) for p in flat]
        batch = _toks(cfg, 4, 9)
        ts = jax.jit(lambda p_, m_, v_, b_, s_, lr_, cfg=cfg:
                     model.train_step(p_, m_, v_, b_, s_, lr_, cfg))
        first = None
        for step in range(1, 13):
            flat, m, v, loss, ce, aux = ts(
                flat, m, v, batch, jnp.asarray(step, jnp.int32),
                jnp.asarray(2e-3, jnp.float32))
            if step == 1:
                first = float(loss)
        assert float(loss) < first, cfg.name


def test_distill_step_moves_student_toward_teacher():
    cfg = TINY_RES
    teacher = model.init_params(cfg, 42)
    student = model.init_params(cfg, 7)
    m = [jnp.zeros_like(p) for p in student]
    v = [jnp.zeros_like(p) for p in student]
    batch = _toks(cfg, 4, 9)
    t_logits = model.teacher_logits_fn(teacher, batch, cfg)

    def kl_to_teacher(params):
        s_logits, _ = model.forward(params, batch[:, :-1], cfg,
                                    use_pallas=False)
        tl = jax.nn.log_softmax(t_logits, -1)
        sl = jax.nn.log_softmax(s_logits, -1)
        return float(jnp.sum(jnp.exp(tl) * (tl - sl), -1).mean())

    # Differential check: training with a strong KD term must end closer to
    # the teacher than training with the KD term disabled (alpha=0), from
    # the same initialization.  (Absolute KL can rise early because the CE
    # term dominates near init.)
    ds = jax.jit(lambda p_, m_, v_, b_, t_, a_, s_, lr_:
                 model.distill_step(p_, m_, v_, b_, t_, a_, s_, lr_, cfg))

    def run(alpha):
        p = [jnp.array(x) for x in student]
        mm = [jnp.zeros_like(x) for x in p]
        vv = [jnp.zeros_like(x) for x in p]
        for step in range(1, 13):
            p, mm, vv, loss, ce, kl = ds(
                p, mm, vv, batch, t_logits,
                jnp.asarray(alpha, jnp.float32),
                jnp.asarray(step, jnp.int32),
                jnp.asarray(2e-3, jnp.float32))
        return kl_to_teacher(p)

    assert run(8.0) < run(0.0)


def test_eval_loss_matches_manual_ce():
    cfg = TINY_DENSE
    flat = model.init_params(cfg, 0)
    batch = _toks(cfg, 2, 9)
    got = float(model.eval_loss(flat, batch, cfg))
    logits, _ = model.forward(flat, batch[:, :-1], cfg, use_pallas=False)
    logp = jax.nn.log_softmax(logits, -1)
    want = float(-jnp.take_along_axis(
        logp, batch[:, 1:][..., None], axis=-1).mean())
    assert abs(got - want) < 1e-6


def test_capacity_semantics():
    assert TINY.capacity(512, 8) == 128  # cf=2.0
    assert TINY.capacity(1, 128) == 1
    assert TINY.moe_layers_note() if hasattr(TINY, "moe_layers_note") else True


def test_pyramid_schedule_shape():
    cfg = configs.get("prmoe-s")
    sched = cfg.experts_schedule
    nz = [e for e in sched if e]
    assert nz == sorted(nz), "pyramid must be non-decreasing with depth"
    assert cfg.residual


def test_half_schedules():
    fh = configs.get("moe-s-8-firsthalf").experts_schedule
    sh = configs.get("moe-s-8-secondhalf").experts_schedule
    n = len(fh)
    assert all(e == 0 for e in fh[n // 2:])
    assert all(e == 0 for e in sh[:n // 2])
    assert sum(1 for e in fh if e) == sum(1 for e in sh if e)
