"""Scatter/gather layout kernels vs sparse-einsum reference."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import gating, layout, ref


def _setup(seed, s, e, m, cap):
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(s, e).astype(np.float32))
    tokens = jnp.asarray(rng.randn(s, m).astype(np.float32))
    return logits, tokens


@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(min_value=1, max_value=48),
    e=st.integers(min_value=2, max_value=8),
    m=st.sampled_from([4, 8, 16]),
    cap_frac=st.floats(min_value=0.2, max_value=2.0),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_scatter_matches_ref(s, e, m, cap_frac, seed):
    cap = max(1, int(cap_frac * s / e))
    logits, tokens = _setup(seed, s, e, m, cap)
    combine, dispatch, _, _ = ref.top1_gating_ref(logits, cap)
    eidx, gate, slot, keep = gating.top1_gating(logits, cap)
    got = layout.scatter_tokens(tokens, eidx, slot, e, cap)
    want = ref.scatter_tokens_ref(tokens, dispatch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(min_value=1, max_value=48),
    e=st.integers(min_value=2, max_value=8),
    m=st.sampled_from([4, 8]),
    cap_frac=st.floats(min_value=0.2, max_value=2.0),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_gather_matches_ref(s, e, m, cap_frac, seed):
    cap = max(1, int(cap_frac * s / e))
    logits, tokens = _setup(seed, s, e, m, cap)
    combine, dispatch, _, _ = ref.top1_gating_ref(logits, cap)
    eidx, gate, slot, keep = gating.top1_gating(logits, cap)
    rng = np.random.RandomState(seed + 1)
    expert_out = jnp.asarray(rng.randn(e, cap, m).astype(np.float32))
    got = layout.gather_tokens(expert_out, eidx, slot, gate, keep)
    want = ref.gather_tokens_ref(expert_out, combine)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(min_value=2, max_value=32),
    e=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_scatter_gather_roundtrip_identity(s, e, seed):
    """With full capacity and identity experts, gather(scatter(x)) scales each
    token by its gate prob — the permutation property of the layout kernels."""
    m, cap = 8, s  # full capacity: nothing dropped
    logits, tokens = _setup(seed, s, e, m, cap)
    eidx, gate, slot, keep = gating.top1_gating(logits, cap)
    blocks = layout.scatter_tokens(tokens, eidx, slot, e, cap)
    back = layout.gather_tokens(blocks, eidx, slot, gate, keep)
    want = np.asarray(tokens) * np.asarray(gate)[:, None]
    np.testing.assert_allclose(np.asarray(back), want, rtol=1e-5, atol=1e-6)


def test_dropped_tokens_zeroed():
    # capacity 1, all tokens routed to the same expert -> only one survives.
    s, e, m = 6, 3, 4
    logits = jnp.asarray(
        np.tile([5.0, 0.0, 0.0], (s, 1)).astype(np.float32))
    tokens = jnp.asarray(np.random.RandomState(0).randn(s, m).astype(np.float32))
    eidx, gate, slot, keep = gating.top1_gating(logits, 1)
    assert np.asarray(keep).sum() == 1
    blocks = layout.scatter_tokens(tokens, eidx, slot, e, 1)
    out = layout.gather_tokens(blocks, eidx, slot, gate, keep)
    out = np.asarray(out)
    assert np.count_nonzero(out.any(axis=1)) == 1  # only the kept token
    np.testing.assert_allclose(
        out[0], np.asarray(tokens)[0] * np.asarray(gate)[0], rtol=1e-5)


def test_trash_row_not_in_output():
    """Dropped tokens write to the trash slot; it must never leak."""
    s, e, m, cap = 8, 2, 4, 2
    logits = jnp.zeros((s, e), jnp.float32)  # all to expert 0, 6 dropped
    tokens = jnp.ones((s, m), jnp.float32) * 7.0
    eidx, gate, slot, keep = gating.top1_gating(logits, cap)
    blocks = np.asarray(layout.scatter_tokens(tokens, eidx, slot, e, cap))
    assert blocks.shape == (e, cap, m)
    # expert 0 has exactly `cap` rows filled; expert 1 all zeros.
    assert np.count_nonzero(blocks[0].any(axis=1)) == cap
    assert not blocks[1].any()
