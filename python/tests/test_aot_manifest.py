"""Manifest/ABI consistency: the exported artifacts must describe exactly
what the Rust side will load.  Skipped when `make artifacts` has not run."""

import hashlib
import json
import os

import numpy as np
import pytest

from compile import aot, configs, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        return json.load(f)


def test_every_registry_model_exported(manifest):
    for name in configs.REGISTRY:
        assert name in manifest["models"], name


def test_param_layout_matches_registry(manifest):
    for name, entry in manifest["models"].items():
        cfg = configs.get(name)
        specs = model.param_specs(cfg)
        assert len(entry["params"]) == len(specs), name
        for got, (want_name, want_shape) in zip(entry["params"], specs):
            assert got["name"] == want_name
            assert tuple(got["shape"]) == tuple(want_shape)
        assert entry["config"]["num_params"] == cfg.num_params()


def test_all_program_files_exist(manifest):
    count = 0
    for entry in manifest["models"].values():
        for prog in entry["programs"].values():
            path = os.path.join(ART, prog["file"])
            assert os.path.exists(path), path
            count += 1
    for prog in manifest["shared"].values():
        assert os.path.exists(os.path.join(ART, prog["file"]))
        count += 1
    assert count > 100  # the full export is substantial


def test_checkpoint_sizes_match_meta(manifest):
    for name, entry in manifest["models"].items():
        d = os.path.join(ART, entry["checkpoint"])
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        size = os.path.getsize(os.path.join(d, "params.bin"))
        assert size == meta["total_elems"] * 4, name
        assert meta["model"] == name
        # offsets are contiguous and ordered
        off = 0
        for p in meta["params"]:
            assert p["offset"] == off
            off += p["nelems"]
        assert off == meta["total_elems"]


def test_train_program_arity(manifest):
    for name, entry in manifest["models"].items():
        n = len(entry["params"])
        ts = entry["programs"].get("train_step")
        if ts is None:
            continue
        # params + m + v + batch + step + lr
        assert len(ts["inputs"]) == 3 * n + 3, name
        # params' + m' + v' + loss + ce + aux
        assert len(ts["outputs"]) == 3 * n + 3, name


def test_serve_program_shapes(manifest):
    for name in aot.SERVE_MODELS:
        entry = manifest["models"][name]
        cfg = entry["config"]
        for b in aot.DECODE_BATCH_SIZES:
            dec = entry["programs"][f"decode_b{b}"]
            # last four inputs: token, k, v, pos
            tok, k, v, pos = dec["inputs"][-4:]
            assert tok["shape"] == [b]
            assert k["shape"] == [cfg["n_layers"], b, cfg["n_heads"],
                                  cfg["max_seq"],
                                  cfg["d_model"] // cfg["n_heads"]]
            assert pos["shape"] == [b]
            logits = dec["outputs"][0]
            assert logits["shape"] == [b, cfg["vocab_size"]]


def test_manifest_schema_v2(manifest):
    """Schema v2: version stamp + the dtype capability block the serving
    stack gates its compression toggles on."""
    assert manifest["schema_version"] == aot.MANIFEST_SCHEMA_VERSION
    caps = manifest["capabilities"]
    # f32 must always be declared — it is the default everything falls
    # back to; the compressed ladders ride along.
    assert "f32" in caps["expert_dtypes"]
    assert "f32" in caps["wire_dtypes"]
    assert set(caps["expert_dtypes"]) >= {"bf16", "i8"}
    assert set(caps["wire_dtypes"]) >= {"f16", "bf16"}


def test_provenance_helpers_are_deterministic_sha256():
    """The provenance stamps are pure functions of the compiler state:
    same process, same digests, well-formed SHA-256 hex."""
    a, b = aot.compiler_config_sha256(), aot.compiler_config_sha256()
    assert a == b
    assert len(a) == 64 and int(a, 16) >= 0
    s1, s2 = aot.source_digest(), aot.source_digest()
    assert s1 == s2
    assert len(s1) == 64 and int(s1, 16) >= 0
    # Different domains must not collide trivially.
    assert a != s1


def test_manifest_provenance_block(manifest):
    prov = manifest.get("provenance")
    if prov is None:
        pytest.skip("artifacts predate the provenance stamp")
    for field in ("compiler_config_sha256", "source_digest"):
        v = prov[field]
        assert len(v) == 64 and int(v, 16) >= 0, field
    # The config digest is recomputable: artifacts built under the current
    # registry/ladders/capabilities must stamp the same value (same spirit
    # as test_param_layout_matches_registry).  source_digest is only
    # shape-checked above — sources may legitimately have moved on since
    # the artifacts were built, and the stamp records what built them.
    assert prov["compiler_config_sha256"] == aot.compiler_config_sha256()


def _iter_programs(manifest):
    for entry in manifest["models"].values():
        yield from entry["programs"].values()
    yield from manifest["shared"].values()


def test_every_program_has_matching_sha256(manifest):
    """Each entry's sha256 matches the bytes on disk — the integrity
    check the Rust loader performs before compiling a program."""
    count = 0
    for prog in _iter_programs(manifest):
        digest = prog["sha256"]
        assert len(digest) == 64 and int(digest, 16) >= 0
        with open(os.path.join(ART, prog["file"]), "rb") as f:
            assert hashlib.sha256(f.read()).hexdigest() == digest, \
                prog["file"]
        count += 1
    assert count > 100


def test_hlo_files_are_text(manifest):
    entry = next(iter(manifest["models"].values()))
    prog = next(iter(entry["programs"].values()))
    with open(os.path.join(ART, prog["file"])) as f:
        head = f.read(200)
    assert head.startswith("HloModule"), "interchange must be HLO text"


def test_initial_checkpoint_statistics(manifest):
    """Init follows the documented scheme (unit LN gains, ~0.02 std)."""
    entry = manifest["models"]["dense-s"]
    d = os.path.join(ART, entry["checkpoint"])
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = np.fromfile(os.path.join(d, "params.bin"), dtype="<f4")
    by_name = {p["name"]: (p["offset"], p["nelems"]) for p in meta["params"]}
    off, n = by_name["layer0.ln1.g"]
    assert np.all(data[off:off + n] == 1.0)
    off, n = by_name["tok_emb"]
    emb = data[off:off + n]
    assert 0.01 < emb.std() < 0.03
    assert abs(float(emb.mean())) < 5e-3
