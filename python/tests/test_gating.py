"""Fused gating kernel vs reference oracle (hypothesis-swept)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gating, ref


def _logits(rng, s, e):
    return jnp.asarray(rng.randn(s, e).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    s=st.integers(min_value=1, max_value=64),
    e=st.integers(min_value=2, max_value=16),
    cap_frac=st.floats(min_value=0.1, max_value=2.0),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_top1_matches_ref(s, e, cap_frac, seed):
    capacity = max(1, int(cap_frac * s / e))
    rng = np.random.RandomState(seed)
    logits = _logits(rng, s, e)

    combine, dispatch, aux_r, eidx_r = ref.top1_gating_ref(logits, capacity)
    eidx, gate, slot, keep = gating.top1_gating(logits, capacity)

    np.testing.assert_array_equal(np.asarray(eidx), np.asarray(eidx_r))

    # keep/slot consistency with the reference dispatch tensor.
    disp = np.asarray(dispatch)
    for tok in range(s):
        if np.asarray(keep)[tok] > 0:
            ei, si = int(np.asarray(eidx)[tok]), int(np.asarray(slot)[tok])
            assert disp[tok, ei, si], f"token {tok} table/dispatch mismatch"
            # gate prob equals the combine weight at that coordinate
            np.testing.assert_allclose(
                np.asarray(gate)[tok], np.asarray(combine)[tok, ei, si],
                rtol=1e-5)
        else:
            assert not disp[tok].any(), f"dropped token {tok} in ref dispatch"


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(min_value=2, max_value=48),
    e=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_top1_capacity_never_exceeded(s, e, seed):
    capacity = max(1, s // e)
    rng = np.random.RandomState(seed)
    eidx, gate, slot, keep = gating.top1_gating(_logits(rng, s, e), capacity)
    eidx, slot, keep = map(np.asarray, (eidx, slot, keep))
    for expert in range(e):
        kept = (eidx == expert) & (keep > 0)
        slots = slot[kept]
        assert len(slots) <= capacity
        # slots are unique and dense-from-zero within each expert
        assert sorted(slots.tolist()) == list(range(len(slots)))


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(min_value=2, max_value=48),
    e=st.integers(min_value=3, max_value=8),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_top2_matches_ref(s, e, seed):
    capacity = max(2, (2 * s) // e)
    rng = np.random.RandomState(seed)
    logits = _logits(rng, s, e)
    combine_r, dispatch_r, aux_r, idx_r = ref.top2_gating_ref(logits, capacity)
    eidx, gate, slot, keep = gating.top2_gating(logits, capacity)
    np.testing.assert_array_equal(np.asarray(eidx), np.asarray(idx_r))
    # reconstruct combine from tables and compare
    S = s
    combine = np.zeros((S, e, capacity), np.float32)
    eidx, gate, slot, keep = map(np.asarray, (eidx, gate, slot, keep))
    for tok in range(S):
        for k in range(2):
            if keep[tok, k] > 0:
                combine[tok, eidx[tok, k], slot[tok, k]] += gate[tok, k]
    np.testing.assert_allclose(combine, np.asarray(combine_r),
                               rtol=1e-4, atol=1e-6)


def test_top1_deterministic():
    rng = np.random.RandomState(7)
    logits = _logits(rng, 32, 8)
    a = gating.top1_gating(logits, 8)
    b = gating.top1_gating(logits, 8)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_aux_loss_matches_ref():
    rng = np.random.RandomState(3)
    logits = _logits(rng, 64, 8)
    _, _, aux_r, eidx_r = ref.top1_gating_ref(logits, 64)
    aux = gating.load_balance_aux_loss(logits, eidx_r, 8)
    np.testing.assert_allclose(float(aux), float(aux_r), rtol=1e-5)


def test_aux_loss_uniform_is_one():
    # Perfectly uniform routing => aux loss == 1 (E * E * (1/E) * (1/E)).
    e = 4
    logits = jnp.zeros((e * 8, e), jnp.float32)
    # identical logits: argmax picks expert 0 for all -> worst case is E
    aux = gating.load_balance_aux_loss(
        logits, jnp.arange(e * 8, dtype=jnp.int32) % e, e)
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-6)


def test_all_tokens_kept_with_full_capacity():
    rng = np.random.RandomState(11)
    s, e = 40, 5
    eidx, gate, slot, keep = gating.top1_gating(_logits(rng, s, e), s)
    assert np.asarray(keep).sum() == s
