"""Model configuration registry — the tiny testbed family (DESIGN.md §4).

Mirrors the paper's Table 1 structurally: a dense base family plus MoE
variants that add experts on every other feedforward layer, PR-MoE variants
with a pyramid expert schedule + residual experts, and depth-reduced MoS
students.  The Rust side has the same presets in ``configs/*.toml``;
``test_aot_manifest.py`` checks the two stay in sync via the manifest.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one model variant.

    ``experts_schedule[i]`` is the number of experts on layer ``i`` (0 means
    the layer has a plain dense FFN).  The paper's "350M+MoE-128" pattern —
    experts on every other feedforward layer — corresponds to nonzero entries
    at odd indices.  ``residual=True`` gives each MoE layer a fixed dense MLP
    branch in parallel with the routed expert (Residual-MoE, §4.1.1).
    """

    name: str
    vocab_size: int = 512
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 64
    experts_schedule: tuple = ()  # empty => dense
    residual: bool = False
    top2: bool = False
    capacity_factor: float = 2.0
    moe_loss_coef: float = 0.01
    # Distillation (MoS students only)
    teacher: Optional[str] = None
    kd_alpha: float = 1.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return any(self.experts_schedule)

    def experts_at(self, layer: int) -> int:
        if not self.experts_schedule:
            return 0
        return self.experts_schedule[layer]

    def capacity(self, n_tokens: int, n_experts: int) -> int:
        """Expert capacity c_e for a given token count."""
        import math
        return max(1, math.ceil(self.capacity_factor * n_tokens / n_experts))

    def num_params(self) -> int:
        """Exact parameter count (matches init_params)."""
        V, L, M, F = self.vocab_size, self.n_layers, self.d_model, self.d_ff
        n = V * M + self.max_seq * M  # tok_emb (tied head) + pos_emb
        n += 2 * M  # final LN
        for i in range(L):
            n += 2 * M + 4 * M * M  # ln1 + wq/wk/wv/wo
            n += 2 * M  # ln2
            e = self.experts_at(i)
            if e == 0:
                n += M * F + F + F * M + M  # dense FFN
            else:
                n += M * e  # gate
                n += e * (M * F + F + F * M + M)  # stacked experts
                if self.residual:
                    n += M * F + F + F * M + M  # fixed residual MLP
        return n


def _every_other(n_layers: int, experts: int) -> tuple:
    """Experts on every other FFN layer (odd indices), as the paper."""
    return tuple(experts if i % 2 == 1 else 0 for i in range(n_layers))


def _pyramid(n_layers: int, lo: int, hi: int) -> tuple:
    """Pyramid schedule: MoE on odd layers; the last MoE layer(s) get ``hi``
    experts, earlier MoE layers get ``lo`` (paper Fig 3 right: deeper layers
    benefit from more experts)."""
    sched = []
    moe_layers = [i for i in range(n_layers) if i % 2 == 1]
    cut = max(1, len(moe_layers) - max(1, len(moe_layers) // 3))
    for i in range(n_layers):
        if i % 2 != 1:
            sched.append(0)
        else:
            sched.append(hi if moe_layers.index(i) >= cut else lo)
    return tuple(sched)


def _first_half(n_layers: int, experts: int) -> tuple:
    return tuple(
        experts if (i % 2 == 1 and i < n_layers // 2) else 0
        for i in range(n_layers))


def _second_half(n_layers: int, experts: int) -> tuple:
    return tuple(
        experts if (i % 2 == 1 and i >= n_layers // 2) else 0
        for i in range(n_layers))


# ---------------------------------------------------------------------------
# The registry.  Sizes follow DESIGN.md §4: dense-s is the "350M" analogue,
# dense-m the "1.3B" (4x activated params via width), dense-l the "6.7B".
# moe-s-8 is "350M+MoE-128": same base as dense-s, 8 experts on every other
# FFN layer.  prmoe-s is "350M+PR-MoE-32/64": pyramid 4/8 + residual.
# mos-s is the depth-reduced PR-MoE student ("+L21+MoS": 4 -> 3 layers).
# ---------------------------------------------------------------------------

def _registry() -> List[ModelConfig]:
    L = 4
    cfgs = [
        ModelConfig(name="dense-s", n_layers=L, d_model=128, n_heads=4,
                    d_ff=512),
        ModelConfig(name="dense-m", n_layers=L, d_model=256, n_heads=8,
                    d_ff=1024),
        ModelConfig(name="dense-l", n_layers=6, d_model=384, n_heads=8,
                    d_ff=1536),
        ModelConfig(name="moe-s-8", n_layers=L, d_model=128, n_heads=4,
                    d_ff=512, experts_schedule=_every_other(L, 8)),
        ModelConfig(name="moe-s-4", n_layers=L, d_model=128, n_heads=4,
                    d_ff=512, experts_schedule=_every_other(L, 4)),
        ModelConfig(name="moe-m-8", n_layers=L, d_model=256, n_heads=8,
                    d_ff=1024, experts_schedule=_every_other(L, 8)),
        # Fig 2 (left): half-MoE ablations
        ModelConfig(name="moe-s-8-firsthalf", n_layers=L, d_model=128,
                    n_heads=4, d_ff=512,
                    experts_schedule=_first_half(L, 8)),
        ModelConfig(name="moe-s-8-secondhalf", n_layers=L, d_model=128,
                    n_heads=4, d_ff=512,
                    experts_schedule=_second_half(L, 8)),
        # Fig 2 (right): Top2 vs Residual
        ModelConfig(name="moe-s-4-top2", n_layers=L, d_model=128, n_heads=4,
                    d_ff=512, experts_schedule=_every_other(L, 4), top2=True),
        ModelConfig(name="moe-s-4-residual", n_layers=L, d_model=128,
                    n_heads=4, d_ff=512, experts_schedule=_every_other(L, 4),
                    residual=True),
        # Fig 4: pyramid-only ablation
        ModelConfig(name="moe-s-pyramid", n_layers=L, d_model=128, n_heads=4,
                    d_ff=512, experts_schedule=_pyramid(L, 4, 8)),
        # PR-MoE (§4.1.2): pyramid + residual
        ModelConfig(name="prmoe-s", n_layers=L, d_model=128, n_heads=4,
                    d_ff=512, experts_schedule=_pyramid(L, 4, 8),
                    residual=True),
        ModelConfig(name="prmoe-m", n_layers=L, d_model=256, n_heads=8,
                    d_ff=1024, experts_schedule=_pyramid(L, 4, 8),
                    residual=True),
        # MoS (§4.2): depth-reduced PR-MoE student distilled from prmoe-s
        ModelConfig(name="mos-s", n_layers=3, d_model=128, n_heads=4,
                    d_ff=512, experts_schedule=_pyramid(3, 4, 8),
                    residual=True, teacher="prmoe-s", kd_alpha=1.0),
        # Depth-reduced student trained from scratch (Table 5 row 2 analogue)
        ModelConfig(name="prmoe-s-l3", n_layers=3, d_model=128, n_heads=4,
                    d_ff=512, experts_schedule=_pyramid(3, 4, 8),
                    residual=True),
    ]
    return cfgs


REGISTRY = {c.name: c for c in _registry()}


def get(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown model {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
