"""AOT exporter: lower every program to HLO text + write the manifest.

This is the single build-time entry point (``make artifacts``).  It emits,
under ``artifacts/``:

* ``<model>/<program>.hlo.txt`` — HLO **text** for every exported program
  (text, not serialized proto: the image's xla_extension 0.5.1 rejects
  jax>=0.5 protos with 64-bit instruction ids; the text parser reassigns
  ids — see /opt/xla-example/README.md).
* ``shared/<program>.hlo.txt`` — layer-granular programs for the
  disaggregated expert-parallel serving path (the Rust coordinator composes
  these, inserting the all-to-all between gate and expert FFN).
* ``ckpt/<model>/`` — initial parameter checkpoints (meta.json + params.bin,
  f32 little-endian in ``param_specs`` order) that the Rust training driver
  reads, updates and re-writes.
* ``manifest.json`` — machine-readable index of all of the above: program
  file paths, positional input/output specs (name, shape, dtype), model
  configs and parameter layouts, plus (schema v2) a ``sha256`` digest per
  program file that the Rust loader verifies before compiling, and a
  ``capabilities`` block declaring which expert-weight ladder dtypes and
  activation wire dtypes the serving stack may enable against these
  artifacts, and a ``provenance`` block (``compiler_config_sha256`` over
  the canonicalized registry/ladders/capabilities, ``source_digest`` over
  the sorted compiler sources) that records which compiler at which
  configuration produced the artifacts.  This file is the ABI between the
  Python build path and the Rust runtime.

Python runs ONCE; after this, the Rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model

# Batch sizes compiled for serving; the Rust batcher rounds up to one of
# these.  Prefill sequence length is always cfg.max_seq (prompts padded).
DECODE_BATCH_SIZES = (1, 2, 4, 8)
PREFILL_BATCH_SIZES = (1, 2, 4, 8)
# Extra microbatch sizes for the EP engine's depth-N pipeline ring: a batch
# of B lanes split into N contiguous groups runs groups of ceil(B/N) and
# floor(B/N) lanes (8 lanes at depth 3 -> groups of 3, 3, 2), so the
# *shared* layer-granular ladders also carry these sizes.  3 is the only
# size the base ladders miss for B <= 8, N <= 4; the monolithic
# prefill_b{B}/decode_b{B} exports stay on the base ladder.
PIPELINE_MICROBATCH_SIZES = (3,)
SHARED_PREFILL_SIZES = tuple(
    sorted(set(PREFILL_BATCH_SIZES) | set(PIPELINE_MICROBATCH_SIZES)))
SHARED_DECODE_SIZES = tuple(
    sorted(set(DECODE_BATCH_SIZES) | set(PIPELINE_MICROBATCH_SIZES)))
# Expert-block capacities compiled for the disaggregated expert-FFN program;
# the coordinator pads each expert's token block up to the next one.
EXPERT_BLOCK_SIZES = (1, 4, 8, 16, 64, 256, 512)

# Training batch geometry (matches rust/src/training defaults).
TRAIN_BATCH, TRAIN_SEQ = 16, 32
EVAL_BATCH = 16

# Variants exported with serving (prefill/decode) programs.
SERVE_MODELS = ("dense-s", "dense-m", "dense-l", "moe-s-8", "prmoe-s",
                "mos-s")
# Variants exported with training programs (Figs 1/2/4/5/6, Tables 2/4/5).
TRAIN_MODELS = tuple(configs.REGISTRY)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype="f32", name=""):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


_DT = {"f32": jnp.float32, "i32": jnp.int32}

# Manifest ABI version.  v2 adds per-program sha256 digests and the
# capabilities block; the Rust loader accepts <= its own SCHEMA_VERSION
# (rust/src/runtime/artifact.rs) and treats absent as v1.
MANIFEST_SCHEMA_VERSION = 2
# Dtypes the serving stack may enable against these artifacts.  Programs
# stay f32 throughout — expert weights dequantize once at install and
# wire activations widen before compute — so every ladder the Rust side
# implements is safe to declare here.
CAPABILITIES = {
    "expert_dtypes": ["f32", "bf16", "i8"],
    "wire_dtypes": ["f32", "f16", "bf16"],
}


def compiler_config_sha256() -> str:
    """Digest of the compiler configuration that shapes the artifacts.

    Covers the model registry (every variant's full config), the
    batch/capacity shape ladders, the training geometry, and the
    capability flags — everything that changes what gets compiled without
    being a source edit.  Deterministic: canonical JSON, sorted keys.
    Two artifact sets with equal stamps were compiled under the same
    configuration.
    """
    import dataclasses

    payload = {
        "schema_version": MANIFEST_SCHEMA_VERSION,
        "capabilities": CAPABILITIES,
        "decode_batch_sizes": list(DECODE_BATCH_SIZES),
        "prefill_batch_sizes": list(PREFILL_BATCH_SIZES),
        "pipeline_microbatch_sizes": list(PIPELINE_MICROBATCH_SIZES),
        "expert_block_sizes": list(EXPERT_BLOCK_SIZES),
        "train_geometry": [TRAIN_BATCH, TRAIN_SEQ, EVAL_BATCH],
        "registry": {
            name: dataclasses.asdict(configs.get(name))
            for name in configs.REGISTRY
        },
    }
    text = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(text.encode()).hexdigest()


def source_digest() -> str:
    """SHA-256 over the compiler's own sources.

    Walks every ``.py`` under ``python/compile/`` (including the kernels
    subpackage) in sorted relative-path order, hashing path and contents,
    so a manifest records exactly which compiler produced it.
    """
    root = os.path.dirname(os.path.abspath(__file__))
    paths = []
    for dirpath, _, files in os.walk(root):
        paths.extend(
            os.path.join(dirpath, fn) for fn in files if fn.endswith(".py"))
    h = hashlib.sha256()
    for p in sorted(paths, key=lambda p: os.path.relpath(p, root)):
        h.update(os.path.relpath(p, root).encode())
        h.update(b"\0")
        with open(p, "rb") as f:
            h.update(f.read())
        h.update(b"\0")
    return h.hexdigest()


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"schema_version": MANIFEST_SCHEMA_VERSION,
                         "capabilities": CAPABILITIES,
                         "provenance": {
                             "compiler_config_sha256":
                                 compiler_config_sha256(),
                             "source_digest": source_digest(),
                         },
                         "models": {}, "shared": {}}

    def export_program(self, rel_name: str, fn: Callable,
                       inputs: List[dict], outputs: List[dict]) -> dict:
        """Lower ``fn`` against ``inputs`` specs and write HLO text."""
        arg_specs = [_sds(s["shape"], _DT[s["dtype"]]) for s in inputs]
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, rel_name + ".hlo.txt")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(text)
        entry = {"file": rel_name + ".hlo.txt",
                 "sha256": hashlib.sha256(text.encode()).hexdigest(),
                 "inputs": inputs, "outputs": outputs}
        print(f"  wrote {rel_name}: {len(inputs)} in / {len(outputs)} out, "
              f"{len(text) // 1024} KiB")
        return entry

    # -- checkpoints --------------------------------------------------------

    def write_checkpoint(self, cfg: configs.ModelConfig, seed: int) -> str:
        flat = model.init_params(cfg, seed)
        specs = model.param_specs(cfg)
        rel = os.path.join("ckpt", cfg.name)
        d = os.path.join(self.out_dir, rel)
        os.makedirs(d, exist_ok=True)
        meta, offset = [], 0
        with open(os.path.join(d, "params.bin"), "wb") as f:
            for (name, shape), arr in zip(specs, flat):
                a = np.asarray(arr, np.float32)
                f.write(a.tobytes())
                meta.append({"name": name, "shape": list(shape),
                             "dtype": "f32", "offset": offset,
                             "nelems": int(a.size)})
                offset += int(a.size)
        with open(os.path.join(d, "meta.json"), "w") as f:
            json.dump({"model": cfg.name, "step": 0, "total_elems": offset,
                       "params": meta}, f, indent=1)
        return rel

    # -- per-model programs --------------------------------------------------

    def export_model(self, name: str, serve: bool, train: bool):
        cfg = configs.get(name)
        print(f"model {name} ({cfg.num_params():,} params)")
        pspecs = model.param_specs(cfg)
        par_in = [_spec(s, "f32", "param:" + n) for n, s in pspecs]
        L, H, Smax, hd = (cfg.n_layers, cfg.n_heads, cfg.max_seq,
                          cfg.head_dim)
        V = cfg.vocab_size
        progs = {}

        if serve:
            for B in PREFILL_BATCH_SIZES:
                ins = par_in + [_spec((B, Smax), "i32", "tokens")]
                outs = [_spec((B, Smax, V), "f32", "logits"),
                        _spec((L, B, H, Smax, hd), "f32", "k_caches"),
                        _spec((L, B, H, Smax, hd), "f32", "v_caches")]
                fn = functools.partial(
                    lambda *a, cfg=cfg: model.prefill(
                        list(a[:-1]), a[-1], cfg, use_pallas=True))
                progs[f"prefill_b{B}"] = self.export_program(
                    f"{name}/prefill_b{B}", fn, ins, outs)
            for B in DECODE_BATCH_SIZES:
                ins = par_in + [
                    _spec((B,), "i32", "token"),
                    _spec((L, B, H, Smax, hd), "f32", "k_caches"),
                    _spec((L, B, H, Smax, hd), "f32", "v_caches"),
                    _spec((B,), "i32", "pos"),
                ]
                outs = [_spec((B, V), "f32", "logits"),
                        _spec((L, B, H, Smax, hd), "f32", "k_caches"),
                        _spec((L, B, H, Smax, hd), "f32", "v_caches")]
                n_par = len(pspecs)
                fn = (lambda *a, cfg=cfg, n=n_par: model.decode_step(
                    list(a[:n]), a[n], a[n + 1], a[n + 2], a[n + 3], cfg,
                    use_pallas=True))
                progs[f"decode_b{B}"] = self.export_program(
                    f"{name}/decode_b{B}", fn, ins, outs)

        if train:
            n = len(pspecs)
            batch_spec = _spec((TRAIN_BATCH, TRAIN_SEQ + 1), "i32", "batch")
            opt_in = ([_spec(s, "f32", "m:" + nm) for nm, s in pspecs]
                      + [_spec(s, "f32", "v:" + nm) for nm, s in pspecs])
            state_out = ([_spec(s, "f32", "param:" + nm) for nm, s in pspecs]
                         + [_spec(s, "f32", "m:" + nm) for nm, s in pspecs]
                         + [_spec(s, "f32", "v:" + nm) for nm, s in pspecs])

            ins = (par_in + opt_in
                   + [batch_spec, _spec((), "i32", "step"),
                      _spec((), "f32", "lr")])
            outs = state_out + [_spec((), "f32", "loss"),
                                _spec((), "f32", "ce"),
                                _spec((), "f32", "aux")]
            fn = (lambda *a, cfg=cfg, n=n: _flatten3(model.train_step(
                list(a[:n]), list(a[n:2 * n]), list(a[2 * n:3 * n]),
                a[3 * n], a[3 * n + 1], a[3 * n + 2], cfg)))
            progs["train_step"] = self.export_program(
                f"{name}/train_step", fn, ins, outs)

            ins = par_in + [_spec((EVAL_BATCH, TRAIN_SEQ + 1), "i32",
                                  "batch")]
            outs = [_spec((), "f32", "loss")]
            fn = (lambda *a, cfg=cfg, n=n:
                  (model.eval_loss(list(a[:n]), a[n], cfg),))
            progs["eval_loss"] = self.export_program(
                f"{name}/eval_loss", fn, ins, outs)

            # Full next-token logits over an eval batch: used by the Rust
            # zero-shot evaluation (cloze prediction, Tables 2/4/5).
            ins = par_in + [_spec((EVAL_BATCH, TRAIN_SEQ + 1), "i32",
                                  "batch")]
            outs = [_spec((EVAL_BATCH, TRAIN_SEQ, V), "f32", "logits")]
            fn = (lambda *a, cfg=cfg, n=n:
                  (model.teacher_logits_fn(list(a[:n]), a[n], cfg),))
            progs["logits"] = self.export_program(
                f"{name}/logits", fn, ins, outs)

            if cfg.teacher is not None:
                tcfg = configs.get(cfg.teacher)
                tspecs = model.param_specs(tcfg)
                tn = len(tspecs)
                t_in = [_spec(s, "f32", "param:" + nm) for nm, s in tspecs]
                ins = t_in + [batch_spec]
                outs = [_spec((TRAIN_BATCH, TRAIN_SEQ, V), "f32",
                              "teacher_logits")]
                fn = (lambda *a, tcfg=tcfg, tn=tn:
                      (model.teacher_logits_fn(list(a[:tn]), a[tn], tcfg),))
                progs["teacher_logits"] = self.export_program(
                    f"{name}/teacher_logits", fn, ins, outs)

                ins = (par_in + opt_in
                       + [batch_spec,
                          _spec((TRAIN_BATCH, TRAIN_SEQ, V), "f32",
                                "teacher_logits"),
                          _spec((), "f32", "kd_alpha"),
                          _spec((), "i32", "step"), _spec((), "f32", "lr")])
                outs = state_out + [_spec((), "f32", "loss"),
                                    _spec((), "f32", "ce"),
                                    _spec((), "f32", "kl")]
                fn = (lambda *a, cfg=cfg, n=n: _flatten3(model.distill_step(
                    list(a[:n]), list(a[n:2 * n]), list(a[2 * n:3 * n]),
                    a[3 * n], a[3 * n + 1], a[3 * n + 2], a[3 * n + 3],
                    a[3 * n + 4], cfg)))
                progs["distill_step"] = self.export_program(
                    f"{name}/distill_step", fn, ins, outs)

        ckpt = self.write_checkpoint(cfg, seed=hash(name) % (2 ** 31))
        self.manifest["models"][name] = {
            "config": {
                "name": cfg.name, "vocab_size": V, "n_layers": L,
                "d_model": cfg.d_model, "n_heads": H, "d_ff": cfg.d_ff,
                "max_seq": Smax,
                "experts_schedule": list(cfg.experts_schedule),
                "residual": cfg.residual, "top2": cfg.top2,
                "capacity_factor": cfg.capacity_factor,
                "moe_loss_coef": cfg.moe_loss_coef,
                "teacher": cfg.teacher, "kd_alpha": cfg.kd_alpha,
                "num_params": cfg.num_params(),
            },
            "params": [{"name": nm, "shape": list(s), "dtype": "f32"}
                       for nm, s in pspecs],
            "checkpoint": ckpt,
            "train_geometry": {"batch": TRAIN_BATCH, "seq": TRAIN_SEQ,
                               "eval_batch": EVAL_BATCH},
            "programs": progs,
        }

    # -- shared layer-granular programs (disaggregated serving path) --------

    def export_shared(self, dims: Sequence[Tuple[int, int, int]],
                      expert_dims: Sequence[Tuple[int, int]],
                      gate_dims: Sequence[Tuple[int, int]],
                      vocab_dims: Sequence[Tuple[int, int]],
                      smax: int):
        """Export per-layer programs for every distinct dimension tuple.

        dims: set of (M, H, F); expert_dims: (M, F); gate_dims: (M, E);
        vocab_dims: (V, M).
        """
        sh = self.manifest["shared"]
        for (V, M) in sorted(set(vocab_dims)):
            for B in SHARED_PREFILL_SIZES:
                key = f"embed_v{V}_m{M}_b{B}_s{smax}"
                ins = [_spec((V, M), "f32", "tok_emb"),
                       _spec((smax, M), "f32", "pos_emb"),
                       _spec((B, smax), "i32", "tokens"),
                       _spec((B,), "i32", "pos0")]
                outs = [_spec((B, smax, M), "f32", "h")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    lambda te, pe, t, p0: (model.prog_embed(te, pe, t, p0),),
                    ins, outs)
            for B in SHARED_DECODE_SIZES:
                key = f"embed_v{V}_m{M}_b{B}_s1"
                ins = [_spec((V, M), "f32", "tok_emb"),
                       _spec((smax, M), "f32", "pos_emb"),
                       _spec((B, 1), "i32", "tokens"),
                       _spec((B,), "i32", "pos0")]
                outs = [_spec((B, 1, M), "f32", "h")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    lambda te, pe, t, p0: (model.prog_embed(te, pe, t, p0),),
                    ins, outs)
                key = f"lm_head_v{V}_m{M}_b{B}"
                ins = [_spec((B, M), "f32", "h"),
                       _spec((M,), "f32", "ln_g"), _spec((M,), "f32", "ln_b"),
                       _spec((V, M), "f32", "tok_emb")]
                outs = [_spec((B, V), "f32", "logits")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    lambda h, g, b, te: (model.prog_lm_head(h, g, b, te),),
                    ins, outs)

        for (M, H, F) in sorted(set(dims)):
            hd = M // H
            for B in SHARED_PREFILL_SIZES:
                key = f"attn_prefill_m{M}_h{H}_b{B}_s{smax}"
                ins = ([_spec((B, smax, M), "f32", "h")]
                       + [_spec((M,), "f32", "ln_g"),
                          _spec((M,), "f32", "ln_b")]
                       + [_spec((M, M), "f32", w)
                          for w in ("wq", "wk", "wv", "wo")])
                outs = [_spec((B, smax, M), "f32", "h"),
                        _spec((B, H, smax, hd), "f32", "k"),
                        _spec((B, H, smax, hd), "f32", "v")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    functools.partial(model.prog_attn_prefill, n_heads=H),
                    ins, outs)
                # LM-head tail: gather each lane's last-position row at the
                # device level so the leader never pulls [B,smax,M] host-side.
                key = f"gather_last_m{M}_b{B}_s{smax}"
                ins = [_spec((B, smax, M), "f32", "h"),
                       _spec((B,), "i32", "lens")]
                outs = [_spec((B, M), "f32", "last")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    lambda h, lens: (model.prog_gather_last(h, lens),),
                    ins, outs)
            for B in SHARED_DECODE_SIZES:
                key = f"attn_decode_m{M}_h{H}_b{B}_s{smax}"
                ins = ([_spec((B, 1, M), "f32", "h")]
                       + [_spec((M,), "f32", "ln_g"),
                          _spec((M,), "f32", "ln_b")]
                       + [_spec((M, M), "f32", w)
                          for w in ("wq", "wk", "wv", "wo")]
                       + [_spec((B, H, smax, hd), "f32", "k_cache"),
                          _spec((B, H, smax, hd), "f32", "v_cache"),
                          _spec((B,), "i32", "pos")])
                outs = [_spec((B, 1, M), "f32", "h"),
                        _spec((B, H, smax, hd), "f32", "k_cache"),
                        _spec((B, H, smax, hd), "f32", "v_cache")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    functools.partial(model.prog_attn_decode, n_heads=H),
                    ins, outs)
            for T in sorted({b for b in SHARED_DECODE_SIZES}
                            | {b * smax for b in SHARED_PREFILL_SIZES}):
                key = f"dense_ffn_m{M}_f{F}_t{T}"
                # operates on [B,S,M]; flat T tokens as [1, T, M]
                ins = ([_spec((1, T, M), "f32", "h")]
                       + [_spec((M,), "f32", "ln_g"),
                          _spec((M,), "f32", "ln_b")]
                       + [_spec((M, F), "f32", "w1"), _spec((F,), "f32", "b1"),
                          _spec((F, M), "f32", "w2"),
                          _spec((M,), "f32", "b2")])
                outs = [_spec((1, T, M), "f32", "h")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    lambda h, g, b, w1, b1, w2, b2:
                    (model.prog_dense_ffn(h, g, b, w1, b1, w2, b2),),
                    ins, outs)

        for (M, E) in sorted(set(gate_dims)):
            for T in sorted({b for b in SHARED_DECODE_SIZES}
                            | {b * smax for b in SHARED_PREFILL_SIZES}):
                key = f"gate_m{M}_e{E}_t{T}"
                ins = [_spec((1, T, M), "f32", "h"),
                       _spec((M,), "f32", "ln_g"), _spec((M,), "f32", "ln_b"),
                       _spec((M, E), "f32", "gate_w")]
                outs = [_spec((T, M), "f32", "ln_h"),
                        _spec((T, E), "f32", "probs")]
                sh[key] = self.export_program(
                    "shared/" + key, model.prog_gate, ins, outs)

        for (M, F) in sorted(set(expert_dims)):
            for C in EXPERT_BLOCK_SIZES:
                key = f"expert_ffn_m{M}_f{F}_c{C}"
                ins = [_spec((C, M), "f32", "x"),
                       _spec((M, F), "f32", "w1"), _spec((F,), "f32", "b1"),
                       _spec((F, M), "f32", "w2"), _spec((M,), "f32", "b2")]
                outs = [_spec((C, M), "f32", "y")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    lambda x, w1, b1, w2, b2:
                    (model.prog_expert_ffn(x, w1, b1, w2, b2),),
                    ins, outs)
            for T in sorted({b for b in SHARED_DECODE_SIZES}
                            | {b * smax for b in SHARED_PREFILL_SIZES}):
                key = f"residual_branch_m{M}_f{F}_t{T}"
                ins = [_spec((T, M), "f32", "x"),
                       _spec((M, F), "f32", "w1"), _spec((F,), "f32", "b1"),
                       _spec((F, M), "f32", "w2"), _spec((M,), "f32", "b2")]
                outs = [_spec((T, M), "f32", "y")]
                sh[key] = self.export_program(
                    "shared/" + key,
                    lambda x, w1, b1, w2, b2:
                    (model.prog_residual_branch(x, w1, b1, w2, b2),),
                    ins, outs)


    def export_kernel_bench(self):
        """Fused vs sparse-einsum MoE layer pairs (§5.4 kernel study).

        Same math, two data paths: `kb_fused_*` lowers the Pallas kernels
        (dense mapping table), `kb_ref_*` lowers the one-hot einsum
        formulation (the paper's baseline, S x E x M x c_e).  The Rust
        bench `benches/kernel_latency.rs` times both executables.
        """
        from .kernels import moe_layer as k_moe
        from .kernels import ref as k_ref

        S, M, F = 256, 128, 256
        for E in (4, 8, 16, 32):
            cap = max(1, 2 * S // E)
            ins = [_spec((S, M), "f32", "tokens"),
                   _spec((M, E), "f32", "gate_w"),
                   _spec((E, M, F), "f32", "w1"), _spec((E, F), "f32", "b1"),
                   _spec((E, F, M), "f32", "w2"), _spec((E, M), "f32", "b2")]
            outs = [_spec((S, M), "f32", "out"), _spec((), "f32", "aux")]
            self.manifest["shared"][f"kb_fused_e{E}"] = self.export_program(
                f"shared/kb_fused_e{E}",
                lambda t, g, w1, b1, w2, b2, cap=cap:
                k_moe.moe_layer_fused(t, g, w1, b1, w2, b2, cap)[:2],
                ins, outs)
            self.manifest["shared"][f"kb_ref_e{E}"] = self.export_program(
                f"shared/kb_ref_e{E}",
                lambda t, g, w1, b1, w2, b2, cap=cap:
                k_ref.moe_layer_ref(t, g, w1, b1, w2, b2, cap),
                ins, outs)


def _flatten3(res):
    """(list, list, list, *scalars) -> flat tuple for export."""
    new_p, new_m, new_v, *rest = res
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + tuple(rest)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="",
                    help="comma-separated subset (default: all)")
    ap.add_argument("--no-shared", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    ex = Exporter(args.out)

    subset = set(args.models.split(",")) if args.models else None
    for name in configs.REGISTRY:
        if subset and name not in subset:
            continue
        ex.export_model(name, serve=name in SERVE_MODELS,
                        train=name in TRAIN_MODELS)

    if not args.no_shared and (subset is None or subset & set(SERVE_MODELS)):
        dims, gate_dims, expert_dims, vocab_dims = set(), set(), set(), set()
        smax = None
        for name in SERVE_MODELS:
            if subset and name not in subset:
                continue
            cfg = configs.get(name)
            smax = cfg.max_seq
            dims.add((cfg.d_model, cfg.n_heads, cfg.d_ff))
            vocab_dims.add((cfg.vocab_size, cfg.d_model))
            for i in range(cfg.n_layers):
                e = cfg.experts_at(i)
                if e:
                    gate_dims.add((cfg.d_model, e))
                    expert_dims.add((cfg.d_model, cfg.d_ff))
        if smax is not None:
            ex.export_shared(dims, expert_dims, gate_dims, vocab_dims, smax)
        ex.export_kernel_bench()

    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(ex.manifest, f, indent=1)
    n_progs = (sum(len(m["programs"]) for m in ex.manifest["models"].values())
               + len(ex.manifest["shared"]))
    print(f"manifest: {n_progs} programs -> {path}")


if __name__ == "__main__":
    main()
