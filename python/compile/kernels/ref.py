"""Pure-jnp reference implementation of the MoE data path.

This file serves two roles:

1. **Correctness oracle** — pytest checks the fused Pallas kernels in
   ``gating.py`` / ``layout.py`` / ``expert_mlp.py`` against these functions
   (``assert_allclose`` over hypothesis-swept shapes).

2. **The paper's baseline** — DeepSpeed-MoE §5.4 describes the conventional
   MoE formulation as "highly sparse-dense einsums" over one-hot masks with
   complexity ``S x E x M x c_e``; the paper's contribution replaces it with a
   dense token->expert mapping table (``S x M x c_e``).  The functions here
   implement the einsum formulation verbatim (GShard-style), so the kernel
   benchmark (`benches/kernel_latency.rs` + `python/tests/test_kernel_perf.py`)
   can measure the fused-vs-einsum ratio the paper reports (~6x).

All functions are differentiable; the training path of the L2 model uses them
directly (the paper likewise trains with the standard formulation and applies
the fused kernels at inference).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def top1_gating_ref(logits, capacity):
    """Reference top-1 gating with capacity, via one-hot masks and cumsum.

    Args:
      logits: [S, E] router logits.
      capacity: int, max tokens per expert (c_e).

    Returns:
      combine: [S, E, C] float — combine weights (gate prob at the token's
        (expert, slot) coordinate, 0 elsewhere).  This is the GShard-style
        sparse "combine tensor" used by the einsum data path.
      dispatch: [S, E, C] bool — one-hot dispatch mask.
      aux_loss: scalar load-balancing auxiliary loss (Switch-style):
        E * sum_e (fraction_tokens_e * mean_prob_e).
      expert_idx: [S] int32 — argmax expert per token (for stats/tests).
    """
    S, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    expert_idx = jnp.argmax(probs, axis=-1)
    mask = jax.nn.one_hot(expert_idx, E, dtype=logits.dtype)  # [S, E]

    # Switch Transformer aux loss uses the *pre-capacity* assignment
    # fractions (dropping happens after the routing decision).
    fraction = jnp.mean(mask, axis=0)  # [E] fraction of tokens per expert
    mean_prob = jnp.mean(probs, axis=0)  # [E]
    aux_loss = E * jnp.sum(fraction * mean_prob)

    # Position of each token within its expert's queue (exclusive cumsum).
    position_in_expert = jnp.cumsum(mask, axis=0) * mask - mask  # [S, E]
    keep = (position_in_expert < capacity) & (mask > 0)  # [S, E] bool
    mask = mask * keep.astype(mask.dtype)

    gate = jnp.sum(probs * mask, axis=-1)  # [S] prob of kept assignment
    slot = jnp.sum(position_in_expert * mask, axis=-1).astype(jnp.int32)  # [S]

    slot_oh = jax.nn.one_hot(slot, capacity, dtype=logits.dtype)  # [S, C]
    dispatch = (mask[:, :, None] * slot_oh[:, None, :]) > 0  # [S, E, C]
    combine = gate[:, None, None] * dispatch.astype(logits.dtype)
    return combine, dispatch, aux_loss, expert_idx


def top2_gating_ref(logits, capacity):
    """Reference top-2 gating (paper's Top2-MoE ablation, Fig 2 right).

    Returns combine/dispatch of shape [S, E, C] plus aux loss.  Gate values of
    the two selected experts are renormalized to sum to 1.
    """
    S, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)

    idx1 = jnp.argmax(probs, axis=-1)
    mask1 = jax.nn.one_hot(idx1, E, dtype=logits.dtype)
    probs_wo1 = probs * (1.0 - mask1)
    idx2 = jnp.argmax(probs_wo1, axis=-1)
    mask2 = jax.nn.one_hot(idx2, E, dtype=logits.dtype)

    # Pre-capacity aux loss (first-choice fractions), as in top-1.
    fraction = jnp.mean(mask1, axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(fraction * mean_prob)

    # Slots: first-choice tokens occupy earlier slots (GShard ordering);
    # second choices queue behind all first choices of that expert.
    pos1 = jnp.cumsum(mask1, axis=0) * mask1 - mask1
    pos2 = (jnp.cumsum(mask2, axis=0) - mask2) + jnp.sum(mask1, axis=0)[None, :]
    pos2 = pos2 * mask2

    keep1 = (pos1 < capacity) & (mask1 > 0)
    keep2 = (pos2 < capacity) & (mask2 > 0)
    mask1 = mask1 * keep1.astype(mask1.dtype)
    mask2 = mask2 * keep2.astype(mask2.dtype)

    g1 = jnp.sum(probs * mask1, axis=-1)
    g2 = jnp.sum(probs * mask2, axis=-1)
    denom = jnp.maximum(g1 + g2, 1e-9)
    g1, g2 = g1 / denom, g2 / denom

    s1 = jnp.sum(pos1 * mask1, axis=-1).astype(jnp.int32)
    s2 = jnp.sum(pos2 * mask2, axis=-1).astype(jnp.int32)
    d1 = (mask1[:, :, None] * jax.nn.one_hot(s1, capacity)[:, None, :]) > 0
    d2 = (mask2[:, :, None] * jax.nn.one_hot(s2, capacity)[:, None, :]) > 0
    combine = (
        g1[:, None, None] * d1.astype(logits.dtype)
        + g2[:, None, None] * d2.astype(logits.dtype)
    )
    dispatch = d1 | d2
    return combine, dispatch, aux_loss, jnp.stack([idx1, idx2], axis=-1)


def scatter_tokens_ref(tokens, dispatch):
    """Sparse-einsum token dispatch (the paper's baseline data path).

    ``S x E x M x c_e`` complexity: every token multiplies against every
    (expert, slot) pair even though at most one is nonzero.

    Args:
      tokens: [S, M]; dispatch: [S, E, C] bool.
    Returns:
      expert_inputs: [E, C, M].
    """
    return jnp.einsum("sm,sec->ecm", tokens, dispatch.astype(tokens.dtype))


def gather_tokens_ref(expert_outputs, combine):
    """Sparse-einsum un-dispatch + gate scaling (baseline data path).

    Args:
      expert_outputs: [E, C, M]; combine: [S, E, C].
    Returns:
      tokens: [S, M] = sum over (e, c) of combine * expert_outputs.
    """
    return jnp.einsum("ecm,sec->sm", expert_outputs, combine)


def expert_ffn_ref(x, w1, b1, w2, b2):
    """Per-expert position-wise FFN (GeLU), batched over experts.

    Args:
      x: [E, C, M]; w1: [E, M, F]; b1: [E, F]; w2: [E, F, M]; b2: [E, M].
    Returns:
      [E, C, M].
    """
    h = jnp.einsum("ecm,emf->ecf", x, w1) + b1[:, None, :]
    h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efm->ecm", h, w2) + b2[:, None, :]


def moe_layer_ref(tokens, gate_w, w1, b1, w2, b2, capacity, top2=False):
    """Full reference MoE layer: gate -> scatter -> expert FFN -> gather.

    Args:
      tokens: [S, M] flattened token activations.
      gate_w: [M, E] router weights.
      w1/b1/w2/b2: stacked expert FFN params (see expert_ffn_ref).
    Returns:
      (output [S, M], aux_loss scalar).
    """
    logits = tokens @ gate_w
    if top2:
        combine, dispatch, aux, _ = top2_gating_ref(logits, capacity)
    else:
        combine, dispatch, aux, _ = top1_gating_ref(logits, capacity)
    expert_in = scatter_tokens_ref(tokens, dispatch)
    expert_out = expert_ffn_ref(expert_in, w1, b1, w2, b2)
    return gather_tokens_ref(expert_out, combine), aux
