"""Fused top-k gating kernel (Pallas).

DeepSpeed-MoE §5.4: the conventional gating function is "numerous operations
to create token-masks, select top-k experts, and perform cumulative-sum ...
all of which are not only wasteful due to the sparse tensor representation,
but also extremely slow due to many kernel call invocations".  The paper's
optimization fuses the whole gating function into a **single kernel** and
replaces the one-hot mask representation with a **dense token-to-expert
mapping table**.

This file implements that fused kernel in Pallas.  One ``pallas_call``
computes, for every token:

  * ``expert_idx[s]``  — the selected expert (top-1; ``top2`` variant emits
    two tables),
  * ``gate_prob[s]``   — the softmax probability of that expert,
  * ``slot[s]``        — the token's position within the expert's capacity
    queue (the paper's Blelloch-scan cumsum, realized here as a vectorized
    exclusive prefix sum over the one-hot assignment),
  * ``keep[s]``        — 1.0 if the token fit under ``capacity``, else 0.0
    (dropped tokens pass through the residual connection only).

Hardware adaptation (DESIGN.md §3): on GPU the paper parallelizes the cumsum
with a Blelloch scan across threads; on TPU the whole (S, E) logits tile sits
in VMEM and the scan is a vector-unit ``cumsum`` along the token axis — one
kernel launch either way, which is the point being reproduced.

Pallas runs with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the kernel structure is still the TPU structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _top1_gating_kernel(logits_ref, expert_idx_ref, gate_ref, slot_ref,
                        keep_ref, capacity: int):
    """Single fused kernel: softmax -> argmax -> capacity cumsum -> tables."""
    logits = logits_ref[...]  # [S, E] resident in VMEM
    S, E = logits.shape

    # Numerically stable softmax on the vector unit.
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    ez = jnp.exp(z)
    probs = ez / jnp.sum(ez, axis=-1, keepdims=True)

    expert_idx = jnp.argmax(probs, axis=-1)  # [S]
    gate = jnp.max(probs, axis=-1)  # [S] prob of the selected expert

    # One-hot assignment via 2-D iota compare (TPU requires >=2-D iota).
    eids = jax.lax.broadcasted_iota(jnp.int32, (S, E), 1)
    onehot = (eids == expert_idx[:, None].astype(jnp.int32)).astype(jnp.int32)

    # Exclusive prefix sum along tokens = position of each token in its
    # expert's queue.  (Paper: Blelloch scan; here: vector cumsum.)
    incl = jnp.cumsum(onehot, axis=0)
    excl = incl - onehot
    slot = jnp.sum(excl * onehot, axis=-1)  # [S]
    keep = (slot < capacity) & (jnp.sum(onehot, axis=-1) > 0)

    expert_idx_ref[...] = expert_idx.astype(jnp.int32)
    gate_ref[...] = gate.astype(logits.dtype)
    slot_ref[...] = jnp.minimum(slot, capacity).astype(jnp.int32)
    keep_ref[...] = keep.astype(logits.dtype)


def top1_gating(logits, capacity: int, *, interpret: bool = True):
    """Fused top-1 gating: returns the dense mapping table.

    Args:
      logits: [S, E] router logits.
      capacity: expert capacity c_e.
    Returns:
      (expert_idx [S] i32, gate_prob [S] f32, slot [S] i32, keep [S] f32).
      ``slot`` is clamped to ``capacity`` for dropped tokens so it can be used
      directly as a scatter index into a (capacity+1)-deep staging buffer.
    """
    S, E = logits.shape
    kernel = functools.partial(_top1_gating_kernel, capacity=capacity)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((S,), jnp.int32),
            jax.ShapeDtypeStruct((S,), logits.dtype),
            jax.ShapeDtypeStruct((S,), jnp.int32),
            jax.ShapeDtypeStruct((S,), logits.dtype),
        ),
        interpret=interpret,
    )(logits)


def _top2_gating_kernel(logits_ref, expert_idx_ref, gate_ref, slot_ref,
                        keep_ref, capacity: int):
    """Fused top-2 gating: two mapping tables, renormalized gate probs.

    Matches ``ref.top2_gating_ref``: second choices queue behind all first
    choices of the same expert.
    """
    logits = logits_ref[...]
    S, E = logits.shape
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    ez = jnp.exp(z)
    probs = ez / jnp.sum(ez, axis=-1, keepdims=True)

    idx1 = jnp.argmax(probs, axis=-1)
    eids = jax.lax.broadcasted_iota(jnp.int32, (S, E), 1)
    oh1 = (eids == idx1[:, None].astype(jnp.int32)).astype(probs.dtype)
    probs_wo1 = probs * (1.0 - oh1)
    idx2 = jnp.argmax(probs_wo1, axis=-1)
    oh2 = (eids == idx2[:, None].astype(jnp.int32)).astype(probs.dtype)

    g1 = jnp.sum(probs * oh1, axis=-1)
    g2 = jnp.sum(probs * oh2, axis=-1)

    pos1 = jnp.cumsum(oh1, axis=0) * oh1 - oh1
    pos2 = (jnp.cumsum(oh2, axis=0) - oh2) + jnp.sum(oh1, axis=0)[None, :]
    pos2 = pos2 * oh2
    s1 = jnp.sum(pos1 * oh1, axis=-1)
    s2 = jnp.sum(pos2 * oh2, axis=-1)
    keep1 = s1 < capacity
    keep2 = s2 < capacity

    denom = jnp.maximum(g1 * keep1 + g2 * keep2, 1e-9)

    expert_idx_ref[...] = jnp.stack([idx1, idx2], axis=-1).astype(jnp.int32)
    gate_ref[...] = (jnp.stack([g1 * keep1, g2 * keep2], axis=-1)
                     / denom[:, None]).astype(logits.dtype)
    slot_ref[...] = jnp.minimum(
        jnp.stack([s1, s2], axis=-1), capacity).astype(jnp.int32)
    keep_ref[...] = jnp.stack(
        [keep1, keep2], axis=-1).astype(logits.dtype)


def top2_gating(logits, capacity: int, *, interpret: bool = True):
    """Fused top-2 gating (paper's Top2-MoE ablation).

    Returns (expert_idx [S,2] i32, gate [S,2] f32, slot [S,2] i32,
    keep [S,2] f32); gates of kept assignments renormalized to sum to 1.
    """
    S, E = logits.shape
    kernel = functools.partial(_top2_gating_kernel, capacity=capacity)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((S, 2), jnp.int32),
            jax.ShapeDtypeStruct((S, 2), logits.dtype),
            jax.ShapeDtypeStruct((S, 2), jnp.int32),
            jax.ShapeDtypeStruct((S, 2), logits.dtype),
        ),
        interpret=interpret,
    )(logits)


def load_balance_aux_loss(logits, expert_idx, num_experts: int):
    """Switch-style auxiliary loss computed from the dense mapping table.

    Kept outside the kernel: it is a training-only quantity and the paper's
    fused kernel is an inference kernel.  ``aux = E * sum_e f_e * p_e``.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    oh = jax.nn.one_hot(expert_idx, num_experts, dtype=probs.dtype)
    fraction = jnp.mean(oh, axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(fraction * mean_prob)
