"""Data-layout transformation kernels (Pallas): scatter / gather.

DeepSpeed-MoE §5.4: the two sparse einsums around the expert computation
(sort tokens by assigned expert id; un-sort and scale by gate probability)
cost ``S x E x M x c_e`` because (E-1)/E of the multiply-adds are against
zeros.  The paper implements them "as data layout transformations using the
mapping table ... reducing the complexity of these operations from
``S x E x M x c_e`` to ``S x M x c_e``".

These kernels are those data-layout transformations: pure permutations driven
by the dense ``(expert_idx, slot, keep)`` tables emitted by ``gating.py``.

Hardware adaptation (DESIGN.md §3): the CUDA version is a thread-per-token
gather.  The Pallas version stages (1, M) token rows through VMEM and keeps
the mapping table as scalar operands (on real TPU: scalar-prefetch / SMEM) so
index arithmetic stays off the vector unit.  Dropped tokens are routed to a
trash slot (row ``capacity``) of a (C+1)-deep staging buffer, which keeps the
store loop mask-free; the wrapper slices the trash row off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scatter_kernel(tokens_ref, expert_idx_ref, slot_ref, out_ref):
    """Permute tokens into [E, C+1, M] expert blocks (row C = trash)."""
    S, M = tokens_ref.shape

    # Zero-init: capacity slots that receive no token must read as zeros so
    # the expert FFN sees padded blocks (matches ref scatter semantics).
    out_ref[...] = jnp.zeros_like(out_ref)

    def body(s, _):
        e = expert_idx_ref[s]
        c = slot_ref[s]  # already == capacity (trash row) for dropped tokens
        row = tokens_ref[s, :]
        pl.store(out_ref, (e, c, pl.dslice(0, M)), row)
        return 0

    jax.lax.fori_loop(0, S, body, 0)


def scatter_tokens(tokens, expert_idx, slot, num_experts: int, capacity: int,
                   *, interpret: bool = True):
    """Sort tokens by expert id into dense per-expert blocks.

    Args:
      tokens: [S, M] activations.
      expert_idx: [S] i32 from ``top1_gating`` (or one column of top-2).
      slot: [S] i32; ``capacity`` marks a dropped token.
    Returns:
      expert_inputs: [E, C, M] — token ``s`` at ``[expert_idx[s], slot[s]]``.
    """
    S, M = tokens.shape
    out = pl.pallas_call(
        _scatter_kernel,
        out_shape=jax.ShapeDtypeStruct(
            (num_experts, capacity + 1, M), tokens.dtype),
        interpret=interpret,
    )(tokens, expert_idx, slot)
    return out[:, :capacity, :]  # drop the trash row


def _gather_kernel(expert_out_ref, expert_idx_ref, slot_ref, gate_ref,
                   keep_ref, out_ref):
    """Inverse permutation + gate scaling: [E, C, M] -> [S, M]."""
    S, M = out_ref.shape
    C = expert_out_ref.shape[1]

    def body(s, _):
        e = expert_idx_ref[s]
        c = jnp.minimum(slot_ref[s], C - 1)  # dropped tokens read garbage...
        row = pl.load(expert_out_ref, (e, c, pl.dslice(0, M)))
        scale = gate_ref[s] * keep_ref[s]  # ...but keep==0 zeroes them out
        pl.store(out_ref, (s, pl.dslice(0, M)), row * scale)
        return 0

    jax.lax.fori_loop(0, S, body, 0)


def gather_tokens(expert_outputs, expert_idx, slot, gate, keep,
                  *, interpret: bool = True):
    """Restore original token order and scale by gate probability.

    Dropped tokens (``keep == 0``) produce zero rows — they contribute only
    through the transformer's residual connection, as in GShard/Switch.

    Args:
      expert_outputs: [E, C, M]; expert_idx/slot: [S] i32;
      gate/keep: [S] f32.
    Returns:
      tokens: [S, M].
    """
    E, C, M = expert_outputs.shape
    S = expert_idx.shape[0]
    return pl.pallas_call(
        _gather_kernel,
        out_shape=jax.ShapeDtypeStruct((S, M), expert_outputs.dtype),
        interpret=interpret,
    )(expert_outputs, expert_idx, slot, gate, keep)


def gather_tokens_top2(expert_outputs, expert_idx, slot, gate, keep,
                       *, interpret: bool = True):
    """Top-2 combine: sum of the two gathered-and-scaled expert outputs.

    Args:
      expert_outputs: [E, C, M]; expert_idx/slot: [S, 2]; gate/keep: [S, 2].
    """
    a = gather_tokens(expert_outputs, expert_idx[:, 0], slot[:, 0],
                      gate[:, 0], keep[:, 0], interpret=interpret)
    b = gather_tokens(expert_outputs, expert_idx[:, 1], slot[:, 1],
                      gate[:, 1], keep[:, 1], interpret=interpret)
    return a + b
