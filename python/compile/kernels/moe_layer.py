"""Fused-kernel MoE layer: the paper's optimized inference data path.

Composes the three §5.4 kernels —

    top1_gating  ->  scatter_tokens  ->  expert_ffn  ->  gather_tokens

— exactly the pipeline DeepSpeed-MoE runs per MoE layer at inference time.
``moe_layer_fused`` is what the L2 inference programs (``forward_full`` /
``decode_full`` and the per-layer ``moe_gate`` / ``expert_ffn`` programs used
by the Rust expert-parallel coordinator) lower into HLO.

The un-fused, one-hot einsum equivalent lives in ``ref.py``; pytest asserts
bit-level agreement and ``test_kernel_perf.py`` measures the latency ratio.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import gating, layout, expert_mlp


def moe_layer_fused(tokens, gate_w, w1, b1, w2, b2, capacity: int,
                    *, top2: bool = False, interpret: bool = True):
    """Optimized MoE layer over flattened tokens.

    Args:
      tokens: [S, M] activations (S = batch x seq after flattening).
      gate_w: [M, E] router weights.
      w1/b1/w2/b2: stacked expert FFN parameters ([E, M, F] etc.).
      capacity: expert capacity c_e.
    Returns:
      (output [S, M], aux_loss scalar, expert_idx [S] or [S,2] i32).
      aux_loss is returned for parity with the training path; at inference the
      caller ignores it.
    """
    E = gate_w.shape[-1]
    logits = tokens @ gate_w

    if top2:
        eidx, gate, slot, keep = gating.top2_gating(
            logits, capacity, interpret=interpret)
        # Both assignment columns scatter into the same expert blocks; second
        # choices queue behind first choices (slots are disjoint by
        # construction, matching ref.top2_gating_ref).
        x1 = layout.scatter_tokens(tokens, eidx[:, 0], slot[:, 0], E, capacity,
                                   interpret=interpret)
        x2 = layout.scatter_tokens(tokens, eidx[:, 1], slot[:, 1], E, capacity,
                                   interpret=interpret)
        expert_in = x1 + x2
        expert_out = expert_mlp.expert_ffn(expert_in, w1, b1, w2, b2,
                                           interpret=interpret)
        out = layout.gather_tokens_top2(expert_out, eidx, slot, gate, keep,
                                        interpret=interpret)
        aux = gating.load_balance_aux_loss(logits, eidx[:, 0], E)
        return out, aux, eidx

    eidx, gate, slot, keep = gating.top1_gating(
        logits, capacity, interpret=interpret)
    expert_in = layout.scatter_tokens(tokens, eidx, slot, E, capacity,
                                      interpret=interpret)
    expert_out = expert_mlp.expert_ffn(expert_in, w1, b1, w2, b2,
                                       interpret=interpret)
    out = layout.gather_tokens(expert_out, eidx, slot, gate, keep,
                               interpret=interpret)
    aux = gating.load_balance_aux_loss(logits, eidx, E)
    return out, aux, eidx


def residual_moe_layer_fused(tokens, mlp_w1, mlp_b1, mlp_w2, mlp_b2,
                             gate_w, w1, b1, w2, b2, capacity: int,
                             *, interpret: bool = True):
    """Residual-MoE layer (paper §4.1.1 Phenomenon-II, Fig 3 right).

    Every token passes a fixed dense MLP *and* one routed expert; outputs are
    summed.  Top-2 quality at top-1 communication volume — the routed branch
    still moves only one expert's worth of tokens through the all-to-all.
    """
    h = jnp.dot(tokens, mlp_w1) + mlp_b1
    h = jax.nn.gelu(h)
    dense_out = jnp.dot(h, mlp_w2) + mlp_b2
    moe_out, aux, eidx = moe_layer_fused(
        tokens, gate_w, w1, b1, w2, b2, capacity, interpret=interpret)
    return dense_out + moe_out, aux, eidx
