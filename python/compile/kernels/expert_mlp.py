"""Grouped expert FFN kernel (Pallas).

The expert computation itself: each expert applies a position-wise
GeLU MLP to its (capacity-bounded) block of tokens.  On GPU DeepSpeed uses a
grouped GEMM; the TPU mapping (DESIGN.md §3) is a 3-D grid
``(expert, token-block, ff-block)`` Pallas matmul whose BlockSpecs express
the HBM->VMEM schedule the CUDA code expressed with threadblocks:

  * grid axis 0 walks experts — each step streams one expert's weights into
    VMEM exactly once (the paper's data-locality argument for expert
    parallelism: fewer experts per device => fewer weight bytes read),
  * within an expert the (C, M)x(M, F) and (C, F)x(F, M) products are tiled
    to MXU-shaped (<=128) blocks.

For the tiny testbed dims (C, M, F <= 1024) a single-block-per-expert grid is
both simpler and faster, so that is the default; ``expert_ffn_tiled`` keeps
the full 3-D-grid formulation for the VMEM-footprint study in EXPERIMENTS.md
§Perf.  Both run under ``interpret=True`` on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expert_ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """One grid step = one expert: (C,M) @ (M,F) -> GeLU -> (F,M)."""
    x = x_ref[...]  # [C, M] this expert's token block (VMEM)
    h = jnp.dot(x, w1_ref[...]) + b1_ref[...]  # MXU matmul
    h = jax.nn.gelu(h)
    out_ref[...] = (jnp.dot(h, w2_ref[...]) + b2_ref[...]).astype(out_ref.dtype)


def expert_ffn(x, w1, b1, w2, b2, *, interpret: bool = True):
    """Grouped expert FFN: grid over experts, one weight stream per expert.

    Args:
      x: [E, C, M] scattered token blocks.
      w1: [E, M, F]; b1: [E, F]; w2: [E, F, M]; b2: [E, M].
    Returns:
      [E, C, M].
    """
    E, C, M = x.shape
    F = w1.shape[-1]
    return pl.pallas_call(
        _expert_ffn_kernel,
        grid=(E,),
        in_specs=[
            pl.BlockSpec((None, C, M), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, M, F), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, F), lambda e: (e, 0)),
            pl.BlockSpec((None, F, M), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, M), lambda e: (e, 0)),
        ],
        out_specs=pl.BlockSpec((None, C, M), lambda e: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, M), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)


def _ffn_h_kernel(x_ref, w1_ref, b1_ref, h_ref):
    """Tiled first matmul: out tile [bc, bf] += x tile [bc, M] @ w1 [M, bf]."""
    h = jnp.dot(x_ref[...], w1_ref[...]) + b1_ref[...]
    h_ref[...] = jax.nn.gelu(h).astype(h_ref.dtype)


def _ffn_o_kernel(h_ref, w2_ref, b2_ref, o_ref):
    o_ref[...] = (jnp.dot(h_ref[...], w2_ref[...]) + b2_ref[...]).astype(
        o_ref.dtype)


def expert_ffn_tiled(x, w1, b1, w2, b2, *, block_c: int = 128,
                     block_f: int = 128, interpret: bool = True):
    """MXU-tiled variant: 3-D grid (expert, token-block, ff-block).

    VMEM working set per grid step (f32): block_c*M + M*block_f + block_c*
    block_f floats — with block 128 and M 4096 that is ~4.2 MB, comfortably
    inside a TPU core's ~16 MB VMEM, leaving room for double buffering
    (pipelined automatically by Pallas across the innermost grid axis).
    """
    E, C, M = x.shape
    F = w1.shape[-1]
    bc, bf = min(block_c, C), min(block_f, F)
    assert C % bc == 0 and F % bf == 0, "tile sizes must divide C and F"

    h = pl.pallas_call(
        _ffn_h_kernel,
        grid=(E, C // bc, F // bf),
        in_specs=[
            pl.BlockSpec((None, bc, M), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((None, M, bf), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((None, bf), lambda e, i, j: (e, j)),
        ],
        out_specs=pl.BlockSpec((None, bc, bf), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        interpret=interpret,
    )(x, w1, b1)

    bm = min(block_f, M)
    assert M % bm == 0
    return pl.pallas_call(
        _ffn_o_kernel,
        grid=(E, C // bc, M // bm),
        in_specs=[
            pl.BlockSpec((None, bc, F), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((None, F, bm), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((None, bm), lambda e, i, j: (e, j)),
        ],
        out_specs=pl.BlockSpec((None, bc, bm), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, M), x.dtype),
        interpret=interpret,
    )(h, w2, b2)
