"""L2 — the GPT-style MoE model family (JAX, build-time only).

Implements the paper's model zoo (§3.1, §4): dense GPT, standard MoE with
top-1 gating on every other FFN layer, Pyramid-MoE, Residual-MoE, PR-MoE and
depth-reduced MoS students — all from one ``ModelConfig``.

Two compute paths:

* **Inference path** (``use_pallas=True``) — MoE layers run the fused §5.4
  Pallas kernels (``kernels.moe_layer``).  This is what the exported
  ``prefill`` / ``decode`` programs lower.
* **Training path** (``use_pallas=False``) — MoE layers run the
  differentiable sparse-einsum reference (``kernels.ref``), matching how
  DeepSpeed trains (the fused kernels are inference kernels).

Parameters are a *flat ordered list* of named arrays (``param_specs``); the
same ordering is recorded in the AOT manifest and mirrored by the Rust
checkpoint loader, so a checkpoint written by the Rust training driver reads
back here and vice versa.

Everything here is lowered once by ``aot.py``; no Python at serving time.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import moe_layer as k_moe
from .kernels import ref as k_ref

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic flat parameter layout: list of (name, shape).

    The order here *is* the ABI between Python and Rust: exported programs
    take parameters positionally in exactly this order, and checkpoints store
    them contiguously in this order.
    """
    M, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (V, M)),
        ("pos_emb", (cfg.max_seq, M)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1.g", (M,)), (p + "ln1.b", (M,)),
            (p + "attn.wq", (M, M)), (p + "attn.wk", (M, M)),
            (p + "attn.wv", (M, M)), (p + "attn.wo", (M, M)),
            (p + "ln2.g", (M,)), (p + "ln2.b", (M,)),
        ]
        E = cfg.experts_at(i)
        if E == 0:
            specs += [
                (p + "mlp.w1", (M, F)), (p + "mlp.b1", (F,)),
                (p + "mlp.w2", (F, M)), (p + "mlp.b2", (M,)),
            ]
        else:
            specs += [(p + "moe.gate", (M, E))]
            specs += [
                (p + "moe.w1", (E, M, F)), (p + "moe.b1", (E, F)),
                (p + "moe.w2", (E, F, M)), (p + "moe.b2", (E, M)),
            ]
            if cfg.residual:
                specs += [
                    (p + "moe.res.w1", (M, F)), (p + "moe.res.b1", (F,)),
                    (p + "moe.res.w2", (F, M)), (p + "moe.res.b2", (M,)),
                ]
    specs += [("lnf.g", (M,)), ("lnf.b", (M,))]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """GPT-2-style init over the flat layout (numpy RNG: reproducible)."""
    rng = np.random.RandomState(seed)
    out = []
    scale = 0.02
    resid_scale = scale / math.sqrt(2 * cfg.n_layers)
    for name, shape in param_specs(cfg):
        if name.endswith((".g",)):
            a = np.ones(shape, np.float32)
        elif name.endswith((".b", ".b1", ".b2")) and "emb" not in name:
            a = np.zeros(shape, np.float32)
        elif name.endswith(("attn.wo", ".w2")):
            a = rng.randn(*shape).astype(np.float32) * resid_scale
        else:
            a = rng.randn(*shape).astype(np.float32) * scale
        out.append(jnp.asarray(a))
    return out


def params_dict(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {name: arr for (name, _), arr in zip(param_specs(cfg), flat)}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _split_heads(x, n_heads):
    B, S, M = x.shape
    return x.reshape(B, S, n_heads, M // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    B, H, S, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * hd)


def attention_prefill(h, p, prefix, cfg: ModelConfig):
    """Causal self-attention over the whole prompt; returns (out, k, v)."""
    x = layer_norm(h, p[prefix + "ln1.g"], p[prefix + "ln1.b"])
    q = _split_heads(x @ p[prefix + "attn.wq"], cfg.n_heads)
    k = _split_heads(x @ p[prefix + "attn.wk"], cfg.n_heads)
    v = _split_heads(x @ p[prefix + "attn.wv"], cfg.n_heads)
    S = h.shape[1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.head_dim)
    causal = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(causal[None, None], scores, -1e9)
    att = jax.nn.softmax(scores, axis=-1)
    out = _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", att, v))
    return h + out @ p[prefix + "attn.wo"], k, v


def attention_decode(h, p, prefix, cfg: ModelConfig, k_cache, v_cache, pos):
    """One-token attention against the KV cache.

    Args:
      h: [B, 1, M]; k_cache/v_cache: [B, H, Smax, hd]; pos: [B] i32 — the
        write position (= current sequence length) per batch lane.
    Returns:
      (h' [B,1,M], k_cache', v_cache').
    """
    B = h.shape[0]
    Smax = k_cache.shape[2]
    x = layer_norm(h, p[prefix + "ln1.g"], p[prefix + "ln1.b"])
    q = _split_heads(x @ p[prefix + "attn.wq"], cfg.n_heads)  # [B,H,1,hd]
    k_new = _split_heads(x @ p[prefix + "attn.wk"], cfg.n_heads)
    v_new = _split_heads(x @ p[prefix + "attn.wv"], cfg.n_heads)

    # Per-lane cache write at pos[b] via one-hot (batch lanes differ).
    sel = jax.nn.one_hot(pos, Smax, dtype=h.dtype)  # [B, Smax]
    sel4 = sel[:, None, :, None]  # [B,1,Smax,1]
    k_cache = k_cache * (1.0 - sel4) + k_new * sel4
    v_cache = v_cache * (1.0 - sel4) + v_new * sel4

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_cache) / math.sqrt(cfg.head_dim)
    idx = jnp.arange(Smax)[None, :]  # [1, Smax]
    valid = idx <= pos[:, None]  # [B, Smax]
    scores = jnp.where(valid[:, None, None, :], scores, -1e9)
    att = jax.nn.softmax(scores, axis=-1)
    out = _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", att, v_cache))
    return h + out @ p[prefix + "attn.wo"], k_cache, v_cache


def dense_ffn(h, p, prefix):
    x = layer_norm(h, p[prefix + "ln2.g"], p[prefix + "ln2.b"])
    x = jax.nn.gelu(x @ p[prefix + "mlp.w1"] + p[prefix + "mlp.b1"])
    return h + (x @ p[prefix + "mlp.w2"] + p[prefix + "mlp.b2"])


def moe_ffn(h, p, prefix, cfg: ModelConfig, n_experts: int, capacity: int,
            use_pallas: bool):
    """MoE FFN sublayer (standard / residual), both compute paths.

    Returns (h', aux_loss).
    """
    B, S, M = h.shape
    x = layer_norm(h, p[prefix + "ln2.g"], p[prefix + "ln2.b"])
    flat = x.reshape(B * S, M)
    gw = p[prefix + "moe.gate"]
    ew = (p[prefix + "moe.w1"], p[prefix + "moe.b1"],
          p[prefix + "moe.w2"], p[prefix + "moe.b2"])
    if use_pallas:
        out, aux, _ = k_moe.moe_layer_fused(
            flat, gw, *ew, capacity, top2=cfg.top2)
    else:
        out, aux = k_ref.moe_layer_ref(
            flat, gw, *ew, capacity, top2=cfg.top2)
    if cfg.residual:
        r = jax.nn.gelu(flat @ p[prefix + "moe.res.w1"]
                        + p[prefix + "moe.res.b1"])
        out = out + (r @ p[prefix + "moe.res.w2"] + p[prefix + "moe.res.b2"])
    return h + out.reshape(B, S, M), aux


# ---------------------------------------------------------------------------
# Full model programs
# ---------------------------------------------------------------------------

def forward(flat_params, tokens, cfg: ModelConfig, use_pallas: bool,
            full_capacity: bool = False):
    """Full forward over [B, S] tokens -> (logits [B,S,V], aux_sum).

    ``full_capacity=True`` (inference) sizes every expert queue to B*S so no
    token is ever dropped; ``False`` (training) uses cfg.capacity_factor,
    which is where the paper's capacity/communication trade-offs live.
    """
    p = params_dict(cfg, flat_params)
    B, S = tokens.shape
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :S, :]
    aux_sum = jnp.zeros((), jnp.float32)
    for i in range(cfg.n_layers):
        prefix = f"layer{i}."
        h, _, _ = attention_prefill(h, p, prefix, cfg)
        E = cfg.experts_at(i)
        if E == 0:
            h = dense_ffn(h, p, prefix)
        else:
            cap = B * S if full_capacity else cfg.capacity(B * S, E)
            h, aux = moe_ffn(h, p, prefix, cfg, E, cap, use_pallas)
            aux_sum = aux_sum + aux
    h = layer_norm(h, p["lnf.g"], p["lnf.b"])
    logits = h @ p["tok_emb"].T  # tied LM head
    return logits, aux_sum


def prefill(flat_params, tokens, cfg: ModelConfig, use_pallas: bool = True):
    """Prefill program: logits + stacked KV caches sized to max_seq.

    Returns (logits [B,S,V], k_caches [L,B,H,Smax,hd], v_caches [...]).
    """
    p = params_dict(cfg, flat_params)
    B, S = tokens.shape
    Smax = cfg.max_seq
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :S, :]
    ks, vs = [], []
    for i in range(cfg.n_layers):
        prefix = f"layer{i}."
        h, k, v = attention_prefill(h, p, prefix, cfg)
        pad = Smax - S
        ks.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
        E = cfg.experts_at(i)
        if E == 0:
            h = dense_ffn(h, p, prefix)
        else:
            # Inference never drops tokens: worst-case capacity (all tokens
            # on one expert).  Training uses cfg.capacity_factor instead.
            h, _ = moe_ffn(h, p, prefix, cfg, E, B * S, use_pallas)
    h = layer_norm(h, p["lnf.g"], p["lnf.b"])
    logits = h @ p["tok_emb"].T
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(flat_params, token, k_caches, v_caches, pos,
                cfg: ModelConfig, use_pallas: bool = True):
    """Single decode step program.

    Args:
      token: [B] i32 current tokens; k/v_caches: [L,B,H,Smax,hd];
      pos: [B] i32 write positions (current lengths).
    Returns:
      (logits [B,V], k_caches', v_caches').
    """
    p = params_dict(cfg, flat_params)
    B = token.shape[0]
    h = p["tok_emb"][token][:, None, :] + p["pos_emb"][pos][:, None, :]
    new_ks, new_vs = [], []
    for i in range(cfg.n_layers):
        prefix = f"layer{i}."
        h, k, v = attention_decode(h, p, prefix, cfg,
                                   k_caches[i], v_caches[i], pos)
        new_ks.append(k)
        new_vs.append(v)
        E = cfg.experts_at(i)
        if E == 0:
            h = dense_ffn(h, p, prefix)
        else:
            # Worst-case capacity: decode never drops tokens.
            h, _ = moe_ffn(h, p, prefix, cfg, E, B, use_pallas)
    h = layer_norm(h, p["lnf.g"], p["lnf.b"])
    logits = (h @ p["tok_emb"].T)[:, 0, :]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


# ---------------------------------------------------------------------------
# Losses / training
# ---------------------------------------------------------------------------

def lm_loss(flat_params, batch, cfg: ModelConfig):
    """Next-token CE + MoE aux loss.  batch: [B, S+1] i32."""
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits, aux = forward(flat_params, inputs, cfg, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    return ce + cfg.moe_loss_coef * aux, (ce, aux)


def distill_loss(flat_params, batch, teacher_logits, kd_alpha,
                 cfg: ModelConfig):
    """Staged-KD objective (§4.2.1, Eq. 1): CE + alpha * KL(student||teacher).

    ``kd_alpha`` is a runtime scalar input so the Rust staged-KD controller
    can anneal/stop KD without recompiling (set 0 after the staging step).
    """
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits, aux = forward(flat_params, inputs, cfg, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    t_logp = jax.nn.log_softmax(teacher_logits, axis=-1)
    kl = jnp.sum(jnp.exp(t_logp) * (t_logp - logp), axis=-1).mean()
    return ce + kd_alpha * kl + cfg.moe_loss_coef * aux, (ce, kl)


def adam_update(flat_params, flat_m, flat_v, grads, step, lr):
    """Adam with bias correction; step is the 1-based step number (i32)."""
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_p, new_m, new_v = [], [], []
    for pp, m, v, g in zip(flat_params, flat_m, flat_v, grads):
        m = ADAM_B1 * m + (1 - ADAM_B1) * g
        v = ADAM_B2 * v + (1 - ADAM_B2) * (g * g)
        mh = m / bc1
        vh = v / bc2
        new_p.append(pp - lr * mh / (jnp.sqrt(vh) + ADAM_EPS))
        new_m.append(m)
        new_v.append(v)
    return new_p, new_m, new_v


def train_step(flat_params, flat_m, flat_v, batch, step, lr,
               cfg: ModelConfig):
    """Fused train step: grads + Adam.  All inputs/outputs flat arrays.

    Returns (new_params, new_m, new_v, loss, ce, aux).
    """
    (loss, (ce, aux)), grads = jax.value_and_grad(
        lambda ps: lm_loss(ps, batch, cfg), has_aux=True)(flat_params)
    new_p, new_m, new_v = adam_update(flat_params, flat_m, flat_v, grads,
                                      step, lr)
    return new_p, new_m, new_v, loss, ce, aux


def distill_step(flat_params, flat_m, flat_v, batch, teacher_logits,
                 kd_alpha, step, lr, cfg: ModelConfig):
    """Fused distillation step (student update given teacher logits)."""
    (loss, (ce, kl)), grads = jax.value_and_grad(
        lambda ps: distill_loss(ps, batch, teacher_logits, kd_alpha, cfg),
        has_aux=True)(flat_params)
    new_p, new_m, new_v = adam_update(flat_params, flat_m, flat_v, grads,
                                      step, lr)
    return new_p, new_m, new_v, loss, ce, kl


def eval_loss(flat_params, batch, cfg: ModelConfig):
    """Validation CE (no aux) over [B, S+1] token batch."""
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits, _ = forward(flat_params, inputs, cfg, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()


def teacher_logits_fn(flat_params, batch, cfg: ModelConfig):
    """Teacher forward for KD: [B, S+1] batch -> logits over inputs."""
    logits, _ = forward(flat_params, batch[:, :-1], cfg, use_pallas=False)
    return logits


# ---------------------------------------------------------------------------
# Per-layer programs for the disaggregated expert-parallel serving path
# (the Rust coordinator composes these, inserting all-to-all between them).
# ---------------------------------------------------------------------------

def prog_embed(tok_emb, pos_emb, tokens, pos0):
    """tokens [B,S] + per-lane start positions pos0 [B] -> h [B,S,M]."""
    B, S = tokens.shape
    positions = pos0[:, None] + jnp.arange(S)[None, :]
    return tok_emb[tokens] + pos_emb[positions]


def prog_attn_prefill(h, ln_g, ln_b, wq, wk, wv, wo, n_heads: int):
    """One layer's attention sublayer over a full prompt (shared across
    layers: weights are inputs)."""
    cfg_like = type("C", (), {"n_heads": n_heads,
                              "head_dim": h.shape[-1] // n_heads})
    p = {"x.ln1.g": ln_g, "x.ln1.b": ln_b, "x.attn.wq": wq, "x.attn.wk": wk,
         "x.attn.wv": wv, "x.attn.wo": wo}
    return attention_prefill(h, p, "x.", cfg_like)


def prog_attn_decode(h, ln_g, ln_b, wq, wk, wv, wo, k_cache, v_cache, pos,
                     n_heads: int):
    cfg_like = type("C", (), {"n_heads": n_heads,
                              "head_dim": h.shape[-1] // n_heads})
    p = {"x.ln1.g": ln_g, "x.ln1.b": ln_b, "x.attn.wq": wq, "x.attn.wk": wk,
         "x.attn.wv": wv, "x.attn.wo": wo}
    return attention_decode(h, p, "x.", cfg_like, k_cache, v_cache, pos)


def prog_dense_ffn(h, ln_g, ln_b, w1, b1, w2, b2):
    """One layer's dense FFN sublayer (pre-LN + residual add inside)."""
    p = {"x.ln2.g": ln_g, "x.ln2.b": ln_b, "x.mlp.w1": w1, "x.mlp.b1": b1,
         "x.mlp.w2": w2, "x.mlp.b2": b2}
    return dense_ffn(h, p, "x.")


def prog_gate(h, ln_g, ln_b, gate_w):
    """MoE gate for the disaggregated path: returns (ln_h flat [T,M],
    probs [T,E]).  Top-1 selection + capacity assignment happen in the Rust
    coordinator (it needs the routing decision to drive the all-to-all)."""
    B, S, M = h.shape
    x = layer_norm(h, ln_g, ln_b).reshape(B * S, M)
    logits = x @ gate_w
    return x, jax.nn.softmax(logits, axis=-1)


def prog_expert_ffn(x, w1, b1, w2, b2):
    """One expert's FFN over its gathered token block [C, M] (no residual:
    the coordinator combines outputs host-side, §5.4 data-layout step)."""
    return (jax.nn.gelu(x @ w1 + b1)) @ w2 + b2


def prog_residual_branch(x, w1, b1, w2, b2):
    """Fixed dense branch of Residual-MoE over flat tokens [T, M]."""
    return (jax.nn.gelu(x @ w1 + b1)) @ w2 + b2


def prog_combine(h, expert_out, gate):
    """h [B,S,M] + gate-scaled expert outputs (flat [T,M]) -> h'."""
    B, S, M = h.shape
    return h + (expert_out * gate[:, None]).reshape(B, S, M)


def prog_gather_last(h, lens):
    """Each lane's last-position row: h [B,S,M] + prompt lengths [B] ->
    [B,M] rows at lens[b]-1.  Lets the serving leader feed the LM head
    without pulling the whole [B,S,M] prefill activation to the host."""
    idx = jnp.clip(lens - 1, 0, h.shape[1] - 1)
    return jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0, :]


def prog_lm_head(h, ln_g, ln_b, tok_emb):
    """Final LN + tied head over the last position: h [B,M] -> logits."""
    x = layer_norm(h, ln_g, ln_b)
    return x @ tok_emb.T
