//! §Perf measurement harness (not a pass/fail test of speed): measures the
//! decode hot path with and without the KV-cache literal-mirror
//! optimization and prints the numbers quoted in EXPERIMENTS.md §Perf.
//!
//! Run with `cargo test --release --test perf_decode -- --nocapture`.

use ds_moe::config::ServingConfig;
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::runtime::Manifest;
use ds_moe::server::{Engine, Scheduler};

fn run_decode_heavy(model: &str) -> (f64, f64) {
    let manifest = Manifest::load("artifacts").unwrap();
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 32,
        valid_seqs: 32,
        ..Default::default()
    });
    let serving = ServingConfig {
        model: model.into(),
        max_new_tokens: 24,
        batch_timeout: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let mut engine = Scheduler::new(
        Engine::new(&manifest, serving.clone()).unwrap(),
        serving,
    );
    // warmup / compile
    engine.submit(corpus.prompt(0, 8), Some(2)).unwrap();
    engine.run_until_idle().unwrap();
    for i in 0..8 {
        engine.submit(corpus.prompt(i, 8), Some(24)).unwrap();
    }
    let t0 = std::time::Instant::now();
    let responses = engine.run_until_idle().unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    (
        engine.metrics.percentile_ns("decode_step", 50.0) as f64 / 1e6,
        tokens as f64 / wall,
    )
}

#[test]
fn measure_cache_mirror_effect() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    for model in ["moe-s-8", "dense-s"] {
        std::env::remove_var("DSMOE_NO_CACHE_MIRROR");
        let (p50_opt, tps_opt) = run_decode_heavy(model);
        std::env::set_var("DSMOE_NO_CACHE_MIRROR", "1");
        let (p50_base, tps_base) = run_decode_heavy(model);
        std::env::remove_var("DSMOE_NO_CACHE_MIRROR");
        println!(
            "[perf] {model}: decode p50 {p50_base:.2} -> {p50_opt:.2} ms \
             ({:+.1}%), throughput {tps_base:.1} -> {tps_opt:.1} tok/s",
            100.0 * (p50_opt - p50_base) / p50_base
        );
        // The optimization must never make things slower by more than noise.
        assert!(
            p50_opt <= p50_base * 1.15,
            "{model}: mirror made decode slower ({p50_opt} vs {p50_base})"
        );
    }
}
