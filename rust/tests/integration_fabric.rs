//! Fabric integration: real multi-threaded execution of the all-to-all
//! schedules (messages relayed between worker threads per plan) and the
//! expert-FFN dispatch path.

use ds_moe::config::AllToAllKind;
use ds_moe::coordinator::alltoall::{plan, uniform_bytes, Topology};
use ds_moe::fabric::{Fabric, WorkerPrograms};
use ds_moe::runtime::{HostTensor, Manifest};

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new("artifacts");
    root.join("manifest.json")
        .exists()
        .then(|| Manifest::load(root).unwrap())
}

fn worker_programs(m: &Manifest) -> WorkerPrograms {
    let ladder = m
        .expert_block_sizes()
        .into_iter()
        .filter_map(|c| {
            m.shared_program(&Manifest::key_expert_ffn(128, 512, c))
                .ok()
                .map(|s| (c, s.clone()))
        })
        .collect();
    WorkerPrograms { expert_ffn: ladder }
}

#[test]
fn alltoall_plans_deliver_over_threads() {
    let Some(m) = manifest() else { return };
    for kind in [AllToAllKind::Naive, AllToAllKind::Hierarchical] {
        let workers = 6;
        let fabric = Fabric::spawn(workers, worker_programs(&m)).unwrap();
        let topo = Topology { workers, node_size: 3, ts_degree: 1 };
        let bytes = uniform_bytes(workers, 64);
        let p = plan(kind, topo, &bytes);
        let delivered = fabric
            .route(&p, |msg| vec![(msg.src * 16 + msg.dst) as u8; msg.bytes])
            .unwrap();
        // Each worker receives traffic; total delivered bytes equals the
        // plan volume (every message materializes at a thread).
        let total: usize = delivered.iter().map(|(_, _, b)| b).sum();
        assert_eq!(total, p.volume(), "{kind:?}");
        assert!(
            fabric.traffic.p2p_messages.load(std::sync::atomic::Ordering::Relaxed)
                as usize
                == p.messages.len(),
            "{kind:?}"
        );
        fabric.shutdown();
    }
}

#[test]
fn expert_ffn_dispatch_matches_local_compute() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(2, worker_programs(&m)).unwrap();
    // Deterministic small weights: w1 = I-ish scaled, b = 0.
    let mdim = 128usize;
    let f = 512usize;
    let mut w1 = vec![0f32; mdim * f];
    for i in 0..mdim {
        w1[i * f + i] = 0.5; // maps x into the first m coords of hidden
    }
    let mut w2 = vec![0f32; f * mdim];
    for i in 0..mdim {
        w2[i * mdim + i] = 2.0;
    }
    let weights = vec![
        HostTensor::f32(&[mdim, f], w1),
        HostTensor::zeros_f32(&[f]),
        HostTensor::f32(&[f, mdim], w2),
        HostTensor::zeros_f32(&[mdim]),
    ];
    fabric.load_expert(1, 0, 3, weights).unwrap();

    let count = 5usize; // not a compiled size: exercises padding (-> 8)
    let mut x = vec![0f32; count * mdim];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i % 7) as f32 - 3.0) * 0.25;
    }
    fabric
        .dispatch_ffn(1, 0, 3, HostTensor::f32(&[count, mdim], x.clone()), 9)
        .unwrap();
    let results = fabric.collect_ffn(1).unwrap();
    assert_eq!(results.len(), 1);
    let (layer, expert, out, tag) = &results[0];
    assert_eq!((*layer, *expert, *tag), (0, 3, 9));
    assert_eq!(out.shape, vec![count, mdim]);
    // reference: gelu(0.5 x) * 2
    let gelu = |v: f32| {
        let c = (2.0f32 / std::f32::consts::PI).sqrt();
        0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
    };
    let got = out.as_f32().unwrap();
    for (i, &xi) in x.iter().enumerate() {
        let want = gelu(0.5 * xi) * 2.0;
        assert!(
            (got[i] - want).abs() < 1e-4,
            "elem {i}: {} vs {want}",
            got[i]
        );
    }
    fabric.shutdown();
}

#[test]
fn unloaded_expert_is_an_error() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(1, worker_programs(&m)).unwrap();
    fabric
        .dispatch_ffn(0, 0, 0, HostTensor::zeros_f32(&[1, 128]), 0)
        .unwrap();
    let err = fabric.collect_ffn(1).unwrap_err().to_string();
    assert!(err.contains("not loaded"), "{err}");
    fabric.shutdown();
}

#[test]
fn oversized_block_is_an_error() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(1, worker_programs(&m)).unwrap();
    let weights = vec![
        HostTensor::zeros_f32(&[128, 512]),
        HostTensor::zeros_f32(&[512]),
        HostTensor::zeros_f32(&[512, 128]),
        HostTensor::zeros_f32(&[128]),
    ];
    fabric.load_expert(0, 0, 0, weights).unwrap();
    // larger than the biggest compiled capacity (512)
    fabric
        .dispatch_ffn(0, 0, 0, HostTensor::zeros_f32(&[600, 128]), 0)
        .unwrap();
    let err = fabric.collect_ffn(1).unwrap_err().to_string();
    assert!(err.contains("exceeds"), "{err}");
    fabric.shutdown();
}
