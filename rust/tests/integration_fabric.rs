//! Fabric integration: real multi-threaded execution of the all-to-all
//! schedules (messages relayed between worker threads per plan), the
//! expert-FFN dispatch path, and the coalesced per-worker batch path
//! (one `ExpertFfnBatch` message per worker per layer).

use std::sync::atomic::Ordering;

use ds_moe::config::AllToAllKind;
use ds_moe::coordinator::alltoall::{plan, uniform_bytes, Topology};
use ds_moe::fabric::{
    A2aMode, ExpertFfnBatch, Fabric, TransportKind, WorkerPrograms,
};
use ds_moe::runtime::{HostTensor, Manifest};
use ds_moe::server::EpEngine;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new("artifacts");
    root.join("manifest.json")
        .exists()
        .then(|| Manifest::load(root).unwrap())
}

fn worker_programs(m: &Manifest) -> WorkerPrograms {
    let ladder = m
        .expert_block_sizes()
        .into_iter()
        .filter_map(|c| {
            m.shared_program(&Manifest::key_expert_ffn(128, 512, c))
                .ok()
                .map(|s| (c, s.clone()))
        })
        .collect();
    WorkerPrograms { expert_ffn: ladder }
}

#[test]
fn alltoall_plans_deliver_over_threads() {
    let Some(m) = manifest() else { return };
    for kind in [AllToAllKind::Naive, AllToAllKind::Hierarchical] {
        let workers = 6;
        let fabric = Fabric::spawn(workers, worker_programs(&m)).unwrap();
        let topo = Topology { workers, node_size: 3, ts_degree: 1 };
        let bytes = uniform_bytes(workers, 64);
        let p = plan(kind, topo, &bytes);
        let delivered = fabric
            .route(&p, |msg| vec![(msg.src * 16 + msg.dst) as u8; msg.bytes])
            .unwrap();
        // Each worker receives traffic; total delivered bytes equals the
        // plan volume (every message materializes at a thread).
        let total: usize = delivered.iter().map(|(_, _, b)| b).sum();
        assert_eq!(total, p.volume(), "{kind:?}");
        assert!(
            fabric.traffic.p2p_messages.load(std::sync::atomic::Ordering::Relaxed)
                as usize
                == p.messages.len(),
            "{kind:?}"
        );
        fabric.shutdown();
    }
}

#[test]
fn expert_ffn_dispatch_matches_local_compute() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(2, worker_programs(&m)).unwrap();
    // Deterministic small weights: w1 = I-ish scaled, b = 0.
    let mdim = 128usize;
    let f = 512usize;
    let mut w1 = vec![0f32; mdim * f];
    for i in 0..mdim {
        w1[i * f + i] = 0.5; // maps x into the first m coords of hidden
    }
    let mut w2 = vec![0f32; f * mdim];
    for i in 0..mdim {
        w2[i * mdim + i] = 2.0;
    }
    let weights = vec![
        HostTensor::f32(&[mdim, f], w1),
        HostTensor::zeros_f32(&[f]),
        HostTensor::f32(&[f, mdim], w2),
        HostTensor::zeros_f32(&[mdim]),
    ];
    fabric.load_expert(1, 0, 3, weights).unwrap();

    let count = 5usize; // not a compiled size: exercises padding (-> 8)
    let mut x = vec![0f32; count * mdim];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i % 7) as f32 - 3.0) * 0.25;
    }
    fabric
        .dispatch_ffn(1, 0, 3, HostTensor::f32(&[count, mdim], x.clone()), 9)
        .unwrap();
    let results = fabric.collect_ffn(1).unwrap();
    assert_eq!(results.len(), 1);
    let (layer, expert, out, tag) = &results[0];
    assert_eq!((*layer, *expert, *tag), (0, 3, 9));
    assert_eq!(out.shape, vec![count, mdim]);
    // reference: gelu(0.5 x) * 2
    let gelu = |v: f32| {
        let c = (2.0f32 / std::f32::consts::PI).sqrt();
        0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
    };
    let got = out.as_f32().unwrap();
    for (i, &xi) in x.iter().enumerate() {
        let want = gelu(0.5 * xi) * 2.0;
        assert!(
            (got[i] - want).abs() < 1e-4,
            "elem {i}: {} vs {want}",
            got[i]
        );
    }
    fabric.shutdown();
}

/// Deterministic diagonal expert weights: y = gelu(s1 * x) * s2.
fn diag_weights(mdim: usize, f: usize, s1: f32, s2: f32) -> Vec<HostTensor> {
    let mut w1 = vec![0f32; mdim * f];
    for i in 0..mdim {
        w1[i * f + i] = s1;
    }
    let mut w2 = vec![0f32; f * mdim];
    for i in 0..mdim {
        w2[i * mdim + i] = s2;
    }
    vec![
        HostTensor::f32(&[mdim, f], w1),
        HostTensor::zeros_f32(&[f]),
        HostTensor::f32(&[f, mdim], w2),
        HostTensor::zeros_f32(&[mdim]),
    ]
}

#[test]
fn coalesced_batch_matches_per_expert_path_with_fewer_messages() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(2, worker_programs(&m)).unwrap();
    let (mdim, f) = (128usize, 512usize);
    // Two experts per worker with distinct weights so any slicing mistake
    // in the packed path shows up as a value mismatch.
    fabric.load_expert(0, 0, 0, diag_weights(mdim, f, 0.5, 2.0)).unwrap();
    fabric.load_expert(0, 0, 2, diag_weights(mdim, f, 0.25, 4.0)).unwrap();
    fabric.load_expert(1, 0, 1, diag_weights(mdim, f, 1.0, 1.0)).unwrap();
    fabric.load_expert(1, 0, 3, diag_weights(mdim, f, 0.75, 3.0)).unwrap();

    // Unpadded block sizes per expert (exercise ladder padding).
    let counts = [3usize, 2, 5, 4];
    let blocks: Vec<Vec<f32>> = counts
        .iter()
        .enumerate()
        .map(|(e, &c)| {
            (0..c * mdim)
                .map(|i| ((i % 11) as f32 - 5.0) * 0.125 + e as f32 * 0.01)
                .collect()
        })
        .collect();

    // Reference: one message per expert (4 messages).
    let msgs0 = fabric.traffic.messages.load(Ordering::Relaxed);
    for e in 0..4 {
        let owner = e % 2;
        fabric
            .dispatch_ffn(
                owner,
                0,
                e,
                HostTensor::f32(&[counts[e], mdim], blocks[e].clone()),
                e as u64,
            )
            .unwrap();
    }
    let mut per_expert: Vec<Vec<f32>> = vec![Vec::new(); 4];
    for (_, e, out, _) in fabric.collect_ffn(4).unwrap() {
        per_expert[e] = out.as_f32().unwrap().to_vec();
    }
    assert_eq!(fabric.traffic.messages.load(Ordering::Relaxed) - msgs0, 4);

    // Coalesced: one ExpertFfnBatch per worker (2 messages), blocks packed
    // back to back.
    let msgs1 = fabric.traffic.messages.load(Ordering::Relaxed);
    for (w, experts) in [(0usize, [0usize, 2]), (1, [1, 3])] {
        let total: usize = experts.iter().map(|&e| counts[e]).sum();
        let mut data = Vec::with_capacity(total * mdim);
        for &e in &experts {
            data.extend_from_slice(&blocks[e]);
        }
        fabric
            .dispatch_ffn_batch(
                w,
                ExpertFfnBatch {
                    layer: 0,
                    experts: experts.iter().map(|&e| (e, 0, counts[e])).collect(),
                    data: HostTensor::f32(&[total, mdim], data),
                    tag: 7, // one exchange generation shared by both workers
                },
            )
            .unwrap();
    }
    let results = fabric.collect_ffn_batches(2, 0, 7, &[]).unwrap();
    assert_eq!(
        fabric.traffic.messages.load(Ordering::Relaxed) - msgs1,
        2,
        "coalesced path must send O(workers) messages, not O(experts)"
    );
    for r in &results {
        assert_eq!(r.layer, 0);
        let flat = r.data.as_f32().unwrap();
        let mut off = 0usize;
        for &(e, _slot, c) in &r.experts {
            assert_eq!(c, counts[e]);
            assert_eq!(
                &flat[off * mdim..(off + c) * mdim],
                per_expert[e].as_slice(),
                "expert {e}: packed output differs from per-expert dispatch"
            );
            off += c;
        }
    }
    fabric.shutdown();
}

#[test]
fn ep_engine_sends_one_message_per_worker_per_moe_layer() {
    let Some(m) = manifest() else { return };
    let workers = 4usize;
    let batch = 4usize;
    let mk_tokens = |ep: &EpEngine| {
        let corpus = ds_moe::data::Corpus::generate(
            ds_moe::data::CorpusConfig::default(),
        );
        let smax = ep.cfg.max_seq;
        let mut tokens = vec![0i32; batch * smax];
        for b in 0..batch {
            let p = corpus.prompt(b, 8);
            tokens[b * smax..b * smax + 8].copy_from_slice(&p);
        }
        tokens
    };

    let mut ep = EpEngine::new(
        &m, "moe-s-8", workers, AllToAllKind::Hierarchical, batch,
    )
    .unwrap();
    ep.set_serial_moe(false);
    // Pin the per-layer coalesced path: the pipelined path legitimately
    // sends one batch per worker per *microbatch* (up to 2x per layer).
    ep.set_pipeline(false);
    let tokens = mk_tokens(&ep);
    ep.forward_prefill(&tokens, &vec![8; batch]).unwrap();
    let overlap_msgs = ep.traffic().messages.load(Ordering::Relaxed);
    let moe_layers = ep.cfg.moe_layers().len() as u64;
    assert!(
        overlap_msgs <= moe_layers * workers as u64,
        "coalesced path sent {overlap_msgs} messages for {moe_layers} MoE \
         layers x {workers} workers"
    );

    let mut ep_serial = EpEngine::new(
        &m, "moe-s-8", workers, AllToAllKind::Hierarchical, batch,
    )
    .unwrap();
    ep_serial.set_serial_moe(true);
    ep_serial.forward_prefill(&tokens, &vec![8; batch]).unwrap();
    let serial_msgs = ep_serial.traffic().messages.load(Ordering::Relaxed);
    // The serial path wakes workers once per non-empty expert (O(experts));
    // with 256 tokens over 8 experts every expert is hit on both layers.
    assert!(
        serial_msgs > overlap_msgs,
        "serial {serial_msgs} vs coalesced {overlap_msgs}"
    );
}

/// Two tagged exchange generations in flight at once (the cross-layer
/// pipeline's steady state): tag-keyed collection must hand each
/// generation exactly its own replies — never cross-combining — while a
/// reply whose tag is neither collected nor open still fails loudly.
#[test]
fn concurrent_tagged_exchanges_collect_by_tag() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(2, worker_programs(&m)).unwrap();
    let (mdim, f) = (128usize, 512usize);
    // Distinct weights per (layer, expert) so any cross-combination of the
    // two generations shows up as a value mismatch.
    fabric.load_expert(0, 0, 0, diag_weights(mdim, f, 0.5, 2.0)).unwrap();
    fabric.load_expert(1, 1, 1, diag_weights(mdim, f, 0.25, 4.0)).unwrap();

    let block_a: Vec<f32> =
        (0..3 * mdim).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let block_b: Vec<f32> =
        (0..5 * mdim).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();

    // Reference outputs via the per-expert path.
    fabric
        .dispatch_ffn(0, 0, 0, HostTensor::f32(&[3, mdim], block_a.clone()), 1)
        .unwrap();
    fabric
        .dispatch_ffn(1, 1, 1, HostTensor::f32(&[5, mdim], block_b.clone()), 2)
        .unwrap();
    let mut want_a = Vec::new();
    let mut want_b = Vec::new();
    for (_, e, out, _) in fabric.collect_ffn(2).unwrap() {
        if e == 0 {
            want_a = out.as_f32().unwrap().to_vec();
        } else {
            want_b = out.as_f32().unwrap().to_vec();
        }
    }

    // Both generations in flight, then collect the *second* one first:
    // generation 21's reply must be stashed (it is open), not combined.
    let mk_batch = |layer: usize, e: usize, block: &[f32], tag: u64| {
        let count = block.len() / mdim;
        ExpertFfnBatch {
            layer,
            experts: vec![(e, 0, count)],
            data: HostTensor::f32(&[count, mdim], block.to_vec()),
            tag,
        }
    };
    fabric.dispatch_ffn_batch(0, mk_batch(0, 0, &block_a, 21)).unwrap();
    fabric.dispatch_ffn_batch(1, mk_batch(1, 1, &block_b, 22)).unwrap();
    let rb = fabric.collect_ffn_batches(1, 1, 22, &[21]).unwrap();
    assert_eq!((rb[0].layer, rb[0].tag), (1, 22));
    assert_eq!(rb[0].data.as_f32().unwrap(), want_b.as_slice());
    // Draining the first generation picks the stashed (or in-channel)
    // reply of tag 21 and nothing else.
    let ra = fabric.collect_ffn_batches(1, 0, 21, &[]).unwrap();
    assert_eq!((ra[0].layer, ra[0].tag), (0, 21));
    assert_eq!(ra[0].data.as_f32().unwrap(), want_a.as_slice());

    // try_collect: non-blocking drain — empty results until the reply
    // lands, then exactly one.
    fabric.dispatch_ffn_batch(0, mk_batch(0, 0, &block_a, 30)).unwrap();
    let mut got = Vec::new();
    for _ in 0..2000 {
        got.extend(fabric.try_collect_ffn_batches(0, 30, &[]).unwrap());
        if !got.is_empty() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].data.as_f32().unwrap(), want_a.as_slice());

    // A reply whose tag is neither collected nor open is stale: loud
    // error, never a silent combine.
    fabric.dispatch_ffn_batch(0, mk_batch(0, 0, &block_a, 31)).unwrap();
    let err = fabric
        .collect_ffn_batches(1, 0, 99, &[])
        .unwrap_err()
        .to_string();
    assert!(err.contains("stale"), "{err}");
    fabric.shutdown();
}

/// Bounded-stash behaviour (prerequisite for pipelining deeper than two
/// microbatches): with `k` exchange generations in flight, the tag-keyed
/// stash never grows beyond the open-tag count, hands each generation
/// exactly its own replies, and drains fully once every generation is
/// collected.  A stashed reply whose generation is no longer open fails
/// loudly on the next collect — and is consumed, so one stale reply
/// cannot wedge every later collect.
#[test]
fn stash_bounded_by_open_tags_and_drains() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(1, worker_programs(&m)).unwrap();
    let (mdim, f) = (128usize, 512usize);
    fabric.load_expert(0, 0, 0, diag_weights(mdim, f, 0.5, 2.0)).unwrap();
    let block: Vec<f32> =
        (0..3 * mdim).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let mk_batch = |tag: u64| ExpertFfnBatch {
        layer: 0,
        experts: vec![(0, 0, 3)],
        data: HostTensor::f32(&[3, mdim], block.clone()),
        tag,
    };

    // Three generations in flight at once (deeper than the current
    // two-microbatch pipeline ever goes).
    assert_eq!(fabric.stash_depth(), 0);
    for tag in [41u64, 42, 43] {
        fabric.dispatch_ffn_batch(0, mk_batch(tag)).unwrap();
    }
    // Collect the *last* generation first: the single worker replies in
    // dispatch order, so both earlier replies must be stashed — exactly
    // the open-tag count, never more.
    let r = fabric.collect_ffn_batches(1, 0, 43, &[41, 42]).unwrap();
    assert_eq!(r[0].tag, 43);
    assert_eq!(fabric.stash_depth(), 2);
    let r = fabric.collect_ffn_batches(1, 0, 42, &[41]).unwrap();
    assert_eq!(r[0].tag, 42);
    assert_eq!(fabric.stash_depth(), 1);
    let r = fabric.collect_ffn_batches(1, 0, 41, &[]).unwrap();
    assert_eq!(r[0].tag, 41);
    // Fully drained after the last collect (the moe_finish analogue).
    assert_eq!(fabric.stash_depth(), 0);

    // Loud failure at depth: park a reply for an open generation, then
    // drop that generation from the open set — the stashed reply is now
    // stale and the next collect must error, consuming it.
    fabric.dispatch_ffn_batch(0, mk_batch(61)).unwrap();
    fabric.dispatch_ffn_batch(0, mk_batch(62)).unwrap();
    let r = fabric.collect_ffn_batches(1, 0, 62, &[61]).unwrap();
    assert_eq!(r[0].tag, 62);
    assert_eq!(fabric.stash_depth(), 1);
    let err = fabric
        .collect_ffn_batches(1, 0, 99, &[])
        .unwrap_err()
        .to_string();
    assert!(err.contains("stale"), "{err}");
    assert_eq!(fabric.stash_depth(), 0, "stale entry must be consumed");
    fabric.shutdown();
}

/// Stash bound at four concurrent exchange generations (the bound itself
/// is generic in the open-tag count — the pipeline ring can legally go as
/// deep as the lane count, plus a staged admission): collecting
/// newest-first parks the earlier replies in the stash, whose depth never
/// exceeds the open tag count at any point and drains to zero once all
/// four are collected.
#[test]
fn stash_bounded_at_ring_depth_4() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(1, worker_programs(&m)).unwrap();
    let (mdim, f) = (128usize, 512usize);
    fabric.load_expert(0, 0, 0, diag_weights(mdim, f, 0.5, 2.0)).unwrap();
    let block: Vec<f32> =
        (0..3 * mdim).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let mk_batch = |tag: u64| ExpertFfnBatch {
        layer: 0,
        experts: vec![(0, 0, 3)],
        data: HostTensor::f32(&[3, mdim], block.clone()),
        tag,
    };

    let tags = [81u64, 82, 83, 84];
    for &tag in &tags {
        fabric.dispatch_ffn_batch(0, mk_batch(tag)).unwrap();
    }
    // Collect newest-first: each collect parks every earlier (still-open)
    // reply, so the stash peaks at open-tag count and shrinks by one per
    // collected generation.
    for (i, &tag) in tags.iter().enumerate().rev() {
        let open: Vec<u64> = tags[..i].to_vec();
        let r = fabric.collect_ffn_batches(1, 0, tag, &open).unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].tag, tag);
        assert!(
            fabric.stash_depth() <= open.len(),
            "stash {} exceeds open tags {}",
            fabric.stash_depth(),
            open.len()
        );
    }
    assert_eq!(fabric.stash_depth(), 0, "stash must drain at depth 4");
    fabric.shutdown();
}

/// One whole exchange generation dispatched three ways — flat over
/// channels (the reference), hierarchical over channels, hierarchical
/// over the socket transport — must produce bitwise-identical expert
/// outputs, while the hierarchical schedule sends O(nodes) cross-node
/// messages per direction instead of O(workers) and pays the §5.3
/// intra-node relay volume (measured, not assumed).
#[test]
fn hierarchical_and_socket_exchanges_match_flat_bitwise() {
    let Some(m) = manifest() else { return };
    let (mdim, f) = (128usize, 512usize);
    let workers = 4usize;
    let node_size = 2usize;
    let counts = [3usize, 5, 2, 4];
    let scales = [(0.5, 2.0), (0.25, 4.0), (1.0, 1.0), (0.75, 3.0)];
    let blocks: Vec<Vec<f32>> = counts
        .iter()
        .enumerate()
        .map(|(w, &c)| {
            (0..c * mdim)
                .map(|i| ((i % 11) as f32 - 5.0) * 0.125 + w as f32 * 0.01)
                .collect()
        })
        .collect();
    let load = |fabric: &Fabric| {
        for w in 0..workers {
            fabric
                .load_expert(
                    w,
                    0,
                    w,
                    diag_weights(mdim, f, scales[w].0, scales[w].1),
                )
                .unwrap();
        }
    };
    let mk_batches = |tag: u64| -> Vec<(usize, ExpertFfnBatch)> {
        (0..workers)
            .map(|w| {
                (
                    w,
                    ExpertFfnBatch {
                        layer: 0,
                        experts: vec![(w, 0, counts[w])],
                        data: HostTensor::f32(
                            &[counts[w], mdim],
                            blocks[w].clone(),
                        ),
                        tag,
                    },
                )
            })
            .collect()
    };
    // Run one exchange, return per-expert outputs plus the observed
    // (cross msgs, intra msgs, intra bytes) deltas.
    let run = |fabric: &Fabric, tag: u64| -> (Vec<Vec<f32>>, u64, u64, u64) {
        let c0 = fabric.traffic.cross_messages.load(Ordering::Relaxed);
        let i0 = fabric.traffic.intra_messages.load(Ordering::Relaxed);
        let b0 = fabric.traffic.intra_bytes.load(Ordering::Relaxed);
        let outstanding = fabric.dispatch_exchange(mk_batches(tag)).unwrap();
        assert_eq!(outstanding, workers, "one part per worker either way");
        let results =
            fabric.collect_ffn_batches(outstanding, 0, tag, &[]).unwrap();
        assert_eq!(results.len(), workers);
        let mut out = vec![Vec::new(); workers];
        for r in &results {
            assert_eq!((r.layer, r.tag), (0, tag));
            assert_eq!(r.experts.len(), 1);
            let (e, c) = r.experts[0];
            assert_eq!(c, counts[e]);
            out[e] = r.data.as_f32().unwrap().to_vec();
        }
        (
            out,
            fabric.traffic.cross_messages.load(Ordering::Relaxed) - c0,
            fabric.traffic.intra_messages.load(Ordering::Relaxed) - i0,
            fabric.traffic.intra_bytes.load(Ordering::Relaxed) - b0,
        )
    };

    // Flat over channels: the reference.
    let fabric = Fabric::spawn(workers, worker_programs(&m)).unwrap();
    load(&fabric);
    let (want, cross, intra, _) = run(&fabric, 5);
    assert_eq!(cross, 2 * workers as u64, "flat: one msg per worker per direction");
    assert_eq!(intra, 0, "flat dispatch uses no intra-node links");
    fabric.shutdown();

    for kind in [TransportKind::Channel, TransportKind::Socket] {
        let mut fabric =
            Fabric::spawn_with(workers, worker_programs(&m), kind).unwrap();
        fabric.set_a2a(A2aMode::Hierarchical { node_size });
        assert_eq!(fabric.a2a(), A2aMode::Hierarchical { node_size });
        load(&fabric);
        let (got, cross, intra, intra_b) = run(&fabric, 6);
        let nodes = (workers / node_size) as u64;
        assert_eq!(
            cross,
            2 * nodes,
            "{kind:?}: hierarchical sends O(nodes) cross-node msgs"
        );
        // Each relay forwards node_size-1 batches out and gathers as many
        // results back over intra-node links.
        assert_eq!(intra, 2 * nodes * (node_size as u64 - 1), "{kind:?}");
        assert!(intra_b > 0, "{kind:?}: relay volume must be counted");
        for e in 0..workers {
            assert_eq!(
                got[e], want[e],
                "{kind:?}: expert {e} output differs from flat dispatch"
            );
        }
        fabric.shutdown();
    }
}

/// A node size that does not divide the worker count falls back to flat
/// dispatch (same contract as the `DSMOE_NODE_SIZE` parser) instead of
/// silently mis-grouping workers.
#[test]
fn non_dividing_node_size_falls_back_to_flat() {
    let Some(m) = manifest() else { return };
    let mut fabric = Fabric::spawn(3, worker_programs(&m)).unwrap();
    fabric.set_a2a(A2aMode::Hierarchical { node_size: 2 });
    assert_eq!(fabric.a2a(), A2aMode::Flat);
    fabric.set_a2a(A2aMode::Hierarchical { node_size: 1 });
    assert_eq!(fabric.a2a(), A2aMode::Flat, "node size 1 degenerates to flat");
    fabric.shutdown();
}

/// Satellite of the stash bound: a relay's coalesced reply carrying one
/// part per node worker must occupy exactly **one** stash slot — the
/// per-generation bound counts coalesced replies, not parts — and a
/// relayed reply whose generation is neither collected nor open still
/// fails loudly.
#[test]
fn relayed_reply_counts_once_in_stash_bound() {
    let Some(m) = manifest() else { return };
    let (mdim, f) = (128usize, 512usize);
    let workers = 2usize; // one node of two workers; worker 0 is the relay
    let mut fabric = Fabric::spawn(workers, worker_programs(&m)).unwrap();
    fabric.set_a2a(A2aMode::Hierarchical { node_size: 2 });
    fabric.load_expert(0, 0, 0, diag_weights(mdim, f, 0.5, 2.0)).unwrap();
    fabric.load_expert(1, 0, 1, diag_weights(mdim, f, 0.25, 4.0)).unwrap();
    let mk_batches = |tag: u64| -> Vec<(usize, ExpertFfnBatch)> {
        (0..workers)
            .map(|w| {
                let c = 3 + w;
                (
                    w,
                    ExpertFfnBatch {
                        layer: 0,
                        experts: vec![(w, 0, c)],
                        data: HostTensor::f32(
                            &[c, mdim],
                            (0..c * mdim)
                                .map(|i| ((i % 13) as f32 - 6.0) * 0.1)
                                .collect(),
                        ),
                        tag,
                    },
                )
            })
            .collect()
    };

    // Two generations in flight.  The single relay completes them in
    // dispatch order, so collecting the *second* first forces the first
    // generation's coalesced reply through the stash.
    assert_eq!(fabric.dispatch_exchange(mk_batches(71)).unwrap(), 2);
    assert_eq!(fabric.dispatch_exchange(mk_batches(72)).unwrap(), 2);
    let r = fabric.collect_ffn_batches(2, 0, 72, &[71]).unwrap();
    assert_eq!(r.len(), 2);
    assert!(r.iter().all(|p| p.tag == 72));
    assert_eq!(
        fabric.stash_depth(),
        1,
        "a relay reply with 2 parts must count once, not per part"
    );
    let r = fabric.collect_ffn_batches(2, 0, 71, &[]).unwrap();
    assert_eq!(r.len(), 2, "both parts come out of the one stash entry");
    assert!(r.iter().all(|p| p.tag == 71));
    assert_eq!(fabric.stash_depth(), 0, "stash drains after the collect");

    // Stale relayed reply: its generation is neither collected nor open —
    // loud error, and the reply is consumed rather than wedging later
    // collects.
    assert_eq!(fabric.dispatch_exchange(mk_batches(73)).unwrap(), 2);
    let err = fabric
        .collect_ffn_batches(1, 0, 99, &[])
        .unwrap_err()
        .to_string();
    assert!(err.contains("stale"), "{err}");
    assert_eq!(fabric.stash_depth(), 0);
    fabric.shutdown();
}

/// Worker errors must stay loud across the socket transport: an error
/// reply serialized through the frame codec still fails the collect with
/// the worker's message.
#[test]
fn socket_transport_errors_stay_loud() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn_with(
        1,
        worker_programs(&m),
        TransportKind::Socket,
    )
    .unwrap();
    fabric
        .dispatch_ffn(0, 0, 0, HostTensor::zeros_f32(&[1, 128]), 0)
        .unwrap();
    let err = fabric.collect_ffn(1).unwrap_err().to_string();
    assert!(err.contains("not loaded"), "{err}");
    fabric.shutdown();
}

#[test]
fn unloaded_expert_is_an_error() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(1, worker_programs(&m)).unwrap();
    fabric
        .dispatch_ffn(0, 0, 0, HostTensor::zeros_f32(&[1, 128]), 0)
        .unwrap();
    let err = fabric.collect_ffn(1).unwrap_err().to_string();
    assert!(err.contains("not loaded"), "{err}");
    fabric.shutdown();
}

#[test]
fn oversized_block_is_an_error() {
    let Some(m) = manifest() else { return };
    let fabric = Fabric::spawn(1, worker_programs(&m)).unwrap();
    let weights = vec![
        HostTensor::zeros_f32(&[128, 512]),
        HostTensor::zeros_f32(&[512]),
        HostTensor::zeros_f32(&[512, 128]),
        HostTensor::zeros_f32(&[128]),
    ];
    fabric.load_expert(0, 0, 0, weights).unwrap();
    // larger than the biggest compiled capacity (512)
    fabric
        .dispatch_ffn(0, 0, 0, HostTensor::zeros_f32(&[600, 128]), 0)
        .unwrap();
    let err = fabric.collect_ffn(1).unwrap_err().to_string();
    assert!(err.contains("exceeds"), "{err}");
    fabric.shutdown();
}
