// De-risk check: pallas-interpret HLO (with While/dynamic-update-slice from
// fori_loop scatter) loads, compiles and runs on the PJRT CPU client.
#[test]
fn load_pallas_moe_hlo() -> anyhow::Result<()> {
    let path = "/tmp/moe_hlo.txt";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: {path} not present");
        return Ok(());
    }
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let (s, e, m, f) = (16usize, 4usize, 8usize, 16usize);
    let mk = |n: usize, dims: &[i64]| -> anyhow::Result<xla::Literal> {
        let v: Vec<f32> = (0..n).map(|i| ((i * 37 % 17) as f32 - 8.0) * 0.05).collect();
        Ok(xla::Literal::vec1(&v).reshape(dims)?)
    };
    let args = vec![
        mk(s * m, &[s as i64, m as i64])?,
        mk(m * e, &[m as i64, e as i64])?,
        mk(e * m * f, &[e as i64, m as i64, f as i64])?,
        mk(e * f, &[e as i64, f as i64])?,
        mk(e * f * m, &[e as i64, f as i64, m as i64])?,
        mk(e * m, &[e as i64, m as i64])?,
    ];
    let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
    let elems = result.to_tuple()?;
    assert_eq!(elems.len(), 2);
    let out = elems[0].to_vec::<f32>()?;
    assert_eq!(out.len(), s * m);
    assert!(out.iter().all(|v| v.is_finite()));
    println!("pallas MoE HLO executed, out[0..4]={:?}", &out[..4]);
    Ok(())
}
