//! SLO-serving invariants on the continuous-batching scheduler, run
//! against an artifact-free in-memory backend:
//!
//! * chunked prefill is a pure latency optimization — per-request token
//!   streams are identical with chunking on and off;
//! * preemption round-trips — an evicted request resumes and produces
//!   exactly the token stream an undisturbed run would have;
//! * backpressure accounting closes — every submission is either queued
//!   or shed, per tier, under both shed policies.
//!
//! The scheduler's inline tests cover the same seams at unit scale; these
//! run through the public crate API (`ds_moe::server::{ForwardModel,
//! Scheduler}`) exactly as an external backend would, including the
//! staged `begin_prefill` / `advance_prefill` / `finish_prefill` chunk
//! protocol that the inline mock does not implement.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;
use ds_moe::config::{ModelConfig, ServingConfig, ShedPolicy};
use ds_moe::coordinator::Request;
use ds_moe::coordinator::Submission;
use ds_moe::metrics::Metrics;
use ds_moe::server::{AdmittedLane, ForwardModel, Scheduler};
use ds_moe::tokenizer::EOS;

/// Prompt-aware deterministic backend: the first token is a function of
/// the *last prompt token* and every decode step increments (mod vocab,
/// skipping EOS).  A request's full token stream therefore depends only
/// on its prompt — any lane mix-up, lost chunk, or resume drift shows up
/// as a token mismatch rather than passing by coincidence.
///
/// Implements the staged-admission protocol: with a non-zero
/// `prefill_chunk` (picked up from [`ServingConfig`] via `configure`),
/// `begin_prefill` stages the batch and reports
/// `ceil(total_prompt_tokens / chunk)` pending chunks, each decode step
/// or `advance_prefill` call drains one, and `finish_prefill` assigns
/// lanes once drained.
struct ChunkMock {
    cfg: ModelConfig,
    metrics: Arc<Metrics>,
    lanes: Vec<Option<u64>>,
    /// Chunked-prefill token budget; 0 = staged admission declined.
    chunk: usize,
    staged: Option<Vec<Request>>,
    pending_chunks: usize,
}

fn next_tok(t: i32, vocab: i32) -> i32 {
    let n = (t + 1).rem_euclid(vocab);
    if n == EOS {
        (n + 1).rem_euclid(vocab)
    } else {
        n
    }
}

impl ChunkMock {
    fn new(lanes: usize) -> Self {
        ChunkMock {
            cfg: ModelConfig {
                name: "chunk-mock".into(),
                vocab_size: 32,
                n_layers: 2,
                d_model: 8,
                n_heads: 2,
                d_ff: 16,
                max_seq: 64,
                experts_schedule: vec![0, 0],
                residual: false,
                top2: false,
                capacity_factor: 1.0,
                moe_loss_coef: 0.0,
                teacher: None,
                kd_alpha: 1.0,
                num_params: 0,
            },
            metrics: Arc::new(Metrics::new()),
            lanes: vec![None; lanes],
            chunk: 0,
            staged: None,
            pending_chunks: 0,
        }
    }

    fn one_hot(&self, tok: i32) -> Vec<f32> {
        let mut row = vec![0f32; self.cfg.vocab_size];
        row[tok as usize] = 1.0;
        row
    }

    fn admit(&mut self, reqs: &[Request]) -> Result<Vec<AdmittedLane>> {
        let vocab = self.cfg.vocab_size as i32;
        let mut out = Vec::new();
        for req in reqs {
            let lane = self
                .lanes
                .iter()
                .position(|l| l.is_none())
                .expect("no free lane");
            self.lanes[lane] = Some(req.id);
            let last = *req.prompt.last().expect("non-empty prompt");
            out.push(AdmittedLane {
                lane,
                logits: self.one_hot(next_tok(last, vocab)),
            });
        }
        Ok(out)
    }
}

impl ForwardModel for ChunkMock {
    fn model_config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn configure(&mut self, serving: &ServingConfig) {
        self.chunk = serving.prefill_chunk;
    }
    fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
    fn set_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = metrics;
    }
    fn prefill_sizes(&self) -> Vec<usize> {
        vec![1, 2, 4]
    }
    fn lane_count(&self) -> usize {
        self.lanes.len()
    }
    fn free_lane_count(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_none()).count()
    }
    fn prefill(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<Vec<AdmittedLane>> {
        anyhow::ensure!(reqs.len() <= compiled);
        self.admit(reqs)
    }
    fn begin_prefill(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<bool> {
        if self.chunk == 0 {
            return Ok(false);
        }
        anyhow::ensure!(reqs.len() <= compiled);
        anyhow::ensure!(self.staged.is_none(), "admission already staged");
        let total: usize = reqs.iter().map(|r| r.prompt.len()).sum();
        self.pending_chunks = total.div_ceil(self.chunk);
        self.staged = Some(reqs.to_vec());
        Ok(true)
    }
    fn finish_prefill(&mut self) -> Result<Vec<AdmittedLane>> {
        anyhow::ensure!(self.pending_chunks == 0, "chunks still pending");
        let reqs = self
            .staged
            .take()
            .ok_or_else(|| anyhow::anyhow!("no staged admission"))?;
        self.admit(&reqs)
    }
    fn prefill_pending(&self) -> bool {
        self.staged.is_some() && self.pending_chunks > 0
    }
    fn advance_prefill(&mut self) -> Result<()> {
        anyhow::ensure!(self.staged.is_some(), "no staged admission");
        self.pending_chunks = self.pending_chunks.saturating_sub(1);
        Ok(())
    }
    fn decode_step(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(tokens.len() == self.lanes.len());
        anyhow::ensure!(pos.len() == self.lanes.len());
        // A staged admission advances one chunk behind each decode step.
        if self.staged.is_some() {
            self.pending_chunks = self.pending_chunks.saturating_sub(1);
        }
        let vocab = self.cfg.vocab_size as i32;
        Ok((0..self.lanes.len())
            .map(|lane| self.one_hot(next_tok(tokens[lane], vocab)))
            .collect())
    }
    fn release(&mut self, lane: usize) {
        self.lanes[lane] = None;
    }
}

fn serving(prefill_chunk: usize) -> ServingConfig {
    ServingConfig {
        max_new_tokens: 6,
        batch_timeout: std::time::Duration::ZERO,
        prefill_chunk,
        ..Default::default()
    }
}

/// One lane mid-decode, then a burst of admissions that must ride the
/// staged (and, when `chunk > 0`, chunked) path.  Returns tokens by id.
fn run_burst(chunk: usize) -> (HashMap<u64, Vec<i32>>, Scheduler<ChunkMock>) {
    let mut s = Scheduler::new(ChunkMock::new(4), serving(chunk));
    s.submit(vec![5, 6, 7], Some(6)).unwrap();
    for _ in 0..2 {
        s.step().unwrap();
    }
    assert_eq!(s.active_count(), 1);
    // Distinct prompts: a lane mix-up would cross token streams.
    s.submit(vec![9, 10], Some(6)).unwrap();
    s.submit(vec![20, 21, 22, 23], Some(6)).unwrap();
    s.submit(vec![13], Some(6)).unwrap();
    let responses = s.run_until_idle().unwrap();
    assert_eq!(responses.len(), 4);
    let by_id = responses.into_iter().map(|r| (r.id, r.tokens)).collect();
    (by_id, s)
}

#[test]
fn chunked_prefill_token_parity() {
    let (off, s_off) = run_burst(0);
    // Budget of 3 over a 2..=4-token-per-prompt burst: multi-chunk
    // admissions, exercising both the behind-decode and idle-lane
    // (`advance_prefill`) drain paths.
    let (on, s_on) = run_burst(3);
    assert_eq!(off.len(), on.len());
    for (id, toks) in &off {
        assert_eq!(on.get(id), Some(toks), "request {id} token stream");
    }
    assert_eq!(s_off.metrics.counter("chunked_admissions"), 0);
    assert!(
        s_on.metrics.counter("chunked_admissions") >= 1,
        "burst admissions must have taken the chunked path"
    );
    // Nothing left staged in either backend.
    assert!(!s_off.model.prefill_pending());
    assert!(!s_on.model.prefill_pending());
    assert_eq!(s_on.model.free_lane_count(), 4);
}

#[test]
fn preemption_round_trip_resumes_identical_continuation() {
    // Reference: the victim runs start-to-finish undisturbed.
    let mut r = Scheduler::new(ChunkMock::new(1), serving(0));
    let ref_id = r.submit(vec![9, 10], Some(6)).unwrap();
    let reference = r.run_until_idle().unwrap();
    assert_eq!(reference.len(), 1);
    assert_eq!(reference[0].id, ref_id);
    let want = reference[0].tokens.clone();
    assert_eq!(want.len(), 6);

    // Same prompt on a single lane, evicted mid-decode by a tier-1
    // arrival, then resumed.
    let mut s = Scheduler::new(ChunkMock::new(1), serving(0));
    let victim = s.submit(vec![9, 10], Some(6)).unwrap();
    for _ in 0..3 {
        s.step().unwrap();
    }
    assert_eq!(s.active_count(), 1);
    let sub = s.submit_tiered(vec![4], Some(2), 1, None).unwrap();
    assert!(matches!(sub, Submission::Queued(_)));
    let responses = s.run_until_idle().unwrap();
    assert_eq!(s.metrics.counter("preemptions"), 1);
    assert_eq!(s.metrics.counter("preempted_t0"), 1);
    assert_eq!(s.metrics.counter("resumed"), 1);
    assert_eq!(responses.len(), 2);
    let got = responses.iter().find(|r| r.id == victim).unwrap();
    // The continuation is token-identical: no lost, duplicated, or
    // diverged tokens across the evict/re-prefill/resume round trip.
    assert_eq!(got.tokens, want);
    assert_eq!(got.prompt_len, 2, "original prompt_len reported");
}

#[test]
fn preemption_round_trip_under_chunked_prefill() {
    // Same round trip with chunking on and a second lane kept busy, so
    // the victim's re-admission (generated prefix folded into the
    // prompt, several tokens over the 2-token budget) rides the chunked
    // protocol behind the other lane's decode steps.
    let mut r = Scheduler::new(ChunkMock::new(1), serving(0));
    let ref_id = r.submit(vec![17, 18, 19], Some(6)).unwrap();
    let reference = r.run_until_idle().unwrap();
    let want = reference[0].tokens.clone();
    assert_eq!(reference[0].id, ref_id);

    let mut s = Scheduler::new(ChunkMock::new(2), serving(2));
    let victim = s.submit(vec![17, 18, 19], Some(6)).unwrap();
    for _ in 0..2 {
        s.step().unwrap();
    }
    // A long-running companion keeps its lane decoding throughout, so
    // every later admission goes through begin/finish_prefill.
    s.submit(vec![25], Some(12)).unwrap();
    s.step().unwrap();
    assert_eq!(s.active_count(), 2);
    // The victim has the most generated tokens → it is the one evicted.
    s.submit_tiered(vec![4], Some(2), 1, None).unwrap();
    let responses = s.run_until_idle().unwrap();
    assert_eq!(s.metrics.counter("preemptions"), 1);
    assert_eq!(s.metrics.counter("resumed"), 1);
    assert!(
        s.metrics.counter("chunked_admissions") >= 1,
        "the folded-prompt re-admission must exceed the chunk budget"
    );
    assert_eq!(responses.len(), 3);
    let got = responses.iter().find(|r| r.id == victim).unwrap();
    assert_eq!(got.tokens, want);
    assert_eq!(got.prompt_len, 3, "original prompt_len reported");
}

#[test]
fn backpressure_accounting_reject() {
    let mut s = Scheduler::new(
        ChunkMock::new(1),
        ServingConfig {
            max_new_tokens: 4,
            batch_timeout: std::time::Duration::ZERO,
            queue_cap: 2,
            shed_policy: ShedPolicy::Reject,
            ..Default::default()
        },
    );
    // Six valid submissions across two tiers, no steps in between: each
    // tier's queue caps at 2, the overflow is shed at the door.
    let mut queued = [0u64; 2];
    let mut shed = [0u64; 2];
    for i in 0..6u8 {
        let tier = i % 2;
        let sub = s
            .submit_tiered(vec![3 + i as i32], Some(4), tier, None)
            .unwrap();
        match sub {
            Submission::Queued(_) => queued[tier as usize] += 1,
            Submission::Shed => shed[tier as usize] += 1,
        }
    }
    for t in 0..2 {
        assert_eq!(queued[t], 2, "tier {t} queued");
        assert_eq!(shed[t], 1, "tier {t} shed");
        assert_eq!(s.metrics.counter(&format!("queued_t{t}")), queued[t]);
        assert_eq!(s.metrics.counter(&format!("shed_t{t}")), shed[t]);
        // The books close per tier: queued + shed == submitted.
        assert_eq!(queued[t] + shed[t], 3);
    }
    assert_eq!(s.metrics.counter("requests_submitted"), 6);
    assert_eq!(s.metrics.counter("requests_shed"), 2);
    // Everything queued completes; nothing shed resurfaces.
    let responses = s.run_until_idle().unwrap();
    assert_eq!(responses.len(), 4);
    assert_eq!(s.metrics.counter("requests_completed"), 4);
}

#[test]
fn backpressure_accounting_drop_oldest() {
    let mut s = Scheduler::new(
        ChunkMock::new(1),
        ServingConfig {
            max_new_tokens: 4,
            batch_timeout: std::time::Duration::ZERO,
            queue_cap: 1,
            shed_policy: ShedPolicy::DropOldest,
            ..Default::default()
        },
    );
    // Under DropOldest every submission is admitted (Queued) but each
    // overflow displaces — sheds — the tier's oldest waiter.
    for i in 0..3 {
        let sub = s.submit_tiered(vec![5 + i], Some(4), 0, None).unwrap();
        assert!(matches!(sub, Submission::Queued(_)), "submission {i}");
    }
    assert_eq!(s.metrics.counter("requests_submitted"), 3);
    assert_eq!(s.metrics.counter("queued_t0"), 3);
    assert_eq!(s.metrics.counter("shed_t0"), 2);
    assert_eq!(s.metrics.counter("requests_shed"), 2);
    // queued - shed survivors actually run.
    let responses = s.run_until_idle().unwrap();
    assert_eq!(responses.len(), 1);
    // The survivor is the *newest* submission (prompt token 7 → first
    // generated token 8).
    assert_eq!(responses[0].tokens[0], 8);
}
