//! End-to-end serving over the monolithic engine: submit real requests,
//! batch, prefill, decode, retire — using the AOT artifacts.

use ds_moe::config::ServingConfig;
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::runtime::Manifest;
use ds_moe::server::Engine;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new("artifacts");
    root.join("manifest.json")
        .exists()
        .then(|| Manifest::load(root).unwrap())
}

fn corpus() -> Corpus {
    Corpus::generate(CorpusConfig {
        train_seqs: 64,
        valid_seqs: 64,
        ..Default::default()
    })
}

#[test]
fn serve_batch_of_requests_moe() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new(
        &m,
        ServingConfig {
            model: "moe-s-8".into(),
            max_new_tokens: 6,
            batch_timeout: std::time::Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();
    let c = corpus();
    let mut ids = Vec::new();
    for i in 0..10 {
        ids.push(engine.submit(c.prompt(i, 8), Some(6)).unwrap());
    }
    let responses = engine.run_until_idle().unwrap();
    assert_eq!(responses.len(), 10);
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort();
    assert_eq!(got, ids);
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 6);
        assert!(r.tokens.iter().all(|&t| (0..512).contains(&t)));
        assert!(r.ttft <= r.total);
        assert_eq!(r.prompt_len, 8);
    }
    assert_eq!(engine.metrics.counter("requests_completed"), 10);
    assert!(engine.metrics.counter("decode_steps") >= 5);
}

#[test]
fn greedy_decoding_is_deterministic() {
    let Some(m) = manifest() else { return };
    let gen = |_: u64| -> Vec<i32> {
        let mut e = Engine::new(
            &m,
            ServingConfig { model: "moe-s-8".into(), ..Default::default() },
        )
        .unwrap();
        let c = corpus();
        e.submit(c.prompt(3, 8), Some(8)).unwrap();
        let r = e.run_until_idle().unwrap();
        r[0].tokens.clone()
    };
    assert_eq!(gen(0), gen(1));
}

#[test]
fn continuous_batching_admits_mid_flight() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new(
        &m,
        ServingConfig {
            model: "dense-s".into(),
            max_new_tokens: 10,
            batch_timeout: std::time::Duration::ZERO, // admit immediately
            ..Default::default()
        },
    )
    .unwrap();
    let c = corpus();
    engine.submit(c.prompt(0, 8), Some(10)).unwrap();
    // a few decode steps alone
    for _ in 0..3 {
        engine.step().unwrap();
    }
    assert_eq!(engine.active_count(), 1);
    // second request joins while the first is mid-decode
    engine.submit(c.prompt(1, 4), Some(4)).unwrap();
    let responses = engine.run_until_idle().unwrap();
    assert_eq!(responses.len(), 2);
    // the late-joining short request must still be complete and correct
    let late = responses.iter().find(|r| r.prompt_len == 4).unwrap();
    assert_eq!(late.tokens.len(), 4);
}

#[test]
fn prompts_longer_than_budget_rejected() {
    let Some(m) = manifest() else { return };
    let mut engine = Engine::new(
        &m,
        ServingConfig { model: "dense-s".into(), ..Default::default() },
    )
    .unwrap();
    assert!(engine.submit(vec![1; 60], Some(10)).is_err());
    assert!(engine.submit(vec![], None).is_err());
    assert!(engine.submit(vec![999], Some(1)).is_err());
}

#[test]
fn serve_all_exported_variants() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    for model in ["dense-s", "moe-s-8", "prmoe-s", "mos-s"] {
        let mut e = Engine::new(
            &m,
            ServingConfig {
                model: model.into(),
                max_new_tokens: 3,
                ..Default::default()
            },
        )
        .unwrap();
        e.submit(c.prompt(0, 8), Some(3)).unwrap();
        let r = e.run_until_idle().unwrap();
        assert_eq!(r.len(), 1, "{model}");
        assert_eq!(r[0].tokens.len(), 3, "{model}");
    }
}
