//! End-to-end serving through the continuous-batching scheduler: submit
//! real requests, batch, prefill, decode, retire — over both backends
//! (the monolithic engine and the expert-parallel engine), using the AOT
//! artifacts.

use ds_moe::config::{AllToAllKind, ServingConfig};
use ds_moe::coordinator::Request;
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::runtime::Manifest;
use ds_moe::server::{Engine, EpEngine, ForwardModel, Scheduler};
use ds_moe::util::stats::argmax;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new("artifacts");
    root.join("manifest.json")
        .exists()
        .then(|| Manifest::load(root).unwrap())
}

fn corpus() -> Corpus {
    Corpus::generate(CorpusConfig {
        train_seqs: 64,
        valid_seqs: 64,
        ..Default::default()
    })
}

fn mono(m: &Manifest, serving: ServingConfig) -> Scheduler<Engine> {
    Scheduler::new(Engine::new(m, serving.clone()).unwrap(), serving)
}

#[test]
fn serve_batch_of_requests_moe() {
    let Some(m) = manifest() else { return };
    let mut engine = mono(
        &m,
        ServingConfig {
            model: "moe-s-8".into(),
            max_new_tokens: 6,
            batch_timeout: std::time::Duration::from_millis(1),
            ..Default::default()
        },
    );
    let c = corpus();
    let mut ids = Vec::new();
    for i in 0..10 {
        ids.push(engine.submit(c.prompt(i, 8), Some(6)).unwrap());
    }
    let responses = engine.run_until_idle().unwrap();
    assert_eq!(responses.len(), 10);
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort();
    assert_eq!(got, ids);
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 6);
        assert!(r.tokens.iter().all(|&t| (0..512).contains(&t)));
        assert!(r.ttft <= r.total);
        assert_eq!(r.prompt_len, 8);
    }
    assert_eq!(engine.metrics.counter("requests_completed"), 10);
    assert!(engine.metrics.counter("decode_steps") >= 5);
    // The scheduler's occupancy metrics are populated.
    assert!(engine.metrics.value_count("decode_utilization") > 0);
    let occ = engine.metrics.value_mean("decode_utilization");
    assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ}");
}

#[test]
fn greedy_decoding_is_deterministic() {
    let Some(m) = manifest() else { return };
    let gen = |_: u64| -> Vec<i32> {
        let mut e = mono(
            &m,
            ServingConfig { model: "moe-s-8".into(), ..Default::default() },
        );
        let c = corpus();
        e.submit(c.prompt(3, 8), Some(8)).unwrap();
        let r = e.run_until_idle().unwrap();
        r[0].tokens.clone()
    };
    assert_eq!(gen(0), gen(1));
}

#[test]
fn temperature_sampling_reproducible_by_seed() {
    let Some(m) = manifest() else { return };
    let gen = |seed: u64| -> Vec<i32> {
        let mut e = mono(
            &m,
            ServingConfig {
                model: "moe-s-8".into(),
                temperature: 0.8,
                seed,
                ..Default::default()
            },
        );
        let c = corpus();
        e.submit(c.prompt(3, 8), Some(8)).unwrap();
        let r = e.run_until_idle().unwrap();
        r[0].tokens.clone()
    };
    // Same seed -> same sampled generation; the seed is plumbed through
    // ServingConfig (no hard-coded RNG in the engine anymore).
    assert_eq!(gen(17), gen(17));
}

#[test]
fn continuous_batching_admits_mid_flight() {
    let Some(m) = manifest() else { return };
    let mut engine = mono(
        &m,
        ServingConfig {
            model: "dense-s".into(),
            max_new_tokens: 10,
            batch_timeout: std::time::Duration::ZERO, // admit immediately
            ..Default::default()
        },
    );
    let c = corpus();
    engine.submit(c.prompt(0, 8), Some(10)).unwrap();
    // a few decode steps alone
    for _ in 0..3 {
        engine.step().unwrap();
    }
    assert_eq!(engine.active_count(), 1);
    // second request joins while the first is mid-decode
    engine.submit(c.prompt(1, 4), Some(4)).unwrap();
    let responses = engine.run_until_idle().unwrap();
    assert_eq!(responses.len(), 2);
    // the late-joining short request must still be complete and correct
    let late = responses.iter().find(|r| r.prompt_len == 4).unwrap();
    assert_eq!(late.tokens.len(), 4);
}

#[test]
fn prompts_longer_than_budget_rejected() {
    let Some(m) = manifest() else { return };
    let mut engine = mono(
        &m,
        ServingConfig { model: "dense-s".into(), ..Default::default() },
    );
    assert!(engine.submit(vec![1; 60], Some(10)).is_err());
    assert!(engine.submit(vec![], None).is_err());
    assert!(engine.submit(vec![999], Some(1)).is_err());
}

#[test]
fn serve_all_exported_variants() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    for model in ["dense-s", "moe-s-8", "prmoe-s", "mos-s"] {
        let mut e = mono(
            &m,
            ServingConfig {
                model: model.into(),
                max_new_tokens: 3,
                ..Default::default()
            },
        );
        e.submit(c.prompt(0, 8), Some(3)).unwrap();
        let r = e.run_until_idle().unwrap();
        assert_eq!(r.len(), 1, "{model}");
        assert_eq!(r[0].tokens.len(), 3, "{model}");
    }
}

/// Continuous batching over the expert-parallel engine: more requests
/// than lanes, arrival-driven admission, lane reuse after retirement, and
/// dead-lane masking (retired lanes send no expert traffic) — the tier-1
/// smoke test `scripts/check.sh` runs by name.
#[test]
fn ep_scheduler_continuous_batching_smoke() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    let batch = 8usize;
    let ep = EpEngine::new(&m, "moe-s-8", 4, AllToAllKind::Hierarchical, batch)
        .unwrap();
    let mut sched = Scheduler::new(
        ep,
        ServingConfig {
            model: "moe-s-8".into(),
            max_batch: batch,
            max_new_tokens: 5,
            batch_timeout: std::time::Duration::from_millis(1),
            ..Default::default()
        },
    );
    // First wave fills the lanes, the trickle joins mid-decode.
    let mut ids = Vec::new();
    for i in 0..batch {
        ids.push(sched.submit(c.prompt(i, 8), Some(5)).unwrap());
    }
    for _ in 0..2 {
        sched.step().unwrap();
    }
    for i in batch..batch + 4 {
        ids.push(sched.submit(c.prompt(i, 8), Some(3)).unwrap());
    }
    let responses = sched.run_until_idle().unwrap();
    assert_eq!(responses.len(), batch + 4);
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort();
    assert_eq!(got, ids);
    for r in &responses {
        assert!(!r.tokens.is_empty());
        assert!(r.ttft <= r.total);
    }
    // All lanes drained and reusable.
    assert_eq!(sched.active_count(), 0);
    assert_eq!(sched.queue_len(), 0);
    assert_eq!(sched.metrics.counter("requests_completed"), (batch + 4) as u64);
    // The fabric's tag-keyed stash is empty between forwards.
    assert_eq!(sched.model.fabric_stash_depth(), 0);
    // Occupancy metrics recorded (busy lanes per decode step).
    assert!(sched.metrics.value_count("decode_utilization") > 0);
}

/// Skewed-retirement regroup: drive the EP engine's `ForwardModel` API
/// directly, retire every lane of one pipeline group, and check that the
/// next decode step (a) rebalances live lanes across the groups, (b) keeps
/// the surviving requests' logits **bit-identical** to an engine that
/// never regroups (lane migration is invisible to the math), and (c)
/// still sends no dead-lane expert traffic.  With `leader_threads >= 2`
/// the same invariants hold through the shard cache protocol (the lanes
/// move between shard-owned groups via ReadLanes/WriteLanes).
fn regroup_rebalances_skewed_retirement(leader_threads: usize) {
    let Some(m) = manifest() else { return };
    let c = corpus();
    let batch = 8usize;
    let plen = 8usize;
    let mk_engine = |regroup: bool| {
        let mut ep = EpEngine::new(
            &m,
            "moe-s-8",
            4,
            AllToAllKind::Hierarchical,
            batch,
        )
        .unwrap();
        // Pin the depth and threshold explicitly so ambient
        // DSMOE_PIPE_DEPTH / DSMOE_REGROUP_SKEW env vars cannot skew the
        // hard-coded two-group expectations below.
        ep.set_pipe_depth(2);
        ep.set_leader_threads(leader_threads);
        if regroup {
            ep.set_regroup_skew(2);
        } else {
            // A skew threshold no retirement pattern can reach pins the
            // no-regroup reference.
            ep.set_regroup_skew(usize::MAX);
        }
        ep
    };
    let mk_reqs = || -> Vec<Request> {
        (0..batch)
            .map(|i| Request {
                id: i as u64 + 1,
                prompt: c.prompt(i, plen),
                max_new_tokens: 8,
                arrival: std::time::Instant::now(),
                tier: 0,
                deadline: None,
            })
            .collect()
    };
    let mut ep = mk_engine(true);
    let mut reference = mk_engine(false);
    if ep.microbatches() < 2 {
        eprintln!("  note: pipeline unavailable; regroup test skipped");
        return;
    }
    let admitted = ep.prefill(batch, &mk_reqs()).unwrap();
    let admitted_ref = reference.prefill(batch, &mk_reqs()).unwrap();
    assert_eq!(admitted.len(), batch);
    // Balanced admission fills both groups evenly.
    assert_eq!(ep.group_live_counts(), vec![4, 4]);

    let mut tokens = vec![0i32; batch];
    let mut pos = vec![0i32; batch];
    for (adm, ar) in admitted.iter().zip(&admitted_ref) {
        assert_eq!(adm.lane, ar.lane);
        assert_eq!(adm.logits, ar.logits, "admission logits differ");
        tokens[adm.lane] = argmax(&adm.logits) as i32;
        pos[adm.lane] = plen as i32;
    }
    // One full-occupancy decode step first: under a multi-threaded
    // leader this migrates the cache groups into the shard pool, so the
    // regroup below exercises the shard-owned-cache path.
    {
        let rows = ep.decode_step(&tokens, &pos).unwrap();
        let rows_ref = reference.decode_step(&tokens, &pos).unwrap();
        for lane in 0..batch {
            assert_eq!(rows[lane], rows_ref[lane], "pre-release decode");
            tokens[lane] = argmax(&rows[lane]) as i32;
            pos[lane] += 1;
        }
    }

    // Retire every lane of group 0 (external ids == physical before any
    // regroup), skewing occupancy to 0 vs 4.
    let mut live: Vec<usize> = Vec::new();
    for adm in &admitted {
        if adm.lane < batch / 2 {
            ep.release(adm.lane);
            reference.release(adm.lane);
        } else {
            live.push(adm.lane);
        }
    }
    assert_eq!(ep.group_live_counts(), vec![0, 4]);

    // Three decode steps: the first triggers the rebalance; all of them
    // must match the never-regrouping engine bit-for-bit on live lanes.
    for step in 0..3 {
        let rows = ep.decode_step(&tokens, &pos).unwrap();
        let rows_ref = reference.decode_step(&tokens, &pos).unwrap();
        for &lane in &live {
            assert_eq!(
                rows[lane], rows_ref[lane],
                "step {step}: lane {lane} diverged after regroup"
            );
            tokens[lane] = argmax(&rows[lane]) as i32;
            pos[lane] += 1;
        }
    }
    // Rebalanced: live load spread evenly across the groups...
    let counts = ep.group_live_counts();
    assert_eq!(counts.iter().sum::<usize>(), live.len());
    let (min, max) = (
        *counts.iter().min().unwrap(),
        *counts.iter().max().unwrap(),
    );
    assert!(max - min <= 1, "still skewed after regroup: {counts:?}");
    assert!(ep.metrics.counter("lane_regroups") >= 1);
    // ...while the reference never moved a lane.
    assert_eq!(reference.group_live_counts(), vec![0, 4]);
    assert_eq!(reference.metrics.counter("lane_regroups"), 0);

    // No dead-lane expert traffic after the migration: one more decode
    // step adds exactly `live.len()` tokens per MoE layer.
    let before: Vec<u64> =
        ep.load_stats.iter().map(|s| s.total_tokens).collect();
    ep.decode_step(&tokens, &pos).unwrap();
    for (s, b) in ep.load_stats.iter().zip(before) {
        assert_eq!(
            s.total_tokens,
            b + live.len() as u64,
            "layer {}: dead lanes leaked into expert routing after \
             regroup",
            s.layer
        );
    }
}

#[test]
fn ep_regroup_rebalances_skewed_retirement() {
    regroup_rebalances_skewed_retirement(1);
}

#[test]
fn ep_regroup_rebalances_skewed_retirement_leader_shards() {
    // The same regroup, with the cache groups owned by leader shards:
    // the lane moves run over the ReadLanes/WriteLanes shard protocol.
    regroup_rebalances_skewed_retirement(2);
}

/// Slow-shard injection: shard 0 sleeps before every layer, so it
/// dispatches late and finishes last — shard completion leaves submission
/// order — while the orchestrator still collects the tagged exchanges
/// oldest-first and the logits stay bit-identical to the single-threaded
/// leader.  One of the tier-1 tests `scripts/check.sh` runs by name.
#[test]
fn leader_shard_slow_shard_collects_oldest_first() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    let batch = 8usize;
    let plen = 8usize;
    let mk = |threads: usize| {
        let mut ep = EpEngine::new(
            &m,
            "moe-s-8",
            4,
            AllToAllKind::Hierarchical,
            batch,
        )
        .unwrap();
        ep.set_serial_moe(false);
        ep.set_pipeline(true);
        ep.set_pipe_depth(2);
        ep.set_leader_threads(threads);
        ep
    };
    let mut single = mk(1);
    let mut slow = mk(2);
    if single.microbatches() < 2 {
        eprintln!("  note: pipeline unavailable; slow-shard test skipped");
        return;
    }
    // Shard 0 sleeps 2ms at every layer start: shard 1 overtakes it on
    // every forward, deterministically.
    slow.inject_slow_shard(0, std::time::Duration::from_millis(2));

    let smax = single.cfg.max_seq;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = c.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }
    let rs = single.forward_prefill(&tokens, &lens).unwrap();
    let rp = slow.forward_prefill(&tokens, &lens).unwrap();
    assert_eq!(rp, rs, "slow-shard prefill diverged");

    let mut tok: Vec<i32> = rs.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..2 {
        let ds = single.forward_decode(&tok, &pos).unwrap();
        let dp = slow.forward_decode(&tok, &pos).unwrap();
        assert_eq!(dp, ds, "slow-shard decode step {step} diverged");
        tok = ds.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
    // Completion genuinely left submission order (shard 0 last)...
    assert_eq!(
        slow.last_shard_completions().to_vec(),
        vec![1, 0],
        "slow shard did not finish last"
    );
    assert!(
        slow.metrics.counter("shard_completions_ooo") >= 1,
        "out-of-order completion not observed"
    );
    // ...yet the exchange discipline held: no stale replies, no stash
    // residue, bit-identical logits (asserted above).
    assert_eq!(slow.fabric_stash_depth(), 0);
}

/// Fabric workers and leader shards are OS threads: dropping the engine
/// must join them all — no `dsmoe-*` thread may outlive its engine
/// (leaked threads accumulate across a test suite).  One of the tier-1
/// tests `scripts/check.sh` runs by name.
#[test]
fn leader_shard_and_fabric_threads_join_on_drop() {
    if !cfg!(target_os = "linux") {
        return; // /proc-based thread enumeration
    }
    fn dsmoe_threads() -> usize {
        let Ok(tasks) = std::fs::read_dir("/proc/self/task") else {
            return 0;
        };
        tasks
            .flatten()
            .filter(|t| {
                std::fs::read_to_string(t.path().join("comm"))
                    .map(|c| c.trim_end().starts_with("dsmoe-"))
                    .unwrap_or(false)
            })
            .count()
    }
    let Some(m) = manifest() else { return };
    let c = corpus();
    let before = dsmoe_threads();
    {
        let batch = 4usize;
        let mut ep = EpEngine::new(
            &m,
            "moe-s-8",
            2,
            AllToAllKind::Hierarchical,
            batch,
        )
        .unwrap();
        ep.set_pipe_depth(2);
        ep.set_leader_threads(2);
        let smax = ep.cfg.max_seq;
        let plen = 8usize;
        let mut tokens = vec![0i32; batch * smax];
        let lens = vec![plen; batch];
        for b in 0..batch {
            let p = c.prompt(b, plen);
            tokens[b * smax..b * smax + plen].copy_from_slice(&p);
        }
        // A forward spawns the shard pool (if the ring engaged): at
        // minimum this engine's 2 fabric workers are alive now.
        ep.forward_prefill(&tokens, &lens).unwrap();
        assert!(dsmoe_threads() >= 2, "engine threads not running");
        drop(ep);
    }
    // Drop joins synchronously, so *this* engine's threads are gone the
    // moment it returns.  Other tests in this binary create their own
    // engines concurrently, so poll until the count returns to the
    // baseline instead of asserting an instant snapshot.
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(120);
    loop {
        let now = dsmoe_threads();
        if now <= before {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "dsmoe threads leaked past engine drop: {now} > {before}"
        );
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
}

/// Chunked prefill over the expert-parallel engine is a pure latency
/// optimization: the same request mix produces byte-identical token
/// streams with `prefill_chunk` on and off.  Two long-running requests
/// keep lanes decoding while a late wave arrives, so the late admissions
/// ride the staged path — with a tiny chunk budget they stay parked
/// across several decode steps (`chunked_admissions`), with the budget
/// off they complete behind a single step, and either way the math must
/// not change.  One of the tests `scripts/check.sh` runs by name.
#[test]
fn ep_chunked_prefill_token_parity() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    let batch = 8usize;
    let run = |chunk: usize| {
        let mut ep = EpEngine::new(
            &m,
            "moe-s-8",
            4,
            AllToAllKind::Hierarchical,
            batch,
        )
        .unwrap();
        // Pin the staged-admission path on: ambient DSMOE_SERIAL_MOE /
        // DSMOE_NO_INTERLEAVE env vars would silently force the
        // stop-the-world admissions this test exists to compare against.
        ep.set_serial_moe(false);
        ep.set_interleave(true);
        let mut sched = Scheduler::new(
            ep,
            ServingConfig {
                model: "moe-s-8".into(),
                max_batch: batch,
                max_new_tokens: 5,
                batch_timeout: std::time::Duration::ZERO,
                prefill_chunk: chunk,
                ..Default::default()
            },
        );
        // Two long-runners hold their lanes through the late wave's
        // admission (staggered budgets → staggered retirement).
        let mut ids = vec![
            sched.submit(c.prompt(0, 8), Some(12)).unwrap(),
            sched.submit(c.prompt(1, 8), Some(10)).unwrap(),
        ];
        for _ in 0..2 {
            sched.step().unwrap();
        }
        assert_eq!(sched.active_count(), 2);
        for i in 2..6 {
            ids.push(sched.submit(c.prompt(i, 8), Some(4)).unwrap());
        }
        let responses = sched.run_until_idle().unwrap();
        assert_eq!(responses.len(), ids.len());
        let chunked = sched.metrics.counter("chunked_admissions");
        let mut toks: Vec<(u64, Vec<i32>)> =
            responses.into_iter().map(|r| (r.id, r.tokens)).collect();
        toks.sort();
        (toks, chunked)
    };
    let (off, chunked_off) = run(0);
    // A 4-token budget against 8-token prompts: every staged admission
    // needs multiple decode steps to drain.
    let (on, chunked_on) = run(4);
    assert_eq!(chunked_off, 0, "budget off must not take the chunked path");
    assert!(chunked_on >= 1, "budget on never took the chunked path");
    assert_eq!(off, on, "chunked prefill changed the generated tokens");
}

/// Dead lanes must send no expert traffic: serve a single request on an
/// 8-lane EP engine and check the load stats account exactly the live
/// tokens (prompt tokens at admission + one per decode step), not
/// `8 * tokens` of the padded lane group.
#[test]
fn ep_scheduler_dead_lanes_send_no_expert_traffic() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    let batch = 8usize;
    let ep = EpEngine::new(&m, "moe-s-8", 4, AllToAllKind::Hierarchical, batch)
        .unwrap();
    let smax = ep.cfg.max_seq;
    let mut sched = Scheduler::new(
        ep,
        ServingConfig {
            model: "moe-s-8".into(),
            max_batch: batch,
            max_new_tokens: 4,
            batch_timeout: std::time::Duration::ZERO,
            ..Default::default()
        },
    );
    sched.submit(c.prompt(0, 8), Some(4)).unwrap();
    let responses = sched.run_until_idle().unwrap();
    assert_eq!(responses.len(), 1);
    let decode_steps = sched.metrics.counter("decode_steps");
    assert!(decode_steps >= 1, "decode_steps {decode_steps}");
    for s in &sched.model.load_stats {
        // Admission prefill runs at compiled lane count 1 (all live), so
        // each MoE layer sees smax prompt-padded tokens once, then one
        // live token per decode step — the 7 dead lanes contribute none.
        assert_eq!(
            s.total_tokens,
            smax as u64 + decode_steps,
            "layer {}: dead lanes leaked into expert routing",
            s.layer
        );
    }
}
