//! The correctness anchor of the whole stack: the disaggregated
//! expert-parallel engine (leader + fabric workers, host-side gating,
//! real token exchange) must produce the same logits as the monolithic
//! AOT program (fused Pallas kernels inside one XLA executable) for the
//! same weights and inputs.

use ds_moe::config::{AllToAllKind, ServingConfig};
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::fabric::TransportKind;
use ds_moe::runtime::{Checkpoint, Dtype, HostTensor, Manifest, Runtime};
use ds_moe::server::{EpEngine, Scheduler};
use ds_moe::tokenizer::EOS;
use ds_moe::util::stats::argmax;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new("artifacts");
    root.join("manifest.json")
        .exists()
        .then(|| Manifest::load(root).unwrap())
}

/// Run the monolithic prefill program directly; return logits rows at
/// each lane's last prompt position plus the KV caches.
fn monolithic_prefill(
    m: &Manifest,
    model: &str,
    tokens: &[i32],
    lens: &[usize],
    batch: usize,
) -> (Vec<Vec<f32>>, HostTensor, HostTensor) {
    let arts = m.model(model).unwrap();
    let cfg = &arts.config;
    let rt = Runtime::cpu().unwrap();
    let prog = rt
        .load(arts.programs.get(&format!("prefill_b{batch}")).unwrap())
        .unwrap();
    let ck = Checkpoint::load(&arts.checkpoint_dir).unwrap();
    let mut inputs: Vec<HostTensor> = ck.tensors.clone();
    inputs.push(HostTensor::i32(&[batch, cfg.max_seq], tokens.to_vec()));
    let outs = prog.run(&inputs).unwrap();
    let logits = &outs[0]; // [B, smax, V]
    let v = cfg.vocab_size;
    let data = logits.as_f32().unwrap();
    let rows = (0..batch)
        .map(|b| {
            let p = lens[b] - 1;
            data[(b * cfg.max_seq + p) * v..(b * cfg.max_seq + p + 1) * v]
                .to_vec()
        })
        .collect();
    (rows, outs[1].clone(), outs[2].clone())
}

fn assert_rows_close(a: &[Vec<f32>], b: &[Vec<f32>], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len());
    for (lane, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len());
        let mut max_abs = 0f32;
        for (x, y) in ra.iter().zip(rb) {
            max_abs = max_abs.max((x - y).abs());
        }
        assert!(
            max_abs < tol,
            "{what}: lane {lane} max |diff| = {max_abs}"
        );
    }
}

fn parity_for(model: &str, workers: usize, a2a: AllToAllKind) {
    let Some(m) = manifest() else { return };
    let batch = 4usize;
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let (mono_rows, _, _) =
        monolithic_prefill(&m, model, &tokens, &lens, batch);

    let mut ep = EpEngine::new(&m, model, workers, a2a, batch).unwrap();
    let ep_rows = ep.forward_prefill(&tokens, &lens).unwrap();
    assert_rows_close(&mono_rows, &ep_rows, 2e-3, &format!("{model} prefill"));

    // Decode parity: continue two tokens greedily on both paths.
    // Monolithic decode via the decode program.
    let arts = m.model(model).unwrap();
    let rt = Runtime::cpu().unwrap();
    let dec = rt
        .load(arts.programs.get(&format!("decode_b{batch}")).unwrap())
        .unwrap();
    let ck = Checkpoint::load(&arts.checkpoint_dir).unwrap();
    let (_, mut kc, mut vc) =
        monolithic_prefill(&m, model, &tokens, &lens, batch);
    let mut mono_tok: Vec<i32> =
        mono_rows.iter().map(|r| argmax(r) as i32).collect();
    let mut ep_tok: Vec<i32> =
        ep_rows.iter().map(|r| argmax(r) as i32).collect();
    assert_eq!(mono_tok, ep_tok, "{model}: first sampled tokens differ");
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..2 {
        // monolithic step
        let mut ins: Vec<HostTensor> = ck.tensors.clone();
        ins.push(HostTensor::i32(&[batch], mono_tok.clone()));
        ins.push(kc.clone());
        ins.push(vc.clone());
        ins.push(HostTensor::i32(&[batch], pos.clone()));
        let outs = dec.run(&ins).unwrap();
        let v = cfg.vocab_size;
        let mono_step_rows: Vec<Vec<f32>> = (0..batch)
            .map(|b| outs[0].as_f32().unwrap()[b * v..(b + 1) * v].to_vec())
            .collect();
        kc = outs[1].clone();
        vc = outs[2].clone();
        // ep step
        let ep_step_rows = ep.forward_decode(&ep_tok, &pos).unwrap();
        assert_rows_close(
            &mono_step_rows,
            &ep_step_rows,
            2e-3,
            &format!("{model} decode step {step}"),
        );
        mono_tok =
            mono_step_rows.iter().map(|r| argmax(r) as i32).collect();
        ep_tok = ep_step_rows.iter().map(|r| argmax(r) as i32).collect();
        assert_eq!(mono_tok, ep_tok);
        for p in &mut pos {
            *p += 1;
        }
    }
}

/// The overlapped/coalesced MoE pipeline must be **bit-identical** (not
/// just tolerance-close) to the serialized `DSMOE_SERIAL_MOE` path: same
/// expert blocks, same padding, same combine order, same residual-add
/// order — only the schedule differs.
fn bitwise_serial_vs_overlap(model: &str, workers: usize) {
    let Some(m) = manifest() else { return };
    let batch = 4usize;
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let mut overlap =
        EpEngine::new(&m, model, workers, AllToAllKind::Hierarchical, batch)
            .unwrap();
    overlap.set_serial_moe(false);
    // Pin the per-layer overlapped path: the pipelined path has its own
    // three-way bitwise test below.
    overlap.set_pipeline(false);
    let mut serial =
        EpEngine::new(&m, model, workers, AllToAllKind::Hierarchical, batch)
            .unwrap();
    serial.set_serial_moe(true);

    let a = overlap.forward_prefill(&tokens, &lens).unwrap();
    let b = serial.forward_prefill(&tokens, &lens).unwrap();
    assert_eq!(
        a, b,
        "{model}: overlapped prefill logits not bit-identical to serial"
    );

    let mut tok: Vec<i32> = a.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..3 {
        let ra = overlap.forward_decode(&tok, &pos).unwrap();
        let rb = serial.forward_decode(&tok, &pos).unwrap();
        assert_eq!(
            ra, rb,
            "{model}: decode step {step} not bit-identical"
        );
        tok = ra.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
}

/// The microbatch-interleaved pipeline must be **bit-identical** to both
/// per-layer paths at any ring depth: the same tokens route to the same
/// experts with the same slot order inside each microbatch, every program
/// is per-lane / per-row independent, and the host-side combine runs in
/// the same order — only the schedule (and the program batch dimension)
/// differs.  Batch 8, so depth 2 (b=4 shapes) exists in every artifact
/// set; depths 3 (3/3/2 groups) and 4 need the depth-N shape ladders —
/// older artifact sets must fall back gracefully (2, then 1) and stay
/// bit-identical there.
fn bitwise_three_way(model: &str, workers: usize, depth: usize) {
    let Some(m) = manifest() else { return };
    let batch = 8usize;
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let mk = |serial: bool, pipeline: bool| {
        let mut e =
            EpEngine::new(&m, model, workers, AllToAllKind::Hierarchical, batch)
                .unwrap();
        e.set_serial_moe(serial);
        e.set_pipeline(pipeline);
        e
    };
    let mut serial = mk(true, false);
    let mut overlap = mk(false, false);
    let mut pipelined = mk(false, true);
    pipelined.set_pipe_depth(depth);
    assert_eq!(overlap.microbatches(), 1);
    let resolved = pipelined.microbatches();
    if pipelined.depth_supported(depth) {
        assert_eq!(
            resolved, depth,
            "{model}: depth-{depth} shapes exist but the ring resolved \
             to {resolved}"
        );
    } else {
        // Artifact set predates the depth-N shape ladders: the fallback
        // ladder must land on 2 (or 1) and stay bit-identical there.
        assert!(
            resolved == 2 || resolved == 1,
            "{model}: unsupported depth {depth} resolved to {resolved}, \
             not a fallback depth"
        );
        eprintln!(
            "  note: {model}: depth-{depth} shapes missing from this \
             artifact set; testing the fallback (depth {resolved})"
        );
    }

    let rs = serial.forward_prefill(&tokens, &lens).unwrap();
    let ro = overlap.forward_prefill(&tokens, &lens).unwrap();
    let rp = pipelined.forward_prefill(&tokens, &lens).unwrap();
    assert_eq!(ro, rs, "{model}: overlapped prefill != serial");
    assert_eq!(rp, rs, "{model}: pipelined prefill != serial");

    let mut tok: Vec<i32> = rs.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..3 {
        let ds = serial.forward_decode(&tok, &pos).unwrap();
        let dov = overlap.forward_decode(&tok, &pos).unwrap();
        let dp = pipelined.forward_decode(&tok, &pos).unwrap();
        assert_eq!(dov, ds, "{model}: overlapped decode step {step}");
        assert_eq!(dp, ds, "{model}: pipelined decode step {step}");
        tok = ds.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
    // The pipeline actually hid waits behind leader compute (when it
    // engaged), and the per-depth metric breakdown is attributable.
    if resolved > 1 {
        assert!(pipelined.metrics.samples("attn_overlap") > 0);
        assert!(pipelined.metrics.samples("pipeline_bubble") > 0);
        assert_eq!(pipelined.metrics.samples("expert_wait"), 0);
        let by_depth = format!("pipeline_bubble_d{resolved}");
        assert!(
            pipelined.metrics.samples(&by_depth) > 0,
            "{model}: no {by_depth} samples"
        );
    }
    // The tag-keyed reply stash drains fully between forwards.
    assert_eq!(pipelined.fabric_stash_depth(), 0);
}

/// Acceptance bar of the continuous-batching refactor: under greedy
/// sampling, the scheduler-driven EP path must emit **token-identical**
/// sequences to back-to-back `forward_prefill`/`forward_decode` over the
/// same prompts — per-lane outputs are independent of lane placement,
/// admission batching, and dead-lane masking.
fn ep_scheduler_token_parity(
    model: &str,
    serial: bool,
    pipeline: bool,
    depth: usize,
    leader_threads: usize,
) {
    let Some(m) = manifest() else { return };
    let batch = 8usize;
    let workers = 4usize;
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let max_new = 5usize;

    // Manual fixed-lane driver: greedy continuation for max_new tokens.
    let mut manual =
        EpEngine::new(&m, model, workers, AllToAllKind::Hierarchical, batch)
            .unwrap();
    manual.set_serial_moe(serial);
    manual.set_pipeline(pipeline);
    manual.set_pipe_depth(depth);
    // The reference always runs the single-threaded leader (pinned, so an
    // ambient DSMOE_LEADER_THREADS cannot collapse the comparison).
    manual.set_leader_threads(1);
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }
    let rows = manual.forward_prefill(&tokens, &lens).unwrap();
    let mut seqs: Vec<Vec<i32>> =
        rows.iter().map(|r| vec![argmax(r) as i32]).collect();
    let mut tok: Vec<i32> = seqs.iter().map(|s| s[0]).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for _ in 1..max_new {
        let rows = manual.forward_decode(&tok, &pos).unwrap();
        tok = rows.iter().map(|r| argmax(r) as i32).collect();
        for (s, &t) in seqs.iter_mut().zip(&tok) {
            s.push(t);
        }
        for p in &mut pos {
            *p += 1;
        }
    }
    // The scheduler retires a sequence at EOS (inclusive); truncate the
    // manual sequences the same way.
    for s in seqs.iter_mut() {
        if let Some(i) = s.iter().position(|&t| t == EOS) {
            s.truncate(i + 1);
        }
    }

    // Scheduler-driven run over the same prompts (greedy: temperature 0).
    // The fixed-lane reference above always runs the single-threaded
    // leader, so a `leader_threads > 1` scheduler run also pins
    // sharded-vs-single parity under admission + retirement + regroup.
    let mut ep =
        EpEngine::new(&m, model, workers, AllToAllKind::Hierarchical, batch)
            .unwrap();
    ep.set_serial_moe(serial);
    ep.set_pipeline(pipeline);
    // Scheduler::new applies ServingConfig::pipe_depth and
    // ::leader_threads through ForwardModel::configure — the config
    // fields are the controls on the scheduler path.
    let mut sched = Scheduler::new(
        ep,
        ServingConfig {
            model: model.into(),
            max_batch: batch,
            max_new_tokens: max_new,
            batch_timeout: std::time::Duration::from_millis(1),
            pipe_depth: depth,
            leader_threads,
            ..Default::default()
        },
    );
    // Two submission waves: the second wave arrives while the first is
    // mid-decode, so its admission runs through the interleaved
    // (prefill-behind-decode) path on backends that support it — tokens
    // must be identical either way.
    let mut ids = Vec::new();
    for b in 0..batch / 2 {
        ids.push(sched.submit(corpus.prompt(b, plen), Some(max_new)).unwrap());
    }
    // Step until the first wave's batch timeout flushes it into lanes.
    for _ in 0..50 {
        sched.step().unwrap();
        if sched.active_count() > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
    assert!(sched.active_count() > 0);
    for b in batch / 2..batch {
        ids.push(sched.submit(corpus.prompt(b, plen), Some(max_new)).unwrap());
    }
    let mut responses = sched.run_until_idle().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), batch);
    for (b, r) in responses.iter().enumerate() {
        assert_eq!(r.id, ids[b]);
        assert_eq!(
            r.tokens, seqs[b],
            "{model} serial={serial} pipeline={pipeline}: request {b} \
             scheduler tokens != fixed-lane tokens"
        );
    }
    assert_eq!(sched.model.fabric_stash_depth(), 0);
}

#[test]
fn scheduler_token_parity_serial() {
    ep_scheduler_token_parity("moe-s-8", true, false, 2, 1);
}

#[test]
fn scheduler_token_parity_overlap() {
    ep_scheduler_token_parity("moe-s-8", false, false, 2, 1);
}

#[test]
fn scheduler_token_parity_pipelined() {
    ep_scheduler_token_parity("moe-s-8", false, true, 2, 1);
}

#[test]
fn scheduler_token_parity_pipelined_depth3() {
    // Depth 3 runs uneven (3/3/2) lane groups plus interleaved admission
    // prefills behind the decode ring — tokens must still match the
    // fixed-lane driver exactly.
    ep_scheduler_token_parity("moe-s-8", false, true, 3, 1);
}

#[test]
fn scheduler_token_parity_pipelined_depth4() {
    ep_scheduler_token_parity("moe-s-8", false, true, 4, 1);
}

#[test]
fn scheduler_token_parity_prmoe_pipelined() {
    ep_scheduler_token_parity("prmoe-s", false, true, 2, 1);
}

#[test]
fn scheduler_token_parity_leader_shards() {
    // Multi-threaded leader under the full scheduler loop: interleaved
    // admissions behind sharded decode steps, retirement, dead-lane
    // masking, and skew-triggered regrouping (through the shard cache
    // protocol) — tokens must match the single-threaded fixed-lane
    // driver exactly.
    ep_scheduler_token_parity("moe-s-8", false, true, 2, 2);
}

#[test]
fn scheduler_token_parity_leader_shards_depth3() {
    ep_scheduler_token_parity("moe-s-8", false, true, 3, 3);
}

#[test]
fn pipelined_bitwise_identical_moe() {
    bitwise_three_way("moe-s-8", 4, 2);
}

#[test]
fn pipelined_bitwise_identical_moe_depth3() {
    // 8 lanes at depth 3: uneven 3/3/2 microbatch groups, three tagged
    // exchanges in flight.
    bitwise_three_way("moe-s-8", 4, 3);
}

#[test]
fn pipelined_bitwise_identical_moe_depth4() {
    bitwise_three_way("moe-s-8", 4, 4);
}

#[test]
fn pipelined_bitwise_identical_prmoe_residual() {
    // PR-MoE: the pipeline also crosses dense layers and the overlapped
    // residual branch.
    bitwise_three_way("prmoe-s", 4, 2);
}

#[test]
fn pipelined_bitwise_identical_prmoe_depth3() {
    bitwise_three_way("prmoe-s", 4, 3);
}

/// Parallel leader shards must be **bit-identical** to the
/// single-threaded leader at the same ring depth: both execute the same
/// `Backbone` compute over the same per-group program shapes, and the
/// orchestrator preserves the ring's dispatch/finish order over the
/// tagged exchanges.  Also toggles `leader_threads` mid-decode in both
/// directions, which forces the KV cache groups to migrate
/// shards → leader → shards (host-side) without perturbing a single bit.
fn bitwise_leader_shards(model: &str, workers: usize, depth: usize) {
    let Some(m) = manifest() else { return };
    let batch = 8usize;
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let mk = |threads: usize| {
        let mut e =
            EpEngine::new(&m, model, workers, AllToAllKind::Hierarchical, batch)
                .unwrap();
        e.set_serial_moe(false);
        e.set_pipeline(true);
        e.set_pipe_depth(depth);
        e.set_leader_threads(threads);
        e
    };
    let mut single = mk(1);
    let mut sharded = mk(depth);
    if single.microbatches() < 2 {
        eprintln!(
            "  note: {model}: no ring at depth {depth} on this artifact \
             set; leader-shard test skipped"
        );
        return;
    }
    assert_eq!(sharded.leader_shards(), sharded.microbatches());
    assert_eq!(single.leader_shards(), 1);

    let rs = single.forward_prefill(&tokens, &lens).unwrap();
    let rp = sharded.forward_prefill(&tokens, &lens).unwrap();
    assert_eq!(rp, rs, "{model}: sharded prefill != single-threaded");

    let mut tok: Vec<i32> = rs.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..3 {
        let ds = single.forward_decode(&tok, &pos).unwrap();
        let dp = sharded.forward_decode(&tok, &pos).unwrap();
        assert_eq!(dp, ds, "{model}: sharded decode step {step}");
        tok = ds.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
    // The shard timers are populated and the single-thread ring waits are
    // not (the exposed wait moved into shard_idle).
    assert!(sharded.metrics.samples("leader_par") > 0);
    assert!(sharded.metrics.samples("shard_idle") > 0);
    assert_eq!(sharded.metrics.samples("pipeline_bubble"), 0);
    assert_eq!(sharded.metrics.samples("expert_wait"), 0);
    assert!(single.metrics.samples("shard_idle") == 0);

    // Threads off mid-decode: the shard-owned caches migrate back to the
    // leader and the single-threaded ring continues bit-identically.
    sharded.set_leader_threads(1);
    for step in 0..2 {
        let ds = single.forward_decode(&tok, &pos).unwrap();
        let dp = sharded.forward_decode(&tok, &pos).unwrap();
        assert_eq!(dp, ds, "{model}: post-migration decode step {step}");
        tok = ds.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
    assert!(sharded.metrics.samples("pipeline_bubble") > 0);

    // And back on: leader-owned caches ship into a fresh shard pool.
    sharded.set_leader_threads(depth);
    let ds = single.forward_decode(&tok, &pos).unwrap();
    let dp = sharded.forward_decode(&tok, &pos).unwrap();
    assert_eq!(dp, ds, "{model}: re-sharded decode");

    // The tag-keyed reply stash drains fully between forwards.
    assert_eq!(sharded.fabric_stash_depth(), 0);
}

/// The live hierarchical all-to-all and the socket transport are pure
/// schedule/wire changes: flat dispatch over channels (the reference),
/// hierarchical dispatch over channels, and hierarchical dispatch over
/// the socket transport must produce **bit-identical** logits for
/// prefill and decode — the same expert blocks reach the same experts
/// and the combine is slot-ordered, so neither the relay fan-out/fan-in
/// nor frame serialization may perturb a single bit.  Run under the
/// depth-N pipeline ring so relayed replies also cross the tag-keyed
/// stash.  The hierarchical runs must actually take the relay path
/// (cross-node messages strictly fewer, intra-node traffic non-zero).
fn bitwise_a2a_and_transport(model: &str, workers: usize, depth: usize) {
    let Some(m) = manifest() else { return };
    let batch = 8usize;
    let node_size = 2usize;
    assert_eq!(workers % node_size, 0);
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let mk = |hier: bool, transport: TransportKind| {
        let mut e = EpEngine::new_with_transport(
            &m,
            model,
            workers,
            AllToAllKind::Hierarchical,
            batch,
            transport,
        )
        .unwrap();
        e.set_serial_moe(false);
        e.set_pipeline(true);
        e.set_pipe_depth(depth);
        // Programmatic toggles (not env) so parallel test binaries never
        // race on DSMOE_A2A / DSMOE_NODE_SIZE / DSMOE_TRANSPORT.
        e.set_node_size(node_size);
        e.set_a2a_hierarchical(hier);
        assert_eq!(e.a2a_hierarchical(), hier);
        e
    };
    let mut flat = mk(false, TransportKind::Channel);
    let mut hier = mk(true, TransportKind::Channel);
    let mut hier_sock = mk(true, TransportKind::Socket);

    let rf = flat.forward_prefill(&tokens, &lens).unwrap();
    let rh = hier.forward_prefill(&tokens, &lens).unwrap();
    let rs = hier_sock.forward_prefill(&tokens, &lens).unwrap();
    assert_eq!(rh, rf, "{model}: hierarchical prefill != flat");
    assert_eq!(rs, rf, "{model}: hierarchical/socket prefill != flat");

    let mut tok: Vec<i32> = rf.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..3 {
        let df = flat.forward_decode(&tok, &pos).unwrap();
        let dh = hier.forward_decode(&tok, &pos).unwrap();
        let ds = hier_sock.forward_decode(&tok, &pos).unwrap();
        assert_eq!(dh, df, "{model}: hierarchical decode step {step}");
        assert_eq!(ds, df, "{model}: hierarchical/socket decode step {step}");
        tok = df.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }

    // The schedules actually diverged on the wire: same workload, fewer
    // cross-node messages hierarchically (O(nodes) vs O(workers) per
    // direction per MoE layer) and non-zero intra-node relay traffic —
    // the flat path must show none.
    use std::sync::atomic::Ordering::Relaxed;
    let cross_flat = flat.traffic().cross_messages.load(Relaxed);
    for (name, e) in [("channel", &hier), ("socket", &hier_sock)] {
        let t = e.traffic();
        let cross = t.cross_messages.load(Relaxed);
        assert!(
            cross < cross_flat,
            "{model}/{name}: hierarchical sent {cross} cross-node msgs, \
             flat sent {cross_flat}"
        );
        assert!(t.intra_messages.load(Relaxed) > 0, "{model}/{name}");
        assert!(t.intra_bytes.load(Relaxed) > 0, "{model}/{name}");
    }
    assert_eq!(flat.traffic().intra_messages.load(Relaxed), 0);

    // The tag-keyed reply stash drains fully between forwards on all
    // three engines (relayed replies included).
    assert_eq!(flat.fabric_stash_depth(), 0);
    assert_eq!(hier.fabric_stash_depth(), 0);
    assert_eq!(hier_sock.fabric_stash_depth(), 0);
}

#[test]
fn a2a_transport_bitwise_identical_depth2() {
    bitwise_a2a_and_transport("moe-s-8", 4, 2);
}

#[test]
fn a2a_transport_bitwise_identical_depth3() {
    // Depth 3: uneven 3/3/2 microbatch groups, three tagged exchanges in
    // flight — relayed coalesced replies cross the stash under pressure.
    bitwise_a2a_and_transport("moe-s-8", 4, 3);
}

#[test]
fn a2a_transport_bitwise_identical_prmoe() {
    // PR-MoE: relays also serve the residual branch's expert exchanges.
    bitwise_a2a_and_transport("prmoe-s", 4, 2);
}

#[test]
fn leader_shards_bitwise_identical_depth2() {
    bitwise_leader_shards("moe-s-8", 4, 2);
}

#[test]
fn leader_shards_bitwise_identical_depth3() {
    // Uneven 3/3/2 groups: three shard threads, three program shapes.
    bitwise_leader_shards("moe-s-8", 4, 3);
}

#[test]
fn leader_shards_bitwise_identical_depth4() {
    bitwise_leader_shards("moe-s-8", 4, 4);
}

#[test]
fn leader_shards_bitwise_identical_prmoe() {
    // PR-MoE: shards also run dense layers and the residual branch.
    bitwise_leader_shards("prmoe-s", 4, 2);
}

#[test]
fn leader_shards_inert_on_single_group_paths() {
    // Serial and no-pipeline paths have one microbatch stream: a
    // leader_threads request must resolve to 1 and change nothing.
    let Some(m) = manifest() else { return };
    let batch = 4usize;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let smax = m.model("moe-s-8").unwrap().config.max_seq;
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }
    for (serial, pipeline) in [(true, false), (false, false)] {
        let mk = |threads: usize| {
            let mut e = EpEngine::new(
                &m,
                "moe-s-8",
                2,
                AllToAllKind::Hierarchical,
                batch,
            )
            .unwrap();
            e.set_serial_moe(serial);
            e.set_pipeline(pipeline);
            e.set_leader_threads(threads);
            e
        };
        let mut reference = mk(1);
        let mut threaded = mk(4);
        assert_eq!(threaded.leader_shards(), 1);
        let a = reference.forward_prefill(&tokens, &lens).unwrap();
        let b = threaded.forward_prefill(&tokens, &lens).unwrap();
        assert_eq!(a, b, "serial={serial} pipeline={pipeline}");
        let tok: Vec<i32> = a.iter().map(|r| argmax(r) as i32).collect();
        let pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
        let da = reference.forward_decode(&tok, &pos).unwrap();
        let db = threaded.forward_decode(&tok, &pos).unwrap();
        assert_eq!(da, db, "serial={serial} pipeline={pipeline} decode");
        assert_eq!(threaded.metrics.samples("leader_par"), 0);
    }
}

#[test]
fn pipe_depth_one_is_the_per_layer_path() {
    // Depth 1 must behave exactly like the overlapped per-layer path: one
    // microbatch, waits in expert_wait, no pipeline metrics.
    let Some(m) = manifest() else { return };
    let mut ep =
        EpEngine::new(&m, "moe-s-8", 4, AllToAllKind::Hierarchical, 8)
            .unwrap();
    ep.set_pipe_depth(1);
    assert_eq!(ep.microbatches(), 1);
    let smax = ep.cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let mut tokens = vec![0i32; 8 * smax];
    for b in 0..8 {
        let p = corpus.prompt(b, 8);
        tokens[b * smax..b * smax + 8].copy_from_slice(&p);
    }
    ep.forward_prefill(&tokens, &vec![8; 8]).unwrap();
    assert!(ep.metrics.samples("expert_wait") > 0);
    assert_eq!(ep.metrics.samples("pipeline_bubble"), 0);
}

#[test]
fn overlap_bitwise_identical_moe() {
    bitwise_serial_vs_overlap("moe-s-8", 4);
}

#[test]
fn overlap_bitwise_identical_prmoe_residual() {
    // PR-MoE also exercises the overlapped residual branch + pyramid
    // per-layer placements.
    bitwise_serial_vs_overlap("prmoe-s", 4);
}

#[test]
fn overlap_bitwise_identical_single_worker() {
    // Degenerate fabric: every expert on one worker, one batch per layer.
    bitwise_serial_vs_overlap("moe-s-8", 1);
}

#[test]
fn parity_moe_2_workers_naive() {
    parity_for("moe-s-8", 2, AllToAllKind::Naive);
}

#[test]
fn parity_moe_4_workers_hierarchical() {
    parity_for("moe-s-8", 4, AllToAllKind::Hierarchical);
}

#[test]
fn parity_moe_8_workers() {
    parity_for("moe-s-8", 8, AllToAllKind::Hierarchical);
}

#[test]
fn parity_prmoe_residual_branch() {
    // PR-MoE exercises pyramid schedules + the residual branch program.
    parity_for("prmoe-s", 4, AllToAllKind::Hierarchical);
}

#[test]
fn parity_mos_student() {
    parity_for("mos-s", 2, AllToAllKind::Naive);
}

/// Hot-expert replication must be **bit-identical** to the static
/// single-owner placement: replicas hold byte-identical weights (shipped
/// over the same fabric load path the construction uses) and the
/// contiguous ceil/floor split plus the slot-covering combine reassemble
/// every token's row from whichever replica computed it — so splitting a
/// hot expert's block across R workers may not perturb a single bit, on
/// the flat schedule, the hierarchical relay schedule, and the socket
/// transport alike.
fn bitwise_replicated_placement(model: &str, workers: usize, depth: usize) {
    let Some(m) = manifest() else { return };
    let batch = 8usize;
    let node_size = 2usize;
    assert_eq!(workers % node_size, 0);
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let mk = |replicate: bool, hier: bool, transport: TransportKind| {
        let mut e = EpEngine::new_with_transport(
            &m,
            model,
            workers,
            AllToAllKind::Hierarchical,
            batch,
            transport,
        )
        .unwrap();
        e.set_serial_moe(false);
        e.set_pipeline(true);
        e.set_pipe_depth(depth);
        e.set_node_size(node_size);
        e.set_a2a_hierarchical(hier);
        if replicate {
            e.set_replicate_hot(true).unwrap();
            // Park the online rebalancer: this test pins the forced
            // placement, the EWMA policy has its own unit tests.
            e.set_rebalance_skew(f64::INFINITY);
            e.force_replicas(0, 2).unwrap();
            assert!(
                e.placement()
                    .layers
                    .values()
                    .all(|lp| lp.replication(0) == 2.min(lp.experts_of.len())),
                "{model}: forced replication not applied"
            );
            assert!(e.metrics.counter("expert_migrations") > 0);
        }
        e
    };
    let mut base = mk(false, false, TransportKind::Channel);
    let mut flat = mk(true, false, TransportKind::Channel);
    let mut hier = mk(true, true, TransportKind::Channel);
    let mut hier_sock = mk(true, true, TransportKind::Socket);

    let rb = base.forward_prefill(&tokens, &lens).unwrap();
    let rf = flat.forward_prefill(&tokens, &lens).unwrap();
    let rh = hier.forward_prefill(&tokens, &lens).unwrap();
    let rs = hier_sock.forward_prefill(&tokens, &lens).unwrap();
    assert_eq!(rf, rb, "{model}: replicated flat prefill != static");
    assert_eq!(rh, rb, "{model}: replicated hierarchical prefill != static");
    assert_eq!(rs, rb, "{model}: replicated socket prefill != static");

    let mut tok: Vec<i32> = rb.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..3 {
        let db = base.forward_decode(&tok, &pos).unwrap();
        let df = flat.forward_decode(&tok, &pos).unwrap();
        let dh = hier.forward_decode(&tok, &pos).unwrap();
        let ds = hier_sock.forward_decode(&tok, &pos).unwrap();
        assert_eq!(df, db, "{model}: replicated flat decode step {step}");
        assert_eq!(dh, db, "{model}: replicated hier decode step {step}");
        assert_eq!(ds, db, "{model}: replicated socket decode step {step}");
        tok = db.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
    for e in [&base, &flat, &hier, &hier_sock] {
        assert_eq!(e.fabric_stash_depth(), 0);
    }
}

#[test]
fn replicated_placement_bitwise_identical() {
    bitwise_replicated_placement("moe-s-8", 4, 2);
}

#[test]
fn replicated_placement_bitwise_identical_prmoe() {
    // PR-MoE: replication composes with pyramid per-layer expert counts
    // and the residual branch.
    bitwise_replicated_placement("prmoe-s", 4, 2);
}

#[test]
fn migration_mid_run_bitwise_identical() {
    // An online migration between forwards — replicate expert 0 onto a
    // second worker (real weight ship over the fabric), bump the
    // placement epoch, keep decoding — must not perturb a single bit vs
    // an untouched engine, and flipping replication back off mid-run
    // (epoch bump back to single-owner packs, replicas left in place)
    // must not either.  No tagged exchange ever crosses an epoch: the
    // stash is empty at every boundary.
    let Some(m) = manifest() else { return };
    let model = "moe-s-8";
    let batch = 8usize;
    let workers = 4usize;
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let mk = || {
        let mut e =
            EpEngine::new(&m, model, workers, AllToAllKind::Hierarchical, batch)
                .unwrap();
        e.set_serial_moe(false);
        e.set_pipeline(true);
        e.set_pipe_depth(2);
        e
    };
    let mut steady = mk();
    let mut migrating = mk();

    let ra = steady.forward_prefill(&tokens, &lens).unwrap();
    let rb = migrating.forward_prefill(&tokens, &lens).unwrap();
    assert_eq!(rb, ra);
    let mut tok: Vec<i32> = ra.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    let mut decode_both = |steady: &mut EpEngine,
                           migrating: &mut EpEngine,
                           steps: usize,
                           what: &str| {
        for step in 0..steps {
            let da = steady.forward_decode(&tok, &pos).unwrap();
            let db = migrating.forward_decode(&tok, &pos).unwrap();
            assert_eq!(db, da, "{what}: decode step {step}");
            tok = da.iter().map(|r| argmax(r) as i32).collect();
            for p in &mut pos {
                *p += 1;
            }
        }
    };
    decode_both(&mut steady, &mut migrating, 2, "pre-migration");

    // The migration: between forwards, with the stash drained.
    assert_eq!(migrating.fabric_stash_depth(), 0);
    migrating.set_replicate_hot(true).unwrap();
    migrating.set_rebalance_skew(f64::INFINITY);
    migrating.force_replicas(0, 2).unwrap();
    assert!(migrating.metrics.counter("expert_migrations") > 0);
    decode_both(&mut steady, &mut migrating, 2, "post-migration");

    // Epoch back to single-owner packs (replicas stay resident but every
    // block returns to its replica-0 home).
    migrating.set_replicate_hot(false).unwrap();
    decode_both(&mut steady, &mut migrating, 2, "post-revert");

    assert_eq!(steady.fabric_stash_depth(), 0);
    assert_eq!(migrating.fabric_stash_depth(), 0);
}

#[test]
fn expert_load_stats_populated() {
    let Some(m) = manifest() else { return };
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let batch = 4;
    let mut ep =
        EpEngine::new(&m, "moe-s-8", 4, AllToAllKind::Hierarchical, batch)
            .unwrap();
    let smax = ep.cfg.max_seq;
    let mut tokens = vec![0i32; batch * smax];
    for b in 0..batch {
        let p = corpus.prompt(b, 8);
        tokens[b * smax..b * smax + 8].copy_from_slice(&p);
    }
    ep.forward_prefill(&tokens, &vec![8; batch]).unwrap();
    for s in &ep.load_stats {
        assert_eq!(s.total_tokens as usize, batch * smax,
                   "layer {} tokens", s.layer);
        assert!(s.utilization() > 0.0);
    }
    assert!(ep.traffic().total_bytes() > 0);
}

/// Tolerance-based row comparison for the compressed data path: every
/// element of `a` must land within `max_abs + max_rel * |b|` of the f32
/// reference `b`, and be finite.  Reports the worst offender on failure.
fn assert_close(
    a: &[Vec<f32>],
    b: &[Vec<f32>],
    max_abs: f32,
    max_rel: f32,
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: row count");
    for (lane, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what}: lane {lane} width");
        let (mut worst, mut at) = (0f32, 0usize);
        for (i, (x, y)) in ra.iter().zip(rb).enumerate() {
            assert!(
                x.is_finite(),
                "{what}: lane {lane} element {i} is {x}"
            );
            let excess = (x - y).abs() - (max_abs + max_rel * y.abs());
            if excess > worst {
                worst = excess;
                at = i;
            }
        }
        assert!(
            worst <= 0.0,
            "{what}: lane {lane} element {at}: {} vs reference {} \
             exceeds max_abs {max_abs} + max_rel {max_rel} by {worst}",
            ra[at],
            rb[at],
        );
    }
}

/// The compressed data path is deliberately NOT bitwise — its contract
/// is tolerance parity against the all-f32 reference on the same trace.
/// Both engines are fed the reference's greedy tokens every step so
/// precision drift never compounds through diverging inputs; the
/// `assert_ne!` pins that the toggle actually changed the numerics
/// (an inert toggle would pass any tolerance).
#[allow(clippy::too_many_arguments)]
fn compressed_parity(
    model: &str,
    workers: usize,
    expert_dtype: Dtype,
    wire_dtype: Dtype,
    hier: bool,
    transport: TransportKind,
    max_abs: f32,
    max_rel: f32,
) {
    let Some(m) = manifest() else { return };
    let batch = 8usize;
    let node_size = 2usize;
    assert_eq!(workers % node_size, 0);
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let mk = |transport: TransportKind| {
        let mut e = EpEngine::new_with_transport(
            &m,
            model,
            workers,
            AllToAllKind::Hierarchical,
            batch,
            transport,
        )
        .unwrap();
        e.set_serial_moe(false);
        e.set_pipeline(true);
        e.set_node_size(node_size);
        e.set_a2a_hierarchical(hier);
        e
    };
    let mut reference = mk(TransportKind::Channel);
    let mut compressed = mk(transport);
    compressed.set_expert_dtype(expert_dtype).unwrap();
    compressed.set_wire_dtype(wire_dtype).unwrap();
    assert_eq!(compressed.expert_dtype(), expert_dtype);
    assert_eq!(compressed.wire_dtype(), wire_dtype);

    let what = format!(
        "{model} experts={expert_dtype} wire={wire_dtype} prefill"
    );
    let rr = reference.forward_prefill(&tokens, &lens).unwrap();
    let rc = compressed.forward_prefill(&tokens, &lens).unwrap();
    assert_ne!(rc, rr, "{what}: compression toggle is inert");
    assert_close(&rc, &rr, max_abs, max_rel, &what);

    let mut tok: Vec<i32> = rr.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..3 {
        let dr = reference.forward_decode(&tok, &pos).unwrap();
        let dc = compressed.forward_decode(&tok, &pos).unwrap();
        assert_close(
            &dc,
            &dr,
            max_abs,
            max_rel,
            &format!(
                "{model} experts={expert_dtype} wire={wire_dtype} \
                 decode step {step}"
            ),
        );
        tok = dr.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
    assert_eq!(reference.fabric_stash_depth(), 0);
    assert_eq!(compressed.fabric_stash_depth(), 0);
}

#[test]
fn bf16_experts_close_to_f32_flat_channel() {
    compressed_parity(
        "moe-s-8",
        4,
        Dtype::BF16,
        Dtype::F32,
        false,
        TransportKind::Channel,
        0.3,
        0.08,
    );
}

#[test]
fn int8_experts_close_to_f32_hier_socket() {
    // The quantized ladder crosses the serialized frame codec and the
    // relay schedule: i8 payloads + their f32 scale rows survive both.
    compressed_parity(
        "moe-s-8",
        4,
        Dtype::I8,
        Dtype::F32,
        true,
        TransportKind::Socket,
        0.6,
        0.12,
    );
}

#[test]
fn f16_wire_close_to_f32_flat_channel() {
    compressed_parity(
        "moe-s-8",
        4,
        Dtype::F32,
        Dtype::F16,
        false,
        TransportKind::Channel,
        0.15,
        0.05,
    );
}

#[test]
fn f16_wire_close_to_f32_hier_socket() {
    // Relayed coalesced replies carry f16 tensors over the socket frame
    // codec — the narrow dtype must survive gather/scatter re-slicing.
    compressed_parity(
        "moe-s-8",
        4,
        Dtype::F32,
        Dtype::F16,
        true,
        TransportKind::Socket,
        0.15,
        0.05,
    );
}

#[test]
fn int8_experts_f16_wire_close_to_f32_hier() {
    // The full compression ladder at once — the serving configuration
    // the e2e bench measures.
    compressed_parity(
        "moe-s-8",
        4,
        Dtype::I8,
        Dtype::F16,
        true,
        TransportKind::Channel,
        0.7,
        0.15,
    );
}

#[test]
fn int8_experts_prmoe_close_to_f32() {
    // The residual-expert branch dequantizes through the same install
    // path.
    compressed_parity(
        "prmoe-s",
        4,
        Dtype::I8,
        Dtype::F32,
        false,
        TransportKind::Channel,
        0.6,
        0.12,
    );
}

/// PR 7 composition: a hot expert forced onto two replicas with int8
/// weights + f16 wire must be bitwise identical to the single-owner run
/// at the same compression point — every replica installs the same
/// dequantized ladder, so splitting the token block across them cannot
/// change a single bit.
#[test]
fn int8_replicated_expert_is_replica_consistent() {
    let Some(m) = manifest() else { return };
    let model = "moe-s-8";
    let (workers, batch) = (4usize, 8usize);
    let cfg = m.model(model).unwrap().config.clone();
    let smax = cfg.max_seq;
    let corpus = Corpus::generate(CorpusConfig {
        train_seqs: 8,
        valid_seqs: 16,
        ..Default::default()
    });
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
    }

    let mk = |replicate: bool| {
        let mut e = EpEngine::new_with_transport(
            &m,
            model,
            workers,
            AllToAllKind::Hierarchical,
            batch,
            TransportKind::Channel,
        )
        .unwrap();
        e.set_serial_moe(false);
        e.set_pipeline(true);
        e.set_node_size(2);
        e.set_a2a_hierarchical(true);
        e.set_expert_dtype(Dtype::I8).unwrap();
        e.set_wire_dtype(Dtype::F16).unwrap();
        if replicate {
            e.set_replicate_hot(true).unwrap();
            e.set_rebalance_skew(f64::INFINITY);
            // The replica ships ride the compressed ladder too.
            e.force_replicas(0, 2).unwrap();
        }
        e
    };
    let mut single = mk(false);
    let mut replicated = mk(true);

    let rs = single.forward_prefill(&tokens, &lens).unwrap();
    let rr = replicated.forward_prefill(&tokens, &lens).unwrap();
    assert_eq!(rr, rs, "{model}: int8 replicated prefill != single-owner");

    let mut tok: Vec<i32> = rs.iter().map(|r| argmax(r) as i32).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for step in 0..3 {
        let ds = single.forward_decode(&tok, &pos).unwrap();
        let dr = replicated.forward_decode(&tok, &pos).unwrap();
        assert_eq!(
            dr, ds,
            "{model}: int8 replicated decode step {step} != single-owner"
        );
        tok = ds.iter().map(|r| argmax(r) as i32).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
}
