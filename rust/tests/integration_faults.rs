//! Fault tolerance (`DSMOE_FAULT_TOLERANCE` semantics, set here through
//! the programmatic setters): killing, delaying, dropping or garbling a
//! worker mid-trace via a deterministic [`FaultPlan`] must never change a
//! single emitted token — the leader hits its exchange deadline, probes
//! the fleet, fails dead workers over (re-homing their experts onto live
//! group-0 survivors) and re-executes or re-queues the interrupted work.
//! Every test compares the full per-request token streams of a faulted
//! run against an unfaulted reference, bitwise.
//!
//! All tests no-op without `artifacts/` (like every integration test) and
//! use `leader_threads = 1` — composing worker failover with mid-protocol
//! leader-shard state is deliberately out of scope (see
//! `rust/src/server/shard.rs`).

use std::time::Duration;

use ds_moe::config::{AllToAllKind, ServingConfig};
use ds_moe::data::{Corpus, CorpusConfig};
use ds_moe::fabric::{FaultPlan, TransportKind, WorkerState};
use ds_moe::runtime::Manifest;
use ds_moe::server::{EpEngine, Scheduler};

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new("artifacts");
    root.join("manifest.json")
        .exists()
        .then(|| Manifest::load(root).unwrap())
}

fn corpus() -> Corpus {
    Corpus::generate(CorpusConfig::default())
}

const WORKERS: usize = 4;
const BATCH: usize = 8;
const REQUESTS: usize = 12;
const MAX_NEW: usize = 6;

/// Scheduler-driven EP engine with fault tolerance armed through the
/// setters (tests never touch env vars): tight exchange deadline so a
/// faulted collect fails over in test time, one probe miss = dead (the
/// probe window is generous enough that a live in-process worker can
/// never miss it).
fn ft_scheduler(
    m: &Manifest,
    transport: TransportKind,
    hier: bool,
    fault_tolerance: bool,
) -> Scheduler<EpEngine> {
    let mut ep = EpEngine::new_with_transport(
        m,
        "moe-s-8",
        WORKERS,
        AllToAllKind::Hierarchical,
        BATCH,
        transport,
    )
    .unwrap();
    ep.set_serial_moe(false);
    ep.set_pipeline(true);
    if hier {
        ep.set_node_size(2);
    }
    ep.set_a2a_hierarchical(hier);
    let serving = ServingConfig {
        model: "moe-s-8".into(),
        workers: WORKERS,
        max_batch: BATCH,
        max_new_tokens: MAX_NEW,
        batch_timeout: Duration::from_millis(1),
        pipe_depth: 2,
        leader_threads: 1,
        ..Default::default()
    };
    let mut sched = Scheduler::new(ep, serving);
    // After `configure` so nothing can clobber the FT knobs.
    sched.model.set_fault_tolerance(fault_tolerance);
    sched.model.set_exchange_timeout(Duration::from_millis(1000));
    sched.model.set_probe_params(Duration::from_secs(2), 1, 2);
    sched
}

/// Serve the deterministic 12-request trace, returning per-request token
/// streams sorted by id.  Greedy sampling + per-lane decode independence
/// make each request's stream a pure function of its prompt, so faulted
/// and unfaulted runs compare bitwise no matter how admissions batch up
/// or how often the fault path re-executes a step.
fn serve_trace(sched: &mut Scheduler<EpEngine>) -> Vec<(u64, Vec<i32>)> {
    let c = corpus();
    for i in 0..REQUESTS {
        sched.submit(c.prompt(i, 8), Some(MAX_NEW)).unwrap();
    }
    let responses = sched.run_until_idle().unwrap();
    assert_eq!(responses.len(), REQUESTS, "requests lost");
    let mut out: Vec<(u64, Vec<i32>)> = responses
        .into_iter()
        .map(|r| {
            assert!(!r.tokens.is_empty());
            (r.id, r.tokens)
        })
        .collect();
    out.sort();
    out
}

/// The tentpole invariant: kill one worker mid-trace and every request
/// still completes with tokens bitwise-identical to an unfailed run, the
/// victim is declared dead, and its experts are re-homed off it in every
/// MoE layer.
fn kill_is_token_identical(
    transport: TransportKind,
    hier: bool,
    victim: usize,
) {
    let Some(m) = manifest() else { return };
    let baseline = serve_trace(&mut ft_scheduler(&m, transport, hier, true));

    let mut sched = ft_scheduler(&m, transport, hier, true);
    // The victim must actually host experts before the failure, or the
    // eviction assertions below would pass vacuously.
    for lp in sched.model.placement().layers.values() {
        assert!(
            !lp.experts_of[victim].is_empty(),
            "victim {victim} hosts nothing at layer {} — bad test setup",
            lp.layer
        );
    }
    // Crash the victim at its 6th expert-batch dispatch: past the first
    // admission, with decode traffic on every lane.
    sched.model.set_fault_plan(FaultPlan {
        kill: Some((victim, 6)),
        ..Default::default()
    });
    let faulted = serve_trace(&mut sched);

    assert_eq!(
        faulted, baseline,
        "tokens diverged after killing worker {victim} \
         ({transport:?}, hier={hier})"
    );
    let m = &sched.metrics;
    assert!(m.counter("worker_deaths") >= 1, "death never detected");
    assert!(m.counter("failovers") >= 1, "failover never ran");
    assert!(m.value_count("ft_recovery") >= 1, "recovery never timed");
    assert_eq!(sched.model.worker_state(victim), WorkerState::Dead);
    for lp in sched.model.placement().layers.values() {
        assert!(
            lp.experts_of[victim].is_empty(),
            "layer {} still routes to the dead worker {victim}: {:?}",
            lp.layer,
            lp.experts_of[victim]
        );
    }
}

#[test]
fn killed_worker_fails_over_token_identical_channel_flat() {
    kill_is_token_identical(TransportKind::Channel, false, 1);
}

#[test]
fn killed_worker_fails_over_token_identical_channel_hier_relay_victim() {
    // Worker 0 relays node {0, 1} under node_size 2 — killing it forces
    // both a relay re-route and an expert failover.
    kill_is_token_identical(TransportKind::Channel, true, 0);
}

#[test]
fn killed_worker_fails_over_token_identical_socket_flat() {
    kill_is_token_identical(TransportKind::Socket, false, 1);
}

#[test]
fn killed_worker_fails_over_token_identical_socket_hier_relay_victim() {
    kill_is_token_identical(TransportKind::Socket, true, 0);
}

/// Default-off discipline: arming fault tolerance (deadline + probe
/// machinery, no faults injected) must not move a single token relative
/// to the stock infallible path.
#[test]
fn fault_tolerance_toggle_is_token_inert_without_faults() {
    let Some(m) = manifest() else { return };
    let mut off = ft_scheduler(&m, TransportKind::Channel, false, false);
    let mut on = ft_scheduler(&m, TransportKind::Channel, false, true);
    assert!(!off.model.fault_tolerance());
    assert!(on.model.fault_tolerance());
    assert_eq!(
        serve_trace(&mut off),
        serve_trace(&mut on),
        "arming fault tolerance changed tokens with no fault injected"
    );
    assert_eq!(on.metrics.counter("worker_deaths"), 0);
    assert_eq!(on.metrics.counter("exchange_timeouts"), 0);
}

/// With engine-local retries disabled the fault must escape to the
/// scheduler, whose `try_recover` + fold path re-queues every in-flight
/// request through the preemption seam — and the continuations are still
/// token-identical.
#[test]
fn escalated_fault_folds_requests_through_scheduler() {
    let Some(m) = manifest() else { return };
    let mut baseline = ft_scheduler(&m, TransportKind::Channel, false, true);
    baseline.model.set_ft_retries(0);
    let expect = serve_trace(&mut baseline);

    let mut sched = ft_scheduler(&m, TransportKind::Channel, false, true);
    sched.model.set_ft_retries(0);
    sched.model.set_fault_plan(FaultPlan {
        kill: Some((1, 6)),
        ..Default::default()
    });
    let got = serve_trace(&mut sched);
    assert_eq!(got, expect, "scheduler-fold recovery changed tokens");
    let mets = &sched.metrics;
    assert!(mets.counter("worker_deaths") >= 1);
    assert!(
        mets.counter("fault_requeues") >= 1,
        "no request was folded back into the queue"
    );
    assert!(mets.counter("degraded_steps") >= 1);
}

/// A lost reply frame: the exchange deadline elapses, but the probe finds
/// every worker alive — recovery must re-execute without killing anyone.
#[test]
fn dropped_reply_recovers_without_declaring_deaths() {
    let Some(m) = manifest() else { return };
    let baseline =
        serve_trace(&mut ft_scheduler(&m, TransportKind::Channel, false, true));
    let mut sched = ft_scheduler(&m, TransportKind::Channel, false, true);
    sched.model.set_fault_plan(FaultPlan {
        drop_reply: Some(5),
        ..Default::default()
    });
    assert_eq!(serve_trace(&mut sched), baseline);
    let mets = &sched.metrics;
    assert!(
        mets.counter("exchange_timeouts") >= 1,
        "the dropped reply never tripped the deadline"
    );
    assert_eq!(mets.counter("worker_deaths"), 0, "live worker declared dead");
    assert_eq!(mets.counter("failovers"), 0);
}

/// A garbled reply frame surfaces as a worker error (`Reply::Err`) — with
/// fault tolerance on it is recoverable, counted, and token-neutral.
#[test]
fn garbled_reply_recovers_without_declaring_deaths() {
    let Some(m) = manifest() else { return };
    let baseline =
        serve_trace(&mut ft_scheduler(&m, TransportKind::Channel, false, true));
    let mut sched = ft_scheduler(&m, TransportKind::Channel, false, true);
    sched.model.set_fault_plan(FaultPlan {
        garble_reply: Some(4),
        ..Default::default()
    });
    assert_eq!(serve_trace(&mut sched), baseline);
    let mets = &sched.metrics;
    assert!(mets.counter("worker_errors") >= 1, "garble never surfaced");
    assert_eq!(mets.counter("worker_deaths"), 0, "live worker declared dead");
}

/// Replies held back well inside the deadline (a GC-pausing worker): no
/// fault fires at all, and the tokens are untouched.
#[test]
fn delayed_replies_within_deadline_are_harmless() {
    let Some(m) = manifest() else { return };
    let baseline =
        serve_trace(&mut ft_scheduler(&m, TransportKind::Channel, false, true));
    let mut sched = ft_scheduler(&m, TransportKind::Channel, false, true);
    sched.model.set_fault_plan(FaultPlan {
        delay: Some((Duration::from_millis(20), 3)),
        ..Default::default()
    });
    assert_eq!(serve_trace(&mut sched), baseline);
    let mets = &sched.metrics;
    assert_eq!(mets.counter("exchange_timeouts"), 0);
    assert_eq!(mets.counter("worker_deaths"), 0);
    assert_eq!(mets.counter("ft_retries"), 0);
}

/// A worker that is dead at drop time must not deadlock the teardown
/// join (the transport hard-closes the wire after the shutdown frames) —
/// the fault-path companion of
/// `leader_shard_and_fabric_threads_join_on_drop`.
#[test]
fn dead_worker_does_not_deadlock_drop() {
    let Some(m) = manifest() else { return };
    for transport in [TransportKind::Channel, TransportKind::Socket] {
        let mut sched = ft_scheduler(&m, transport, false, true);
        sched.model.set_fault_plan(FaultPlan {
            kill: Some((1, 6)),
            ..Default::default()
        });
        let _ = serve_trace(&mut sched);
        assert!(
            sched.metrics.counter("worker_deaths") >= 1,
            "setup: the kill never landed ({transport:?})"
        );
        let h = std::thread::spawn(move || drop(sched));
        let t0 = std::time::Instant::now();
        while !h.is_finished() && t0.elapsed() < Duration::from_secs(120) {
            std::thread::sleep(Duration::from_millis(50));
        }
        assert!(
            h.is_finished(),
            "dropping the engine deadlocked with a dead worker \
             ({transport:?})"
        );
        h.join().unwrap();
    }
}
