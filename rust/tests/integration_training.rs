//! Training-stack integration: loss decreases, checkpoints round-trip
//! through the Rust<->Python ABI, and the staged-KD controller behaves.

use ds_moe::data::{Corpus, CorpusConfig, EvalSuite};
use ds_moe::runtime::Manifest;
use ds_moe::training::{Distiller, KdMode, LrSchedule, Trainer};

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new("artifacts");
    root.join("manifest.json")
        .exists()
        .then(|| Manifest::load(root).unwrap())
}

fn corpus() -> Corpus {
    Corpus::generate(CorpusConfig {
        train_seqs: 256,
        valid_seqs: 64,
        ..Default::default()
    })
}

fn sched(steps: usize) -> LrSchedule {
    LrSchedule { peak: 2e-3, min: 2e-4, warmup_steps: 5, decay_steps: steps }
}

#[test]
fn moe_training_reduces_loss() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    let mut tr = Trainer::new(&m, "moe-s-8", sched(30)).unwrap();
    let before = tr.eval(&c, 2).unwrap();
    tr.run(&c, 30, 10, true).unwrap();
    let after = tr.eval(&c, 2).unwrap();
    assert!(
        after < before - 0.5,
        "loss should drop substantially: {before:.3} -> {after:.3}"
    );
    assert_eq!(tr.step, 30);
    assert!(!tr.history.is_empty());
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    let dir = std::env::temp_dir().join(format!(
        "dsmoe-train-ckpt-{}",
        std::process::id()
    ));
    let val_a;
    {
        let mut tr = Trainer::new(&m, "dense-s", sched(10)).unwrap();
        tr.run(&c, 10, 5, true).unwrap();
        val_a = tr.eval(&c, 2).unwrap();
        tr.save(&dir).unwrap();
    }
    {
        let mut tr2 = Trainer::new(&m, "dense-s", sched(10)).unwrap();
        tr2.restore(&dir).unwrap();
        assert_eq!(tr2.step, 10);
        let val_b = tr2.eval(&c, 2).unwrap();
        assert!(
            (val_a - val_b).abs() < 1e-5,
            "restored eval differs: {val_a} vs {val_b}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zero_shot_improves_with_training() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    let suite = EvalSuite::from_corpus(&c, 8);
    let mut tr = Trainer::new(&m, "dense-s", sched(40)).unwrap();
    let (_, acc_before) = tr.zero_shot(&suite, 8).unwrap();
    tr.run(&c, 40, 20, true).unwrap();
    let (per_task, acc_after) = tr.zero_shot(&suite, 8).unwrap();
    assert!(acc_after > acc_before + 0.05,
            "cloze accuracy {acc_before:.3} -> {acc_after:.3}");
    assert_eq!(per_task.len(), c.config.n_domains);
}

#[test]
fn distillation_stages_alpha_and_trains() {
    let Some(m) = manifest() else { return };
    let c = corpus();
    // train a tiny teacher first
    let tdir = std::env::temp_dir().join(format!(
        "dsmoe-teacher-{}",
        std::process::id()
    ));
    {
        let mut teacher = Trainer::new(&m, "prmoe-s", sched(20)).unwrap();
        teacher.run(&c, 20, 10, true).unwrap();
        teacher.save(&tdir).unwrap();
    }
    let mut d = Distiller::new(&m, "mos-s", &tdir, sched(20),
                               KdMode::Staged { frac: 0.5 })
        .unwrap();
    // alpha on early, off late
    assert!(d.alpha_at(1, 20) > 0.0);
    assert_eq!(d.alpha_at(11, 20), 0.0);
    let before = d.student.eval(&c, 2).unwrap();
    d.run(&c, 20, 10, true).unwrap();
    let after = d.student.eval(&c, 2).unwrap();
    assert!(after < before, "distill: {before:.3} -> {after:.3}");
    std::fs::remove_dir_all(&tdir).ok();
}

#[test]
fn distiller_rejects_wrong_teacher() {
    let Some(m) = manifest() else { return };
    let dir = std::env::temp_dir().join(format!(
        "dsmoe-wrong-teacher-{}",
        std::process::id()
    ));
    {
        let tr = Trainer::new(&m, "dense-s", sched(1)).unwrap();
        tr.save(&dir).unwrap(); // a dense-s checkpoint, not prmoe-s
    }
    let err = Distiller::new(&m, "mos-s", &dir, sched(1), KdMode::Full)
        .err()
        .expect("should reject")
        .to_string();
    assert!(err.contains("teacher"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
