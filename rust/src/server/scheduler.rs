//! Engine-agnostic continuous-batching scheduler.
//!
//! DeepSpeed-MoE's serving win (§5) is an *end-to-end system*: request
//! admission, dynamic batch formation, prefill splicing into decode lanes,
//! iteration-level decode batching, and retirement.  That loop used to be
//! hard-welded inside the monolithic [`crate::server::Engine`]; this module
//! carves it out so the same scheduler drives any backend that can prefill
//! into lanes and take one decode step — today the monolithic engine and
//! the disaggregated expert-parallel [`crate::server::EpEngine`].
//!
//! The split:
//!
//! * [`ForwardModel`] — the backend contract: compiled prefill sizes and
//!   lane inventory, `prefill(compiled, reqs) -> admitted lanes` (run a
//!   prefill at a compiled batch shape and splice each request's KV into a
//!   free lane), `decode_step(tokens, pos) -> logits` (one step over the
//!   whole lane group; free lanes are padded), and `release(lane)`.  A
//!   backend that can hide admission compute behind its decode forward
//!   additionally implements the split admission API
//!   (`begin_prefill`/`finish_prefill`): the scheduler stages the
//!   admission, runs the decode step, and collects the admitted lanes
//!   afterwards — prefill-behind-decode interleaving instead of a
//!   stop-the-world prefill.
//! * [`Scheduler`] — owns the [`Router`] (admission + FIFO), the
//!   [`BatchPolicy`] (size-or-timeout batch formation), per-lane request
//!   bookkeeping, sampling ([`crate::util::sampling::Sampler`], seeded by
//!   `ServingConfig::seed`), and the TTFT / retirement metrics.  One
//!   [`Scheduler::step`] = at most one prefill admission plus one decode
//!   step, exactly the loop the old engine ran.
//!
//! Metric names are unchanged from the pre-refactor engine (`prefill`,
//! `decode_step`, `ttft`, `request_total`, `decode_steps`, …) and land in
//! the backend's own registry, so existing dashboards and benches keep
//! working; the scheduler adds `queue_depth` / `lanes_busy` gauges and a
//! `decode_utilization` summary (busy lanes per decode step).
//!
//! # SLO-aware serving (PR 9, all default-off)
//!
//! * **Chunked prefill** (`ServingConfig::prefill_chunk` /
//!   `DSMOE_PREFILL_CHUNK`): when the backend reports a staged admission
//!   still pending after a decode step ([`ForwardModel::prefill_pending`]),
//!   the scheduler parks the admission and keeps draining it one
//!   token-budget chunk per step — behind further decode steps, or
//!   directly ([`ForwardModel::advance_prefill`]) when every lane is idle
//!   — so a 2k-token prompt no longer stalls decode lanes for its whole
//!   prefill.
//! * **Priority tiers + preemption**: [`Scheduler::submit_tiered`] places
//!   a request at a priority tier (0 = batch); the router drains highest
//!   tier first and an above-tier-0 waiter flushes partial batches
//!   immediately (`BatchPolicy::decide_urgent`).  Under lane pressure the
//!   longest-running lowest-tier decode is preempted: its lane released,
//!   its generated prefix folded into the prompt, and the request
//!   re-queued at the head of its tier — on re-admission the re-prefill
//!   reconstructs the KV cache and the continuation is token-identical.
//! * **Backpressure** (`ServingConfig::queue_cap` / `DSMOE_QUEUE_CAP`,
//!   `DSMOE_SHED_POLICY`): bounded per-tier queues; valid submissions
//!   that cannot queue are *shed* (`Submission::Shed`), counted per tier
//!   (`shed_t{tier}`), so under the `Reject` policy
//!   `queued + shed == submitted` holds exactly.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{ModelConfig, ServingConfig};
use crate::coordinator::{
    BatchPolicy, Decision, Limits, Request, Response, Router, Submission,
};
use crate::metrics::Metrics;
use crate::tokenizer::EOS;
use crate::util::sampling::Sampler;

/// One request admitted into a decode lane by [`ForwardModel::prefill`]:
/// the lane it occupies and the logits row at its prompt's last position
/// (the scheduler samples the first generated token from it).
#[derive(Debug)]
pub struct AdmittedLane {
    pub lane: usize,
    pub logits: Vec<f32>,
}

/// What a serving backend must provide for the scheduler to drive it.
///
/// The backend owns programs, weights, and KV storage; the scheduler owns
/// requests, sampling, and lane occupancy bookkeeping.  Lane indices are
/// stable identifiers in `0..lane_count()`: `prefill` assigns them,
/// `decode_step` is indexed by them, `release` frees them.
pub trait ForwardModel {
    /// Architecture of the model being served (admission limits).
    fn model_config(&self) -> &ModelConfig;

    /// Apply backend-relevant serving settings (called once by
    /// [`Scheduler::new`] before any other use).  Default: nothing to
    /// apply.  The EP engine takes its pipeline ring depth
    /// (`ServingConfig::pipe_depth`) from here.
    fn configure(&mut self, _serving: &ServingConfig) {}

    /// The backend's metrics registry; the scheduler records into the same
    /// one so a single report covers both layers.
    fn metrics(&self) -> Arc<Metrics>;

    /// Swap in a fresh metrics registry (benches reset between warmup and
    /// the measured run).
    fn set_metrics(&mut self, metrics: Arc<Metrics>);

    /// Compiled prefill batch sizes, ascending (drives the
    /// [`BatchPolicy`]).
    fn prefill_sizes(&self) -> Vec<usize>;

    /// Total decode lanes.
    fn lane_count(&self) -> usize;

    /// Lanes currently free for admission.
    fn free_lane_count(&self) -> usize;

    /// Run one prefill at compiled batch size `compiled`
    /// (`reqs.len() <= compiled`; the remainder is padding), splice each
    /// request's KV cache into a free lane, and return the admitted lanes
    /// in request order.
    fn prefill(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<Vec<AdmittedLane>>;

    /// Stage an admission prefill to run *behind* the next decode step
    /// (prefill-behind-decode interleaving): a backend that can hide
    /// admission compute inside its decode forward stores the staged
    /// state and returns `Ok(true)`; the scheduler then runs one decode
    /// step and collects the admission with
    /// [`ForwardModel::finish_prefill`].  The default declines
    /// (`Ok(false)`), in which case the scheduler falls back to the
    /// stop-the-world [`ForwardModel::prefill`].
    fn begin_prefill(
        &mut self,
        _compiled: usize,
        _reqs: &[Request],
    ) -> Result<bool> {
        Ok(false)
    }

    /// Complete the admission staged by [`ForwardModel::begin_prefill`]
    /// (called exactly once after it returned `Ok(true)`, once
    /// [`ForwardModel::prefill_pending`] reports no remaining work — for
    /// an unchunked backend that is after the single decode step in
    /// between).
    fn finish_prefill(&mut self) -> Result<Vec<AdmittedLane>> {
        anyhow::bail!("backend has no staged admission")
    }

    /// True while a staged admission still has layer programs to run
    /// (chunked prefill, `DSMOE_PREFILL_CHUNK`): the scheduler keeps
    /// stepping the admission — behind further decode steps, or via
    /// [`ForwardModel::advance_prefill`] when no lane is decoding — and
    /// only calls [`ForwardModel::finish_prefill`] once this returns
    /// false.  Backends without chunked admissions complete the staged
    /// prefill behind the single interleaved decode step and never report
    /// pending work.
    fn prefill_pending(&self) -> bool {
        false
    }

    /// Advance a pending chunked admission by one chunk *without* a
    /// decode step (used when every decode lane is idle, so there is no
    /// forward pass to hide the chunk behind).  Default: nothing is ever
    /// pending, no-op.
    fn advance_prefill(&mut self) -> Result<()> {
        Ok(())
    }

    /// One decode step over the whole lane group.  `tokens[lane]` /
    /// `pos[lane]` carry the last sampled token and its cache position for
    /// busy lanes (zeros for free lanes, which must produce no side
    /// effects beyond their own lane).  Returns one logits row per lane;
    /// rows of free lanes are unspecified.
    fn decode_step(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>>;

    /// Free a retired request's lane.
    fn release(&mut self, lane: usize);

    /// A step failed with `err`: attempt backend-level recovery.  A
    /// fault-tolerant backend (the EP engine under
    /// `DSMOE_FAULT_TOLERANCE`) probes its workers, fails over dead
    /// ones, and returns `Ok(true)` — the scheduler then folds every
    /// in-flight request back into the queue through the preemption seam
    /// (continuations stay token-identical) and keeps stepping.
    /// `Ok(false)` (the default) means the error is not recoverable here
    /// and must propagate.
    fn try_recover(&mut self, _err: &anyhow::Error) -> Result<bool> {
        Ok(false)
    }
}

/// Consecutive recovered-but-failed steps after which the scheduler stops
/// retrying and propagates the fault (a wedged fabric must not spin
/// forever); any successful step resets the count.
const MAX_CONSECUTIVE_FAULTS: u32 = 8;

struct ActiveSeq {
    request: Request,
    /// Original prompt length.  Equals `request.prompt.len()` — kept
    /// separately because a preempted request is briefly re-queued with
    /// its generated prefix folded into the prompt, and position / length
    /// bookkeeping must always use the original.
    prompt_len: usize,
    generated: Vec<i32>,
    last_token: i32,
    first_token_at: std::time::Instant,
}

/// Decode progress stashed when a lane is preempted, restored when the
/// re-queued request is re-admitted (keyed by request id).
struct ResumeState {
    prompt_len: usize,
    generated: Vec<i32>,
    first_token_at: std::time::Instant,
}

/// Continuous-batching scheduler over any [`ForwardModel`] backend.
pub struct Scheduler<M: ForwardModel> {
    pub model: M,
    pub router: Router,
    policy: BatchPolicy,
    serving: ServingConfig,
    active: HashMap<usize, ActiveSeq>, // by lane
    /// Requests whose chunked admission is mid-flight in the backend
    /// (staged, not yet collectable) — see `step_chunked`.
    chunked: Option<Vec<Request>>,
    /// Requests popped for the admission running *within the current
    /// step* (staged or stop-the-world).  Held in a field rather than a
    /// local so a fault mid-step can fold them back into the queue
    /// instead of losing them.
    admitting: Option<Vec<Request>>,
    /// Consecutive steps that ended in a recovered fault (see
    /// [`MAX_CONSECUTIVE_FAULTS`]).
    consecutive_faults: u32,
    /// Preempted-lane progress awaiting re-admission, by request id.
    resumes: HashMap<u64, ResumeState>,
    pub done: Vec<Response>,
    pub metrics: Arc<Metrics>,
    sampler: Sampler,
    max_seq: usize,
}

impl<M: ForwardModel> Scheduler<M> {
    pub fn new(mut model: M, serving: ServingConfig) -> Scheduler<M> {
        model.configure(&serving);
        let cfg = model.model_config();
        let mut router = Router::new(Limits {
            max_seq: cfg.max_seq,
            vocab_size: cfg.vocab_size,
            default_max_new: serving.max_new_tokens,
        });
        router.set_backpressure(serving.queue_cap, serving.shed_policy);
        let max_seq = cfg.max_seq;
        let policy =
            BatchPolicy::new(model.prefill_sizes(), serving.batch_timeout);
        let metrics = model.metrics();
        let sampler = Sampler::new(serving.temperature, serving.seed);
        Scheduler {
            model,
            router,
            policy,
            serving,
            active: HashMap::new(),
            chunked: None,
            admitting: None,
            consecutive_faults: 0,
            resumes: HashMap::new(),
            done: Vec::new(),
            metrics,
            sampler,
            max_seq,
        }
    }

    /// Validate + enqueue a request at tier 0; returns its id.
    /// Backpressure shed surfaces as an error here — callers that need to
    /// distinguish shed from invalid use [`Scheduler::submit_tiered`].
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new: Option<usize>,
    ) -> Result<u64> {
        match self.submit_tiered(prompt, max_new, 0, None)? {
            Submission::Queued(id) => Ok(id),
            Submission::Shed => anyhow::bail!("request shed: queue full"),
        }
    }

    /// Validate + enqueue a request at a priority tier (0 = batch, higher
    /// = more urgent) with an optional TTFT deadline.  `Err` = invalid
    /// request; `Ok(Submission::Shed)` = valid but turned away by
    /// backpressure (`ServingConfig::queue_cap`).
    pub fn submit_tiered(
        &mut self,
        prompt: Vec<i32>,
        max_new: Option<usize>,
        tier: u8,
        deadline: Option<std::time::Duration>,
    ) -> Result<Submission> {
        self.metrics.inc("requests_submitted", 1);
        let shed_before = self.router.shed;
        let sub = self.router.submit_tiered(prompt, max_new, tier, deadline)?;
        // Count sheds off the router's counter, not the Submission:
        // under `DropOldest` a Queued outcome still displaced (shed) the
        // tier's oldest waiter.
        let shed = self.router.shed - shed_before;
        if shed > 0 {
            self.metrics.inc("requests_shed", shed);
            self.metrics.inc(&format!("shed_t{tier}"), shed);
        }
        if matches!(sub, Submission::Queued(_)) {
            self.metrics.inc(&format!("queued_t{tier}"), 1);
        }
        Ok(sub)
    }

    /// One scheduler iteration: admit a prefill batch if the policy says
    /// so, then run one decode step if any lane is live.  Returns true if
    /// any work was done.
    ///
    /// When lanes are decoding and the backend supports it, the admission
    /// is *staged* ([`ForwardModel::begin_prefill`]) so its layer programs
    /// run behind the decode step's in-flight expert exchanges, and
    /// collected afterwards ([`ForwardModel::finish_prefill`]) — instead
    /// of stopping every decode lane for the whole prefill.  The `prefill`
    /// latency metric then covers only the exposed (non-hidden) tail.
    pub fn step(&mut self) -> Result<bool> {
        match self.step_attempt() {
            Ok(worked) => {
                self.consecutive_faults = 0;
                Ok(worked)
            }
            Err(e) => self.recover_step(e),
        }
    }

    fn step_attempt(&mut self) -> Result<bool> {
        if self.chunked.is_some() {
            return self.step_chunked();
        }
        self.maybe_preempt();
        let free = self.model.free_lane_count();
        // An above-tier-0 waiter flushes partial batches immediately —
        // interactive requests never idle behind the batching clock.
        let urgent = self.router.highest_waiting_tier().unwrap_or(0) > 0;
        let decision = self.policy.decide_urgent(
            self.router.queue_len(),
            free,
            self.router.oldest_wait(),
            urgent,
        );
        let mut worked = false;
        if let Decision::Prefill { compiled, take } = decision {
            let reqs = self.router.pop_up_to(take);
            for req in &reqs {
                // Queue wait per tier (fresh submissions only: a resumed
                // request's arrival is its original submission time).
                if !self.resumes.contains_key(&req.id) {
                    self.metrics.observe(
                        &format!("queue_wait_t{}", req.tier),
                        req.arrival.elapsed(),
                    );
                }
            }
            // Popped requests live in `self.admitting` until registered,
            // so a fault anywhere in the step can fold them back into
            // the queue (`recover_step`) instead of losing them.
            let interleave = !self.active.is_empty();
            self.admitting = Some(reqs);
            let staged = interleave && {
                let reqs = self.admitting.take().unwrap();
                let r = self.model.begin_prefill(compiled, &reqs);
                self.admitting = Some(reqs);
                r?
            };
            if !staged {
                let reqs = self.admitting.take().unwrap();
                let t = std::time::Instant::now();
                match self.model.prefill(compiled, &reqs) {
                    Ok(admitted) => {
                        self.metrics.observe("prefill", t.elapsed());
                        self.register_admitted(reqs, admitted)?;
                    }
                    Err(e) => {
                        self.admitting = Some(reqs);
                        return Err(e);
                    }
                }
            }
            worked = true;
        }
        if !self.active.is_empty() {
            let t = std::time::Instant::now();
            self.decode_once()?;
            self.metrics.observe("decode_step", t.elapsed());
            worked = true;
        }
        if self.admitting.is_some() {
            if self.model.prefill_pending() {
                // Chunked prefill: the staged admission ran only a
                // token-budget slice behind this decode step.  Park it;
                // subsequent steps keep draining it (`step_chunked`).
                self.metrics.inc("chunked_admissions", 1);
                self.chunked = self.admitting.take();
            } else {
                let t = std::time::Instant::now();
                let admitted = self.model.finish_prefill()?;
                self.metrics.observe("prefill", t.elapsed());
                self.metrics.inc("interleaved_admissions", 1);
                let reqs = self.admitting.take().unwrap();
                self.register_admitted(reqs, admitted)?;
            }
        }
        self.metrics.gauge("queue_depth", self.router.queue_len() as f64);
        self.metrics.gauge("lanes_busy", self.active.len() as f64);
        Ok(worked)
    }

    /// A step failed.  If the backend recovers
    /// ([`ForwardModel::try_recover`]: probe → failover → placement
    /// bump), fold every in-flight request back into the queue through
    /// the preemption seam — interrupted admissions re-queue untouched,
    /// interrupted decodes fold their generated prefix into the prompt
    /// with a [`ResumeState`] so the re-prefilled continuation is
    /// token-identical — and report the step as worked so drive loops
    /// keep going.  Unrecoverable errors (and faults that persist past
    /// [`MAX_CONSECUTIVE_FAULTS`] steps without one clean step in
    /// between) propagate unchanged.
    fn recover_step(&mut self, e: anyhow::Error) -> Result<bool> {
        self.consecutive_faults += 1;
        if self.consecutive_faults > MAX_CONSECUTIVE_FAULTS
            || !self.model.try_recover(&e)?
        {
            return Err(e);
        }
        let mut folded = 0u64;
        // Interrupted admissions first: these requests were popped from
        // the queue front, so re-queueing them before the older active
        // lanes keeps overall age order once both are at the front.
        for reqs in [self.admitting.take(), self.chunked.take()]
            .into_iter()
            .flatten()
        {
            for req in reqs.into_iter().rev() {
                self.router.requeue_front(req);
                folded += 1;
            }
        }
        // Interrupted decodes: exactly the preemption fold.  Push in
        // reverse id (admission) order so the oldest request ends up
        // frontmost within its tier.
        let mut lanes: Vec<usize> = self.active.keys().copied().collect();
        lanes.sort_unstable_by_key(|l| {
            std::cmp::Reverse(self.active[l].request.id)
        });
        for lane in lanes {
            let seq = self.active.remove(&lane).unwrap();
            self.model.release(lane);
            let mut req = seq.request;
            req.prompt.truncate(seq.prompt_len);
            req.prompt.extend_from_slice(&seq.generated);
            self.resumes.insert(
                req.id,
                ResumeState {
                    prompt_len: seq.prompt_len,
                    generated: seq.generated,
                    first_token_at: seq.first_token_at,
                },
            );
            self.router.requeue_front(req);
            folded += 1;
        }
        self.metrics.inc("fault_requeues", folded);
        self.metrics.inc("degraded_steps", 1);
        Ok(true)
    }

    /// One scheduler iteration while a chunked admission is mid-flight:
    /// run a decode step (the backend advances the admission by one chunk
    /// behind it) — or advance the admission directly when every lane is
    /// idle — then collect the admitted lanes once the backend reports no
    /// remaining prefill work.  New admissions hold off until the
    /// in-flight one lands (its staged lane assignments must stay valid).
    fn step_chunked(&mut self) -> Result<bool> {
        if self.active.is_empty() {
            self.model.advance_prefill()?;
        } else {
            let t = std::time::Instant::now();
            self.decode_once()?;
            self.metrics.observe("decode_step", t.elapsed());
        }
        if !self.model.prefill_pending() {
            let reqs = self.chunked.take().expect("chunked admission state");
            let t = std::time::Instant::now();
            let admitted = self.model.finish_prefill()?;
            self.metrics.observe("prefill", t.elapsed());
            self.metrics.inc("interleaved_admissions", 1);
            self.register_admitted(reqs, admitted)?;
        }
        self.metrics.gauge("queue_depth", self.router.queue_len() as f64);
        self.metrics.gauge("lanes_busy", self.active.len() as f64);
        Ok(true)
    }

    /// Under lane pressure with an above-tier waiter, evict one decode
    /// lane: lowest tier first, longest-running within the tier (most
    /// generated tokens — it has the most slack to its deadline and the
    /// most opportunity to be re-admitted later).  The evicted request is
    /// re-queued at the *head* of its tier with its generated prefix
    /// folded into the prompt: re-prefilling that puts the KV cache back
    /// exactly where the lane left off, so the continuation is
    /// token-identical and no work is lost.  Inert by construction when
    /// every request is tier 0.
    fn maybe_preempt(&mut self) {
        if self.active.is_empty() || self.model.free_lane_count() > 0 {
            return;
        }
        let top = self.router.highest_waiting_tier().unwrap_or(0);
        if top == 0 {
            return;
        }
        let victim = self
            .active
            .iter()
            .filter(|(_, seq)| seq.request.tier < top)
            .min_by_key(|(lane, seq)| {
                (
                    seq.request.tier,
                    std::cmp::Reverse(seq.generated.len()),
                    **lane,
                )
            })
            .map(|(&lane, _)| lane);
        let Some(lane) = victim else { return };
        let seq = self.active.remove(&lane).unwrap();
        self.model.release(lane);
        self.metrics.inc("preemptions", 1);
        self.metrics
            .inc(&format!("preempted_t{}", seq.request.tier), 1);
        let mut req = seq.request;
        req.prompt.truncate(seq.prompt_len);
        req.prompt.extend_from_slice(&seq.generated);
        self.resumes.insert(
            req.id,
            ResumeState {
                prompt_len: seq.prompt_len,
                generated: seq.generated,
                first_token_at: seq.first_token_at,
            },
        );
        self.router.requeue_front(req);
    }

    /// Sample each admitted request's first token and activate its lane.
    fn register_admitted(
        &mut self,
        reqs: Vec<Request>,
        admitted: Vec<AdmittedLane>,
    ) -> Result<()> {
        anyhow::ensure!(
            admitted.len() == reqs.len(),
            "backend admitted {} of {} requests",
            admitted.len(),
            reqs.len()
        );
        for (req, adm) in reqs.into_iter().zip(admitted) {
            let first = self.sampler.sample(&adm.logits);
            let now = std::time::Instant::now();
            self.metrics.inc("prefills", 1);
            if let Some(rs) = self.resumes.remove(&req.id) {
                // Re-admission after preemption: the generated prefix was
                // folded into the re-queued prompt, so the admission
                // logits sit exactly where the evicted lane would have
                // decoded next — `first` is the continuation token.
                let mut req = req;
                req.prompt.truncate(rs.prompt_len);
                let mut generated = rs.generated;
                generated.push(first);
                self.metrics.inc("resumed", 1);
                self.active.insert(
                    adm.lane,
                    ActiveSeq {
                        prompt_len: rs.prompt_len,
                        request: req,
                        generated,
                        last_token: first,
                        first_token_at: rs.first_token_at,
                    },
                );
                // The resumed sample may already complete the request
                // (EOS / max_new / max_seq) — retire now, exactly as the
                // evicted lane's next decode step would have.
                self.maybe_retire(adm.lane);
            } else {
                let ttft = now - req.arrival;
                self.metrics.observe("ttft", ttft);
                self.metrics.observe(&format!("ttft_t{}", req.tier), ttft);
                if matches!(req.deadline, Some(d) if ttft > d) {
                    self.metrics.inc("deadline_misses", 1);
                    self.metrics
                        .inc(&format!("deadline_miss_t{}", req.tier), 1);
                }
                self.active.insert(
                    adm.lane,
                    ActiveSeq {
                        prompt_len: req.prompt.len(),
                        request: req,
                        generated: vec![first],
                        last_token: first,
                        first_token_at: now,
                    },
                );
            }
        }
        Ok(())
    }

    /// Retire the lane if its sequence just hit a completion condition
    /// (EOS, max_new, max_seq): free the lane and emit the [`Response`].
    fn maybe_retire(&mut self, lane: usize) {
        let Some(seq) = self.active.get(&lane) else { return };
        let finished = seq.last_token == EOS
            || seq.generated.len() >= seq.request.max_new_tokens
            || seq.prompt_len + seq.generated.len() >= self.max_seq;
        if !finished {
            return;
        }
        let seq = self.active.remove(&lane).unwrap();
        self.model.release(lane);
        let total = seq.request.arrival.elapsed();
        self.metrics.observe("request_total", total);
        self.metrics.inc("requests_completed", 1);
        self.metrics.inc("tokens_generated", seq.generated.len() as u64);
        self.done.push(Response {
            id: seq.request.id,
            prompt_len: seq.prompt_len,
            tokens: seq.generated,
            ttft: seq.first_token_at - seq.request.arrival,
            total,
            tier: seq.request.tier,
        });
    }

    fn decode_once(&mut self) -> Result<()> {
        let b = self.model.lane_count();
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for (&lane, seq) in &self.active {
            tokens[lane] = seq.last_token;
            // Cache position of the token being decoded: prompt plus all
            // generated tokens except the one the step will produce.
            pos[lane] = (seq.prompt_len + seq.generated.len() - 1) as i32;
        }
        let busy = self.active.len();
        self.metrics
            .record_value("decode_utilization", busy as f64 / b.max(1) as f64);
        let rows = self.model.decode_step(&tokens, &pos)?;
        anyhow::ensure!(rows.len() == b, "decode returned {} rows", rows.len());
        self.metrics.inc("decode_steps", 1);
        self.metrics.inc("decode_tokens", busy as u64);

        // Sample in lane order, not HashMap iteration order: with
        // temperature sampling every lane draws from one shared RNG, so a
        // nondeterministic draw-to-lane assignment would break
        // seed-reproducibility across runs.
        let mut lanes: Vec<usize> = self.active.keys().copied().collect();
        lanes.sort_unstable();
        for lane in lanes {
            let next = self.sampler.sample(&rows[lane]);
            let seq = self.active.get_mut(&lane).unwrap();
            seq.generated.push(next);
            seq.last_token = next;
            self.maybe_retire(lane);
        }
        Ok(())
    }

    /// True while a chunked admission is mid-flight in the backend (its
    /// requests are neither queued nor active yet).
    pub fn admission_in_flight(&self) -> bool {
        self.chunked.is_some()
    }

    /// Drain the queue and all in-flight sequences.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        while self.router.queue_len() > 0
            || !self.active.is_empty()
            || self.admission_in_flight()
        {
            // When only partial batches wait, sleep just until the oldest
            // request's flush deadline (capped at one timeout) instead of
            // a fixed full timeout; the floor avoids a busy spin when the
            // deadline is due on the next decide().
            if !self.step()? {
                let remaining = self
                    .policy
                    .time_to_flush(self.router.oldest_wait())
                    .unwrap_or(self.serving.batch_timeout);
                let floor = std::time::Duration::from_micros(50);
                std::thread::sleep(remaining.max(floor));
            }
        }
        Ok(std::mem::take(&mut self.done))
    }

    pub fn take_done(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.done)
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn queue_len(&self) -> usize {
        self.router.queue_len()
    }

    /// Swap in a fresh metrics registry (shared with the backend), so
    /// benches can measure steady state without warmup samples.
    pub fn reset_metrics(&mut self) {
        let m = Arc::new(Metrics::new());
        self.model.set_metrics(m.clone());
        self.metrics = m;
    }

    /// Drive an open-loop Poisson workload: submit `n` requests at `rate`
    /// req/s (request `i`'s prompt built by `prompt(i)`), stepping until
    /// every request has retired.  Returns the responses and the
    /// wall-clock seconds — the arrival loop shared by `ds-moe ep-serve`,
    /// `examples/serve_moe.rs`, and the e2e bench.
    pub fn run_poisson<F>(
        &mut self,
        n: usize,
        rate: f64,
        max_new: usize,
        seed: u64,
        mut prompt: F,
    ) -> Result<(Vec<Response>, f64)>
    where
        F: FnMut(usize) -> Vec<i32>,
    {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut arrivals = Vec::with_capacity(n);
        let mut t_acc = 0.0;
        for _ in 0..n {
            t_acc += rng.exponential(rate);
            arrivals.push(t_acc);
        }
        let t0 = std::time::Instant::now();
        let mut submitted = 0usize;
        while submitted < n
            || self.active_count() > 0
            || self.queue_len() > 0
            || self.admission_in_flight()
        {
            let now = t0.elapsed().as_secs_f64();
            while submitted < n && arrivals[submitted] <= now {
                self.submit(prompt(submitted), Some(max_new))?;
                submitted += 1;
            }
            if !self.step()? {
                std::thread::sleep(std::time::Duration::from_micros(100));
            }
        }
        Ok((self.take_done(), t0.elapsed().as_secs_f64()))
    }
}

/// Nearest-rank TTFT percentile (`q` in 0..=100) over completed responses;
/// 0 when the list is empty.
pub fn ttft_percentile(responses: &[Response], q: usize) -> u64 {
    let mut ttfts: Vec<u64> =
        responses.iter().map(|r| r.ttft.as_nanos() as u64).collect();
    ttfts.sort_unstable();
    if ttfts.is_empty() {
        0
    } else {
        ttfts[(ttfts.len() - 1) * q / 100]
    }
}

/// Nearest-rank TPOT (time-per-output-token) percentile (`q` in 0..=100)
/// over completed responses, in ns/token: each response contributes its
/// post-first-token decode time divided by its decode-token count.
/// Single-token responses have no decode phase and are skipped; 0 when no
/// response qualifies.
pub fn tpot_percentile(responses: &[Response], q: usize) -> u64 {
    let mut tpots: Vec<u64> = responses
        .iter()
        .filter(|r| r.tokens.len() > 1)
        .map(|r| {
            (r.total - r.ttft).as_nanos() as u64 / (r.tokens.len() as u64 - 1)
        })
        .collect();
    tpots.sort_unstable();
    if tpots.is_empty() {
        0
    } else {
        tpots[(tpots.len() - 1) * q / 100]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic in-memory backend: "logits" are one-hot rows whose
    /// argmax encodes the next token, so the scheduler's batching, lane
    /// bookkeeping, and retirement logic are testable without artifacts.
    struct MockModel {
        cfg: ModelConfig,
        metrics: Arc<Metrics>,
        lanes: Vec<Option<u64>>, // request id per busy lane
        /// Next token each lane should emit (token = lane + 3, fixed).
        prefills: usize,
        released: Vec<usize>,
    }

    fn mock_cfg() -> ModelConfig {
        ModelConfig {
            name: "mock".into(),
            vocab_size: 32,
            n_layers: 1,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            max_seq: 16,
            experts_schedule: vec![0],
            residual: false,
            top2: false,
            capacity_factor: 1.0,
            moe_loss_coef: 0.0,
            teacher: None,
            kd_alpha: 1.0,
            num_params: 0,
        }
    }

    impl MockModel {
        fn new(lanes: usize) -> Self {
            MockModel {
                cfg: mock_cfg(),
                metrics: Arc::new(Metrics::new()),
                lanes: vec![None; lanes],
                prefills: 0,
                released: Vec::new(),
            }
        }

        fn one_hot(&self, tok: i32) -> Vec<f32> {
            let mut row = vec![0f32; self.cfg.vocab_size];
            row[tok as usize] = 1.0;
            row
        }
    }

    impl ForwardModel for MockModel {
        fn model_config(&self) -> &ModelConfig {
            &self.cfg
        }
        fn metrics(&self) -> Arc<Metrics> {
            self.metrics.clone()
        }
        fn set_metrics(&mut self, metrics: Arc<Metrics>) {
            self.metrics = metrics;
        }
        fn prefill_sizes(&self) -> Vec<usize> {
            vec![1, 2, 4]
        }
        fn lane_count(&self) -> usize {
            self.lanes.len()
        }
        fn free_lane_count(&self) -> usize {
            self.lanes.iter().filter(|l| l.is_none()).count()
        }
        fn prefill(
            &mut self,
            compiled: usize,
            reqs: &[Request],
        ) -> Result<Vec<AdmittedLane>> {
            anyhow::ensure!(reqs.len() <= compiled);
            self.prefills += 1;
            let mut out = Vec::new();
            for req in reqs {
                let lane = self
                    .lanes
                    .iter()
                    .position(|l| l.is_none())
                    .expect("no free lane");
                self.lanes[lane] = Some(req.id);
                out.push(AdmittedLane {
                    lane,
                    logits: self.one_hot(lane as i32 + 3),
                });
            }
            Ok(out)
        }
        fn decode_step(
            &mut self,
            tokens: &[i32],
            pos: &[i32],
        ) -> Result<Vec<Vec<f32>>> {
            anyhow::ensure!(tokens.len() == self.lanes.len());
            anyhow::ensure!(pos.len() == self.lanes.len());
            // Each busy lane echoes its last token + 1 (mod vocab, EOS
            // avoided so max_new terminates the sequence).
            let vocab = self.cfg.vocab_size as i32;
            Ok((0..self.lanes.len())
                .map(|lane| {
                    let next = (tokens[lane] + 1).rem_euclid(vocab);
                    let next = if next == EOS { next + 1 } else { next };
                    self.one_hot(next)
                })
                .collect())
        }
        fn release(&mut self, lane: usize) {
            self.lanes[lane] = None;
            self.released.push(lane);
        }
    }

    fn serving() -> ServingConfig {
        ServingConfig {
            max_new_tokens: 4,
            batch_timeout: std::time::Duration::from_millis(1),
            ..Default::default()
        }
    }

    #[test]
    fn requests_complete_and_lanes_release() {
        let mut s = Scheduler::new(MockModel::new(4), serving());
        let mut ids = Vec::new();
        for i in 0..6 {
            ids.push(s.submit(vec![5 + i], Some(4)).unwrap());
        }
        let responses = s.run_until_idle().unwrap();
        assert_eq!(responses.len(), 6);
        let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
        got.sort();
        assert_eq!(got, ids);
        for r in &responses {
            assert_eq!(r.tokens.len(), 4);
            assert!(r.ttft <= r.total);
            // First token is the one-hot the prefill emitted; the rest
            // increment deterministically.
            for w in r.tokens.windows(2) {
                let want = if w[0] + 1 == EOS { w[0] + 2 } else { w[0] + 1 };
                assert_eq!(w[1], want);
            }
        }
        assert_eq!(s.model.released.len(), 6);
        assert_eq!(s.model.free_lane_count(), 4);
        assert_eq!(s.metrics.counter("requests_completed"), 6);
        assert_eq!(s.metrics.counter("requests_submitted"), 6);
        assert!(s.metrics.samples("ttft") == 6);
        assert!(s.metrics.value_count("decode_utilization") > 0);
        assert!(s.metrics.counter("decode_tokens") >= 6 * 3);
    }

    #[test]
    fn continuous_admission_mid_decode() {
        let mut s = Scheduler::new(
            MockModel::new(4),
            ServingConfig {
                max_new_tokens: 8,
                batch_timeout: std::time::Duration::ZERO,
                ..Default::default()
            },
        );
        s.submit(vec![1, 3], Some(8)).unwrap();
        for _ in 0..3 {
            s.step().unwrap();
        }
        assert_eq!(s.active_count(), 1);
        // A second request joins while the first is mid-decode.
        s.submit(vec![4], Some(2)).unwrap();
        let responses = s.run_until_idle().unwrap();
        assert_eq!(responses.len(), 2);
        let late = responses.iter().find(|r| r.prompt_len == 1).unwrap();
        assert_eq!(late.tokens.len(), 2);
        // Two separate prefill admissions happened.
        assert_eq!(s.model.prefills, 2);
    }

    #[test]
    fn eos_retires_early() {
        // A backend that emits EOS on the first decode step.
        struct EosModel(MockModel);
        impl ForwardModel for EosModel {
            fn model_config(&self) -> &ModelConfig {
                self.0.model_config()
            }
            fn metrics(&self) -> Arc<Metrics> {
                self.0.metrics()
            }
            fn set_metrics(&mut self, m: Arc<Metrics>) {
                self.0.set_metrics(m);
            }
            fn prefill_sizes(&self) -> Vec<usize> {
                self.0.prefill_sizes()
            }
            fn lane_count(&self) -> usize {
                self.0.lane_count()
            }
            fn free_lane_count(&self) -> usize {
                self.0.free_lane_count()
            }
            fn prefill(
                &mut self,
                compiled: usize,
                reqs: &[Request],
            ) -> Result<Vec<AdmittedLane>> {
                self.0.prefill(compiled, reqs)
            }
            fn decode_step(
                &mut self,
                tokens: &[i32],
                _pos: &[i32],
            ) -> Result<Vec<Vec<f32>>> {
                Ok(tokens.iter().map(|_| self.0.one_hot(EOS)).collect())
            }
            fn release(&mut self, lane: usize) {
                self.0.release(lane)
            }
        }
        let mut s = Scheduler::new(EosModel(MockModel::new(2)), serving());
        s.submit(vec![7], Some(4)).unwrap();
        let r = s.run_until_idle().unwrap();
        assert_eq!(r.len(), 1);
        // first token + the EOS that retired it
        assert_eq!(r[0].tokens.len(), 2);
        assert_eq!(*r[0].tokens.last().unwrap(), EOS);
    }

    #[test]
    fn tier1_preempts_and_victim_resumes_to_full_length() {
        let mut s = Scheduler::new(
            MockModel::new(2),
            ServingConfig {
                max_new_tokens: 8,
                batch_timeout: std::time::Duration::ZERO,
                ..Default::default()
            },
        );
        s.submit(vec![1], Some(8)).unwrap();
        s.submit(vec![2], Some(8)).unwrap();
        for _ in 0..3 {
            s.step().unwrap();
        }
        assert_eq!(s.active_count(), 2);
        // A tier-1 arrival under full lanes evicts one tier-0 decode.
        let sub = s.submit_tiered(vec![3], Some(4), 1, None).unwrap();
        assert!(matches!(sub, Submission::Queued(_)));
        s.step().unwrap();
        assert_eq!(s.metrics.counter("preemptions"), 1);
        assert_eq!(s.metrics.counter("preempted_t0"), 1);
        let responses = s.run_until_idle().unwrap();
        assert_eq!(responses.len(), 3);
        // The victim resumed and still produced its full token budget;
        // nobody's work was lost or duplicated.
        assert_eq!(s.metrics.counter("resumed"), 1);
        for r in &responses {
            let want = if r.tier == 1 { 4 } else { 8 };
            assert_eq!(r.tokens.len(), want, "request {} length", r.id);
        }
        assert_eq!(s.model.free_lane_count(), 2);
        // TTFT was measured once per request, at first admission only.
        assert_eq!(s.metrics.samples("ttft"), 3);
    }

    #[test]
    fn resumed_request_at_budget_retires_immediately() {
        // One lane: a tier-0 request is evicted after 3 of its 4 tokens;
        // the resume sample is its 4th and must retire it at
        // re-admission, not after a stray extra decode step.
        let mut s = Scheduler::new(
            MockModel::new(1),
            ServingConfig {
                max_new_tokens: 4,
                batch_timeout: std::time::Duration::ZERO,
                ..Default::default()
            },
        );
        let a = s.submit(vec![1], Some(4)).unwrap();
        s.step().unwrap(); // admit + decode: 2 generated
        s.step().unwrap(); // 3 generated
        let sub = s.submit_tiered(vec![2], Some(4), 1, None).unwrap();
        assert!(matches!(sub, Submission::Queued(_)));
        let responses = s.run_until_idle().unwrap();
        assert_eq!(s.metrics.counter("preemptions"), 1);
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert_eq!(r.tokens.len(), 4, "request {} length", r.id);
            if r.id == a {
                assert_eq!(r.prompt_len, 1, "original prompt_len reported");
            }
        }
    }

    #[test]
    fn backpressure_sheds_and_accounts() {
        let mut s = Scheduler::new(
            MockModel::new(1),
            ServingConfig {
                max_new_tokens: 4,
                batch_timeout: std::time::Duration::ZERO,
                queue_cap: 2,
                ..Default::default()
            },
        );
        // No steps yet: the third valid submission overflows cap 2.
        assert!(matches!(
            s.submit_tiered(vec![1], Some(4), 0, None).unwrap(),
            Submission::Queued(_)
        ));
        assert!(matches!(
            s.submit_tiered(vec![2], Some(4), 0, None).unwrap(),
            Submission::Queued(_)
        ));
        assert_eq!(
            s.submit_tiered(vec![3], Some(4), 0, None).unwrap(),
            Submission::Shed
        );
        assert_eq!(s.metrics.counter("requests_submitted"), 3);
        assert_eq!(s.metrics.counter("queued_t0"), 2);
        assert_eq!(s.metrics.counter("shed_t0"), 1);
        // Reject policy: queued + shed == submitted, exactly.
        assert_eq!(
            s.metrics.counter("queued_t0") + s.metrics.counter("shed_t0"),
            s.metrics.counter("requests_submitted")
        );
        let responses = s.run_until_idle().unwrap();
        assert_eq!(responses.len(), 2);
        assert_eq!(s.metrics.counter("requests_completed"), 2);
    }

    #[test]
    fn tpot_percentile_skips_single_token_responses() {
        use std::time::Duration;
        let mk = |ttft_ms: u64, total_ms: u64, n_tokens: usize| Response {
            id: 1,
            prompt_len: 1,
            tokens: vec![0; n_tokens],
            ttft: Duration::from_millis(ttft_ms),
            total: Duration::from_millis(total_ms),
            tier: 0,
        };
        assert_eq!(tpot_percentile(&[], 50), 0);
        // Single-token responses have no decode phase.
        assert_eq!(tpot_percentile(&[mk(5, 5, 1)], 50), 0);
        // 9ms decode over 3 decode tokens = 3ms/token.
        let r = mk(1, 10, 4);
        assert_eq!(tpot_percentile(&[r.clone()], 50), 3_000_000);
        // Mixed: percentiles rank the per-response TPOTs.
        let fast = mk(1, 4, 4); // 1ms/token
        let slow = mk(1, 31, 4); // 10ms/token
        let both = [fast, slow, mk(5, 5, 1)];
        assert_eq!(tpot_percentile(&both, 0), 1_000_000);
        assert_eq!(tpot_percentile(&both, 100), 10_000_000);
    }
}
