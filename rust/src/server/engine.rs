//! Monolithic serving engine: continuous decode batching on one device.
//!
//! Event loop (one `step()` per iteration, driven by the caller or
//! `run_until_idle`):
//!
//! 1. Ask the [`BatchPolicy`] whether to admit waiting requests; if so, run
//!    a `prefill_b{B}` at a compiled batch size, splice each request's KV
//!    cache into a free decode lane, and emit its first token.
//! 2. If any lane is live, run one `decode_b{B}` step over the whole group
//!    (fixed compiled B; free lanes are padded), append tokens, retire
//!    finished requests.
//!
//! Tokens are sampled greedily (`temperature == 0`) or with temperature
//! sampling; sequences end at `max_new_tokens` or EOS.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::ServingConfig;
use crate::coordinator::{
    BatchPolicy, Decision, KvCacheGroup, Limits, Request, Response, Router,
};
use crate::metrics::Metrics;
use crate::runtime::{Checkpoint, HostTensor, Manifest, Program, Runtime};
use crate::tokenizer::EOS;
use crate::util::rng::Rng;

struct ActiveSeq {
    request: Request,
    generated: Vec<i32>,
    last_token: i32,
    first_token_at: std::time::Instant,
}

pub struct Engine {
    rt: Runtime,
    cfg: crate::config::ModelConfig,
    serving: ServingConfig,
    params: Vec<xla::Literal>,
    prefill_progs: HashMap<usize, Rc<Program>>, // by batch size
    decode_prog: Rc<Program>,
    pub router: Router,
    policy: BatchPolicy,
    group: KvCacheGroup,
    active: HashMap<usize, ActiveSeq>, // by lane
    pub done: Vec<Response>,
    pub metrics: std::sync::Arc<Metrics>,
    rng: Rng,
    /// Cached literal mirror of the KV cache; invalidated by lane splices.
    cache_lits: Option<(xla::Literal, xla::Literal)>,
}

impl Engine {
    pub fn new(manifest: &Manifest, serving: ServingConfig) -> Result<Engine> {
        let arts = manifest.model(&serving.model)?;
        let cfg = arts.config.clone();
        let rt = Runtime::cpu()?;

        // Load checkpoint into literals once (params are read-only here).
        let ck = Checkpoint::load(&arts.checkpoint_dir)?;
        anyhow::ensure!(
            ck.names.len() == arts.params.len(),
            "checkpoint/manifest param count mismatch"
        );
        let params: Result<Vec<_>> =
            ck.tensors.iter().map(|t| t.to_literal()).collect();

        // Compile prefill programs for every available batch size and the
        // decode program at the serving batch size.
        let mut prefill_progs = HashMap::new();
        let mut prefill_sizes = Vec::new();
        for (key, spec) in &arts.programs {
            if let Some(b) = key.strip_prefix("prefill_b") {
                let b: usize = b.parse().context("prefill key")?;
                prefill_progs.insert(b, rt.load(spec)?);
                prefill_sizes.push(b);
            }
        }
        anyhow::ensure!(!prefill_progs.is_empty(),
                        "model {} exports no prefill programs", cfg.name);
        let decode_key = format!("decode_b{}", serving.max_batch);
        let decode_prog = rt.load(
            arts.programs
                .get(&decode_key)
                .with_context(|| format!("no {decode_key} program"))?,
        )?;

        let router = Router::new(Limits {
            max_seq: cfg.max_seq,
            vocab_size: cfg.vocab_size,
            default_max_new: serving.max_new_tokens,
        });
        let policy = BatchPolicy::new(prefill_sizes, serving.batch_timeout);
        let group = KvCacheGroup::new(
            cfg.n_layers,
            serving.max_batch,
            cfg.n_heads,
            cfg.max_seq,
            cfg.head_dim(),
        );
        Ok(Engine {
            rt,
            cfg,
            serving,
            params: params?,
            prefill_progs,
            decode_prog,
            router,
            policy,
            group,
            active: HashMap::new(),
            done: Vec::new(),
            metrics: std::sync::Arc::new(Metrics::new()),
            rng: Rng::new(0xD5),
            cache_lits: None,
        })
    }

    pub fn model_config(&self) -> &crate::config::ModelConfig {
        &self.cfg
    }

    pub fn submit(&mut self, prompt: Vec<i32>, max_new: Option<usize>) -> Result<u64> {
        self.metrics.inc("requests_submitted", 1);
        self.router.submit(prompt, max_new)
    }

    /// One scheduler iteration.  Returns true if any work was done.
    pub fn step(&mut self) -> Result<bool> {
        let free = self.group.free_lanes().len();
        let decision = self.policy.decide(
            self.router.queue_len(),
            free,
            self.router.oldest_wait(),
        );
        let mut worked = false;
        if let Decision::Prefill { compiled, take } = decision {
            let reqs = self.router.pop_up_to(take);
            let t = std::time::Instant::now();
            self.do_prefill(compiled, reqs)?;
            self.metrics.observe("prefill", t.elapsed());
            worked = true;
        }
        if !self.group.is_idle() {
            let t = std::time::Instant::now();
            self.do_decode()?;
            self.metrics.observe("decode_step", t.elapsed());
            worked = true;
        }
        Ok(worked)
    }

    /// Drain the queue and all in-flight sequences.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        while self.router.queue_len() > 0 || !self.group.is_idle() {
            // When only partial batches wait, sleep just until the oldest
            // request's flush deadline (capped at one timeout) instead of
            // a fixed full timeout — a request that has already waited
            // most of the timeout should not eat another whole one of
            // TTFT.  The floor avoids a busy spin when the deadline is
            // due on the next decide().
            if !self.step()? {
                // time_to_flush is <= the policy timeout by construction.
                let remaining = self
                    .policy
                    .time_to_flush(self.router.oldest_wait())
                    .unwrap_or(self.serving.batch_timeout);
                let floor = std::time::Duration::from_micros(50);
                std::thread::sleep(remaining.max(floor));
            }
        }
        Ok(std::mem::take(&mut self.done))
    }

    pub fn take_done(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.done)
    }

    fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.serving.temperature <= 0.0 {
            let mut best = 0;
            for (i, &v) in logits.iter().enumerate() {
                if v > logits[best] {
                    best = i;
                }
            }
            return best as i32;
        }
        let t = self.serving.temperature;
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = logits
            .iter()
            .map(|&v| (((v - max) / t) as f64).exp())
            .collect();
        self.rng.weighted(&weights) as i32
    }

    /// Materialize the literal cache mirror back into the host-side group
    /// (needed before lane splicing).
    fn sync_cache_to_host(&mut self) -> Result<()> {
        if let Some((k, v)) = self.cache_lits.take() {
            self.group.update(
                HostTensor::from_literal(&k)?,
                HostTensor::from_literal(&v)?,
            )?;
        }
        Ok(())
    }

    fn do_prefill(&mut self, compiled: usize, reqs: Vec<Request>) -> Result<()> {
        self.sync_cache_to_host()?;
        let smax = self.cfg.max_seq;
        let prog = self.prefill_progs[&compiled].clone();

        // Pack prompts (right-padded) into [compiled, smax].
        let mut tokens = vec![0i32; compiled * smax];
        for (b, r) in reqs.iter().enumerate() {
            tokens[b * smax..b * smax + r.prompt.len()]
                .copy_from_slice(&r.prompt);
        }
        let tok_lit = HostTensor::i32(&[compiled, smax], tokens).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&tok_lit);
        let outs = prog.run_literal_refs(&inputs)?;
        // Single host pull per output; per-lane rows/slices are consumed in
        // place below (no HostTensor wrappers, no per-request [L,1,H,S,hd]
        // owned copies).
        let logits_data: Vec<f32> = outs[0].to_vec()?; // [B, smax, V]
        let kc_data: Vec<f32> = outs[1].to_vec()?; // [L, B, H, smax, hd]
        let vc_data: Vec<f32> = outs[2].to_vec()?;

        let v = self.cfg.vocab_size;
        let free = self.group.free_lanes();
        anyhow::ensure!(free.len() >= reqs.len(), "prefill without free lanes");

        // Lane splices invalidate the literal mirror once per prefill, not
        // per admitted lane (sync_cache_to_host has already drained it).
        self.cache_lits = None;
        for (i, req) in reqs.into_iter().enumerate() {
            let lane = free[i];
            let plen = req.prompt.len();
            // First generated token comes from the prompt's last position.
            let row =
                &logits_data[(i * smax + plen - 1) * v..(i * smax + plen) * v];
            let first = self.sample(row);

            // Splice this request's cache slice straight out of the batched
            // prefill outputs into the lane storage.
            self.group.admit_from_batch(
                lane, req.id, plen, &kc_data, &vc_data, i, compiled,
            )?;
            let now = std::time::Instant::now();
            self.metrics.observe("ttft", now - req.arrival);
            self.metrics.inc("prefills", 1);
            self.active.insert(
                lane,
                ActiveSeq {
                    request: req,
                    generated: vec![first],
                    last_token: first,
                    first_token_at: now,
                },
            );
        }
        Ok(())
    }

    fn do_decode(&mut self) -> Result<()> {
        let b = self.group.batch;
        let mut tokens = vec![0i32; b];
        for (&lane, seq) in &self.active {
            tokens[lane] = seq.last_token;
        }
        let pos = self.group.positions();

        let tok_lit = HostTensor::i32(&[b], tokens).to_literal()?;
        let pos_lit = HostTensor::i32(&[b], pos).to_literal()?;
        if self.cache_lits.is_none() {
            self.cache_lits =
                Some((self.group.k.to_literal()?, self.group.v.to_literal()?));
        }
        let (k_lit, v_lit) = self.cache_lits.take().unwrap();

        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&tok_lit);
        inputs.push(&k_lit);
        inputs.push(&v_lit);
        inputs.push(&pos_lit);
        let mut outs = self.decode_prog.run_literal_refs(&inputs)?;
        let logits = HostTensor::from_literal(&outs[0])?; // [B, V]
        // Keep the updated caches as literals for the next decode step —
        // they are only materialized back to host tensors when a prefill
        // needs to splice a lane (see do_prefill / sync_cache_to_host).
        // DSMOE_NO_CACHE_MIRROR forces the pre-optimization behaviour
        // (full literal->host->literal round trip per step) for the §Perf
        // before/after measurement in EXPERIMENTS.md.
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        if std::env::var_os("DSMOE_NO_CACHE_MIRROR").is_some() {
            self.group.update(
                HostTensor::from_literal(&k_new)?,
                HostTensor::from_literal(&v_new)?,
            )?;
            self.cache_lits = None;
        } else {
            self.cache_lits = Some((k_new, v_new));
        }
        self.metrics.inc("decode_steps", 1);
        self.metrics.inc(
            "decode_tokens",
            self.active.len() as u64,
        );

        let v = self.cfg.vocab_size;
        let logits_data = logits.as_f32()?.to_vec();
        let lanes: Vec<usize> = self.active.keys().copied().collect();
        for lane in lanes {
            // advance cache position for the token just written
            self.group.advance(lane)?;
            let row = &logits_data[lane * v..(lane + 1) * v];
            let next = self.sample(row);
            let seq = self.active.get_mut(&lane).unwrap();
            seq.generated.push(next);
            seq.last_token = next;
            let finished = next == EOS
                || seq.generated.len() >= seq.request.max_new_tokens
                || seq.request.prompt.len() + seq.generated.len()
                    >= self.cfg.max_seq;
            if finished {
                let seq = self.active.remove(&lane).unwrap();
                self.group.release(lane);
                let total = seq.request.arrival.elapsed();
                self.metrics.observe("request_total", total);
                self.metrics.inc("requests_completed", 1);
                self.metrics
                    .inc("tokens_generated", seq.generated.len() as u64);
                self.done.push(Response {
                    id: seq.request.id,
                    prompt_len: seq.request.prompt.len(),
                    tokens: seq.generated,
                    ttft: seq.first_token_at - seq.request.arrival,
                    total,
                });
            }
        }
        Ok(())
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn compiled_programs(&self) -> usize {
        self.rt.cached_programs()
    }
}
