//! Monolithic serving backend: single-device prefill/decode over the fused
//! AOT programs (`prefill_b{B}` / `decode_b{B}`).
//!
//! Since the continuous-batching refactor the event loop lives in the
//! engine-agnostic [`crate::server::Scheduler`]; this type is the
//! [`ForwardModel`] backend it drives:
//!
//! * [`Engine::prefill`] runs a `prefill_b{B}` at a compiled batch size and
//!   splices each request's KV cache into a free decode lane straight from
//!   the batched outputs ([`KvCacheGroup::admit_from_batch`], zero-copy);
//! * [`Engine::decode_step`] runs one `decode_b{B}` step over the whole
//!   lane group (fixed compiled B; free lanes are padded) and keeps the
//!   updated caches as literals between steps (the KV literal mirror —
//!   `DSMOE_NO_CACHE_MIRROR` forces the pre-optimization host round trip
//!   for the §Perf measurement);
//! * [`Engine::release`] frees a retired request's lane.
//!
//! Sampling, batching policy, and request bookkeeping live in the
//! scheduler; construct one with
//! `Scheduler::new(Engine::new(&manifest, serving.clone())?, serving)`.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::ServingConfig;
use crate::coordinator::{KvCacheGroup, Request};
use crate::metrics::Metrics;
use crate::runtime::{Checkpoint, HostTensor, Manifest, Program, Runtime};
use crate::server::scheduler::{AdmittedLane, ForwardModel};

pub struct Engine {
    rt: Runtime,
    cfg: crate::config::ModelConfig,
    params: Vec<xla::Literal>,
    prefill_progs: HashMap<usize, Rc<Program>>, // by batch size
    prefill_sizes: Vec<usize>,
    decode_prog: Rc<Program>,
    group: KvCacheGroup,
    pub metrics: std::sync::Arc<Metrics>,
    /// Cached literal mirror of the KV cache; invalidated by lane splices.
    cache_lits: Option<(xla::Literal, xla::Literal)>,
}

impl Engine {
    pub fn new(manifest: &Manifest, serving: ServingConfig) -> Result<Engine> {
        let arts = manifest.model(&serving.model)?;
        let cfg = arts.config.clone();
        let rt = Runtime::cpu()?;

        // Load checkpoint into literals once (params are read-only here).
        let ck = Checkpoint::load(&arts.checkpoint_dir)?;
        anyhow::ensure!(
            ck.names.len() == arts.params.len(),
            "checkpoint/manifest param count mismatch"
        );
        let params: Result<Vec<_>> =
            ck.tensors.iter().map(|t| t.to_literal()).collect();

        // Compile prefill programs for every available batch size and the
        // decode program at the serving batch size.
        let mut prefill_progs = HashMap::new();
        let mut prefill_sizes = Vec::new();
        for (key, spec) in &arts.programs {
            if let Some(b) = key.strip_prefix("prefill_b") {
                let b: usize = b.parse().context("prefill key")?;
                prefill_progs.insert(b, rt.load(spec)?);
                prefill_sizes.push(b);
            }
        }
        anyhow::ensure!(!prefill_progs.is_empty(),
                        "model {} exports no prefill programs", cfg.name);
        prefill_sizes.sort();
        let decode_key = format!("decode_b{}", serving.max_batch);
        let decode_prog = rt.load(
            arts.programs
                .get(&decode_key)
                .with_context(|| format!("no {decode_key} program"))?,
        )?;

        let group = KvCacheGroup::new(
            cfg.n_layers,
            serving.max_batch,
            cfg.n_heads,
            cfg.max_seq,
            cfg.head_dim(),
        );
        Ok(Engine {
            rt,
            cfg,
            params: params?,
            prefill_progs,
            prefill_sizes,
            decode_prog,
            group,
            metrics: std::sync::Arc::new(Metrics::new()),
            cache_lits: None,
        })
    }

    pub fn model_config(&self) -> &crate::config::ModelConfig {
        &self.cfg
    }

    /// Materialize the literal cache mirror back into the host-side group
    /// (needed before lane splicing).
    fn sync_cache_to_host(&mut self) -> Result<()> {
        if let Some((k, v)) = self.cache_lits.take() {
            self.group.update(
                HostTensor::from_literal(&k)?,
                HostTensor::from_literal(&v)?,
            )?;
        }
        Ok(())
    }

    pub fn compiled_programs(&self) -> usize {
        self.rt.cached_programs()
    }
}

impl ForwardModel for Engine {
    fn model_config(&self) -> &crate::config::ModelConfig {
        &self.cfg
    }

    fn configure(&mut self, serving: &crate::config::ServingConfig) {
        // The monolithic engine's prefill is one fused program — there is
        // no per-layer seam to chunk an admission across, so a requested
        // chunk budget cannot apply here (the scheduler's default
        // stop-the-world path stays in effect, which is also what an
        // unset budget means).
        if serving.prefill_chunk > 0 {
            eprintln!(
                "[serve] DSMOE_PREFILL_CHUNK has no effect on the \
                 monolithic engine (fused prefill program)"
            );
        }
    }

    fn metrics(&self) -> std::sync::Arc<Metrics> {
        self.metrics.clone()
    }

    fn set_metrics(&mut self, metrics: std::sync::Arc<Metrics>) {
        self.metrics = metrics;
    }

    fn prefill_sizes(&self) -> Vec<usize> {
        self.prefill_sizes.clone()
    }

    fn lane_count(&self) -> usize {
        self.group.batch
    }

    fn free_lane_count(&self) -> usize {
        self.group.free_lanes().len()
    }

    fn prefill(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<Vec<AdmittedLane>> {
        anyhow::ensure!(
            reqs.len() <= compiled,
            "prefill: {} requests at compiled size {compiled}",
            reqs.len()
        );
        self.sync_cache_to_host()?;
        let smax = self.cfg.max_seq;
        let prog = self
            .prefill_progs
            .get(&compiled)
            .with_context(|| format!("no prefill_b{compiled} program"))?
            .clone();

        // Pack prompts (right-padded) into [compiled, smax].
        let mut tokens = vec![0i32; compiled * smax];
        for (b, r) in reqs.iter().enumerate() {
            tokens[b * smax..b * smax + r.prompt.len()]
                .copy_from_slice(&r.prompt);
        }
        let tok_lit = HostTensor::i32(&[compiled, smax], tokens).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&tok_lit);
        let outs = prog.run_literal_refs(&inputs)?;
        // Single host pull per output; per-lane rows/slices are consumed in
        // place below (no HostTensor wrappers, no per-request [L,1,H,S,hd]
        // owned copies).
        let logits_data: Vec<f32> = outs[0].to_vec()?; // [B, smax, V]
        let kc_data: Vec<f32> = outs[1].to_vec()?; // [L, B, H, smax, hd]
        let vc_data: Vec<f32> = outs[2].to_vec()?;

        let v = self.cfg.vocab_size;
        let free = self.group.free_lanes();
        anyhow::ensure!(free.len() >= reqs.len(), "prefill without free lanes");

        // Lane splices invalidate the literal mirror once per prefill, not
        // per admitted lane (sync_cache_to_host has already drained it).
        self.cache_lits = None;
        let mut admitted = Vec::with_capacity(reqs.len());
        for (i, req) in reqs.iter().enumerate() {
            let lane = free[i];
            let plen = req.prompt.len();
            // The first generated token comes from the prompt's last
            // position; the scheduler samples it from this row.
            let row =
                logits_data[(i * smax + plen - 1) * v..(i * smax + plen) * v]
                    .to_vec();

            // Splice this request's cache slice straight out of the batched
            // prefill outputs into the lane storage.
            self.group.admit_from_batch(
                lane, req.id, plen, &kc_data, &vc_data, i, compiled,
            )?;
            admitted.push(AdmittedLane { lane, logits: row });
        }
        Ok(admitted)
    }

    fn decode_step(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.group.batch;
        anyhow::ensure!(tokens.len() == b && pos.len() == b, "lane shape");

        let tok_lit = HostTensor::i32(&[b], tokens.to_vec()).to_literal()?;
        let pos_lit = HostTensor::i32(&[b], pos.to_vec()).to_literal()?;
        if self.cache_lits.is_none() {
            self.cache_lits =
                Some((self.group.k.to_literal()?, self.group.v.to_literal()?));
        }
        let (k_lit, v_lit) = self.cache_lits.take().unwrap();

        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&tok_lit);
        inputs.push(&k_lit);
        inputs.push(&v_lit);
        inputs.push(&pos_lit);
        let mut outs = self.decode_prog.run_literal_refs(&inputs)?;
        let logits = HostTensor::from_literal(&outs[0])?; // [B, V]
        // Keep the updated caches as literals for the next decode step —
        // they are only materialized back to host tensors when a prefill
        // needs to splice a lane (see prefill / sync_cache_to_host).
        // DSMOE_NO_CACHE_MIRROR forces the pre-optimization behaviour
        // (full literal->host->literal round trip per step) for the §Perf
        // before/after measurement in EXPERIMENTS.md.
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        if std::env::var_os("DSMOE_NO_CACHE_MIRROR").is_some() {
            self.group.update(
                HostTensor::from_literal(&k_new)?,
                HostTensor::from_literal(&v_new)?,
            )?;
            self.cache_lits = None;
        } else {
            self.cache_lits = Some((k_new, v_new));
        }

        // Advance each busy lane's cache position for the token just
        // written (the max_seq guard lives in KvCacheGroup::advance; the
        // scheduler retires sequences before they can overflow).
        for (lane, _, _) in self.group.busy_lanes() {
            self.group.advance(lane)?;
        }

        let v = self.cfg.vocab_size;
        let logits_data = logits.as_f32()?.to_vec();
        Ok((0..b)
            .map(|lane| logits_data[lane * v..(lane + 1) * v].to_vec())
            .collect())
    }

    fn release(&mut self, lane: usize) {
        self.group.release(lane);
    }
}
