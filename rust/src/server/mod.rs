//! The serving stack: one continuous-batching scheduler, two backends.
//!
//! ```text
//!      requests ──► Scheduler<M: ForwardModel>          (scheduler.rs)
//!                   ├── Router        admission + FIFO
//!                   ├── BatchPolicy   size-or-timeout batch formation
//!                   ├── Sampler       greedy / seeded temperature
//!                   └── lane + TTFT/retirement bookkeeping
//!                         │  ForwardModel trait:
//!                         │  prefill(compiled, reqs) -> admitted lanes
//!                         │  decode_step(tokens, pos) -> logits
//!                         │  release(lane)
//!            ┌────────────┴────────────┐
//!      Engine (engine.rs)        EpEngine (ep.rs)
//!      monolithic single-device  disaggregated expert-parallel
//!      fused prefill_b{B}/       leader drives the dense backbone,
//!      decode_b{B} programs,     fabric workers run expert FFNs;
//!      zero-copy lane splicing   split-phase MoE, microbatch
//!      + KV literal mirror       pipelining, masked dead lanes
//! ```
//!
//! * [`Scheduler`] — engine-agnostic continuous batching: admit → prefill
//!   splice → decode → retire, the loop §5 of the paper treats as one
//!   system.  Owns sampling and all request bookkeeping; metric names are
//!   those of the pre-refactor engine plus `queue_depth` / `lanes_busy`
//!   gauges and the `decode_utilization` summary.  Backends that
//!   implement the split admission API (`begin_prefill`/`finish_prefill`)
//!   get prefill-behind-decode interleaving: the admission's layer
//!   programs run while the decode step's expert exchanges are on the
//!   fabric, instead of stopping every decode lane
//!   (`interleaved_admissions` counter; admission waits land in
//!   `prefill_stall`).
//! * [`engine::Engine`] — single-device backend over the monolithic AOT
//!   programs (fused Pallas kernels inside): the baseline the paper's
//!   single-GPU numbers correspond to.
//! * [`ep::EpEngine`] — the disaggregated expert-parallel backend (§5's
//!   architecture: gate → group tokens by expert → all-to-all → expert
//!   FFN → return & combine), with split-phase MoE, a depth-N
//!   cross-layer microbatch pipeline ring (`pipe_depth` groups of lanes,
//!   N tagged exchanges in flight), dynamic live-lane regrouping under
//!   skewed retirement, and per-group host KV mirrors.  Also usable
//!   standalone through its legacy fixed-lane `forward_prefill` /
//!   `forward_decode` API.
//! * `shard` (internal) — parallel leader shards: with
//!   `leader_threads >= 2`, each pipeline microbatch group's dense
//!   backbone (embed/attention/gate/combine, via the shared
//!   `shard::Backbone` that the single-threaded leader also executes)
//!   runs on its own OS thread with its own thread-bound runtime and its
//!   group's KV caches, while the engine orchestrates the tagged expert
//!   exchanges oldest-first — the §5 move of parallelizing the dense
//!   parameters too, not just the experts.
//!
//! Both backends produce identical logits for identical weights/input —
//! the parity tests in `rust/tests/integration_parity.rs` (including the
//! scheduler-vs-fixed-lane token parity tests and the depth-3/4 three-way
//! bitwise tests) are the end-to-end correctness anchor of the whole
//! stack.
//!
//! ## Env toggles (expert-parallel data path)
//!
//! | variable               | effect                                      |
//! |------------------------|---------------------------------------------|
//! | `DSMOE_SERIAL_MOE`     | serialized per-expert MoE path (pre-overlap |
//! |                        | baseline); also disables the pipeline.      |
//! | `DSMOE_NO_PIPELINE`    | per-layer overlapped path (no microbatch    |
//! |                        | interleaving).                              |
//! | `DSMOE_PIPE_DEPTH`     | microbatch pipeline ring depth N (default   |
//! |                        | 2); unsupported depths fall back 2 → 1;     |
//! |                        | 0/negative/garbage warn and fall back to 2. |
//! | `DSMOE_LEADER_THREADS` | >= 2: one leader-shard thread per           |
//! |                        | microbatch group — dense backbones of       |
//! |                        | different microbatches run concurrently     |
//! |                        | (default 1 = single-threaded leader).       |
//! | `DSMOE_NO_INTERLEAVE`  | stop-the-world admission prefills (disable  |
//! |                        | prefill-behind-decode interleaving).        |
//! | `DSMOE_REGROUP_SKEW`   | live-lane skew (max − min per group) that   |
//! |                        | triggers a dynamic regroup (default 2: a    |
//! |                        | skew of 1 is unavoidable whenever live      |
//! |                        | lanes don't divide evenly across groups).   |
//! | `DSMOE_NO_CACHE_MIRROR`| monolithic engine: host round trip of the   |
//! |                        | KV cache every decode step (pre-mirror      |
//! |                        | baseline, §Perf).  The EP engine's          |
//! |                        | per-group mirrors have no toggle — splices  |
//! |                        | and regroups always write through them.     |
//! | `DSMOE_A2A`            | `hierarchical`: route the live expert       |
//! |                        | exchange through the §5.3 two-stage relay   |
//! |                        | schedule — O(nodes) cross-node messages per |
//! |                        | direction per MoE layer instead of          |
//! |                        | O(workers) (default `flat`; bit-identical). |
//! | `DSMOE_NODE_SIZE`      | workers per node for hierarchical dispatch  |
//! |                        | and plan accounting; must be a positive     |
//! |                        | divisor of the worker count (else warn +    |
//! |                        | flat).  Unset: largest divisor ≤ 8.         |
//! | `DSMOE_TRANSPORT`      | leader↔worker wire: `channel` (in-process,  |
//! |                        | default) or `socket` (Unix sockets with     |
//! |                        | length-prefixed serialized frames — the     |
//! |                        | separate-process worker protocol).          |
//! | `DSMOE_REPLICATE_HOT`  | split a replicated expert's token block     |
//! |                        | across its replicas and run the online      |
//! |                        | load-aware rebalancer between forwards      |
//! |                        | (default off: static placement, bit-        |
//! |                        | identical to the pre-replication path).     |
//! | `DSMOE_REBALANCE_SKEW` | EWMA max/mean expert-load skew above which  |
//! |                        | the rebalancer replicates the hottest       |
//! |                        | expert / de-replicates cooled ones          |
//! |                        | (default 2.0; clamped to >= 1).             |
//! | `DSMOE_MAX_REPLICAS`   | ceiling on per-expert replication under the |
//! |                        | rebalancer (default: worker count).         |
//! | `DSMOE_EXPERT_DTYPE`   | expert-FFN weight ladder shipped to the     |
//! |                        | workers: `f32` (default), `bf16`, or        |
//! |                        | `int8`/`i8` with per-output-channel scales  |
//! |                        | — workers dequantize once at install and    |
//! |                        | compute in f32.  Shrinks startup shipping   |
//! |                        | and migration payloads ~2x / ~3.5x.  Gated  |
//! |                        | on the manifest's capability flags.         |
//! | `DSMOE_WIRE_DTYPE`     | dispatch/combine activation payloads on the |
//! |                        | fabric: `f32` (default, bitwise identical)  |
//! |                        | or `f16`/`bf16` — halves per-layer          |
//! |                        | all-to-all bytes under flat and             |
//! |                        | hierarchical schedules; replies come back   |
//! |                        | in the wire dtype and are widened at        |
//! |                        | combine.  Gated on the capability flags.    |
//! | `DSMOE_PREFILL_CHUNK`  | prompt-token budget a staged admission may  |
//! |                        | advance per decode step (chunked prefill):  |
//! |                        | a large prompt's admission spreads across   |
//! |                        | several decode steps instead of stalling    |
//! |                        | the lanes for its whole prefill.  Default 0 |
//! |                        | = off (admission completes behind a single  |
//! |                        | decode step).  EP engine only — the         |
//! |                        | monolithic fused prefill has no layer seam. |
//! | `DSMOE_QUEUE_CAP`      | bounded per-tier admission queues: a valid  |
//! |                        | submission to a full tier queue is *shed*   |
//! |                        | (backpressure), counted per tier.  Default  |
//! |                        | 0 = unbounded (no shedding).                |
//! | `DSMOE_SHED_POLICY`    | what a full tier queue sheds: `reject` (the |
//! |                        | new arrival, default) or `drop-oldest` (the |
//! |                        | tier's stalest waiter — the new arrival     |
//! |                        | takes its slot).                            |
//! | `DSMOE_FAULT_TOLERANCE`| survive worker death/hangs: exchange        |
//! |                        | deadlines, probe sweeps, live expert        |
//! |                        | failover, and scheduler-level request       |
//! |                        | requeue (token-identical continuations).    |
//! |                        | Default off: any worker fault is a loud,    |
//! |                        | immediate error, bitwise identical to the   |
//! |                        | pre-FT path.  See `server/ep.rs` for the    |
//! |                        | companion `DSMOE_EXCHANGE_TIMEOUT_MS` /     |
//! |                        | `DSMOE_FT_PROBE_TIMEOUT_MS` /               |
//! |                        | `DSMOE_FT_DEAD_AFTER` /                     |
//! |                        | `DSMOE_FT_RECOVER_AFTER` /                  |
//! |                        | `DSMOE_FT_RETRIES` knobs.                   |

pub mod engine;
pub mod ep;
pub mod scheduler;
pub(crate) mod shard;

pub use engine::Engine;
pub use ep::{EpEngine, InflightMoe};
pub use scheduler::{
    tpot_percentile, ttft_percentile, AdmittedLane, ForwardModel, Scheduler,
};
