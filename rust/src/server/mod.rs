//! Serving engines.
//!
//! * [`engine::Engine`] — single-device serving over the monolithic AOT
//!   programs (`prefill_b{B}` / `decode_b{B}`, fused Pallas kernels inside):
//!   continuous decode batching with lane-level admission, the baseline the
//!   paper's single-GPU numbers correspond to.
//! * [`ep::EpEngine`] — the disaggregated expert-parallel engine: the leader
//!   runs the dense backbone layer by layer via the shared AOT programs and
//!   dispatches gathered expert blocks to fabric workers (§5's architecture:
//!   gate → group tokens by expert → all-to-all → expert FFN → return &
//!   combine).
//!
//! Both engines produce identical logits for identical weights/input — the
//! parity test in `rust/tests/integration_parity.rs` is the end-to-end
//! correctness anchor of the whole stack.

pub mod engine;
pub mod ep;

pub use engine::Engine;
pub use ep::{EpEngine, InflightMoe};
