//! The serving stack: one continuous-batching scheduler, two backends.
//!
//! ```text
//!      requests ──► Scheduler<M: ForwardModel>          (scheduler.rs)
//!                   ├── Router        admission + FIFO
//!                   ├── BatchPolicy   size-or-timeout batch formation
//!                   ├── Sampler       greedy / seeded temperature
//!                   └── lane + TTFT/retirement bookkeeping
//!                         │  ForwardModel trait:
//!                         │  prefill(compiled, reqs) -> admitted lanes
//!                         │  decode_step(tokens, pos) -> logits
//!                         │  release(lane)
//!            ┌────────────┴────────────┐
//!      Engine (engine.rs)        EpEngine (ep.rs)
//!      monolithic single-device  disaggregated expert-parallel
//!      fused prefill_b{B}/       leader drives the dense backbone,
//!      decode_b{B} programs,     fabric workers run expert FFNs;
//!      zero-copy lane splicing   split-phase MoE, microbatch
//!      + KV literal mirror       pipelining, masked dead lanes
//! ```
//!
//! * [`Scheduler`] — engine-agnostic continuous batching: admit → prefill
//!   splice → decode → retire, the loop §5 of the paper treats as one
//!   system.  Owns sampling and all request bookkeeping; metric names are
//!   those of the pre-refactor engine plus `queue_depth` / `lanes_busy`
//!   gauges and the `decode_utilization` summary.
//! * [`engine::Engine`] — single-device backend over the monolithic AOT
//!   programs (fused Pallas kernels inside): the baseline the paper's
//!   single-GPU numbers correspond to.
//! * [`ep::EpEngine`] — the disaggregated expert-parallel backend (§5's
//!   architecture: gate → group tokens by expert → all-to-all → expert
//!   FFN → return & combine), with split-phase MoE and cross-layer
//!   microbatch pipelining.  Also usable standalone through its legacy
//!   fixed-lane `forward_prefill` / `forward_decode` API.
//!
//! Both backends produce identical logits for identical weights/input —
//! the parity tests in `rust/tests/integration_parity.rs` (including the
//! scheduler-vs-fixed-lane token parity test) are the end-to-end
//! correctness anchor of the whole stack.
//!
//! ## Env toggles (expert-parallel data path)
//!
//! | variable               | effect                                      |
//! |------------------------|---------------------------------------------|
//! | `DSMOE_SERIAL_MOE`     | serialized per-expert MoE path (pre-overlap |
//! |                        | baseline); also disables the pipeline.      |
//! | `DSMOE_NO_PIPELINE`    | per-layer overlapped path (no microbatch    |
//! |                        | interleaving).                              |
//! | `DSMOE_NO_CACHE_MIRROR`| monolithic engine: host round trip of the   |
//! |                        | KV cache every decode step (pre-mirror      |
//! |                        | baseline, §Perf).                           |

pub mod engine;
pub mod ep;
pub mod scheduler;

pub use engine::Engine;
pub use ep::{EpEngine, InflightMoe};
pub use scheduler::{ttft_percentile, AdmittedLane, ForwardModel, Scheduler};
