//! Leader shards: the dense backbone on N runtime threads.
//!
//! DeepSpeed-MoE's inference design (§5) parallelizes the *dense* part of
//! the model as well as the experts — the dense backbone is never a single
//! serial thread of execution.  The depth-N pipeline ring (PR 4) hides
//! leader compute behind fabric round trips, but attention/gate/combine of
//! different microbatches still serialized on the one leader thread.  This
//! module removes that serialization:
//!
//! * [`Backbone`] — every dense computation of the expert-parallel leader
//!   (embedding, attention, gate + routing + coalesced pack, dense FFN,
//!   PR-MoE residual branch, combine, LM head) bound to **one** runtime
//!   thread.  The [`crate::server::EpEngine`] owns one for its own thread;
//!   each leader shard owns another, materialized from the same
//!   [`SharedArtifacts`].  Because the leader and the shards execute the
//!   *same* `Backbone` methods on the same program shapes, the sharded
//!   schedule is bit-identical to the single-threaded one by construction.
//! * [`ShardPool`] — one OS thread per pipeline microbatch group (the
//!   same pattern as the fabric workers: thread-bound `Runtime`, channel
//!   protocol, joined on drop).  A shard owns its group's KV caches and
//!   host mirrors; the engine talks to it through [`ShardCmd`] /
//!   [`ShardEvent`] channels.  Expert exchanges stay centralized: a shard
//!   *prepares* the coalesced per-worker payloads ([`PreparedBatch`]) and
//!   hands them to the orchestrating engine, which owns the fabric, tags
//!   the exchange, dispatches it, and routes the collected replies back —
//!   preserving the ring's dispatch/finish order over tagged channels.
//!
//! Per-forward timers: `leader_par` (each shard's busy compute time — the
//! work that now runs concurrently across shards) and `shard_idle` (each
//! shard's exposed wait for expert replies).  With `leader_threads = 1`
//! the engine never constructs a pool and nothing here runs.
//!
//! **Failure model.**  Shards are leader-side threads, not fabric workers:
//! a shard panic or channel break is a *leader* failure and fails the
//! forward loudly and coherently (the pool joins on drop; see
//! `leader_shard_and_fabric_threads_join_on_drop`).  The fault-tolerance
//! path (`DSMOE_FAULT_TOLERANCE`, PR 10) covers *worker* death/hangs only
//! and is exercised with `leader_threads = 1`; composing mid-protocol
//! shard state with worker failover is deliberately out of scope — a
//! fault surfacing while a shard holds prepared-but-undispatched batches
//! propagates as an ordinary error rather than being retried.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{AllToAllKind, ModelConfig};
use crate::coordinator::alltoall::{self, Topology};
use crate::coordinator::gate::Routing;
use crate::coordinator::kv_cache::copy_lane;
use crate::coordinator::{LayerPlacement, Placement};
use crate::fabric::FfnBatchResult;
use crate::metrics::Metrics;
use crate::runtime::{
    Dtype, HostTensor, Manifest, Program, Runtime, SharedArtifacts,
};

use super::ep::LaneGroupCaches;

/// Routing pack/combine scratch reused across MoE layers (and forwards) so
/// the hot path does not reallocate its staging buffers per layer.  The
/// engine keeps one slot per pipeline microbatch plus one for a staged
/// admission; each leader shard keeps its own.
#[derive(Default)]
pub(crate) struct MoeScratch {
    /// `[T * M]` combine accumulation buffer.
    pub(crate) combine: Vec<f32>,
    /// Per-worker `(expert, first slot, rows)` segment lists for the
    /// current layer (one full-block segment per expert when hot-expert
    /// replication is off).
    pub(crate) worker_experts: Vec<Vec<(usize, usize, usize)>>,
}

/// One worker's coalesced expert payload, prepared but not yet tagged or
/// put on the fabric — the side that owns the fabric assigns the exchange
/// tag and dispatches.
pub(crate) struct PreparedBatch {
    pub(crate) worker: usize,
    /// `(expert id, first slot, row count)` in packed order.  The slot
    /// origin is nonzero only when hot-expert replication split this
    /// expert's block across workers.
    pub(crate) experts: Vec<(usize, usize, usize)>,
    /// `[total_rows, M]` packed activation rows.
    pub(crate) data: HostTensor,
}

/// Result of [`Backbone::ffn_prepare`]: a dense FFN that completed locally,
/// or a fully prepared MoE exchange awaiting expert replies.
pub(crate) enum Prepared {
    Dense { out: xla::Literal, elapsed: std::time::Duration },
    Moe(Box<PreparedMoe>),
}

/// Everything phase 5 (combine) needs once the expert replies arrive.
/// (The layer index travels alongside, in the caller's own state — the
/// engine's `InflightMoe` / the shard's loop variable.)
pub(crate) struct PreparedMoe {
    /// Original `h` dims, restored on combine.
    pub(crate) shape: Vec<usize>,
    pub(crate) routing: Routing,
    /// Per-worker payloads; taken by the dispatching side.
    pub(crate) batches: Vec<PreparedBatch>,
    /// PR-MoE fixed-branch output, if the model has one.
    pub(crate) residual: Option<Vec<f32>>,
    /// Residual stream pulled to the host (combine accumulates into it).
    pub(crate) out_data: Vec<f32>,
    /// Taken from the caller's [`MoeScratch`], returned at combine.
    pub(crate) worker_experts: Vec<Vec<(usize, usize, usize)>>,
    /// Leader time spent in the dispatch half (gate → leader overlap).
    pub(crate) dispatch_elapsed: std::time::Duration,
}

/// The dense backbone bound to one runtime thread: AOT programs compiled
/// on this thread's PJRT client, dense weight literals materialized from
/// the shared artifact set, and every dense computation of the
/// expert-parallel leader as a method.  One instance per thread — the
/// engine's own, plus one per leader shard.
pub(crate) struct Backbone {
    rt: Runtime,
    pub(crate) cfg: ModelConfig,
    arts: SharedArtifacts,
    params: HashMap<String, xla::Literal>,
    progs: HashMap<String, Rc<Program>>,
    /// Current expert placement epoch.  Mutated only between forwards
    /// (engine setter / [`ShardCmd::SetPlacement`]), never mid-exchange.
    pub(crate) placement: Placement,
    /// `DSMOE_REPLICATE_HOT`: split a replicated expert's token block
    /// across its hosting workers instead of sending it all to replica
    /// group 0's owner.  Off ⇒ the pack is byte-identical to the static
    /// single-owner path.
    pub(crate) replicate_hot: bool,
    /// Bench/test hook ([`crate::server::EpEngine::set_route_pin`]):
    /// route every live token to this expert instead of the gate's
    /// argmax — a deterministic worst-case hot-expert workload.
    pub(crate) force_expert: Option<usize>,
    /// `DSMOE_WIRE_DTYPE`: activation dtype of dispatch payloads (replies
    /// come back in the same dtype).  `Dtype::F32` (default) keeps the
    /// pack/combine path bitwise identical to the uncompressed engine.
    pub(crate) wire_dtype: Dtype,
    alltoall: AllToAllKind,
    /// Fabric worker count (sizes the per-worker pack lists).
    workers: usize,
    /// Hierarchical node size for plan accounting, derived once per thread
    /// by the single shared parser (`Topology::node_size_from_env`) —
    /// never a hard-coded 8.
    node_size: usize,
    pub(crate) metrics: Arc<Metrics>,
}

impl Backbone {
    pub(crate) fn new(
        arts: SharedArtifacts,
        cfg: ModelConfig,
        placement: Placement,
        alltoall: AllToAllKind,
        workers: usize,
        metrics: Arc<Metrics>,
    ) -> Result<Backbone> {
        let rt = Runtime::cpu()?;
        let params = arts.materialize_dense_params()?;
        let node_size = Topology::node_size_from_env(workers);
        Ok(Backbone {
            rt,
            cfg,
            arts,
            params,
            progs: HashMap::new(),
            placement,
            replicate_hot: false,
            force_expert: None,
            wire_dtype: Dtype::F32,
            alltoall,
            workers,
            node_size,
            metrics,
        })
    }

    pub(crate) fn prog(&mut self, key: &str) -> Result<Rc<Program>> {
        if let Some(p) = self.progs.get(key) {
            return Ok(p.clone());
        }
        let spec = self.arts.manifest().shared_program(key)?;
        let p = self.rt.load(spec)?;
        self.progs.insert(key.to_string(), p.clone());
        Ok(p)
    }

    pub(crate) fn p(&self, name: &str) -> &xla::Literal {
        &self.params[name]
    }

    /// Token+position embedding for a prefill microbatch `[lanes, smax]`.
    pub(crate) fn embed_prefill(
        &mut self,
        tokens: &[i32],
        lanes: usize,
    ) -> Result<xla::Literal> {
        let (v, m, smax) =
            (self.cfg.vocab_size, self.cfg.d_model, self.cfg.max_seq);
        let embed = self.prog(&Manifest::key_embed(v, m, lanes, smax))?;
        let tok =
            HostTensor::i32(&[lanes, smax], tokens.to_vec()).to_literal()?;
        let pos0 = HostTensor::i32(&[lanes], vec![0; lanes]).to_literal()?;
        Ok(embed
            .run_literal_refs(&[
                self.p("tok_emb"),
                self.p("pos_emb"),
                &tok,
                &pos0,
            ])?
            .remove(0))
    }

    /// Token+position embedding for one decode step `[lanes, 1]` at
    /// per-lane positions.
    pub(crate) fn embed_decode(
        &mut self,
        tokens: &[i32],
        pos: &xla::Literal,
        lanes: usize,
    ) -> Result<xla::Literal> {
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);
        let embed = self.prog(&Manifest::key_embed(v, m, lanes, 1))?;
        let tok =
            HostTensor::i32(&[lanes, 1], tokens.to_vec()).to_literal()?;
        Ok(embed
            .run_literal_refs(&[
                self.p("tok_emb"),
                self.p("pos_emb"),
                &tok,
                pos,
            ])?
            .remove(0))
    }

    pub(crate) fn attn_prefill(
        &mut self,
        layer: usize,
        h: xla::Literal,
        lanes: usize,
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let (m, hh, smax) =
            (self.cfg.d_model, self.cfg.n_heads, self.cfg.max_seq);
        let prog =
            self.prog(&Manifest::key_attn_prefill(m, hh, lanes, smax))?;
        let pre = format!("layer{layer}.");
        let mut outs = prog.run_literal_refs(&[
            &h,
            self.p(&format!("{pre}ln1.g")),
            self.p(&format!("{pre}ln1.b")),
            self.p(&format!("{pre}attn.wq")),
            self.p(&format!("{pre}attn.wk")),
            self.p(&format!("{pre}attn.wv")),
            self.p(&format!("{pre}attn.wo")),
        ])?;
        let vv = outs.pop().unwrap();
        let k = outs.pop().unwrap();
        let h2 = outs.pop().unwrap();
        Ok((h2, k, vv))
    }

    /// One decode-attention step; the caller owns the KV caches and
    /// installs the returned updated literals.
    pub(crate) fn attn_decode(
        &mut self,
        layer: usize,
        h: xla::Literal,
        pos: &xla::Literal,
        lanes: usize,
        k: &xla::Literal,
        v: &xla::Literal,
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let (m, hh, smax) =
            (self.cfg.d_model, self.cfg.n_heads, self.cfg.max_seq);
        let prog =
            self.prog(&Manifest::key_attn_decode(m, hh, lanes, smax))?;
        let pre = format!("layer{layer}.");
        let mut outs = prog.run_literal_refs(&[
            &h,
            self.p(&format!("{pre}ln1.g")),
            self.p(&format!("{pre}ln1.b")),
            self.p(&format!("{pre}attn.wq")),
            self.p(&format!("{pre}attn.wk")),
            self.p(&format!("{pre}attn.wv")),
            self.p(&format!("{pre}attn.wo")),
            k,
            v,
            pos,
        ])?;
        let vc = outs.pop().unwrap();
        let kc = outs.pop().unwrap();
        let h2 = outs.pop().unwrap();
        Ok((h2, kc, vc))
    }

    /// FFN sublayer, phases 1–3 of the split-phase MoE (gate → coalesced
    /// per-worker pack → leader-overlap work), minus the fabric sends —
    /// the caller owns tags and the fabric.  Dense FFN layers complete
    /// here.  `mask` marks live tokens (None = all live); dead tokens are
    /// excluded from gate routing and expert dispatch.  Load-stats
    /// recording stays with the code that owns the stats (the engine or
    /// the shard orchestrator), not here.
    pub(crate) fn ffn_prepare(
        &mut self,
        layer: usize,
        h: xla::Literal,
        mask: Option<&[bool]>,
        scratch: &mut MoeScratch,
    ) -> Result<Prepared> {
        let (m, f) = (self.cfg.d_model, self.cfg.d_ff);
        let pre = format!("layer{layer}.");
        let n_experts = self.cfg.experts_at(layer);
        let t_layer = std::time::Instant::now();
        let shape: Vec<usize> = h
            .array_shape()?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect();
        let t_tokens: usize = shape.iter().product::<usize>() / m;

        if n_experts == 0 {
            let prog = self.prog(&Manifest::key_dense_ffn(m, f, t_tokens))?;
            // dense_ffn operates on [1, T, M]: reshape at the literal
            // level instead of a literal->host->literal round trip.
            let orig_dims: Vec<i64> =
                shape.iter().map(|&d| d as i64).collect();
            let flat = h.reshape(&[1, t_tokens as i64, m as i64])?;
            let out = prog
                .run_literal_refs(&[
                    &flat,
                    self.p(&format!("{pre}ln2.g")),
                    self.p(&format!("{pre}ln2.b")),
                    self.p(&format!("{pre}mlp.w1")),
                    self.p(&format!("{pre}mlp.b1")),
                    self.p(&format!("{pre}mlp.w2")),
                    self.p(&format!("{pre}mlp.b2")),
                ])?
                .remove(0);
            return Ok(Prepared::Dense {
                out: out.reshape(&orig_dims)?,
                elapsed: t_layer.elapsed(),
            });
        }

        // Phase 1: gate.  [B,S,M] -> [1,T,M] is a literal reshape; only
        // ln(h) and the router probabilities come back to the host (the
        // routing tables need them).
        let t0 = std::time::Instant::now();
        let gate = self.prog(&Manifest::key_gate(m, n_experts, t_tokens))?;
        let flat = h.reshape(&[1, t_tokens as i64, m as i64])?;
        let outs = gate.run_literal_refs(&[
            &flat,
            self.p(&format!("{pre}ln2.g")),
            self.p(&format!("{pre}ln2.b")),
            self.p(&format!("{pre}moe.gate")),
        ])?;
        let ln_h = HostTensor::from_literal(&outs[0])?; // [T, M]
        let probs = HostTensor::from_literal(&outs[1])?; // [T, E]
        self.metrics.observe("gate", t0.elapsed());

        // Dead lanes (retired/free under continuous batching) are masked
        // out of routing here, so they take no expert slot and send no
        // expert traffic.
        let routing = match self.force_expert {
            Some(pin) if pin < n_experts => {
                Routing::pinned_masked(probs.as_f32()?, n_experts, mask, pin)
            }
            _ => Routing::top1_masked(probs.as_f32()?, n_experts, mask),
        };

        // Phase 2: coalesced pack — one payload per hosting worker.
        // Without replication every expert is one full block on its
        // replica-0 owner (slot origin 0 — byte-identical to the static
        // path).  With `replicate_hot` a replicated expert's block is
        // split contiguously across every hosting worker (ceil/floor so
        // replicas differ by at most one row); replicas hold identical
        // weights, so the per-token results are bitwise-equal however
        // the block is split.
        let t1 = std::time::Instant::now();
        let mut worker_experts = std::mem::take(&mut scratch.worker_experts);
        for list in &mut worker_experts {
            list.clear();
        }
        if worker_experts.len() < self.workers {
            worker_experts.resize(self.workers, Vec::new());
        }
        {
            let lp = self.placement.layer(layer).unwrap();
            for e in 0..n_experts {
                let c = routing.counts[e];
                if c == 0 {
                    continue;
                }
                if self.replicate_hot {
                    let replicas = lp.replicas_of(e);
                    let r = replicas.len();
                    let (base, rem) = (c / r, c % r);
                    let mut slot0 = 0usize;
                    for (i, &w) in replicas.iter().enumerate() {
                        let rows = base + usize::from(i < rem);
                        if rows == 0 {
                            continue;
                        }
                        worker_experts[w].push((e, slot0, rows));
                        slot0 += rows;
                    }
                } else {
                    worker_experts[lp.owner(e, 0)].push((e, 0, c));
                }
            }
        }
        let ln_flat = ln_h.as_f32()?;
        let mut batches = Vec::new();
        for (w, segs) in worker_experts.iter().enumerate() {
            if segs.is_empty() {
                continue;
            }
            // Packed straight into the wire dtype: f32 (default) is the
            // exact pack_segments rows; f16/bf16 narrow once here and the
            // worker replies in kind.
            let data =
                routing.pack_segments_wire(ln_flat, m, segs, self.wire_dtype)?;
            batches.push(PreparedBatch {
                worker: w,
                experts: segs.clone(),
                data,
            });
        }
        self.metrics.observe("dispatch", t1.elapsed());

        // Phase 3: leader overlap — everything that does not depend on
        // the expert outputs: all-to-all plan accounting, the PR-MoE
        // fixed residual branch, and the combine buffer prep.
        let t2 = std::time::Instant::now();
        let plan = {
            let lp = self.placement.layer(layer).unwrap();
            self.exchange_plan(&routing, lp, m)
        };
        self.metrics.inc("alltoall_bytes", plan.volume() as u64);
        self.metrics.inc("alltoall_hops", plan.hops() as u64);
        let residual: Option<Vec<f32>> = if self.cfg.residual {
            let rb =
                self.prog(&Manifest::key_residual_branch(m, f, t_tokens))?;
            let out = rb
                .run_literal_refs(&[
                    &outs[0], // ln(h) [T, M], no host round trip
                    self.p(&format!("{pre}moe.res.w1")),
                    self.p(&format!("{pre}moe.res.b1")),
                    self.p(&format!("{pre}moe.res.w2")),
                    self.p(&format!("{pre}moe.res.b2")),
                ])?
                .remove(0);
            Some(out.to_vec::<f32>()?)
        } else {
            None
        };
        // Combine prep: the residual stream, pulled to the host once (the
        // [1,T,M] reshape shares h's row-major element order).
        let out_data: Vec<f32> = flat.to_vec()?;
        self.metrics.observe("leader_overlap", t2.elapsed());

        Ok(Prepared::Moe(Box::new(PreparedMoe {
            shape,
            routing,
            batches,
            residual,
            out_data,
            worker_experts,
            dispatch_elapsed: t_layer.elapsed(),
        })))
    }

    /// Phase 5 of the split-phase MoE: combine the packed expert replies
    /// (gate-scale, un-permute), then add the residual branch and the
    /// residual stream — the same op order as the serial path, so every
    /// schedule is bit-identical.
    pub(crate) fn moe_combine(
        &mut self,
        shape: &[usize],
        routing: &Routing,
        residual: Option<&[f32]>,
        mut out_data: Vec<f32>,
        results: &[FfnBatchResult],
        combine: &mut Vec<f32>,
    ) -> Result<xla::Literal> {
        let t4 = std::time::Instant::now();
        {
            // Wire-aware combine: f32 replies are borrowed (bitwise path),
            // f16/bf16 replies are widened once.
            let packs: Vec<(&[(usize, usize, usize)], &HostTensor)> = results
                .iter()
                .map(|r| (r.experts.as_slice(), &r.data))
                .collect();
            routing.combine_packed_wire(&packs, self.cfg.d_model, combine)?;
        }
        if let Some(res) = residual {
            for (c, r) in combine.iter_mut().zip(res) {
                *c += *r;
            }
        }
        for (o, c) in out_data.iter_mut().zip(combine.iter()) {
            *o += *c;
        }
        let out = HostTensor::f32(shape, out_data).to_literal()?;
        self.metrics.observe("combine", t4.elapsed());
        Ok(out)
    }

    /// Build the all-to-all byte matrix this routing implies at the
    /// layer's EP degree (tokens sharded round-robin over workers, as
    /// they would be when each worker owns part of the batch) and plan it
    /// with the configured schedule.  The destination is derived from the
    /// placement — not `e % ep` — so migrated/replicated layouts are
    /// accounted where the tokens actually go.
    pub(crate) fn exchange_plan(
        &self,
        routing: &Routing,
        lp: &LayerPlacement,
        m: usize,
    ) -> alltoall::Plan {
        let ep = lp.ep_degree;
        let owners: Vec<usize> =
            (0..routing.n_experts).map(|e| lp.owner(e, 0) % ep).collect();
        let mut bytes = vec![vec![0usize; ep]; ep];
        for (t, &e) in routing.expert.iter().enumerate() {
            if e >= routing.n_experts {
                continue; // masked token (dead lane): no exchange traffic
            }
            let src = t % ep; // token's home shard
            let dst = owners[e]; // expert's host, placement-derived
            if src != dst {
                bytes[src][dst] += m * 4;
            }
        }
        let topo = Topology {
            workers: ep,
            node_size: self.node_size.min(ep).max(1),
            ts_degree: 1,
        };
        alltoall::plan(self.alltoall, topo, &bytes)
    }

    /// LM head over each lane's last real position.  `h` is
    /// `[lanes, smax, M]`; the last-position rows are gathered **at the
    /// literal level** by the `gather_last_*` AOT program; artifact sets
    /// predating that program fall back to a host-side gather.
    pub(crate) fn lm_head_last(
        &mut self,
        h: &xla::Literal,
        lens: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let (m, smax) = (self.cfg.d_model, self.cfg.max_seq);
        let lanes = lens.len();
        let key = Manifest::key_gather_last(m, lanes, smax);
        let last = if self.arts.manifest().shared_program(&key).is_ok() {
            let gather = self.prog(&key)?;
            let lens_lit = HostTensor::i32(
                &[lanes],
                lens.iter().map(|&l| l as i32).collect(),
            )
            .to_literal()?;
            gather.run_literal_refs(&[h, &lens_lit])?.remove(0)
        } else {
            let hd: Vec<f32> = h.to_vec()?;
            let mut last = vec![0f32; lanes * m];
            for lane in 0..lanes {
                let p = lens[lane].max(1) - 1;
                let off = (lane * smax + p) * m;
                last[lane * m..(lane + 1) * m]
                    .copy_from_slice(&hd[off..off + m]);
            }
            HostTensor::f32(&[lanes, m], last).to_literal()?
        };
        self.lm_head_rows(&last, lanes)
    }

    /// LM head over `[lanes, M]` hidden rows, fed straight from the
    /// literal; returns one logits row per lane.
    pub(crate) fn lm_head_rows(
        &mut self,
        h: &xla::Literal,
        lanes: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);
        let prog = self.prog(&Manifest::key_lm_head(v, m, lanes))?;
        let out = prog
            .run_literal_refs(&[
                h,
                self.p("lnf.g"),
                self.p("lnf.b"),
                self.p("tok_emb"),
            ])?
            .remove(0);
        let data: Vec<f32> = out.to_vec()?;
        Ok((0..lanes)
            .map(|lane| data[lane * v..(lane + 1) * v].to_vec())
            .collect())
    }
}

/// One lane's per-layer KV data crossing the engine↔shard boundary
/// (admission splices, regroup moves).
pub(crate) struct LaneWrite {
    pub(crate) layer: usize,
    /// In-group lane offset.
    pub(crate) lane: usize,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
}

/// Commands the engine sends to a leader shard.
pub(crate) enum ShardCmd {
    /// Full prefill over this shard's lane group; rebuilds its KV caches
    /// and replies [`ShardEvent::PrefillDone`] with last-position logits.
    Prefill { tokens: Vec<i32>, lens: Vec<usize> },
    /// One decode step over the group's lanes; replies
    /// [`ShardEvent::DecodeDone`].
    Decode { tokens: Vec<i32>, pos: Vec<i32>, mask: Option<Vec<bool>> },
    /// Collected expert replies for the exchange the shard is waiting on
    /// (matched by the shard-local `seq`).
    MoeReplies { seq: u64, results: Vec<FfnBatchResult> },
    /// Pull per-layer host copies of the given in-group lanes
    /// (→ [`ShardEvent::Lanes`]).
    ReadLanes { lanes: Vec<usize> },
    /// Write per-layer lane data through the host mirrors
    /// (→ [`ShardEvent::Ack`]).
    WriteLanes { writes: Vec<LaneWrite> },
    /// Hand the whole cache group back as host tensors
    /// (→ [`ShardEvent::Caches`]); the shard keeps nothing.
    TakeCaches,
    /// Install a cache group from host tensors (→ [`ShardEvent::Ack`]).
    InstallCaches { layers: Vec<(HostTensor, HostTensor)> },
    /// Swap the metrics registry (benches reset between warmup and the
    /// measured run).
    SetMetrics(Arc<Metrics>),
    /// Install a new placement epoch (hot-expert replication / migration).
    /// Sent only between forwards — channel ordering guarantees it applies
    /// before the next Prefill/Decode, so no in-flight exchange ever sees
    /// a torn placement.
    SetPlacement { placement: Placement, replicate_hot: bool },
    /// Switch the activation wire dtype (`DSMOE_WIRE_DTYPE`).  Sent only
    /// between forwards, like `SetPlacement` — no in-flight exchange ever
    /// mixes wire dtypes.
    SetWireDtype(Dtype),
    Shutdown,
}

/// Events a leader shard sends back on the shared orchestrator channel.
pub(crate) enum ShardEvent {
    /// The shard's next MoE exchange is prepared: the orchestrator tags
    /// it, puts it on the fabric, and later answers with
    /// [`ShardCmd::MoeReplies`].  `assignments` carries the routing's
    /// per-token expert ids for the engine-side load stats.
    MoeDispatch {
        shard: usize,
        seq: u64,
        layer: usize,
        batches: Vec<PreparedBatch>,
        assignments: Vec<usize>,
    },
    PrefillDone { shard: usize, rows: Vec<Vec<f32>> },
    DecodeDone { shard: usize, rows: Vec<Vec<f32>> },
    Lanes { shard: usize, writes: Vec<LaneWrite> },
    Caches { shard: usize, layers: Vec<(HostTensor, HostTensor)> },
    Ack { shard: usize },
    Err { shard: usize, msg: String },
}

pub(crate) struct ShardHandle {
    /// `None` once shut down — dropping the sender is what unblocks a
    /// shard that was interrupted mid-forward.
    tx: Option<Sender<ShardCmd>>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Everything a shard thread needs to build its own [`Backbone`].
pub(crate) struct PoolSpec {
    pub(crate) groups: Vec<(usize, usize)>,
    pub(crate) arts: SharedArtifacts,
    pub(crate) cfg: ModelConfig,
    pub(crate) placement: Placement,
    pub(crate) replicate_hot: bool,
    pub(crate) wire_dtype: Dtype,
    pub(crate) alltoall: AllToAllKind,
    pub(crate) workers: usize,
    pub(crate) metrics: Arc<Metrics>,
    /// Test-only slow-shard injection: (shard index, per-layer delay).
    pub(crate) slow_shard: Option<(usize, std::time::Duration)>,
}

/// One OS thread per pipeline microbatch group, each owning its own
/// runtime-bound [`Backbone`] and its group's KV caches.  Threads are
/// joined on [`ShardPool::shutdown`] / `Drop` — no leaked OS threads
/// across engines or tests.
pub(crate) struct ShardPool {
    pub(crate) handles: Vec<ShardHandle>,
    pub(crate) events: Receiver<ShardEvent>,
    pub(crate) groups: Vec<(usize, usize)>,
}

impl ShardPool {
    pub(crate) fn spawn(spec: PoolSpec) -> Result<ShardPool> {
        anyhow::ensure!(!spec.groups.is_empty(), "empty shard partition");
        let (event_tx, events) = channel::<ShardEvent>();
        let mut handles = Vec::with_capacity(spec.groups.len());
        for (idx, &(lane0, lanes)) in spec.groups.iter().enumerate() {
            let (tx, rx) = channel::<ShardCmd>();
            let event_tx = event_tx.clone();
            let arts = spec.arts.clone();
            let cfg = spec.cfg.clone();
            let placement = spec.placement.clone();
            let replicate_hot = spec.replicate_hot;
            let wire_dtype = spec.wire_dtype;
            let (alltoall, workers) = (spec.alltoall, spec.workers);
            let metrics = spec.metrics.clone();
            let slow = spec
                .slow_shard
                .and_then(|(s, d)| (s == idx).then_some(d));
            let join = std::thread::Builder::new()
                .name(format!("dsmoe-shard-{idx}"))
                .spawn(move || {
                    shard_main(
                        idx, lane0, lanes, arts, cfg, placement,
                        replicate_hot, wire_dtype, alltoall, workers, metrics,
                        slow, rx, event_tx,
                    )
                })
                .context("spawning leader shard")?;
            handles.push(ShardHandle { tx: Some(tx), join: Some(join) });
        }
        Ok(ShardPool { handles, events, groups: spec.groups })
    }

    pub(crate) fn send(&self, shard: usize, cmd: ShardCmd) -> Result<()> {
        self.handles[shard]
            .tx
            .as_ref()
            .with_context(|| format!("leader shard {shard} shut down"))?
            .send(cmd)
            .map_err(|_| anyhow::anyhow!("leader shard {shard} gone"))
    }

    /// Await shard `shard`'s `Ack` (cache surgery is strictly
    /// request/reply per shard, so nothing else can be in flight).
    pub(crate) fn expect_ack(&self, shard: usize) -> Result<()> {
        match self.events.recv() {
            Ok(ShardEvent::Ack { shard: s }) if s == shard => Ok(()),
            Ok(ShardEvent::Err { shard: s, msg }) => {
                anyhow::bail!("leader shard {s}: {msg}")
            }
            Ok(_) => anyhow::bail!(
                "unexpected shard event while awaiting ack from shard \
                 {shard}"
            ),
            Err(_) => anyhow::bail!("leader shards disconnected"),
        }
    }

    pub(crate) fn expect_lanes(
        &self,
        shard: usize,
    ) -> Result<Vec<LaneWrite>> {
        match self.events.recv() {
            Ok(ShardEvent::Lanes { shard: s, writes }) if s == shard => {
                Ok(writes)
            }
            Ok(ShardEvent::Err { shard: s, msg }) => {
                anyhow::bail!("leader shard {s}: {msg}")
            }
            Ok(_) => anyhow::bail!(
                "unexpected shard event while awaiting lanes from shard \
                 {shard}"
            ),
            Err(_) => anyhow::bail!("leader shards disconnected"),
        }
    }

    pub(crate) fn expect_caches(
        &self,
        shard: usize,
    ) -> Result<Vec<(HostTensor, HostTensor)>> {
        match self.events.recv() {
            Ok(ShardEvent::Caches { shard: s, layers }) if s == shard => {
                Ok(layers)
            }
            Ok(ShardEvent::Err { shard: s, msg }) => {
                anyhow::bail!("leader shard {s}: {msg}")
            }
            Ok(_) => anyhow::bail!(
                "unexpected shard event while awaiting caches from shard \
                 {shard}"
            ),
            Err(_) => anyhow::bail!("leader shards disconnected"),
        }
    }

    /// Close every shard's command channel and join the threads.  The
    /// explicit `Shutdown` is the clean exit for idle shards; *dropping*
    /// the senders is what unblocks a shard interrupted mid-forward (its
    /// next `recv` disconnects instead of waiting forever), so the joins
    /// below can never deadlock.
    pub(crate) fn shutdown(&mut self) {
        for h in &mut self.handles {
            if let Some(tx) = h.tx.take() {
                let _ = tx.send(ShardCmd::Shutdown);
            }
        }
        for h in &mut self.handles {
            if let Some(j) = h.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_main(
    idx: usize,
    lane0: usize,
    lanes: usize,
    arts: SharedArtifacts,
    cfg: ModelConfig,
    placement: Placement,
    replicate_hot: bool,
    wire_dtype: Dtype,
    alltoall: AllToAllKind,
    workers: usize,
    metrics: Arc<Metrics>,
    slow: Option<std::time::Duration>,
    rx: Receiver<ShardCmd>,
    tx: Sender<ShardEvent>,
) {
    let n_layers = cfg.n_layers;
    let lane_elems = cfg.n_heads * cfg.max_seq * cfg.head_dim();
    let mut bb =
        match Backbone::new(arts, cfg, placement, alltoall, workers, metrics)
        {
            Ok(b) => b,
            Err(e) => {
                let _ = tx.send(ShardEvent::Err {
                    shard: idx,
                    msg: format!("backbone init: {e:#}"),
                });
                return;
            }
        };
    bb.replicate_hot = replicate_hot;
    bb.wire_dtype = wire_dtype;
    let mut caches: Option<LaneGroupCaches> = None;
    let mut scratch = MoeScratch::default();
    let mut seq = 0u64;

    // Error handling: every fallible command reports through an Err event
    // and the shard keeps serving — fatal decisions belong to the engine.
    while let Ok(cmd) = rx.recv() {
        match cmd {
            ShardCmd::Shutdown => break,
            ShardCmd::SetMetrics(m) => bb.metrics = m,
            ShardCmd::SetPlacement { placement, replicate_hot } => {
                bb.placement = placement;
                bb.replicate_hot = replicate_hot;
            }
            ShardCmd::SetWireDtype(d) => bb.wire_dtype = d,
            ShardCmd::Prefill { tokens, lens } => {
                let r = shard_prefill(
                    &mut bb, idx, lane0, lanes, &tokens, &lens, &mut caches,
                    &mut scratch, &rx, &tx, &mut seq, slow,
                );
                let _ = match r {
                    Ok(rows) => {
                        tx.send(ShardEvent::PrefillDone { shard: idx, rows })
                    }
                    Err(e) => tx.send(ShardEvent::Err {
                        shard: idx,
                        msg: format!("{e:#}"),
                    }),
                };
            }
            ShardCmd::Decode { tokens, pos, mask } => {
                let r = shard_decode(
                    &mut bb, idx, lanes, &tokens, &pos, mask.as_deref(),
                    &mut caches, &mut scratch, &rx, &tx, &mut seq, slow,
                );
                let _ = match r {
                    Ok(rows) => {
                        tx.send(ShardEvent::DecodeDone { shard: idx, rows })
                    }
                    Err(e) => tx.send(ShardEvent::Err {
                        shard: idx,
                        msg: format!("{e:#}"),
                    }),
                };
            }
            ShardCmd::ReadLanes { lanes: which } => {
                let r =
                    read_lanes(&mut caches, &which, n_layers, lane_elems);
                let _ = match r {
                    Ok(writes) => {
                        tx.send(ShardEvent::Lanes { shard: idx, writes })
                    }
                    Err(e) => tx.send(ShardEvent::Err {
                        shard: idx,
                        msg: format!("{e:#}"),
                    }),
                };
            }
            ShardCmd::WriteLanes { writes } => {
                let r = write_lanes(&mut caches, &writes, lane_elems);
                let _ = match r {
                    Ok(()) => tx.send(ShardEvent::Ack { shard: idx }),
                    Err(e) => tx.send(ShardEvent::Err {
                        shard: idx,
                        msg: format!("{e:#}"),
                    }),
                };
            }
            ShardCmd::TakeCaches => {
                let r = take_caches(&mut caches, n_layers);
                let _ = match r {
                    Ok(layers) => {
                        tx.send(ShardEvent::Caches { shard: idx, layers })
                    }
                    Err(e) => tx.send(ShardEvent::Err {
                        shard: idx,
                        msg: format!("{e:#}"),
                    }),
                };
            }
            ShardCmd::InstallCaches { layers } => {
                let r = install_caches(
                    &mut caches, lane0, lanes, n_layers, layers,
                );
                let _ = match r {
                    Ok(()) => tx.send(ShardEvent::Ack { shard: idx }),
                    Err(e) => tx.send(ShardEvent::Err {
                        shard: idx,
                        msg: format!("{e:#}"),
                    }),
                };
            }
            ShardCmd::MoeReplies { .. } => {
                let _ = tx.send(ShardEvent::Err {
                    shard: idx,
                    msg: "expert replies with no exchange in flight"
                        .to_string(),
                });
            }
        }
    }
}

/// Pull per-layer host copies of the given in-group lanes out of the
/// shard's cache group (regroup source reads).
fn read_lanes(
    caches: &mut Option<LaneGroupCaches>,
    which: &[usize],
    n_layers: usize,
    lane_elems: usize,
) -> Result<Vec<LaneWrite>> {
    let g = caches.as_mut().context("shard has no caches")?;
    let mut out = Vec::with_capacity(n_layers * which.len());
    for layer in 0..n_layers {
        for &l in which {
            let k = {
                let hk = g.host_k(layer)?.as_f32()?;
                hk[l * lane_elems..(l + 1) * lane_elems].to_vec()
            };
            let v = {
                let hv = g.host_v(layer)?.as_f32()?;
                hv[l * lane_elems..(l + 1) * lane_elems].to_vec()
            };
            out.push(LaneWrite { layer, lane: l, k, v });
        }
    }
    Ok(out)
}

/// Write per-lane KV data through the host mirrors and re-upload the
/// touched layers (admission splices, regroup destinations).
fn write_lanes(
    caches: &mut Option<LaneGroupCaches>,
    writes: &[LaneWrite],
    lane_elems: usize,
) -> Result<()> {
    let g = caches.as_mut().context("shard has no caches")?;
    let mut touched: Vec<usize> = writes.iter().map(|w| w.layer).collect();
    for w in writes {
        let dk = g.host_k(w.layer)?.as_f32_mut()?;
        copy_lane(dk, w.lane, &w.k, 0, lane_elems);
        let dv = g.host_v(w.layer)?.as_f32_mut()?;
        copy_lane(dv, w.lane, &w.v, 0, lane_elems);
    }
    touched.sort_unstable();
    touched.dedup();
    for layer in touched {
        g.push_layer(layer)?;
    }
    Ok(())
}

/// Hand the whole cache group back as host tensors (cache migration to
/// the leader); the shard keeps nothing.
fn take_caches(
    caches: &mut Option<LaneGroupCaches>,
    n_layers: usize,
) -> Result<Vec<(HostTensor, HostTensor)>> {
    let mut g = caches.take().context("shard has no caches")?;
    let mut layers = Vec::with_capacity(n_layers);
    for layer in 0..n_layers {
        // Move the mirrors out instead of cloning — `g` is dropped at
        // the end of this call.
        layers.push(g.take_host(layer)?);
    }
    Ok(layers)
}

/// Install a cache group from host tensors (cache migration from the
/// leader).
fn install_caches(
    caches: &mut Option<LaneGroupCaches>,
    lane0: usize,
    lanes: usize,
    n_layers: usize,
    layers: Vec<(HostTensor, HostTensor)>,
) -> Result<()> {
    anyhow::ensure!(
        layers.len() == n_layers,
        "cache install: {} layers for a {n_layers}-layer model",
        layers.len()
    );
    let mut g = LaneGroupCaches::new(lane0, lanes, n_layers);
    for (k, v) in layers {
        g.push_host(k, v)?;
    }
    *caches = Some(g);
    Ok(())
}

/// FFN sublayer inside a shard: dense layers complete locally; MoE layers
/// hand the prepared exchange to the orchestrator and block until the
/// collected replies come back (that wait is the shard's exposed
/// `shard_idle`).
#[allow(clippy::too_many_arguments)]
fn shard_ffn(
    bb: &mut Backbone,
    idx: usize,
    layer: usize,
    h: xla::Literal,
    mask: Option<&[bool]>,
    scratch: &mut MoeScratch,
    rx: &Receiver<ShardCmd>,
    tx: &Sender<ShardEvent>,
    seq: &mut u64,
    idle: &mut std::time::Duration,
) -> Result<xla::Literal> {
    match bb.ffn_prepare(layer, h, mask, scratch)? {
        Prepared::Dense { out, .. } => Ok(out),
        Prepared::Moe(p) => {
            let PreparedMoe {
                shape,
                routing,
                batches,
                residual,
                out_data,
                worker_experts,
                dispatch_elapsed,
                ..
            } = *p;
            *seq += 1;
            tx.send(ShardEvent::MoeDispatch {
                shard: idx,
                seq: *seq,
                layer,
                batches,
                assignments: routing.assignments().to_vec(),
            })
            .map_err(|_| anyhow::anyhow!("orchestrator gone"))?;
            let t = std::time::Instant::now();
            let results = match rx.recv() {
                Ok(ShardCmd::MoeReplies { seq: s, results }) => {
                    anyhow::ensure!(
                        s == *seq,
                        "expert replies for exchange {s} while waiting on \
                         {}",
                        *seq
                    );
                    results
                }
                Ok(_) => anyhow::bail!(
                    "unexpected shard command while awaiting expert replies"
                ),
                Err(_) => {
                    anyhow::bail!("orchestrator channel closed mid-exchange")
                }
            };
            let wait = t.elapsed();
            *idle += wait;
            bb.metrics.observe("shard_idle", wait);
            let out = bb.moe_combine(
                &shape,
                &routing,
                residual.as_deref(),
                out_data,
                &results,
                &mut scratch.combine,
            )?;
            scratch.worker_experts = worker_experts;
            bb.metrics.observe("moe_layer", dispatch_elapsed + wait);
            Ok(out)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_prefill(
    bb: &mut Backbone,
    idx: usize,
    lane0: usize,
    lanes: usize,
    tokens: &[i32],
    lens: &[usize],
    caches: &mut Option<LaneGroupCaches>,
    scratch: &mut MoeScratch,
    rx: &Receiver<ShardCmd>,
    tx: &Sender<ShardEvent>,
    seq: &mut u64,
    slow: Option<std::time::Duration>,
) -> Result<Vec<Vec<f32>>> {
    let t_task = std::time::Instant::now();
    let mut idle = std::time::Duration::ZERO;
    let n_layers = bb.cfg.n_layers;
    let mut group = LaneGroupCaches::new(lane0, lanes, n_layers);
    let mut h = bb.embed_prefill(tokens, lanes)?;
    for layer in 0..n_layers {
        if let Some(d) = slow {
            std::thread::sleep(d);
        }
        let (h2, k, v) = bb.attn_prefill(layer, h, lanes)?;
        group.push_kv(k, v);
        // Legacy full prefill drives every lane: no mask.
        h = shard_ffn(
            bb, idx, layer, h2, None, scratch, rx, tx, seq, &mut idle,
        )?;
    }
    let rows = bb.lm_head_last(&h, lens)?;
    *caches = Some(group);
    // Busy compute only: the concurrent-dense-backbone time this shard
    // actually contributed (its waits are in shard_idle).
    bb.metrics
        .observe("leader_par", t_task.elapsed().saturating_sub(idle));
    Ok(rows)
}

#[allow(clippy::too_many_arguments)]
fn shard_decode(
    bb: &mut Backbone,
    idx: usize,
    lanes: usize,
    tokens: &[i32],
    pos: &[i32],
    mask: Option<&[bool]>,
    caches: &mut Option<LaneGroupCaches>,
    scratch: &mut MoeScratch,
    rx: &Receiver<ShardCmd>,
    tx: &Sender<ShardEvent>,
    seq: &mut u64,
    slow: Option<std::time::Duration>,
) -> Result<Vec<Vec<f32>>> {
    let t_task = std::time::Instant::now();
    let mut idle = std::time::Duration::ZERO;
    let n_layers = bb.cfg.n_layers;
    let m = bb.cfg.d_model;
    let group = caches
        .as_mut()
        .context("decode before the shard's caches were installed")?;
    let pos_lit = HostTensor::i32(&[lanes], pos.to_vec()).to_literal()?;
    let mut h = bb.embed_decode(tokens, &pos_lit, lanes)?;
    for layer in 0..n_layers {
        if let Some(d) = slow {
            std::thread::sleep(d);
        }
        let (h2, kc, vc) = bb.attn_decode(
            layer,
            h,
            &pos_lit,
            lanes,
            &group.k[layer],
            &group.v[layer],
        )?;
        group.k[layer] = kc;
        group.v[layer] = vc;
        // The decode write staled this layer's host mirror.
        group.invalidate(layer);
        h = shard_ffn(
            bb, idx, layer, h2, mask, scratch, rx, tx, seq, &mut idle,
        )?;
    }
    let flat = h.reshape(&[lanes as i64, m as i64])?;
    let rows = bb.lm_head_rows(&flat, lanes)?;
    bb.metrics
        .observe("leader_par", t_task.elapsed().saturating_sub(idle));
    Ok(rows)
}
