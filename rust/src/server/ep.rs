//! Disaggregated expert-parallel engine (§5's system, at testbed scale).
//!
//! The leader owns the dense backbone (embeddings, attention, layer norms,
//! gates, residual branches, LM head) and drives it layer by layer through
//! the shared AOT programs; fabric workers own the expert FFN weights per
//! the [`Placement`].
//!
//! ## Split-phase MoE
//!
//! Every MoE layer is driven through a two-call API instead of a monolithic
//! FFN call (per-phase latencies land in [`Metrics`] under these names):
//!
//! * [`EpEngine::moe_dispatch`]`(layer, h) -> InflightMoe` runs
//!   1. **`gate`** — the `gate_*` program produces `ln(h)` and router
//!      probabilities (`[B,S,M] → [1,T,M]` stays a literal-level reshape);
//!      host top-1 gating builds the dense token→expert mapping table
//!      ([`Routing`]);
//!   2. **`dispatch`** — token blocks coalesced per owning worker: one
//!      tagged [`crate::fabric::ExpertFfnBatch`] per worker carries all of
//!      that worker's expert blocks in one contiguous payload (the paper's
//!      grouped all-to-all, §5.1) — O(workers) messages per layer;
//!   3. **`leader_overlap`** — while the workers execute: all-to-all plan
//!      accounting, the PR-MoE fixed residual branch, and combine-buffer
//!      prep — then returns with the exchange still out on the fabric.
//! * [`EpEngine::moe_finish`]`(inflight) -> h'` runs
//!   4. **`expert_wait`** (or **`pipeline_bubble`** under the pipelined
//!      driver) — block on the coalesced tagged replies; and
//!   5. **`combine`** — gate-scale and un-permute the packed expert
//!      outputs, then add the residual branch and the residual stream.
//!
//! [`MoeScratch`] is an N-slot pool (one slot per pipeline microbatch plus
//! one for a staged admission prefill), so several tagged exchanges can be
//! in flight at once; a reply from any exchange that is neither being
//! collected nor still open fails loudly (tag-keyed collection in
//! [`crate::fabric::Fabric`]).
//!
//! ## Depth-N microbatch pipeline ring
//!
//! `forward_prefill`/`forward_decode` split the batch into
//! `N = DSMOE_PIPE_DEPTH` (default 2, [`EpEngine::set_pipe_depth`])
//! contiguous microbatch lane groups when the group-sized AOT shapes
//! exist, and drive them through a rotating in-flight ring
//! ([`EpEngine::run_pipeline`]): step `(layer, mb)` dispatches microbatch
//! `mb`'s attention + gate + dispatch; once N exchanges are on the fabric
//! the oldest — the same microbatch one layer earlier, by construction —
//! is finished first.  Every start that runs while another exchange is
//! pending lands in `attn_overlap`; the only exposed wait is the ring
//! fill/drain bubble (`pipeline_bubble`, also broken down per depth as
//! `pipeline_bubble_d{N}`).  Groups are as even as possible (8 lanes at
//! depth 3 run as 3/3/2).  A requested depth whose shape ladder is missing
//! from the artifact set falls back to depth 2, then 1.  Decode KV caches
//! live in per-microbatch lane groups and are repartitioned on the host if
//! the partition changes between forwards.
//!
//! ## Continuous batching (scheduler-backed mode)
//!
//! The engine also implements [`ForwardModel`], so the engine-agnostic
//! [`crate::server::Scheduler`] can drive it with real request admission:
//! an admission prefill runs at a compiled lane count (padding masked),
//! its per-layer KV is spliced into free lanes of the decode groups
//! (admissions balance live load across the N pipeline lane groups),
//! decode steps run the normal full-lane-group forwards with retired/free
//! lanes masked out of gate + dispatch (dead lanes send **no** expert
//! traffic), and released lanes are reused by later admissions.  Live
//! lanes stay bit-identical to the fixed-lane driver; the legacy mode
//! (`forward_prefill`/`forward_decode` with every lane driven explicitly)
//! is untouched and resets the lane state.  Three scheduler-mode
//! capabilities ride on top:
//!
//! * **Prefill-behind-decode interleaving** — `begin_prefill` stages an
//!   admission; each decode-layer exchange the ring puts on the fabric
//!   advances the staged prefill by one layer
//!   ([`EpEngine::advance_admission`]), so admission compute hides behind
//!   decode round trips instead of stopping the world.  The admission's
//!   own exposed wait lands in `prefill_stall`; `finish_prefill` completes
//!   whatever the gaps did not cover and splices the KV.
//! * **Dynamic lane regrouping** — when retirement skews per-group live
//!   occupancy by at least `DSMOE_REGROUP_SKEW` (default 2) lanes, live
//!   lanes migrate into free slots of idler groups before the next decode
//!   step (KV moved through the host mirrors; external lane ids are
//!   preserved via an internal lane permutation, so the scheduler never
//!   observes the move).  Counted in `lane_regroups` / `lane_moves`.
//! * **Host-side KV mirrors** — each lane group keeps per-layer host
//!   copies of its K/V caches (invalidated by decode writes, exactly like
//!   the monolithic engine's `cache_lits`), so admission splices and
//!   regroup moves copy only the touched lanes instead of round-tripping
//!   the whole group's cache per layer.
//!
//! ## Env toggles
//!
//! | variable              | effect                                       |
//! |-----------------------|----------------------------------------------|
//! | `DSMOE_SERIAL_MOE`    | serialized per-expert MoE path (pre-overlap  |
//! |                       | baseline): gate → one message per expert →   |
//! |                       | blocking collect → combine; also disables    |
//! |                       | the pipeline ([`EpEngine::set_serial_moe`]). |
//! | `DSMOE_NO_PIPELINE`   | per-layer overlapped path (the pre-pipeline  |
//! |                       | behaviour): split-phase dispatch immediately |
//! |                       | followed by finish, full-batch shapes        |
//! |                       | ([`EpEngine::set_pipeline`]).                |
//! | `DSMOE_PIPE_DEPTH`    | microbatch ring depth N (default 2;          |
//! |                       | [`EpEngine::set_pipe_depth`]).               |
//! | `DSMOE_NO_INTERLEAVE` | stop-the-world admission prefills (the       |
//! |                       | pre-interleaving scheduler behaviour;        |
//! |                       | [`EpEngine::set_interleave`]).               |
//! | `DSMOE_REGROUP_SKEW`  | live-lane skew (max − min per group) that    |
//! |                       | triggers a regroup; default 2 — a skew of 1  |
//! |                       | is unavoidable whenever live lanes don't     |
//! |                       | divide evenly, so 2 is the smallest          |
//! |                       | actionable imbalance.                        |
//!
//! All paths — serial, overlapped, pipelined at any depth — produce
//! **bit-identical** logits for prefill and decode (asserted at depths 2,
//! 3 and 4 in `integration_parity.rs`); `benches/e2e_serving.rs` compares
//! their forward latencies, exposed waits, the depth sweep, and
//! interleaved vs stop-the-world admission into `BENCH_e2e.json`.

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::{AllToAllKind, ModelConfig};
use crate::coordinator::alltoall::{self, Topology};
use crate::coordinator::kv_cache::{copy_lane, split_lanes};
use crate::coordinator::{Placement, Request, Routing};
use crate::fabric::{ExpertFfnBatch, Fabric, FfnBatchResult, WorkerPrograms};
use crate::metrics::Metrics;
use crate::moe::ExpertLoadStats;
use crate::runtime::{
    Checkpoint, HostTensor, Manifest, Program, Runtime,
};
use crate::server::scheduler::{AdmittedLane, ForwardModel};
use crate::util::env_usize;

pub struct EpEngine {
    rt: Runtime,
    pub cfg: ModelConfig,
    params: HashMap<String, xla::Literal>,
    #[allow(dead_code)] // retained for checkpoint hot-swap (future work)
    params_host: HashMap<String, HostTensor>,
    placement: Placement,
    fabric: Fabric,
    pub metrics: std::sync::Arc<Metrics>,
    pub load_stats: Vec<ExpertLoadStats>,
    /// `stats_idx[layer]` = index into `load_stats` (None for dense
    /// layers): O(1) per-layer lookup instead of a linear scan.
    stats_idx: Vec<Option<usize>>,
    manifest_keys: ManifestKeys,
    progs: HashMap<String, Rc<Program>>,
    alltoall: AllToAllKind,
    /// Decode KV caches in per-microbatch lane groups; each group holds
    /// per-layer `[lanes, H, Smax, hd]` tensors (monolithic layout is
    /// `[L, B, ...]`).  One group when the pipeline is off, N when on.
    caches: Vec<LaneGroupCaches>,
    batch: usize,
    /// `DSMOE_SERIAL_MOE`: run the old serialized per-expert MoE path
    /// instead of the overlapped/coalesced pipeline (for measurement).
    serial_moe: bool,
    /// `DSMOE_NO_PIPELINE` (inverted): microbatch-interleave forwards when
    /// the group-sized program shapes are available.
    pipeline: bool,
    /// Requested microbatch ring depth (`DSMOE_PIPE_DEPTH`, default 2);
    /// the resolved depth falls back 2 → 1 when shapes are missing.
    pipe_depth: usize,
    /// `depth_ok[d]`: the manifest has every program shape the d-group
    /// lane partition needs (computed once at construction).
    depth_ok: Vec<bool>,
    /// Lane partition of the forward currently in flight (its group
    /// count); keys the per-depth metric breakdowns.
    active_depth: usize,
    /// `DSMOE_NO_INTERLEAVE` (inverted): admission prefills run behind
    /// in-flight decode exchanges instead of stopping the world.
    interleave: bool,
    /// Live-lane skew (max − min per group) that triggers a regroup
    /// (`DSMOE_REGROUP_SKEW`, default 2).
    regroup_skew: usize,
    /// Routing/combine scratch pool: one slot per pipeline microbatch
    /// (index = microbatch) plus a dedicated slot (index = `batch`) for a
    /// staged admission prefill.
    scratch: Vec<MoeScratch>,
    /// Monotonic exchange generation: stamped into every coalesced batch
    /// so stale replies of an aborted exchange (even at the same layer of
    /// a retried forward) can never be combined into a later one.
    exchange_seq: u64,
    /// Tags of exchanges currently out on the fabric (at most the ring
    /// depth plus a staged admission): the collector stashes replies for
    /// these instead of failing.
    open_tags: Vec<u64>,
    /// Continuous-batching lane occupancy (scheduler-backed mode):
    /// `lane_live[phys]` is true while a live request occupies the
    /// physical lane.  Dead lanes are masked out of gate + dispatch so
    /// they send no expert traffic.  Empty in the legacy fixed-lane mode
    /// (no masking — every lane is driven explicitly), which keeps that
    /// path bit-identical to the pre-refactor engine.
    lane_live: Vec<bool>,
    /// Scheduler-visible lane id → physical lane slot.  Identity until a
    /// regroup migrates live lanes between groups; external ids stay
    /// stable for a request's whole lifetime.  Empty in legacy mode.
    lane_phys: Vec<usize>,
    /// Inverse of `lane_phys`: physical slot → external lane id.
    lane_ext: Vec<usize>,
    /// Admission prefill staged by `begin_prefill`, advanced layer by
    /// layer behind in-flight decode exchanges.
    pending_admission: Option<AdmissionState>,
    /// Compiled lane counts at which a scheduler admission prefill can run
    /// (every prefill-side program shape exists in the manifest).
    prefill_sizes: Vec<usize>,
}

struct ManifestKeys {
    manifest: Manifest,
}

/// Routing pack/combine scratch reused across MoE layers (and forwards) so
/// the hot path does not reallocate its staging buffers per layer.  The
/// engine keeps one slot per pipeline microbatch (double buffering).
#[derive(Default)]
struct MoeScratch {
    /// `[T * M]` combine accumulation buffer.
    combine: Vec<f32>,
    /// Per-worker expert lists for the current layer.
    worker_experts: Vec<Vec<usize>>,
}

/// Decode KV caches for one contiguous lane group (a pipeline microbatch).
struct LaneGroupCaches {
    lane0: usize,
    lanes: usize,
    k: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    /// Per-layer host mirrors of `k`/`v` (`None` = stale, repulled on
    /// demand): admission splices and regroup moves write through these so
    /// only the touched lanes are copied; decode writes invalidate the
    /// touched layer (the monolithic engine's `cache_lits`, per group).
    hk: Vec<Option<HostTensor>>,
    hv: Vec<Option<HostTensor>>,
}

impl LaneGroupCaches {
    fn new(lane0: usize, lanes: usize, n_layers: usize) -> LaneGroupCaches {
        LaneGroupCaches {
            lane0,
            lanes,
            k: Vec::with_capacity(n_layers),
            v: Vec::with_capacity(n_layers),
            hk: Vec::with_capacity(n_layers),
            hv: Vec::with_capacity(n_layers),
        }
    }

    /// Append one layer's freshly computed caches (mirror starts stale).
    fn push_kv(&mut self, k: xla::Literal, v: xla::Literal) {
        self.k.push(k);
        self.v.push(v);
        self.hk.push(None);
        self.hv.push(None);
    }

    /// Append one layer's caches from host tensors (mirror starts valid).
    fn push_host(&mut self, k: HostTensor, v: HostTensor) -> Result<()> {
        self.k.push(k.to_literal()?);
        self.v.push(v.to_literal()?);
        self.hk.push(Some(k));
        self.hv.push(Some(v));
        Ok(())
    }

    /// Host mirror of layer `layer`'s K cache, pulling from the literal
    /// only when stale.
    fn host_k(&mut self, layer: usize) -> Result<&mut HostTensor> {
        if self.hk[layer].is_none() {
            self.hk[layer] = Some(HostTensor::from_literal(&self.k[layer])?);
        }
        Ok(self.hk[layer].as_mut().unwrap())
    }

    fn host_v(&mut self, layer: usize) -> Result<&mut HostTensor> {
        if self.hv[layer].is_none() {
            self.hv[layer] = Some(HostTensor::from_literal(&self.v[layer])?);
        }
        Ok(self.hv[layer].as_mut().unwrap())
    }

    /// Rebuild layer `layer`'s literals from its (valid) host mirrors.
    fn push_layer(&mut self, layer: usize) -> Result<()> {
        if let Some(h) = &self.hk[layer] {
            self.k[layer] = h.to_literal()?;
        }
        if let Some(h) = &self.hv[layer] {
            self.v[layer] = h.to_literal()?;
        }
        Ok(())
    }

    /// Decode wrote layer `layer`'s caches: the host mirror is stale.
    fn invalidate(&mut self, layer: usize) {
        self.hk[layer] = None;
        self.hv[layer] = None;
    }
}

/// A staged admission prefill ([`EpEngine::stage_admission`]): advanced
/// one layer at a time behind in-flight decode exchanges
/// ([`EpEngine::advance_admission`]) and completed — LM head, KV splice,
/// lane activation — by [`EpEngine::complete_admission`].
struct AdmissionState {
    /// Compiled lane count of the prefill programs.
    compiled: usize,
    /// Leading lanes that carry real prompts (the rest is padding).
    live: usize,
    /// Per compiled lane: prompt length (padding lanes: 1).
    lens: Vec<usize>,
    /// Free physical lanes the admitted requests will occupy.
    lanes: Vec<usize>,
    /// Padding mask over the `compiled * smax` prefill tokens.
    mask: Option<Vec<bool>>,
    /// Activation after the last completed layer.
    h: Option<xla::Literal>,
    /// Next layer to run.
    layer: usize,
    /// Per completed layer: `[compiled, H, Smax, hd]` K/V caches.
    kv: Vec<(xla::Literal, xla::Literal)>,
    /// Leader time spent on this admission across interleaved steps
    /// (observed as `forward_prefill` at completion).
    elapsed: std::time::Duration,
}

/// What kind of forward the shared interleave scheduler
/// ([`EpEngine::run_pipeline`]) is driving, with the per-microbatch state
/// its start step needs.
enum PipeCtx<'a> {
    /// Prefill: KV cache groups being built layer by layer.
    Prefill(&'a mut [LaneGroupCaches]),
    /// Decode: per-microbatch position literals.
    Decode(&'a [xla::Literal]),
}

/// A split-phase MoE layer whose expert exchange may still be on the
/// fabric: produced by [`EpEngine::moe_dispatch`], consumed by
/// [`EpEngine::moe_finish`].  Dense FFN layers complete at dispatch time
/// and carry their result through the same type so pipeline drivers treat
/// every layer uniformly.
pub struct InflightMoe {
    layer: usize,
    /// Leader time spent in the dispatch half (gate → leader overlap).
    /// `moe_layer` is recorded as this plus the finish half, so the
    /// pipelined path's number measures the layer's own cost and not the
    /// partner microbatch's work interleaved between the two halves.
    dispatch_elapsed: std::time::Duration,
    state: InflightState,
}

enum InflightState {
    /// Dense FFN — nothing on the fabric, result already computed.
    Done(xla::Literal),
    Pending(Box<PendingMoe>),
}

struct PendingMoe {
    slot: usize,
    /// Original `h` dims, restored on combine.
    shape: Vec<usize>,
    routing: Routing,
    /// Worker replies not yet received.
    outstanding: usize,
    tag: u64,
    /// PR-MoE fixed-branch output (leader-side), if the model has one.
    residual: Option<Vec<f32>>,
    /// Residual stream pulled to the host (combine accumulates into it).
    out_data: Vec<f32>,
    /// Taken from the slot's [`MoeScratch`], returned at finish.
    worker_experts: Vec<Vec<usize>>,
    results: Vec<FfnBatchResult>,
    /// Metric the exposed wait lands in: `expert_wait` on the per-layer
    /// path, `pipeline_bubble` under the pipelined driver,
    /// `prefill_stall` for a staged admission's layers.
    wait_metric: &'static str,
    /// Ring depth to break the wait metric down by (`{metric}_d{N}`),
    /// captured at dispatch time where the active partition is
    /// authoritative; `None` = no per-depth breakdown.
    depth_tag: Option<usize>,
}

impl InflightMoe {
    /// True while the expert exchange is (possibly) still on the fabric.
    pub fn pending(&self) -> bool {
        matches!(self.state, InflightState::Pending(_))
    }

    pub fn layer(&self) -> usize {
        self.layer
    }
}

impl EpEngine {
    pub fn new(
        manifest: &Manifest,
        model: &str,
        workers: usize,
        alltoall: AllToAllKind,
        batch: usize,
    ) -> Result<EpEngine> {
        let arts = manifest.model(model)?;
        let cfg = arts.config.clone();
        anyhow::ensure!(cfg.is_moe(), "EP engine needs an MoE model");
        let rt = Runtime::cpu()?;

        let ck = Checkpoint::load(&arts.checkpoint_dir)?;
        let mut params = HashMap::new();
        let mut params_host = HashMap::new();
        for (n, t) in ck.names.iter().zip(&ck.tensors) {
            params.insert(n.clone(), t.to_literal()?);
            params_host.insert(n.clone(), t.clone());
        }

        // Expert FFN program ladder for the fabric workers.
        let (m, f) = (cfg.d_model, cfg.d_ff);
        let ladder: Vec<_> = manifest
            .expert_block_sizes()
            .into_iter()
            .filter_map(|c| {
                manifest
                    .shared_program(&Manifest::key_expert_ffn(m, f, c))
                    .ok()
                    .map(|s| (c, s.clone()))
            })
            .collect();
        anyhow::ensure!(!ladder.is_empty(), "no expert_ffn programs for m{m} f{f}");

        let placement = Placement::for_model(&cfg, workers);
        let fabric = Fabric::spawn(workers, WorkerPrograms { expert_ffn: ladder })?;

        // Ship expert weights to their owners.
        for w in 0..workers {
            for (layer, e) in placement.worker_manifest(w) {
                let weights = ["w1", "b1", "w2", "b2"]
                    .iter()
                    .map(|part| {
                        let full = &params_host
                            [&format!("layer{layer}.moe.{part}")];
                        Ok(slice_expert(full, e, part)?)
                    })
                    .collect::<Result<Vec<_>>>()?;
                fabric.load_expert(w, layer, e, weights)?;
            }
        }

        let load_stats: Vec<ExpertLoadStats> = cfg
            .moe_layers()
            .into_iter()
            .map(|(i, e)| ExpertLoadStats::new(i, e))
            .collect();
        let mut stats_idx = vec![None; cfg.n_layers];
        for (i, s) in load_stats.iter().enumerate() {
            stats_idx[s.layer] = Some(i);
        }
        // Which microbatch ring depths this artifact set supports: depth d
        // partitions the batch into d contiguous groups, and every group
        // size needs its full prefill+decode program ladder.
        let depth_ok: Vec<bool> = (0..=batch)
            .map(|d| {
                d >= 1
                    && partition_lanes(batch, d).iter().all(|&(_, lanes)| {
                        group_shapes_available(manifest, &cfg, lanes)
                    })
            })
            .collect();

        // Compiled lane counts a scheduler admission prefill can run at:
        // the standard AOT ladder filtered by what this artifact set
        // actually exports (older sets may only have the full batch).
        let mut prefill_sizes: Vec<usize> = [1usize, 2, 3, 4, 8, 16, 32]
            .into_iter()
            .chain([batch])
            .filter(|&s| s <= batch)
            .filter(|&s| prefill_shapes_available(manifest, &cfg, s))
            .collect();
        prefill_sizes.sort();
        prefill_sizes.dedup();
        if prefill_sizes.is_empty() {
            // forward_prefill needs the full-batch shapes anyway; admission
            // will surface the missing-program error on first use.
            prefill_sizes.push(batch);
        }

        Ok(EpEngine {
            rt,
            cfg,
            params,
            params_host,
            placement,
            fabric,
            metrics: std::sync::Arc::new(Metrics::new()),
            load_stats,
            stats_idx,
            manifest_keys: ManifestKeys { manifest: manifest.clone() },
            progs: HashMap::new(),
            alltoall,
            caches: Vec::new(),
            batch,
            serial_moe: std::env::var_os("DSMOE_SERIAL_MOE")
                .is_some_and(|v| v != "0"),
            pipeline: !std::env::var_os("DSMOE_NO_PIPELINE")
                .is_some_and(|v| v != "0"),
            pipe_depth: env_usize("DSMOE_PIPE_DEPTH", 2),
            depth_ok,
            active_depth: 1,
            interleave: !std::env::var_os("DSMOE_NO_INTERLEAVE")
                .is_some_and(|v| v != "0"),
            regroup_skew: env_usize("DSMOE_REGROUP_SKEW", 2).max(1),
            scratch: (0..=batch).map(|_| MoeScratch::default()).collect(),
            exchange_seq: 0,
            open_tags: Vec::new(),
            lane_live: Vec::new(),
            lane_phys: Vec::new(),
            lane_ext: Vec::new(),
            pending_admission: None,
            prefill_sizes,
        })
    }

    /// Select the serialized (`true`) or overlapped/coalesced (`false`)
    /// MoE data path.  Defaults to the `DSMOE_SERIAL_MOE` env toggle;
    /// exposed programmatically so tests and benches can compare both paths
    /// in one process without racing on the environment.
    pub fn set_serial_moe(&mut self, serial: bool) {
        self.serial_moe = serial;
    }

    pub fn serial_moe(&self) -> bool {
        self.serial_moe
    }

    /// Enable/disable the microbatch-interleaved pipeline (defaults to the
    /// inverse of the `DSMOE_NO_PIPELINE` env toggle).  Even when enabled
    /// the engine falls back to the per-layer path unless the group-sized
    /// program shapes exist in the manifest.
    pub fn set_pipeline(&mut self, pipeline: bool) {
        self.pipeline = pipeline;
    }

    pub fn pipeline(&self) -> bool {
        self.pipeline
    }

    /// Request a microbatch ring depth (defaults to `DSMOE_PIPE_DEPTH`,
    /// default 2).  Clamped to the lane count; a depth whose program
    /// shapes are missing from the artifact set falls back to 2, then 1
    /// (see [`EpEngine::microbatches`] for the resolved value).
    pub fn set_pipe_depth(&mut self, depth: usize) {
        self.pipe_depth = depth;
    }

    pub fn pipe_depth(&self) -> usize {
        self.pipe_depth
    }

    /// Enable/disable prefill-behind-decode admission interleaving
    /// (defaults to the inverse of the `DSMOE_NO_INTERLEAVE` env toggle).
    pub fn set_interleave(&mut self, interleave: bool) {
        self.interleave = interleave;
    }

    pub fn interleave(&self) -> bool {
        self.interleave
    }

    /// Live-lane skew (max − min across groups) that triggers a dynamic
    /// regroup before a decode step; clamped to at least 1.
    pub fn set_regroup_skew(&mut self, skew: usize) {
        self.regroup_skew = skew.max(1);
    }

    /// Live lanes per decode lane group (scheduler-backed mode; empty
    /// groups report 0 in legacy mode).
    pub fn group_live_counts(&self) -> Vec<usize> {
        self.caches
            .iter()
            .map(|c| {
                (c.lane0..c.lane0 + c.lanes)
                    .filter(|&l| {
                        self.lane_live.get(l).copied().unwrap_or(false)
                    })
                    .count()
            })
            .collect()
    }

    /// True if this artifact set carries every program shape the d-group
    /// lane partition needs.
    pub fn depth_supported(&self, depth: usize) -> bool {
        depth >= 1 && depth <= self.batch && self.depth_ok[depth]
    }

    /// Number of microbatches the next forward will run with: the
    /// requested ring depth when the pipeline is active and its shapes
    /// exist, else the fallback (2, then 1).
    pub fn microbatches(&self) -> usize {
        self.resolved_depth()
    }

    /// Resolve the requested ring depth against the toggles and the
    /// artifact set: serial / no-pipeline force 1; otherwise the ladder is
    /// requested depth → 2 → 1.
    fn resolved_depth(&self) -> usize {
        if self.serial_moe || !self.pipeline {
            return 1;
        }
        let want = self.pipe_depth.clamp(1, self.batch.max(1));
        if want <= 1 {
            return 1;
        }
        if self.depth_ok[want] {
            return want;
        }
        if want > 2 && self.batch >= 2 && self.depth_ok[2] {
            return 2;
        }
        1
    }

    fn prog(&mut self, key: &str) -> Result<Rc<Program>> {
        if let Some(p) = self.progs.get(key) {
            return Ok(p.clone());
        }
        let spec = self.manifest_keys.manifest.shared_program(key)?;
        let p = self.rt.load(spec)?;
        self.progs.insert(key.to_string(), p.clone());
        Ok(p)
    }

    fn p(&self, name: &str) -> &xla::Literal {
        &self.params[name]
    }

    /// Contiguous `(lane0, lanes)` microbatch groups for the next forward:
    /// the resolved ring depth's partition (sizes as even as possible),
    /// one full-batch group when the pipeline is off.
    fn lane_groups(&self) -> Vec<(usize, usize)> {
        partition_lanes(self.batch, self.resolved_depth())
    }

    /// Full prefill over padded prompts [B, smax]; returns last-position
    /// logits per lane at `lens[b]-1` and primes the decode caches.
    pub fn forward_prefill(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let (b, smax) = (self.batch, self.cfg.max_seq);
        anyhow::ensure!(tokens.len() == b * smax, "tokens shape");
        anyhow::ensure!(lens.len() == b, "lens shape");
        // Range-check here so the literal-level gather and the host
        // fallback in lm_head_last fail identically (the AOT program would
        // silently clip, the host path would panic).
        anyhow::ensure!(
            lens.iter().all(|&l| l <= smax),
            "prompt length exceeds max_seq {smax}"
        );
        // A staged admission holds requests whose KV is mid-flight;
        // silently dropping it here would lose them.  The scheduler always
        // finishes a staged admission within the same step, so this can
        // only be an API misuse — fail loudly.
        anyhow::ensure!(
            self.pending_admission.is_none(),
            "forward_prefill with a staged admission (finish_prefill first)"
        );
        let t_fwd = std::time::Instant::now();
        // Exchanges of an aborted earlier forward are no longer open: any
        // reply of theirs that straggles in must fail loudly, not sit in
        // the stash forever.
        self.open_tags.clear();
        // A full fixed-lane prefill rebuilds every lane: back to legacy
        // mode (no lane occupancy, no dead-lane masking, identity lane
        // permutation).
        self.lane_live.clear();
        self.lane_phys.clear();
        self.lane_ext.clear();
        let groups = self.lane_groups();
        self.active_depth = groups.len();
        self.metrics.gauge("pipe_depth", groups.len() as f64);
        let out = if groups.len() > 1 {
            self.prefill_pipelined(tokens, lens, &groups)?
        } else {
            self.prefill_single(tokens, lens)?
        };
        self.metrics.observe("forward_prefill", t_fwd.elapsed());
        Ok(out)
    }

    /// Single-microbatch prefill: the per-layer (serial or overlapped)
    /// data path over full-batch program shapes.
    fn prefill_single(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let (b, smax) = (self.batch, self.cfg.max_seq);
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);

        let embed = self.prog(&Manifest::key_embed(v, m, b, smax))?;
        let tok = HostTensor::i32(&[b, smax], tokens.to_vec()).to_literal()?;
        let pos0 = HostTensor::i32(&[b], vec![0; b]).to_literal()?;
        let mut h = embed
            .run_literal_refs(&[
                self.p("tok_emb"),
                self.p("pos_emb"),
                &tok,
                &pos0,
            ])?
            .remove(0);

        let mut group = LaneGroupCaches::new(0, b, self.cfg.n_layers);
        for layer in 0..self.cfg.n_layers {
            let (h2, k, vv) = self.attn_prefill(layer, h, b)?;
            group.push_kv(k, vv);
            h = self.ffn_layer(layer, h2, None)?;
        }
        self.caches = vec![group];

        self.lm_head_last(&h, lens)
    }

    /// Microbatch-interleaved prefill: while one microbatch's expert blocks
    /// are on the fabric for layer L, the leader runs the other
    /// microbatch's attention + gate + dispatch, so only the fill/drain
    /// bubble of the pipeline is an exposed wait.
    fn prefill_pipelined(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
        groups: &[(usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let smax = self.cfg.max_seq;
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);
        let n_layers = self.cfg.n_layers;

        let mut cache_groups: Vec<LaneGroupCaches> = groups
            .iter()
            .map(|&(lane0, lanes)| LaneGroupCaches::new(lane0, lanes, n_layers))
            .collect();
        let mut hs: Vec<Option<xla::Literal>> =
            Vec::with_capacity(groups.len());
        for &(lane0, lanes) in groups {
            let embed = self.prog(&Manifest::key_embed(v, m, lanes, smax))?;
            let tok = HostTensor::i32(
                &[lanes, smax],
                tokens[lane0 * smax..(lane0 + lanes) * smax].to_vec(),
            )
            .to_literal()?;
            let pos0 = HostTensor::i32(&[lanes], vec![0; lanes]).to_literal()?;
            hs.push(Some(
                embed
                    .run_literal_refs(&[
                        self.p("tok_emb"),
                        self.p("pos_emb"),
                        &tok,
                        &pos0,
                    ])?
                    .remove(0),
            ));
        }

        self.run_pipeline(&mut hs, &mut PipeCtx::Prefill(&mut cache_groups))?;
        self.caches = cache_groups;

        let mut rows = Vec::with_capacity(self.batch);
        for (g, &(lane0, lanes)) in groups.iter().enumerate() {
            let h = hs[g].take().unwrap();
            rows.extend(self.lm_head_last(&h, &lens[lane0..lane0 + lanes])?);
        }
        Ok(rows)
    }

    /// The microbatch-interleave scheduler shared by prefill and decode: a
    /// rotating ring of at most `hs.len()` in-flight layer exchanges.
    /// Step `(layer, mb)` dispatches microbatch `mb`'s attention + gate +
    /// dispatch; once the ring is full the oldest in-flight entry — the
    /// same microbatch at the previous layer, by construction — is
    /// finished first, so each microbatch's layers run in order while up
    /// to N exchanges share the fabric.  Starts that run while another
    /// exchange is pending land in `attn_overlap`; a staged admission
    /// prefill advances one layer behind each freshly dispatched decode
    /// exchange.  `hs` holds each microbatch's activation and is left
    /// holding the final layer outputs.
    fn run_pipeline(
        &mut self,
        hs: &mut [Option<xla::Literal>],
        ctx: &mut PipeCtx<'_>,
    ) -> Result<()> {
        let n_layers = self.cfg.n_layers;
        let n_mb = hs.len();
        let mut ring: VecDeque<(usize, InflightMoe)> =
            VecDeque::with_capacity(n_mb);
        for layer in 0..n_layers {
            for mb in 0..n_mb {
                if ring.len() == n_mb {
                    // The front is (mb, layer - 1): finishing it frees
                    // exactly the microbatch this step starts.
                    let (fmb, fl) = ring.pop_front().unwrap();
                    debug_assert_eq!(fmb, mb);
                    hs[fmb] = Some(self.moe_finish(fl)?);
                }
                let t = std::time::Instant::now();
                let h = hs[mb].take().unwrap();
                let fl = self.start_layer(layer, h, mb, ctx)?;
                if ring.iter().any(|(_, f)| f.pending()) {
                    self.metrics.observe_tagged(
                        "attn_overlap",
                        self.active_depth,
                        t.elapsed(),
                    );
                }
                ring.push_back((mb, fl));
                // Prefill-behind-decode: a staged admission advances one
                // layer while this step's exchange is on the fabric.
                if matches!(ctx, PipeCtx::Decode(_)) {
                    self.advance_admission(1)?;
                }
                // Opportunistic drain: replies already arrived for the
                // next entry to finish shorten its eventual bubble.
                if let Some((_, f)) = ring.front_mut() {
                    self.poll_inflight(f)?;
                }
            }
        }
        while let Some((mb, fl)) = ring.pop_front() {
            hs[mb] = Some(self.moe_finish(fl)?);
        }
        Ok(())
    }

    /// One microbatch's attention + split-phase dispatch at one layer,
    /// dispatched on the pipeline kind.
    fn start_layer(
        &mut self,
        layer: usize,
        h: xla::Literal,
        mb: usize,
        ctx: &mut PipeCtx<'_>,
    ) -> Result<InflightMoe> {
        match ctx {
            PipeCtx::Prefill(groups) => {
                self.start_prefill(layer, h, &mut groups[mb], mb)
            }
            PipeCtx::Decode(pos) => self.start_decode(layer, h, &pos[mb], mb),
        }
    }

    /// Attention + split-phase dispatch for one prefill microbatch layer.
    fn start_prefill(
        &mut self,
        layer: usize,
        h: xla::Literal,
        cache: &mut LaneGroupCaches,
        slot: usize,
    ) -> Result<InflightMoe> {
        let (h2, k, vv) = self.attn_prefill(layer, h, cache.lanes)?;
        cache.push_kv(k, vv);
        // Legacy full prefill drives every lane: no mask.
        self.moe_dispatch_in(
            layer,
            h2,
            slot,
            "pipeline_bubble",
            Some(self.active_depth),
            None,
        )
    }

    /// One decode step over [B] tokens at per-lane positions.
    pub fn forward_decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.batch;
        anyhow::ensure!(tokens.len() == b && pos.len() == b);
        anyhow::ensure!(!self.caches.is_empty(), "decode before prefill");
        let t_fwd = std::time::Instant::now();
        // See forward_prefill: aborted exchanges are no longer open.
        self.open_tags.clear();
        let groups = self.lane_groups();
        self.active_depth = groups.len();
        self.metrics.gauge("pipe_depth", groups.len() as f64);
        // A toggle between forwards (pipeline on/off, depth change)
        // changes the lane partition; reshape the cache groups before
        // decoding.
        self.repartition_caches(&groups)?;
        let out = if groups.len() > 1 {
            self.decode_pipelined(tokens, pos, &groups)?
        } else {
            self.decode_single(tokens, pos)?
        };
        self.metrics.observe("forward_decode", t_fwd.elapsed());
        Ok(out)
    }

    fn decode_single(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.batch;
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);

        let embed = self.prog(&Manifest::key_embed(v, m, b, 1))?;
        let tok = HostTensor::i32(&[b, 1], tokens.to_vec()).to_literal()?;
        let pos_lit = HostTensor::i32(&[b], pos.to_vec()).to_literal()?;
        let mut h = embed
            .run_literal_refs(&[
                self.p("tok_emb"),
                self.p("pos_emb"),
                &tok,
                &pos_lit,
            ])?
            .remove(0);

        let mask = self.decode_mask(0, b);
        for layer in 0..self.cfg.n_layers {
            h = self.attn_decode(layer, h, &pos_lit, 0)?;
            h = self.ffn_layer(layer, h, mask.as_deref())?;
        }
        // [B, 1, M]: feed the LM head straight from the literal (a reshape,
        // not a host round trip).
        let flat = h.reshape(&[b as i64, m as i64])?;
        self.lm_head_rows(&flat, b)
    }

    /// Microbatch-interleaved decode step (same schedule as
    /// [`EpEngine::prefill_pipelined`], with per-microbatch KV lane
    /// groups).
    fn decode_pipelined(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        groups: &[(usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);

        let mut hs: Vec<Option<xla::Literal>> =
            Vec::with_capacity(groups.len());
        let mut pos_lits: Vec<xla::Literal> =
            Vec::with_capacity(groups.len());
        for &(lane0, lanes) in groups {
            let embed = self.prog(&Manifest::key_embed(v, m, lanes, 1))?;
            let tok = HostTensor::i32(
                &[lanes, 1],
                tokens[lane0..lane0 + lanes].to_vec(),
            )
            .to_literal()?;
            let pos_lit =
                HostTensor::i32(&[lanes], pos[lane0..lane0 + lanes].to_vec())
                    .to_literal()?;
            hs.push(Some(
                embed
                    .run_literal_refs(&[
                        self.p("tok_emb"),
                        self.p("pos_emb"),
                        &tok,
                        &pos_lit,
                    ])?
                    .remove(0),
            ));
            pos_lits.push(pos_lit);
        }

        self.run_pipeline(&mut hs, &mut PipeCtx::Decode(&pos_lits))?;

        let mut rows = Vec::with_capacity(self.batch);
        for (g, &(_, lanes)) in groups.iter().enumerate() {
            let h = hs[g].take().unwrap();
            let flat = h.reshape(&[lanes as i64, m as i64])?;
            rows.extend(self.lm_head_rows(&flat, lanes)?);
        }
        Ok(rows)
    }

    /// Attention + split-phase dispatch for one decode microbatch layer
    /// (`group` selects the KV lane group).
    fn start_decode(
        &mut self,
        layer: usize,
        h: xla::Literal,
        pos: &xla::Literal,
        group: usize,
    ) -> Result<InflightMoe> {
        let h2 = self.attn_decode(layer, h, pos, group)?;
        let (lane0, lanes) =
            (self.caches[group].lane0, self.caches[group].lanes);
        let mask = self.decode_mask(lane0, lanes);
        self.moe_dispatch_in(
            layer,
            h2,
            group,
            "pipeline_bubble",
            Some(self.active_depth),
            mask.as_deref(),
        )
    }

    /// Token mask for a decode microbatch covering lanes
    /// `[lane0, lane0 + lanes)`: `None` in the legacy fixed-lane mode or
    /// when every lane in range is live (no masking — the fast path stays
    /// untouched), otherwise one liveness bit per lane (= per decode
    /// token).
    fn decode_mask(&self, lane0: usize, lanes: usize) -> Option<Vec<bool>> {
        if self.lane_live.is_empty() {
            return None;
        }
        let m = self.lane_live[lane0..lane0 + lanes].to_vec();
        if m.iter().all(|&x| x) {
            None
        } else {
            Some(m)
        }
    }

    /// Rebuild the decode cache groups for a new lane partition (host-side
    /// merge + split; only runs when the pipeline toggle or ring depth
    /// changed between forwards).  The rebuilt groups carry valid host
    /// mirrors — the merge pulled everything to the host anyway.
    fn repartition_caches(&mut self, groups: &[(usize, usize)]) -> Result<()> {
        let current: Vec<(usize, usize)> =
            self.caches.iter().map(|c| (c.lane0, c.lanes)).collect();
        if current.as_slice() == groups {
            return Ok(());
        }
        let (hh, smax, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim());
        let lane_elems = hh * smax * hd;
        let n_layers = self.cfg.n_layers;
        let mut new_groups: Vec<LaneGroupCaches> = groups
            .iter()
            .map(|&(lane0, lanes)| LaneGroupCaches::new(lane0, lanes, n_layers))
            .collect();
        for layer in 0..n_layers {
            // Lane-major cache layout: concatenating the groups' buffers
            // yields the full [B, H, Smax, hd] tensor, and contiguous
            // chunks of it are the target groups.
            let mut full_k: Vec<f32> =
                Vec::with_capacity(self.batch * lane_elems);
            let mut full_v: Vec<f32> =
                Vec::with_capacity(self.batch * lane_elems);
            for g in &mut self.caches {
                full_k.extend_from_slice(g.host_k(layer)?.as_f32()?);
                full_v.extend_from_slice(g.host_v(layer)?.as_f32()?);
            }
            let kparts = split_lanes(&full_k, lane_elems, groups);
            let vparts = split_lanes(&full_v, lane_elems, groups);
            for ((ng, kp), vp) in
                new_groups.iter_mut().zip(kparts).zip(vparts)
            {
                let shape = [ng.lanes, hh, smax, hd];
                ng.push_host(
                    HostTensor::f32(&shape, kp),
                    HostTensor::f32(&shape, vp),
                )?;
            }
        }
        self.caches = new_groups;
        Ok(())
    }

    /// Dynamic lane regrouping: when retirement has skewed per-group live
    /// occupancy by at least `regroup_skew`, migrate live lanes from
    /// surplus groups into free slots of deficit groups so every group
    /// carries an (almost) even live load.  KV moves through the host
    /// mirrors (only the moved lanes are copied; only destination groups
    /// are re-uploaded); the scheduler's lane ids survive via the
    /// external→physical lane permutation.  Never runs in legacy mode or
    /// while an admission is staged (its target lanes are physical).
    fn maybe_regroup(&mut self) -> Result<()> {
        if self.lane_live.is_empty()
            || self.pending_admission.is_some()
            || self.caches.len() < 2
        {
            return Ok(());
        }
        let counts = self.group_live_counts();
        let (min, max) = (
            counts.iter().copied().min().unwrap_or(0),
            counts.iter().copied().max().unwrap_or(0),
        );
        if max - min < self.regroup_skew {
            return Ok(());
        }
        let groups: Vec<(usize, usize)> =
            self.caches.iter().map(|c| (c.lane0, c.lanes)).collect();
        let n_g = groups.len();
        let mut live_in: Vec<Vec<usize>> = groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln).filter(|&l| self.lane_live[l]).collect()
            })
            .collect();
        let mut free_in: Vec<Vec<usize>> = groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln).filter(|&l| !self.lane_live[l]).collect()
            })
            .collect();
        let total_live: usize = counts.iter().sum();
        // Balanced targets respecting group capacities: hand out the live
        // lanes one at a time to the least-loaded group with room.
        let mut target = vec![0usize; n_g];
        for _ in 0..total_live {
            let g = (0..n_g)
                .filter(|&g| target[g] < groups[g].1)
                .min_by_key(|&g| (target[g], g))
                .expect("live lanes exceed lane count");
            target[g] += 1;
        }
        let mut surplus: Vec<usize> = Vec::new();
        for g in 0..n_g {
            while live_in[g].len() > target[g] {
                surplus.push(live_in[g].pop().unwrap());
            }
        }
        // (src physical, dst physical) live-lane moves.
        let mut moves: Vec<(usize, usize)> = Vec::new();
        for g in 0..n_g {
            while live_in[g].len() < target[g] {
                let dst = free_in[g].remove(0);
                let src = surplus.pop().expect("regroup accounting");
                moves.push((src, dst));
                live_in[g].push(dst);
            }
        }
        if moves.is_empty() {
            return Ok(());
        }
        let (hh, smax, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim());
        let lane_elems = hh * smax * hd;
        let group_of = |lane: usize| {
            groups
                .iter()
                .position(|&(l0, ln)| lane >= l0 && lane < l0 + ln)
                .expect("lane outside every group")
        };
        for layer in 0..self.cfg.n_layers {
            for &(src, dst) in &moves {
                let (sg, dg) = (group_of(src), group_of(dst));
                let s_off = src - groups[sg].0;
                let d_off = dst - groups[dg].0;
                let tmp_k = {
                    let hk = self.caches[sg].host_k(layer)?.as_f32()?;
                    hk[s_off * lane_elems..(s_off + 1) * lane_elems].to_vec()
                };
                let tmp_v = {
                    let hv = self.caches[sg].host_v(layer)?.as_f32()?;
                    hv[s_off * lane_elems..(s_off + 1) * lane_elems].to_vec()
                };
                let dk = self.caches[dg].host_k(layer)?.as_f32_mut()?;
                copy_lane(dk, d_off, &tmp_k, 0, lane_elems);
                let dv = self.caches[dg].host_v(layer)?.as_f32_mut()?;
                copy_lane(dv, d_off, &tmp_v, 0, lane_elems);
            }
        }
        // Re-upload only the destination groups (sources are unchanged —
        // their moved lanes are dead now and masked out of everything).
        let mut touched: Vec<usize> =
            moves.iter().map(|&(_, dst)| group_of(dst)).collect();
        touched.sort_unstable();
        touched.dedup();
        for g in touched {
            for layer in 0..self.cfg.n_layers {
                self.caches[g].push_layer(layer)?;
            }
        }
        // Swap the external bindings of each (src, dst) pair so the
        // scheduler's lane ids keep resolving to the moved data.
        for &(src, dst) in &moves {
            let (src_ext, dst_ext) = (self.lane_ext[src], self.lane_ext[dst]);
            self.lane_ext.swap(src, dst);
            self.lane_phys[src_ext] = dst;
            self.lane_phys[dst_ext] = src;
            self.lane_live[dst] = true;
            self.lane_live[src] = false;
        }
        self.metrics.inc("lane_regroups", 1);
        self.metrics.inc("lane_moves", moves.len() as u64);
        Ok(())
    }

    /// Depth of the fabric's tag-keyed reply stash (bounded by the open
    /// exchange count; must be zero between forwards).
    pub fn fabric_stash_depth(&self) -> usize {
        self.fabric.stash_depth()
    }

    /// Initialize continuous-batching lane state: all lanes free (identity
    /// lane permutation), decode cache groups zero-filled at the current
    /// lane partition with valid host mirrors (first-wave admissions
    /// splice without a single device pull).  Re-entered from legacy mode
    /// (after a fixed-lane `forward_prefill`) this resets every lane.
    fn ensure_lane_state(&mut self) -> Result<()> {
        if !self.lane_live.is_empty() {
            return Ok(());
        }
        self.lane_live = vec![false; self.batch];
        self.lane_phys = (0..self.batch).collect();
        self.lane_ext = (0..self.batch).collect();
        let (hh, smax, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim());
        let n_layers = self.cfg.n_layers;
        let mut groups = Vec::new();
        for (lane0, lanes) in self.lane_groups() {
            let mut g = LaneGroupCaches::new(lane0, lanes, n_layers);
            for _ in 0..n_layers {
                let shape = [lanes, hh, smax, hd];
                g.push_host(
                    HostTensor::zeros_f32(&shape),
                    HostTensor::zeros_f32(&shape),
                )?;
            }
            groups.push(g);
        }
        self.caches = groups;
        Ok(())
    }

    /// Choose `n` free lanes for admission, keeping the pipeline's lane
    /// groups balanced: each pick goes to the group with the fewest busy
    /// lanes among those with a free one, so the N microbatches carry
    /// similar live load.
    fn pick_free_lanes(&self, n: usize) -> Result<Vec<usize>> {
        let groups: Vec<(usize, usize)> =
            self.caches.iter().map(|c| (c.lane0, c.lanes)).collect();
        let mut free: Vec<Vec<usize>> = groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln).filter(|&l| !self.lane_live[l]).collect()
            })
            .collect();
        let mut busy: Vec<usize> = groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln).filter(|&l| self.lane_live[l]).count()
            })
            .collect();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let g = (0..groups.len())
                .filter(|&g| !free[g].is_empty())
                .min_by_key(|&g| busy[g])
                .context("no free lane for admission")?;
            out.push(free[g].remove(0));
            busy[g] += 1;
        }
        Ok(out)
    }

    /// Stage an admission prefill over `compiled` lanes (the first
    /// `reqs.len()` carry real prompts, the rest are padding): validates,
    /// picks balanced free lanes, and runs the embedding.  The per-layer
    /// body runs through [`EpEngine::advance_admission`] — interleaved
    /// behind decode exchanges or all at once from
    /// [`EpEngine::complete_admission`].  Per-lane outputs are
    /// bit-identical to a full-batch forward over the same prompts (every
    /// program is per-lane/per-row independent — the same property the
    /// parity tests pin).
    fn stage_admission(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<()> {
        anyhow::ensure!(
            self.pending_admission.is_none(),
            "admission already staged"
        );
        anyhow::ensure!(
            !reqs.is_empty() && reqs.len() <= compiled,
            "admission prefill: {} requests at compiled size {compiled}",
            reqs.len()
        );
        anyhow::ensure!(
            self.prefill_sizes.contains(&compiled),
            "no admission prefill shapes at lane count {compiled} \
             (available: {:?})",
            self.prefill_sizes
        );
        self.ensure_lane_state()?;
        let lanes = self.pick_free_lanes(reqs.len())?;
        let smax = self.cfg.max_seq;
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);
        // No forward is in flight when an admission is staged: exchanges
        // of an aborted earlier forward are no longer open.
        self.open_tags.clear();
        let t0 = std::time::Instant::now();
        let mut tokens = vec![0i32; compiled * smax];
        let mut lens = vec![1usize; compiled]; // padding lanes: dummy len
        for (i, r) in reqs.iter().enumerate() {
            anyhow::ensure!(
                r.prompt.len() <= smax,
                "prompt length exceeds max_seq {smax}"
            );
            tokens[i * smax..i * smax + r.prompt.len()]
                .copy_from_slice(&r.prompt);
            lens[i] = r.prompt.len();
        }
        let embed = self.prog(&Manifest::key_embed(v, m, compiled, smax))?;
        let tok = HostTensor::i32(&[compiled, smax], tokens).to_literal()?;
        let pos0 = HostTensor::i32(&[compiled], vec![0; compiled])
            .to_literal()?;
        let h = embed
            .run_literal_refs(&[
                self.p("tok_emb"),
                self.p("pos_emb"),
                &tok,
                &pos0,
            ])?
            .remove(0);
        let live = reqs.len();
        let mask: Option<Vec<bool>> = if live == compiled {
            None
        } else {
            Some((0..compiled * smax).map(|i| i / smax < live).collect())
        };
        self.pending_admission = Some(AdmissionState {
            compiled,
            live,
            lens,
            lanes,
            mask,
            h: Some(h),
            layer: 0,
            kv: Vec::with_capacity(self.cfg.n_layers),
            elapsed: t0.elapsed(),
        });
        Ok(())
    }

    /// Run up to `layers` staged-admission layer steps (attention +
    /// split-phase MoE with the padding masked; the admission's exposed
    /// expert wait lands in `prefill_stall`).  No-op without a staged
    /// admission; re-entrancy safe — the state is taken for the duration,
    /// so the admission's own MoE layers never recurse into further
    /// advances.
    fn advance_admission(&mut self, layers: usize) -> Result<()> {
        let Some(mut st) = self.pending_admission.take() else {
            return Ok(());
        };
        let t0 = std::time::Instant::now();
        for _ in 0..layers {
            if st.layer >= self.cfg.n_layers {
                break;
            }
            self.admission_layer(&mut st)?;
        }
        st.elapsed += t0.elapsed();
        self.pending_admission = Some(st);
        Ok(())
    }

    /// One admission-prefill layer: attention, then dispatch + finish on
    /// the dedicated admission scratch slot.  Replies of any concurrently
    /// open decode exchange arriving during the `prefill_stall` wait are
    /// stashed tag-keyed for their own collection.  Under
    /// `DSMOE_SERIAL_MOE` the layer runs the serialized per-expert
    /// baseline instead (as the pre-split admission path did), so the
    /// serial toggle's traffic and wait measurements stay uncontaminated.
    fn admission_layer(&mut self, st: &mut AdmissionState) -> Result<()> {
        let layer = st.layer;
        let h = st.h.take().expect("admission activation");
        let (h2, k, vv) = self.attn_prefill(layer, h, st.compiled)?;
        st.kv.push((k, vv));
        let out = if self.serial_moe && self.cfg.experts_at(layer) > 0 {
            self.moe_layer_serial(layer, h2, st.mask.as_deref())?
        } else {
            let slot = self.batch; // dedicated admission scratch slot
            let inflight = self.moe_dispatch_in(
                layer,
                h2,
                slot,
                "prefill_stall",
                None,
                st.mask.as_deref(),
            )?;
            self.moe_finish(inflight)?
        };
        st.h = Some(out);
        st.layer += 1;
        Ok(())
    }

    /// Complete a staged admission: run whatever layers the decode gaps
    /// did not cover, take the LM head, splice the KV into the chosen
    /// lanes, and mark them live.  Returns the admitted lanes in request
    /// order (external lane ids).
    fn complete_admission(&mut self) -> Result<Vec<AdmittedLane>> {
        self.advance_admission(self.cfg.n_layers)?;
        let mut st = self
            .pending_admission
            .take()
            .context("no admission staged")?;
        let t0 = std::time::Instant::now();
        let h = st.h.take().expect("admission activation");
        let mut rows = self.lm_head_last(&h, &st.lens)?;
        rows.truncate(st.live);
        self.splice_admitted(&st.kv, &st.lanes)?;
        self.metrics.observe("forward_prefill", st.elapsed + t0.elapsed());
        let mut out = Vec::with_capacity(st.live);
        for (&lane, logits) in st.lanes.iter().zip(rows) {
            self.lane_live[lane] = true;
            out.push(AdmittedLane { lane: self.lane_ext[lane], logits });
        }
        Ok(out)
    }

    /// Splice freshly prefilled lanes into the decode cache groups:
    /// `admits[i]` maps source lane `i` of the admission prefill to a free
    /// physical lane.  Writes go through the per-group host mirrors, so
    /// only the admitted lanes are copied host-side and a device pull
    /// happens only when a decode step staled the touched layer since the
    /// last splice.
    fn splice_admitted(
        &mut self,
        kv: &[(xla::Literal, xla::Literal)],
        admits: &[usize],
    ) -> Result<()> {
        let (hh, smax, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim());
        let lane_elems = hh * smax * hd;
        for (layer, (k_lit, v_lit)) in kv.iter().enumerate() {
            let src_k: Vec<f32> = k_lit.to_vec()?;
            let src_v: Vec<f32> = v_lit.to_vec()?;
            for g in &mut self.caches {
                let (lane0, lanes) = (g.lane0, g.lanes);
                let in_group: Vec<(usize, usize)> = admits
                    .iter()
                    .enumerate()
                    .filter(|&(_, &l)| l >= lane0 && l < lane0 + lanes)
                    .map(|(src, &l)| (src, l - lane0))
                    .collect();
                if in_group.is_empty() {
                    continue;
                }
                {
                    let dst = g.host_k(layer)?.as_f32_mut()?;
                    for &(src, d) in &in_group {
                        copy_lane(dst, d, &src_k, src, lane_elems);
                    }
                }
                {
                    let dst = g.host_v(layer)?.as_f32_mut()?;
                    for &(src, d) in &in_group {
                        copy_lane(dst, d, &src_v, src, lane_elems);
                    }
                }
                g.push_layer(layer)?;
            }
        }
        Ok(())
    }

    fn attn_prefill(
        &mut self,
        layer: usize,
        h: xla::Literal,
        lanes: usize,
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let (m, hh, smax) =
            (self.cfg.d_model, self.cfg.n_heads, self.cfg.max_seq);
        let prog = self.prog(&Manifest::key_attn_prefill(m, hh, lanes, smax))?;
        let pre = format!("layer{layer}.");
        let mut outs = prog.run_literal_refs(&[
            &h,
            self.p(&format!("{pre}ln1.g")),
            self.p(&format!("{pre}ln1.b")),
            self.p(&format!("{pre}attn.wq")),
            self.p(&format!("{pre}attn.wk")),
            self.p(&format!("{pre}attn.wv")),
            self.p(&format!("{pre}attn.wo")),
        ])?;
        let vv = outs.pop().unwrap();
        let k = outs.pop().unwrap();
        let h2 = outs.pop().unwrap();
        Ok((h2, k, vv))
    }

    fn attn_decode(
        &mut self,
        layer: usize,
        h: xla::Literal,
        pos: &xla::Literal,
        group: usize,
    ) -> Result<xla::Literal> {
        let (m, hh, smax) =
            (self.cfg.d_model, self.cfg.n_heads, self.cfg.max_seq);
        let lanes = self.caches[group].lanes;
        let prog = self.prog(&Manifest::key_attn_decode(m, hh, lanes, smax))?;
        let pre = format!("layer{layer}.");
        let cache = &self.caches[group];
        let mut outs = prog.run_literal_refs(&[
            &h,
            self.p(&format!("{pre}ln1.g")),
            self.p(&format!("{pre}ln1.b")),
            self.p(&format!("{pre}attn.wq")),
            self.p(&format!("{pre}attn.wk")),
            self.p(&format!("{pre}attn.wv")),
            self.p(&format!("{pre}attn.wo")),
            &cache.k[layer],
            &cache.v[layer],
            pos,
        ])?;
        let vc = outs.pop().unwrap();
        let kc = outs.pop().unwrap();
        let h2 = outs.pop().unwrap();
        let cache = &mut self.caches[group];
        cache.k[layer] = kc;
        cache.v[layer] = vc;
        // The decode write staled this layer's host mirror.
        cache.invalidate(layer);
        Ok(h2)
    }

    /// FFN sublayer on the per-layer path: split-phase dispatch followed
    /// immediately by finish (the PR-1 overlapped schedule), or the
    /// serialized baseline under `DSMOE_SERIAL_MOE`.  `mask` marks live
    /// tokens (None = all live); dead tokens are excluded from gate
    /// routing and expert dispatch.
    fn ffn_layer(
        &mut self,
        layer: usize,
        h: xla::Literal,
        mask: Option<&[bool]>,
    ) -> Result<xla::Literal> {
        if self.serial_moe && self.cfg.experts_at(layer) > 0 {
            return self.moe_layer_serial(layer, h, mask);
        }
        let inflight =
            self.moe_dispatch_in(layer, h, 0, "expert_wait", None, mask)?;
        // Prefill-behind-decode on the per-layer overlapped path: a
        // staged admission advances one layer while this exchange is on
        // the fabric (no-op outside scheduler-backed decode).
        self.advance_admission(1)?;
        self.moe_finish(inflight)
    }

    /// Split-phase MoE, phase 1 of 2: gate, coalesced tagged dispatch, and
    /// the leader-overlap work (all-to-all accounting, PR-MoE residual
    /// branch, combine prep).  Returns with the exchange still on the
    /// fabric; pass the result to [`EpEngine::moe_finish`].  Dense FFN
    /// layers complete here and flow through the same [`InflightMoe`].
    pub fn moe_dispatch(
        &mut self,
        layer: usize,
        h: xla::Literal,
    ) -> Result<InflightMoe> {
        self.moe_dispatch_in(layer, h, 0, "expert_wait", None, None)
    }

    fn moe_dispatch_in(
        &mut self,
        layer: usize,
        h: xla::Literal,
        slot: usize,
        wait_metric: &'static str,
        depth_tag: Option<usize>,
        mask: Option<&[bool]>,
    ) -> Result<InflightMoe> {
        let (m, f) = (self.cfg.d_model, self.cfg.d_ff);
        let pre = format!("layer{layer}.");
        let n_experts = self.cfg.experts_at(layer);
        let t_layer = std::time::Instant::now();
        let shape: Vec<usize> = h
            .array_shape()?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect();
        let t_tokens: usize = shape.iter().product::<usize>() / m;

        if n_experts == 0 {
            let prog = self.prog(&Manifest::key_dense_ffn(m, f, t_tokens))?;
            // dense_ffn operates on [1, T, M]: reshape at the literal level
            // instead of a literal->host->literal round trip.
            let orig_dims: Vec<i64> =
                shape.iter().map(|&d| d as i64).collect();
            let flat = h.reshape(&[1, t_tokens as i64, m as i64])?;
            let out = prog
                .run_literal_refs(&[
                    &flat,
                    self.p(&format!("{pre}ln2.g")),
                    self.p(&format!("{pre}ln2.b")),
                    self.p(&format!("{pre}mlp.w1")),
                    self.p(&format!("{pre}mlp.b1")),
                    self.p(&format!("{pre}mlp.w2")),
                    self.p(&format!("{pre}mlp.b2")),
                ])?
                .remove(0);
            return Ok(InflightMoe {
                layer,
                dispatch_elapsed: t_layer.elapsed(),
                state: InflightState::Done(out.reshape(&orig_dims)?),
            });
        }

        // Phase 1: gate.  [B,S,M] -> [1,T,M] is a literal reshape; only
        // ln(h) and the router probabilities come back to the host (the
        // routing tables need them).
        let t0 = std::time::Instant::now();
        let gate = self.prog(&Manifest::key_gate(m, n_experts, t_tokens))?;
        let flat = h.reshape(&[1, t_tokens as i64, m as i64])?;
        let outs = gate.run_literal_refs(&[
            &flat,
            self.p(&format!("{pre}ln2.g")),
            self.p(&format!("{pre}ln2.b")),
            self.p(&format!("{pre}moe.gate")),
        ])?;
        let ln_h = HostTensor::from_literal(&outs[0])?; // [T, M]
        let probs = HostTensor::from_literal(&outs[1])?; // [T, E]
        self.metrics.observe("gate", t0.elapsed());

        // Dead lanes (retired/free under continuous batching) are masked
        // out of routing here, so they take no expert slot and send no
        // expert traffic.
        let routing = Routing::top1_masked(probs.as_f32()?, n_experts, mask);
        if let Some(i) = self.stats_idx[layer] {
            self.load_stats[i].record_assignments(routing.assignments());
        }

        // Phase 2: coalesced dispatch — one tagged ExpertFfnBatch per
        // owning worker (replica 0 group), all of its expert blocks packed
        // into a single payload whose ownership moves into the channel.
        let t1 = std::time::Instant::now();
        let (ep_degree, owners): (usize, Vec<usize>) = {
            let lp = self.placement.layer(layer).unwrap();
            (lp.ep_degree, (0..n_experts).map(|e| lp.owner(e, 0)).collect())
        };
        let mut worker_experts =
            std::mem::take(&mut self.scratch[slot].worker_experts);
        for list in &mut worker_experts {
            list.clear();
        }
        if worker_experts.len() < self.fabric.n_workers() {
            worker_experts.resize(self.fabric.n_workers(), Vec::new());
        }
        for e in 0..n_experts {
            if routing.counts[e] > 0 {
                worker_experts[owners[e]].push(e);
            }
        }
        let ln_flat = ln_h.as_f32()?;
        self.exchange_seq += 1;
        let exchange_tag = self.exchange_seq;
        let mut outstanding = 0usize;
        for (w, experts) in worker_experts.iter().enumerate() {
            if experts.is_empty() {
                continue;
            }
            let total: usize =
                experts.iter().map(|&e| routing.counts[e]).sum();
            let mut data = Vec::new();
            routing.pack_blocks(ln_flat, m, experts, &mut data);
            self.fabric.dispatch_ffn_batch(
                w,
                ExpertFfnBatch {
                    layer,
                    experts: experts
                        .iter()
                        .map(|&e| (e, routing.counts[e]))
                        .collect(),
                    data: HostTensor::f32(&[total, m], data),
                    tag: exchange_tag,
                },
            )?;
            outstanding += 1;
        }
        self.metrics.observe("dispatch", t1.elapsed());

        // Phase 3: leader overlap — everything that does not depend on the
        // expert outputs runs while the workers execute: all-to-all plan
        // accounting, the PR-MoE fixed residual branch, and the combine
        // buffer prep (pulling the residual stream to the host).
        let t2 = std::time::Instant::now();
        let plan = self.exchange_plan(&routing, ep_degree, m);
        self.metrics.inc("alltoall_bytes", plan.volume() as u64);
        self.metrics.inc("alltoall_hops", plan.hops() as u64);
        let residual: Option<Vec<f32>> = if self.cfg.residual {
            let rb =
                self.prog(&Manifest::key_residual_branch(m, f, t_tokens))?;
            let out = rb
                .run_literal_refs(&[
                    &outs[0], // ln(h) [T, M], no host round trip
                    self.p(&format!("{pre}moe.res.w1")),
                    self.p(&format!("{pre}moe.res.b1")),
                    self.p(&format!("{pre}moe.res.w2")),
                    self.p(&format!("{pre}moe.res.b2")),
                ])?
                .remove(0);
            Some(out.to_vec::<f32>()?)
        } else {
            None
        };
        // Combine prep: the residual stream, pulled to the host once (the
        // [1,T,M] reshape shares h's row-major element order).
        let out_data: Vec<f32> = flat.to_vec()?;
        self.metrics.observe("leader_overlap", t2.elapsed());

        self.open_tags.push(exchange_tag);
        Ok(InflightMoe {
            layer,
            dispatch_elapsed: t_layer.elapsed(),
            state: InflightState::Pending(Box::new(PendingMoe {
                slot,
                shape,
                routing,
                outstanding,
                tag: exchange_tag,
                residual,
                out_data,
                worker_experts,
                results: Vec::new(),
                wait_metric,
                depth_tag,
            })),
        })
    }

    /// Opportunistically drain any already-arrived replies of an in-flight
    /// exchange (non-blocking), so the eventual [`EpEngine::moe_finish`]
    /// wait only covers work that is genuinely still outstanding.
    pub fn poll_inflight(&mut self, inflight: &mut InflightMoe) -> Result<()> {
        let layer = inflight.layer;
        if let InflightState::Pending(p) = &mut inflight.state {
            if p.outstanding > 0 {
                let got = self.fabric.try_collect_ffn_batches(
                    layer,
                    p.tag,
                    &self.open_tags,
                )?;
                p.outstanding -= got.len();
                p.results.extend(got);
            }
        }
        Ok(())
    }

    /// Split-phase MoE, phase 2 of 2: block on the remaining coalesced
    /// replies of this exchange and combine (gate-scale, un-permute,
    /// residual adds) in the same order as the serial path —
    /// bit-identical logits by construction.
    pub fn moe_finish(&mut self, inflight: InflightMoe) -> Result<xla::Literal> {
        let InflightMoe { layer, dispatch_elapsed, state } = inflight;
        let p = match state {
            InflightState::Done(h) => return Ok(h),
            InflightState::Pending(p) => p,
        };
        let m = self.cfg.d_model;

        // Phase 4: wait for the coalesced worker replies still in flight
        // (replies of the *other* open exchange get stashed, tag-keyed).
        let t3 = std::time::Instant::now();
        let mut results = p.results;
        if p.outstanding > 0 {
            results.extend(self.fabric.collect_ffn_batches(
                p.outstanding,
                layer,
                p.tag,
                &self.open_tags,
            )?);
        }
        self.open_tags.retain(|&t| t != p.tag);
        if let Some(depth) = p.depth_tag {
            // Per-depth breakdown: depth sweeps stay attributable from a
            // single metrics report.
            self.metrics.observe_tagged(p.wait_metric, depth, t3.elapsed());
        } else {
            self.metrics.observe(p.wait_metric, t3.elapsed());
        }

        // Phase 5: combine — gate-scale, un-permute (scratch buffer reused
        // across layers), then add the residual branch and the residual
        // stream in the same order as the serial path (bit-identical).
        let t4 = std::time::Instant::now();
        let mut combined = std::mem::take(&mut self.scratch[p.slot].combine);
        {
            let packs: Vec<(&[(usize, usize)], &[f32])> = results
                .iter()
                .map(|r| Ok((r.experts.as_slice(), r.data.as_f32()?)))
                .collect::<Result<_>>()?;
            p.routing.combine_packed(&packs, m, &mut combined)?;
        }
        if let Some(res) = &p.residual {
            for (c, r) in combined.iter_mut().zip(res) {
                *c += *r;
            }
        }
        let mut out_data = p.out_data;
        for (o, c) in out_data.iter_mut().zip(&combined) {
            *o += *c;
        }
        let out = HostTensor::f32(&p.shape, out_data).to_literal()?;
        self.scratch[p.slot].combine = combined;
        self.scratch[p.slot].worker_experts = p.worker_experts;
        self.metrics.observe("combine", t4.elapsed());
        // Dispatch half + finish half: excludes whatever the pipeline
        // interleaved between the two (the per-layer path has no gap).
        self.metrics
            .observe("moe_layer", dispatch_elapsed + t3.elapsed());
        Ok(out)
    }

    /// The pre-overlap serialized MoE path (`DSMOE_SERIAL_MOE=1`): gate →
    /// one message per expert → blocking collect → combine → residual
    /// branch, with the original literal→host→literal staging.  Kept
    /// verbatim as the before/after measurement baseline; must stay
    /// bit-identical to the split-phase pipeline.
    fn moe_layer_serial(
        &mut self,
        layer: usize,
        h: xla::Literal,
        mask: Option<&[bool]>,
    ) -> Result<xla::Literal> {
        let (m, f) = (self.cfg.d_model, self.cfg.d_ff);
        let pre = format!("layer{layer}.");
        let n_experts = self.cfg.experts_at(layer);
        let t_layer = std::time::Instant::now();

        let t0 = std::time::Instant::now();
        let h_host = HostTensor::from_literal(&h)?;
        let t_tokens = h_host.nelems() / m;
        let gate = self.prog(&Manifest::key_gate(m, n_experts, t_tokens))?;
        let shape = h_host.shape.clone();
        let flat = HostTensor::f32(&[1, t_tokens, m], h_host.as_f32()?.to_vec())
            .to_literal()?;
        let outs = gate.run_literal_refs(&[
            &flat,
            self.p(&format!("{pre}ln2.g")),
            self.p(&format!("{pre}ln2.b")),
            self.p(&format!("{pre}moe.gate")),
        ])?;
        let ln_h = HostTensor::from_literal(&outs[0])?; // [T, M]
        let probs = HostTensor::from_literal(&outs[1])?; // [T, E]
        self.metrics.observe("gate", t0.elapsed());

        let routing = Routing::top1_masked(probs.as_f32()?, n_experts, mask);
        if let Some(i) = self.stats_idx[layer] {
            self.load_stats[i].record_assignments(routing.assignments());
        }

        // Log the all-to-all schedule this exchange would use at scale.
        let lp = self.placement.layer(layer).unwrap();
        let plan = self.exchange_plan(&routing, lp.ep_degree, m);
        self.metrics
            .inc("alltoall_bytes", plan.volume() as u64);
        self.metrics.inc("alltoall_hops", plan.hops() as u64);

        // Dispatch expert blocks to their owners (replica 0 group).
        let t1 = std::time::Instant::now();
        let ln_flat = ln_h.as_f32()?;
        let mut inflight = 0usize;
        for e in 0..n_experts {
            if routing.counts[e] == 0 {
                continue;
            }
            let block = routing.expert_block(ln_flat, m, e);
            let owner = lp.owner(e, 0);
            self.fabric.dispatch_ffn(
                owner,
                layer,
                e,
                HostTensor::f32(&[routing.counts[e], m], block),
                e as u64,
            )?;
            inflight += 1;
        }
        let results = self.fabric.collect_ffn(inflight)?;
        self.metrics.observe("expert_exchange", t1.elapsed());

        let mut expert_outputs: Vec<Vec<f32>> =
            vec![Vec::new(); n_experts];
        for (_, e, out, _) in results {
            expert_outputs[e] = out.as_f32()?.to_vec();
        }
        let mut combined = routing.combine(&expert_outputs, m);

        // Residual-MoE fixed branch (PR-MoE): runs at the leader (it is a
        // dense, non-expert computation).
        if self.cfg.residual {
            let rb =
                self.prog(&Manifest::key_residual_branch(m, f, t_tokens))?;
            let lnh_lit =
                HostTensor::f32(&[t_tokens, m], ln_flat.to_vec()).to_literal()?;
            let out = rb
                .run_literal_refs(&[
                    &lnh_lit,
                    self.p(&format!("{pre}moe.res.w1")),
                    self.p(&format!("{pre}moe.res.b1")),
                    self.p(&format!("{pre}moe.res.w2")),
                    self.p(&format!("{pre}moe.res.b2")),
                ])?
                .remove(0);
            let res = HostTensor::from_literal(&out)?;
            for (c, r) in combined.iter_mut().zip(res.as_f32()?) {
                *c += r;
            }
        }

        // Residual add: h + combined.
        let mut out = h_host.as_f32()?.to_vec();
        for (o, c) in out.iter_mut().zip(&combined) {
            *o += c;
        }
        let out = HostTensor::f32(&shape, out).to_literal()?;
        self.metrics.observe("moe_layer", t_layer.elapsed());
        Ok(out)
    }

    /// Build the all-to-all byte matrix this routing implies at EP degree
    /// `ep` (tokens sharded round-robin over workers, as they would be when
    /// each worker owns part of the batch) and plan it with the configured
    /// schedule.
    fn exchange_plan(
        &self,
        routing: &Routing,
        ep: usize,
        m: usize,
    ) -> alltoall::Plan {
        let mut bytes = vec![vec![0usize; ep]; ep];
        for (t, &e) in routing.expert.iter().enumerate() {
            if e >= routing.n_experts {
                continue; // masked token (dead lane): no exchange traffic
            }
            let src = t % ep; // token's home shard
            let dst = e % ep; // expert's owner (round-robin placement)
            if src != dst {
                bytes[src][dst] += m * 4;
            }
        }
        let topo = Topology {
            workers: ep,
            node_size: ep.min(8),
            ts_degree: 1,
        };
        alltoall::plan(self.alltoall, topo, &bytes)
    }

    /// LM head over each lane's last real position.  `h` is
    /// `[lanes, smax, M]`; the last-position rows are gathered **at the
    /// literal level** by the `gather_last_*` AOT program (one `[lanes, M]`
    /// transfer instead of pulling the whole activation); artifact sets
    /// predating that program fall back to a host-side gather.
    fn lm_head_last(
        &mut self,
        h: &xla::Literal,
        lens: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let (m, smax) = (self.cfg.d_model, self.cfg.max_seq);
        let lanes = lens.len();
        let key = Manifest::key_gather_last(m, lanes, smax);
        let last = if self.manifest_keys.manifest.shared_program(&key).is_ok()
        {
            let gather = self.prog(&key)?;
            let lens_lit = HostTensor::i32(
                &[lanes],
                lens.iter().map(|&l| l as i32).collect(),
            )
            .to_literal()?;
            gather.run_literal_refs(&[h, &lens_lit])?.remove(0)
        } else {
            let hd: Vec<f32> = h.to_vec()?;
            let mut last = vec![0f32; lanes * m];
            for lane in 0..lanes {
                let p = lens[lane].max(1) - 1;
                let off = (lane * smax + p) * m;
                last[lane * m..(lane + 1) * m]
                    .copy_from_slice(&hd[off..off + m]);
            }
            HostTensor::f32(&[lanes, m], last).to_literal()?
        };
        self.lm_head_rows(&last, lanes)
    }

    /// LM head over `[lanes, M]` hidden rows, fed straight from the
    /// literal; returns one logits row per lane.
    fn lm_head_rows(
        &mut self,
        h: &xla::Literal,
        lanes: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);
        let prog = self.prog(&Manifest::key_lm_head(v, m, lanes))?;
        let out = prog
            .run_literal_refs(&[
                h,
                self.p("lnf.g"),
                self.p("lnf.b"),
                self.p("tok_emb"),
            ])?
            .remove(0);
        let data: Vec<f32> = out.to_vec()?;
        Ok((0..lanes)
            .map(|lane| data[lane * v..(lane + 1) * v].to_vec())
            .collect())
    }

    pub fn traffic(&self) -> &crate::fabric::Traffic {
        &self.fabric.traffic
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }
}

/// Continuous batching over the expert-parallel engine: the scheduler
/// admits requests via compiled-size admission prefills whose KV is
/// spliced into free lanes of the per-microbatch decode groups (balanced
/// across the two pipeline groups), decode steps run full-lane-group
/// forwards with dead lanes masked out of gate + dispatch, and `release`
/// frees a lane for the next admission.
impl ForwardModel for EpEngine {
    fn model_config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn configure(&mut self, serving: &crate::config::ServingConfig) {
        self.set_pipe_depth(serving.pipe_depth);
    }

    fn metrics(&self) -> std::sync::Arc<Metrics> {
        self.metrics.clone()
    }

    fn set_metrics(&mut self, metrics: std::sync::Arc<Metrics>) {
        self.metrics = metrics;
    }

    fn prefill_sizes(&self) -> Vec<usize> {
        self.prefill_sizes.clone()
    }

    fn lane_count(&self) -> usize {
        self.batch
    }

    fn free_lane_count(&self) -> usize {
        if self.lane_live.is_empty() {
            self.batch
        } else {
            self.lane_live.iter().filter(|&&l| !l).count()
        }
    }

    fn prefill(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<Vec<AdmittedLane>> {
        // Stop-the-world admission: stage and complete back to back (no
        // decode step runs in between).
        self.stage_admission(compiled, reqs)?;
        self.complete_admission()
    }

    fn begin_prefill(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<bool> {
        if self.serial_moe || !self.interleave {
            // The serialized path has no dispatch/finish gap to hide an
            // admission in; DSMOE_NO_INTERLEAVE pins the stop-the-world
            // baseline.
            return Ok(false);
        }
        self.stage_admission(compiled, reqs)?;
        Ok(true)
    }

    fn finish_prefill(&mut self) -> Result<Vec<AdmittedLane>> {
        self.complete_admission()
    }

    fn decode_step(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.batch;
        anyhow::ensure!(tokens.len() == b && pos.len() == b, "lane shape");
        // Rebalance live lanes across the groups if retirement skewed the
        // occupancy (before the forward, so this step already runs even).
        self.maybe_regroup()?;
        if self.lane_ext.iter().enumerate().all(|(p, &e)| p == e) {
            return self.forward_decode(tokens, pos);
        }
        // A past regroup moved lanes: feed the forward in physical order
        // and hand the rows back under the scheduler's external ids.
        let tok: Vec<i32> =
            self.lane_ext.iter().map(|&e| tokens[e]).collect();
        let ps: Vec<i32> = self.lane_ext.iter().map(|&e| pos[e]).collect();
        let rows = self.forward_decode(&tok, &ps)?;
        let mut out = vec![Vec::new(); b];
        for (p, row) in rows.into_iter().enumerate() {
            out[self.lane_ext[p]] = row;
        }
        Ok(out)
    }

    fn release(&mut self, lane: usize) {
        let phys = self.lane_phys.get(lane).copied().unwrap_or(lane);
        if let Some(l) = self.lane_live.get_mut(phys) {
            *l = false;
        }
    }
}

/// Split `batch` lanes into `depth` contiguous groups, sizes as even as
/// possible (the first `batch % depth` groups carry one extra lane):
/// 8 lanes at depth 3 partition as 3/3/2.  `depth` is clamped to
/// `[1, batch]`.
fn partition_lanes(batch: usize, depth: usize) -> Vec<(usize, usize)> {
    let d = depth.clamp(1, batch.max(1));
    let (base, extra) = (batch / d, batch % d);
    let mut out = Vec::with_capacity(d);
    let mut lane0 = 0;
    for g in 0..d {
        let lanes = base + usize::from(g < extra);
        out.push((lane0, lanes));
        lane0 += lanes;
    }
    out
}

/// True if every AOT program a pipeline microbatch of `bh` lanes needs
/// exists in the manifest (prefill and decode shapes).  Evaluated once at
/// engine construction — the manifest never changes afterwards.
fn group_shapes_available(
    manifest: &Manifest,
    cfg: &ModelConfig,
    bh: usize,
) -> bool {
    let (v, m, hh, f, smax) = (
        cfg.vocab_size,
        cfg.d_model,
        cfg.n_heads,
        cfg.d_ff,
        cfg.max_seq,
    );
    let mut keys = vec![
        Manifest::key_embed(v, m, bh, smax),
        Manifest::key_embed(v, m, bh, 1),
        Manifest::key_attn_prefill(m, hh, bh, smax),
        Manifest::key_attn_decode(m, hh, bh, smax),
        Manifest::key_lm_head(v, m, bh),
    ];
    let has_dense = cfg.experts_schedule.iter().any(|&e| e == 0);
    for t in [bh, bh * smax] {
        for (_, e) in cfg.moe_layers() {
            keys.push(Manifest::key_gate(m, e, t));
        }
        if has_dense {
            keys.push(Manifest::key_dense_ffn(m, f, t));
        }
        if cfg.residual {
            keys.push(Manifest::key_residual_branch(m, f, t));
        }
    }
    keys.iter().all(|k| manifest.shared_program(k).is_ok())
}

/// True if every AOT program a scheduler admission prefill needs at lane
/// count `lanes` exists in the manifest (prefill-side shapes only — decode
/// always runs at the full lane group).  `gather_last` is not required:
/// `lm_head_last` falls back to a host-side gather for artifact sets that
/// predate it.
fn prefill_shapes_available(
    manifest: &Manifest,
    cfg: &ModelConfig,
    lanes: usize,
) -> bool {
    let (v, m, hh, f, smax) = (
        cfg.vocab_size,
        cfg.d_model,
        cfg.n_heads,
        cfg.d_ff,
        cfg.max_seq,
    );
    let t = lanes * smax;
    let mut keys = vec![
        Manifest::key_embed(v, m, lanes, smax),
        Manifest::key_attn_prefill(m, hh, lanes, smax),
        Manifest::key_lm_head(v, m, lanes),
    ];
    for (_, e) in cfg.moe_layers() {
        keys.push(Manifest::key_gate(m, e, t));
    }
    if cfg.experts_schedule.iter().any(|&e| e == 0) {
        keys.push(Manifest::key_dense_ffn(m, f, t));
    }
    if cfg.residual {
        keys.push(Manifest::key_residual_branch(m, f, t));
    }
    keys.iter().all(|k| manifest.shared_program(k).is_ok())
}

/// Slice expert `e`'s weights out of the stacked parameter tensors
/// (`moe.w1 [E, M, F]` → `[M, F]`, biases `[E, F]` → `[F]`, …).
fn slice_expert(full: &HostTensor, e: usize, _part: &str) -> Result<HostTensor> {
    let shape = &full.shape;
    anyhow::ensure!(shape.len() >= 2, "stacked expert tensor expected");
    let per: usize = shape[1..].iter().product();
    let data = full.as_f32()?[e * per..(e + 1) * per].to_vec();
    Ok(HostTensor::f32(&shape[1..], data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_expert_extracts_rows() {
        let full = HostTensor::f32(
            &[2, 3],
            vec![1., 2., 3., 10., 20., 30.],
        );
        let e1 = slice_expert(&full, 1, "b1").unwrap();
        assert_eq!(e1.shape, vec![3]);
        assert_eq!(e1.as_f32().unwrap(), &[10., 20., 30.]);
        let full3 = HostTensor::f32(&[2, 2, 2],
                                    vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let e0 = slice_expert(&full3, 0, "w1").unwrap();
        assert_eq!(e0.shape, vec![2, 2]);
        assert_eq!(e0.as_f32().unwrap(), &[0., 1., 2., 3.]);
    }

    #[test]
    fn partition_lanes_even_and_uneven() {
        assert_eq!(partition_lanes(8, 1), vec![(0, 8)]);
        assert_eq!(partition_lanes(8, 2), vec![(0, 4), (4, 4)]);
        assert_eq!(partition_lanes(8, 3), vec![(0, 3), (3, 3), (6, 2)]);
        assert_eq!(
            partition_lanes(8, 4),
            vec![(0, 2), (2, 2), (4, 2), (6, 2)]
        );
        // Depth clamps to the lane count; zero depth means one group.
        assert_eq!(partition_lanes(4, 9).len(), 4);
        assert_eq!(partition_lanes(4, 0), vec![(0, 4)]);
        // Every partition is contiguous and covers the batch exactly.
        for b in 1..=9usize {
            for d in 1..=b {
                let p = partition_lanes(b, d);
                assert_eq!(p.len(), d);
                let mut next = 0;
                for &(lane0, lanes) in &p {
                    assert_eq!(lane0, next);
                    assert!(lanes > 0);
                    next += lanes;
                }
                assert_eq!(next, b);
            }
        }
    }
}
