//! Disaggregated expert-parallel engine (§5's system, at testbed scale).
//!
//! The leader owns the dense backbone (embeddings, attention, layer norms,
//! gates, residual branches, LM head) and drives it layer by layer through
//! the shared AOT programs; fabric workers own the expert FFN weights per
//! the [`Placement`].
//!
//! ## Split-phase MoE
//!
//! Every MoE layer is driven through a two-call API instead of a monolithic
//! FFN call (per-phase latencies land in [`Metrics`] under these names):
//!
//! * [`EpEngine::moe_dispatch`]`(layer, h) -> InflightMoe` runs
//!   1. **`gate`** — the `gate_*` program produces `ln(h)` and router
//!      probabilities (`[B,S,M] → [1,T,M]` stays a literal-level reshape);
//!      host top-1 gating builds the dense token→expert mapping table
//!      ([`Routing`]);
//!   2. **`dispatch`** — token blocks coalesced per owning worker: one
//!      tagged [`crate::fabric::ExpertFfnBatch`] per worker carries all of
//!      that worker's expert blocks in one contiguous payload (the paper's
//!      grouped all-to-all, §5.1) — O(workers) messages per layer;
//!   3. **`leader_overlap`** — while the workers execute: all-to-all plan
//!      accounting, the PR-MoE fixed residual branch, and combine-buffer
//!      prep — then returns with the exchange still out on the fabric.
//! * [`EpEngine::moe_finish`]`(inflight) -> h'` runs
//!   4. **`expert_wait`** (or **`pipeline_bubble`** under the pipelined
//!      driver) — block on the coalesced tagged replies; and
//!   5. **`combine`** — gate-scale and un-permute the packed expert
//!      outputs, then add the residual branch and the residual stream.
//!
//! [`MoeScratch`] is an N-slot pool (one slot per pipeline microbatch plus
//! one for a staged admission prefill), so several tagged exchanges can be
//! in flight at once; a reply from any exchange that is neither being
//! collected nor still open fails loudly (tag-keyed collection in
//! [`crate::fabric::Fabric`]).
//!
//! ## Depth-N microbatch pipeline ring
//!
//! `forward_prefill`/`forward_decode` split the batch into
//! `N = DSMOE_PIPE_DEPTH` (default 2, [`EpEngine::set_pipe_depth`])
//! contiguous microbatch lane groups when the group-sized AOT shapes
//! exist, and drive them through a rotating in-flight ring
//! ([`EpEngine::run_pipeline`]): step `(layer, mb)` dispatches microbatch
//! `mb`'s attention + gate + dispatch; once N exchanges are on the fabric
//! the oldest — the same microbatch one layer earlier, by construction —
//! is finished first.  Every start that runs while another exchange is
//! pending lands in `attn_overlap`; the only exposed wait is the ring
//! fill/drain bubble (`pipeline_bubble`, also broken down per depth as
//! `pipeline_bubble_d{N}`).  Groups are as even as possible (8 lanes at
//! depth 3 run as 3/3/2).  A requested depth whose shape ladder is missing
//! from the artifact set falls back to depth 2, then 1.  Decode KV caches
//! live in per-microbatch lane groups and are repartitioned on the host if
//! the partition changes between forwards.
//!
//! ## Continuous batching (scheduler-backed mode)
//!
//! The engine also implements [`ForwardModel`], so the engine-agnostic
//! [`crate::server::Scheduler`] can drive it with real request admission:
//! an admission prefill runs at a compiled lane count (padding masked),
//! its per-layer KV is spliced into free lanes of the decode groups
//! (admissions balance live load across the N pipeline lane groups),
//! decode steps run the normal full-lane-group forwards with retired/free
//! lanes masked out of gate + dispatch (dead lanes send **no** expert
//! traffic), and released lanes are reused by later admissions.  Live
//! lanes stay bit-identical to the fixed-lane driver; the legacy mode
//! (`forward_prefill`/`forward_decode` with every lane driven explicitly)
//! is untouched and resets the lane state.  Three scheduler-mode
//! capabilities ride on top:
//!
//! * **Prefill-behind-decode interleaving** — `begin_prefill` stages an
//!   admission; each decode-layer exchange the ring puts on the fabric
//!   advances the staged prefill by one layer
//!   ([`EpEngine::advance_admission`]), so admission compute hides behind
//!   decode round trips instead of stopping the world.  The admission's
//!   own exposed wait lands in `prefill_stall`; `finish_prefill` completes
//!   whatever the gaps did not cover and splices the KV.
//! * **Dynamic lane regrouping** — when retirement skews per-group live
//!   occupancy by at least `DSMOE_REGROUP_SKEW` (default 2) lanes, live
//!   lanes migrate into free slots of idler groups before the next decode
//!   step (KV moved through the host mirrors; external lane ids are
//!   preserved via an internal lane permutation, so the scheduler never
//!   observes the move).  Counted in `lane_regroups` / `lane_moves`.
//! * **Host-side KV mirrors** — each lane group keeps per-layer host
//!   copies of its K/V caches (invalidated by decode writes, exactly like
//!   the monolithic engine's `cache_lits`), so admission splices and
//!   regroup moves copy only the touched lanes instead of round-tripping
//!   the whole group's cache per layer.
//!
//! ## Parallel leader shards
//!
//! The ring hides leader compute behind fabric round trips, but the
//! attention/gate/combine of different microbatches still serialize on
//! the one leader thread.  `DSMOE_LEADER_THREADS >= 2`
//! ([`EpEngine::set_leader_threads`] / `ServingConfig::leader_threads` /
//! `--leader-threads`) removes that serialization: each microbatch
//! group's **dense backbone runs on its own OS thread** with its own
//! thread-bound runtime ([`crate::server::shard`] — the same pattern as
//! the fabric workers), owning that group's KV caches and host mirrors.
//! Microbatch B's attention+gate executes on shard 2 *concurrently* with
//! microbatch A's attention on shard 1 while A's experts are on the
//! fabric.  This engine stays the orchestrator: shards hand it prepared
//! coalesced payloads, it tags them, puts them on the fabric, collects
//! replies **oldest-exchange-first** (the ring's dispatch/finish order,
//! over the same tag-keyed exchanges), and routes them back; a staged
//! admission still advances one layer behind each freshly dispatched
//! decode exchange.  Shard busy compute lands in `leader_par`, a shard's
//! exposed reply wait in `shard_idle`, and the `leader_threads` gauge
//! records the thread count each forward ran with.  Caches migrate
//! automatically (host-side) when the thread count or partition toggles
//! between forwards; with the default `leader_threads = 1` nothing
//! changes.  The sharded schedule is bit-identical to the single-threaded
//! leader: both execute the same [`crate::server::shard::Backbone`]
//! methods over the same program shapes, per-lane/per-row independent.
//!
//! ## Env toggles
//!
//! | variable              | effect                                       |
//! |-----------------------|----------------------------------------------|
//! | `DSMOE_SERIAL_MOE`    | serialized per-expert MoE path (pre-overlap  |
//! |                       | baseline): gate → one message per expert →   |
//! |                       | blocking collect → combine; also disables    |
//! |                       | the pipeline ([`EpEngine::set_serial_moe`]). |
//! | `DSMOE_NO_PIPELINE`   | per-layer overlapped path (the pre-pipeline  |
//! |                       | behaviour): split-phase dispatch immediately |
//! |                       | followed by finish, full-batch shapes        |
//! |                       | ([`EpEngine::set_pipeline`]).                |
//! | `DSMOE_PIPE_DEPTH`    | microbatch ring depth N (default 2;          |
//! |                       | [`EpEngine::set_pipe_depth`]; 0/negative/    |
//! |                       | garbage warn and fall back to 2).            |
//! | `DSMOE_LEADER_THREADS`| >= 2: one leader-shard thread per microbatch |
//! |                       | group (default 1 = the single-threaded       |
//! |                       | leader; [`EpEngine::set_leader_threads`]).   |
//! | `DSMOE_NO_INTERLEAVE` | stop-the-world admission prefills (the       |
//! |                       | pre-interleaving scheduler behaviour;        |
//! |                       | [`EpEngine::set_interleave`]).               |
//! | `DSMOE_REGROUP_SKEW`  | live-lane skew (max − min per group) that    |
//! |                       | triggers a regroup; default 2 — a skew of 1  |
//! |                       | is unavoidable whenever live lanes don't     |
//! |                       | divide evenly, so 2 is the smallest          |
//! |                       | actionable imbalance.                        |
//! | `DSMOE_A2A`           | `hierarchical` routes the live expert        |
//! |                       | exchange through the two-stage relay         |
//! |                       | schedule (intra-node gather at a relay       |
//! |                       | worker, then one cross-node message per      |
//! |                       | node); `flat`/unset keeps one message per    |
//! |                       | worker ([`EpEngine::set_a2a_hierarchical`]). |
//! | `DSMOE_NODE_SIZE`     | workers per node for the hierarchical        |
//! |                       | schedule (shared `Topology` parser: must be  |
//! |                       | a positive divisor of the worker count, else |
//! |                       | warn + flat; [`EpEngine::set_node_size`]).   |
//! | `DSMOE_TRANSPORT`     | fabric wire for leader↔worker traffic:       |
//! |                       | `channel` (default, in-process bounded       |
//! |                       | channels) or `socket` (Unix-domain sockets   |
//! |                       | carrying length-prefixed serialized frames;  |
//! |                       | [`EpEngine::new_with_transport`]).           |
//! | `DSMOE_REPLICATE_HOT` | split a replicated expert's token block      |
//! |                       | across its hosting workers and run the       |
//! |                       | between-forwards load-aware rebalancer;      |
//! |                       | unset/`0` (default) keeps the static single- |
//! |                       | owner placement bit-identically              |
//! |                       | ([`EpEngine::set_replicate_hot`]).           |
//! | `DSMOE_REBALANCE_SKEW`| recent (EWMA) max/mean expert-load skew at   |
//! |                       | which the rebalancer replicates the hottest  |
//! |                       | expert (default 2.0; only read when          |
//! |                       | replication is on;                           |
//! |                       | [`EpEngine::set_rebalance_skew`]).           |
//! | `DSMOE_MAX_REPLICAS`  | per-expert replication ceiling for the       |
//! |                       | rebalancer (default: the worker count;       |
//! |                       | [`EpEngine::set_max_replicas`]).             |
//! | `DSMOE_EXPERT_DTYPE`  | expert-FFN weight ladder shipped to the      |
//! |                       | workers: `f32` (default), `bf16`, or         |
//! |                       | `int8`/`i8` (per-output-channel scales).     |
//! |                       | Workers dequantize once at install time and  |
//! |                       | compute in f32; shrinks both the startup     |
//! |                       | ship and every migration payload.  Gated on  |
//! |                       | the manifest's capability flags              |
//! |                       | ([`EpEngine::set_expert_dtype`]).            |
//! | `DSMOE_WIRE_DTYPE`    | dispatch/combine activation payload dtype on |
//! |                       | the fabric: `f32` (default, bitwise          |
//! |                       | identical) or `f16`/`bf16` — halves the      |
//! |                       | per-layer all-to-all bytes under both the    |
//! |                       | flat and hierarchical schedules; workers     |
//! |                       | widen, compute f32, and reply in the wire    |
//! |                       | dtype ([`EpEngine::set_wire_dtype`]).  The   |
//! |                       | serialized baseline stays f32 either way.    |
//! | `DSMOE_PREFILL_CHUNK` | chunked prefill: prompt-token budget a       |
//! |                       | staged admission may advance per decode step |
//! |                       | (`ceil(budget / live prompt tokens)` layers  |
//! |                       | per step, at least 1), so a huge prompt's    |
//! |                       | admission spreads over several decode steps. |
//! |                       | Default 0 = off — the admission completes    |
//! |                       | behind one decode step, the pre-chunking     |
//! |                       | behavior ([`EpEngine::set_prefill_chunk`]).  |
//! | `DSMOE_QUEUE_CAP`     | scheduler front door: bounded per-tier       |
//! |                       | admission queues (0 = unbounded, default).   |
//! |                       | Enforced by the router, not this engine.     |
//! | `DSMOE_SHED_POLICY`   | `reject` (default) sheds the overflowing new |
//! |                       | arrival; `drop-oldest` sheds the tier's      |
//! |                       | stalest waiter instead.  Router-level.       |
//! | `DSMOE_FAULT_TOLERANCE`| survive worker death/hangs: blocking expert |
//! |                       | collects get a deadline, a miss triggers a   |
//! |                       | probe sweep + failover (experts re-homed     |
//! |                       | onto survivors, placement epoch bumped) and  |
//! |                       | the forward re-executes bit-identically.     |
//! |                       | Unset/`0` (default) keeps the infallible     |
//! |                       | path byte-identical                          |
//! |                       | ([`EpEngine::set_fault_tolerance`]).         |
//! | `DSMOE_EXCHANGE_TIMEOUT_MS`| deadline on blocking expert-exchange    |
//! |                       | waits when fault tolerance is on (default    |
//! |                       | 30000; [`EpEngine::set_exchange_timeout`]).  |
//! | `DSMOE_FT_PROBE_TIMEOUT_MS`| per-sweep pong wait of the worker       |
//! |                       | health probe (default 1000;                  |
//! |                       | [`EpEngine::set_probe_params`]).             |
//! | `DSMOE_FT_DEAD_AFTER` | consecutive missed probes before a worker is |
//! |                       | declared dead (default 2; a closed wire is   |
//! |                       | dead immediately).                           |
//! | `DSMOE_FT_RECOVER_AFTER`| clean probes before a suspect worker is    |
//! |                       | healthy again (default 2).                   |
//! | `DSMOE_FT_RETRIES`    | forward re-executions per fabric fault       |
//! |                       | before the error propagates to the scheduler |
//! |                       | fold (default 3; [`EpEngine::set_ft_retries`]).|
//!
//! All paths — serial, overlapped, pipelined at any depth, single- or
//! multi-threaded leader — produce **bit-identical** logits for prefill
//! and decode (asserted at depths 2, 3 and 4, and for
//! `leader_threads ∈ {1, N}`, in `integration_parity.rs`);
//! `benches/e2e_serving.rs` compares their forward latencies, exposed
//! waits, the depth sweep, interleaved vs stop-the-world admission, and
//! the leader-parallel study into `BENCH_e2e.json`.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::TryRecvError;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{AllToAllKind, ModelConfig};
use crate::coordinator::kv_cache::{copy_lane, split_lanes};
use crate::coordinator::rebalance::Action;
use crate::coordinator::{Placement, Rebalancer, Request, Routing};
use crate::fabric::{
    A2aMode, ExpertFfnBatch, Fabric, FfnBatchResult, TransportKind,
    WorkerPrograms,
};
use crate::metrics::Metrics;
use crate::moe::ExpertLoadStats;
use crate::runtime::{
    Checkpoint, Dtype, HostTensor, Manifest, SharedArtifacts,
};
use crate::server::scheduler::{AdmittedLane, ForwardModel};
use crate::server::shard::{
    Backbone, LaneWrite, MoeScratch, PoolSpec, Prepared, PreparedMoe,
    ShardCmd, ShardEvent, ShardPool,
};
use crate::util::{env_pos_f64, env_pos_usize};

pub struct EpEngine {
    /// The dense backbone bound to *this* thread (programs, dense weight
    /// literals): the single-threaded leader's compute, and the shared
    /// implementation every leader shard also runs.
    bb: Backbone,
    /// The thread-shareable artifact set leader shards materialize their
    /// own backbones from.
    arts: SharedArtifacts,
    pub cfg: ModelConfig,
    placement: Placement,
    fabric: Fabric,
    pub metrics: Arc<Metrics>,
    pub load_stats: Vec<ExpertLoadStats>,
    /// `stats_idx[layer]` = index into `load_stats` (None for dense
    /// layers): O(1) per-layer lookup instead of a linear scan.
    stats_idx: Vec<Option<usize>>,
    alltoall: AllToAllKind,
    /// Workers per node for the live hierarchical dispatch
    /// (`DSMOE_NODE_SIZE` via the shared `Topology::node_size_from_env`
    /// parser); applied to the fabric whenever hierarchical routing is
    /// (re)enabled.
    node_size: usize,
    /// Decode KV caches in per-microbatch lane groups; each group holds
    /// per-layer `[lanes, H, Smax, hd]` tensors (monolithic layout is
    /// `[L, B, ...]`).  One group when the pipeline is off, N when on.
    caches: Vec<LaneGroupCaches>,
    batch: usize,
    /// `DSMOE_SERIAL_MOE`: run the old serialized per-expert MoE path
    /// instead of the overlapped/coalesced pipeline (for measurement).
    serial_moe: bool,
    /// `DSMOE_NO_PIPELINE` (inverted): microbatch-interleave forwards when
    /// the group-sized program shapes are available.
    pipeline: bool,
    /// Requested microbatch ring depth (`DSMOE_PIPE_DEPTH`, default 2);
    /// the resolved depth falls back 2 → 1 when shapes are missing.
    pipe_depth: usize,
    /// `depth_ok[d]`: the manifest has every program shape the d-group
    /// lane partition needs (computed once at construction).
    depth_ok: Vec<bool>,
    /// Lane partition of the forward currently in flight (its group
    /// count); keys the per-depth metric breakdowns.
    active_depth: usize,
    /// `DSMOE_NO_INTERLEAVE` (inverted): admission prefills run behind
    /// in-flight decode exchanges instead of stopping the world.
    interleave: bool,
    /// `DSMOE_PREFILL_CHUNK`: prompt-token budget a staged admission may
    /// advance per decode step (0 = off: the admission completes behind a
    /// single decode step, the pre-chunking behavior).  With a budget, a
    /// large admission spreads across as many decode steps as it needs —
    /// `ceil(budget / live prompt tokens)` layers per step — so one giant
    /// prompt no longer monopolizes the lane group's step time.
    prefill_chunk: usize,
    /// Hidden-advance budget for the decode step in flight: how many more
    /// admission layers the prefill-behind-decode sites may run this step
    /// (`usize::MAX` when chunking is off — never throttle).  Reset by
    /// `decode_step`; `complete_admission` is exempt (it drains whatever
    /// remains).
    admission_allowance: usize,
    /// Live-lane skew (max − min per group) that triggers a regroup
    /// (`DSMOE_REGROUP_SKEW`, default 2).
    regroup_skew: usize,
    /// `DSMOE_REPLICATE_HOT`: hot-expert replication on the dispatch path
    /// plus the between-forwards load-aware rebalancer.  Off (default)
    /// preserves the static single-owner placement bit-identically.
    replicate_hot: bool,
    /// Recent max/mean expert-load skew at which the rebalancer
    /// replicates the hottest expert (`DSMOE_REBALANCE_SKEW`, default
    /// 2.0, clamped to >= 1).
    rebalance_skew: f64,
    /// Per-expert replication ceiling (`DSMOE_MAX_REPLICAS`, default:
    /// the worker count — replicas live on distinct workers).
    max_replicas: usize,
    /// Expert-FFN weight ladder shipped to the workers
    /// (`DSMOE_EXPERT_DTYPE`, default f32 — the uncompressed baseline).
    /// Workers dequantize to f32 once at install, so the AOT expert
    /// programs are dtype-agnostic; only the ship payload shrinks.
    expert_dtype: Dtype,
    /// Dispatch/combine activation payload dtype on the fabric
    /// (`DSMOE_WIRE_DTYPE`, default f32 — that path is pure moves, so the
    /// default stays bitwise identical to the uncompressed engine).
    wire_dtype: Dtype,
    /// Requested leader shard threads (`DSMOE_LEADER_THREADS`, default
    /// 1): >= 2 runs each microbatch group's dense backbone on its own
    /// thread-bound runtime.
    leader_threads: usize,
    /// The leader-shard pool (spawned lazily for the active partition;
    /// threads joined on drop).
    shards: Option<ShardPool>,
    /// True while the decode KV cache groups live inside the shard pool
    /// rather than in `caches`.
    shard_caches: bool,
    /// Test-only slow-shard injection, applied at the next pool spawn.
    slow_shard: Option<(usize, std::time::Duration)>,
    /// Shard completion order of the most recent sharded forward.
    shard_completions: Vec<usize>,
    /// Routing/combine scratch pool: one slot per pipeline microbatch
    /// (index = microbatch) plus a dedicated slot (index = `batch`) for a
    /// staged admission prefill.
    scratch: Vec<MoeScratch>,
    /// Monotonic exchange generation: stamped into every coalesced batch
    /// so stale replies of an aborted exchange (even at the same layer of
    /// a retried forward) can never be combined into a later one.
    exchange_seq: u64,
    /// Tags of exchanges currently out on the fabric (at most the ring
    /// depth plus a staged admission): the collector stashes replies for
    /// these instead of failing.
    open_tags: Vec<u64>,
    /// Continuous-batching lane occupancy (scheduler-backed mode):
    /// `lane_live[phys]` is true while a live request occupies the
    /// physical lane.  Dead lanes are masked out of gate + dispatch so
    /// they send no expert traffic.  Empty in the legacy fixed-lane mode
    /// (no masking — every lane is driven explicitly), which keeps that
    /// path bit-identical to the pre-refactor engine.
    lane_live: Vec<bool>,
    /// Scheduler-visible lane id → physical lane slot.  Identity until a
    /// regroup migrates live lanes between groups; external ids stay
    /// stable for a request's whole lifetime.  Empty in legacy mode.
    lane_phys: Vec<usize>,
    /// Inverse of `lane_phys`: physical slot → external lane id.
    lane_ext: Vec<usize>,
    /// Admission prefill staged by `begin_prefill`, advanced layer by
    /// layer behind in-flight decode exchanges.
    pending_admission: Option<AdmissionState>,
    /// Compiled lane counts at which a scheduler admission prefill can run
    /// (every prefill-side program shape exists in the manifest).
    prefill_sizes: Vec<usize>,
    /// `DSMOE_FAULT_TOLERANCE`: exchange deadlines + probe sweeps +
    /// worker failover + forward retries.  Off (default) keeps the
    /// infallible dispatch path byte-identical.
    fault_tolerance: bool,
    /// Deadline on blocking expert-exchange waits while fault tolerance
    /// is on (`DSMOE_EXCHANGE_TIMEOUT_MS`, default 30s).
    exchange_timeout: std::time::Duration,
    /// Pong wait of one worker-health probe sweep
    /// (`DSMOE_FT_PROBE_TIMEOUT_MS`, default 1s).
    probe_timeout: std::time::Duration,
    /// Consecutive missed probes before a worker is declared dead
    /// (`DSMOE_FT_DEAD_AFTER`, default 2).
    ft_dead_after: u32,
    /// Clean probes before a suspect worker is healthy again
    /// (`DSMOE_FT_RECOVER_AFTER`, default 2).
    ft_recover_after: u32,
    /// Forward re-executions per fabric fault before the error escapes to
    /// the scheduler's fold-and-requeue seam (`DSMOE_FT_RETRIES`,
    /// default 3).
    ft_retries: usize,
}

/// Decode KV caches for one contiguous lane group (a pipeline microbatch).
/// Owned by the engine on the single-threaded paths, or by that group's
/// leader shard when `leader_threads >= 2`.
pub(crate) struct LaneGroupCaches {
    pub(crate) lane0: usize,
    pub(crate) lanes: usize,
    pub(crate) k: Vec<xla::Literal>,
    pub(crate) v: Vec<xla::Literal>,
    /// Per-layer host mirrors of `k`/`v` (`None` = stale, repulled on
    /// demand): admission splices and regroup moves write through these so
    /// only the touched lanes are copied; decode writes invalidate the
    /// touched layer (the monolithic engine's `cache_lits`, per group).
    hk: Vec<Option<HostTensor>>,
    hv: Vec<Option<HostTensor>>,
}

impl LaneGroupCaches {
    pub(crate) fn new(
        lane0: usize,
        lanes: usize,
        n_layers: usize,
    ) -> LaneGroupCaches {
        LaneGroupCaches {
            lane0,
            lanes,
            k: Vec::with_capacity(n_layers),
            v: Vec::with_capacity(n_layers),
            hk: Vec::with_capacity(n_layers),
            hv: Vec::with_capacity(n_layers),
        }
    }

    /// Append one layer's freshly computed caches (mirror starts stale).
    pub(crate) fn push_kv(&mut self, k: xla::Literal, v: xla::Literal) {
        self.k.push(k);
        self.v.push(v);
        self.hk.push(None);
        self.hv.push(None);
    }

    /// Append one layer's caches from host tensors (mirror starts valid).
    pub(crate) fn push_host(
        &mut self,
        k: HostTensor,
        v: HostTensor,
    ) -> Result<()> {
        self.k.push(k.to_literal()?);
        self.v.push(v.to_literal()?);
        self.hk.push(Some(k));
        self.hv.push(Some(v));
        Ok(())
    }

    /// Host mirror of layer `layer`'s K cache, pulling from the literal
    /// only when stale.
    pub(crate) fn host_k(&mut self, layer: usize) -> Result<&mut HostTensor> {
        if self.hk[layer].is_none() {
            self.hk[layer] = Some(HostTensor::from_literal(&self.k[layer])?);
        }
        Ok(self.hk[layer].as_mut().unwrap())
    }

    pub(crate) fn host_v(&mut self, layer: usize) -> Result<&mut HostTensor> {
        if self.hv[layer].is_none() {
            self.hv[layer] = Some(HostTensor::from_literal(&self.v[layer])?);
        }
        Ok(self.hv[layer].as_mut().unwrap())
    }

    /// Rebuild layer `layer`'s literals from its (valid) host mirrors.
    pub(crate) fn push_layer(&mut self, layer: usize) -> Result<()> {
        if let Some(h) = &self.hk[layer] {
            self.k[layer] = h.to_literal()?;
        }
        if let Some(h) = &self.hv[layer] {
            self.v[layer] = h.to_literal()?;
        }
        Ok(())
    }

    /// Decode wrote layer `layer`'s caches: the host mirror is stale.
    pub(crate) fn invalidate(&mut self, layer: usize) {
        self.hk[layer] = None;
        self.hv[layer] = None;
    }

    /// Move layer `layer`'s (validated) host mirrors out, leaving the
    /// mirror stale — for cache migration, where this container is about
    /// to be dropped anyway; avoids cloning the whole KV cache.
    pub(crate) fn take_host(
        &mut self,
        layer: usize,
    ) -> Result<(HostTensor, HostTensor)> {
        self.host_k(layer)?;
        self.host_v(layer)?;
        Ok((
            self.hk[layer].take().unwrap(),
            self.hv[layer].take().unwrap(),
        ))
    }
}

/// A staged admission prefill ([`EpEngine::stage_admission`]): advanced
/// one layer at a time behind in-flight decode exchanges
/// ([`EpEngine::advance_admission`]) and completed — LM head, KV splice,
/// lane activation — by [`EpEngine::complete_admission`].
struct AdmissionState {
    /// Compiled lane count of the prefill programs.
    compiled: usize,
    /// Leading lanes that carry real prompts (the rest is padding).
    live: usize,
    /// Per compiled lane: prompt length (padding lanes: 1).
    lens: Vec<usize>,
    /// Free physical lanes the admitted requests will occupy.
    lanes: Vec<usize>,
    /// Padding mask over the `compiled * smax` prefill tokens.
    mask: Option<Vec<bool>>,
    /// Activation after the last completed layer.
    h: Option<xla::Literal>,
    /// Next layer to run.
    layer: usize,
    /// Per completed layer: `[compiled, H, Smax, hd]` K/V caches.
    kv: Vec<(xla::Literal, xla::Literal)>,
    /// Leader time spent on this admission across interleaved steps
    /// (observed as `forward_prefill` at completion).
    elapsed: std::time::Duration,
}

/// What kind of forward the shared interleave scheduler
/// ([`EpEngine::run_pipeline`]) is driving, with the per-microbatch state
/// its start step needs.
enum PipeCtx<'a> {
    /// Prefill: KV cache groups being built layer by layer.
    Prefill(&'a mut [LaneGroupCaches]),
    /// Decode: per-microbatch position literals.
    Decode(&'a [xla::Literal]),
}

/// A split-phase MoE layer whose expert exchange may still be on the
/// fabric: produced by [`EpEngine::moe_dispatch`], consumed by
/// [`EpEngine::moe_finish`].  Dense FFN layers complete at dispatch time
/// and carry their result through the same type so pipeline drivers treat
/// every layer uniformly.
pub struct InflightMoe {
    layer: usize,
    /// Leader time spent in the dispatch half (gate → leader overlap).
    /// `moe_layer` is recorded as this plus the finish half, so the
    /// pipelined path's number measures the layer's own cost and not the
    /// partner microbatch's work interleaved between the two halves.
    dispatch_elapsed: std::time::Duration,
    state: InflightState,
}

enum InflightState {
    /// Dense FFN — nothing on the fabric, result already computed.
    Done(xla::Literal),
    Pending(Box<PendingMoe>),
}

struct PendingMoe {
    slot: usize,
    /// Original `h` dims, restored on combine.
    shape: Vec<usize>,
    routing: Routing,
    /// Worker replies not yet received.
    outstanding: usize,
    tag: u64,
    /// PR-MoE fixed-branch output (leader-side), if the model has one.
    residual: Option<Vec<f32>>,
    /// Residual stream pulled to the host (combine accumulates into it).
    out_data: Vec<f32>,
    /// Taken from the slot's [`MoeScratch`], returned at finish.
    worker_experts: Vec<Vec<(usize, usize, usize)>>,
    results: Vec<FfnBatchResult>,
    /// Metric the exposed wait lands in: `expert_wait` on the per-layer
    /// path, `pipeline_bubble` under the pipelined driver,
    /// `prefill_stall` for a staged admission's layers.
    wait_metric: &'static str,
    /// Ring depth to break the wait metric down by (`{metric}_d{N}`),
    /// captured at dispatch time where the active partition is
    /// authoritative; `None` = no per-depth breakdown.
    depth_tag: Option<usize>,
}

impl InflightMoe {
    /// True while the expert exchange is (possibly) still on the fabric.
    pub fn pending(&self) -> bool {
        matches!(self.state, InflightState::Pending(_))
    }

    pub fn layer(&self) -> usize {
        self.layer
    }
}

/// Parse `DSMOE_A2A` into "hierarchical live dispatch?".  Unset or
/// `flat` keeps the flat per-worker schedule; `hierarchical` (or the
/// short form `hier`) enables the §5.3 two-stage relay schedule.  Any
/// other value warns and falls back to flat so a typo can never
/// silently change the dispatch path.
/// Parse a dtype env toggle (`DSMOE_EXPERT_DTYPE` / `DSMOE_WIRE_DTYPE`).
/// Unset/empty keeps the f32 default; `int8` is accepted as an alias for
/// `i8`; anything else outside `allowed` warns and falls back to f32, so
/// a typo can never silently change the data path.
fn dtype_from_env(var: &str, allowed: &[Dtype]) -> Dtype {
    let Ok(v) = std::env::var(var) else { return Dtype::F32 };
    let s = v.trim();
    if s.is_empty() {
        return Dtype::F32;
    }
    let parsed = match s {
        "int8" => Some(Dtype::I8),
        _ => Dtype::parse(s),
    };
    match parsed {
        Some(d) if allowed.contains(&d) => d,
        _ => {
            let names: Vec<&str> =
                allowed.iter().map(|d| d.name()).collect();
            eprintln!(
                "[config] {var}={s:?} is not one of {names:?}; \
                 falling back to f32"
            );
            Dtype::F32
        }
    }
}

fn a2a_hier_from_env() -> bool {
    match std::env::var("DSMOE_A2A") {
        Ok(v) => match v.trim() {
            "hierarchical" | "hier" => true,
            "flat" | "" => false,
            other => {
                eprintln!(
                    "[config] DSMOE_A2A={other:?} is not \"flat\" or \
                     \"hierarchical\"; falling back to flat dispatch"
                );
                false
            }
        },
        Err(_) => false,
    }
}

impl EpEngine {
    pub fn new(
        manifest: &Manifest,
        model: &str,
        workers: usize,
        alltoall: AllToAllKind,
        batch: usize,
    ) -> Result<EpEngine> {
        Self::new_with_transport(
            manifest,
            model,
            workers,
            alltoall,
            batch,
            TransportKind::from_env(),
        )
    }

    /// [`EpEngine::new`] with an explicit fabric transport (the transport
    /// is fixed at worker spawn time; `new` reads `DSMOE_TRANSPORT`).
    /// Exposed so tests and benches can compare channel vs. socket fabrics
    /// in one process without racing on the environment.
    pub fn new_with_transport(
        manifest: &Manifest,
        model: &str,
        workers: usize,
        alltoall: AllToAllKind,
        batch: usize,
        transport: TransportKind,
    ) -> Result<EpEngine> {
        let model_arts = manifest.model(model)?;
        let cfg = model_arts.config.clone();
        anyhow::ensure!(cfg.is_moe(), "EP engine needs an MoE model");

        let ck = Checkpoint::load(&model_arts.checkpoint_dir)?;
        let mut params_host = HashMap::new();
        for (n, t) in ck.names.iter().zip(&ck.tensors) {
            params_host.insert(n.clone(), t.clone());
        }

        // Expert FFN program ladder for the fabric workers.
        let (m, f) = (cfg.d_model, cfg.d_ff);
        let ladder: Vec<_> = manifest
            .expert_block_sizes()
            .into_iter()
            .filter_map(|c| {
                manifest
                    .shared_program(&Manifest::key_expert_ffn(m, f, c))
                    .ok()
                    .map(|s| (c, s.clone()))
            })
            .collect();
        anyhow::ensure!(!ladder.is_empty(), "no expert_ffn programs for m{m} f{f}");

        let placement = Placement::for_model(&cfg, workers);
        let mut fabric = Fabric::spawn_with(
            workers,
            WorkerPrograms { expert_ffn: ladder },
            transport,
        )?;
        // Live-dispatch all-to-all routing: flat by default, the §5.3
        // hierarchical schedule behind `DSMOE_A2A=hierarchical`, node size
        // from the single shared `DSMOE_NODE_SIZE` parser.
        let node_size =
            crate::coordinator::alltoall::Topology::node_size_from_env(workers);
        if a2a_hier_from_env() {
            fabric.set_a2a(A2aMode::Hierarchical { node_size });
        }

        // Compressed data-path toggles, gated on what this artifact set
        // declares it supports (v1 manifests default to f32-only): an
        // unsupported request warns and keeps the f32 baseline rather
        // than serving a mode the artifact build never promised.
        let mut expert_dtype = dtype_from_env(
            "DSMOE_EXPERT_DTYPE",
            &[Dtype::F32, Dtype::BF16, Dtype::I8],
        );
        if !manifest.capabilities.supports_expert_dtype(expert_dtype.name())
        {
            eprintln!(
                "[config] DSMOE_EXPERT_DTYPE={} is not in this artifact \
                 set's expert_dtypes capabilities {:?}; falling back to \
                 f32 (rebuild the artifacts with a schema-v2 aot.py)",
                expert_dtype.name(),
                manifest.capabilities.expert_dtypes,
            );
            expert_dtype = Dtype::F32;
        }
        let mut wire_dtype = dtype_from_env(
            "DSMOE_WIRE_DTYPE",
            &[Dtype::F32, Dtype::F16, Dtype::BF16],
        );
        if !manifest.capabilities.supports_wire_dtype(wire_dtype.name()) {
            eprintln!(
                "[config] DSMOE_WIRE_DTYPE={} is not in this artifact \
                 set's wire_dtypes capabilities {:?}; falling back to \
                 f32 (rebuild the artifacts with a schema-v2 aot.py)",
                wire_dtype.name(),
                manifest.capabilities.wire_dtypes,
            );
            wire_dtype = Dtype::F32;
        }

        // Ship expert weights to their owners, encoded in the expert
        // ladder dtype (workers dequantize once at install).
        for w in 0..workers {
            for (layer, e) in placement.worker_manifest(w) {
                let weights = ["w1", "b1", "w2", "b2"]
                    .iter()
                    .map(|part| {
                        let full = &params_host
                            [&format!("layer{layer}.moe.{part}")];
                        Ok(slice_expert(full, e, part)?)
                    })
                    .collect::<Result<Vec<_>>>()?;
                let weights =
                    encode_expert_weights(weights, expert_dtype)?;
                fabric.load_expert(w, layer, e, weights)?;
            }
        }

        // Fault tolerance (armed only after the startup weight ship: a
        // worker dying during construction is a hard error — there is
        // nothing to fail over to yet).
        let fault_tolerance = std::env::var_os("DSMOE_FAULT_TOLERANCE")
            .is_some_and(|v| v != "0");
        let exchange_timeout = std::time::Duration::from_millis(
            env_pos_usize("DSMOE_EXCHANGE_TIMEOUT_MS", 30_000) as u64,
        );
        if fault_tolerance {
            fabric.set_exchange_deadline(Some(exchange_timeout));
        }

        let load_stats: Vec<ExpertLoadStats> = cfg
            .moe_layers()
            .into_iter()
            .map(|(i, e)| ExpertLoadStats::new(i, e))
            .collect();
        let mut stats_idx = vec![None; cfg.n_layers];
        for (i, s) in load_stats.iter().enumerate() {
            stats_idx[s.layer] = Some(i);
        }
        // Which microbatch ring depths this artifact set supports: depth d
        // partitions the batch into d contiguous groups, and every group
        // size needs its full prefill+decode program ladder.
        let depth_ok: Vec<bool> = (0..=batch)
            .map(|d| {
                d >= 1
                    && partition_lanes(batch, d).iter().all(|&(_, lanes)| {
                        group_shapes_available(manifest, &cfg, lanes)
                    })
            })
            .collect();

        // Compiled lane counts a scheduler admission prefill can run at:
        // the standard AOT ladder filtered by what this artifact set
        // actually exports (older sets may only have the full batch).
        let mut prefill_sizes: Vec<usize> = [1usize, 2, 3, 4, 8, 16, 32]
            .into_iter()
            .chain([batch])
            .filter(|&s| s <= batch)
            .filter(|&s| prefill_shapes_available(manifest, &cfg, s))
            .collect();
        prefill_sizes.sort();
        prefill_sizes.dedup();
        if prefill_sizes.is_empty() {
            // forward_prefill needs the full-batch shapes anyway; admission
            // will surface the missing-program error on first use.
            prefill_sizes.push(batch);
        }

        // One thread-shareable artifact set feeds this thread's backbone
        // and every leader shard's.
        let arts = SharedArtifacts::new(manifest.clone(), params_host);
        let metrics = Arc::new(Metrics::new());
        let replicate_hot = std::env::var_os("DSMOE_REPLICATE_HOT")
            .is_some_and(|v| v != "0");
        let mut bb = Backbone::new(
            arts.clone(),
            cfg.clone(),
            placement.clone(),
            alltoall,
            workers,
            metrics.clone(),
        )?;
        bb.replicate_hot = replicate_hot;
        bb.wire_dtype = wire_dtype;

        Ok(EpEngine {
            bb,
            arts,
            cfg,
            placement,
            fabric,
            metrics,
            load_stats,
            stats_idx,
            alltoall,
            node_size,
            caches: Vec::new(),
            batch,
            serial_moe: std::env::var_os("DSMOE_SERIAL_MOE")
                .is_some_and(|v| v != "0"),
            pipeline: !std::env::var_os("DSMOE_NO_PIPELINE")
                .is_some_and(|v| v != "0"),
            pipe_depth: env_pos_usize("DSMOE_PIPE_DEPTH", 2),
            depth_ok,
            active_depth: 1,
            interleave: !std::env::var_os("DSMOE_NO_INTERLEAVE")
                .is_some_and(|v| v != "0"),
            prefill_chunk: crate::util::env_usize_off(
                "DSMOE_PREFILL_CHUNK",
                0,
            ),
            admission_allowance: usize::MAX,
            regroup_skew: env_pos_usize("DSMOE_REGROUP_SKEW", 2),
            replicate_hot,
            rebalance_skew: env_pos_f64("DSMOE_REBALANCE_SKEW", 2.0)
                .max(1.0),
            max_replicas: env_pos_usize("DSMOE_MAX_REPLICAS", workers),
            expert_dtype,
            wire_dtype,
            leader_threads: env_pos_usize("DSMOE_LEADER_THREADS", 1),
            shards: None,
            shard_caches: false,
            slow_shard: None,
            shard_completions: Vec::new(),
            scratch: (0..=batch).map(|_| MoeScratch::default()).collect(),
            exchange_seq: 0,
            open_tags: Vec::new(),
            lane_live: Vec::new(),
            lane_phys: Vec::new(),
            lane_ext: Vec::new(),
            pending_admission: None,
            prefill_sizes,
            fault_tolerance,
            exchange_timeout,
            probe_timeout: std::time::Duration::from_millis(
                env_pos_usize("DSMOE_FT_PROBE_TIMEOUT_MS", 1000) as u64,
            ),
            ft_dead_after: env_pos_usize("DSMOE_FT_DEAD_AFTER", 2) as u32,
            ft_recover_after: env_pos_usize("DSMOE_FT_RECOVER_AFTER", 2)
                as u32,
            ft_retries: env_pos_usize("DSMOE_FT_RETRIES", 3),
        })
    }

    /// Select the serialized (`true`) or overlapped/coalesced (`false`)
    /// MoE data path.  Defaults to the `DSMOE_SERIAL_MOE` env toggle;
    /// exposed programmatically so tests and benches can compare both paths
    /// in one process without racing on the environment.
    pub fn set_serial_moe(&mut self, serial: bool) {
        self.serial_moe = serial;
    }

    pub fn serial_moe(&self) -> bool {
        self.serial_moe
    }

    /// Route the live expert exchange through the hierarchical (two-stage
    /// relay) all-to-all schedule instead of the flat per-worker one.
    /// Defaults to the `DSMOE_A2A` env toggle; exposed programmatically so
    /// parity tests and benches can compare both schedules in one process
    /// without racing on the environment.  The node size applied is the
    /// engine's current [`EpEngine::node_size`].
    pub fn set_a2a_hierarchical(&mut self, hier: bool) {
        if hier {
            let node_size = self.node_size;
            self.fabric.set_a2a(A2aMode::Hierarchical { node_size });
        } else {
            self.fabric.set_a2a(A2aMode::Flat);
        }
    }

    pub fn a2a_hierarchical(&self) -> bool {
        matches!(self.fabric.a2a(), A2aMode::Hierarchical { .. })
    }

    /// Override the workers-per-node grouping used by the hierarchical
    /// schedule (defaults to `DSMOE_NODE_SIZE` via the shared
    /// `Topology::node_size_from_env` parser).  Re-applies immediately if
    /// hierarchical routing is already active; the fabric itself still
    /// falls back to flat when the value does not divide the worker count.
    pub fn set_node_size(&mut self, node_size: usize) {
        self.node_size = node_size.max(1);
        if self.a2a_hierarchical() {
            let node_size = self.node_size;
            self.fabric.set_a2a(A2aMode::Hierarchical { node_size });
        }
    }

    pub fn node_size(&self) -> usize {
        self.node_size
    }

    /// Enable/disable the microbatch-interleaved pipeline (defaults to the
    /// inverse of the `DSMOE_NO_PIPELINE` env toggle).  Even when enabled
    /// the engine falls back to the per-layer path unless the group-sized
    /// program shapes exist in the manifest.
    pub fn set_pipeline(&mut self, pipeline: bool) {
        self.pipeline = pipeline;
    }

    pub fn pipeline(&self) -> bool {
        self.pipeline
    }

    /// Request a microbatch ring depth (defaults to `DSMOE_PIPE_DEPTH`,
    /// default 2).  Clamped to the lane count; a depth whose program
    /// shapes are missing from the artifact set falls back to 2, then 1
    /// (see [`EpEngine::microbatches`] for the resolved value).
    pub fn set_pipe_depth(&mut self, depth: usize) {
        self.pipe_depth = depth;
    }

    pub fn pipe_depth(&self) -> usize {
        self.pipe_depth
    }

    /// Enable/disable prefill-behind-decode admission interleaving
    /// (defaults to the inverse of the `DSMOE_NO_INTERLEAVE` env toggle).
    pub fn set_interleave(&mut self, interleave: bool) {
        self.interleave = interleave;
    }

    pub fn interleave(&self) -> bool {
        self.interleave
    }

    /// Prompt-token budget a staged admission may advance per decode step
    /// (defaults to `DSMOE_PREFILL_CHUNK`; 0 = off — the admission
    /// completes behind a single decode step).  Chunking needs the
    /// interleaved admission seam, so it has no effect when
    /// `DSMOE_NO_INTERLEAVE` / `DSMOE_SERIAL_MOE` force the
    /// stop-the-world path.
    pub fn set_prefill_chunk(&mut self, tokens: usize) {
        self.prefill_chunk = tokens;
    }

    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Live-lane skew (max − min across groups) that triggers a dynamic
    /// regroup before a decode step; clamped to at least 1.
    pub fn set_regroup_skew(&mut self, skew: usize) {
        self.regroup_skew = skew.max(1);
    }

    /// Enable/disable hot-expert replication on the live dispatch path
    /// (defaults to the `DSMOE_REPLICATE_HOT` env toggle).  On, the gate
    /// splits a replicated expert's token block contiguously across its
    /// hosting workers and the between-forwards rebalancer watches the
    /// EWMA load histograms; off preserves the static single-owner pack
    /// byte-for-byte.  Applied at the next forward — placement epochs
    /// only ever move between forwards.
    pub fn set_replicate_hot(&mut self, on: bool) -> Result<()> {
        self.replicate_hot = on;
        self.apply_placement()
    }

    pub fn replicate_hot(&self) -> bool {
        self.replicate_hot
    }

    /// Recent max/mean expert-load skew at which the rebalancer
    /// replicates the hottest expert (defaults to
    /// `DSMOE_REBALANCE_SKEW`, default 2.0); clamped to at least 1.0
    /// (1.0 = replicate on any imbalance at all).
    pub fn set_rebalance_skew(&mut self, skew: f64) {
        self.rebalance_skew = skew.max(1.0);
    }

    pub fn rebalance_skew(&self) -> f64 {
        self.rebalance_skew
    }

    /// Per-expert replication ceiling for the rebalancer (defaults to
    /// `DSMOE_MAX_REPLICAS`, default: the worker count); clamped to at
    /// least 1.
    pub fn set_max_replicas(&mut self, r: usize) {
        self.max_replicas = r.max(1);
    }

    pub fn max_replicas(&self) -> usize {
        self.max_replicas
    }

    /// Select the expert-FFN weight ladder shipped to the workers
    /// (defaults to `DSMOE_EXPERT_DTYPE`): `f32` (the uncompressed
    /// baseline), `bf16`, or `i8` (per-output-channel scales).  Changing
    /// the dtype re-ships every placed expert — including replicas — over
    /// the fabric's blocking load path, so call only between forwards.
    /// Workers dequantize to f32 at install; the AOT expert programs are
    /// untouched.  Exposed programmatically so benches and parity tests
    /// can sweep the ladder in one process without racing on the
    /// environment (no capability gate here — the env path gates on the
    /// manifest's capability flags).
    pub fn set_expert_dtype(&mut self, dtype: Dtype) -> Result<()> {
        anyhow::ensure!(
            matches!(dtype, Dtype::F32 | Dtype::BF16 | Dtype::I8),
            "{dtype} is not an expert weight ladder dtype (f32/bf16/i8)"
        );
        if dtype == self.expert_dtype {
            return Ok(());
        }
        self.expert_dtype = dtype;
        debug_assert!(self.open_tags.is_empty());
        for w in 0..self.fabric.n_workers() {
            for (layer, e) in self.placement.worker_manifest(w) {
                self.ship_expert(layer, e, w)?;
            }
        }
        Ok(())
    }

    pub fn expert_dtype(&self) -> Dtype {
        self.expert_dtype
    }

    /// Select the dispatch/combine activation payload dtype on the fabric
    /// (defaults to `DSMOE_WIRE_DTYPE`): `f32` (the default — that path
    /// is pure moves, bitwise identical), `f16`, or `bf16`.  Applied to
    /// this engine's backbone and pushed to any live leader shards; call
    /// only between forwards (like every placement-epoch toggle), so no
    /// in-flight exchange ever mixes wire dtypes.  The serialized
    /// baseline (`DSMOE_SERIAL_MOE`) stays f32 either way.
    pub fn set_wire_dtype(&mut self, dtype: Dtype) -> Result<()> {
        anyhow::ensure!(
            matches!(dtype, Dtype::F32 | Dtype::F16 | Dtype::BF16),
            "{dtype} is not an activation wire dtype (f32/f16/bf16)"
        );
        debug_assert!(self.open_tags.is_empty());
        self.wire_dtype = dtype;
        self.bb.wire_dtype = dtype;
        if let Some(pool) = &self.shards {
            for g in 0..pool.handles.len() {
                pool.send(g, ShardCmd::SetWireDtype(dtype))?;
            }
        }
        Ok(())
    }

    pub fn wire_dtype(&self) -> Dtype {
        self.wire_dtype
    }

    /// Bench/test hook: route every live token to `expert` (scaled by
    /// that expert's own gate probability) instead of the gate's argmax —
    /// a deterministic worst-case hot-expert workload for the
    /// replication study.  `None` restores real routing.  Applies to the
    /// leader's backbone (the serial and single-threaded paths); leader
    /// shards keep real routing.
    pub fn set_route_pin(&mut self, expert: Option<usize>) {
        self.bb.force_expert = expert;
    }

    /// Toggle fault tolerance programmatically (defaults to
    /// `DSMOE_FAULT_TOLERANCE`; tests and benches set it here so runs
    /// never race on the environment).  On: blocking expert collects get
    /// the exchange deadline and faults take the probe → failover →
    /// retry path.  Off: the deadline is disarmed and every wait is the
    /// original infallible block — byte-identical to the pre-FT engine.
    pub fn set_fault_tolerance(&mut self, on: bool) {
        self.fault_tolerance = on;
        self.fabric
            .set_exchange_deadline(on.then_some(self.exchange_timeout));
    }

    pub fn fault_tolerance(&self) -> bool {
        self.fault_tolerance
    }

    /// Deadline on blocking expert-exchange waits
    /// (`DSMOE_EXCHANGE_TIMEOUT_MS`); re-arms the fabric when fault
    /// tolerance is on.
    pub fn set_exchange_timeout(&mut self, d: std::time::Duration) {
        self.exchange_timeout = d;
        if self.fault_tolerance {
            self.fabric.set_exchange_deadline(Some(d));
        }
    }

    /// Worker-health probe knobs (`DSMOE_FT_PROBE_TIMEOUT_MS`,
    /// `DSMOE_FT_DEAD_AFTER`, `DSMOE_FT_RECOVER_AFTER`).
    pub fn set_probe_params(
        &mut self,
        timeout: std::time::Duration,
        dead_after: u32,
        recover_after: u32,
    ) {
        self.probe_timeout = timeout;
        self.ft_dead_after = dead_after.max(1);
        self.ft_recover_after = recover_after.max(1);
    }

    /// Forward re-executions per fabric fault before the error escapes to
    /// the scheduler (`DSMOE_FT_RETRIES`; 0 = always escalate).
    pub fn set_ft_retries(&mut self, n: usize) {
        self.ft_retries = n;
    }

    /// Install a deterministic chaos plan on the fabric transport (kill /
    /// delay / drop / garble — tests and the `fault_tolerance` bench
    /// study).
    pub fn set_fault_plan(&mut self, plan: crate::fabric::FaultPlan) {
        self.fabric.install_fault_plan(plan);
    }

    /// Health classification of one fabric worker (test observability).
    pub fn worker_state(&self, w: usize) -> crate::fabric::WorkerState {
        self.fabric.worker_state(w)
    }

    /// Deterministic migration hook for studies and tests: replicate
    /// expert `expert` of every MoE layer onto the least-expert-loaded
    /// non-hosting workers until it has `r` hosts, shipping weights over
    /// the fabric exactly like an online migration, then bump the
    /// placement epoch.  Call only between forwards.
    pub fn force_replicas(&mut self, expert: usize, r: usize) -> Result<()> {
        let layers: Vec<usize> =
            self.placement.layers.keys().copied().collect();
        let mut ships: Vec<(usize, usize)> = Vec::new();
        for layer in layers {
            let lp = self.placement.layer_mut(layer).unwrap();
            if expert >= lp.n_experts {
                continue;
            }
            let cap = r.min(lp.experts_of.len());
            while lp.replication(expert) < cap {
                let to = (0..lp.experts_of.len())
                    .filter(|&w| !lp.experts_of[w].contains(&expert))
                    .min_by_key(|&w| (lp.experts_of[w].len(), w))
                    .context("no worker left to replicate onto")?;
                assert!(lp.add_replica(expert, to));
                ships.push((layer, to));
            }
            let max_r = lp.max_replication();
            self.metrics
                .gauge(&format!("expert_replicas_l{layer}"), max_r as f64);
        }
        for (layer, to) in ships {
            self.ship_expert(layer, expert, to)?;
            self.metrics.inc("expert_migrations", 1);
        }
        self.apply_placement()
    }

    /// Ship one expert's weights to a worker over the fabric's blocking
    /// load path (the worker acks before any later exchange can reach
    /// it), sliced from the shared host-side checkpoint exactly as at
    /// engine construction and encoded in the active expert ladder dtype
    /// — a bf16 migration payload is half the f32 one, int8 about a
    /// quarter.
    fn ship_expert(&mut self, layer: usize, e: usize, w: usize) -> Result<()> {
        let weights = {
            let params = self.arts.params();
            ["w1", "b1", "w2", "b2"]
                .iter()
                .map(|part| {
                    let full = params
                        .get(&format!("layer{layer}.moe.{part}"))
                        .with_context(|| {
                            format!("missing layer{layer}.moe.{part}")
                        })?;
                    slice_expert(full, e, part)
                })
                .collect::<Result<Vec<_>>>()?
        };
        let weights = encode_expert_weights(weights, self.expert_dtype)?;
        self.fabric.load_expert(w, layer, e, weights)
    }

    /// Propagate the current placement epoch to every placement reader —
    /// this engine's backbone and any live leader-shard pool.  Called
    /// only between forwards (no open tagged exchanges), so no in-flight
    /// exchange ever observes a torn placement.
    fn apply_placement(&mut self) -> Result<()> {
        debug_assert!(self.open_tags.is_empty());
        self.bb.placement = self.placement.clone();
        self.bb.replicate_hot = self.replicate_hot;
        if let Some(pool) = &self.shards {
            for g in 0..pool.handles.len() {
                pool.send(
                    g,
                    ShardCmd::SetPlacement {
                        placement: self.placement.clone(),
                        replicate_hot: self.replicate_hot,
                    },
                )?;
            }
        }
        Ok(())
    }

    /// The migration half of hot-expert replication: after a forward
    /// completes (all exchanges collected), read each MoE layer's EWMA
    /// load histogram, let the [`Rebalancer`] propose placement changes,
    /// ship weights for new replicas over `fabric.load_expert`, and bump
    /// the placement epoch before the next forward dispatches.  No-op
    /// unless `DSMOE_REPLICATE_HOT` is on.
    fn maybe_rebalance(&mut self) -> Result<()> {
        if !self.replicate_hot {
            return Ok(());
        }
        let policy = Rebalancer {
            skew_threshold: self.rebalance_skew,
            max_replicas: self.max_replicas.min(self.fabric.n_workers()),
        };
        let plans: Vec<(usize, Vec<Action>)> = self
            .load_stats
            .iter()
            .filter_map(|s| {
                let lp = self.placement.layer(s.layer)?;
                let acts = policy.plan(lp, s.recent_histogram());
                (!acts.is_empty()).then_some((s.layer, acts))
            })
            .collect();
        let mut events = 0u64;
        for (layer, acts) in plans {
            let mut applied = false;
            for a in acts {
                match a {
                    Action::Replicate { expert, to, .. } => {
                        let lp = self.placement.layer_mut(layer).unwrap();
                        if lp.add_replica(expert, to) {
                            self.ship_expert(layer, expert, to)?;
                            self.metrics.inc("expert_migrations", 1);
                            applied = true;
                        }
                    }
                    Action::Dereplicate { expert, from, .. } => {
                        let lp = self.placement.layer_mut(layer).unwrap();
                        // Dropping a host just stops splitting tokens to
                        // it; its stale weights are harmless.
                        applied |= lp.remove_replica(expert, from);
                    }
                }
            }
            if applied {
                events += 1;
                let max_r =
                    self.placement.layer(layer).unwrap().max_replication();
                self.metrics.gauge(
                    &format!("expert_replicas_l{layer}"),
                    max_r as f64,
                );
            }
        }
        if events > 0 {
            self.metrics.inc("rebalance_events", events);
            self.apply_placement()?;
        }
        Ok(())
    }

    /// Classify a fabric fault for the report counters: a missed exchange
    /// deadline is an `exchange_timeout` (dead or hung worker), anything
    /// else (e.g. a garbled reply frame surfacing as a worker error) a
    /// `worker_error`.
    fn note_fault(&self, e: &anyhow::Error) {
        if format!("{e:#}").contains("deadline") {
            self.metrics.inc("exchange_timeouts", 1);
        } else {
            self.metrics.inc("worker_errors", 1);
        }
    }

    /// The failure path behind every fault-tolerant retry: abort all open
    /// exchanges (stash drained, partial replies discarded — never
    /// combined), drop any staged admission (its prefill re-runs from
    /// scratch), sweep worker health, and fail over each newly dead
    /// worker.  After this the fabric is quiescent and the placement
    /// epoch reflects only live workers, so the retried forward
    /// re-executes bit-identically — replicas and re-shipped experts hold
    /// byte-identical weights wherever they live.
    fn recover_from_fault(&mut self) -> Result<()> {
        let t = std::time::Instant::now();
        let tags = std::mem::take(&mut self.open_tags);
        self.fabric.abort_open_exchanges(&tags);
        self.pending_admission = None;
        let report = self.fabric.probe_workers(
            self.probe_timeout,
            self.ft_dead_after,
            self.ft_recover_after,
        )?;
        for w in report.newly_dead {
            self.failover_worker(w)?;
        }
        self.metrics.observe("ft_recovery", t.elapsed());
        Ok(())
    }

    /// Live expert failover for a declared-dead worker: plan replications
    /// that keep every expert it hosted on a live replica-group-0 worker
    /// (dispatch derives destinations from `owner(e, 0)`), re-ship those
    /// weights from the shared checkpoint over the fabric's blocking load
    /// path, evict the worker from every layer, and bump the placement
    /// epoch exactly like an online rebalance.  The worker is marked dead
    /// on the fabric first, so hierarchical relays re-route around it and
    /// probe sweeps skip it from now on.
    fn failover_worker(&mut self, w: usize) -> Result<()> {
        self.metrics.inc("worker_deaths", 1);
        self.fabric.mark_dead(w);
        let dead: Vec<bool> = (0..self.fabric.n_workers())
            .map(|x| self.fabric.is_dead(x))
            .collect();
        let layers: Vec<usize> =
            self.placement.layers.keys().copied().collect();
        let mut ships: Vec<(usize, usize, usize)> = Vec::new();
        for layer in layers {
            let lp = self.placement.layer_mut(layer).unwrap();
            for a in Rebalancer::plan_failover(lp, w, &dead) {
                if let Action::Replicate { expert, to, .. } = a {
                    if lp.add_replica(expert, to) {
                        ships.push((layer, expert, to));
                    }
                }
            }
            lp.evict_worker(w);
            let max_r = lp.max_replication();
            self.metrics
                .gauge(&format!("expert_replicas_l{layer}"), max_r as f64);
        }
        for (layer, e, to) in ships {
            self.ship_expert(layer, e, to)?;
            self.metrics.inc("expert_migrations", 1);
        }
        self.metrics.inc("failovers", 1);
        self.apply_placement()
    }

    /// Request leader shard threads (defaults to `DSMOE_LEADER_THREADS`,
    /// default 1 — the single-threaded leader).  Any value >= 2 runs each
    /// pipeline microbatch group's dense backbone on its own thread-bound
    /// runtime ([`crate::server::shard`]); takes effect at the next
    /// forward, with KV caches migrating automatically between the leader
    /// and the shards.
    pub fn set_leader_threads(&mut self, n: usize) {
        self.leader_threads = n.max(1);
    }

    pub fn leader_threads(&self) -> usize {
        self.leader_threads
    }

    /// Leader shard threads the next forward will actually run with: one
    /// per microbatch group when sharding is enabled and the resolved
    /// ring depth has at least two groups, else 1 (serial / no-pipeline /
    /// depth-1 paths have a single microbatch stream — nothing to split).
    pub fn leader_shards(&self) -> usize {
        self.resolved_leader_threads()
    }

    fn resolved_leader_threads(&self) -> usize {
        let groups = self.resolved_depth();
        if self.leader_threads >= 2 && groups >= 2 {
            groups
        } else {
            1
        }
    }

    /// Shard completion order of the most recent sharded forward (test
    /// observability for the slow-shard ordering invariant).
    pub fn last_shard_completions(&self) -> &[usize] {
        &self.shard_completions
    }

    /// Test hook: make shard `shard` sleep `delay` before every layer of
    /// a sharded forward, forcing shard completion out of submission
    /// order.  Applied when the pool (re)spawns — set it before the first
    /// sharded forward.
    #[doc(hidden)]
    pub fn inject_slow_shard(
        &mut self,
        shard: usize,
        delay: std::time::Duration,
    ) {
        self.slow_shard = Some((shard, delay));
    }

    /// Live lanes per decode lane group (scheduler-backed mode; empty
    /// groups report 0 in legacy mode), wherever the caches live.
    pub fn group_live_counts(&self) -> Vec<usize> {
        let groups = self.cache_groups();
        self.live_counts_for(&groups)
    }

    fn live_counts_for(&self, groups: &[(usize, usize)]) -> Vec<usize> {
        groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln)
                    .filter(|&l| {
                        self.lane_live.get(l).copied().unwrap_or(false)
                    })
                    .count()
            })
            .collect()
    }

    /// Current decode cache partition, wherever the caches live (the
    /// engine's own groups, or the shard pool's).
    fn cache_groups(&self) -> Vec<(usize, usize)> {
        if self.shard_caches {
            self.shards
                .as_ref()
                .map(|p| p.groups.clone())
                .unwrap_or_default()
        } else {
            self.caches.iter().map(|c| (c.lane0, c.lanes)).collect()
        }
    }

    /// The metrics registry is swappable (benches install a fresh one
    /// between warmup and measurement, sometimes by assigning the public
    /// field directly); propagate the current registry to the backbone
    /// and any live shards so per-phase timers keep landing where the
    /// caller reads them.
    fn sync_metrics(&mut self) {
        if !Arc::ptr_eq(&self.bb.metrics, &self.metrics) {
            self.bb.metrics = self.metrics.clone();
            if let Some(pool) = &self.shards {
                for g in 0..pool.handles.len() {
                    let _ = pool
                        .send(g, ShardCmd::SetMetrics(self.metrics.clone()));
                }
            }
        }
    }

    /// True if this artifact set carries every program shape the d-group
    /// lane partition needs.
    pub fn depth_supported(&self, depth: usize) -> bool {
        depth >= 1 && depth <= self.batch && self.depth_ok[depth]
    }

    /// Number of microbatches the next forward will run with: the
    /// requested ring depth when the pipeline is active and its shapes
    /// exist, else the fallback (2, then 1).
    pub fn microbatches(&self) -> usize {
        self.resolved_depth()
    }

    /// Resolve the requested ring depth against the toggles and the
    /// artifact set: serial / no-pipeline force 1; otherwise the ladder is
    /// requested depth → 2 → 1.
    fn resolved_depth(&self) -> usize {
        if self.serial_moe || !self.pipeline {
            return 1;
        }
        let want = self.pipe_depth.clamp(1, self.batch.max(1));
        if want <= 1 {
            return 1;
        }
        if self.depth_ok[want] {
            return want;
        }
        if want > 2 && self.batch >= 2 && self.depth_ok[2] {
            return 2;
        }
        1
    }

    /// Contiguous `(lane0, lanes)` microbatch groups for the next forward:
    /// the resolved ring depth's partition (sizes as even as possible),
    /// one full-batch group when the pipeline is off.
    fn lane_groups(&self) -> Vec<(usize, usize)> {
        partition_lanes(self.batch, self.resolved_depth())
    }

    /// Full prefill over padded prompts [B, smax]; returns last-position
    /// logits per lane at `lens[b]-1` and primes the decode caches.
    ///
    /// With `DSMOE_FAULT_TOLERANCE`, a fabric fault (dead/hung worker,
    /// garbled reply) triggers abort → probe → failover and up to
    /// `DSMOE_FT_RETRIES` re-executions; a prefill rebuilds every lane
    /// from the tokens, so a retried run is bit-identical to an unfaulted
    /// one.
    pub fn forward_prefill(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let mut attempt = 0usize;
        loop {
            match self.forward_prefill_inner(tokens, lens) {
                Ok(out) => return Ok(out),
                Err(e) => {
                    if !self.should_retry_fault(&e, attempt) {
                        return Err(e);
                    }
                    attempt += 1;
                    self.retry_recover(&e)?;
                }
            }
        }
    }

    /// Retry gate shared by the forward wrappers: an engine-local retry
    /// is worthwhile only when fault tolerance is on, the error is a
    /// recoverable fabric fault, retries remain, and no staged admission
    /// is in flight — an interrupted staged admission must escape to the
    /// scheduler, whose fold re-queues the staged requests (an
    /// engine-local retry would silently lose them).  A propagated error
    /// keeps its type chain so the scheduler's `try_recover` can still
    /// classify it.
    fn should_retry_fault(&self, e: &anyhow::Error, attempt: usize) -> bool {
        self.fault_tolerance
            && self.pending_admission.is_none()
            && crate::fabric::is_fault(e)
            && attempt < self.ft_retries
    }

    /// Count and run one recovery ahead of a forward retry.
    fn retry_recover(&mut self, e: &anyhow::Error) -> Result<()> {
        self.metrics.inc("ft_retries", 1);
        self.note_fault(e);
        self.recover_from_fault()
            .with_context(|| format!("recovering from fault: {e:#}"))
    }

    fn forward_prefill_inner(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let (b, smax) = (self.batch, self.cfg.max_seq);
        anyhow::ensure!(tokens.len() == b * smax, "tokens shape");
        anyhow::ensure!(lens.len() == b, "lens shape");
        // Range-check here so the literal-level gather and the host
        // fallback in lm_head_last fail identically (the AOT program would
        // silently clip, the host path would panic).
        anyhow::ensure!(
            lens.iter().all(|&l| l <= smax),
            "prompt length exceeds max_seq {smax}"
        );
        // A staged admission holds requests whose KV is mid-flight;
        // silently dropping it here would lose them.  The scheduler always
        // finishes a staged admission within the same step, so this can
        // only be an API misuse — fail loudly.
        anyhow::ensure!(
            self.pending_admission.is_none(),
            "forward_prefill with a staged admission (finish_prefill first)"
        );
        self.sync_metrics();
        let t_fwd = std::time::Instant::now();
        // Exchanges of an aborted earlier forward are no longer open: any
        // reply of theirs that straggles in must fail loudly, not sit in
        // the stash forever.
        self.open_tags.clear();
        // A full fixed-lane prefill rebuilds every lane: back to legacy
        // mode (no lane occupancy, no dead-lane masking, identity lane
        // permutation).
        self.lane_live.clear();
        self.lane_phys.clear();
        self.lane_ext.clear();
        let groups = self.lane_groups();
        self.active_depth = groups.len();
        self.metrics.gauge("pipe_depth", groups.len() as f64);
        let threads = self.resolved_leader_threads();
        self.metrics.gauge("leader_threads", threads as f64);
        let out = if threads > 1 {
            self.prefill_sharded(tokens, lens, &groups)?
        } else {
            // Lanes are rebuilt on the leader: whatever a pool still
            // holds is stale, and its threads/runtimes/weight copies are
            // dead weight on the single-threaded path — release it.
            self.drop_shards();
            if groups.len() > 1 {
                self.prefill_pipelined(tokens, lens, &groups)?
            } else {
                self.prefill_single(tokens, lens)?
            }
        };
        self.metrics.observe("forward_prefill", t_fwd.elapsed());
        // Between-forwards rebalance window: every exchange of this
        // forward is collected, so a placement epoch bump is safe.
        self.maybe_rebalance()?;
        Ok(out)
    }

    /// Single-microbatch prefill: the per-layer (serial or overlapped)
    /// data path over full-batch program shapes.
    fn prefill_single(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.batch;
        let mut h = self.bb.embed_prefill(tokens, b)?;

        let mut group = LaneGroupCaches::new(0, b, self.cfg.n_layers);
        for layer in 0..self.cfg.n_layers {
            let (h2, k, vv) = self.bb.attn_prefill(layer, h, b)?;
            group.push_kv(k, vv);
            h = self.ffn_layer(layer, h2, None)?;
        }
        self.caches = vec![group];

        self.bb.lm_head_last(&h, lens)
    }

    /// Microbatch-interleaved prefill: while one microbatch's expert blocks
    /// are on the fabric for layer L, the leader runs the other
    /// microbatch's attention + gate + dispatch, so only the fill/drain
    /// bubble of the pipeline is an exposed wait.
    fn prefill_pipelined(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
        groups: &[(usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let smax = self.cfg.max_seq;
        let n_layers = self.cfg.n_layers;

        let mut cache_groups: Vec<LaneGroupCaches> = groups
            .iter()
            .map(|&(lane0, lanes)| LaneGroupCaches::new(lane0, lanes, n_layers))
            .collect();
        let mut hs: Vec<Option<xla::Literal>> =
            Vec::with_capacity(groups.len());
        for &(lane0, lanes) in groups {
            hs.push(Some(self.bb.embed_prefill(
                &tokens[lane0 * smax..(lane0 + lanes) * smax],
                lanes,
            )?));
        }

        self.run_pipeline(&mut hs, &mut PipeCtx::Prefill(&mut cache_groups))?;
        self.caches = cache_groups;

        let mut rows = Vec::with_capacity(self.batch);
        for (g, &(lane0, lanes)) in groups.iter().enumerate() {
            let h = hs[g].take().unwrap();
            rows.extend(
                self.bb.lm_head_last(&h, &lens[lane0..lane0 + lanes])?,
            );
        }
        Ok(rows)
    }

    /// Legacy full prefill with the dense backbone sharded: one leader
    /// shard per microbatch group runs embed → attention → gate → combine
    /// for its lanes concurrently with the others, while this thread
    /// orchestrates the tagged expert exchanges on the fabric
    /// (oldest-exchange-first).  The shards end up owning the freshly
    /// built KV cache groups.
    fn prefill_sharded(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
        groups: &[(usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let smax = self.cfg.max_seq;
        // Lanes are rebuilt in the shards; local groups are stale.
        self.caches = Vec::new();
        self.shard_caches = false;
        self.ensure_pool(groups)?;
        let cmds: Vec<ShardCmd> = groups
            .iter()
            .map(|&(lane0, lanes)| ShardCmd::Prefill {
                tokens: tokens[lane0 * smax..(lane0 + lanes) * smax]
                    .to_vec(),
                lens: lens[lane0..lane0 + lanes].to_vec(),
            })
            .collect();
        let rows = self.drive_shards(cmds, false)?;
        self.shard_caches = true;
        Ok(rows.into_iter().flatten().collect())
    }

    /// The microbatch-interleave scheduler shared by prefill and decode: a
    /// rotating ring of at most `hs.len()` in-flight layer exchanges.
    /// Step `(layer, mb)` dispatches microbatch `mb`'s attention + gate +
    /// dispatch; once the ring is full the oldest in-flight entry — the
    /// same microbatch at the previous layer, by construction — is
    /// finished first, so each microbatch's layers run in order while up
    /// to N exchanges share the fabric.  Starts that run while another
    /// exchange is pending land in `attn_overlap`; a staged admission
    /// prefill advances one layer behind each freshly dispatched decode
    /// exchange.  `hs` holds each microbatch's activation and is left
    /// holding the final layer outputs.
    fn run_pipeline(
        &mut self,
        hs: &mut [Option<xla::Literal>],
        ctx: &mut PipeCtx<'_>,
    ) -> Result<()> {
        let n_layers = self.cfg.n_layers;
        let n_mb = hs.len();
        let mut ring: VecDeque<(usize, InflightMoe)> =
            VecDeque::with_capacity(n_mb);
        for layer in 0..n_layers {
            for mb in 0..n_mb {
                if ring.len() == n_mb {
                    // The front is (mb, layer - 1): finishing it frees
                    // exactly the microbatch this step starts.
                    let (fmb, fl) = ring.pop_front().unwrap();
                    debug_assert_eq!(fmb, mb);
                    hs[fmb] = Some(self.moe_finish(fl)?);
                }
                let t = std::time::Instant::now();
                let h = hs[mb].take().unwrap();
                let fl = self.start_layer(layer, h, mb, ctx)?;
                if ring.iter().any(|(_, f)| f.pending()) {
                    self.metrics.observe_tagged(
                        "attn_overlap",
                        self.active_depth,
                        t.elapsed(),
                    );
                }
                ring.push_back((mb, fl));
                // Prefill-behind-decode: a staged admission advances one
                // layer while this step's exchange is on the fabric
                // (throttled by the chunked-prefill budget, if any).
                if matches!(ctx, PipeCtx::Decode(_)) {
                    self.advance_admission_hidden()?;
                }
                // Opportunistic drain: replies already arrived for the
                // next entry to finish shorten its eventual bubble.
                if let Some((_, f)) = ring.front_mut() {
                    self.poll_inflight(f)?;
                }
            }
        }
        while let Some((mb, fl)) = ring.pop_front() {
            hs[mb] = Some(self.moe_finish(fl)?);
        }
        Ok(())
    }

    /// One microbatch's attention + split-phase dispatch at one layer,
    /// dispatched on the pipeline kind.
    fn start_layer(
        &mut self,
        layer: usize,
        h: xla::Literal,
        mb: usize,
        ctx: &mut PipeCtx<'_>,
    ) -> Result<InflightMoe> {
        match ctx {
            PipeCtx::Prefill(groups) => {
                self.start_prefill(layer, h, &mut groups[mb], mb)
            }
            PipeCtx::Decode(pos) => self.start_decode(layer, h, &pos[mb], mb),
        }
    }

    /// Attention + split-phase dispatch for one prefill microbatch layer.
    fn start_prefill(
        &mut self,
        layer: usize,
        h: xla::Literal,
        cache: &mut LaneGroupCaches,
        slot: usize,
    ) -> Result<InflightMoe> {
        let (h2, k, vv) = self.bb.attn_prefill(layer, h, cache.lanes)?;
        cache.push_kv(k, vv);
        // Legacy full prefill drives every lane: no mask.
        self.moe_dispatch_in(
            layer,
            h2,
            slot,
            "pipeline_bubble",
            Some(self.active_depth),
            None,
        )
    }

    /// One decode step over [B] tokens at per-lane positions.
    pub fn forward_decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        // Fault-tolerant retry loop (see `forward_prefill`): a decode
        // step reads KV below each lane's position and writes only at it,
        // so re-execution after a failover is bit-identical.
        let mut attempt = 0usize;
        loop {
            match self.forward_decode_inner(tokens, pos) {
                Ok(out) => return Ok(out),
                Err(e) => {
                    if !self.should_retry_fault(&e, attempt) {
                        return Err(e);
                    }
                    attempt += 1;
                    self.retry_recover(&e)?;
                }
            }
        }
    }

    fn forward_decode_inner(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.batch;
        anyhow::ensure!(tokens.len() == b && pos.len() == b);
        anyhow::ensure!(
            !self.caches.is_empty() || self.shard_caches,
            "decode before prefill"
        );
        self.sync_metrics();
        let t_fwd = std::time::Instant::now();
        // See forward_prefill: aborted exchanges are no longer open.
        self.open_tags.clear();
        let groups = self.lane_groups();
        self.active_depth = groups.len();
        self.metrics.gauge("pipe_depth", groups.len() as f64);
        let threads = self.resolved_leader_threads();
        self.metrics.gauge("leader_threads", threads as f64);
        // A toggle between forwards (pipeline on/off, depth change,
        // leader threads on/off) changes the lane partition or the cache
        // home; place the cache groups before decoding.
        let out = if threads > 1 {
            self.place_caches_in_shards(&groups)?;
            self.decode_sharded(tokens, pos, &groups)?
        } else {
            self.place_caches_local(&groups)?;
            // No pool may outlive the switch to single-threaded decode
            // (threads, runtimes, and dense-weight copies are per shard).
            self.drop_shards();
            if groups.len() > 1 {
                self.decode_pipelined(tokens, pos, &groups)?
            } else {
                self.decode_single(tokens, pos)?
            }
        };
        self.metrics.observe("forward_decode", t_fwd.elapsed());
        // Between-forwards rebalance window (see forward_prefill).
        self.maybe_rebalance()?;
        Ok(out)
    }

    fn decode_single(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.batch;
        let m = self.cfg.d_model;

        let pos_lit = HostTensor::i32(&[b], pos.to_vec()).to_literal()?;
        let mut h = self.bb.embed_decode(tokens, &pos_lit, b)?;

        let mask = self.decode_mask(0, b);
        for layer in 0..self.cfg.n_layers {
            h = self.attn_decode(layer, h, &pos_lit, 0)?;
            h = self.ffn_layer(layer, h, mask.as_deref())?;
        }
        // [B, 1, M]: feed the LM head straight from the literal (a reshape,
        // not a host round trip).
        let flat = h.reshape(&[b as i64, m as i64])?;
        self.bb.lm_head_rows(&flat, b)
    }

    /// Microbatch-interleaved decode step (same schedule as
    /// [`EpEngine::prefill_pipelined`], with per-microbatch KV lane
    /// groups).
    fn decode_pipelined(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        groups: &[(usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let m = self.cfg.d_model;

        let mut hs: Vec<Option<xla::Literal>> =
            Vec::with_capacity(groups.len());
        let mut pos_lits: Vec<xla::Literal> =
            Vec::with_capacity(groups.len());
        for &(lane0, lanes) in groups {
            let pos_lit =
                HostTensor::i32(&[lanes], pos[lane0..lane0 + lanes].to_vec())
                    .to_literal()?;
            hs.push(Some(self.bb.embed_decode(
                &tokens[lane0..lane0 + lanes],
                &pos_lit,
                lanes,
            )?));
            pos_lits.push(pos_lit);
        }

        self.run_pipeline(&mut hs, &mut PipeCtx::Decode(&pos_lits))?;

        let mut rows = Vec::with_capacity(self.batch);
        for (g, &(_, lanes)) in groups.iter().enumerate() {
            let h = hs[g].take().unwrap();
            let flat = h.reshape(&[lanes as i64, m as i64])?;
            rows.extend(self.bb.lm_head_rows(&flat, lanes)?);
        }
        Ok(rows)
    }

    /// One decode step with the dense backbone sharded: each microbatch
    /// group's embed → attention → gate → combine runs on its own shard
    /// thread against its own KV caches, while this thread orchestrates
    /// the expert exchanges (and advances any staged admission behind
    /// them).
    fn decode_sharded(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        groups: &[(usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let cmds: Vec<ShardCmd> = groups
            .iter()
            .map(|&(lane0, lanes)| ShardCmd::Decode {
                tokens: tokens[lane0..lane0 + lanes].to_vec(),
                pos: pos[lane0..lane0 + lanes].to_vec(),
                mask: self.decode_mask(lane0, lanes),
            })
            .collect();
        let rows = self.drive_shards(cmds, true)?;
        Ok(rows.into_iter().flatten().collect())
    }

    /// Drive one sharded forward: send `cmds` (one per shard), then
    /// service the shards' expert exchanges against the fabric until
    /// every shard reports its rows.  Exchanges are tagged in dispatch
    /// order and **completed oldest-first** — the ring's dispatch/finish
    /// discipline — with the tag-keyed stash absorbing replies that
    /// arrive while an older exchange is still open.  During a scheduler
    /// decode, a staged admission advances one layer behind each freshly
    /// dispatched exchange (prefill-behind-decode, as on the
    /// single-threaded ring).
    fn drive_shards(
        &mut self,
        cmds: Vec<ShardCmd>,
        decode: bool,
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let pool = self.shards.take().context("leader-shard pool missing")?;
        match self.drive_shards_inner(&pool, cmds, decode) {
            Ok(rows) => {
                self.shards = Some(pool);
                Ok(rows)
            }
            Err(e) => {
                // A failed sharded forward leaves shards mid-layer:
                // dropping the pool disconnects their channels (a shard
                // blocked on expert replies errors out of its forward)
                // and joins the threads.  The cache state goes with them.
                drop(pool);
                self.shard_caches = false;
                Err(e)
            }
        }
    }

    fn drive_shards_inner(
        &mut self,
        pool: &ShardPool,
        cmds: Vec<ShardCmd>,
        decode: bool,
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let n = pool.handles.len();
        anyhow::ensure!(cmds.len() == n, "one command per shard");
        for (g, cmd) in cmds.into_iter().enumerate() {
            pool.send(g, cmd)?;
        }
        /// An exchange on the fabric whose replies a shard is waiting on.
        struct OpenExchange {
            shard: usize,
            seq: u64,
            layer: usize,
            tag: u64,
            outstanding: usize,
            results: Vec<FfnBatchResult>,
        }
        let mut pending: VecDeque<OpenExchange> = VecDeque::new();
        let mut rows: Vec<Option<Vec<Vec<f32>>>> =
            (0..n).map(|_| None).collect();
        self.shard_completions.clear();
        let mut done = 0usize;
        while done < n {
            let mut progress = false;
            // Drain shard events: dispatch prepared exchanges onto the
            // fabric (tagging them here, in arrival order) and record
            // finished shards.
            loop {
                match pool.events.try_recv() {
                    Ok(ShardEvent::MoeDispatch {
                        shard,
                        seq,
                        layer,
                        batches,
                        assignments,
                    }) => {
                        progress = true;
                        if let Some(i) = self.stats_idx[layer] {
                            self.load_stats[i]
                                .record_assignments(&assignments);
                        }
                        self.exchange_seq += 1;
                        let tag = self.exchange_seq;
                        let batches: Vec<(usize, ExpertFfnBatch)> = batches
                            .into_iter()
                            .map(|b| {
                                (
                                    b.worker,
                                    ExpertFfnBatch {
                                        layer,
                                        experts: b.experts,
                                        data: b.data,
                                        tag,
                                    },
                                )
                            })
                            .collect();
                        let outstanding =
                            self.fabric.dispatch_exchange(batches)?;
                        self.open_tags.push(tag);
                        pending.push_back(OpenExchange {
                            shard,
                            seq,
                            layer,
                            tag,
                            outstanding,
                            results: Vec::new(),
                        });
                        if decode {
                            // Prefill-behind-decode: a staged admission
                            // advances one layer behind this exchange
                            // (throttled by the chunk budget, if any).
                            self.advance_admission_hidden()?;
                        }
                    }
                    Ok(ShardEvent::PrefillDone { shard, rows: r })
                    | Ok(ShardEvent::DecodeDone { shard, rows: r }) => {
                        progress = true;
                        anyhow::ensure!(
                            rows[shard].is_none(),
                            "shard {shard} reported twice"
                        );
                        rows[shard] = Some(r);
                        self.shard_completions.push(shard);
                        done += 1;
                    }
                    Ok(ShardEvent::Err { shard, msg }) => {
                        anyhow::bail!("leader shard {shard}: {msg}")
                    }
                    Ok(_) => anyhow::bail!(
                        "unexpected shard event during a sharded forward"
                    ),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        anyhow::bail!("leader shards disconnected")
                    }
                }
            }
            // Complete the OLDEST open exchange first (ring discipline);
            // replies of younger open exchanges stay in the fabric's
            // tag-keyed stash until their turn.
            if let Some(front) = pending.front_mut() {
                if front.outstanding > 0 {
                    let got = self.fabric.try_collect_ffn_batches(
                        front.layer,
                        front.tag,
                        &self.open_tags,
                    )?;
                    front.outstanding -= got.len();
                    front.results.extend(got);
                }
                if front.outstanding == 0 {
                    let ex = pending.pop_front().unwrap();
                    self.open_tags.retain(|&t| t != ex.tag);
                    progress = true;
                    pool.send(
                        ex.shard,
                        ShardCmd::MoeReplies {
                            seq: ex.seq,
                            results: ex.results,
                        },
                    )?;
                }
            }
            if !progress {
                // Nothing arrived and the front exchange is still on the
                // fabric: yield briefly rather than spinning.
                std::thread::sleep(std::time::Duration::from_micros(20));
            }
        }
        anyhow::ensure!(
            pending.is_empty(),
            "sharded forward finished with open exchanges"
        );
        if self.shard_completions.windows(2).any(|w| w[0] > w[1]) {
            // Shards finished out of submission order (a slow shard was
            // overtaken) — the oldest-first collection above is what kept
            // the exchange discipline intact.
            self.metrics.inc("shard_completions_ooo", 1);
        }
        Ok(rows
            .into_iter()
            .map(|r| r.expect("every shard reported"))
            .collect())
    }

    /// Spawn (or reuse) the leader-shard pool for lane partition
    /// `groups`.
    fn ensure_pool(&mut self, groups: &[(usize, usize)]) -> Result<()> {
        if let Some(pool) = &self.shards {
            if pool.groups == groups {
                return Ok(());
            }
        }
        self.drop_shards();
        self.shards = Some(ShardPool::spawn(PoolSpec {
            groups: groups.to_vec(),
            arts: self.arts.clone(),
            cfg: self.cfg.clone(),
            placement: self.placement.clone(),
            alltoall: self.alltoall,
            workers: self.fabric.n_workers(),
            metrics: self.metrics.clone(),
            slow_shard: self.slow_shard,
            replicate_hot: self.replicate_hot,
            wire_dtype: self.wire_dtype,
        })?);
        self.shard_caches = false;
        Ok(())
    }

    /// Tear down the pool (joining its threads) without preserving its
    /// caches — callers migrate first if they need them.
    fn drop_shards(&mut self) {
        if let Some(mut p) = self.shards.take() {
            p.shutdown();
        }
        self.shard_caches = false;
    }

    /// Bring the decode cache groups onto the leader at partition
    /// `groups`: migrate them out of the shard pool first if that is
    /// where they live (host-side `TakeCaches` per shard), then
    /// repartition if the lane partition changed.
    fn place_caches_local(&mut self, groups: &[(usize, usize)]) -> Result<()> {
        if self.shard_caches {
            let pool =
                self.shards.take().context("shard caches without a pool")?;
            let r = self.take_caches_from(&pool);
            self.shards = Some(pool);
            self.caches = r?;
            self.shard_caches = false;
            // The pool's threads, runtimes, and dense-weight copies are
            // dead weight while the leader runs single-threaded — release
            // them (a later shard-mode forward respawns; when the caller
            // is place_caches_in_shards this is a partition change, which
            // needed a fresh pool anyway).
            self.drop_shards();
        }
        self.repartition_caches(groups)
    }

    fn take_caches_from(
        &mut self,
        pool: &ShardPool,
    ) -> Result<Vec<LaneGroupCaches>> {
        let n_layers = self.cfg.n_layers;
        let mut out = Vec::with_capacity(pool.groups.len());
        for (g, &(lane0, lanes)) in pool.groups.iter().enumerate() {
            pool.send(g, ShardCmd::TakeCaches)?;
            let layers = pool.expect_caches(g)?;
            let mut c = LaneGroupCaches::new(lane0, lanes, n_layers);
            for (k, v) in layers {
                c.push_host(k, v)?;
            }
            out.push(c);
        }
        Ok(out)
    }

    /// Hand the decode cache groups to the shard pool at partition
    /// `groups`: a no-op when the pool already owns caches at this
    /// partition; otherwise the caches are brought local (merging any
    /// old home), repartitioned, and shipped per group through the host
    /// mirrors.
    fn place_caches_in_shards(
        &mut self,
        groups: &[(usize, usize)],
    ) -> Result<()> {
        if self.shard_caches {
            if let Some(pool) = &self.shards {
                if pool.groups == groups {
                    return Ok(());
                }
            }
        }
        self.place_caches_local(groups)?;
        anyhow::ensure!(!self.caches.is_empty(), "decode before prefill");
        self.ensure_pool(groups)?;
        let pool = self.shards.take().context("leader-shard pool missing")?;
        let r = self.install_caches_into(&pool);
        self.shards = Some(pool);
        r?;
        self.caches.clear();
        self.shard_caches = true;
        Ok(())
    }

    fn install_caches_into(&mut self, pool: &ShardPool) -> Result<()> {
        let n_layers = self.cfg.n_layers;
        anyhow::ensure!(
            self.caches.len() == pool.groups.len(),
            "cache groups do not match the shard partition"
        );
        for (g, cache) in self.caches.iter_mut().enumerate() {
            let mut layers = Vec::with_capacity(n_layers);
            for layer in 0..n_layers {
                // Move the mirrors out instead of cloning: the local
                // groups are cleared right after the install (an error
                // path just leaves them with stale mirrors, which repull
                // from the literals on next use).
                layers.push(cache.take_host(layer)?);
            }
            pool.send(g, ShardCmd::InstallCaches { layers })?;
            pool.expect_ack(g)?;
        }
        Ok(())
    }

    /// Attention + split-phase dispatch for one decode microbatch layer
    /// (`group` selects the KV lane group).
    fn start_decode(
        &mut self,
        layer: usize,
        h: xla::Literal,
        pos: &xla::Literal,
        group: usize,
    ) -> Result<InflightMoe> {
        let h2 = self.attn_decode(layer, h, pos, group)?;
        let (lane0, lanes) =
            (self.caches[group].lane0, self.caches[group].lanes);
        let mask = self.decode_mask(lane0, lanes);
        self.moe_dispatch_in(
            layer,
            h2,
            group,
            "pipeline_bubble",
            Some(self.active_depth),
            mask.as_deref(),
        )
    }

    /// Token mask for a decode microbatch covering lanes
    /// `[lane0, lane0 + lanes)`: `None` in the legacy fixed-lane mode or
    /// when every lane in range is live (no masking — the fast path stays
    /// untouched), otherwise one liveness bit per lane (= per decode
    /// token).
    fn decode_mask(&self, lane0: usize, lanes: usize) -> Option<Vec<bool>> {
        if self.lane_live.is_empty() {
            return None;
        }
        let m = self.lane_live[lane0..lane0 + lanes].to_vec();
        if m.iter().all(|&x| x) {
            None
        } else {
            Some(m)
        }
    }

    /// Rebuild the decode cache groups for a new lane partition (host-side
    /// merge + split; only runs when the pipeline toggle or ring depth
    /// changed between forwards).  The rebuilt groups carry valid host
    /// mirrors — the merge pulled everything to the host anyway.
    fn repartition_caches(&mut self, groups: &[(usize, usize)]) -> Result<()> {
        let current: Vec<(usize, usize)> =
            self.caches.iter().map(|c| (c.lane0, c.lanes)).collect();
        if current.as_slice() == groups {
            return Ok(());
        }
        let (hh, smax, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim());
        let lane_elems = hh * smax * hd;
        let n_layers = self.cfg.n_layers;
        let mut new_groups: Vec<LaneGroupCaches> = groups
            .iter()
            .map(|&(lane0, lanes)| LaneGroupCaches::new(lane0, lanes, n_layers))
            .collect();
        for layer in 0..n_layers {
            // Lane-major cache layout: concatenating the groups' buffers
            // yields the full [B, H, Smax, hd] tensor, and contiguous
            // chunks of it are the target groups.
            let mut full_k: Vec<f32> =
                Vec::with_capacity(self.batch * lane_elems);
            let mut full_v: Vec<f32> =
                Vec::with_capacity(self.batch * lane_elems);
            for g in &mut self.caches {
                full_k.extend_from_slice(g.host_k(layer)?.as_f32()?);
                full_v.extend_from_slice(g.host_v(layer)?.as_f32()?);
            }
            let kparts = split_lanes(&full_k, lane_elems, groups);
            let vparts = split_lanes(&full_v, lane_elems, groups);
            for ((ng, kp), vp) in
                new_groups.iter_mut().zip(kparts).zip(vparts)
            {
                let shape = [ng.lanes, hh, smax, hd];
                ng.push_host(
                    HostTensor::f32(&shape, kp),
                    HostTensor::f32(&shape, vp),
                )?;
            }
        }
        self.caches = new_groups;
        Ok(())
    }

    /// Dynamic lane regrouping: when retirement has skewed per-group live
    /// occupancy by at least `regroup_skew`, migrate live lanes from
    /// surplus groups into free slots of deficit groups so every group
    /// carries an (almost) even live load.  KV moves through the host
    /// mirrors (only the moved lanes are copied; only destination groups
    /// are re-uploaded); the scheduler's lane ids survive via the
    /// external→physical lane permutation.  Never runs in legacy mode or
    /// while an admission is staged (its target lanes are physical).
    fn maybe_regroup(&mut self) -> Result<()> {
        let groups = self.cache_groups();
        if self.lane_live.is_empty()
            || self.pending_admission.is_some()
            || groups.len() < 2
        {
            return Ok(());
        }
        let counts = self.live_counts_for(&groups);
        let (min, max) = (
            counts.iter().copied().min().unwrap_or(0),
            counts.iter().copied().max().unwrap_or(0),
        );
        if max - min < self.regroup_skew {
            return Ok(());
        }
        let n_g = groups.len();
        let mut live_in: Vec<Vec<usize>> = groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln).filter(|&l| self.lane_live[l]).collect()
            })
            .collect();
        let mut free_in: Vec<Vec<usize>> = groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln).filter(|&l| !self.lane_live[l]).collect()
            })
            .collect();
        let total_live: usize = counts.iter().sum();
        // Balanced targets respecting group capacities: hand out the live
        // lanes one at a time to the least-loaded group with room.
        let mut target = vec![0usize; n_g];
        for _ in 0..total_live {
            let g = (0..n_g)
                .filter(|&g| target[g] < groups[g].1)
                .min_by_key(|&g| (target[g], g))
                .expect("live lanes exceed lane count");
            target[g] += 1;
        }
        let mut surplus: Vec<usize> = Vec::new();
        for g in 0..n_g {
            while live_in[g].len() > target[g] {
                surplus.push(live_in[g].pop().unwrap());
            }
        }
        // (src physical, dst physical) live-lane moves.
        let mut moves: Vec<(usize, usize)> = Vec::new();
        for g in 0..n_g {
            while live_in[g].len() < target[g] {
                let dst = free_in[g].remove(0);
                let src = surplus.pop().expect("regroup accounting");
                moves.push((src, dst));
                live_in[g].push(dst);
            }
        }
        if moves.is_empty() {
            return Ok(());
        }
        if self.shard_caches {
            self.regroup_moves_shards(&moves, &groups)?;
        } else {
            self.regroup_moves_local(&moves, &groups)?;
        }
        // Swap the external bindings of each (src, dst) pair so the
        // scheduler's lane ids keep resolving to the moved data.
        for &(src, dst) in &moves {
            let (src_ext, dst_ext) = (self.lane_ext[src], self.lane_ext[dst]);
            self.lane_ext.swap(src, dst);
            self.lane_phys[src_ext] = dst;
            self.lane_phys[dst_ext] = src;
            self.lane_live[dst] = true;
            self.lane_live[src] = false;
        }
        self.metrics.inc("lane_regroups", 1);
        self.metrics.inc("lane_moves", moves.len() as u64);
        Ok(())
    }

    /// Regroup KV moves with engine-local cache groups: through the host
    /// mirrors, re-uploading only the destination groups (sources are
    /// unchanged — their moved lanes are dead now and masked out of
    /// everything).
    fn regroup_moves_local(
        &mut self,
        moves: &[(usize, usize)],
        groups: &[(usize, usize)],
    ) -> Result<()> {
        let (hh, smax, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim());
        let lane_elems = hh * smax * hd;
        let group_of = |lane: usize| {
            groups
                .iter()
                .position(|&(l0, ln)| lane >= l0 && lane < l0 + ln)
                .expect("lane outside every group")
        };
        for layer in 0..self.cfg.n_layers {
            for &(src, dst) in moves {
                let (sg, dg) = (group_of(src), group_of(dst));
                let s_off = src - groups[sg].0;
                let d_off = dst - groups[dg].0;
                let tmp_k = {
                    let hk = self.caches[sg].host_k(layer)?.as_f32()?;
                    hk[s_off * lane_elems..(s_off + 1) * lane_elems].to_vec()
                };
                let tmp_v = {
                    let hv = self.caches[sg].host_v(layer)?.as_f32()?;
                    hv[s_off * lane_elems..(s_off + 1) * lane_elems].to_vec()
                };
                let dk = self.caches[dg].host_k(layer)?.as_f32_mut()?;
                copy_lane(dk, d_off, &tmp_k, 0, lane_elems);
                let dv = self.caches[dg].host_v(layer)?.as_f32_mut()?;
                copy_lane(dv, d_off, &tmp_v, 0, lane_elems);
            }
        }
        let mut touched: Vec<usize> =
            moves.iter().map(|&(_, dst)| group_of(dst)).collect();
        touched.sort_unstable();
        touched.dedup();
        for g in touched {
            for layer in 0..self.cfg.n_layers {
                self.caches[g].push_layer(layer)?;
            }
        }
        Ok(())
    }

    /// Regroup KV moves when the caches live in the shard pool: read the
    /// moved lanes out of their source shards, write them into the
    /// destination shards (host mirrors + re-upload of touched layers
    /// inside each shard) — the same data flow as the local path,
    /// expressed over the `ReadLanes`/`WriteLanes` protocol.
    fn regroup_moves_shards(
        &mut self,
        moves: &[(usize, usize)],
        groups: &[(usize, usize)],
    ) -> Result<()> {
        let pool =
            self.shards.take().context("shard caches without a pool")?;
        let r = Self::regroup_moves_via(&pool, moves, groups, self.cfg.n_layers);
        self.shards = Some(pool);
        r
    }

    fn regroup_moves_via(
        pool: &ShardPool,
        moves: &[(usize, usize)],
        groups: &[(usize, usize)],
        n_layers: usize,
    ) -> Result<()> {
        let group_of = |lane: usize| {
            groups
                .iter()
                .position(|&(l0, ln)| lane >= l0 && lane < l0 + ln)
                .expect("lane outside every group")
        };
        // Pull every moved source lane (all layers) out of its shard.
        let mut read_req: Vec<Vec<usize>> = vec![Vec::new(); groups.len()];
        for &(src, _) in moves {
            let sg = group_of(src);
            read_req[sg].push(src - groups[sg].0);
        }
        let mut src_data: HashMap<(usize, usize), (Vec<f32>, Vec<f32>)> =
            HashMap::new();
        for (sg, lanes) in read_req.iter().enumerate() {
            if lanes.is_empty() {
                continue;
            }
            pool.send(sg, ShardCmd::ReadLanes { lanes: lanes.clone() })?;
            for w in pool.expect_lanes(sg)? {
                src_data
                    .insert((groups[sg].0 + w.lane, w.layer), (w.k, w.v));
            }
        }
        // Write them into the destination shards.
        let mut writes: Vec<Vec<LaneWrite>> = vec![Vec::new(); groups.len()];
        for &(src, dst) in moves {
            let dg = group_of(dst);
            let d_off = dst - groups[dg].0;
            for layer in 0..n_layers {
                let (k, v) = src_data
                    .get(&(src, layer))
                    .context("regroup read missing a lane")?
                    .clone();
                writes[dg].push(LaneWrite { layer, lane: d_off, k, v });
            }
        }
        for (g, w) in writes.into_iter().enumerate() {
            if w.is_empty() {
                continue;
            }
            pool.send(g, ShardCmd::WriteLanes { writes: w })?;
            pool.expect_ack(g)?;
        }
        Ok(())
    }

    /// Depth of the fabric's tag-keyed reply stash (bounded by the open
    /// exchange count; must be zero between forwards).
    pub fn fabric_stash_depth(&self) -> usize {
        self.fabric.stash_depth()
    }

    /// Initialize continuous-batching lane state: all lanes free (identity
    /// lane permutation), decode cache groups zero-filled at the current
    /// lane partition with valid host mirrors (first-wave admissions
    /// splice without a single device pull).  Re-entered from legacy mode
    /// (after a fixed-lane `forward_prefill`) this resets every lane.
    fn ensure_lane_state(&mut self) -> Result<()> {
        if !self.lane_live.is_empty() {
            return Ok(());
        }
        // Entering scheduler mode resets every lane: whatever a shard
        // pool still holds is stale (the first sharded decode installs
        // these fresh groups into it).
        self.shard_caches = false;
        self.lane_live = vec![false; self.batch];
        self.lane_phys = (0..self.batch).collect();
        self.lane_ext = (0..self.batch).collect();
        let (hh, smax, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim());
        let n_layers = self.cfg.n_layers;
        let mut groups = Vec::new();
        for (lane0, lanes) in self.lane_groups() {
            let mut g = LaneGroupCaches::new(lane0, lanes, n_layers);
            for _ in 0..n_layers {
                let shape = [lanes, hh, smax, hd];
                g.push_host(
                    HostTensor::zeros_f32(&shape),
                    HostTensor::zeros_f32(&shape),
                )?;
            }
            groups.push(g);
        }
        self.caches = groups;
        Ok(())
    }

    /// Choose `n` free lanes for admission, keeping the pipeline's lane
    /// groups balanced: each pick goes to the group with the fewest busy
    /// lanes among those with a free one, so the N microbatches carry
    /// similar live load.
    fn pick_free_lanes(&self, n: usize) -> Result<Vec<usize>> {
        let groups = self.cache_groups();
        let mut free: Vec<Vec<usize>> = groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln).filter(|&l| !self.lane_live[l]).collect()
            })
            .collect();
        let mut busy: Vec<usize> = groups
            .iter()
            .map(|&(l0, ln)| {
                (l0..l0 + ln).filter(|&l| self.lane_live[l]).count()
            })
            .collect();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let g = (0..groups.len())
                .filter(|&g| !free[g].is_empty())
                .min_by_key(|&g| busy[g])
                .context("no free lane for admission")?;
            out.push(free[g].remove(0));
            busy[g] += 1;
        }
        Ok(out)
    }

    /// Stage an admission prefill over `compiled` lanes (the first
    /// `reqs.len()` carry real prompts, the rest are padding): validates,
    /// picks balanced free lanes, and runs the embedding.  The per-layer
    /// body runs through [`EpEngine::advance_admission`] — interleaved
    /// behind decode exchanges or all at once from
    /// [`EpEngine::complete_admission`].  Per-lane outputs are
    /// bit-identical to a full-batch forward over the same prompts (every
    /// program is per-lane/per-row independent — the same property the
    /// parity tests pin).
    fn stage_admission(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<()> {
        anyhow::ensure!(
            self.pending_admission.is_none(),
            "admission already staged"
        );
        anyhow::ensure!(
            !reqs.is_empty() && reqs.len() <= compiled,
            "admission prefill: {} requests at compiled size {compiled}",
            reqs.len()
        );
        anyhow::ensure!(
            self.prefill_sizes.contains(&compiled),
            "no admission prefill shapes at lane count {compiled} \
             (available: {:?})",
            self.prefill_sizes
        );
        self.sync_metrics();
        self.ensure_lane_state()?;
        let lanes = self.pick_free_lanes(reqs.len())?;
        let smax = self.cfg.max_seq;
        // No forward is in flight when an admission is staged: exchanges
        // of an aborted earlier forward are no longer open.
        self.open_tags.clear();
        let t0 = std::time::Instant::now();
        let mut tokens = vec![0i32; compiled * smax];
        let mut lens = vec![1usize; compiled]; // padding lanes: dummy len
        for (i, r) in reqs.iter().enumerate() {
            anyhow::ensure!(
                r.prompt.len() <= smax,
                "prompt length exceeds max_seq {smax}"
            );
            tokens[i * smax..i * smax + r.prompt.len()]
                .copy_from_slice(&r.prompt);
            lens[i] = r.prompt.len();
        }
        let h = self.bb.embed_prefill(&tokens, compiled)?;
        let live = reqs.len();
        let mask: Option<Vec<bool>> = if live == compiled {
            None
        } else {
            Some((0..compiled * smax).map(|i| i / smax < live).collect())
        };
        self.pending_admission = Some(AdmissionState {
            compiled,
            live,
            lens,
            lanes,
            mask,
            h: Some(h),
            layer: 0,
            kv: Vec::with_capacity(self.cfg.n_layers),
            elapsed: t0.elapsed(),
        });
        Ok(())
    }

    /// Run up to `layers` staged-admission layer steps (attention +
    /// split-phase MoE with the padding masked; the admission's exposed
    /// expert wait lands in `prefill_stall`).  No-op without a staged
    /// admission; re-entrancy safe — the state is taken for the duration,
    /// so the admission's own MoE layers never recurse into further
    /// advances.
    fn advance_admission(&mut self, layers: usize) -> Result<()> {
        let Some(mut st) = self.pending_admission.take() else {
            return Ok(());
        };
        let t0 = std::time::Instant::now();
        for _ in 0..layers {
            if st.layer >= self.cfg.n_layers {
                break;
            }
            self.admission_layer(&mut st)?;
        }
        st.elapsed += t0.elapsed();
        self.pending_admission = Some(st);
        Ok(())
    }

    /// [`EpEngine::advance_admission`] as called from the
    /// prefill-behind-decode sites, throttled by the chunked-prefill
    /// budget: with `DSMOE_PREFILL_CHUNK` off the allowance is
    /// `usize::MAX` and this is exactly `advance_admission(1)`; with a
    /// budget, each decode step spends at most its allowance
    /// ([`EpEngine::admission_allowance_layers`]) and the admission
    /// spills into later steps.
    fn advance_admission_hidden(&mut self) -> Result<()> {
        if self.admission_allowance == 0 {
            return Ok(());
        }
        if self.admission_allowance != usize::MAX {
            self.admission_allowance -= 1;
        }
        self.advance_admission(1)
    }

    /// Admission layers one decode step may hide under the chunk budget:
    /// `ceil(prefill_chunk / live prompt tokens)`, at least 1 so every
    /// step makes progress even when one prompt exceeds the budget.
    /// `usize::MAX` (no throttle) when chunking is off or nothing is
    /// staged.
    fn admission_allowance_layers(&self) -> usize {
        if self.prefill_chunk == 0 {
            return usize::MAX;
        }
        let Some(st) = &self.pending_admission else {
            return usize::MAX;
        };
        let live_tokens: usize =
            st.lens[..st.live].iter().sum::<usize>().max(1);
        self.prefill_chunk.div_ceil(live_tokens).max(1)
    }

    /// One admission-prefill layer: attention, then dispatch + finish on
    /// the dedicated admission scratch slot.  Replies of any concurrently
    /// open decode exchange arriving during the `prefill_stall` wait are
    /// stashed tag-keyed for their own collection.  Under
    /// `DSMOE_SERIAL_MOE` the layer runs the serialized per-expert
    /// baseline instead (as the pre-split admission path did), so the
    /// serial toggle's traffic and wait measurements stay uncontaminated.
    fn admission_layer(&mut self, st: &mut AdmissionState) -> Result<()> {
        let layer = st.layer;
        let h = st.h.take().expect("admission activation");
        let (h2, k, vv) = self.bb.attn_prefill(layer, h, st.compiled)?;
        st.kv.push((k, vv));
        let out = if self.serial_moe && self.cfg.experts_at(layer) > 0 {
            self.moe_layer_serial(layer, h2, st.mask.as_deref())?
        } else {
            let slot = self.batch; // dedicated admission scratch slot
            let inflight = self.moe_dispatch_in(
                layer,
                h2,
                slot,
                "prefill_stall",
                None,
                st.mask.as_deref(),
            )?;
            self.moe_finish(inflight)?
        };
        st.h = Some(out);
        st.layer += 1;
        Ok(())
    }

    /// Complete a staged admission: run whatever layers the decode gaps
    /// did not cover, take the LM head, splice the KV into the chosen
    /// lanes, and mark them live.  Returns the admitted lanes in request
    /// order (external lane ids).
    fn complete_admission(&mut self) -> Result<Vec<AdmittedLane>> {
        self.advance_admission(self.cfg.n_layers)?;
        let mut st = self
            .pending_admission
            .take()
            .context("no admission staged")?;
        let t0 = std::time::Instant::now();
        let h = st.h.take().expect("admission activation");
        let mut rows = self.bb.lm_head_last(&h, &st.lens)?;
        rows.truncate(st.live);
        self.splice_admitted(&st.kv, &st.lanes)?;
        self.metrics.observe("forward_prefill", st.elapsed + t0.elapsed());
        let mut out = Vec::with_capacity(st.live);
        for (&lane, logits) in st.lanes.iter().zip(rows) {
            self.lane_live[lane] = true;
            out.push(AdmittedLane { lane: self.lane_ext[lane], logits });
        }
        Ok(out)
    }

    /// Splice freshly prefilled lanes into the decode cache groups:
    /// `admits[i]` maps source lane `i` of the admission prefill to a free
    /// physical lane.  Writes go through the per-group host mirrors, so
    /// only the admitted lanes are copied host-side and a device pull
    /// happens only when a decode step staled the touched layer since the
    /// last splice.
    fn splice_admitted(
        &mut self,
        kv: &[(xla::Literal, xla::Literal)],
        admits: &[usize],
    ) -> Result<()> {
        if self.shard_caches {
            let pool =
                self.shards.take().context("shard caches without a pool")?;
            let r = Self::splice_admitted_via(&pool, kv, admits, &self.cfg);
            self.shards = Some(pool);
            return r;
        }
        let (hh, smax, hd) =
            (self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim());
        let lane_elems = hh * smax * hd;
        for (layer, (k_lit, v_lit)) in kv.iter().enumerate() {
            let src_k: Vec<f32> = k_lit.to_vec()?;
            let src_v: Vec<f32> = v_lit.to_vec()?;
            for g in &mut self.caches {
                let (lane0, lanes) = (g.lane0, g.lanes);
                let in_group: Vec<(usize, usize)> = admits
                    .iter()
                    .enumerate()
                    .filter(|&(_, &l)| l >= lane0 && l < lane0 + lanes)
                    .map(|(src, &l)| (src, l - lane0))
                    .collect();
                if in_group.is_empty() {
                    continue;
                }
                {
                    let dst = g.host_k(layer)?.as_f32_mut()?;
                    for &(src, d) in &in_group {
                        copy_lane(dst, d, &src_k, src, lane_elems);
                    }
                }
                {
                    let dst = g.host_v(layer)?.as_f32_mut()?;
                    for &(src, d) in &in_group {
                        copy_lane(dst, d, &src_v, src, lane_elems);
                    }
                }
                g.push_layer(layer)?;
            }
        }
        Ok(())
    }

    /// Admission splice when the caches live in the shard pool: the same
    /// per-lane copies, expressed as `WriteLanes` batches per destination
    /// shard.
    fn splice_admitted_via(
        pool: &ShardPool,
        kv: &[(xla::Literal, xla::Literal)],
        admits: &[usize],
        cfg: &ModelConfig,
    ) -> Result<()> {
        let lane_elems = cfg.n_heads * cfg.max_seq * cfg.head_dim();
        let mut writes: Vec<Vec<LaneWrite>> =
            vec![Vec::new(); pool.groups.len()];
        for (layer, (k_lit, v_lit)) in kv.iter().enumerate() {
            let src_k: Vec<f32> = k_lit.to_vec()?;
            let src_v: Vec<f32> = v_lit.to_vec()?;
            for (src, &phys) in admits.iter().enumerate() {
                let g = pool
                    .groups
                    .iter()
                    .position(|&(l0, ln)| phys >= l0 && phys < l0 + ln)
                    .context("admitted lane outside every shard group")?;
                writes[g].push(LaneWrite {
                    layer,
                    lane: phys - pool.groups[g].0,
                    k: src_k[src * lane_elems..(src + 1) * lane_elems]
                        .to_vec(),
                    v: src_v[src * lane_elems..(src + 1) * lane_elems]
                        .to_vec(),
                });
            }
        }
        for (g, w) in writes.into_iter().enumerate() {
            if w.is_empty() {
                continue;
            }
            pool.send(g, ShardCmd::WriteLanes { writes: w })?;
            pool.expect_ack(g)?;
        }
        Ok(())
    }

    /// Decode attention over group `group`'s engine-local caches (the
    /// compute lives in [`Backbone::attn_decode`], shared with the leader
    /// shards).
    fn attn_decode(
        &mut self,
        layer: usize,
        h: xla::Literal,
        pos: &xla::Literal,
        group: usize,
    ) -> Result<xla::Literal> {
        let lanes = self.caches[group].lanes;
        let (h2, kc, vc) = {
            let cache = &self.caches[group];
            self.bb.attn_decode(
                layer,
                h,
                pos,
                lanes,
                &cache.k[layer],
                &cache.v[layer],
            )?
        };
        let cache = &mut self.caches[group];
        cache.k[layer] = kc;
        cache.v[layer] = vc;
        // The decode write staled this layer's host mirror.
        cache.invalidate(layer);
        Ok(h2)
    }

    /// FFN sublayer on the per-layer path: split-phase dispatch followed
    /// immediately by finish (the PR-1 overlapped schedule), or the
    /// serialized baseline under `DSMOE_SERIAL_MOE`.  `mask` marks live
    /// tokens (None = all live); dead tokens are excluded from gate
    /// routing and expert dispatch.
    fn ffn_layer(
        &mut self,
        layer: usize,
        h: xla::Literal,
        mask: Option<&[bool]>,
    ) -> Result<xla::Literal> {
        if self.serial_moe && self.cfg.experts_at(layer) > 0 {
            return self.moe_layer_serial(layer, h, mask);
        }
        let inflight =
            self.moe_dispatch_in(layer, h, 0, "expert_wait", None, mask)?;
        // Prefill-behind-decode on the per-layer overlapped path: a
        // staged admission advances one layer while this exchange is on
        // the fabric (no-op outside scheduler-backed decode; throttled by
        // the chunked-prefill budget, if any).
        self.advance_admission_hidden()?;
        self.moe_finish(inflight)
    }

    /// Split-phase MoE, phase 1 of 2: gate, coalesced tagged dispatch, and
    /// the leader-overlap work (all-to-all accounting, PR-MoE residual
    /// branch, combine prep).  Returns with the exchange still on the
    /// fabric; pass the result to [`EpEngine::moe_finish`].  Dense FFN
    /// layers complete here and flow through the same [`InflightMoe`].
    pub fn moe_dispatch(
        &mut self,
        layer: usize,
        h: xla::Literal,
    ) -> Result<InflightMoe> {
        self.moe_dispatch_in(layer, h, 0, "expert_wait", None, None)
    }

    fn moe_dispatch_in(
        &mut self,
        layer: usize,
        h: xla::Literal,
        slot: usize,
        wait_metric: &'static str,
        depth_tag: Option<usize>,
        mask: Option<&[bool]>,
    ) -> Result<InflightMoe> {
        // Phases 1–3 (gate → coalesced pack → leader overlap) live in the
        // backbone, shared verbatim with the leader shards; this engine
        // owns what a shard cannot: the exchange tag and the fabric.
        let prepared =
            self.bb
                .ffn_prepare(layer, h, mask, &mut self.scratch[slot])?;
        let PreparedMoe {
            shape,
            routing,
            batches,
            residual,
            out_data,
            worker_experts,
            dispatch_elapsed,
            ..
        } = match prepared {
            Prepared::Dense { out, elapsed } => {
                return Ok(InflightMoe {
                    layer,
                    dispatch_elapsed: elapsed,
                    state: InflightState::Done(out),
                });
            }
            Prepared::Moe(p) => *p,
        };
        if let Some(i) = self.stats_idx[layer] {
            self.load_stats[i].record_assignments(routing.assignments());
        }
        self.exchange_seq += 1;
        let exchange_tag = self.exchange_seq;
        let batches: Vec<(usize, ExpertFfnBatch)> = batches
            .into_iter()
            .map(|b| {
                (
                    b.worker,
                    ExpertFfnBatch {
                        layer,
                        experts: b.experts,
                        data: b.data,
                        tag: exchange_tag,
                    },
                )
            })
            .collect();
        let outstanding = self.fabric.dispatch_exchange(batches)?;
        self.open_tags.push(exchange_tag);
        Ok(InflightMoe {
            layer,
            dispatch_elapsed,
            state: InflightState::Pending(Box::new(PendingMoe {
                slot,
                shape,
                routing,
                outstanding,
                tag: exchange_tag,
                residual,
                out_data,
                worker_experts,
                results: Vec::new(),
                wait_metric,
                depth_tag,
            })),
        })
    }

    /// Opportunistically drain any already-arrived replies of an in-flight
    /// exchange (non-blocking), so the eventual [`EpEngine::moe_finish`]
    /// wait only covers work that is genuinely still outstanding.
    pub fn poll_inflight(&mut self, inflight: &mut InflightMoe) -> Result<()> {
        let layer = inflight.layer;
        if let InflightState::Pending(p) = &mut inflight.state {
            if p.outstanding > 0 {
                let got = self.fabric.try_collect_ffn_batches(
                    layer,
                    p.tag,
                    &self.open_tags,
                )?;
                p.outstanding -= got.len();
                p.results.extend(got);
            }
        }
        Ok(())
    }

    /// Split-phase MoE, phase 2 of 2: block on the remaining coalesced
    /// replies of this exchange and combine (gate-scale, un-permute,
    /// residual adds) in the same order as the serial path —
    /// bit-identical logits by construction.
    pub fn moe_finish(&mut self, inflight: InflightMoe) -> Result<xla::Literal> {
        let InflightMoe { layer, dispatch_elapsed, state } = inflight;
        let p = match state {
            InflightState::Done(h) => return Ok(h),
            InflightState::Pending(p) => p,
        };

        // Phase 4: wait for the coalesced worker replies still in flight
        // (replies of the *other* open exchange get stashed, tag-keyed).
        let t3 = std::time::Instant::now();
        let mut results = p.results;
        if p.outstanding > 1 {
            // More than one worker still owes a reply: time the straggler
            // tail (first remaining reply → last) separately, so the
            // replication study can see whether splitting a hot expert's
            // block actually shrank the slowest-worker wait.  The first
            // collect may return several parts at once (stash drain,
            // coalesced relay replies), so the remainder is counted from
            // what actually arrived.
            let first = self.fabric.collect_ffn_batches(
                1,
                layer,
                p.tag,
                &self.open_tags,
            )?;
            let got = first.len();
            results.extend(first);
            let t_straggle = std::time::Instant::now();
            if got < p.outstanding {
                results.extend(self.fabric.collect_ffn_batches(
                    p.outstanding - got,
                    layer,
                    p.tag,
                    &self.open_tags,
                )?);
            }
            self.metrics.observe("hot_worker_wait", t_straggle.elapsed());
        } else if p.outstanding > 0 {
            results.extend(self.fabric.collect_ffn_batches(
                p.outstanding,
                layer,
                p.tag,
                &self.open_tags,
            )?);
        }
        self.open_tags.retain(|&t| t != p.tag);
        if let Some(depth) = p.depth_tag {
            // Per-depth breakdown: depth sweeps stay attributable from a
            // single metrics report.
            self.metrics.observe_tagged(p.wait_metric, depth, t3.elapsed());
        } else {
            self.metrics.observe(p.wait_metric, t3.elapsed());
        }

        // Phase 5: combine — in the backbone (scratch buffer reused
        // across layers), same op order as the serial path
        // (bit-identical).
        let out = {
            let slot_scratch = &mut self.scratch[p.slot];
            self.bb.moe_combine(
                &p.shape,
                &p.routing,
                p.residual.as_deref(),
                p.out_data,
                &results,
                &mut slot_scratch.combine,
            )?
        };
        self.scratch[p.slot].worker_experts = p.worker_experts;
        // Dispatch half + finish half: excludes whatever the pipeline
        // interleaved between the two (the per-layer path has no gap).
        self.metrics
            .observe("moe_layer", dispatch_elapsed + t3.elapsed());
        Ok(out)
    }

    /// The pre-overlap serialized MoE path (`DSMOE_SERIAL_MOE=1`): gate →
    /// one message per expert → blocking collect → combine → residual
    /// branch, with the original literal→host→literal staging.  Kept
    /// verbatim as the before/after measurement baseline; must stay
    /// bit-identical to the split-phase pipeline.
    fn moe_layer_serial(
        &mut self,
        layer: usize,
        h: xla::Literal,
        mask: Option<&[bool]>,
    ) -> Result<xla::Literal> {
        let (m, f) = (self.cfg.d_model, self.cfg.d_ff);
        let pre = format!("layer{layer}.");
        let n_experts = self.cfg.experts_at(layer);
        let t_layer = std::time::Instant::now();

        let t0 = std::time::Instant::now();
        let h_host = HostTensor::from_literal(&h)?;
        let t_tokens = h_host.nelems() / m;
        let gate = self.bb.prog(&Manifest::key_gate(m, n_experts, t_tokens))?;
        let shape = h_host.shape.clone();
        let flat = HostTensor::f32(&[1, t_tokens, m], h_host.as_f32()?.to_vec())
            .to_literal()?;
        let outs = gate.run_literal_refs(&[
            &flat,
            self.bb.p(&format!("{pre}ln2.g")),
            self.bb.p(&format!("{pre}ln2.b")),
            self.bb.p(&format!("{pre}moe.gate")),
        ])?;
        let ln_h = HostTensor::from_literal(&outs[0])?; // [T, M]
        let probs = HostTensor::from_literal(&outs[1])?; // [T, E]
        self.metrics.observe("gate", t0.elapsed());

        let routing = match self.bb.force_expert {
            Some(pin) if pin < n_experts => {
                Routing::pinned_masked(probs.as_f32()?, n_experts, mask, pin)
            }
            _ => Routing::top1_masked(probs.as_f32()?, n_experts, mask),
        };
        if let Some(i) = self.stats_idx[layer] {
            self.load_stats[i].record_assignments(routing.assignments());
        }

        // Log the all-to-all schedule this exchange would use at scale.
        let lp = self.placement.layer(layer).unwrap();
        let plan = self.bb.exchange_plan(&routing, lp, m);
        self.metrics
            .inc("alltoall_bytes", plan.volume() as u64);
        self.metrics.inc("alltoall_hops", plan.hops() as u64);

        // Dispatch expert blocks to their owners (replica 0 group).
        let t1 = std::time::Instant::now();
        let ln_flat = ln_h.as_f32()?;
        let mut inflight = 0usize;
        for e in 0..n_experts {
            if routing.counts[e] == 0 {
                continue;
            }
            let block = routing.expert_block(ln_flat, m, e);
            let owner = lp.owner(e, 0);
            self.fabric.dispatch_ffn(
                owner,
                layer,
                e,
                HostTensor::f32(&[routing.counts[e], m], block),
                e as u64,
            )?;
            inflight += 1;
        }
        let results = self.fabric.collect_ffn(inflight)?;
        self.metrics.observe("expert_exchange", t1.elapsed());

        let mut expert_outputs: Vec<Vec<f32>> =
            vec![Vec::new(); n_experts];
        for (_, e, out, _) in results {
            expert_outputs[e] = out.as_f32()?.to_vec();
        }
        let mut combined = routing.combine(&expert_outputs, m);

        // Residual-MoE fixed branch (PR-MoE): runs at the leader (it is a
        // dense, non-expert computation).
        if self.cfg.residual {
            let rb =
                self.bb.prog(&Manifest::key_residual_branch(m, f, t_tokens))?;
            let lnh_lit =
                HostTensor::f32(&[t_tokens, m], ln_flat.to_vec()).to_literal()?;
            let out = rb
                .run_literal_refs(&[
                    &lnh_lit,
                    self.bb.p(&format!("{pre}moe.res.w1")),
                    self.bb.p(&format!("{pre}moe.res.b1")),
                    self.bb.p(&format!("{pre}moe.res.w2")),
                    self.bb.p(&format!("{pre}moe.res.b2")),
                ])?
                .remove(0);
            let res = HostTensor::from_literal(&out)?;
            for (c, r) in combined.iter_mut().zip(res.as_f32()?) {
                *c += r;
            }
        }

        // Residual add: h + combined.
        let mut out = h_host.as_f32()?.to_vec();
        for (o, c) in out.iter_mut().zip(&combined) {
            *o += c;
        }
        let out = HostTensor::f32(&shape, out).to_literal()?;
        self.metrics.observe("moe_layer", t_layer.elapsed());
        Ok(out)
    }

    pub fn traffic(&self) -> &crate::fabric::Traffic {
        &self.fabric.traffic
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }
}

/// Continuous batching over the expert-parallel engine: the scheduler
/// admits requests via compiled-size admission prefills whose KV is
/// spliced into free lanes of the per-microbatch decode groups (balanced
/// across the two pipeline groups), decode steps run full-lane-group
/// forwards with dead lanes masked out of gate + dispatch, and `release`
/// frees a lane for the next admission.
impl ForwardModel for EpEngine {
    fn model_config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn configure(&mut self, serving: &crate::config::ServingConfig) {
        self.set_pipe_depth(serving.pipe_depth);
        self.set_leader_threads(serving.leader_threads);
        self.set_prefill_chunk(serving.prefill_chunk);
    }

    fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    fn set_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = metrics;
        self.sync_metrics();
    }

    fn prefill_sizes(&self) -> Vec<usize> {
        self.prefill_sizes.clone()
    }

    fn lane_count(&self) -> usize {
        self.batch
    }

    fn free_lane_count(&self) -> usize {
        if self.lane_live.is_empty() {
            self.batch
        } else {
            self.lane_live.iter().filter(|&&l| !l).count()
        }
    }

    fn prefill(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<Vec<AdmittedLane>> {
        // Stop-the-world admission: stage and complete back to back (no
        // decode step runs in between).
        self.stage_admission(compiled, reqs)?;
        self.complete_admission()
    }

    fn begin_prefill(
        &mut self,
        compiled: usize,
        reqs: &[Request],
    ) -> Result<bool> {
        if self.serial_moe || !self.interleave {
            // The serialized path has no dispatch/finish gap to hide an
            // admission in; DSMOE_NO_INTERLEAVE pins the stop-the-world
            // baseline.
            return Ok(false);
        }
        self.stage_admission(compiled, reqs)?;
        Ok(true)
    }

    fn finish_prefill(&mut self) -> Result<Vec<AdmittedLane>> {
        self.complete_admission()
    }

    fn prefill_pending(&self) -> bool {
        // Only chunked admissions report pending work: without a budget
        // the staged admission completes behind the single interleaved
        // decode step, exactly the pre-chunking contract.
        self.prefill_chunk > 0
            && self
                .pending_admission
                .as_ref()
                .is_some_and(|st| st.layer < self.cfg.n_layers)
    }

    fn advance_prefill(&mut self) -> Result<()> {
        // One chunk directly — no decode forward to hide it behind
        // (every lane idle), so the budget is the step.
        let layers = self.admission_allowance_layers();
        self.advance_admission(layers.min(self.cfg.n_layers))
    }

    fn decode_step(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.batch;
        anyhow::ensure!(tokens.len() == b && pos.len() == b, "lane shape");
        // Fresh hidden-advance budget for this step's chunked admission
        // (usize::MAX — no throttle — when chunking is off).
        self.admission_allowance = self.admission_allowance_layers();
        // Rebalance live lanes across the groups if retirement skewed the
        // occupancy (before the forward, so this step already runs even).
        self.maybe_regroup()?;
        if self.lane_ext.iter().enumerate().all(|(p, &e)| p == e) {
            return self.forward_decode(tokens, pos);
        }
        // A past regroup moved lanes: feed the forward in physical order
        // and hand the rows back under the scheduler's external ids.
        let tok: Vec<i32> =
            self.lane_ext.iter().map(|&e| tokens[e]).collect();
        let ps: Vec<i32> = self.lane_ext.iter().map(|&e| pos[e]).collect();
        let rows = self.forward_decode(&tok, &ps)?;
        let mut out = vec![Vec::new(); b];
        for (p, row) in rows.into_iter().enumerate() {
            out[self.lane_ext[p]] = row;
        }
        Ok(out)
    }

    fn release(&mut self, lane: usize) {
        let phys = self.lane_phys.get(lane).copied().unwrap_or(lane);
        if let Some(l) = self.lane_live.get_mut(phys) {
            *l = false;
        }
    }

    fn try_recover(&mut self, err: &anyhow::Error) -> Result<bool> {
        // The scheduler's second line of defense: engine-local retries
        // are exhausted (or were skipped because a staged admission was
        // in flight).  Recover the fabric/placement here and tell the
        // scheduler to fold every in-flight request back into the queue.
        if !self.fault_tolerance || !crate::fabric::is_fault(err) {
            return Ok(false);
        }
        self.note_fault(err);
        self.recover_from_fault()?;
        Ok(true)
    }
}

/// Split `batch` lanes into `depth` contiguous groups, sizes as even as
/// possible (the first `batch % depth` groups carry one extra lane):
/// 8 lanes at depth 3 partition as 3/3/2.  `depth` is clamped to
/// `[1, batch]`.
fn partition_lanes(batch: usize, depth: usize) -> Vec<(usize, usize)> {
    let d = depth.clamp(1, batch.max(1));
    let (base, extra) = (batch / d, batch % d);
    let mut out = Vec::with_capacity(d);
    let mut lane0 = 0;
    for g in 0..d {
        let lanes = base + usize::from(g < extra);
        out.push((lane0, lanes));
        lane0 += lanes;
    }
    out
}

/// True if every AOT program a pipeline microbatch of `bh` lanes needs
/// exists in the manifest (prefill and decode shapes).  Evaluated once at
/// engine construction — the manifest never changes afterwards.
fn group_shapes_available(
    manifest: &Manifest,
    cfg: &ModelConfig,
    bh: usize,
) -> bool {
    let (v, m, hh, f, smax) = (
        cfg.vocab_size,
        cfg.d_model,
        cfg.n_heads,
        cfg.d_ff,
        cfg.max_seq,
    );
    let mut keys = vec![
        Manifest::key_embed(v, m, bh, smax),
        Manifest::key_embed(v, m, bh, 1),
        Manifest::key_attn_prefill(m, hh, bh, smax),
        Manifest::key_attn_decode(m, hh, bh, smax),
        Manifest::key_lm_head(v, m, bh),
    ];
    let has_dense = cfg.experts_schedule.iter().any(|&e| e == 0);
    for t in [bh, bh * smax] {
        for (_, e) in cfg.moe_layers() {
            keys.push(Manifest::key_gate(m, e, t));
        }
        if has_dense {
            keys.push(Manifest::key_dense_ffn(m, f, t));
        }
        if cfg.residual {
            keys.push(Manifest::key_residual_branch(m, f, t));
        }
    }
    keys.iter().all(|k| manifest.shared_program(k).is_ok())
}

/// True if every AOT program a scheduler admission prefill needs at lane
/// count `lanes` exists in the manifest (prefill-side shapes only — decode
/// always runs at the full lane group).  `gather_last` is not required:
/// `lm_head_last` falls back to a host-side gather for artifact sets that
/// predate it.
fn prefill_shapes_available(
    manifest: &Manifest,
    cfg: &ModelConfig,
    lanes: usize,
) -> bool {
    let (v, m, hh, f, smax) = (
        cfg.vocab_size,
        cfg.d_model,
        cfg.n_heads,
        cfg.d_ff,
        cfg.max_seq,
    );
    let t = lanes * smax;
    let mut keys = vec![
        Manifest::key_embed(v, m, lanes, smax),
        Manifest::key_attn_prefill(m, hh, lanes, smax),
        Manifest::key_lm_head(v, m, lanes),
    ];
    for (_, e) in cfg.moe_layers() {
        keys.push(Manifest::key_gate(m, e, t));
    }
    if cfg.experts_schedule.iter().any(|&e| e == 0) {
        keys.push(Manifest::key_dense_ffn(m, f, t));
    }
    if cfg.residual {
        keys.push(Manifest::key_residual_branch(m, f, t));
    }
    keys.iter().all(|k| manifest.shared_program(k).is_ok())
}

/// Encode one expert's f32 `[w1, b1, w2, b2]` ship list in the ladder
/// dtype.  `f32` passes through untouched (the baseline ships the exact
/// master weights); `bf16`/`f16` narrow the two matrices and keep the
/// biases f32 (they are a rounding-error-prone accumulator target and a
/// negligible fraction of the bytes); `i8` quantizes the matrices
/// per output channel, interleaving each quantized matrix with its scale
/// vector — `[w1_q, w1_scales, b1, w2_q, w2_scales, b2]` — which is the
/// layout the worker's install path consumes (an i8 tensor always eats
/// the next tensor as its scales).
fn encode_expert_weights(
    weights: Vec<HostTensor>,
    dtype: Dtype,
) -> Result<Vec<HostTensor>> {
    anyhow::ensure!(weights.len() == 4, "expert ship list is [w1,b1,w2,b2]");
    match dtype {
        Dtype::F32 => Ok(weights),
        Dtype::BF16 | Dtype::F16 => {
            let mut out = Vec::with_capacity(4);
            for (i, t) in weights.into_iter().enumerate() {
                // Matrices sit at positions 0 and 2; biases stay f32.
                out.push(if i % 2 == 0 { t.convert(dtype)? } else { t });
            }
            Ok(out)
        }
        Dtype::I8 => {
            let mut it = weights.into_iter();
            let (w1, b1, w2, b2) = (
                it.next().unwrap(),
                it.next().unwrap(),
                it.next().unwrap(),
                it.next().unwrap(),
            );
            let (w1_q, w1_s) = w1.quantize_i8_per_col()?;
            let (w2_q, w2_s) = w2.quantize_i8_per_col()?;
            Ok(vec![w1_q, w1_s, b1, w2_q, w2_s, b2])
        }
        Dtype::I32 => {
            anyhow::bail!("i32 is not an expert weight ladder dtype")
        }
    }
}

/// Slice expert `e`'s weights out of the stacked parameter tensors
/// (`moe.w1 [E, M, F]` → `[M, F]`, biases `[E, F]` → `[F]`, …).
fn slice_expert(full: &HostTensor, e: usize, _part: &str) -> Result<HostTensor> {
    let shape = &full.shape;
    anyhow::ensure!(shape.len() >= 2, "stacked expert tensor expected");
    let per: usize = shape[1..].iter().product();
    let data = full.as_f32()?[e * per..(e + 1) * per].to_vec();
    Ok(HostTensor::f32(&shape[1..], data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_expert_extracts_rows() {
        let full = HostTensor::f32(
            &[2, 3],
            vec![1., 2., 3., 10., 20., 30.],
        );
        let e1 = slice_expert(&full, 1, "b1").unwrap();
        assert_eq!(e1.shape, vec![3]);
        assert_eq!(e1.as_f32().unwrap(), &[10., 20., 30.]);
        let full3 = HostTensor::f32(&[2, 2, 2],
                                    vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let e0 = slice_expert(&full3, 0, "w1").unwrap();
        assert_eq!(e0.shape, vec![2, 2]);
        assert_eq!(e0.as_f32().unwrap(), &[0., 1., 2., 3.]);
    }

    #[test]
    fn encode_expert_weights_ladder() {
        let mk = || {
            vec![
                HostTensor::f32(&[2, 3], vec![1., -2., 3., 0.5, 4., -6.]),
                HostTensor::f32(&[3], vec![0.1, 0.2, 0.3]),
                HostTensor::f32(&[3, 2], vec![1., 2., 3., 4., 5., 6.]),
                HostTensor::f32(&[2], vec![-0.1, -0.2]),
            ]
        };
        let f32_bytes: usize = mk().iter().map(|t| t.byte_len()).sum();

        // f32 passes through byte-for-byte.
        let base = encode_expert_weights(mk(), Dtype::F32).unwrap();
        assert_eq!(base, mk());

        // bf16: matrices halve, biases stay f32.
        let bf = encode_expert_weights(mk(), Dtype::BF16).unwrap();
        assert_eq!(bf.len(), 4);
        assert_eq!(bf[0].dtype(), Dtype::BF16);
        assert_eq!(bf[1].dtype(), Dtype::F32);
        assert_eq!(bf[2].dtype(), Dtype::BF16);
        assert_eq!(bf[3].dtype(), Dtype::F32);
        let bf_bytes: usize = bf.iter().map(|t| t.byte_len()).sum();
        // The two 6-element matrices halve (2 * 12 bytes saved).
        assert_eq!(bf_bytes, f32_bytes - 24);

        // i8: [w1_q, w1_scales, b1, w2_q, w2_scales, b2], and the
        // interleaved layout round-trips through the install-side
        // dequantizer to near the master weights.
        let q = encode_expert_weights(mk(), Dtype::I8).unwrap();
        assert_eq!(q.len(), 6);
        assert_eq!(q[0].dtype(), Dtype::I8);
        assert_eq!(q[1].dtype(), Dtype::F32);
        assert_eq!(q[2].dtype(), Dtype::F32);
        assert_eq!(q[3].dtype(), Dtype::I8);
        assert_eq!(q[4].dtype(), Dtype::F32);
        assert_eq!(q[5].dtype(), Dtype::F32);
        let w1 = HostTensor::dequantize_i8_per_col(&q[0], &q[1]).unwrap();
        for (a, b) in w1
            .as_f32()
            .unwrap()
            .iter()
            .zip(mk()[0].as_f32().unwrap())
        {
            assert!((a - b).abs() <= 6.0 / 127.0, "{a} vs {b}");
        }

        assert!(encode_expert_weights(mk(), Dtype::I32).is_err());
    }

    #[test]
    fn partition_lanes_even_and_uneven() {
        assert_eq!(partition_lanes(8, 1), vec![(0, 8)]);
        assert_eq!(partition_lanes(8, 2), vec![(0, 4), (4, 4)]);
        assert_eq!(partition_lanes(8, 3), vec![(0, 3), (3, 3), (6, 2)]);
        assert_eq!(
            partition_lanes(8, 4),
            vec![(0, 2), (2, 2), (4, 2), (6, 2)]
        );
        // Depth clamps to the lane count; zero depth means one group.
        assert_eq!(partition_lanes(4, 9).len(), 4);
        assert_eq!(partition_lanes(4, 0), vec![(0, 4)]);
        // Every partition is contiguous and covers the batch exactly.
        for b in 1..=9usize {
            for d in 1..=b {
                let p = partition_lanes(b, d);
                assert_eq!(p.len(), d);
                let mut next = 0;
                for &(lane0, lanes) in &p {
                    assert_eq!(lane0, next);
                    assert!(lanes > 0);
                    next += lanes;
                }
                assert_eq!(next, b);
            }
        }
    }
}
