//! Disaggregated expert-parallel engine (§5's system, at testbed scale).
//!
//! The leader owns the dense backbone (embeddings, attention, layer norms,
//! gates, residual branches, LM head) and drives it layer by layer through
//! the shared AOT programs; fabric workers own the expert FFN weights per
//! the [`Placement`].
//!
//! ## The overlapped, coalesced MoE pipeline
//!
//! Every MoE layer runs as five phases (per-phase latencies land in
//! [`Metrics`] under the same names):
//!
//! 1. **`gate`** — the `gate_*` program produces `ln(h)` and router
//!    probabilities; the `[B,S,M] → [1,T,M]` reshape is a literal-level
//!    reshape (no host round trip), and host top-1 gating builds the dense
//!    token→expert mapping table ([`Routing`]).
//! 2. **`dispatch`** — token blocks are *coalesced per owning worker*: one
//!    [`crate::fabric::ExpertFfnBatch`] per worker carries all of that
//!    worker's expert blocks packed into a single contiguous payload (the
//!    paper's grouped all-to-all, §5.1) — one channel message and one
//!    worker wakeup per worker per layer, O(workers) not O(experts).
//! 3. **`leader_overlap`** — *while the workers execute* `expert_ffn_c{C}`
//!    (each block padded internally against the compiled capacity ladder),
//!    the leader runs everything that does not depend on the expert
//!    outputs: the all-to-all plan accounting, the PR-MoE fixed residual
//!    branch, and the combine-buffer preparation (pulling the residual
//!    stream to the host).
//! 4. **`expert_wait`** — block on the coalesced worker replies (the only
//!    part of the round trip still exposed on the leader's critical path).
//! 5. **`combine`** — gate-scale and un-permute the packed expert outputs
//!    (reusing a scratch buffer across layers), add the residual branch and
//!    the residual stream.
//!
//! Setting `DSMOE_SERIAL_MOE=1` (or [`EpEngine::set_serial_moe`]) restores
//! the old serialized data path — gate → one message per expert → blocking
//! collect → residual branch after the round trip, with the original
//! literal→host→literal staging — for before/after measurement.  Both paths
//! produce **bit-identical** logits (asserted in `integration_parity.rs`);
//! the whole-layer leader wall clock lands in the `moe_layer` metric for
//! both, which is what `benches/e2e_serving.rs` compares into
//! `BENCH_e2e.json`.
//!
//! `forward_prefill` / `forward_decode` produce logits bit-comparable to the
//! monolithic engine's programs (integration_parity.rs).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use crate::config::{AllToAllKind, ModelConfig};
use crate::coordinator::alltoall::{self, Topology};
use crate::coordinator::{Placement, Routing};
use crate::fabric::{ExpertFfnBatch, Fabric, WorkerPrograms};
use crate::metrics::Metrics;
use crate::moe::ExpertLoadStats;
use crate::runtime::{
    Checkpoint, HostTensor, Manifest, Program, Runtime,
};

pub struct EpEngine {
    rt: Runtime,
    pub cfg: ModelConfig,
    params: HashMap<String, xla::Literal>,
    #[allow(dead_code)] // retained for checkpoint hot-swap (future work)
    params_host: HashMap<String, HostTensor>,
    placement: Placement,
    fabric: Fabric,
    pub metrics: std::sync::Arc<Metrics>,
    pub load_stats: Vec<ExpertLoadStats>,
    manifest_keys: ManifestKeys,
    progs: HashMap<String, Rc<Program>>,
    alltoall: AllToAllKind,
    /// Per-layer decode KV caches [B, H, Smax, hd] (monolithic layout is
    /// [L, B, ...]; the EP engine keeps per-layer tensors).
    caches: Option<(Vec<xla::Literal>, Vec<xla::Literal>)>,
    batch: usize,
    /// `DSMOE_SERIAL_MOE`: run the old serialized per-expert MoE path
    /// instead of the overlapped/coalesced pipeline (for measurement).
    serial_moe: bool,
    scratch: MoeScratch,
    /// Monotonic exchange generation: stamped into every coalesced batch
    /// so stale replies of an aborted exchange (even at the same layer of
    /// a retried forward) can never be combined into a later one.
    exchange_seq: u64,
}

struct ManifestKeys {
    manifest: Manifest,
}

/// Routing pack/combine scratch reused across MoE layers (and forwards) so
/// the hot path does not reallocate its staging buffers per layer.
#[derive(Default)]
struct MoeScratch {
    /// `[T * M]` combine accumulation buffer.
    combine: Vec<f32>,
    /// Per-worker expert lists for the current layer.
    worker_experts: Vec<Vec<usize>>,
}

impl EpEngine {
    pub fn new(
        manifest: &Manifest,
        model: &str,
        workers: usize,
        alltoall: AllToAllKind,
        batch: usize,
    ) -> Result<EpEngine> {
        let arts = manifest.model(model)?;
        let cfg = arts.config.clone();
        anyhow::ensure!(cfg.is_moe(), "EP engine needs an MoE model");
        let rt = Runtime::cpu()?;

        let ck = Checkpoint::load(&arts.checkpoint_dir)?;
        let mut params = HashMap::new();
        let mut params_host = HashMap::new();
        for (n, t) in ck.names.iter().zip(&ck.tensors) {
            params.insert(n.clone(), t.to_literal()?);
            params_host.insert(n.clone(), t.clone());
        }

        // Expert FFN program ladder for the fabric workers.
        let (m, f) = (cfg.d_model, cfg.d_ff);
        let ladder: Vec<_> = manifest
            .expert_block_sizes()
            .into_iter()
            .filter_map(|c| {
                manifest
                    .shared_program(&Manifest::key_expert_ffn(m, f, c))
                    .ok()
                    .map(|s| (c, s.clone()))
            })
            .collect();
        anyhow::ensure!(!ladder.is_empty(), "no expert_ffn programs for m{m} f{f}");

        let placement = Placement::for_model(&cfg, workers);
        let fabric = Fabric::spawn(workers, WorkerPrograms { expert_ffn: ladder })?;

        // Ship expert weights to their owners.
        for w in 0..workers {
            for (layer, e) in placement.worker_manifest(w) {
                let weights = ["w1", "b1", "w2", "b2"]
                    .iter()
                    .map(|part| {
                        let full = &params_host
                            [&format!("layer{layer}.moe.{part}")];
                        Ok(slice_expert(full, e, part)?)
                    })
                    .collect::<Result<Vec<_>>>()?;
                fabric.load_expert(w, layer, e, weights)?;
            }
        }

        let load_stats = cfg
            .moe_layers()
            .into_iter()
            .map(|(i, e)| ExpertLoadStats::new(i, e))
            .collect();

        Ok(EpEngine {
            rt,
            cfg,
            params,
            params_host,
            placement,
            fabric,
            metrics: std::sync::Arc::new(Metrics::new()),
            load_stats,
            manifest_keys: ManifestKeys { manifest: manifest.clone() },
            progs: HashMap::new(),
            alltoall,
            caches: None,
            batch,
            serial_moe: std::env::var_os("DSMOE_SERIAL_MOE")
                .map_or(false, |v| v != "0"),
            scratch: MoeScratch::default(),
            exchange_seq: 0,
        })
    }

    /// Select the serialized (`true`) or overlapped/coalesced (`false`)
    /// MoE data path.  Defaults to the `DSMOE_SERIAL_MOE` env toggle;
    /// exposed programmatically so tests and benches can compare both paths
    /// in one process without racing on the environment.
    pub fn set_serial_moe(&mut self, serial: bool) {
        self.serial_moe = serial;
    }

    pub fn serial_moe(&self) -> bool {
        self.serial_moe
    }

    fn prog(&mut self, key: &str) -> Result<Rc<Program>> {
        if let Some(p) = self.progs.get(key) {
            return Ok(p.clone());
        }
        let spec = self.manifest_keys.manifest.shared_program(key)?;
        let p = self.rt.load(spec)?;
        self.progs.insert(key.to_string(), p.clone());
        Ok(p)
    }

    fn p(&self, name: &str) -> &xla::Literal {
        &self.params[name]
    }

    /// Full prefill over padded prompts [B, smax]; returns last-position
    /// logits per lane at `lens[b]-1` and primes the decode caches.
    pub fn forward_prefill(
        &mut self,
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        let (b, smax) = (self.batch, self.cfg.max_seq);
        anyhow::ensure!(tokens.len() == b * smax, "tokens shape");
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);
        let t_tokens = b * smax;

        // embed
        let embed = self.prog(&Manifest::key_embed(v, m, b, smax))?;
        let tok = HostTensor::i32(&[b, smax], tokens.to_vec()).to_literal()?;
        let pos0 = HostTensor::i32(&[b], vec![0; b]).to_literal()?;
        let mut h = embed
            .run_literal_refs(&[
                self.p("tok_emb"),
                self.p("pos_emb"),
                &tok,
                &pos0,
            ])?
            .remove(0);

        let mut kcs = Vec::new();
        let mut vcs = Vec::new();
        for layer in 0..self.cfg.n_layers {
            let (h2, k, vv) = self.attn_prefill(layer, h)?;
            kcs.push(k);
            vcs.push(vv);
            h = self.ffn_layer(layer, h2, t_tokens)?;
        }
        self.caches = Some((kcs, vcs));

        // LM head on each lane's last real position.
        let h_host = HostTensor::from_literal(&h)?; // [B, smax, M]
        let hd = h_host.as_f32()?;
        let mut last = vec![0f32; b * m];
        for lane in 0..b {
            let p = lens[lane].max(1) - 1;
            let off = (lane * smax + p) * m;
            last[lane * m..(lane + 1) * m]
                .copy_from_slice(&hd[off..off + m]);
        }
        self.lm_head(last)
    }

    /// One decode step over [B] tokens at per-lane positions.
    pub fn forward_decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let b = self.batch;
        anyhow::ensure!(tokens.len() == b && pos.len() == b);
        let (v, m) = (self.cfg.vocab_size, self.cfg.d_model);
        anyhow::ensure!(self.caches.is_some(), "decode before prefill");

        let embed = self.prog(&Manifest::key_embed(v, m, b, 1))?;
        let tok = HostTensor::i32(&[b, 1], tokens.to_vec()).to_literal()?;
        let pos_lit = HostTensor::i32(&[b], pos.to_vec()).to_literal()?;
        let mut h = embed
            .run_literal_refs(&[
                self.p("tok_emb"),
                self.p("pos_emb"),
                &tok,
                &pos_lit,
            ])?
            .remove(0);

        for layer in 0..self.cfg.n_layers {
            h = self.attn_decode(layer, h, &pos_lit)?;
            h = self.ffn_layer(layer, h, b)?;
        }
        // [B, 1, M]: feed the LM head straight from the literal (one host
        // copy, not the from_literal + to_vec double copy).
        self.lm_head(h.to_vec::<f32>()?)
    }

    fn attn_prefill(
        &mut self,
        layer: usize,
        h: xla::Literal,
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let (m, hh, b, smax) =
            (self.cfg.d_model, self.cfg.n_heads, self.batch, self.cfg.max_seq);
        let prog = self.prog(&Manifest::key_attn_prefill(m, hh, b, smax))?;
        let pre = format!("layer{layer}.");
        let mut outs = prog.run_literal_refs(&[
            &h,
            self.p(&format!("{pre}ln1.g")),
            self.p(&format!("{pre}ln1.b")),
            self.p(&format!("{pre}attn.wq")),
            self.p(&format!("{pre}attn.wk")),
            self.p(&format!("{pre}attn.wv")),
            self.p(&format!("{pre}attn.wo")),
        ])?;
        let vv = outs.pop().unwrap();
        let k = outs.pop().unwrap();
        let h2 = outs.pop().unwrap();
        Ok((h2, k, vv))
    }

    fn attn_decode(
        &mut self,
        layer: usize,
        h: xla::Literal,
        pos: &xla::Literal,
    ) -> Result<xla::Literal> {
        let (m, hh, b, smax) =
            (self.cfg.d_model, self.cfg.n_heads, self.batch, self.cfg.max_seq);
        let prog = self.prog(&Manifest::key_attn_decode(m, hh, b, smax))?;
        let pre = format!("layer{layer}.");
        let (kcs, vcs) = self.caches.as_ref().unwrap();
        let mut outs = prog.run_literal_refs(&[
            &h,
            self.p(&format!("{pre}ln1.g")),
            self.p(&format!("{pre}ln1.b")),
            self.p(&format!("{pre}attn.wq")),
            self.p(&format!("{pre}attn.wk")),
            self.p(&format!("{pre}attn.wv")),
            self.p(&format!("{pre}attn.wo")),
            &kcs[layer],
            &vcs[layer],
            pos,
        ])?;
        let vc = outs.pop().unwrap();
        let kc = outs.pop().unwrap();
        let h2 = outs.pop().unwrap();
        let (kcs, vcs) = self.caches.as_mut().unwrap();
        kcs[layer] = kc;
        vcs[layer] = vc;
        Ok(h2)
    }

    /// FFN sublayer: dense program or the expert-parallel MoE path.
    fn ffn_layer(
        &mut self,
        layer: usize,
        h: xla::Literal,
        t_tokens: usize,
    ) -> Result<xla::Literal> {
        let (m, f) = (self.cfg.d_model, self.cfg.d_ff);
        let pre = format!("layer{layer}.");
        let n_experts = self.cfg.experts_at(layer);
        if n_experts == 0 {
            let prog = self.prog(&Manifest::key_dense_ffn(m, f, t_tokens))?;
            // dense_ffn operates on [1, T, M]: reshape at the literal level
            // instead of the old literal->host->literal round trip.
            let orig_dims: Vec<i64> = h.array_shape()?.dims().to_vec();
            let flat = h.reshape(&[1, t_tokens as i64, m as i64])?;
            let out = prog
                .run_literal_refs(&[
                    &flat,
                    self.p(&format!("{pre}ln2.g")),
                    self.p(&format!("{pre}ln2.b")),
                    self.p(&format!("{pre}mlp.w1")),
                    self.p(&format!("{pre}mlp.b1")),
                    self.p(&format!("{pre}mlp.w2")),
                    self.p(&format!("{pre}mlp.b2")),
                ])?
                .remove(0);
            return Ok(out.reshape(&orig_dims)?);
        }
        if self.serial_moe {
            return self.moe_layer_serial(layer, h, t_tokens);
        }

        // --- MoE path: overlapped, coalesced pipeline ------------------
        let t_layer = std::time::Instant::now();

        // Phase 1: gate.  [B,S,M] -> [1,T,M] is a literal reshape; only
        // ln(h) and the router probabilities come back to the host (the
        // routing tables need them).
        let t0 = std::time::Instant::now();
        let gate = self.prog(&Manifest::key_gate(m, n_experts, t_tokens))?;
        let shape: Vec<usize> = h
            .array_shape()?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect();
        let flat = h.reshape(&[1, t_tokens as i64, m as i64])?;
        let outs = gate.run_literal_refs(&[
            &flat,
            self.p(&format!("{pre}ln2.g")),
            self.p(&format!("{pre}ln2.b")),
            self.p(&format!("{pre}moe.gate")),
        ])?;
        let ln_h = HostTensor::from_literal(&outs[0])?; // [T, M]
        let probs = HostTensor::from_literal(&outs[1])?; // [T, E]
        self.metrics.observe("gate", t0.elapsed());

        let routing = Routing::top1(probs.as_f32()?, n_experts);
        if let Some(stats) = self
            .load_stats
            .iter_mut()
            .find(|s| s.layer == layer)
        {
            stats.record_assignments(routing.assignments());
        }

        // Phase 2: coalesced dispatch — one ExpertFfnBatch per owning
        // worker (replica 0 group), all of its expert blocks packed into a
        // single payload whose ownership moves into the channel.
        let t1 = std::time::Instant::now();
        let (ep_degree, owners): (usize, Vec<usize>) = {
            let lp = self.placement.layer(layer).unwrap();
            (lp.ep_degree, (0..n_experts).map(|e| lp.owner(e, 0)).collect())
        };
        let mut worker_experts =
            std::mem::take(&mut self.scratch.worker_experts);
        for v in &mut worker_experts {
            v.clear();
        }
        if worker_experts.len() < self.fabric.n_workers() {
            worker_experts.resize(self.fabric.n_workers(), Vec::new());
        }
        for e in 0..n_experts {
            if routing.counts[e] > 0 {
                worker_experts[owners[e]].push(e);
            }
        }
        let ln_flat = ln_h.as_f32()?;
        self.exchange_seq += 1;
        let exchange_tag = self.exchange_seq;
        let mut inflight = 0usize;
        for (w, experts) in worker_experts.iter().enumerate() {
            if experts.is_empty() {
                continue;
            }
            let total: usize =
                experts.iter().map(|&e| routing.counts[e]).sum();
            let mut data = Vec::new();
            routing.pack_blocks(ln_flat, m, experts, &mut data);
            self.fabric.dispatch_ffn_batch(
                w,
                ExpertFfnBatch {
                    layer,
                    experts: experts
                        .iter()
                        .map(|&e| (e, routing.counts[e]))
                        .collect(),
                    data: HostTensor::f32(&[total, m], data),
                    tag: exchange_tag,
                },
            )?;
            inflight += 1;
        }
        self.metrics.observe("dispatch", t1.elapsed());

        // Phase 3: leader overlap — everything that does not depend on the
        // expert outputs runs while the workers execute: all-to-all plan
        // accounting, the PR-MoE fixed residual branch, and the combine
        // buffer prep (pulling the residual stream to the host).
        let t2 = std::time::Instant::now();
        let plan = self.exchange_plan(&routing, ep_degree, m);
        self.metrics.inc("alltoall_bytes", plan.volume() as u64);
        self.metrics.inc("alltoall_hops", plan.hops() as u64);
        let residual: Option<Vec<f32>> = if self.cfg.residual {
            let rb =
                self.prog(&Manifest::key_residual_branch(m, f, t_tokens))?;
            let out = rb
                .run_literal_refs(&[
                    &outs[0], // ln(h) [T, M], no host round trip
                    self.p(&format!("{pre}moe.res.w1")),
                    self.p(&format!("{pre}moe.res.b1")),
                    self.p(&format!("{pre}moe.res.w2")),
                    self.p(&format!("{pre}moe.res.b2")),
                ])?
                .remove(0);
            Some(out.to_vec::<f32>()?)
        } else {
            None
        };
        // Combine prep: the residual stream, pulled to the host once (the
        // [1,T,M] reshape shares h's row-major element order).
        let mut out_data: Vec<f32> = flat.to_vec()?;
        self.metrics.observe("leader_overlap", t2.elapsed());

        // Phase 4: wait for the coalesced worker replies.
        let t3 = std::time::Instant::now();
        let results =
            self.fabric.collect_ffn_batches(inflight, layer, exchange_tag)?;
        self.metrics.observe("expert_wait", t3.elapsed());

        // Phase 5: combine — gate-scale, un-permute (scratch buffer reused
        // across layers), then add the residual branch and the residual
        // stream in the same order as the serial path (bit-identical).
        let t4 = std::time::Instant::now();
        let mut combined = std::mem::take(&mut self.scratch.combine);
        {
            let packs: Vec<(&[(usize, usize)], &[f32])> = results
                .iter()
                .map(|r| Ok((r.experts.as_slice(), r.data.as_f32()?)))
                .collect::<Result<_>>()?;
            routing.combine_packed(&packs, m, &mut combined)?;
        }
        if let Some(res) = &residual {
            for (c, r) in combined.iter_mut().zip(res) {
                *c += *r;
            }
        }
        for (o, c) in out_data.iter_mut().zip(&combined) {
            *o += *c;
        }
        let out = HostTensor::f32(&shape, out_data).to_literal()?;
        self.scratch.combine = combined;
        self.scratch.worker_experts = worker_experts;
        self.metrics.observe("combine", t4.elapsed());
        self.metrics.observe("moe_layer", t_layer.elapsed());
        Ok(out)
    }

    /// The pre-overlap serialized MoE path (`DSMOE_SERIAL_MOE=1`): gate →
    /// one message per expert → blocking collect → combine → residual
    /// branch, with the original literal→host→literal staging.  Kept
    /// verbatim as the before/after measurement baseline; must stay
    /// bit-identical to the overlapped pipeline.
    fn moe_layer_serial(
        &mut self,
        layer: usize,
        h: xla::Literal,
        t_tokens: usize,
    ) -> Result<xla::Literal> {
        let (m, f) = (self.cfg.d_model, self.cfg.d_ff);
        let pre = format!("layer{layer}.");
        let n_experts = self.cfg.experts_at(layer);
        let t_layer = std::time::Instant::now();

        let t0 = std::time::Instant::now();
        let gate = self.prog(&Manifest::key_gate(m, n_experts, t_tokens))?;
        let h_host = HostTensor::from_literal(&h)?;
        let shape = h_host.shape.clone();
        let flat = HostTensor::f32(&[1, t_tokens, m], h_host.as_f32()?.to_vec())
            .to_literal()?;
        let outs = gate.run_literal_refs(&[
            &flat,
            self.p(&format!("{pre}ln2.g")),
            self.p(&format!("{pre}ln2.b")),
            self.p(&format!("{pre}moe.gate")),
        ])?;
        let ln_h = HostTensor::from_literal(&outs[0])?; // [T, M]
        let probs = HostTensor::from_literal(&outs[1])?; // [T, E]
        self.metrics.observe("gate", t0.elapsed());

        let routing = Routing::top1(probs.as_f32()?, n_experts);
        if let Some(stats) = self
            .load_stats
            .iter_mut()
            .find(|s| s.layer == layer)
        {
            stats.record_assignments(routing.assignments());
        }

        // Log the all-to-all schedule this exchange would use at scale.
        let lp = self.placement.layer(layer).unwrap();
        let plan = self.exchange_plan(&routing, lp.ep_degree, m);
        self.metrics
            .inc("alltoall_bytes", plan.volume() as u64);
        self.metrics.inc("alltoall_hops", plan.hops() as u64);

        // Dispatch expert blocks to their owners (replica 0 group).
        let t1 = std::time::Instant::now();
        let ln_flat = ln_h.as_f32()?;
        let mut inflight = 0usize;
        for e in 0..n_experts {
            if routing.counts[e] == 0 {
                continue;
            }
            let block = routing.expert_block(ln_flat, m, e);
            let owner = lp.owner(e, 0);
            self.fabric.dispatch_ffn(
                owner,
                layer,
                e,
                HostTensor::f32(&[routing.counts[e], m], block),
                e as u64,
            )?;
            inflight += 1;
        }
        let results = self.fabric.collect_ffn(inflight)?;
        self.metrics.observe("expert_exchange", t1.elapsed());

        let mut expert_outputs: Vec<Vec<f32>> =
            vec![Vec::new(); n_experts];
        for (_, e, out, _) in results {
            expert_outputs[e] = out.as_f32()?.to_vec();
        }
        let mut combined = routing.combine(&expert_outputs, m);

        // Residual-MoE fixed branch (PR-MoE): runs at the leader (it is a
        // dense, non-expert computation).
        if self.cfg.residual {
            let rb =
                self.prog(&Manifest::key_residual_branch(m, f, t_tokens))?;
            let lnh_lit =
                HostTensor::f32(&[t_tokens, m], ln_flat.to_vec()).to_literal()?;
            let out = rb
                .run_literal_refs(&[
                    &lnh_lit,
                    self.p(&format!("{pre}moe.res.w1")),
                    self.p(&format!("{pre}moe.res.b1")),
                    self.p(&format!("{pre}moe.res.w2")),
                    self.p(&format!("{pre}moe.res.b2")),
                ])?
                .remove(0);
            let res = HostTensor::from_literal(&out)?;
            for (c, r) in combined.iter_mut().zip(res.as_f32()?) {
                *c += r;
            }
        }

        // Residual add: h + combined.
        let mut out = h_host.as_f32()?.to_vec();
        for (o, c) in out.iter_mut().zip(&combined) {
            *o += c;
        }
        let out = HostTensor::f32(&shape, out).to_literal()?;
        self.metrics.observe("moe_layer", t_layer.elapsed());
        Ok(out)
    }

    /// Build the all-to-all byte matrix this routing implies at EP degree
    /// `ep` (tokens sharded round-robin over workers, as they would be when
    /// each worker owns part of the batch) and plan it with the configured
    /// schedule.
    fn exchange_plan(
        &self,
        routing: &Routing,
        ep: usize,
        m: usize,
    ) -> alltoall::Plan {
        let mut bytes = vec![vec![0usize; ep]; ep];
        for (t, &e) in routing.expert.iter().enumerate() {
            let src = t % ep; // token's home shard
            let dst = e % ep; // expert's owner (round-robin placement)
            if src != dst {
                bytes[src][dst] += m * 4;
            }
        }
        let topo = Topology {
            workers: ep,
            node_size: ep.min(8),
            ts_degree: 1,
        };
        alltoall::plan(self.alltoall, topo, &bytes)
    }

    fn lm_head(&mut self, last_h: Vec<f32>) -> Result<Vec<Vec<f32>>> {
        let (v, m, b) = (self.cfg.vocab_size, self.cfg.d_model, self.batch);
        let prog = self.prog(&Manifest::key_lm_head(v, m, b))?;
        let h = HostTensor::f32(&[b, m], last_h).to_literal()?;
        let out = prog
            .run_literal_refs(&[
                &h,
                self.p("lnf.g"),
                self.p("lnf.b"),
                self.p("tok_emb"),
            ])?
            .remove(0);
        let logits = HostTensor::from_literal(&out)?;
        let data = logits.as_f32()?;
        Ok((0..b).map(|lane| data[lane * v..(lane + 1) * v].to_vec()).collect())
    }

    pub fn traffic(&self) -> &crate::fabric::Traffic {
        &self.fabric.traffic
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }
}

/// Slice expert `e`'s weights out of the stacked parameter tensors
/// (`moe.w1 [E, M, F]` → `[M, F]`, biases `[E, F]` → `[F]`, …).
fn slice_expert(full: &HostTensor, e: usize, _part: &str) -> Result<HostTensor> {
    let shape = &full.shape;
    anyhow::ensure!(shape.len() >= 2, "stacked expert tensor expected");
    let per: usize = shape[1..].iter().product();
    let data = full.as_f32()?[e * per..(e + 1) * per].to_vec();
    Ok(HostTensor::f32(&shape[1..], data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_expert_extracts_rows() {
        let full = HostTensor::f32(
            &[2, 3],
            vec![1., 2., 3., 10., 20., 30.],
        );
        let e1 = slice_expert(&full, 1, "b1").unwrap();
        assert_eq!(e1.shape, vec![3]);
        assert_eq!(e1.as_f32().unwrap(), &[10., 20., 30.]);
        let full3 = HostTensor::f32(&[2, 2, 2],
                                    vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let e0 = slice_expert(&full3, 0, "w1").unwrap();
        assert_eq!(e0.shape, vec![2, 2]);
        assert_eq!(e0.as_f32().unwrap(), &[0., 1., 2., 3.]);
    }
}
