//! Configuration types: model architecture (mirrors
//! `python/compile/configs.py` via the manifest), serving and training
//! settings, and the paper-scale inference configurations of Table 6.

pub mod paper;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Architecture of one model variant (loaded from the manifest — the Python
/// registry is the single source of truth for the tiny testbed family).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    /// experts_schedule[i] = number of experts on layer i (0 = dense FFN).
    pub experts_schedule: Vec<usize>,
    pub residual: bool,
    pub top2: bool,
    pub capacity_factor: f64,
    pub moe_loss_coef: f64,
    pub teacher: Option<String>,
    pub kd_alpha: f64,
    pub num_params: usize,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().with_context(|| format!("field {k}"))
        };
        let f = |k: &str| -> Result<f64> {
            j.req(k)?.as_f64().with_context(|| format!("field {k}"))
        };
        Ok(ModelConfig {
            name: j.req("name")?.as_str().context("name")?.to_string(),
            vocab_size: u("vocab_size")?,
            n_layers: u("n_layers")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            d_ff: u("d_ff")?,
            max_seq: u("max_seq")?,
            experts_schedule: j.req("experts_schedule")?.usize_vec()?,
            residual: j.req("residual")?.as_bool().unwrap_or(false),
            top2: j.req("top2")?.as_bool().unwrap_or(false),
            capacity_factor: f("capacity_factor")?,
            moe_loss_coef: f("moe_loss_coef")?,
            teacher: j
                .get("teacher")
                .and_then(|t| t.as_str())
                .map(|s| s.to_string()),
            kd_alpha: j.get("kd_alpha").and_then(|v| v.as_f64()).unwrap_or(1.0),
            num_params: u("num_params")?,
        })
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn is_moe(&self) -> bool {
        self.experts_schedule.iter().any(|&e| e > 0)
    }

    pub fn experts_at(&self, layer: usize) -> usize {
        self.experts_schedule.get(layer).copied().unwrap_or(0)
    }

    /// Layers that carry an MoE FFN (index, n_experts).
    pub fn moe_layers(&self) -> Vec<(usize, usize)> {
        self.experts_schedule
            .iter()
            .enumerate()
            .filter(|&(_, &e)| e > 0)
            .map(|(i, &e)| (i, e))
            .collect()
    }

    /// Max experts on any layer (drives expert-parallel worker layout).
    pub fn max_experts(&self) -> usize {
        self.experts_schedule.iter().copied().max().unwrap_or(0)
    }

    /// Total expert parameter count vs non-expert ("base") count: the split
    /// that drives the paper's parallelism choices (EP for experts, TP/DP
    /// for the rest).
    pub fn param_split(&self) -> (usize, usize) {
        let (m, f) = (self.d_model, self.d_ff);
        let expert_ffn = m * f + f + f * m + m;
        let mut expert = 0usize;
        for &e in &self.experts_schedule {
            if e > 0 {
                expert += e * expert_ffn + m * e; // experts + gate
            }
        }
        (expert, self.num_params - expert)
    }
}

/// Serving engine settings (testbed scale).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Model variant to serve (must have prefill/decode programs).
    pub model: String,
    /// Expert-parallel worker count (1 = single device).
    pub workers: usize,
    /// Decode batch lanes (must be one of the compiled batch sizes).
    pub max_batch: usize,
    /// Batch formation timeout.
    pub batch_timeout: std::time::Duration,
    /// Max new tokens per request unless the request says otherwise.
    pub max_new_tokens: usize,
    /// All-to-all schedule used by the expert-parallel path.
    pub alltoall: AllToAllKind,
    /// Microbatch pipeline ring depth for the expert-parallel engine:
    /// N in-flight tagged exchanges per forward.  Applied by
    /// `Scheduler::new` through `ForwardModel::configure` (equivalently
    /// `EpEngine::set_pipe_depth`); falls back 2 → 1 when the artifact
    /// set lacks the group-sized program shapes.
    pub pipe_depth: usize,
    /// Leader shard threads for the expert-parallel engine: values >= 2
    /// run each pipeline microbatch group's dense backbone on its own
    /// OS thread + thread-bound runtime (`DSMOE_LEADER_THREADS`; applied
    /// through `ForwardModel::configure`, equivalently
    /// `EpEngine::set_leader_threads`).  1 (default) keeps the
    /// single-threaded leader.
    pub leader_threads: usize,
    /// Chunked-prefill token budget (`DSMOE_PREFILL_CHUNK`): a staged
    /// admission advances at most this many prompt tokens' worth of layer
    /// work behind each decode step and stays staged across steps until
    /// done, so a giant prompt can't stall decode lanes for its whole
    /// prefill.  0 (default) = off: the admission completes after one
    /// decode step, exactly the pre-chunking behavior.
    pub prefill_chunk: usize,
    /// Per-tier inbound queue capacity (`DSMOE_QUEUE_CAP`): submissions
    /// beyond it hit `shed_policy`.  0 (default) = unbounded, the
    /// pre-backpressure behavior.
    pub queue_cap: usize,
    /// What to do with a submission to a full tier queue
    /// (`DSMOE_SHED_POLICY`).
    pub shed_policy: ShedPolicy,
    /// Greedy (argmax) vs temperature sampling.
    pub temperature: f32,
    /// Seed for temperature sampling (`util::sampling::Sampler`), so
    /// sampled generations are reproducible-but-configurable.  Greedy
    /// decoding ignores it.
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            model: "moe-s-8".into(),
            workers: 1,
            max_batch: 8,
            batch_timeout: std::time::Duration::from_millis(2),
            max_new_tokens: 16,
            alltoall: AllToAllKind::Hierarchical,
            // Seeded from DSMOE_PIPE_DEPTH / DSMOE_LEADER_THREADS so the
            // env toggles survive the scheduler path: on that path this
            // config is the single source of truth (Scheduler::new
            // applies it through ForwardModel::configure, overwriting any
            // earlier set_pipe_depth / set_leader_threads), so pass
            // non-default values here rather than on the engine.
            pipe_depth: crate::util::env_pos_usize("DSMOE_PIPE_DEPTH", 2),
            leader_threads: crate::util::env_pos_usize(
                "DSMOE_LEADER_THREADS",
                1,
            ),
            prefill_chunk: crate::util::env_usize_off(
                "DSMOE_PREFILL_CHUNK",
                0,
            ),
            queue_cap: crate::util::env_usize_off("DSMOE_QUEUE_CAP", 0),
            shed_policy: ShedPolicy::from_env(),
            temperature: 0.0,
            seed: 0xD5, // the old Engine's hard-coded RNG seed
        }
    }
}

/// Backpressure policy for a full tier queue (`DSMOE_SHED_POLICY`): how
/// the router responds when `ServingConfig::queue_cap` is hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Shed the *new* submission (load shedding at the front door).
    #[default]
    Reject,
    /// Admit the new submission and shed the oldest queued request of the
    /// same tier (the one most likely past its deadline anyway).
    DropOldest,
}

impl ShedPolicy {
    /// Parse `DSMOE_SHED_POLICY`: unset → `Reject`; garbage → warn on
    /// stderr and fall back to `Reject` (same contract as the numeric
    /// env parsers in `util`).
    pub fn from_env() -> Self {
        let Some(raw) = std::env::var_os("DSMOE_SHED_POLICY") else {
            return ShedPolicy::Reject;
        };
        let s = raw.to_string_lossy();
        match s.trim().parse() {
            Ok(p) => p,
            Err(_) => {
                eprintln!(
                    "[config] DSMOE_SHED_POLICY={s:?} is not \
                     reject|drop-oldest; falling back to reject"
                );
                ShedPolicy::Reject
            }
        }
    }
}

impl std::str::FromStr for ShedPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "reject" => ShedPolicy::Reject,
            "drop-oldest" | "drop_oldest" => ShedPolicy::DropOldest,
            _ => anyhow::bail!("unknown shed policy {s:?}"),
        })
    }
}

/// The three all-to-all schedules the paper compares (§5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllToAllKind {
    /// Naive: every pair exchanges directly — O(p) hops.
    Naive,
    /// Hierarchical: intra-node exchange + inter-node — O(G + p/G).
    Hierarchical,
    /// Parallelism-coordinated: all-to-all only within same tensor-slicing
    /// rank — O(p/L) + O(L).
    Coordinated,
}

impl std::str::FromStr for AllToAllKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "naive" => AllToAllKind::Naive,
            "hierarchical" => AllToAllKind::Hierarchical,
            "coordinated" => AllToAllKind::Coordinated,
            _ => anyhow::bail!("unknown all-to-all kind {s:?}"),
        })
    }
}

/// Training settings (Table 1 analogue for the tiny family).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub steps: usize,
    pub lr: f64,
    pub min_lr: f64,
    pub warmup_steps: usize,
    /// Cosine decay horizon (paper: decay over 260–300B tokens).
    pub decay_steps: usize,
    pub eval_every: usize,
    pub seed: u64,
    /// Staged KD: stop distillation at this fraction of total steps
    /// (paper stops at 400K of ~570K steps ≈ 0.7); None = no KD.
    pub kd_stop_frac: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "moe-s-8".into(),
            steps: 400,
            lr: 1e-3,
            min_lr: 1e-4,
            warmup_steps: 20,
            decay_steps: 400,
            eval_every: 20,
            seed: 1234,
            kd_stop_frac: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_json() -> Json {
        Json::parse(
            r#"{"name":"moe-s-8","vocab_size":512,"n_layers":4,
                "d_model":128,"n_heads":4,"d_ff":512,"max_seq":64,
                "experts_schedule":[0,8,0,8],"residual":false,"top2":false,
                "capacity_factor":2.0,"moe_loss_coef":0.01,
                "teacher":null,"kd_alpha":1.0,"num_params":3200000}"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_model_config() {
        let c = ModelConfig::from_json(&demo_json()).unwrap();
        assert_eq!(c.name, "moe-s-8");
        assert!(c.is_moe());
        assert_eq!(c.moe_layers(), vec![(1, 8), (3, 8)]);
        assert_eq!(c.max_experts(), 8);
        assert_eq!(c.head_dim(), 32);
        assert!(c.teacher.is_none());
    }

    #[test]
    fn param_split_counts_experts() {
        let c = ModelConfig::from_json(&demo_json()).unwrap();
        let (expert, base) = c.param_split();
        let ffn = 128 * 512 + 512 + 512 * 128 + 128;
        assert_eq!(expert, 2 * (8 * ffn + 128 * 8));
        assert_eq!(expert + base, c.num_params);
    }

    #[test]
    fn alltoall_parse() {
        assert_eq!(
            "hierarchical".parse::<AllToAllKind>().unwrap(),
            AllToAllKind::Hierarchical
        );
        assert!("bogus".parse::<AllToAllKind>().is_err());
    }
}
