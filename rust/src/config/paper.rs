//! Paper-scale model configurations (Table 6 + the dense comparators of
//! Figures 14/15 and the training models of Table 1).  These drive the
//! cluster performance simulator; they are never executed on the testbed.

/// A paper-scale transformer (dense base; experts added via `experts`).
#[derive(Debug, Clone, PartialEq)]
pub struct PaperModel {
    pub name: &'static str,
    pub n_layers: usize,
    pub hidden: usize,
    pub n_heads: usize,
    /// Experts per MoE layer (0 = dense model).  MoE on every other layer.
    pub experts: usize,
    /// Tensor-slicing (model-parallel) degree used in the paper's setup.
    pub mp_degree: usize,
    /// Expert-parallel degree used in the paper's setup.
    pub ep_degree: usize,
    /// Total parameter count (billions) as declared in the paper's tables.
    /// 0.0 = derive from the architecture.  Declared values are used for
    /// memory/bandwidth modelling because the paper's larger configs do not
    /// exactly match the standard GPT parameter formula (their table is
    /// authoritative for bytes moved).
    pub declared_total_b: f64,
}

impl PaperModel {
    pub fn d_ff(&self) -> usize {
        4 * self.hidden
    }

    pub fn n_moe_layers(&self) -> usize {
        if self.experts == 0 {
            0
        } else {
            self.n_layers / 2
        }
    }

    /// Total parameters in billions: the paper's declared figure when
    /// available, else derived from the architecture.
    pub fn params_b(&self) -> f64 {
        if self.declared_total_b > 0.0 {
            self.declared_total_b
        } else {
            self.derived_params_b()
        }
    }

    /// Architecture-derived parameter count (embeddings + per-layer
    /// attn/FFN, experts on every other FFN layer).
    pub fn derived_params_b(&self) -> f64 {
        let h = self.hidden as f64;
        let vocab = 51_200.0; // GPT-2 BPE vocab padded, as Megatron
        let emb = vocab * h;
        let attn = 4.0 * h * h;
        let ffn = 8.0 * h * h; // w1 (h x 4h) + w2 (4h x h)
        let mut total = emb;
        for i in 0..self.n_layers {
            total += attn;
            if self.experts > 0 && i % 2 == 1 {
                total += ffn * self.experts as f64 + h * self.experts as f64;
            } else {
                total += ffn;
            }
        }
        total / 1e9
    }

    /// Parameters on the token's critical path (base + one expert per MoE
    /// layer) — the quantity the paper's §5.1 "best-case view" is about.
    pub fn activated_params_b(&self) -> f64 {
        let h = self.hidden as f64;
        let vocab = 51_200.0;
        let total = vocab * h
            + self.n_layers as f64 * (4.0 * h * h + 8.0 * h * h);
        total / 1e9
    }

    /// Expert vs non-expert parameter split, in billions.  The derived
    /// expert/base ratio is applied to the (possibly declared) total so the
    /// two always sum to `params_b()`.
    pub fn param_split_b(&self) -> (f64, f64) {
        let h = self.hidden as f64;
        let ffn = 8.0 * h * h;
        let expert_derived = self.n_moe_layers() as f64
            * (ffn * self.experts as f64 + h * self.experts as f64)
            / 1e9;
        let frac = expert_derived / self.derived_params_b();
        let expert = frac * self.params_b();
        (expert, self.params_b() - expert)
    }
}

/// Table 6: the MoE configurations of the inference evaluation.
pub fn table6() -> Vec<PaperModel> {
    vec![
        PaperModel { name: "1.3B+MoE-128", n_layers: 24, hidden: 2048,
                     n_heads: 16, experts: 128, mp_degree: 1, ep_degree: 128,
                     declared_total_b: 52.0 },
        PaperModel { name: "2.4B+MoE-128", n_layers: 16, hidden: 3584,
                     n_heads: 28, experts: 128, mp_degree: 1, ep_degree: 128,
                     declared_total_b: 107.7 },
        PaperModel { name: "8B+MoE-128", n_layers: 30, hidden: 4096,
                     n_heads: 32, experts: 128, mp_degree: 4, ep_degree: 128,
                     declared_total_b: 349.0 },
        PaperModel { name: "24B+MoE-128", n_layers: 40, hidden: 8192,
                     n_heads: 64, experts: 128, mp_degree: 8, ep_degree: 128,
                     declared_total_b: 1064.9 },
        PaperModel { name: "47B+MoE-128", n_layers: 58, hidden: 8192,
                     n_heads: 64, experts: 128, mp_degree: 8, ep_degree: 128,
                     declared_total_b: 2024.0 },
    ]
}

pub fn by_name(name: &str) -> Option<PaperModel> {
    table6()
        .into_iter()
        .chain(dense_models())
        .chain(training_models())
        .find(|m| m.name == name)
}

/// Dense comparators (Figs 14/15) and the MT-NLG-ish 530B for context.
pub fn dense_models() -> Vec<PaperModel> {
    vec![
        PaperModel { name: "dense-6.7B", n_layers: 32, hidden: 4096,
                     n_heads: 32, experts: 0, mp_degree: 1, ep_degree: 1,
                     declared_total_b: 6.7 },
        PaperModel { name: "dense-175B", n_layers: 96, hidden: 12288,
                     n_heads: 96, experts: 0, mp_degree: 16, ep_degree: 1,
                     declared_total_b: 175.0 },
    ]
}

/// Table 1 training models (dense + MoE pairs used by Table 3 / Fig 1).
pub fn training_models() -> Vec<PaperModel> {
    vec![
        PaperModel { name: "dense-350M", n_layers: 24, hidden: 1024,
                     n_heads: 16, experts: 0, mp_degree: 1, ep_degree: 1,
                     declared_total_b: 0.35 },
        PaperModel { name: "dense-1.3B", n_layers: 24, hidden: 2048,
                     n_heads: 16, experts: 0, mp_degree: 1, ep_degree: 1,
                     declared_total_b: 1.3 },
        PaperModel { name: "350M+MoE-128", n_layers: 24, hidden: 1024,
                     n_heads: 16, experts: 128, mp_degree: 1, ep_degree: 128,
                     declared_total_b: 13.0 },
    ]
}

/// PR-MoE / MoS variants of a standard-MoE config (Figs 12/13): the paper
/// reports "up to 3x" (PR-MoE) and "up to 3.7x" (PR-MoE+MoS) total-size
/// reduction at the same quality.  We model them as parameter scale factors
/// on the expert partition plus a depth reduction for MoS (12.5%).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Standard,
    PrMoe,
    PrMoeMos,
}

impl Variant {
    pub fn label(self) -> &'static str {
        match self {
            Variant::Standard => "MoE",
            Variant::PrMoe => "PR-MoE",
            Variant::PrMoeMos => "PR-MoE+MoS",
        }
    }

    /// Multiplier on expert parameter bytes (paper §4 summary: PR-MoE up to
    /// 3x smaller; +MoS 3.7x including the 12.5% depth cut).
    pub fn expert_scale(self) -> f64 {
        match self {
            Variant::Standard => 1.0,
            // 1.3B case: 31B/52B expert partitions -> ~0.58; 350M case 4/13
            // -> ~0.31.  We use the 1.3B-class ratio (the inference study's
            // models are all 1.3B+ scale).
            Variant::PrMoe => 0.58,
            Variant::PrMoeMos => 0.58 * 0.875,
        }
    }

    /// Multiplier on depth (MoS removes 12.5% of layers).
    pub fn depth_scale(self) -> f64 {
        match self {
            Variant::Standard | Variant::PrMoe => 1.0,
            Variant::PrMoeMos => 0.875,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_sizes_match_paper() {
        // Paper Table 6 total sizes (billions): 52, 107.7, 349, 1064.9, 2024.
        let want = [52.0, 107.7, 349.0, 1064.9, 2024.0];
        for (m, w) in table6().iter().zip(want) {
            let got = m.params_b();
            let rel = (got - w).abs() / w;
            assert!(rel < 0.01, "{}: got {got:.1}B want {w}B", m.name);
        }
        // The derived formula reproduces the small configs closely (the
        // larger ones use the declared figures; see declared_total_b doc).
        let m0 = &table6()[0];
        let rel = (m0.derived_params_b() - 52.0).abs() / 52.0;
        assert!(rel < 0.05, "derived 1.3B+MoE-128: {:.1}B", m0.derived_params_b());
    }

    #[test]
    fn param_split_sums_to_total() {
        for m in table6() {
            let (e, b) = m.param_split_b();
            assert!((e + b - m.params_b()).abs() < 1e-6, "{}", m.name);
            assert!(e > b, "{}: experts should dominate", m.name);
        }
    }

    #[test]
    fn activated_equals_dense_base() {
        // 1.3B+MoE-128 activates ~1.3B params per token.
        let m = &table6()[0];
        let a = m.activated_params_b();
        assert!((a - 1.3).abs() < 0.3, "activated {a:.2}B");
    }

    #[test]
    fn dense_comparators() {
        let d = dense_models();
        assert!((d[0].params_b() - 6.7).abs() < 1.0);
        assert!((d[1].params_b() - 175.0).abs() < 20.0);
    }

    #[test]
    fn variant_scales_ordered() {
        assert!(Variant::PrMoe.expert_scale() < 1.0);
        assert!(Variant::PrMoeMos.expert_scale() < Variant::PrMoe.expert_scale());
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("1.3B+MoE-128").is_some());
        assert!(by_name("dense-175B").is_some());
        assert!(by_name("nope").is_none());
    }
}
