//! Serving metrics registry: named counters + latency histograms, shared
//! across coordinator threads.  Rendered as a text report (`/metrics`-style)
//! by the server and quoted by the e2e bench.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::stats::{LatencyHistogram, Summary};

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    latencies: BTreeMap<String, LatencyHistogram>,
    /// Exact-percentile summaries over dimensionless values (e.g. the
    /// decode-utilization ratio: busy lanes per decode step).
    values: BTreeMap<String, Summary>,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut m = self.inner.lock().unwrap();
        *m.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn gauge(&self, name: &str, value: f64) {
        let mut m = self.inner.lock().unwrap();
        m.gauges.insert(name.to_string(), value);
    }

    pub fn observe_ns(&self, name: &str, ns: u64) {
        let mut m = self.inner.lock().unwrap();
        m.latencies.entry(name.to_string()).or_default().record(ns);
    }

    pub fn observe(&self, name: &str, d: std::time::Duration) {
        self.observe_ns(name, d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Record `d` into histogram `name` **and** into its per-depth
    /// breakdown `{name}_d{depth}`, so a single report attributes e.g.
    /// `pipeline_bubble` / `attn_overlap` to the pipeline depth that
    /// produced each sample (depth sweeps, `DSMOE_PIPE_DEPTH`).
    pub fn observe_tagged(
        &self,
        name: &str,
        depth: usize,
        d: std::time::Duration,
    ) {
        self.observe(name, d);
        self.observe(&format!("{name}_d{depth}"), d);
    }

    /// Time a closure into histogram `name`.
    pub fn timed<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = std::time::Instant::now();
        let out = f();
        self.observe(name, t.elapsed());
        out
    }

    /// Record a dimensionless sample into value summary `name` (exact
    /// percentiles, unlike the log-bucketed latency histograms).
    pub fn record_value(&self, name: &str, v: f64) {
        let mut m = self.inner.lock().unwrap();
        m.values.entry(name.to_string()).or_default().record(v);
    }

    pub fn value_mean(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .values
            .get(name)
            .map(|s| s.mean())
            .unwrap_or(0.0)
    }

    pub fn value_percentile(&self, name: &str, q: f64) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .values
            .get_mut(name)
            .map(|s| s.percentile(q))
            .unwrap_or(0.0)
    }

    pub fn value_count(&self, name: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .values
            .get(name)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn percentile_ns(&self, name: &str, q: f64) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .latencies
            .get(name)
            .map(|h| h.percentile_ns(q))
            .unwrap_or(0)
    }

    /// Summed time (ns) recorded in latency histogram `name` — total time
    /// spent in that phase across the whole run.
    pub fn sum_ns(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .latencies
            .get(name)
            .map(|h| h.total_ns().min(u64::MAX as u128) as u64)
            .unwrap_or(0)
    }

    /// Number of samples recorded in latency histogram `name`.
    pub fn samples(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .latencies
            .get(name)
            .map(|h| h.count())
            .unwrap_or(0)
    }

    pub fn mean_ns(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .latencies
            .get(name)
            .map(|h| h.mean_ns())
            .unwrap_or(0.0)
    }

    /// Text report, one metric per line.
    pub fn report(&self) -> String {
        let mut m = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &m.counters {
            out.push_str(&format!("counter {k} {v}\n"));
        }
        for (k, v) in &m.gauges {
            out.push_str(&format!("gauge {k} {v:.4}\n"));
        }
        for (k, h) in &m.latencies {
            out.push_str(&format!("latency {k} {}\n", h.summary_string()));
        }
        for (k, s) in m.values.iter_mut() {
            out.push_str(&format!(
                "summary {k} n={} mean={:.4} p50={:.4} max={:.4}\n",
                s.len(),
                s.mean(),
                s.percentile(50.0),
                s.max()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("requests", 1);
        m.inc("requests", 2);
        m.gauge("queue_depth", 5.0);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.counter("missing"), 0);
        let r = m.report();
        assert!(r.contains("counter requests 3"));
        assert!(r.contains("gauge queue_depth 5.0000"));
    }

    #[test]
    fn latency_observation() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.observe_ns("decode", i * 1_000);
        }
        let p50 = m.percentile_ns("decode", 50.0);
        assert!((45_000..60_000).contains(&p50), "p50 {p50}");
        assert!(m.mean_ns("decode") > 0.0);
        assert_eq!(m.samples("decode"), 100);
        assert_eq!(m.samples("missing"), 0);
        assert_eq!(m.sum_ns("decode"), 5_050_000); // exact, not bucketed
        assert_eq!(m.sum_ns("missing"), 0);
    }

    #[test]
    fn value_summaries() {
        let m = Metrics::new();
        for i in 0..8 {
            m.record_value("decode_utilization", i as f64 / 8.0);
        }
        assert_eq!(m.value_count("decode_utilization"), 8);
        assert!((m.value_mean("decode_utilization") - 0.4375).abs() < 1e-9);
        let p50 = m.value_percentile("decode_utilization", 50.0);
        assert!((0.3..=0.6).contains(&p50), "p50 {p50}");
        assert_eq!(m.value_count("missing"), 0);
        assert_eq!(m.value_mean("missing"), 0.0);
        let r = m.report();
        assert!(r.contains("summary decode_utilization n=8"), "{r}");
    }

    #[test]
    fn observe_tagged_records_base_and_depth() {
        let m = Metrics::new();
        let d = std::time::Duration::from_micros(5);
        m.observe_tagged("pipeline_bubble", 3, d);
        m.observe_tagged("pipeline_bubble", 3, d);
        m.observe_tagged("pipeline_bubble", 4, d);
        assert_eq!(m.samples("pipeline_bubble"), 3);
        assert_eq!(m.samples("pipeline_bubble_d3"), 2);
        assert_eq!(m.samples("pipeline_bubble_d4"), 1);
        let r = m.report();
        assert!(r.contains("latency pipeline_bubble_d3"), "{r}");
    }

    #[test]
    fn timed_records() {
        let m = Metrics::new();
        let v = m.timed("op", || {
            std::thread::sleep(std::time::Duration::from_micros(200));
            42
        });
        assert_eq!(v, 42);
        assert!(m.percentile_ns("op", 50.0) >= 100_000);
    }

    #[test]
    fn shared_across_threads() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.inc("n", 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 400);
    }
}
