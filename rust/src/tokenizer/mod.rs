//! Tokenizer over the synthetic vocabulary.
//!
//! The corpus is generated directly in token-id space; to make the serving
//! path exercise a real text boundary (requests arrive as text, responses
//! leave as text) each id is given a deterministic pseudo-word surface form
//! built from syllables.  Encoding is an exact-match lookup with a fallback
//! to `<sep>` for unknown words — mirroring a byte-fallback tokenizer's
//! "never fails to encode" contract at testbed scale.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const N_SPECIALS: usize = 4;

const ONSETS: &[&str] = &["b", "d", "f", "g", "k", "l", "m", "n", "p", "r",
                          "s", "t", "v", "z", "ch", "st"];
const NUCLEI: &[&str] = &["a", "e", "i", "o", "u", "ai", "ou"];
const CODAS: &[&str] = &["", "n", "r", "s", "t", "l", "m"];

/// Bijective id <-> pseudo-word tokenizer.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    words: Vec<String>,
    lookup: HashMap<String, i32>,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Self {
        assert!(vocab_size > N_SPECIALS);
        let mut words = vec![
            "<pad>".to_string(),
            "<bos>".to_string(),
            "<eos>".to_string(),
            "<sep>".to_string(),
        ];
        for id in 0..vocab_size - N_SPECIALS {
            words.push(Self::word_for(id));
        }
        let lookup = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Tokenizer { words, lookup }
    }

    /// Deterministic two-syllable pseudo-word for a content id.
    fn word_for(id: usize) -> String {
        let n1 = ONSETS.len() * NUCLEI.len();
        let syl = |i: usize| {
            format!("{}{}", ONSETS[i % ONSETS.len()],
                    NUCLEI[(i / ONSETS.len()) % NUCLEI.len()])
        };
        if id < n1 * CODAS.len() {
            format!("{}{}", syl(id % n1), CODAS[id / n1])
        } else {
            // Extend with a second syllable for large vocabs.
            let rest = id - n1 * CODAS.len();
            format!("{}{}", syl(rest % n1), Self::word_for(rest / n1))
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.words.len()
    }

    pub fn decode_token(&self, id: i32) -> &str {
        self.words
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<oov>")
    }

    /// Token ids -> space-joined text, dropping specials.
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&id| id >= N_SPECIALS as i32)
            .map(|&id| self.decode_token(id))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Whitespace-split encode; unknown words become `<sep>`.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .map(|w| self.lookup.get(w).copied().unwrap_or(SEP))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_ids() {
        let t = Tokenizer::new(512);
        assert_eq!(t.vocab_size(), 512);
        for id in N_SPECIALS as i32..512 {
            let text = t.decode_token(id).to_string();
            let back = t.encode(&text);
            assert_eq!(back, vec![id], "word {text:?}");
        }
    }

    #[test]
    fn words_are_unique() {
        let t = Tokenizer::new(512);
        let set: std::collections::HashSet<_> = t.words.iter().collect();
        assert_eq!(set.len(), 512);
    }

    #[test]
    fn unknown_maps_to_sep() {
        let t = Tokenizer::new(512);
        assert_eq!(t.encode("xyzzyqqq"), vec![SEP]);
    }

    #[test]
    fn decode_skips_specials() {
        let t = Tokenizer::new(512);
        let text = t.decode(&[BOS, 10, 11, EOS, PAD]);
        assert!(!text.contains('<'));
        assert_eq!(text.split(' ').count(), 2);
    }

    #[test]
    fn sentence_roundtrip() {
        let t = Tokenizer::new(512);
        let ids = vec![7, 42, 100, 300];
        let text = t.decode(&ids);
        assert_eq!(t.encode(&text), ids);
    }
}
