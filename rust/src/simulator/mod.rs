//! A100 cluster performance simulator (paper scale).
//!
//! The paper's evaluation ran on up to 256 A100s; this module carries
//! calibrated device/link models ([`device`]), collective cost models for
//! the §5.3 all-to-all schedules ([`collectives`]), the memory-bandwidth-
//! bound decode latency model ([`inference`]), the memory-fit solver
//! ([`memory`]), the training-throughput model ([`training`]), and the
//! figure-level scenario runners ([`scenarios`]) that regenerate Figures
//! 10–15 and Table 3.  Absolute numbers are modelled; the *shapes* (who
//! wins, by what factor, where scaling stalls) are asserted by unit tests
//! and quoted next to the paper's numbers in EXPERIMENTS.md.

pub mod collectives;
pub mod device;
pub mod inference;
pub mod memory;
pub mod scenarios;
pub mod training;

pub use device::{Cluster, GpuSpec, LinkSpec};
pub use inference::{decode_latency, Breakdown, Layout, Stack};

/// CLI entry: run a named scenario and print its table.
pub fn run_named(name: &str) -> anyhow::Result<()> {
    let t = match name {
        "fig10" => scenarios::fig10(),
        "fig11" => scenarios::fig11(),
        "fig12" => scenarios::fig12(),
        "fig13" => scenarios::fig13(),
        "fig14" => scenarios::fig14(),
        "fig15" => scenarios::fig15(),
        "table3" => scenarios::table3(),
        "calibrated" => scenarios::calibrated(),
        other => anyhow::bail!(
            "unknown scenario {other:?} (fig10..fig15, table3, calibrated)"
        ),
    };
    t.print();
    Ok(())
}
