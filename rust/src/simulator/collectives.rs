//! Analytic cost models for the collectives the serving system issues:
//! all-to-all (three schedules of §5.3), ring all-reduce (tensor-slicing),
//! and all-gather (parallelism-coordinated re-replication).

use crate::config::AllToAllKind;

use super::device::Cluster;

/// All-to-all over `p` ranks exchanging `bytes_per_pair` to each peer.
///
/// * naive: p-1 sequential point-to-point rounds; each round's cost is the
///   slowest involved link (inter-node once the exchange spans nodes).
/// * hierarchical: G intra-node rounds + p/G inter-node rounds with bundled
///   (G-times larger) messages — fewer latency terms, 2x volume (§5.3).
/// * coordinated: the exchange runs only among the p/L ranks that share a
///   tensor-slicing rank, plus an allgather of the result across the L
///   slicing ranks (§5.3, Fig 9).
pub fn alltoall(
    kind: AllToAllKind,
    cluster: &Cluster,
    p: usize,
    bytes_per_pair: f64,
    ts_degree: usize,
    per_hop_overhead: f64,
) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let g = cluster.gpus_per_node.min(p);
    match kind {
        AllToAllKind::Naive => {
            let mut t = 0.0;
            // rounds hit intra-node peers for (g-1) rounds, inter-node after
            for r in 1..p {
                let link = if r < g { cluster.intra } else { cluster.inter };
                t += link.xfer(bytes_per_pair) + per_hop_overhead;
            }
            t
        }
        AllToAllKind::Hierarchical => {
            let n_nodes = p.div_ceil(g);
            // intra-node: g-1 rounds of (bundled toward gateways) messages,
            // each carrying n_nodes * bytes_per_pair.
            let intra = (g - 1) as f64
                * (cluster.intra.xfer(bytes_per_pair * n_nodes as f64)
                   + per_hop_overhead);
            // inter-node: n_nodes-1 rounds of bundled messages carrying
            // g * bytes_per_pair.
            let inter = n_nodes.saturating_sub(1) as f64
                * (cluster.inter.xfer(bytes_per_pair * g as f64)
                   + per_hop_overhead);
            intra + inter
        }
        AllToAllKind::Coordinated => {
            let l = ts_degree.max(1);
            let group = (p / l).max(1);
            // independent naive exchange within each rank group (groups run
            // in parallel), messages L-times larger is NOT needed: data is
            // already replicated, each group moves its own share.
            let mut t = 0.0;
            for r in 1..group {
                let link = if r < g { cluster.intra } else { cluster.inter };
                t += link.xfer(bytes_per_pair) + per_hop_overhead;
            }
            // + allgather across the L slicing ranks (intra-node: slicing
            // is within a node by construction, §5.2).
            t + allgather(cluster, l, bytes_per_pair * group as f64)
        }
    }
}

/// Ring all-reduce of `bytes` across `n` ranks (NCCL ring model:
/// 2(n-1)/n * bytes at ring bandwidth + 2(n-1) latency terms).
pub fn allreduce(cluster: &Cluster, n: usize, bytes: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let spans_nodes = n > cluster.gpus_per_node;
    let link = if spans_nodes { cluster.inter } else { cluster.intra };
    let vol = 2.0 * (n - 1) as f64 / n as f64 * bytes;
    vol / link.bandwidth + 2.0 * (n - 1) as f64 * link.latency
}

/// Ring all-gather of `bytes` per rank across `n` ranks.
pub fn allgather(cluster: &Cluster, n: usize, bytes: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let spans_nodes = n > cluster.gpus_per_node;
    let link = if spans_nodes { cluster.inter } else { cluster.intra };
    (n - 1) as f64 * link.xfer(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cl(p: usize) -> Cluster {
        Cluster::azure_a100(p)
    }

    #[test]
    fn naive_grows_linearly_with_p() {
        let b = 4096.0;
        let t16 = alltoall(AllToAllKind::Naive, &cl(16), 16, b, 1, 0.0);
        let t64 = alltoall(AllToAllKind::Naive, &cl(64), 64, b, 1, 0.0);
        assert!(t64 > 3.0 * t16, "t16 {t16} t64 {t64}");
    }

    #[test]
    fn hierarchical_beats_naive_at_scale_small_messages() {
        let b = 2048.0; // latency-bound regime
        for p in [32, 64, 128, 256] {
            let n = alltoall(AllToAllKind::Naive, &cl(p), p, b, 1, 0.0);
            let h = alltoall(AllToAllKind::Hierarchical, &cl(p), p, b, 1, 0.0);
            assert!(h < n, "p={p}: hier {h} !< naive {n}");
        }
    }

    #[test]
    fn hierarchical_loses_for_huge_messages() {
        // bandwidth-bound: the 2x volume hurts (paper: "better scaling for
        // small batch sizes ... latency-bound").
        let b = 64e6;
        let p = 64;
        let n = alltoall(AllToAllKind::Naive, &cl(p), p, b, 1, 0.0);
        let h = alltoall(AllToAllKind::Hierarchical, &cl(p), p, b, 1, 0.0);
        assert!(h > n * 0.9, "hier should not win big-message: {h} vs {n}");
    }

    #[test]
    fn coordinated_beats_naive_with_slicing() {
        let b = 4096.0;
        let p = 128;
        let n = alltoall(AllToAllKind::Naive, &cl(p), p, b, 1, 0.0);
        let c = alltoall(AllToAllKind::Coordinated, &cl(p), p, b, 8, 0.0);
        assert!(c < n / 3.0, "coord {c} vs naive {n}");
    }

    #[test]
    fn allreduce_model_monotone() {
        let c = cl(8);
        let t2 = allreduce(&c, 2, 1e6);
        let t8 = allreduce(&c, 8, 1e6);
        assert!(t8 > t2);
        assert_eq!(allreduce(&c, 1, 1e6), 0.0);
    }

    #[test]
    fn allgather_zero_for_single() {
        assert_eq!(allgather(&cl(8), 1, 1e6), 0.0);
        assert!(allgather(&cl(8), 8, 1e6) > 0.0);
    }
}
