//! Memory-fit solver: minimum GPUs to serve a model (Fig 12).
//!
//! A deployment fits when every GPU's share of the weights plus activation
//! headroom fits in HBM.  PR-MoE shrinks the expert partition (~0.58x at
//! 1.3B-class ratios) and MoS removes 12.5% of layers, which together halve
//! the minimum GPU count — the paper's "2x fewer resources" (Fig 12).

use crate::config::paper::{PaperModel, Variant};

use super::device::GpuSpec;
use super::inference::BYTES_PER_PARAM;

/// Fraction of HBM usable for weights (the rest: activations, KV cache,
/// workspace, fragmentation).
pub const USABLE_FRACTION: f64 = 0.8;

/// Bytes each GPU must hold for a deployment on `n` GPUs (paper-default
/// layout: EP over experts + expert-slicing beyond, TP for the base).
pub fn bytes_per_gpu(model: &PaperModel, variant: Variant, n: usize) -> f64 {
    let (expert_b, base_b) = model.param_split_b();
    let expert_bytes =
        expert_b * 1e9 * BYTES_PER_PARAM * variant.expert_scale();
    let base_bytes = base_b * 1e9 * BYTES_PER_PARAM * variant.depth_scale();
    let tp = model.mp_degree.min(n).max(1);
    let expert_shard = if model.experts > 0 {
        let ep = model.experts.min(n);
        let slice = (n / model.experts).max(1);
        (ep * slice) as f64
    } else {
        1.0
    };
    base_bytes / tp as f64 + expert_bytes / expert_shard
}

/// Minimum power-of-two GPU count at which the deployment fits.
pub fn min_gpus(model: &PaperModel, variant: Variant, gpu: &GpuSpec) -> usize {
    let budget = gpu.mem_bytes as f64 * USABLE_FRACTION;
    let mut n = 1;
    while n <= 1 << 14 {
        if bytes_per_gpu(model, variant, n) <= budget {
            return n;
        }
        n *= 2;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper;
    use crate::simulator::device::GpuSpec;

    #[test]
    fn variants_need_fewer_or_equal_gpus() {
        let gpu = GpuSpec::a100_40g();
        for m in paper::table6() {
            let std = min_gpus(&m, Variant::Standard, &gpu);
            let pr = min_gpus(&m, Variant::PrMoe, &gpu);
            let mos = min_gpus(&m, Variant::PrMoeMos, &gpu);
            assert!(pr <= std, "{}: pr {pr} > std {std}", m.name);
            assert!(mos <= pr, "{}: mos {mos} > pr {pr}", m.name);
        }
    }

    #[test]
    fn fig12_headline_2x_somewhere() {
        // Paper Fig 12: PR-MoE+MoS serves with 2x fewer GPUs for at least
        // one of the studied sizes.
        let gpu = GpuSpec::a100_40g();
        let any_2x = paper::table6().iter().any(|m| {
            let std = min_gpus(m, Variant::Standard, &gpu);
            let mos = min_gpus(m, Variant::PrMoeMos, &gpu);
            std >= 2 * mos
        });
        assert!(any_2x, "no configuration shows the 2x reduction");
    }

    #[test]
    fn bytes_per_gpu_decreases_with_n() {
        let m = &paper::table6()[2]; // 349B
        let b8 = bytes_per_gpu(m, Variant::Standard, 8);
        let b128 = bytes_per_gpu(m, Variant::Standard, 128);
        assert!(b128 < b8);
    }

    #[test]
    fn dense_min_gpus_driven_by_tp() {
        let gpu = GpuSpec::a100_40g();
        let d = &paper::dense_models()[1]; // 175B
        let n = min_gpus(d, Variant::Standard, &gpu);
        // 350 GB fp16 / 32 GB usable ≈ 11 -> 16 (power of two); tp capped
        // at 16 so it fits exactly there.
        assert_eq!(n, 16);
    }
}
