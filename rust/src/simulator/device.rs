//! Device and interconnect models for the paper's testbed: Azure ND A100
//! instances (A100-40GB, NVLink within a node of 8, HDR InfiniBand across
//! nodes).  All constants carry their sources.

/// One GPU's capabilities.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    /// HBM capacity in bytes.
    pub mem_bytes: u64,
    /// Peak HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Achievable fraction of peak bandwidth for streaming weight reads
    /// under an optimized kernel stack (DeepSpeed kernels hit 0.8–0.9 of
    /// peak on memory-bound transformer inference; see DeepSpeed-inference
    /// paper [51]).
    pub mem_eff: f64,
    /// Dense fp16 peak, FLOP/s (A100 tensor core: 312 TFLOPS).
    pub flops: f64,
    /// Per-kernel launch overhead, seconds (CUDA launch + framework
    /// dispatch; ~5-10us from PyTorch profiling literature).
    pub kernel_overhead: f64,
}

impl GpuSpec {
    /// NVIDIA A100 40GB SXM (Azure ND A100 v4).
    pub fn a100_40g() -> Self {
        GpuSpec {
            mem_bytes: 40 * (1 << 30),
            mem_bw: 1.555e12, // 1555 GB/s
            mem_eff: 0.85,
            flops: 312e12,
            kernel_overhead: 8e-6,
        }
    }
}

/// A point-to-point link.
#[derive(Debug, Clone, Copy)]
pub struct LinkSpec {
    /// One-way latency per message, seconds.
    pub latency: f64,
    /// Bandwidth per direction, bytes/s.
    pub bandwidth: f64,
}

impl LinkSpec {
    /// NVLink 3 (A100): 600 GB/s total bidirectional => ~300 GB/s per
    /// direction; ~3 us software latency (NCCL intra-node small-message).
    pub fn nvlink() -> Self {
        LinkSpec { latency: 3e-6, bandwidth: 300e9 }
    }

    /// HDR InfiniBand on Azure ND A100 v4: 8x200 Gb/s per node = 200 GB/s
    /// aggregate, ~25 GB/s per GPU pair; ~8 us cross-node latency.
    pub fn infiniband() -> Self {
        LinkSpec { latency: 8e-6, bandwidth: 25e9 }
    }

    /// Transfer time for one message.
    pub fn xfer(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.bandwidth
    }
}

/// Cluster shape.
#[derive(Debug, Clone, Copy)]
pub struct Cluster {
    pub n_gpus: usize,
    pub gpus_per_node: usize,
    pub gpu: GpuSpec,
    pub intra: LinkSpec,
    pub inter: LinkSpec,
}

impl Cluster {
    pub fn azure_a100(n_gpus: usize) -> Self {
        Cluster {
            n_gpus,
            gpus_per_node: 8,
            gpu: GpuSpec::a100_40g(),
            intra: LinkSpec::nvlink(),
            inter: LinkSpec::infiniband(),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_gpus.div_ceil(self.gpus_per_node)
    }

    /// Link between two ranks (node-major placement).
    pub fn link(&self, a: usize, b: usize) -> LinkSpec {
        if a / self.gpus_per_node == b / self.gpus_per_node {
            self.intra
        } else {
            self.inter
        }
    }

    /// Time to stream `bytes` of weights from HBM on one GPU.
    pub fn weight_stream(&self, bytes: f64) -> f64 {
        bytes / (self.gpu.mem_bw * self.gpu.mem_eff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_constants_sane() {
        let g = GpuSpec::a100_40g();
        assert_eq!(g.mem_bytes, 42_949_672_960);
        assert!(g.mem_bw > 1e12 && g.mem_bw < 2.1e12);
    }

    #[test]
    fn link_selection() {
        let c = Cluster::azure_a100(16);
        assert_eq!(c.n_nodes(), 2);
        assert!((c.link(0, 7).bandwidth - 300e9).abs() < 1.0);
        assert!((c.link(0, 8).bandwidth - 25e9).abs() < 1.0);
    }

    #[test]
    fn weight_stream_time() {
        let c = Cluster::azure_a100(1);
        // 13.4 GB (6.7B fp16) at ~1.32 TB/s effective -> ~10 ms
        let t = c.weight_stream(13.4e9);
        assert!(t > 0.008 && t < 0.012, "t {t}");
    }

    #[test]
    fn xfer_latency_dominates_small_messages() {
        let ib = LinkSpec::infiniband();
        let small = ib.xfer(1024.0);
        assert!((small - 8e-6) / 8e-6 < 0.01); // latency-bound
        let big = ib.xfer(1e9);
        assert!(big > 0.039); // bandwidth-bound
    }
}
