//! Figure/table scenario runners: each function regenerates one of the
//! paper's evaluation artifacts as a [`Table`] (printed + CSV by benches).

use crate::config::paper::{self, PaperModel, Variant};
use crate::util::table::{f1, f2, ratio, Table};

use super::device::{Cluster, GpuSpec};
use super::inference::{decode_latency, Layout, Stack};
use super::memory;
use super::training;

/// Batch lanes per GPU used across the serving scenarios (latency studies
/// run moderate per-device batches; the shapes are insensitive to the exact
/// value — see fig10 sweep in the bench).
pub const TOKENS_PER_GPU: f64 = 16.0;

fn lat_ms(
    m: &PaperModel,
    v: Variant,
    stack: Stack,
    n: usize,
    layout: Layout,
) -> f64 {
    let cl = Cluster::azure_a100(n);
    decode_latency(m, v, stack, &cl, layout, TOKENS_PER_GPU).total() * 1e3
}

fn thr_per_gpu(lat_ms: f64) -> f64 {
    TOKENS_PER_GPU / (lat_ms / 1e3)
}

/// Fig 10: 52B (1.3B+MoE-128), 8→64 GPUs, DS vs PyTorch.
pub fn fig10() -> Table {
    let m = paper::by_name("1.3B+MoE-128").unwrap();
    let mut t = Table::new(
        "Figure 10 — 52B MoE (1.3B+MoE-128), scaling 8..64 GPUs",
        &["GPUs", "PyTorch ms", "DS ms", "speedup",
          "PyTorch tok/s/GPU", "DS tok/s/GPU"],
    );
    for n in [8, 16, 32, 64] {
        let lay = Layout { n_gpus: n, tp: 1, ep: n, expert_slice: 1 };
        let pt = lat_ms(&m, Variant::Standard, Stack::PyTorch, n, lay);
        let ds = lat_ms(&m, Variant::Standard, Stack::DeepSpeed, n, lay);
        t.row(&[
            n.to_string(),
            f2(pt),
            f2(ds),
            ratio(pt / ds),
            f1(thr_per_gpu(pt)),
            f1(thr_per_gpu(ds)),
        ]);
    }
    t.note("paper: DS scales past 32 GPUs with *increasing* per-GPU \
            throughput (super-linear); PyTorch stalls");
    t
}

/// Fig 11: Table 6 models (107B..2T) on 128/256 GPUs, DS vs PyTorch.
pub fn fig11() -> Table {
    let mut t = Table::new(
        "Figure 11 — 107B..2T MoE models, DS (opt) vs PyTorch (base)",
        &["model", "params", "GPUs", "PyTorch ms", "DS ms", "reduction"],
    );
    for m in paper::table6().iter().skip(1) {
        // 128 GPUs baseline; DS gets 256 for the trillion-scale models
        // (as the paper: "128/256 GPUs ... 256 for the trillion-scale").
        let n_base = 128;
        let n_ds = if m.params_b() > 500.0 { 256 } else { 128 };
        let lay_pt = Layout::paper_default(m, n_base);
        let lay_ds = Layout::paper_default(m, n_ds);
        let pt = lat_ms(m, Variant::Standard, Stack::PyTorch, n_base, lay_pt);
        let ds = lat_ms(m, Variant::Standard, Stack::DeepSpeed, n_ds, lay_ds);
        t.row(&[
            m.name.to_string(),
            format!("{:.0}B", m.params_b()),
            format!("{n_base}/{n_ds}"),
            f2(pt),
            f2(ds),
            ratio(pt / ds),
        ]);
    }
    t.note("paper: up to 7.3x latency reduction; 1T-parameter model \
            under 25 ms");
    t
}

/// Fig 12: minimum GPUs to serve — MoE vs PR-MoE vs PR-MoE+MoS.
pub fn fig12() -> Table {
    let gpu = GpuSpec::a100_40g();
    let mut t = Table::new(
        "Figure 12 — minimum GPUs required for inference",
        &["model", "MoE", "PR-MoE", "PR-MoE+MoS", "reduction"],
    );
    for m in paper::table6() {
        let std = memory::min_gpus(&m, Variant::Standard, &gpu);
        let pr = memory::min_gpus(&m, Variant::PrMoe, &gpu);
        let mos = memory::min_gpus(&m, Variant::PrMoeMos, &gpu);
        t.row(&[
            m.name.to_string(),
            std.to_string(),
            pr.to_string(),
            mos.to_string(),
            ratio(std as f64 / mos as f64),
        ]);
    }
    t.note("paper: PR-MoE+MoS serves with 2x fewer GPUs (e.g. 16 vs 32)");
    t
}

/// Fig 13: latency of MoE / PR-MoE / PR-MoE+MoS across GPU counts.
pub fn fig13() -> Table {
    let mut t = Table::new(
        "Figure 13 — PR-MoE / MoS latency (DeepSpeed)",
        &["model", "GPUs", "MoE ms", "PR-MoE ms", "PR-MoE+MoS ms"],
    );
    for m in [paper::by_name("8B+MoE-128").unwrap(),
              paper::by_name("24B+MoE-128").unwrap()] {
        for n in [16usize, 32, 64, 128] {
            let lay = Layout::paper_default(&m, n);
            if memory::bytes_per_gpu(&m, Variant::Standard, n)
                > GpuSpec::a100_40g().mem_bytes as f64 * memory::USABLE_FRACTION
            {
                continue; // standard variant does not fit this few GPUs
            }
            t.row(&[
                m.name.to_string(),
                n.to_string(),
                f2(lat_ms(&m, Variant::Standard, Stack::DeepSpeed, n, lay)),
                f2(lat_ms(&m, Variant::PrMoe, Stack::DeepSpeed, n, lay)),
                f2(lat_ms(&m, Variant::PrMoeMos, Stack::DeepSpeed, n, lay)),
            ]);
        }
    }
    t.note("paper: PR-MoE+MoS is lowest-latency at every point");
    t
}

/// Fig 14: 52B MoE vs quality-equivalent 6.7B dense.
pub fn fig14() -> Table {
    let moe = paper::by_name("1.3B+MoE-128").unwrap();
    let dense = paper::by_name("dense-6.7B").unwrap();
    let mut t = Table::new(
        "Figure 14 — 52B MoE vs quality-equivalent 6.7B dense",
        &["config", "GPUs", "latency ms", "tok/s/GPU (cost proxy)"],
    );
    // dense on 1 GPU (the paper: "1 GPU ... offers the lowest latency").
    let d_lay = Layout { n_gpus: 1, tp: 1, ep: 1, expert_slice: 1 };
    let d_pt = lat_ms(&dense, Variant::Standard, Stack::PyTorch, 1, d_lay);
    let d_ds = lat_ms(&dense, Variant::Standard, Stack::DeepSpeed, 1, d_lay);
    let n = 128;
    let m_lay = Layout { n_gpus: n, tp: 1, ep: 128, expert_slice: 1 };
    let m_pt = lat_ms(&moe, Variant::Standard, Stack::PyTorch, n, m_lay);
    let m_ds = lat_ms(&moe, Variant::Standard, Stack::DeepSpeed, n, m_lay);
    let m_mos = lat_ms(&moe, Variant::PrMoeMos, Stack::DeepSpeed, n, m_lay);
    for (name, gpus, ms) in [
        ("6.7B dense (PyTorch)", 1, d_pt),
        ("6.7B dense (DeepSpeed)", 1, d_ds),
        ("52B MoE (PyTorch)", n, m_pt),
        ("52B MoE (DeepSpeed)", n, m_ds),
        ("PR-MoE+MoS (DeepSpeed)", n, m_mos),
    ] {
        t.row(&[
            name.to_string(),
            gpus.to_string(),
            f2(ms),
            f1(thr_per_gpu(ms)),
        ]);
    }
    t.note(&format!(
        "paper: PR-MoE+MoS 2.4x faster than dense-on-PyTorch; here {}",
        ratio(d_pt / m_mos)
    ));
    t
}

/// Fig 15: trillion-scale MoE vs quality-equivalent 175B dense.
pub fn fig15() -> Table {
    let moe = paper::by_name("24B+MoE-128").unwrap(); // ~1.06T params
    let dense = paper::by_name("dense-175B").unwrap();
    let mut t = Table::new(
        "Figure 15 — ~1T MoE vs quality-equivalent 175B dense",
        &["config", "GPUs", "tp", "latency ms", "tok/s/GPU (cost proxy)"],
    );
    // dense-175B: 16-way tensor slicing (paper), PyTorch vs DS.
    let d_lay = Layout { n_gpus: 16, tp: 16, ep: 1, expert_slice: 1 };
    let d_pt = lat_ms(&dense, Variant::Standard, Stack::PyTorch, 16, d_lay);
    let d_ds = lat_ms(&dense, Variant::Standard, Stack::DeepSpeed, 16, d_lay);
    // MoE: 256 GPUs, tp=8 (half the dense degree, §5.5.4), EP 128, slice 2.
    let n = 256;
    let m_lay = Layout { n_gpus: n, tp: 8, ep: 128, expert_slice: 2 };
    let m_pt = lat_ms(&moe, Variant::Standard, Stack::PyTorch, n, m_lay);
    let m_ds = lat_ms(&moe, Variant::Standard, Stack::DeepSpeed, n, m_lay);
    let m_mos = lat_ms(&moe, Variant::PrMoeMos, Stack::DeepSpeed, n, m_lay);
    for (name, gpus, tp, ms) in [
        ("175B dense (PyTorch)", 16, 16, d_pt),
        ("175B dense (DeepSpeed)", 16, 16, d_ds),
        ("1T MoE (PyTorch)", n, 8, m_pt),
        ("1T MoE (DeepSpeed)", n, 8, m_ds),
        ("1T PR-MoE+MoS (DeepSpeed)", n, 8, m_mos),
    ] {
        t.row(&[
            name.to_string(),
            gpus.to_string(),
            tp.to_string(),
            f2(ms),
            f1(thr_per_gpu(ms)),
        ]);
    }
    t.note(&format!(
        "paper: 4.5x faster / 9x cheaper vs dense-PyTorch; here {} faster, \
         {} cheaper",
        ratio(d_pt / m_mos),
        ratio(thr_per_gpu(m_mos) / thr_per_gpu(d_pt))
    ));
    t
}

/// Table 3: training throughput, 6.7B dense vs 1.3B+MoE-128.
pub fn table3() -> Table {
    let cl = Cluster::azure_a100(128);
    let dense = PaperModel {
        name: "6.7B dense",
        n_layers: 32,
        hidden: 4096,
        n_heads: 32,
        experts: 0,
        mp_degree: 8,
        ep_degree: 1,
        declared_total_b: 6.7,
    };
    let moe = paper::by_name("1.3B+MoE-128").unwrap();
    let d = training::samples_per_sec(&dense, &cl);
    let m = training::samples_per_sec(&moe, &cl);
    let mut t = Table::new(
        "Table 3 — training throughput on 128 A100s",
        &["model", "samples/s (paper)", "samples/s (model)", "gain"],
    );
    t.row(&["6.7B dense".into(), "70".into(), f1(d), ratio(1.0)]);
    t.row(&["1.3B+MoE-128".into(), "372".into(), f1(m), ratio(m / d)]);
    t.note("paper: 5x throughput gain / cost reduction");
    t
}

/// Measured serving calibration loaded from `BENCH_e2e.json`'s
/// `slo_serving` section (written by `benches/e2e_serving.rs`): the
/// per-output-token decode latency this machine actually measured,
/// used to re-anchor the simulator's absolute latency scale so the
/// fleet-size extrapolations start from a measurement instead of the
/// built-in device constants.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Measured per-output-token decode latency, seconds.
    pub tpot_s: f64,
    /// Which bench row supplied it (mode + tier), for the table note.
    pub source: String,
}

impl Calibration {
    /// Read the bench JSON at `path`.  `None` — the graceful fallback to
    /// the built-in device model — when the file, the `slo_serving`
    /// section, or a nonzero TPOT sample is absent.
    pub fn load(path: &str) -> Option<Calibration> {
        let text = std::fs::read_to_string(path).ok()?;
        let j = crate::util::json::Json::parse(&text).ok()?;
        Self::from_json(&j)
    }

    /// Pick the calibration point out of a parsed `BENCH_e2e.json`:
    /// prefer the SLO-mode interactive tier's TPOT p50 (the
    /// latency-critical number), then any nonzero tier of any mode.
    pub fn from_json(j: &crate::util::json::Json) -> Option<Calibration> {
        let rows = j.get("slo_serving")?.as_arr()?;
        let mut best: Option<(u32, f64, String)> = None;
        for row in rows {
            let mode = row.get("mode").and_then(|m| m.as_str())?;
            for tier in row.get("tiers")?.as_arr()? {
                let t = tier.get("tier").and_then(|t| t.as_usize())?;
                let ns = tier.get("tpot_p50_ns").and_then(|n| n.as_f64())?;
                if ns <= 0.0 {
                    continue;
                }
                let pref = match (mode, t) {
                    ("slo", 1) => 0,
                    ("slo", _) => 1,
                    (_, 1) => 2,
                    _ => 3,
                };
                let better = match &best {
                    Some((p, _, _)) => pref < *p,
                    None => true,
                };
                if better {
                    best = Some((
                        pref,
                        ns * 1e-9,
                        format!("{mode} mode, tier {t}, TPOT p50"),
                    ));
                }
            }
        }
        best.map(|(_, tpot_s, source)| Calibration { tpot_s, source })
    }
}

/// Calibrated serving extrapolation: the fig10 scaling sweep with its
/// absolute per-token latency re-anchored to this machine's measured
/// TPOT ([`Calibration::load`]).  The device model supplies the scaling
/// *shape* (who stalls, who scales); the measurement supplies the
/// absolute scale.  Without a bench file the table degrades to the
/// uncalibrated model with a note saying so.
pub fn calibrated() -> Table {
    calibrated_from(Calibration::load("BENCH_e2e.json"))
}

pub fn calibrated_from(cal: Option<Calibration>) -> Table {
    let m = paper::by_name("1.3B+MoE-128").unwrap();
    let mut t = Table::new(
        "Calibrated extrapolation — 52B MoE decode, 8..64 GPUs",
        &["GPUs", "modeled ms", "calibrated ms", "tok/s/GPU"],
    );
    // Anchor point: the model's smallest DeepSpeed configuration vs the
    // measured per-output-token latency.
    let anchor_lay = Layout { n_gpus: 8, tp: 1, ep: 8, expert_slice: 1 };
    let anchor_ms =
        lat_ms(&m, Variant::Standard, Stack::DeepSpeed, 8, anchor_lay);
    let scale = cal
        .as_ref()
        .map(|c| c.tpot_s * 1e3 / anchor_ms)
        .filter(|s| s.is_finite() && *s > 0.0);
    for n in [8, 16, 32, 64] {
        let lay = Layout { n_gpus: n, tp: 1, ep: n, expert_slice: 1 };
        let ds = lat_ms(&m, Variant::Standard, Stack::DeepSpeed, n, lay);
        let cal_ms = scale.map_or(ds, |s| ds * s);
        t.row(&[
            n.to_string(),
            f2(ds),
            f2(cal_ms),
            f1(thr_per_gpu(cal_ms)),
        ]);
    }
    match &cal {
        Some(c) => t.note(&format!(
            "anchored to measured TPOT {:.3} ms ({}) from BENCH_e2e.json's \
             slo_serving section; model shape x measured scale",
            c.tpot_s * 1e3,
            c.source,
        )),
        None => t.note(
            "no usable BENCH_e2e.json slo_serving section — uncalibrated \
             built-in device model (run the e2e bench to calibrate)",
        ),
    };
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_scenarios_render() {
        for t in [fig10(), fig11(), fig12(), fig13(), fig14(), fig15(),
                  table3()] {
            assert!(!t.rows.is_empty(), "{} empty", t.title);
            let s = t.render();
            assert!(s.contains("=="));
        }
    }

    #[test]
    fn calibration_prefers_slo_interactive_tier() {
        let j = crate::util::json::Json::parse(
            r#"{"slo_serving": [
                 {"mode": "fifo", "tiers": [
                   {"tier": 0, "tpot_p50_ns": 4000000},
                   {"tier": 1, "tpot_p50_ns": 3000000}]},
                 {"mode": "slo", "tiers": [
                   {"tier": 0, "tpot_p50_ns": 2500000},
                   {"tier": 1, "tpot_p50_ns": 2000000}]}]}"#,
        )
        .unwrap();
        let c = Calibration::from_json(&j).unwrap();
        assert!((c.tpot_s - 2e-3).abs() < 1e-12, "tpot {}", c.tpot_s);
        assert!(c.source.contains("slo mode, tier 1"), "{}", c.source);
    }

    #[test]
    fn calibration_falls_back_across_modes_and_skips_zero() {
        // The slo rows report zero TPOT (e.g. single-token responses):
        // fall back to the fifo interactive tier rather than a zero scale.
        let j = crate::util::json::Json::parse(
            r#"{"slo_serving": [
                 {"mode": "slo", "tiers": [
                   {"tier": 1, "tpot_p50_ns": 0}]},
                 {"mode": "fifo", "tiers": [
                   {"tier": 1, "tpot_p50_ns": 5000000}]}]}"#,
        )
        .unwrap();
        let c = Calibration::from_json(&j).unwrap();
        assert!((c.tpot_s - 5e-3).abs() < 1e-12);
        // Absent section / empty file: graceful None.
        let empty = crate::util::json::Json::parse("{}").unwrap();
        assert!(Calibration::from_json(&empty).is_none());
    }

    #[test]
    fn calibrated_renders_with_and_without_measurement() {
        let plain = calibrated_from(None);
        assert_eq!(plain.rows.len(), 4);
        assert!(plain.render().contains("uncalibrated"));
        // With a measurement the calibrated column is anchored: the 8-GPU
        // row's calibrated latency equals the measured TPOT.
        let cal = Calibration {
            tpot_s: 2e-3,
            source: "slo mode, tier 1, TPOT p50".into(),
        };
        let t = calibrated_from(Some(cal));
        let ms: f64 = t.rows[0][2].parse().unwrap();
        assert!((ms - 2.0).abs() < 0.05, "anchor row {ms} ms");
    }

    #[test]
    fn fig11_headline_ratios() {
        let t = fig11();
        // at least one configuration shows >= 4x latency reduction
        let best: f64 = t
            .rows
            .iter()
            .map(|r| r[5].trim_end_matches('x').parse::<f64>().unwrap())
            .fold(0.0, f64::max);
        assert!(best >= 4.0, "best DS/PyTorch reduction {best}x");
    }

    #[test]
    fn fig14_moe_beats_dense_on_ds() {
        let t = fig14();
        let ms = |i: usize| t.rows[i][2].parse::<f64>().unwrap();
        // MoE on DeepSpeed faster than dense on PyTorch
        assert!(ms(3) < ms(0), "52B-on-DS {} vs dense-on-PT {}", ms(3), ms(0));
        // ...and PR-MoE+MoS fastest of all MoE rows
        assert!(ms(4) < ms(3));
    }

    #[test]
    fn fig15_cost_and_speed_gains() {
        let t = fig15();
        let ms = |i: usize| t.rows[i][3].parse::<f64>().unwrap();
        let cost = |i: usize| t.rows[i][4].parse::<f64>().unwrap();
        let speedup = ms(0) / ms(4);
        let cheaper = cost(4) / cost(0);
        assert!(speedup > 2.0, "speedup {speedup:.1} (paper 4.5x)");
        assert!(cheaper > 3.0, "cost gain {cheaper:.1} (paper 9x)");
    }
}
