//! Decode-latency model for paper-scale serving (Figs 10–15).
//!
//! MoE inference at small-to-moderate batch is **memory-bandwidth bound**
//! (§5: "the inference latency of an MoE model depends primarily on the
//! time it takes to load the model parameters from main memory").  One
//! decode step for a batch costs:
//!
//! * streaming the non-expert weights each GPU owns (sliced by
//!   tensor-parallel degree),
//! * streaming the expert weights each GPU actually touches — with the
//!   paper's token grouping this is `min(experts_per_gpu, tokens_per_gpu)`
//!   experts (§5.5.1's data-locality effect: more GPUs => fewer experts per
//!   GPU => fewer bytes => *super-linear* per-GPU throughput),
//! * the MoE all-to-all (twice per MoE layer) under the configured schedule,
//! * tensor-slicing all-reduces (twice per layer when tp > 1),
//! * per-kernel launch overheads — where the PyTorch baseline pays the
//!   sparse-einsum formulation's op count and DS-MoE pays the fused count
//!   (§5.4's ~6x MoE-kernel reduction).

use crate::config::paper::{PaperModel, Variant};
use crate::config::AllToAllKind;

use super::collectives;
use super::device::Cluster;

/// Software stack being modelled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stack {
    /// Distributed PyTorch baseline: naive all-to-all, sparse-einsum MoE
    /// kernels, unfused transformer ops.
    PyTorch,
    /// DeepSpeed-MoE: hierarchical / parallelism-coordinated all-to-all,
    /// fused gating + data-layout kernels, fused transformer kernels.
    DeepSpeed,
}

impl Stack {
    /// Achievable HBM-bandwidth fraction for weight streaming.
    fn mem_eff(self) -> f64 {
        match self {
            // Unfused fp16 inference typically realizes ~50-60% of peak.
            Stack::PyTorch => 0.55,
            Stack::DeepSpeed => 0.85,
        }
    }

    /// Kernel launches per dense transformer layer.
    fn ops_per_layer(self) -> f64 {
        match self {
            Stack::PyTorch => 25.0,
            Stack::DeepSpeed => 6.0, // fused QKV/attn/FFN kernels [51]
        }
    }

    /// Extra kernel launches on an MoE layer (gating + dispatch).  §5.4:
    /// "numerous operations ... extremely slow due to many kernel call
    /// invocations" vs a single fused kernel.
    fn moe_ops(self) -> f64 {
        match self {
            Stack::PyTorch => 30.0,
            Stack::DeepSpeed => 4.0,
        }
    }

    /// Host-side software overhead per point-to-point operation.  The
    /// paper observes "major overhead" using NCCL via torch.distributed at
    /// scale (§5.3) and replaces it with a custom SCCL-based interface;
    /// ~20us/op for the 2021 torch dispatch stack vs ~2us for the custom
    /// path is consistent with their reported gap.
    fn p2p_overhead(self) -> f64 {
        match self {
            Stack::PyTorch => 20e-6,
            Stack::DeepSpeed => 2e-6,
        }
    }

    fn alltoall_kind(self, tp: usize) -> AllToAllKind {
        match self {
            Stack::PyTorch => AllToAllKind::Naive,
            Stack::DeepSpeed => {
                if tp > 1 {
                    AllToAllKind::Coordinated
                } else {
                    AllToAllKind::Hierarchical
                }
            }
        }
    }
}

/// Parallel layout for a serving deployment.
#[derive(Debug, Clone, Copy)]
pub struct Layout {
    pub n_gpus: usize,
    /// Tensor-slicing degree for non-expert parameters.
    pub tp: usize,
    /// Expert-parallel degree (experts sharded across this many GPUs).
    pub ep: usize,
    /// Expert-slicing degree (tensor-slicing *within* an expert, §5.2) —
    /// used when GPUs outnumber experts.
    pub expert_slice: usize,
}

impl Layout {
    /// The paper's default layout for a model on `n` GPUs: EP up to the
    /// expert count, expert-slicing beyond, TP as configured for the model.
    pub fn paper_default(model: &PaperModel, n: usize) -> Layout {
        let tp = model.mp_degree.min(n);
        let ep = model.experts.max(1).min(n);
        let expert_slice = if model.experts > 0 && n > model.experts {
            (n / model.experts).max(1)
        } else {
            1
        };
        Layout { n_gpus: n, tp, ep, expert_slice }
    }
}

/// One decode step's latency breakdown (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub base_stream: f64,
    pub expert_stream: f64,
    pub compute: f64,
    pub alltoall: f64,
    pub allreduce: f64,
    pub kernel_overhead: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.base_stream
            + self.expert_stream
            + self.compute
            + self.alltoall
            + self.allreduce
            + self.kernel_overhead
    }
}

pub const BYTES_PER_PARAM: f64 = 2.0; // fp16 serving

/// Per-decode-step latency for `model` under `variant` scaling, `stack`,
/// `layout`, with `tokens_per_gpu` batch lanes per device.
pub fn decode_latency(
    model: &PaperModel,
    variant: Variant,
    stack: Stack,
    cluster: &Cluster,
    layout: Layout,
    tokens_per_gpu: f64,
) -> Breakdown {
    let (expert_b, base_b) = model.param_split_b();
    let expert_bytes = expert_b * 1e9 * BYTES_PER_PARAM * variant.expert_scale();
    let base_bytes = base_b * 1e9 * BYTES_PER_PARAM * variant.depth_scale();
    let n_layers = model.n_layers as f64 * variant.depth_scale();
    let n_moe_layers = model.n_moe_layers() as f64 * variant.depth_scale();
    let h = model.hidden as f64;
    let eff_bw = cluster.gpu.mem_bw * stack.mem_eff();

    // --- weight streaming -------------------------------------------------
    let base_per_gpu = base_bytes / layout.tp as f64;
    let base_stream = base_per_gpu / eff_bw;

    let expert_stream = if model.experts > 0 {
        let shard = layout.ep as f64 * layout.expert_slice as f64;
        let experts_per_gpu = model.experts as f64 / layout.ep as f64;
        // Token grouping bounds the distinct experts a GPU touches by its
        // local token count (per MoE layer).
        let activated = experts_per_gpu.min(tokens_per_gpu.max(1.0));
        let frac = activated / experts_per_gpu;
        (expert_bytes / shard * frac) / eff_bw
    } else {
        0.0
    };

    // --- compute (usually sub-dominant at decode) -------------------------
    // Per-GPU FLOPs: the base slice this GPU owns plus the experts it
    // actually runs (both already sharded by tp / expert-slicing).
    let expert_active_per_gpu = if model.experts > 0 {
        let experts_per_gpu = model.experts as f64 / layout.ep as f64;
        let activated = experts_per_gpu.min(tokens_per_gpu.max(1.0));
        expert_bytes / BYTES_PER_PARAM / model.experts as f64 * activated
            / layout.expert_slice as f64
    } else {
        0.0
    };
    let active_params = base_bytes / BYTES_PER_PARAM / layout.tp as f64
        + expert_active_per_gpu;
    let flops = 2.0 * active_params * tokens_per_gpu;
    let compute = flops / (cluster.gpu.flops * 0.5);

    // --- communication ----------------------------------------------------
    let kind = stack.alltoall_kind(layout.tp);
    let a2a_ranks = layout.ep;
    // Each rank scatters its local tokens across all ranks: per-pair payload.
    let bytes_per_pair =
        (tokens_per_gpu / a2a_ranks as f64).max(1.0) * h * BYTES_PER_PARAM;
    let one_a2a = collectives::alltoall(
        kind, cluster, a2a_ranks, bytes_per_pair, layout.tp,
        stack.p2p_overhead(),
    );
    let alltoall = 2.0 * n_moe_layers * one_a2a;

    let allreduce = if layout.tp > 1 {
        let msg = tokens_per_gpu * h * BYTES_PER_PARAM;
        2.0 * n_layers * collectives::allreduce(cluster, layout.tp, msg)
    } else {
        0.0
    };

    // --- kernel overheads ---------------------------------------------------
    let kernel_overhead = cluster.gpu.kernel_overhead
        * (n_layers * stack.ops_per_layer() + n_moe_layers * stack.moe_ops());

    Breakdown {
        base_stream,
        expert_stream,
        compute,
        alltoall,
        allreduce,
        kernel_overhead,
    }
}

/// Aggregate throughput in tokens/s (all GPUs) and per GPU.
pub fn throughput(
    latency_s: f64,
    tokens_per_gpu: f64,
    n_gpus: usize,
) -> (f64, f64) {
    let per_gpu = tokens_per_gpu / latency_s;
    (per_gpu * n_gpus as f64, per_gpu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper;

    fn m52() -> PaperModel {
        paper::table6().into_iter().next().unwrap() // 1.3B+MoE-128
    }

    #[test]
    fn deepspeed_latency_decreases_with_gpus() {
        let m = m52();
        let mut prev = f64::INFINITY;
        for n in [8, 16, 32, 64] {
            let cl = Cluster::azure_a100(n);
            let lay = Layout { n_gpus: n, tp: 1, ep: n, expert_slice: 1 };
            let t = decode_latency(&m, Variant::Standard, Stack::DeepSpeed,
                                   &cl, lay, 16.0)
                .total();
            assert!(t < prev, "latency should fall: {t} at {n}");
            prev = t;
        }
    }

    #[test]
    fn deepspeed_per_gpu_throughput_superlinear() {
        // Fig 10's headline: per-GPU throughput *increases* with GPU count.
        let m = m52();
        let tp8 = {
            let cl = Cluster::azure_a100(8);
            let lay = Layout { n_gpus: 8, tp: 1, ep: 8, expert_slice: 1 };
            let t = decode_latency(&m, Variant::Standard, Stack::DeepSpeed,
                                   &cl, lay, 16.0).total();
            16.0 / t
        };
        let tp64 = {
            let cl = Cluster::azure_a100(64);
            let lay = Layout { n_gpus: 64, tp: 1, ep: 64, expert_slice: 1 };
            let t = decode_latency(&m, Variant::Standard, Stack::DeepSpeed,
                                   &cl, lay, 16.0).total();
            16.0 / t
        };
        assert!(tp64 > tp8, "per-gpu throughput {tp8} -> {tp64}");
    }

    #[test]
    fn pytorch_stops_scaling() {
        // Fig 10: the baseline's naive all-to-all erases scaling gains.
        let m = m52();
        let lat = |n: usize| {
            let cl = Cluster::azure_a100(n);
            let lay = Layout { n_gpus: n, tp: 1, ep: n, expert_slice: 1 };
            decode_latency(&m, Variant::Standard, Stack::PyTorch, &cl, lay,
                           16.0)
                .total()
        };
        // flat or worsening from 32 to 64 while DS keeps improving
        assert!(lat(64) > lat(32) * 0.9, "pytorch should stall");
    }

    #[test]
    fn deepspeed_beats_pytorch_everywhere() {
        let m = m52();
        for n in [8, 16, 32, 64] {
            let cl = Cluster::azure_a100(n);
            let lay = Layout { n_gpus: n, tp: 1, ep: n, expert_slice: 1 };
            let ds = decode_latency(&m, Variant::Standard, Stack::DeepSpeed,
                                    &cl, lay, 16.0).total();
            let pt = decode_latency(&m, Variant::Standard, Stack::PyTorch,
                                    &cl, lay, 16.0).total();
            assert!(pt > ds, "n={n}: pt {pt} ds {ds}");
        }
    }

    #[test]
    fn variants_strictly_faster() {
        let m = m52();
        let cl = Cluster::azure_a100(32);
        let lay = Layout { n_gpus: 32, tp: 1, ep: 32, expert_slice: 1 };
        let t = |v: Variant| {
            decode_latency(&m, v, Stack::DeepSpeed, &cl, lay, 16.0).total()
        };
        assert!(t(Variant::PrMoe) < t(Variant::Standard));
        assert!(t(Variant::PrMoeMos) < t(Variant::PrMoe));
    }

    #[test]
    fn trillion_scale_under_25ms() {
        // Fig 11: "a staggering trillion parameter MoE model can be
        // inferenced under 25ms" on 256 GPUs.
        let m = paper::table6()[3].clone(); // 24B+MoE-128, 1.06T params
        let cl = Cluster::azure_a100(256);
        let lay = Layout::paper_default(&m, 256);
        let t = decode_latency(&m, Variant::Standard, Stack::DeepSpeed, &cl,
                               lay, 16.0)
            .total();
        assert!(t < 0.025, "trillion-param latency {t}");
    }
}
