//! Training-throughput model (Table 3).
//!
//! Training is compute-bound: `6 * activated_params * tokens` FLOPs per
//! sample (fwd + bwd).  The paper's Table 3 measures 70 samples/s for the
//! 6.7B dense model and 372 samples/s for 1.3B+MoE-128 on 128 A100s — both
//! correspond to ~15% MFU on the 2021 stack, with MoE paying a small
//! all-to-all tax and dense paying a tensor-parallel tax, which is exactly
//! how we model them.

use crate::config::paper::PaperModel;

use super::collectives;
use super::device::Cluster;

/// Model FLOP utilization achieved by the DeepSpeed training stack on this
/// generation of hardware (calibrated to Table 3; see module docs).
pub const TRAIN_MFU: f64 = 0.155;

/// Sequence length used in the paper's training runs (Table 1: 2K).
pub const SEQ_LEN: f64 = 2048.0;

/// Samples/second for a training run on `cluster`.
pub fn samples_per_sec(model: &PaperModel, cluster: &Cluster) -> f64 {
    let active = model.activated_params_b() * 1e9;
    let flops_per_sample = 6.0 * active * SEQ_LEN;
    let raw = cluster.n_gpus as f64 * cluster.gpu.flops * TRAIN_MFU
        / flops_per_sample;

    // Parallelism taxes.
    let tp_tax = match model.mp_degree {
        0 | 1 => 1.0,
        // tensor-slicing all-reduces overlap imperfectly; Megatron-LM
        // reports ~75-85% scaling efficiency at tp=8.
        d => 1.0 - 0.03 * (d as f64).log2(),
    };
    let moe_tax = if model.experts > 0 {
        // two all-to-alls per MoE layer per microbatch fwd+bwd (4 total);
        // estimate as a throughput factor from the collective model.
        let ep = model.ep_degree.min(cluster.n_gpus);
        let bytes_per_pair =
            SEQ_LEN / ep as f64 * model.hidden as f64 * 2.0;
        let a2a = collectives::alltoall(
            crate::config::AllToAllKind::Hierarchical,
            cluster,
            ep,
            bytes_per_pair,
            1,
            2e-6,
        );
        let comm = 4.0 * model.n_moe_layers() as f64 * a2a;
        let compute =
            flops_per_sample / (cluster.gpu.flops * TRAIN_MFU);
        compute / (compute + comm)
    } else {
        1.0
    };
    raw * tp_tax * moe_tax
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper;

    #[test]
    fn table3_dense_6_7b() {
        // Paper: 70 samples/s on 128 A100s.
        let m = paper::PaperModel {
            name: "dense-6.7B-train",
            n_layers: 32,
            hidden: 4096,
            n_heads: 32,
            experts: 0,
            mp_degree: 8, // Table 1: model-parallel degree 8 for 6.7B
            ep_degree: 1,
            declared_total_b: 6.7,
        };
        let cl = Cluster::azure_a100(128);
        let got = samples_per_sec(&m, &cl);
        let rel = (got - 70.0).abs() / 70.0;
        assert!(rel < 0.30, "6.7B dense: {got:.0} vs paper 70");
    }

    #[test]
    fn table3_moe_ratio_about_5x() {
        // Paper: 372 vs 70 => 5.3x.
        let dense = paper::PaperModel {
            name: "d",
            n_layers: 32,
            hidden: 4096,
            n_heads: 32,
            experts: 0,
            mp_degree: 8,
            ep_degree: 1,
            declared_total_b: 6.7,
        };
        let moe = paper::PaperModel {
            name: "m",
            n_layers: 24,
            hidden: 2048,
            n_heads: 16,
            experts: 128,
            mp_degree: 1,
            ep_degree: 128,
            declared_total_b: 52.0,
        };
        let cl = Cluster::azure_a100(128);
        let ratio = samples_per_sec(&moe, &cl) / samples_per_sec(&dense, &cl);
        assert!(
            (3.5..7.0).contains(&ratio),
            "MoE/dense throughput ratio {ratio:.1} (paper: 5.3x)"
        );
    }
}
