//! ds-moe CLI — leader entrypoint.
//!
//! Subcommands:
//!   serve     — run the serving engine on a model and a synthetic workload
//!   ep-serve  — expert-parallel serving across fabric workers
//!   train     — train a variant on the synthetic corpus
//!   distill   — staged-KD Mixture-of-Students training
//!   eval      — zero-shot cloze evaluation of a checkpoint
//!   simulate  — paper-scale cluster simulations (Figs 10–15, Table 3)
//!   info      — dump manifest / model inventory

use anyhow::{Context, Result};

use ds_moe::config::{AllToAllKind, ServingConfig, ShedPolicy};
use ds_moe::coordinator::Response;
use ds_moe::data::{Corpus, CorpusConfig, EvalSuite};
use ds_moe::fabric::TransportKind;
use ds_moe::runtime::{Dtype, Manifest};
use ds_moe::server::{
    tpot_percentile, ttft_percentile, Engine, EpEngine, Scheduler,
};
use ds_moe::simulator;
use ds_moe::training::{Distiller, KdMode, LrSchedule, Trainer};
use ds_moe::util::args::Args;
use ds_moe::util::stats::fmt_ns;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args.remove(0);
    let rest = Args::parse(args);
    let r = match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "ep-serve" => cmd_ep_serve(rest),
        "train" => cmd_train(rest),
        "distill" => cmd_distill(rest),
        "eval" => cmd_eval(rest),
        "simulate" => cmd_simulate(rest),
        "info" => cmd_info(rest),
        "--help" | "help" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "ds-moe — DeepSpeed-MoE reproduction\n\
         usage: ds-moe <serve|ep-serve|train|distill|eval|simulate|info> \
         [--help] [options]\n\
         run a subcommand with --help for its options"
    );
}

fn manifest(args: &mut Args) -> Result<Manifest> {
    let root = args.get("artifacts", "artifacts",
                        "artifact directory (make artifacts)");
    Manifest::load(root)
}

fn corpus(args: &mut Args) -> Corpus {
    let seed = args.get_usize("corpus-seed", 20220717, "corpus seed");
    Corpus::generate(CorpusConfig { seed: seed as u64, ..Default::default() })
}

fn cmd_serve(mut args: Args) -> Result<()> {
    let m = manifest(&mut args)?;
    let model = args.get("model", "moe-s-8", "model variant to serve");
    let n_requests =
        args.get_usize("requests", 16, "synthetic requests to serve");
    let max_new = args.get_usize("max-new", 12, "tokens to generate");
    let prompt_len = args.get_usize("prompt-len", 8, "prompt length");
    let serving = ServingConfig {
        model: model.clone(),
        max_new_tokens: max_new,
        ..Default::default()
    };
    if args.has("help") {
        eprint!("{}", args.usage("ds-moe serve"));
        return Ok(());
    }
    let mut engine =
        Scheduler::new(Engine::new(&m, serving.clone())?, serving);
    let corpus = corpus(&mut args);
    println!(
        "serving {model} ({} params)",
        engine.model.model_config().num_params
    );
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        engine.submit(corpus.prompt(i, prompt_len), Some(max_new))?;
    }
    let responses = engine.run_until_idle()?;
    let wall = t0.elapsed();
    let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
    println!(
        "{} responses, {toks} tokens in {wall:?} ({:.1} tok/s)",
        responses.len(),
        toks as f64 / wall.as_secs_f64()
    );
    let tok = ds_moe::tokenizer::Tokenizer::new(
        engine.model.model_config().vocab_size,
    );
    for r in responses.iter().take(3) {
        println!("  #{}: {}", r.id, tok.decode(&r.tokens));
    }
    println!("--- metrics ---\n{}", engine.metrics.report());
    Ok(())
}

fn cmd_ep_serve(mut args: Args) -> Result<()> {
    let m = manifest(&mut args)?;
    let model = args.get("model", "moe-s-8", "MoE model variant");
    let workers = args.get_usize("workers", 4, "fabric workers");
    let batch = args.get_usize("batch", 8, "decode batch (lanes)");
    let steps = args.get_usize("steps", 8, "decode steps (legacy mode)");
    let a2a: AllToAllKind = args
        .get("alltoall", "hierarchical", "naive|hierarchical|coordinated")
        .parse()?;
    let serial = args.get_bool(
        "serial", false, "serialized per-expert MoE path (DSMOE_SERIAL_MOE)",
    );
    let no_pipeline = args.get_bool(
        "no-pipeline", false,
        "disable microbatch interleaving (DSMOE_NO_PIPELINE)",
    );
    // Flag default comes from DSMOE_PIPE_DEPTH (via ServingConfig) so the
    // env toggle works without --pipe-depth.
    let pipe_depth = args.get_usize(
        "pipe-depth",
        ServingConfig::default().pipe_depth,
        "microbatch pipeline ring depth N (DSMOE_PIPE_DEPTH)",
    );
    let leader_threads = args.get_usize(
        "leader-threads",
        ServingConfig::default().leader_threads,
        "leader shard threads: >=2 = one thread per microbatch group \
         (DSMOE_LEADER_THREADS)",
    );
    let no_interleave = args.get_bool(
        "no-interleave", false,
        "stop-the-world admission prefills (DSMOE_NO_INTERLEAVE)",
    );
    let live_a2a = args.get(
        "a2a", "",
        "live dispatch schedule: flat|hierarchical (default: DSMOE_A2A)",
    );
    let node_size = args.get_usize(
        "node-size", 0,
        "workers per node for hierarchical dispatch \
         (0 = DSMOE_NODE_SIZE / derived)",
    );
    let transport = args.get(
        "transport", "",
        "fabric wire: channel|socket (default: DSMOE_TRANSPORT)",
    );
    let expert_dtype = args.get(
        "expert-dtype", "",
        "expert weight ladder: f32|bf16|i8 (default: DSMOE_EXPERT_DTYPE)",
    );
    let wire_dtype = args.get(
        "wire-dtype", "",
        "activation wire dtype: f32|f16|bf16 (default: DSMOE_WIRE_DTYPE)",
    );
    let legacy = args.get_bool(
        "legacy", false,
        "fixed-lane driver (no request admission; pre-scheduler behaviour)",
    );
    let n_requests =
        args.get_usize("requests", 16, "requests (request-driven mode)");
    let rate = args.get_f64("rate", 100.0, "Poisson arrival rate, req/s");
    let max_new = args.get_usize("max-new", 8, "tokens per request");
    // SLO-aware serving toggles (all default-off; flag defaults come from
    // the env-seeded ServingConfig so the env toggles work bare).
    let prefill_chunk = args.get_usize(
        "prefill-chunk",
        ServingConfig::default().prefill_chunk,
        "chunked prefill: prompt-token budget an admission may advance per \
         decode step, 0 = off (DSMOE_PREFILL_CHUNK)",
    );
    let queue_cap = args.get_usize(
        "queue-cap",
        ServingConfig::default().queue_cap,
        "bounded per-tier admission queues, 0 = unbounded (DSMOE_QUEUE_CAP)",
    );
    let shed_policy = args.get(
        "shed-policy", "",
        "full-queue shedding: reject|drop-oldest (default: DSMOE_SHED_POLICY)",
    );
    let tiers = args.get_usize(
        "tiers", 1,
        "priority tiers: request i gets tier i % tiers (tier 0 = batch, \
         higher = interactive, preempts); 1 = single-tier FIFO",
    );
    let fault_tolerance = args.get_bool(
        "fault-tolerance", false,
        "survive worker death/hangs: exchange deadlines, probe sweeps, \
         live expert failover (DSMOE_FAULT_TOLERANCE)",
    );
    if args.has("help") {
        eprint!("{}", args.usage("ds-moe ep-serve"));
        return Ok(());
    }
    let corpus = corpus(&mut args);
    let transport: TransportKind = if transport.is_empty() {
        TransportKind::from_env()
    } else {
        transport.parse().map_err(anyhow::Error::msg)?
    };
    let mut ep = EpEngine::new_with_transport(
        &m, &model, workers, a2a, batch, transport,
    )?;
    if node_size > 0 {
        ep.set_node_size(node_size);
    }
    match live_a2a.as_str() {
        "" => {} // keep the DSMOE_A2A-derived setting
        "flat" => ep.set_a2a_hierarchical(false),
        "hierarchical" | "hier" => ep.set_a2a_hierarchical(true),
        other => anyhow::bail!(
            "--a2a expects flat|hierarchical, got {other:?}"
        ),
    }
    if serial {
        ep.set_serial_moe(true);
    }
    if no_pipeline {
        ep.set_pipeline(false);
    }
    ep.set_pipe_depth(pipe_depth);
    ep.set_leader_threads(leader_threads);
    if no_interleave {
        ep.set_interleave(false);
    }
    if !expert_dtype.is_empty() {
        let d = Dtype::parse(&expert_dtype)
            .with_context(|| format!("--expert-dtype {expert_dtype:?}"))?;
        ep.set_expert_dtype(d)?;
    }
    if !wire_dtype.is_empty() {
        let d = Dtype::parse(&wire_dtype)
            .with_context(|| format!("--wire-dtype {wire_dtype:?}"))?;
        ep.set_wire_dtype(d)?;
    }
    if fault_tolerance {
        ep.set_fault_tolerance(true);
    }
    println!(
        "ep-serve {model}: {workers} workers, batch {batch}, {a2a:?}, \
         {} microbatch(es) (depth {pipe_depth} requested), \
         {} leader thread(s), {} mode{}",
        ep.microbatches(),
        ep.leader_shards(),
        if legacy { "fixed-lane" } else { "request-driven" },
        if !legacy && ep.interleave() && !serial {
            ", interleaved admission"
        } else {
            ""
        }
    );
    if legacy {
        return ep_serve_fixed(ep, &corpus, batch, steps);
    }

    // Request-driven continuous batching: Poisson-ish open-loop arrivals
    // through the engine-agnostic scheduler.
    let shed_policy: ShedPolicy = if shed_policy.is_empty() {
        ShedPolicy::from_env()
    } else {
        shed_policy.parse()?
    };
    let serving = ServingConfig {
        model: model.clone(),
        workers,
        max_batch: batch,
        max_new_tokens: max_new,
        alltoall: a2a,
        pipe_depth,
        leader_threads,
        prefill_chunk,
        queue_cap,
        shed_policy,
        ..Default::default()
    };
    let mut sched = Scheduler::new(ep, serving);
    let plen = 8usize;
    let (responses, wall) = if tiers > 1 {
        run_poisson_tiered(&mut sched, n_requests, rate, max_new, tiers, |i| {
            corpus.prompt(i, plen)
        })?
    } else {
        sched.run_poisson(n_requests, rate, max_new, 7, |i| {
            corpus.prompt(i, plen)
        })?
    };
    let tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    println!(
        "{} responses / {tokens} tokens in {wall:.3}s ({:.1} tok/s), \
         TTFT p50 {} p99 {}, TPOT p50 {} p99 {}",
        responses.len(),
        tokens as f64 / wall,
        fmt_ns(ttft_percentile(&responses, 50)),
        fmt_ns(ttft_percentile(&responses, 99)),
        fmt_ns(tpot_percentile(&responses, 50)),
        fmt_ns(tpot_percentile(&responses, 99)),
    );
    tier_report(&sched.metrics, &responses);
    println!(
        "lane occupancy: {:.1}% mean over {} decode steps; \
         exposed pipeline bubble {}, prefill stall {} \
         ({} interleaved admissions)",
        100.0 * sched.metrics.value_mean("decode_utilization"),
        sched.metrics.counter("decode_steps"),
        fmt_ns(sched.metrics.sum_ns("pipeline_bubble")),
        fmt_ns(sched.metrics.sum_ns("prefill_stall")),
        sched.metrics.counter("interleaved_admissions"),
    );
    ep_report(&sched.model);
    println!("--- metrics ---\n{}", sched.metrics.report());
    Ok(())
}

/// `Scheduler::run_poisson` with tiered submission: request `i` gets tier
/// `i % tiers`, so a `--tiers 2` run interleaves batch (tier 0) and
/// interactive (tier 1) traffic on the same arrival process.  Shed
/// requests (bounded queues) simply never produce a response.
fn run_poisson_tiered<M, F>(
    sched: &mut Scheduler<M>,
    n: usize,
    rate: f64,
    max_new: usize,
    tiers: usize,
    mut prompt: F,
) -> Result<(Vec<Response>, f64)>
where
    M: ds_moe::server::ForwardModel,
    F: FnMut(usize) -> Vec<i32>,
{
    let mut rng = ds_moe::util::rng::Rng::new(7);
    let mut arrivals = Vec::with_capacity(n);
    let mut t_acc = 0.0;
    for _ in 0..n {
        t_acc += rng.exponential(rate);
        arrivals.push(t_acc);
    }
    let t0 = std::time::Instant::now();
    let mut submitted = 0usize;
    while submitted < n
        || sched.active_count() > 0
        || sched.queue_len() > 0
        || sched.admission_in_flight()
    {
        let now = t0.elapsed().as_secs_f64();
        while submitted < n && arrivals[submitted] <= now {
            let tier = (submitted % tiers) as u8;
            sched.submit_tiered(prompt(submitted), Some(max_new), tier,
                                None)?;
            submitted += 1;
        }
        if !sched.step()? {
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
    }
    Ok((sched.take_done(), t0.elapsed().as_secs_f64()))
}

/// Per-tier TTFT/TPOT/shed/preemption breakdown; silent for plain
/// single-tier FIFO runs with nothing shed or preempted.
fn tier_report(metrics: &ds_moe::metrics::Metrics, responses: &[Response]) {
    let mut tiers: Vec<u8> = responses.iter().map(|r| r.tier).collect();
    tiers.sort_unstable();
    tiers.dedup();
    let shed = metrics.counter("requests_shed");
    let preempted = metrics.counter("preemptions");
    if tiers.len() <= 1 && shed == 0 && preempted == 0 {
        return;
    }
    for t in tiers {
        let rs: Vec<Response> = responses
            .iter()
            .filter(|r| r.tier == t)
            .cloned()
            .collect();
        println!(
            "  tier {t}: {} done, TTFT p50 {} p99 {}, TPOT p50 {} p99 {}, \
             shed {}, preempted {}, deadline misses {}",
            rs.len(),
            fmt_ns(ttft_percentile(&rs, 50)),
            fmt_ns(ttft_percentile(&rs, 99)),
            fmt_ns(tpot_percentile(&rs, 50)),
            fmt_ns(tpot_percentile(&rs, 99)),
            metrics.counter(&format!("shed_t{t}")),
            metrics.counter(&format!("preempted_t{t}")),
            metrics.counter(&format!("deadline_miss_t{t}")),
        );
    }
    if shed + preempted > 0 {
        println!(
            "  backpressure: {shed} shed; {preempted} preemptions, \
             {} resumed",
            metrics.counter("resumed"),
        );
    }
}

/// The legacy fixed-lane driver: one full-batch prefill, then `steps`
/// decode steps over every lane (no admission, no retirement).
fn ep_serve_fixed(
    mut ep: EpEngine,
    corpus: &Corpus,
    batch: usize,
    steps: usize,
) -> Result<()> {
    let smax = ep.cfg.max_seq;
    let plen = 8usize;
    let mut tokens = vec![0i32; batch * smax];
    let mut lens = vec![plen; batch];
    for b in 0..batch {
        let p = corpus.prompt(b, plen);
        tokens[b * smax..b * smax + plen].copy_from_slice(&p);
        lens[b] = plen;
    }
    let t0 = std::time::Instant::now();
    let logits = ep.forward_prefill(&tokens, &lens)?;
    let mut last: Vec<i32> = logits.iter().map(|row| argmax(row)).collect();
    let mut pos: Vec<i32> = lens.iter().map(|&l| l as i32).collect();
    for _ in 0..steps {
        let logits = ep.forward_decode(&last, &pos)?;
        last = logits.iter().map(|row| argmax(row)).collect();
        for p in &mut pos {
            *p += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "prefill + {steps} decode steps in {wall:?} \
         ({:.1} tok/s aggregate)",
        (batch * steps) as f64 / wall.as_secs_f64()
    );
    ep_report(&ep);
    println!("--- metrics ---\n{}", ep.metrics.report());
    Ok(())
}

fn ep_report(ep: &EpEngine) {
    use std::sync::atomic::Ordering::Relaxed;
    let t = ep.traffic();
    println!("traffic: {} bytes total, {} expert messages",
             t.total_bytes(),
             t.messages.load(Relaxed));
    println!(
        "compression: expert weights {} on the wire, activations {}",
        ep.expert_dtype(),
        ep.wire_dtype()
    );
    for d in Dtype::ALL {
        let (disp, comb) = (t.dispatch_bytes(d), t.combine_bytes(d));
        if disp > 0 || comb > 0 {
            println!(
                "         {} payloads: dispatch {disp} bytes, \
                 combine {comb} bytes",
                d.name()
            );
        }
    }
    println!(
        "         cross-node {} bytes / {} msgs, \
         intra-node {} bytes / {} msgs ({})",
        t.cross_bytes.load(Relaxed),
        t.cross_messages.load(Relaxed),
        t.intra_bytes.load(Relaxed),
        t.intra_messages.load(Relaxed),
        if ep.a2a_hierarchical() {
            format!("hierarchical a2a, node size {}", ep.node_size())
        } else {
            "flat a2a".to_string()
        }
    );
    for s in &ep.load_stats {
        println!(
            "layer {}: imbalance {:.2} recent skew {:.2} entropy {:.2} \
             utilization {:.0}%",
            s.layer,
            s.imbalance(),
            s.recent_skew(),
            s.entropy(),
            100.0 * s.utilization()
        );
    }
    if ep.fault_tolerance() {
        let degraded = ep.metrics.counter("degraded_steps") > 0;
        println!(
            "fault tolerance: on, degraded: {degraded} — \
             {} worker deaths, {} failovers, {} engine retries, \
             {} exchange timeouts, {} requests requeued",
            ep.metrics.counter("worker_deaths"),
            ep.metrics.counter("failovers"),
            ep.metrics.counter("ft_retries"),
            ep.metrics.counter("exchange_timeouts"),
            ep.metrics.counter("fault_requeues"),
        );
    }
}

fn argmax(row: &[f32]) -> i32 {
    ds_moe::util::stats::argmax(row) as i32
}

fn cmd_train(mut args: Args) -> Result<()> {
    let m = manifest(&mut args)?;
    let model = args.get("model", "moe-s-8", "model variant");
    let steps = args.get_usize("steps", 200, "training steps");
    let eval_every = args.get_usize("eval-every", 20, "eval interval");
    let lr = args.get_f64("lr", 1e-3, "peak learning rate");
    let save = args.get("save", "", "checkpoint dir to save to (optional)");
    if args.has("help") {
        eprint!("{}", args.usage("ds-moe train"));
        return Ok(());
    }
    let corpus = corpus(&mut args);
    let sched = LrSchedule {
        peak: lr,
        min: lr / 10.0,
        warmup_steps: steps / 20,
        decay_steps: steps,
    };
    let mut tr = Trainer::new(&m, &model, sched)?;
    println!("training {model} ({} params) for {steps} steps", tr.param_count());
    tr.run(&corpus, steps, eval_every, false)?;
    if !save.is_empty() {
        tr.save(&save)?;
        println!("saved checkpoint to {save}");
    }
    Ok(())
}

fn cmd_distill(mut args: Args) -> Result<()> {
    let m = manifest(&mut args)?;
    let student = args.get("student", "mos-s", "student model");
    let teacher_ckpt = args.get(
        "teacher-ckpt",
        "checkpoints/prmoe-s",
        "trained teacher checkpoint dir",
    );
    let steps = args.get_usize("steps", 200, "training steps");
    let eval_every = args.get_usize("eval-every", 20, "eval interval");
    let mode = args.get("mode", "staged", "none|full|staged");
    let frac = args.get_f64("kd-stop-frac", 0.7, "staged KD stop fraction");
    let lr = args.get_f64("lr", 1e-3, "peak learning rate");
    let save = args.get("save", "", "checkpoint dir to save to (optional)");
    if args.has("help") {
        eprint!("{}", args.usage("ds-moe distill"));
        return Ok(());
    }
    let kd = match mode.as_str() {
        "none" => KdMode::None,
        "full" => KdMode::Full,
        "staged" => KdMode::Staged { frac },
        other => anyhow::bail!("unknown KD mode {other}"),
    };
    let corpus = corpus(&mut args);
    let sched = LrSchedule {
        peak: lr,
        min: lr / 10.0,
        warmup_steps: steps / 20,
        decay_steps: steps,
    };
    let mut d = Distiller::new(&m, &student, &teacher_ckpt, sched, kd)?;
    println!("distilling {student} (mode {mode}) for {steps} steps");
    d.run(&corpus, steps, eval_every, false)?;
    if !save.is_empty() {
        d.student.save(&save)?;
        println!("saved student checkpoint to {save}");
    }
    Ok(())
}

fn cmd_eval(mut args: Args) -> Result<()> {
    let m = manifest(&mut args)?;
    let model = args.get("model", "moe-s-8", "model variant");
    let ckpt = args.get("ckpt", "", "trained checkpoint dir (default: initial)");
    let prompt_len = args.get_usize("prompt-len", 8, "cloze prompt length");
    if args.has("help") {
        eprint!("{}", args.usage("ds-moe eval"));
        return Ok(());
    }
    let corpus = corpus(&mut args);
    let suite = EvalSuite::from_corpus(&corpus, prompt_len);
    let sched = LrSchedule { peak: 0.0, min: 0.0, warmup_steps: 1,
                             decay_steps: 1 };
    let mut tr = Trainer::new(&m, &model, sched)?;
    if !ckpt.is_empty() {
        tr.restore(&ckpt).context("restoring checkpoint")?;
    }
    let valid = tr.eval(&corpus, 8)?;
    let (per_task, mean) = tr.zero_shot(&suite, prompt_len)?;
    println!("{model}: valid loss {valid:.4}");
    for (name, acc) in per_task {
        println!("  {name}: {:.1}%", 100.0 * acc);
    }
    println!("  mean: {:.1}%", 100.0 * mean);
    Ok(())
}

fn cmd_simulate(mut args: Args) -> Result<()> {
    let what = args.get(
        "figure", "fig10",
        "fig10|fig11|fig12|fig13|fig14|fig15|table3|calibrated",
    );
    if args.has("help") {
        eprint!("{}", args.usage("ds-moe simulate"));
        return Ok(());
    }
    simulator::run_named(&what)
}

fn cmd_info(mut args: Args) -> Result<()> {
    let m = manifest(&mut args)?;
    println!("{} models, {} shared programs", m.models.len(), m.shared.len());
    for (name, arts) in &m.models {
        println!(
            "  {name:<22} {:>10} params  layers {:?}  programs: {}",
            arts.config.num_params,
            arts.config.experts_schedule,
            arts.programs.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    Ok(())
}
