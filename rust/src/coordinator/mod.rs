//! The L3 coordinator — DeepSpeed-MoE's system contribution (§5):
//!
//! * [`router`] — request admission + inbound FIFO.
//! * [`batcher`] — dynamic batch formation at compiled batch sizes.
//! * [`gate`] — host-side top-1 routing: the dense token→expert mapping
//!   table that drives token grouping (§5.4's kernel, mirrored at the
//!   coordinator where blocks cross worker boundaries).
//! * [`placement`] — multi-expert/multi-data expert placement (§4.1.3).
//! * [`rebalance`] — load-aware hot-expert replication/migration policy
//!   driven by the EWMA expert-load histograms.
//! * [`alltoall`] — naive / hierarchical / parallelism-coordinated token
//!   exchange schedules (§5.3, Figs 8–9).
//! * [`kv_cache`] — lane-granular KV caches for continuous decode batching.

pub mod alltoall;
pub mod batcher;
pub mod gate;
pub mod kv_cache;
pub mod placement;
pub mod rebalance;
pub mod router;

pub use alltoall::{plan, Plan, Topology};
pub use batcher::{BatchPolicy, Decision};
pub use gate::Routing;
pub use kv_cache::KvCacheGroup;
pub use placement::{LayerPlacement, Placement};
pub use rebalance::Rebalancer;
pub use router::{Limits, Request, Response, Router, Submission};
