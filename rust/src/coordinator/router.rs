//! Request router: admission control and the inbound queue.
//!
//! The serving stack's front door — validates requests against model
//! limits, assigns ids, timestamps arrivals, and exposes the FIFO the
//! batcher drains.  Owned by the engine-agnostic `server::Scheduler`, one
//! instance per serving stack regardless of backend.  (The cross-GPU
//! "routing" of tokens to experts is `gate.rs`/`alltoall.rs`; this module
//! routes *requests*.)

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

/// An admitted generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Time from arrival to first generated token.
    pub ttft: std::time::Duration,
    /// Time from arrival to completion.
    pub total: std::time::Duration,
}

/// Admission limits (derived from the model + serving config).
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    pub max_seq: usize,
    pub vocab_size: usize,
    pub default_max_new: usize,
}

#[derive(Debug)]
pub struct Router {
    limits: Limits,
    next_id: u64,
    queue: VecDeque<Request>,
    pub admitted: u64,
    pub rejected: u64,
}

impl Router {
    pub fn new(limits: Limits) -> Self {
        Router { limits, next_id: 1, queue: VecDeque::new(), admitted: 0,
                 rejected: 0 }
    }

    /// Validate + enqueue.  Returns the assigned request id.
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: Option<usize>,
    ) -> Result<u64> {
        let max_new = max_new_tokens.unwrap_or(self.limits.default_max_new);
        if prompt.is_empty() {
            self.rejected += 1;
            bail!("empty prompt");
        }
        if prompt.len() + max_new > self.limits.max_seq {
            self.rejected += 1;
            bail!(
                "prompt ({}) + max_new ({}) exceeds max_seq {}",
                prompt.len(), max_new, self.limits.max_seq
            );
        }
        if let Some(&bad) = prompt
            .iter()
            .find(|&&t| t < 0 || t as usize >= self.limits.vocab_size)
        {
            self.rejected += 1;
            bail!("token {bad} outside vocab {}", self.limits.vocab_size);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.admitted += 1;
        self.queue.push_back(Request {
            id,
            prompt,
            max_new_tokens: max_new,
            arrival: Instant::now(),
        });
        Ok(id)
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Pop up to `n` requests (batch formation).
    pub fn pop_up_to(&mut self, n: usize) -> Vec<Request> {
        let take = n.min(self.queue.len());
        self.queue.drain(..take).collect()
    }

    /// Age of the oldest waiting request (drives batching timeout).
    pub fn oldest_wait(&self) -> Option<std::time::Duration> {
        self.queue.front().map(|r| r.arrival.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> Limits {
        Limits { max_seq: 64, vocab_size: 512, default_max_new: 16 }
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut r = Router::new(limits());
        let a = r.submit(vec![1, 2, 3], None).unwrap();
        let b = r.submit(vec![4], Some(8)).unwrap();
        assert!(b > a);
        assert_eq!(r.queue_len(), 2);
        assert_eq!(r.pop().unwrap().id, a);
        assert_eq!(r.pop().unwrap().id, b);
        assert!(r.pop().is_none());
    }

    #[test]
    fn admission_limits() {
        let mut r = Router::new(limits());
        assert!(r.submit(vec![], None).is_err());
        assert!(r.submit(vec![1; 60], Some(10)).is_err()); // 70 > 64
        assert!(r.submit(vec![600], None).is_err()); // out of vocab
        assert!(r.submit(vec![-1], None).is_err());
        assert_eq!(r.rejected, 4);
        assert_eq!(r.admitted, 0);
        assert!(r.submit(vec![1; 48], Some(16)).is_ok()); // exactly max_seq
    }

    #[test]
    fn pop_up_to_drains_prefix() {
        let mut r = Router::new(limits());
        for i in 0..5 {
            r.submit(vec![1 + i], None).unwrap();
        }
        let batch = r.pop_up_to(3);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].prompt, vec![1]);
        assert_eq!(r.queue_len(), 2);
        assert_eq!(r.pop_up_to(10).len(), 2);
    }

    #[test]
    fn oldest_wait_tracks_head() {
        let mut r = Router::new(limits());
        assert!(r.oldest_wait().is_none());
        r.submit(vec![1], None).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(r.oldest_wait().unwrap().as_micros() >= 2000);
    }
}
