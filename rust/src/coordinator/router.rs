//! Request router: admission control and the inbound queues.
//!
//! The serving stack's front door — validates requests against model
//! limits, assigns ids, timestamps arrivals, and exposes the queues the
//! batcher drains.  Owned by the engine-agnostic `server::Scheduler`, one
//! instance per serving stack regardless of backend.  (The cross-GPU
//! "routing" of tokens to experts is `gate.rs`/`alltoall.rs`; this module
//! routes *requests*.)
//!
//! PR 9 makes the front door SLO-aware: one FIFO per priority *tier*
//! (higher tier = more urgent; tier 0 is batch/background), drained
//! highest-tier-first, plus a bounded-queue backpressure policy
//! ([`crate::config::ShedPolicy`]) so a burst from one tenant sheds load
//! instead of growing an unbounded backlog.  All of it is inert by
//! default: `submit` enqueues at tier 0 with no cap, which is exactly the
//! old single-FIFO behavior.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::ShedPolicy;

/// An admitted generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
    /// Priority tier: 0 = batch/background, higher = more urgent.
    pub tier: u8,
    /// Optional TTFT deadline relative to `arrival` (reporting only —
    /// the scheduler counts misses per tier, it never drops late work).
    pub deadline: Option<Duration>,
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Time from arrival to first generated token.
    pub ttft: std::time::Duration,
    /// Time from arrival to completion.
    pub total: std::time::Duration,
    /// Priority tier the request was submitted at.
    pub tier: u8,
}

/// Admission limits (derived from the model + serving config).
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    pub max_seq: usize,
    pub vocab_size: usize,
    pub default_max_new: usize,
}

/// Outcome of a valid submission under backpressure: either enqueued
/// (with the assigned id) or shed at the front door.  Invalid requests
/// (bad prompt / limits) still surface as `Err` — shedding is a load
/// decision, not a validation failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Submission {
    Queued(u64),
    Shed,
}

#[derive(Debug)]
pub struct Router {
    limits: Limits,
    next_id: u64,
    /// One FIFO per tier, indexed by tier, grown on demand.
    queues: Vec<VecDeque<Request>>,
    /// Per-tier cap (0 = unbounded, the default).
    queue_cap: usize,
    shed_policy: ShedPolicy,
    pub admitted: u64,
    pub rejected: u64,
    /// Valid submissions turned away (or displaced) by backpressure.
    pub shed: u64,
}

impl Router {
    pub fn new(limits: Limits) -> Self {
        Router {
            limits,
            next_id: 1,
            queues: vec![VecDeque::new()],
            queue_cap: 0,
            shed_policy: ShedPolicy::Reject,
            admitted: 0,
            rejected: 0,
            shed: 0,
        }
    }

    /// Enable bounded per-tier queues (`DSMOE_QUEUE_CAP` > 0) with the
    /// given overflow policy.  `cap == 0` keeps queues unbounded.
    pub fn set_backpressure(&mut self, cap: usize, policy: ShedPolicy) {
        self.queue_cap = cap;
        self.shed_policy = policy;
    }

    /// Validate + enqueue at tier 0 with no deadline — the legacy FIFO
    /// front door.  Returns the assigned request id; backpressure shed
    /// surfaces as an error here (callers that want to distinguish shed
    /// from invalid use [`Router::submit_tiered`]).
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: Option<usize>,
    ) -> Result<u64> {
        match self.submit_tiered(prompt, max_new_tokens, 0, None)? {
            Submission::Queued(id) => Ok(id),
            Submission::Shed => bail!("request shed: tier 0 queue full"),
        }
    }

    /// Validate + enqueue at an explicit tier with an optional TTFT
    /// deadline.  `Err` means the request itself was invalid;
    /// `Ok(Submission::Shed)` means it was valid but turned away (or, under
    /// `DropOldest`, enqueued by displacing the tier's oldest waiter).
    pub fn submit_tiered(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: Option<usize>,
        tier: u8,
        deadline: Option<Duration>,
    ) -> Result<Submission> {
        let max_new = max_new_tokens.unwrap_or(self.limits.default_max_new);
        if prompt.is_empty() {
            self.rejected += 1;
            bail!("empty prompt");
        }
        if prompt.len() + max_new > self.limits.max_seq {
            self.rejected += 1;
            bail!(
                "prompt ({}) + max_new ({}) exceeds max_seq {}",
                prompt.len(), max_new, self.limits.max_seq
            );
        }
        if let Some(&bad) = prompt
            .iter()
            .find(|&&t| t < 0 || t as usize >= self.limits.vocab_size)
        {
            self.rejected += 1;
            bail!("token {bad} outside vocab {}", self.limits.vocab_size);
        }
        let ti = tier as usize;
        if self.queues.len() <= ti {
            self.queues.resize_with(ti + 1, VecDeque::new);
        }
        if self.queue_cap > 0 && self.queues[ti].len() >= self.queue_cap {
            match self.shed_policy {
                ShedPolicy::Reject => {
                    self.shed += 1;
                    return Ok(Submission::Shed);
                }
                ShedPolicy::DropOldest => {
                    // Displace the stalest same-tier waiter; the new
                    // arrival takes its slot below.
                    self.queues[ti].pop_front();
                    self.shed += 1;
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.admitted += 1;
        self.queues[ti].push_back(Request {
            id,
            prompt,
            max_new_tokens: max_new,
            arrival: Instant::now(),
            tier,
            deadline,
        });
        Ok(Submission::Queued(id))
    }

    /// Put a preempted request back at the *head* of its tier's queue so
    /// it is the next admission from that tier.  Bypasses validation and
    /// the queue cap: the request was already admitted once and its
    /// partial work (generated prefix folded into `prompt`) must not be
    /// shed.
    pub fn requeue_front(&mut self, req: Request) {
        let ti = req.tier as usize;
        if self.queues.len() <= ti {
            self.queues.resize_with(ti + 1, VecDeque::new);
        }
        self.queues[ti].push_front(req);
    }

    pub fn queue_len(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    /// Waiting count for one tier (0 for tiers never submitted to).
    pub fn queue_len_tier(&self, tier: u8) -> usize {
        self.queues.get(tier as usize).map_or(0, VecDeque::len)
    }

    /// Highest tier with at least one waiter.
    pub fn highest_waiting_tier(&self) -> Option<u8> {
        (0..self.queues.len())
            .rev()
            .find(|&t| !self.queues[t].is_empty())
            .map(|t| t as u8)
    }

    pub fn pop(&mut self) -> Option<Request> {
        let t = self.highest_waiting_tier()? as usize;
        self.queues[t].pop_front()
    }

    /// Pop up to `n` requests (batch formation): highest tier first,
    /// FIFO within a tier.
    pub fn pop_up_to(&mut self, n: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(n.min(self.queue_len()));
        while out.len() < n {
            match self.pop() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Age of the oldest waiting request across all tiers (drives the
    /// batching timeout).
    pub fn oldest_wait(&self) -> Option<std::time::Duration> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|r| r.arrival.elapsed()))
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> Limits {
        Limits { max_seq: 64, vocab_size: 512, default_max_new: 16 }
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut r = Router::new(limits());
        let a = r.submit(vec![1, 2, 3], None).unwrap();
        let b = r.submit(vec![4], Some(8)).unwrap();
        assert!(b > a);
        assert_eq!(r.queue_len(), 2);
        assert_eq!(r.pop().unwrap().id, a);
        assert_eq!(r.pop().unwrap().id, b);
        assert!(r.pop().is_none());
    }

    #[test]
    fn admission_limits() {
        let mut r = Router::new(limits());
        assert!(r.submit(vec![], None).is_err());
        assert!(r.submit(vec![1; 60], Some(10)).is_err()); // 70 > 64
        assert!(r.submit(vec![600], None).is_err()); // out of vocab
        assert!(r.submit(vec![-1], None).is_err());
        assert_eq!(r.rejected, 4);
        assert_eq!(r.admitted, 0);
        assert!(r.submit(vec![1; 48], Some(16)).is_ok()); // exactly max_seq
    }

    #[test]
    fn pop_up_to_drains_prefix() {
        let mut r = Router::new(limits());
        for i in 0..5 {
            r.submit(vec![1 + i], None).unwrap();
        }
        let batch = r.pop_up_to(3);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].prompt, vec![1]);
        assert_eq!(r.queue_len(), 2);
        assert_eq!(r.pop_up_to(10).len(), 2);
    }

    #[test]
    fn oldest_wait_tracks_head() {
        let mut r = Router::new(limits());
        assert!(r.oldest_wait().is_none());
        r.submit(vec![1], None).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(r.oldest_wait().unwrap().as_micros() >= 2000);
    }

    #[test]
    fn higher_tier_drains_first_fifo_within() {
        let mut r = Router::new(limits());
        let a = match r.submit_tiered(vec![1], None, 0, None).unwrap() {
            Submission::Queued(id) => id,
            Submission::Shed => panic!("shed"),
        };
        let b = match r.submit_tiered(vec![2], None, 1, None).unwrap() {
            Submission::Queued(id) => id,
            Submission::Shed => panic!("shed"),
        };
        let c = match r.submit_tiered(vec![3], None, 1, None).unwrap() {
            Submission::Queued(id) => id,
            Submission::Shed => panic!("shed"),
        };
        assert_eq!(r.highest_waiting_tier(), Some(1));
        assert_eq!(r.queue_len_tier(1), 2);
        // Tier 1 drains first (FIFO within), then tier 0.
        assert_eq!(r.pop().unwrap().id, b);
        assert_eq!(r.pop().unwrap().id, c);
        assert_eq!(r.pop().unwrap().id, a);
        assert!(r.highest_waiting_tier().is_none());
    }

    #[test]
    fn reject_policy_sheds_new_arrival() {
        let mut r = Router::new(limits());
        r.set_backpressure(2, ShedPolicy::Reject);
        for t in 0..2 {
            assert!(matches!(
                r.submit_tiered(vec![10 + t], None, 0, None).unwrap(),
                Submission::Queued(_)
            ));
        }
        // Queue full: the third valid submission is shed, not an error.
        assert_eq!(
            r.submit_tiered(vec![12], None, 0, None).unwrap(),
            Submission::Shed
        );
        assert_eq!(r.shed, 1);
        assert_eq!(r.queue_len(), 2);
        // Another tier has its own headroom.
        assert!(matches!(
            r.submit_tiered(vec![13], None, 1, None).unwrap(),
            Submission::Queued(_)
        ));
        // Accounting: every valid submission is either queued or shed.
        assert_eq!(r.admitted + r.shed, 4);
        // And the legacy front door surfaces shed as an error.
        r.submit(vec![14], None).unwrap();
        assert!(r.submit(vec![15], None).is_err());
    }

    #[test]
    fn drop_oldest_policy_displaces_head() {
        let mut r = Router::new(limits());
        r.set_backpressure(2, ShedPolicy::DropOldest);
        r.submit_tiered(vec![1], None, 0, None).unwrap();
        r.submit_tiered(vec![2], None, 0, None).unwrap();
        // Full: the oldest waiter (prompt [1]) is displaced, the new
        // arrival is queued.
        let s = r.submit_tiered(vec![3], None, 0, None).unwrap();
        assert!(matches!(s, Submission::Queued(_)));
        assert_eq!(r.shed, 1);
        assert_eq!(r.queue_len(), 2);
        assert_eq!(r.pop().unwrap().prompt, vec![2]);
        assert_eq!(r.pop().unwrap().prompt, vec![3]);
    }

    #[test]
    fn requeue_front_is_next_out_and_ignores_cap() {
        let mut r = Router::new(limits());
        r.set_backpressure(1, ShedPolicy::Reject);
        r.submit_tiered(vec![1], None, 0, None).unwrap();
        let preempted = Request {
            id: 99,
            prompt: vec![7, 8],
            max_new_tokens: 4,
            arrival: Instant::now(),
            tier: 0,
            deadline: None,
        };
        r.requeue_front(preempted);
        assert_eq!(r.queue_len(), 2); // cap bypassed
        assert_eq!(r.pop().unwrap().id, 99); // head of its tier
        assert_eq!(r.pop().unwrap().prompt, vec![1]);
    }
}
