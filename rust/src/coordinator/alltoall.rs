//! All-to-all token-exchange schedules (§5.3).
//!
//! Expert parallelism requires an all-to-all between all expert-parallel
//! workers at every MoE layer.  The paper's scaling contribution is two
//! schedule optimizations on top of the naive exchange:
//!
//! * **Naive**: every pair (src, dst) exchanges directly — O(p) sequential
//!   hops per device at small message sizes (latency-bound regime).
//! * **Hierarchical** (Fig 8): a data-layout transform + intra-node
//!   all-to-all, then a second transform + inter-node all-to-all —
//!   O(G + p/G) hops for node size G, at the cost of 2x communication
//!   volume.
//! * **Parallelism-coordinated** (Fig 9): when tensor-slicing of degree L is
//!   active, data is replicated across the L slicing ranks, so the
//!   all-to-all only needs to run between workers of the same slicing rank:
//!   O(p/L) hops (+ an O(L) allgather when re-entering sliced operators).
//!
//! `plan()` emits the concrete message list (src, dst, phase, bytes) that the
//! fabric executes at testbed scale; `hops()`/`volume()` expose the
//! analytical quantities the simulator and the property tests check.

use crate::config::AllToAllKind;

/// One point-to-point message in a schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    pub src: usize,
    pub dst: usize,
    /// Phase index: messages in the same phase proceed in parallel;
    /// phases are barriers (hierarchical = transform/intra/transform/inter).
    pub phase: usize,
    pub bytes: usize,
}

/// A full exchange plan.
#[derive(Debug, Clone)]
pub struct Plan {
    pub kind: AllToAllKind,
    pub workers: usize,
    pub messages: Vec<Message>,
    pub n_phases: usize,
}

/// Topology parameters for schedule construction.
#[derive(Debug, Clone, Copy)]
pub struct Topology {
    pub workers: usize,
    /// Workers per "node" (hierarchical schedule granularity).
    pub node_size: usize,
    /// Tensor-slicing degree (coordinated schedule granularity).
    pub ts_degree: usize,
}

impl Topology {
    pub fn flat(workers: usize) -> Self {
        Topology {
            workers,
            node_size: Self::node_size_from_env(workers),
            ts_degree: 1,
        }
    }

    /// The single source of the hierarchical node size, for the analytic
    /// plans here, `Backbone::exchange_plan`'s accounting, and the fabric's
    /// live hierarchical dispatch alike.  `DSMOE_NODE_SIZE` set to a
    /// positive divisor of `workers` wins; unset derives the largest
    /// divisor of `workers` not exceeding `min(workers, 8)` (the testbed
    /// stand-in for an 8-GPU node, matching the old hard-coded default
    /// whenever that divided the worker count); anything else — zero,
    /// negative, garbage, larger than `workers`, or not dividing it —
    /// warns on stderr and falls back to flat (node size 1), same contract
    /// as `util::env_pos_usize`.
    pub fn node_size_from_env(workers: usize) -> usize {
        let raw = std::env::var("DSMOE_NODE_SIZE").ok();
        Self::node_size_from(workers, raw.as_deref())
    }

    /// Env-free core of [`Topology::node_size_from_env`] (unit-testable
    /// without racing on the shared process environment).
    pub fn node_size_from(workers: usize, raw: Option<&str>) -> usize {
        let workers = workers.max(1);
        let default = || {
            (1..=workers.min(8))
                .rev()
                .find(|g| workers % g == 0)
                .unwrap_or(1)
        };
        let Some(s) = raw else { return default() };
        match s.trim().parse::<i64>() {
            Ok(g) if g >= 1 && (g as usize) <= workers
                && workers % (g as usize) == 0 =>
            {
                g as usize
            }
            _ => {
                eprintln!(
                    "[config] DSMOE_NODE_SIZE={s:?} is not a positive \
                     divisor of {workers} workers; falling back to flat \
                     (node size 1)"
                );
                1
            }
        }
    }
}

/// Build the message plan to deliver `bytes[src][dst]` payloads.
pub fn plan(kind: AllToAllKind, topo: Topology, bytes: &[Vec<usize>]) -> Plan {
    assert_eq!(bytes.len(), topo.workers);
    match kind {
        AllToAllKind::Naive => plan_naive(topo, bytes),
        AllToAllKind::Hierarchical => plan_hierarchical(topo, bytes),
        AllToAllKind::Coordinated => plan_coordinated(topo, bytes),
    }
}

fn plan_naive(topo: Topology, bytes: &[Vec<usize>]) -> Plan {
    let p = topo.workers;
    let mut messages = Vec::new();
    // Round r: worker i sends to (i + r) % p — the classic pairwise
    // exchange; p-1 sequential rounds (plus local copy at r=0).
    for r in 1..p {
        for src in 0..p {
            let dst = (src + r) % p;
            if bytes[src][dst] > 0 {
                messages.push(Message {
                    src,
                    dst,
                    phase: r - 1,
                    bytes: bytes[src][dst],
                });
            }
        }
    }
    Plan { kind: AllToAllKind::Naive, workers: p, messages, n_phases: p.saturating_sub(1) }
}

fn plan_hierarchical(topo: Topology, bytes: &[Vec<usize>]) -> Plan {
    // Standard two-step hierarchical all-to-all (paper Fig 8): to deliver
    // src -> dst = (node_d, local_j), first hand the payload to the local
    // peer with the *destination's local index* (intra-node step, bundled
    // across destination nodes), then that gateway sends straight to dst
    // (inter-node step).  Exactly two hops per payload => volume <= 2x,
    // and O(G) + O(p/G) sequential phases.
    let p = topo.workers;
    let g = topo.node_size.min(p).max(1);
    let n_nodes = p.div_ceil(g);
    let node_of = |w: usize| w / g;
    let node_len = |n: usize| if n + 1 == n_nodes && p % g != 0 { p % g } else { g };
    let mut messages = Vec::new();

    // Intra-node step: bundle per (src, gateway) pair.
    // staged[gateway][dst] accumulates what the gateway must forward.
    let mut intra: Vec<Vec<usize>> = vec![vec![0; p]; p]; // [src][gateway]
    let mut staged: Vec<Vec<usize>> = vec![vec![0; p]; p]; // [gateway][dst]
    for src in 0..p {
        for dst in 0..p {
            if bytes[src][dst] == 0 {
                continue;
            }
            let sn = node_of(src);
            let local_j = (dst % g).min(node_len(sn) - 1);
            let gateway = sn * g + local_j;
            if gateway == src {
                staged[src][dst] += bytes[src][dst];
            } else {
                intra[src][gateway] += bytes[src][dst];
                staged[gateway][dst] += bytes[src][dst];
            }
        }
    }
    for src in 0..p {
        for gw in 0..p {
            if intra[src][gw] > 0 {
                // local ring phase: distance between local indices
                let phase = (gw % g + g - src % g) % g - 1;
                messages.push(Message { src, dst: gw, phase,
                                        bytes: intra[src][gw] });
            }
        }
    }
    // Inter-node step (phases g-1 ..): gateway -> final destination.
    for gw in 0..p {
        for dst in 0..p {
            let b = staged[gw][dst];
            if b == 0 || gw == dst {
                continue;
            }
            let (gn, dn) = (node_of(gw), node_of(dst));
            let phase = if gn == dn {
                // destination shares the gateway's node (payload arrived
                // at the right node already): deliver in the local phases.
                (dst % g + g - gw % g) % g - 1
            } else {
                (g - 1) + (dn + n_nodes - gn) % n_nodes - 1
            };
            messages.push(Message { src: gw, dst, phase, bytes: b });
        }
    }
    Plan {
        kind: AllToAllKind::Hierarchical,
        workers: p,
        messages,
        n_phases: (g - 1) + n_nodes.saturating_sub(1),
    }
}

fn plan_coordinated(topo: Topology, bytes: &[Vec<usize>]) -> Plan {
    let p = topo.workers;
    let l = topo.ts_degree.max(1);
    assert!(p % l == 0, "workers {p} must be divisible by ts degree {l}");
    let group = p / l; // workers per tensor-slicing rank group
    let mut messages = Vec::new();
    // Data is replicated across the L slicing ranks (tensor-slicing
    // all-reduce has already run), so each rank-group of size p/L runs an
    // independent naive exchange in parallel: O(p/L) phases.
    for rank in 0..l {
        let base = rank * group;
        for r in 1..group {
            for i in 0..group {
                let src = base + i;
                let dst = base + (i + r) % group;
                if bytes[src][dst] > 0 {
                    messages.push(Message {
                        src,
                        dst,
                        phase: r - 1,
                        bytes: bytes[src][dst],
                    });
                }
            }
        }
    }
    Plan {
        kind: AllToAllKind::Coordinated,
        workers: p,
        messages,
        n_phases: group.saturating_sub(1),
    }
}

impl Plan {
    /// Sequential hop count (phases) — the latency-bound cost the paper's
    /// O(p) / O(G + p/G) / O(p/L) claims are about.
    pub fn hops(&self) -> usize {
        self.n_phases
    }

    /// Total bytes moved (hierarchical pays up to 2x here — the paper's
    /// stated trade-off).
    pub fn volume(&self) -> usize {
        self.messages.iter().map(|m| m.bytes).sum()
    }

    /// Check every (src,dst) payload is deliverable: naive/coordinated move
    /// it directly; hierarchical via one relay.  Used by tests.
    pub fn max_phase(&self) -> usize {
        self.messages.iter().map(|m| m.phase).max().unwrap_or(0)
    }
}

/// Uniform payload matrix helper (tokens * bytes_per_token evenly spread).
pub fn uniform_bytes(workers: usize, per_pair: usize) -> Vec<Vec<usize>> {
    (0..workers)
        .map(|src| {
            (0..workers)
                .map(|dst| if src == dst { 0 } else { per_pair })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;

    #[test]
    fn naive_hop_count_is_p_minus_1() {
        let topo = Topology::flat(16);
        let p = plan(AllToAllKind::Naive, topo, &uniform_bytes(16, 100));
        assert_eq!(p.hops(), 15);
        assert_eq!(p.volume(), 16 * 15 * 100);
    }

    #[test]
    fn hierarchical_fewer_hops_more_volume() {
        let topo = Topology { workers: 64, node_size: 8, ts_degree: 1 };
        let naive = plan(AllToAllKind::Naive, topo, &uniform_bytes(64, 10));
        let hier =
            plan(AllToAllKind::Hierarchical, topo, &uniform_bytes(64, 10));
        // O(G + p/G) = 8 + 8 = 16 << 63
        assert!(hier.hops() <= 16, "hops {}", hier.hops());
        assert!(hier.hops() < naive.hops());
        // volume at most 2x naive (paper: "2x increase in communication
        // volume")
        assert!(hier.volume() <= 2 * naive.volume(),
                "{} vs {}", hier.volume(), naive.volume());
    }

    #[test]
    fn coordinated_scales_with_ts_degree() {
        let mut bytes = uniform_bytes(32, 10);
        // zero cross-rank-group traffic (replicated data): only in-group
        for src in 0..32 {
            for dst in 0..32 {
                if src / 8 != dst / 8 {
                    bytes[src][dst] = 0;
                }
            }
        }
        let topo = Topology { workers: 32, node_size: 8, ts_degree: 4 };
        let p = plan(AllToAllKind::Coordinated, topo, &bytes);
        // O(p/L) = 8 workers per group -> 7 hops
        assert_eq!(p.hops(), 7);
        // every message stays inside its rank group
        for m in &p.messages {
            assert_eq!(m.src / 8, m.dst / 8);
        }
    }

    #[test]
    fn property_plans_deliver_all_bytes() {
        prop(60, |c| {
            let p = c.usize(2, 24);
            let kind = *c.choose(&[
                AllToAllKind::Naive,
                AllToAllKind::Hierarchical,
            ]);
            let per = c.usize(1, 50);
            let topo = Topology {
                workers: p,
                node_size: c.usize(1, 8).min(p),
                ts_degree: 1,
            };
            let bytes = uniform_bytes(p, per);
            let total_payload: usize =
                bytes.iter().flatten().sum();
            let plan = plan(kind, topo, &bytes);
            // all plans carry at least the payload volume (hierarchical may
            // relay, adding up to 2x)
            crate::prop_assert!(
                plan.volume() >= total_payload,
                "volume {} < payload {} ({kind:?}, p={p})",
                plan.volume(),
                total_payload
            );
            crate::prop_assert!(
                plan.volume() <= 2 * total_payload,
                "volume {} > 2x payload {} ({kind:?}, p={p})",
                plan.volume(),
                total_payload
            );
            crate::prop_assert!(plan.max_phase() < plan.n_phases.max(1));
            Ok(())
        });
    }

    #[test]
    fn empty_traffic_empty_plan() {
        let topo = Topology::flat(8);
        let p = plan(AllToAllKind::Naive, topo, &uniform_bytes(8, 0));
        assert!(p.messages.is_empty());
    }

    #[test]
    fn node_size_default_is_largest_divisor_up_to_8() {
        // Matches the old hard-coded `min(8)` wherever 8 divided the
        // worker count…
        assert_eq!(Topology::node_size_from(8, None), 8);
        assert_eq!(Topology::node_size_from(16, None), 8);
        assert_eq!(Topology::node_size_from(128, None), 8);
        // …but never silently picks a non-dividing node size anymore.
        assert_eq!(Topology::node_size_from(12, None), 6);
        assert_eq!(Topology::node_size_from(7, None), 7);
        assert_eq!(Topology::node_size_from(5, None), 5);
        assert_eq!(Topology::node_size_from(9, None), 3);
        assert_eq!(Topology::node_size_from(1, None), 1);
    }

    #[test]
    fn node_size_env_override_validated() {
        // Valid: positive divisor of the worker count.
        assert_eq!(Topology::node_size_from(8, Some("2")), 2);
        assert_eq!(Topology::node_size_from(8, Some(" 4 ")), 4);
        assert_eq!(Topology::node_size_from(8, Some("8")), 8);
        // Invalid or non-dividing: warn + fall back to flat (1).
        for bad in ["0", "-2", "bogus", "", "2.5", "3", "16"] {
            assert_eq!(
                Topology::node_size_from(8, Some(bad)),
                1,
                "value {bad:?} must fall back to flat"
            );
        }
    }

    #[test]
    fn paper_hop_arithmetic() {
        // §5.3: 128 GPUs, 8-way slicing: all-to-all latency term goes from
        // 128*C1 to 16*C1.
        let topo = Topology { workers: 128, node_size: 8, ts_degree: 8 };
        let mut bytes = uniform_bytes(128, 4);
        for s in 0..128 {
            for d in 0..128 {
                if s / 16 != d / 16 {
                    bytes[s][d] = 0;
                }
            }
        }
        let coord = plan(AllToAllKind::Coordinated, topo, &bytes);
        assert_eq!(coord.hops(), 15); // p/L - 1 = 16 - 1
        let naive = plan(
            AllToAllKind::Naive,
            Topology::flat(128),
            &uniform_bytes(128, 4),
        );
        assert_eq!(naive.hops(), 127);
    }
}
