//! Dynamic batcher: forms prefill batches at compiled batch sizes.
//!
//! The AOT artifacts are compiled for fixed batch geometries (aot.py's
//! `PREFILL_BATCH_SIZES` / `DECODE_BATCH_SIZES`), so batching is a rounding
//! problem: given `waiting` requests, `free` decode lanes, and the oldest
//! request's wait time, choose a compiled prefill size now or keep waiting
//! for a fuller batch.  Owned by the engine-agnostic
//! `server::Scheduler` and fed each backend's compiled sizes
//! (`ForwardModel::prefill_sizes`).  Policy (classic size-or-timeout):
//!
//! * flush when `waiting >= max(compiled sizes) that fits free lanes`, or
//! * flush whatever fits once the oldest request has waited `timeout`.

use std::time::Duration;

/// Batch-formation decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Decision {
    /// Run a prefill of this compiled batch size (taking `take` requests,
    /// padding the rest of the lanes).
    Prefill { compiled: usize, take: usize },
    /// Keep waiting (accumulate a fuller batch).
    Wait,
}

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Compiled prefill batch sizes, ascending (e.g. [1, 4, 8]).
    pub sizes: Vec<usize>,
    pub timeout: Duration,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>, timeout: Duration) -> Self {
        assert!(!sizes.is_empty());
        sizes.sort();
        BatchPolicy { sizes, timeout }
    }

    /// Smallest compiled size >= n (or the largest available).
    pub fn round_up(&self, n: usize) -> usize {
        *self
            .sizes
            .iter()
            .find(|&&s| s >= n)
            .unwrap_or(self.sizes.last().unwrap())
    }

    /// Largest compiled size <= n (None if even the smallest exceeds n).
    pub fn round_down(&self, n: usize) -> Option<usize> {
        self.sizes.iter().rev().find(|&&s| s <= n).copied()
    }

    /// Time remaining until the oldest waiting request hits the flush
    /// timeout (`Duration::ZERO` once elapsed); `None` when nothing waits.
    /// `Scheduler::run_until_idle` sleeps only this long instead of a full
    /// extra `timeout`, so partial batches flush on their deadline rather
    /// than up to one timeout late (TTFT, low-traffic path).
    pub fn time_to_flush(
        &self,
        oldest_wait: Option<Duration>,
    ) -> Option<Duration> {
        oldest_wait.map(|w| self.timeout.saturating_sub(w))
    }

    pub fn decide(
        &self,
        waiting: usize,
        free_lanes: usize,
        oldest_wait: Option<Duration>,
    ) -> Decision {
        self.decide_urgent(waiting, free_lanes, oldest_wait, false)
    }

    /// [`BatchPolicy::decide`] with an urgency override: when `urgent`
    /// (an above-tier-0 request is waiting), a partial batch flushes
    /// immediately instead of accumulating until the timeout — an
    /// interactive-tier request never idles behind the batching clock.
    /// `urgent == false` is byte-for-byte the classic size-or-timeout
    /// policy.
    pub fn decide_urgent(
        &self,
        waiting: usize,
        free_lanes: usize,
        oldest_wait: Option<Duration>,
        urgent: bool,
    ) -> Decision {
        if waiting == 0 || free_lanes == 0 {
            return Decision::Wait;
        }
        let Some(cap) = self.round_down(free_lanes) else {
            return Decision::Wait; // no compiled size fits the free lanes
        };
        let full = cap.min(*self.sizes.last().unwrap());
        if waiting >= full {
            return Decision::Prefill { compiled: full, take: full };
        }
        let timed_out = matches!(oldest_wait, Some(w) if w >= self.timeout);
        if timed_out || urgent {
            let take = waiting.min(cap);
            Decision::Prefill { compiled: self.round_up(take).min(cap), take }
        } else {
            Decision::Wait
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(2))
    }

    #[test]
    fn rounding() {
        let p = policy();
        assert_eq!(p.round_up(1), 1);
        assert_eq!(p.round_up(3), 4);
        assert_eq!(p.round_up(5), 8);
        assert_eq!(p.round_up(20), 8); // clamp to largest
        assert_eq!(p.round_down(6), Some(4));
        assert_eq!(p.round_down(0), None);
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let p = policy();
        assert_eq!(
            p.decide(10, 8, Some(Duration::ZERO)),
            Decision::Prefill { compiled: 8, take: 8 }
        );
    }

    #[test]
    fn partial_batch_waits_until_timeout() {
        let p = policy();
        assert_eq!(p.decide(2, 8, Some(Duration::from_micros(100))),
                   Decision::Wait);
        assert_eq!(
            p.decide(2, 8, Some(Duration::from_millis(3))),
            Decision::Prefill { compiled: 4, take: 2 }
        );
    }

    #[test]
    fn limited_by_free_lanes() {
        let p = policy();
        // 10 waiting but only 3 free lanes: largest compiled <= 3 is 1...
        assert_eq!(
            p.decide(10, 3, Some(Duration::ZERO)),
            Decision::Prefill { compiled: 1, take: 1 }
        );
        // 5 free lanes -> compiled 4
        assert_eq!(
            p.decide(10, 5, Some(Duration::ZERO)),
            Decision::Prefill { compiled: 4, take: 4 }
        );
    }

    #[test]
    fn nothing_waiting_or_no_lanes() {
        let p = policy();
        assert_eq!(p.decide(0, 8, None), Decision::Wait);
        assert_eq!(p.decide(5, 0, Some(Duration::from_secs(1))),
                   Decision::Wait);
    }

    #[test]
    fn single_request_low_traffic_latency() {
        // After timeout a single request runs alone at compiled size 1 —
        // the low-traffic latency path.
        let p = policy();
        assert_eq!(
            p.decide(1, 8, Some(Duration::from_millis(5))),
            Decision::Prefill { compiled: 1, take: 1 }
        );
    }

    #[test]
    fn waiting_exceeds_largest_compiled_size() {
        let p = policy();
        // Far more waiting than any compiled size: flush at the max size,
        // leaving the rest queued for the next decide().
        assert_eq!(
            p.decide(100, 16, Some(Duration::ZERO)),
            Decision::Prefill { compiled: 8, take: 8 }
        );
        // round_up clamps to the largest size for any oversized n
        assert_eq!(p.round_up(usize::MAX), 8);
    }

    #[test]
    fn free_lanes_below_smallest_compiled_size() {
        let p = BatchPolicy::new(vec![4, 8], Duration::from_millis(2));
        // Even an elapsed timeout cannot flush into 3 lanes when the
        // smallest compiled size is 4 — there is no program to run.
        assert_eq!(p.decide(6, 3, Some(Duration::from_secs(1))),
                   Decision::Wait);
        assert_eq!(p.round_down(3), None);
        // round_up of 0 picks the smallest compiled size
        assert_eq!(p.round_up(0), 4);
    }

    #[test]
    fn timeout_exactly_elapsed_flushes() {
        let p = policy(); // timeout = 2ms
        // w == timeout must flush (>=, not >): a request is never made to
        // wait an extra scheduler round at its exact deadline.
        assert_eq!(
            p.decide(2, 8, Some(Duration::from_millis(2))),
            Decision::Prefill { compiled: 4, take: 2 }
        );
    }

    #[test]
    fn urgent_flushes_partial_batch_before_timeout() {
        let p = policy(); // timeout = 2ms
        // Classic policy waits; the urgency override flushes now.
        let young = Some(Duration::from_micros(100));
        assert_eq!(p.decide_urgent(2, 8, young, false), Decision::Wait);
        assert_eq!(
            p.decide_urgent(2, 8, young, true),
            Decision::Prefill { compiled: 4, take: 2 }
        );
        // Urgency cannot conjure lanes or requests.
        assert_eq!(p.decide_urgent(0, 8, None, true), Decision::Wait);
        assert_eq!(p.decide_urgent(5, 0, young, true), Decision::Wait);
    }

    #[test]
    fn time_to_flush_remaining() {
        let p = policy(); // timeout = 2ms
        assert_eq!(p.time_to_flush(None), None);
        assert_eq!(
            p.time_to_flush(Some(Duration::from_millis(1))),
            Some(Duration::from_millis(1))
        );
        // exactly elapsed and past-due both clamp to zero
        assert_eq!(p.time_to_flush(Some(Duration::from_millis(2))),
                   Some(Duration::ZERO));
        assert_eq!(p.time_to_flush(Some(Duration::from_secs(1))),
                   Some(Duration::ZERO));
    }

    #[test]
    fn property_take_never_exceeds_compiled_or_lanes() {
        use crate::util::prop::prop;
        prop(200, |c| {
            let p = policy();
            let waiting = c.usize(0, 32);
            let free = c.usize(0, 16);
            let wait_ms = c.usize(0, 10);
            if let Decision::Prefill { compiled, take } = p.decide(
                waiting,
                free,
                Some(Duration::from_millis(wait_ms as u64)),
            ) {
                crate::prop_assert!(take <= compiled, "take > compiled");
                crate::prop_assert!(compiled <= free.max(1),
                                    "compiled {compiled} > free {free}");
                crate::prop_assert!(take <= waiting, "take > waiting");
                crate::prop_assert!(take > 0, "empty prefill");
                crate::prop_assert!(
                    p.sizes.contains(&compiled),
                    "compiled {compiled} not a compiled size"
                );
            }
            Ok(())
        });
    }
}
