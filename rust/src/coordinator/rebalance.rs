//! Online load-aware expert rebalancing: the measurement→placement
//! control loop.
//!
//! The paper's placement (§4.1.3) is balanced by *expert count*, but real
//! routing is skewed by *token count* — one hot expert serializes its
//! worker while the rest idle, and the whole pipeline ring waits on the
//! slowest exchange ("Who Says Elephants Can't Run" reports replicating
//! hot experts and rebalancing placement from observed load as the
//! production fix).  This module is the pure policy half of that loop: it
//! reads the per-layer EWMA load histogram
//! ([`crate::moe::ExpertLoadStats::recent_histogram`]) and proposes
//! placement [`Action`]s; the engine applies them between forwards —
//! shipping weights over the existing `fabric.load_expert` path and
//! bumping the placement epoch only at exchange boundaries, so no
//! in-flight tagged exchange ever observes a torn placement.
//!
//! The policy is deliberately incremental: at most one replication per
//! layer per call (weight shipping is the expensive step), plus any
//! number of de-replications of cooled experts (those are free — dropping
//! a host just stops splitting tokens to it; stale weights are harmless).

use crate::coordinator::placement::LayerPlacement;

/// One placement change proposed by [`Rebalancer::plan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Host `expert` on worker `to` as well (caller ships the weights).
    Replicate { layer: usize, expert: usize, to: usize },
    /// Stop hosting `expert` on worker `from` (no weight movement).
    Dereplicate { layer: usize, expert: usize, from: usize },
}

/// Load-aware replication policy.  Stateless between calls — all memory
/// lives in the EWMA histogram and the placement itself.
#[derive(Debug, Clone, Copy)]
pub struct Rebalancer {
    /// Recent max/mean skew ratio that triggers replication
    /// (`DSMOE_REBALANCE_SKEW`; 1.0 is perfectly balanced).
    pub skew_threshold: f64,
    /// Replication ceiling per expert (`DSMOE_MAX_REPLICAS`).
    pub max_replicas: usize,
}

impl Rebalancer {
    /// Recent per-worker load under the split-dispatch model: each hosted
    /// expert contributes its EWMA load divided by its replication (the
    /// gate splits a replicated expert's block evenly across hosts).
    fn worker_load(lp: &LayerPlacement, recent: &[f64], w: usize) -> f64 {
        lp.experts_of[w]
            .iter()
            .map(|&e| recent[e] / lp.replication(e) as f64)
            .sum()
    }

    /// The workers a balanced placement gives expert `e` (one per replica
    /// group) — the copies migration must never remove.
    fn home_set(lp: &LayerPlacement, e: usize) -> Vec<usize> {
        (0..lp.dp_degree).map(|r| r * lp.ep_degree + e % lp.ep_degree).collect()
    }

    /// Propose placement changes for one layer from its recent load view.
    /// Replicates the hottest expert onto the least-loaded non-hosting
    /// worker when skew crosses the threshold; de-replicates extra copies
    /// of experts that have cooled to (or below) the mean.
    pub fn plan(&self, lp: &LayerPlacement, recent: &[f64]) -> Vec<Action> {
        assert_eq!(recent.len(), lp.n_experts);
        let workers = lp.experts_of.len();
        let mean = recent.iter().sum::<f64>() / lp.n_experts as f64;
        if mean <= 0.0 {
            return Vec::new();
        }
        let mut actions = Vec::new();

        // Cool-down first: extra replicas (beyond the balanced home set)
        // of experts at or below the mean stop earning their dispatch
        // split — release them so the host's capacity goes back to its
        // own experts.
        for e in 0..lp.n_experts {
            if recent[e] > mean {
                continue;
            }
            let homes = Self::home_set(lp, e);
            for w in lp.replicas_of(e) {
                if !homes.contains(&w) {
                    actions.push(Action::Dereplicate {
                        layer: lp.layer,
                        expert: e,
                        from: w,
                    });
                }
            }
        }

        // Heat-up: one replication per call, hottest expert first.
        let hot = (0..lp.n_experts)
            .max_by(|&a, &b| recent[a].total_cmp(&recent[b]))
            .unwrap();
        let skew = recent[hot] / mean;
        if skew >= self.skew_threshold
            && lp.replication(hot) < self.max_replicas
        {
            let target = (0..workers)
                .filter(|&w| !lp.experts_of[w].contains(&hot))
                .min_by(|&a, &b| {
                    Self::worker_load(lp, recent, a)
                        .total_cmp(&Self::worker_load(lp, recent, b))
                });
            if let Some(to) = target {
                actions.push(Action::Replicate {
                    layer: lp.layer,
                    expert: hot,
                    to,
                });
            }
        }
        actions
    }

    /// Failover plan for one layer when worker `victim` is declared dead:
    /// the replications needed before the victim can be evicted.  Dispatch
    /// derives each expert's destination from `owner(e, 0)`, which only
    /// searches replica group 0 (workers `0..ep_degree`) — so every expert
    /// the victim hosted must keep a *live group-0* host, not merely any
    /// surviving copy.  Targets are the least-loaded live group-0 workers
    /// (lowest index breaks ties), with planned additions counted so a
    /// multi-expert failover spreads instead of piling onto one survivor.
    /// `dead[w]` marks previously-declared-dead workers to skip as
    /// targets; the victim itself need not be marked yet.
    pub fn plan_failover(
        lp: &LayerPlacement,
        victim: usize,
        dead: &[bool],
    ) -> Vec<Action> {
        let workers = lp.experts_of.len();
        let group0 = lp.ep_degree.min(workers);
        let live =
            |w: usize| w != victim && !dead.get(w).copied().unwrap_or(false);
        let mut load: Vec<usize> =
            lp.experts_of.iter().map(|v| v.len()).collect();
        let mut planned: Vec<Vec<usize>> = vec![Vec::new(); workers];
        let mut actions = Vec::new();
        for &e in &lp.experts_of[victim] {
            let hosted = |w: usize| {
                lp.experts_of[w].contains(&e) || planned[w].contains(&e)
            };
            if (0..group0).any(|w| live(w) && hosted(w)) {
                continue;
            }
            // Prefer a group-0 target (dispatchable); fall back to any
            // live worker so the expert's bytes at least survive.
            let to = (0..group0)
                .filter(|&w| live(w) && !hosted(w))
                .min_by_key(|&w| (load[w], w))
                .or_else(|| {
                    (0..workers)
                        .filter(|&w| live(w) && !hosted(w))
                        .min_by_key(|&w| (load[w], w))
                });
            let Some(to) = to else { continue };
            planned[to].push(e);
            load[to] += 1;
            actions.push(Action::Replicate { layer: lp.layer, expert: e, to });
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Rebalancer {
        Rebalancer { skew_threshold: 2.0, max_replicas: 4 }
    }

    #[test]
    fn balanced_load_plans_nothing() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        let acts = policy().plan(&lp, &[1.0, 1.0, 1.0, 1.0]);
        assert!(acts.is_empty(), "{acts:?}");
    }

    #[test]
    fn zero_load_plans_nothing() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        assert!(policy().plan(&lp, &[0.0; 4]).is_empty());
    }

    #[test]
    fn hot_expert_replicates_onto_least_loaded_worker() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        // Expert 0 is hot (skew 8/ (11/4) ≈ 2.9); worker 2 is coolest.
        let recent = [8.0, 1.0, 0.5, 1.5];
        let acts = policy().plan(&lp, &recent);
        assert_eq!(
            acts,
            vec![Action::Replicate { layer: 0, expert: 0, to: 2 }]
        );
    }

    #[test]
    fn below_threshold_does_not_replicate() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        // max/mean = 1.6 < 2.0
        assert!(policy().plan(&lp, &[2.0, 1.0, 1.0, 1.0]).is_empty());
    }

    #[test]
    fn replication_respects_the_ceiling() {
        let mut lp = LayerPlacement::balanced(0, 4, 4);
        assert!(lp.add_replica(0, 1));
        let p = Rebalancer { skew_threshold: 2.0, max_replicas: 2 };
        // Expert 0 is still hottest but already at the ceiling.
        assert!(p.plan(&lp, &[8.0, 1.0, 0.5, 1.5]).is_empty());
    }

    #[test]
    fn cooled_extra_replica_is_released_but_homes_are_kept() {
        let mut lp = LayerPlacement::balanced(0, 4, 8); // dp=2: homes at w and w+4
        assert!(lp.add_replica(0, 1)); // extra replica from an earlier hot phase
        // Expert 0 cooled to the mean: the extra copy goes, both balanced
        // homes (workers 0 and 4) stay.
        let acts = policy().plan(&lp, &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(
            acts,
            vec![Action::Dereplicate { layer: 0, expert: 0, from: 1 }]
        );
    }

    #[test]
    fn hot_expert_keeps_its_extra_replica() {
        let mut lp = LayerPlacement::balanced(0, 4, 4);
        assert!(lp.add_replica(0, 1));
        let p = Rebalancer { skew_threshold: 2.0, max_replicas: 2 };
        // Still hot: no dereplicate, and the ceiling blocks growth.
        assert!(p.plan(&lp, &[8.0, 1.0, 0.5, 1.5]).is_empty());
    }

    #[test]
    fn failover_rehomes_each_lost_expert_onto_a_survivor() {
        // 8 experts over 4 workers (2 each); killing worker 1 must
        // replicate both of its experts onto distinct least-loaded
        // survivors (spread, not pile-up).
        let lp = LayerPlacement::balanced(0, 8, 4);
        let acts = Rebalancer::plan_failover(&lp, 1, &[false; 4]);
        assert_eq!(acts.len(), lp.experts_of[1].len());
        let mut targets: Vec<usize> = acts
            .iter()
            .map(|a| match *a {
                Action::Replicate { expert, to, .. } => {
                    assert!(lp.experts_of[1].contains(&expert));
                    assert_ne!(to, 1);
                    to
                }
                ref other => panic!("unexpected action {other:?}"),
            })
            .collect();
        targets.sort_unstable();
        targets.dedup();
        assert_eq!(targets.len(), acts.len(), "targets piled up");
    }

    #[test]
    fn failover_skips_experts_with_a_live_group0_copy() {
        let mut lp = LayerPlacement::balanced(0, 4, 4);
        assert!(lp.add_replica(1, 0)); // expert 1 already hosted on worker 0
        let acts = Rebalancer::plan_failover(&lp, 1, &[false; 4]);
        assert!(acts.is_empty(), "{acts:?}");
    }

    #[test]
    fn failover_rehomes_into_group0_even_with_a_dp_copy() {
        // dp=2: worker 5 holds the same experts as worker 1, but dispatch
        // only consults replica group 0 (workers 0..4) — the plan must
        // still create a group-0 copy, targeting the emptiest live
        // group-0 worker.
        let lp = LayerPlacement::balanced(0, 4, 8);
        let acts = Rebalancer::plan_failover(&lp, 1, &[false; 8]);
        assert_eq!(acts.len(), 1);
        let Action::Replicate { expert, to, .. } = acts[0] else {
            panic!("unexpected action {:?}", acts[0]);
        };
        assert_eq!(expert, 1);
        assert!(to < 4 && to != 1, "target {to} outside live group 0");
    }

    #[test]
    fn failover_skips_already_dead_targets() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        let mut dead = [false; 4];
        dead[0] = true;
        let acts = Rebalancer::plan_failover(&lp, 1, &dead);
        assert_eq!(acts.len(), 1);
        let Action::Replicate { to, .. } = acts[0] else {
            panic!("unexpected action {:?}", acts[0]);
        };
        assert!(to != 0 && to != 1, "targeted a dead worker: {to}");
    }
}
