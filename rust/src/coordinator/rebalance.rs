//! Online load-aware expert rebalancing: the measurement→placement
//! control loop.
//!
//! The paper's placement (§4.1.3) is balanced by *expert count*, but real
//! routing is skewed by *token count* — one hot expert serializes its
//! worker while the rest idle, and the whole pipeline ring waits on the
//! slowest exchange ("Who Says Elephants Can't Run" reports replicating
//! hot experts and rebalancing placement from observed load as the
//! production fix).  This module is the pure policy half of that loop: it
//! reads the per-layer EWMA load histogram
//! ([`crate::moe::ExpertLoadStats::recent_histogram`]) and proposes
//! placement [`Action`]s; the engine applies them between forwards —
//! shipping weights over the existing `fabric.load_expert` path and
//! bumping the placement epoch only at exchange boundaries, so no
//! in-flight tagged exchange ever observes a torn placement.
//!
//! The policy is deliberately incremental: at most one replication per
//! layer per call (weight shipping is the expensive step), plus any
//! number of de-replications of cooled experts (those are free — dropping
//! a host just stops splitting tokens to it; stale weights are harmless).

use crate::coordinator::placement::LayerPlacement;

/// One placement change proposed by [`Rebalancer::plan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Host `expert` on worker `to` as well (caller ships the weights).
    Replicate { layer: usize, expert: usize, to: usize },
    /// Stop hosting `expert` on worker `from` (no weight movement).
    Dereplicate { layer: usize, expert: usize, from: usize },
}

/// Load-aware replication policy.  Stateless between calls — all memory
/// lives in the EWMA histogram and the placement itself.
#[derive(Debug, Clone, Copy)]
pub struct Rebalancer {
    /// Recent max/mean skew ratio that triggers replication
    /// (`DSMOE_REBALANCE_SKEW`; 1.0 is perfectly balanced).
    pub skew_threshold: f64,
    /// Replication ceiling per expert (`DSMOE_MAX_REPLICAS`).
    pub max_replicas: usize,
}

impl Rebalancer {
    /// Recent per-worker load under the split-dispatch model: each hosted
    /// expert contributes its EWMA load divided by its replication (the
    /// gate splits a replicated expert's block evenly across hosts).
    fn worker_load(lp: &LayerPlacement, recent: &[f64], w: usize) -> f64 {
        lp.experts_of[w]
            .iter()
            .map(|&e| recent[e] / lp.replication(e) as f64)
            .sum()
    }

    /// The workers a balanced placement gives expert `e` (one per replica
    /// group) — the copies migration must never remove.
    fn home_set(lp: &LayerPlacement, e: usize) -> Vec<usize> {
        (0..lp.dp_degree).map(|r| r * lp.ep_degree + e % lp.ep_degree).collect()
    }

    /// Propose placement changes for one layer from its recent load view.
    /// Replicates the hottest expert onto the least-loaded non-hosting
    /// worker when skew crosses the threshold; de-replicates extra copies
    /// of experts that have cooled to (or below) the mean.
    pub fn plan(&self, lp: &LayerPlacement, recent: &[f64]) -> Vec<Action> {
        assert_eq!(recent.len(), lp.n_experts);
        let workers = lp.experts_of.len();
        let mean = recent.iter().sum::<f64>() / lp.n_experts as f64;
        if mean <= 0.0 {
            return Vec::new();
        }
        let mut actions = Vec::new();

        // Cool-down first: extra replicas (beyond the balanced home set)
        // of experts at or below the mean stop earning their dispatch
        // split — release them so the host's capacity goes back to its
        // own experts.
        for e in 0..lp.n_experts {
            if recent[e] > mean {
                continue;
            }
            let homes = Self::home_set(lp, e);
            for w in lp.replicas_of(e) {
                if !homes.contains(&w) {
                    actions.push(Action::Dereplicate {
                        layer: lp.layer,
                        expert: e,
                        from: w,
                    });
                }
            }
        }

        // Heat-up: one replication per call, hottest expert first.
        let hot = (0..lp.n_experts)
            .max_by(|&a, &b| recent[a].total_cmp(&recent[b]))
            .unwrap();
        let skew = recent[hot] / mean;
        if skew >= self.skew_threshold
            && lp.replication(hot) < self.max_replicas
        {
            let target = (0..workers)
                .filter(|&w| !lp.experts_of[w].contains(&hot))
                .min_by(|&a, &b| {
                    Self::worker_load(lp, recent, a)
                        .total_cmp(&Self::worker_load(lp, recent, b))
                });
            if let Some(to) = target {
                actions.push(Action::Replicate {
                    layer: lp.layer,
                    expert: hot,
                    to,
                });
            }
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Rebalancer {
        Rebalancer { skew_threshold: 2.0, max_replicas: 4 }
    }

    #[test]
    fn balanced_load_plans_nothing() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        let acts = policy().plan(&lp, &[1.0, 1.0, 1.0, 1.0]);
        assert!(acts.is_empty(), "{acts:?}");
    }

    #[test]
    fn zero_load_plans_nothing() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        assert!(policy().plan(&lp, &[0.0; 4]).is_empty());
    }

    #[test]
    fn hot_expert_replicates_onto_least_loaded_worker() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        // Expert 0 is hot (skew 8/ (11/4) ≈ 2.9); worker 2 is coolest.
        let recent = [8.0, 1.0, 0.5, 1.5];
        let acts = policy().plan(&lp, &recent);
        assert_eq!(
            acts,
            vec![Action::Replicate { layer: 0, expert: 0, to: 2 }]
        );
    }

    #[test]
    fn below_threshold_does_not_replicate() {
        let lp = LayerPlacement::balanced(0, 4, 4);
        // max/mean = 1.6 < 2.0
        assert!(policy().plan(&lp, &[2.0, 1.0, 1.0, 1.0]).is_empty());
    }

    #[test]
    fn replication_respects_the_ceiling() {
        let mut lp = LayerPlacement::balanced(0, 4, 4);
        assert!(lp.add_replica(0, 1));
        let p = Rebalancer { skew_threshold: 2.0, max_replicas: 2 };
        // Expert 0 is still hottest but already at the ceiling.
        assert!(p.plan(&lp, &[8.0, 1.0, 0.5, 1.5]).is_empty());
    }

    #[test]
    fn cooled_extra_replica_is_released_but_homes_are_kept() {
        let mut lp = LayerPlacement::balanced(0, 4, 8); // dp=2: homes at w and w+4
        assert!(lp.add_replica(0, 1)); // extra replica from an earlier hot phase
        // Expert 0 cooled to the mean: the extra copy goes, both balanced
        // homes (workers 0 and 4) stay.
        let acts = policy().plan(&lp, &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(
            acts,
            vec![Action::Dereplicate { layer: 0, expert: 0, from: 1 }]
        );
    }

    #[test]
    fn hot_expert_keeps_its_extra_replica() {
        let mut lp = LayerPlacement::balanced(0, 4, 4);
        assert!(lp.add_replica(0, 1));
        let p = Rebalancer { skew_threshold: 2.0, max_replicas: 2 };
        // Still hot: no dereplicate, and the ceiling blocks growth.
        assert!(p.plan(&lp, &[8.0, 1.0, 0.5, 1.5]).is_empty());
    }
}
