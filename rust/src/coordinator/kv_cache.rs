//! KV-cache manager: lane-granular cache state for continuous batching.
//!
//! The monolithic decode program operates on a fixed-lane group
//! (`[L, B, H, Smax, hd]` caches, per-lane positions).  This manager owns
//! those host-side tensors, tracks which lanes are live, and splices a
//! freshly prefilled single-request cache (`[L, 1, H, Smax, hd]`) into a free
//! lane — which is how new requests join an in-flight decode group without
//! recomputing the others (iteration-level batching at the decode loop).

use anyhow::{bail, Result};

use crate::runtime::HostTensor;

/// Identity of a request occupying a lane.
pub type RequestId = u64;

#[derive(Debug, Clone, PartialEq)]
pub enum Lane {
    Free,
    /// (request, current length = next write position)
    Busy { request: RequestId, pos: usize },
}

/// Cache group for one decode batch.
#[derive(Debug)]
pub struct KvCacheGroup {
    pub n_layers: usize,
    pub batch: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub k: HostTensor,
    pub v: HostTensor,
    pub lanes: Vec<Lane>,
}

impl KvCacheGroup {
    pub fn new(
        n_layers: usize,
        batch: usize,
        n_heads: usize,
        max_seq: usize,
        head_dim: usize,
    ) -> Self {
        let shape = [n_layers, batch, n_heads, max_seq, head_dim];
        KvCacheGroup {
            n_layers,
            batch,
            n_heads,
            max_seq,
            head_dim,
            k: HostTensor::zeros_f32(&shape),
            v: HostTensor::zeros_f32(&shape),
            lanes: vec![Lane::Free; batch],
        }
    }

    pub fn free_lanes(&self) -> Vec<usize> {
        self.lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| matches!(l, Lane::Free))
            .map(|(i, _)| i)
            .collect()
    }

    pub fn busy_lanes(&self) -> Vec<(usize, RequestId, usize)> {
        self.lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| match l {
                Lane::Busy { request, pos } => Some((i, *request, *pos)),
                Lane::Free => None,
            })
            .collect()
    }

    pub fn is_idle(&self) -> bool {
        self.lanes.iter().all(|l| matches!(l, Lane::Free))
    }

    /// Splice a prefilled single-lane cache (`[L, 1, H, Smax, hd]`) into
    /// `lane`, marking it busy at `pos` (= prompt length).
    pub fn admit(
        &mut self,
        lane: usize,
        request: RequestId,
        pos: usize,
        k1: &HostTensor,
        v1: &HostTensor,
    ) -> Result<()> {
        if lane >= self.batch {
            bail!("lane {lane} out of range (batch {})", self.batch);
        }
        if !matches!(self.lanes[lane], Lane::Free) {
            bail!("lane {lane} is busy");
        }
        let want = [self.n_layers, 1, self.n_heads, self.max_seq, self.head_dim];
        if k1.shape != want || v1.shape != want {
            bail!("prefill cache shape {:?}, want {:?}", k1.shape, want);
        }
        if pos > self.max_seq {
            bail!("pos {pos} exceeds max_seq {}", self.max_seq);
        }
        self.splice(lane, k1, v1)?;
        self.lanes[lane] = Lane::Busy { request, pos };
        Ok(())
    }

    /// Splice lane `lane` directly out of a **batched** prefill output
    /// (`[L, src_batch, H, Smax, hd]` flat buffers, source lane
    /// `src_lane`) — the zero-copy admit path: no intermediate
    /// `[L, 1, H, Smax, hd]` per-request tensors are materialized.
    #[allow(clippy::too_many_arguments)]
    pub fn admit_from_batch(
        &mut self,
        lane: usize,
        request: RequestId,
        pos: usize,
        kc: &[f32],
        vc: &[f32],
        src_lane: usize,
        src_batch: usize,
    ) -> Result<()> {
        if lane >= self.batch {
            bail!("lane {lane} out of range (batch {})", self.batch);
        }
        if !matches!(self.lanes[lane], Lane::Free) {
            bail!("lane {lane} is busy");
        }
        if pos > self.max_seq {
            bail!("pos {pos} exceeds max_seq {}", self.max_seq);
        }
        if src_lane >= src_batch {
            bail!("source lane {src_lane} out of batch {src_batch}");
        }
        let lane_elems = self.n_heads * self.max_seq * self.head_dim;
        let want = self.n_layers * src_batch * lane_elems;
        if kc.len() != want || vc.len() != want {
            bail!(
                "batched prefill cache has k={} / v={} elems, want {want} \
                 (L={} x B={src_batch} x {lane_elems})",
                kc.len(), vc.len(), self.n_layers
            );
        }
        let batch = self.batch;
        for (dst_all, src_all) in [(&mut self.k, kc), (&mut self.v, vc)] {
            let dst = dst_all.as_f32_mut()?;
            for layer in 0..self.n_layers {
                let src_off = (layer * src_batch + src_lane) * lane_elems;
                let dst_off = (layer * batch + lane) * lane_elems;
                dst[dst_off..dst_off + lane_elems]
                    .copy_from_slice(&src_all[src_off..src_off + lane_elems]);
            }
        }
        self.lanes[lane] = Lane::Busy { request, pos };
        Ok(())
    }

    fn splice(&mut self, lane: usize, k1: &HostTensor, v1: &HostTensor) -> Result<()> {
        let lane_elems = self.n_heads * self.max_seq * self.head_dim;
        let batch = self.batch;
        for (dst_all, src_all) in
            [(&mut self.k, k1), (&mut self.v, v1)]
        {
            let src = src_all.as_f32()?.to_vec();
            let dst = dst_all.as_f32_mut()?;
            for layer in 0..self.n_layers {
                let src_off = layer * lane_elems;
                let dst_off = (layer * batch + lane) * lane_elems;
                dst[dst_off..dst_off + lane_elems]
                    .copy_from_slice(&src[src_off..src_off + lane_elems]);
            }
        }
        Ok(())
    }

    /// Advance a lane after a decode step (one more token in the cache).
    pub fn advance(&mut self, lane: usize) -> Result<usize> {
        match &mut self.lanes[lane] {
            Lane::Busy { pos, .. } => {
                *pos += 1;
                if *pos >= self.max_seq {
                    bail!("lane {lane} hit max_seq {}", self.max_seq);
                }
                Ok(*pos)
            }
            Lane::Free => bail!("advancing free lane {lane}"),
        }
    }

    /// Release a finished request's lane.
    pub fn release(&mut self, lane: usize) {
        self.lanes[lane] = Lane::Free;
    }

    /// Positions vector for the decode program: busy lanes their real pos,
    /// free lanes 0 (their one-hot writes land on slot 0 of an unused lane
    /// and are overwritten by the next admit's splice).
    pub fn positions(&self) -> Vec<i32> {
        self.lanes
            .iter()
            .map(|l| match l {
                Lane::Busy { pos, .. } => *pos as i32,
                Lane::Free => 0,
            })
            .collect()
    }

    /// Replace the whole group state with updated caches from a decode step.
    pub fn update(&mut self, k: HostTensor, v: HostTensor) -> Result<()> {
        if k.shape != self.k.shape || v.shape != self.v.shape {
            bail!("cache update shape mismatch");
        }
        self.k = k;
        self.v = v;
        Ok(())
    }

    pub fn cache_bytes(&self) -> usize {
        self.k.byte_len() + self.v.byte_len()
    }
}

/// Split a lane-major flat buffer (`lane_elems` contiguous elements per
/// lane, e.g. one layer's `[B, H, Smax, hd]` cache) into per-group chunks
/// given as `(lane0, lanes)` ranges.  Because the lane axis is outermost,
/// each group is a single contiguous copy — this is how the expert-parallel
/// engine repartitions its decode caches between the full-batch and the
/// per-microbatch lane layouts.
pub fn split_lanes(
    buf: &[f32],
    lane_elems: usize,
    groups: &[(usize, usize)],
) -> Vec<Vec<f32>> {
    groups
        .iter()
        .map(|&(lane0, lanes)| {
            buf[lane0 * lane_elems..(lane0 + lanes) * lane_elems].to_vec()
        })
        .collect()
}

/// Copy one lane's contiguous block between two lane-major flat buffers
/// (e.g. one layer's `[lanes, H, Smax, hd]` cache).  This is the splice
/// primitive the expert-parallel engine uses to admit a freshly prefilled
/// request's KV into a free lane of a decode group.
pub fn copy_lane(
    dst: &mut [f32],
    dst_lane: usize,
    src: &[f32],
    src_lane: usize,
    lane_elems: usize,
) {
    dst[dst_lane * lane_elems..(dst_lane + 1) * lane_elems].copy_from_slice(
        &src[src_lane * lane_elems..(src_lane + 1) * lane_elems],
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group() -> KvCacheGroup {
        KvCacheGroup::new(2, 4, 2, 8, 4)
    }

    fn lane_cache(fill: f32) -> HostTensor {
        let shape = [2, 1, 2, 8, 4];
        HostTensor::f32(&shape, vec![fill; shape.iter().product()])
    }

    #[test]
    fn admit_and_release_lifecycle() {
        let mut g = group();
        assert_eq!(g.free_lanes(), vec![0, 1, 2, 3]);
        g.admit(1, 100, 5, &lane_cache(1.0), &lane_cache(2.0)).unwrap();
        assert_eq!(g.free_lanes(), vec![0, 2, 3]);
        assert_eq!(g.busy_lanes(), vec![(1, 100, 5)]);
        assert_eq!(g.positions(), vec![0, 5, 0, 0]);
        assert_eq!(g.advance(1).unwrap(), 6);
        g.release(1);
        assert!(g.is_idle());
    }

    #[test]
    fn splice_writes_only_target_lane() {
        let mut g = group();
        g.admit(2, 7, 3, &lane_cache(9.0), &lane_cache(9.0)).unwrap();
        let k = g.k.as_f32().unwrap();
        let lane_elems = 2 * 8 * 4;
        for layer in 0..2 {
            for lane in 0..4 {
                let off = (layer * 4 + lane) * lane_elems;
                let want = if lane == 2 { 9.0 } else { 0.0 };
                assert!(
                    k[off..off + lane_elems].iter().all(|&x| x == want),
                    "layer {layer} lane {lane}"
                );
            }
        }
    }

    #[test]
    fn admit_guards() {
        let mut g = group();
        g.admit(0, 1, 2, &lane_cache(0.0), &lane_cache(0.0)).unwrap();
        // busy lane
        assert!(g.admit(0, 2, 2, &lane_cache(0.0), &lane_cache(0.0)).is_err());
        // bad shape
        let bad = HostTensor::zeros_f32(&[2, 1, 2, 4, 4]);
        assert!(g.admit(1, 3, 2, &bad, &bad).is_err());
        // out-of-range lane / pos
        assert!(g.admit(9, 4, 2, &lane_cache(0.0), &lane_cache(0.0)).is_err());
        assert!(g.admit(1, 5, 99, &lane_cache(0.0), &lane_cache(0.0)).is_err());
    }

    #[test]
    fn admit_from_batch_matches_admit() {
        // A fake batched prefill output: 2 layers, source batch 3, each
        // element tagged by (layer, src_lane) so slices are identifiable.
        let (l, src_b, h, s, hd) = (2usize, 3usize, 2usize, 8usize, 4usize);
        let lane_elems = h * s * hd;
        let mut kc = vec![0f32; l * src_b * lane_elems];
        for layer in 0..l {
            for lane in 0..src_b {
                let off = (layer * src_b + lane) * lane_elems;
                for x in &mut kc[off..off + lane_elems] {
                    *x = (layer * 10 + lane) as f32;
                }
            }
        }
        let vc: Vec<f32> = kc.iter().map(|x| x + 100.0).collect();

        // Reference path: extract [L,1,H,S,hd] slices, then admit().
        let mut reference = group();
        let extract = |src: &[f32], src_lane: usize| {
            let mut one = vec![0f32; l * lane_elems];
            for layer in 0..l {
                let s_off = (layer * src_b + src_lane) * lane_elems;
                one[layer * lane_elems..(layer + 1) * lane_elems]
                    .copy_from_slice(&src[s_off..s_off + lane_elems]);
            }
            HostTensor::f32(&[l, 1, h, s, hd], one)
        };
        reference
            .admit(3, 42, 5, &extract(&kc, 1), &extract(&vc, 1))
            .unwrap();

        // Zero-copy path: splice straight from the batched buffers.
        let mut direct = group();
        direct.admit_from_batch(3, 42, 5, &kc, &vc, 1, src_b).unwrap();
        assert_eq!(direct.k, reference.k);
        assert_eq!(direct.v, reference.v);
        assert_eq!(direct.busy_lanes(), reference.busy_lanes());
    }

    #[test]
    fn admit_from_batch_guards() {
        let mut g = group();
        let lane_elems = 2 * 8 * 4;
        let ok = vec![0f32; 2 * 2 * lane_elems];
        // src_lane out of src_batch
        assert!(g.admit_from_batch(0, 1, 2, &ok, &ok, 2, 2).is_err());
        // wrong buffer size
        let short = vec![0f32; 3];
        assert!(g.admit_from_batch(0, 1, 2, &short, &ok, 0, 2).is_err());
        // busy lane
        g.admit_from_batch(0, 1, 2, &ok, &ok, 0, 2).unwrap();
        assert!(g.admit_from_batch(0, 2, 2, &ok, &ok, 1, 2).is_err());
    }

    #[test]
    fn split_lanes_partitions_lane_major_buffers() {
        // 4 lanes x 3 elems, each lane tagged by its index.
        let lane_elems = 3;
        let buf: Vec<f32> = (0..4)
            .flat_map(|lane| vec![lane as f32; lane_elems])
            .collect();
        let halves = split_lanes(&buf, lane_elems, &[(0, 2), (2, 2)]);
        assert_eq!(halves[0], vec![0., 0., 0., 1., 1., 1.]);
        assert_eq!(halves[1], vec![2., 2., 2., 3., 3., 3.]);
        // Merging the halves back is plain concatenation (lane-major), and
        // a full-range "split" is the identity.
        let mut merged = halves[0].clone();
        merged.extend_from_slice(&halves[1]);
        assert_eq!(merged, buf);
        let full = split_lanes(&buf, lane_elems, &[(0, 4)]);
        assert_eq!(full[0], buf);
    }

    #[test]
    fn copy_lane_moves_one_block() {
        let lane_elems = 3;
        let src: Vec<f32> = (0..9).map(|x| x as f32).collect(); // 3 lanes
        let mut dst = vec![0f32; 12]; // 4 lanes
        copy_lane(&mut dst, 2, &src, 1, lane_elems);
        assert_eq!(dst, vec![0., 0., 0., 0., 0., 0., 3., 4., 5., 0., 0., 0.]);
        // Other lanes untouched by a second copy.
        copy_lane(&mut dst, 0, &src, 2, lane_elems);
        assert_eq!(dst[..3], [6., 7., 8.]);
        assert_eq!(dst[6..9], [3., 4., 5.]);
    }

    #[test]
    fn advance_overflow_detected() {
        let mut g = group();
        g.admit(0, 1, 6, &lane_cache(0.0), &lane_cache(0.0)).unwrap();
        assert_eq!(g.advance(0).unwrap(), 7);
        assert!(g.advance(0).is_err()); // 8 == max_seq
        assert!(g.advance(1).is_err()); // free lane
    }

    #[test]
    fn property_splice_preserves_other_lanes() {
        use crate::util::prop::prop;
        prop(40, |c| {
            let lanes = c.usize(1, 6);
            let mut g = KvCacheGroup::new(2, lanes, 2, 4, 2);
            let mk = |f: f32| {
                let shape = [2, 1, 2, 4, 2];
                HostTensor::f32(&shape, vec![f; shape.iter().product()])
            };
            let a = c.usize(0, lanes - 1);
            g.admit(a, 1, 1, &mk(1.0), &mk(1.0)).map_err(|e| e.to_string())?;
            let before = g.k.as_f32().unwrap().to_vec();
            let b = c.usize(0, lanes - 1);
            if b != a {
                g.admit(b, 2, 1, &mk(2.0), &mk(2.0))
                    .map_err(|e| e.to_string())?;
                let after = g.k.as_f32().unwrap();
                let lane_elems = 2 * 4 * 2;
                for layer in 0..2 {
                    let off = (layer * lanes + a) * lane_elems;
                    crate::prop_assert!(
                        after[off..off + lane_elems]
                            == before[off..off + lane_elems],
                        "lane {a} disturbed by admit into {b}"
                    );
                }
            }
            Ok(())
        });
    }
}
