//! Host-side gating for the expert-parallel serving path.
//!
//! The AOT `gate_*` program returns softmax router probabilities; the
//! coordinator turns them into the paper's **dense token-to-expert mapping
//! table** (§5.4) — `(expert, slot)` per token — because the routing
//! decision is what drives token grouping and the all-to-all (§5.1: "group
//! and route all tokens with the same critical data path together").
//!
//! This mirrors the L1 Pallas gating kernel exactly (same assignment, same
//! slot ordering); `python/tests/test_gating.py` pins the kernel to the
//! reference and `rust/tests/integration_parity.rs` pins this host version
//! to the kernel through the end-to-end logits comparison.

use crate::moe;
use crate::runtime::{Dtype, HostTensor};

/// Sentinel expert id for a token masked out of routing (a dead decode
/// lane or prefill padding): it gets no expert, no slot, and no dispatch —
/// a dead lane must send no expert traffic.
pub const MASKED: usize = usize::MAX;

/// Routing decision for a token batch at one MoE layer.
#[derive(Debug, Clone)]
pub struct Routing {
    pub n_experts: usize,
    /// Per token: selected expert ([`MASKED`] = not routed).
    pub expert: Vec<usize>,
    /// Per token: gate probability of the selected expert.
    pub prob: Vec<f32>,
    /// Per token: slot within the expert's block (dense mapping table).
    pub slot: Vec<usize>,
    /// Tokens routed to each expert (= block sizes before padding).
    pub counts: Vec<usize>,
}

impl Routing {
    /// Build the mapping table from gate probabilities (`[T, E]` row-major).
    ///
    /// Inference never drops tokens (worst-case capacity), so every token
    /// gets a slot; `counts[e]` tells the dispatcher how large each expert's
    /// block really is before padding to a compiled size.
    pub fn top1(probs: &[f32], n_experts: usize) -> Routing {
        Self::top1_masked(probs, n_experts, None)
    }

    /// [`Routing::top1`] with an optional per-token liveness mask: tokens
    /// with `mask[t] == false` are assigned [`MASKED`] — they take no slot,
    /// count toward no expert, and are skipped by pack/combine — so free
    /// decode lanes and prefill padding generate no expert traffic.  Live
    /// tokens route exactly as in the unmasked case (per-token top-1 is
    /// independent across tokens), which keeps the continuous-batching
    /// path bit-identical to the fixed-lane path for live lanes.
    pub fn top1_masked(
        probs: &[f32],
        n_experts: usize,
        mask: Option<&[bool]>,
    ) -> Routing {
        let routed = moe::top1_route(probs, n_experts);
        if let Some(mask) = mask {
            assert_eq!(routed.len(), mask.len(), "mask length != token count");
        }
        let t = routed.len();
        let mut expert = Vec::with_capacity(t);
        let mut prob = Vec::with_capacity(t);
        let mut slot = Vec::with_capacity(t);
        let mut counts = vec![0usize; n_experts];
        for (tok, (e, p)) in routed.into_iter().enumerate() {
            if mask.is_some_and(|m| !m[tok]) {
                expert.push(MASKED);
                prob.push(0.0);
                slot.push(0);
                continue;
            }
            expert.push(e);
            prob.push(p);
            slot.push(counts[e]); // exclusive running count = queue position
            counts[e] += 1;
        }
        Routing { n_experts, expert, prob, slot, counts }
    }

    /// [`Routing::top1_masked`] with the argmax overridden: every live
    /// token routes to expert `pin`, scaled by its own gate probability
    /// for that expert — a deterministic worst-case hot-expert workload
    /// for the replication study (the forward stays self-consistent:
    /// pack, FFN, and combine all agree on the pinned assignment).
    pub fn pinned_masked(
        probs: &[f32],
        n_experts: usize,
        mask: Option<&[bool]>,
        pin: usize,
    ) -> Routing {
        assert!(pin < n_experts, "pinned expert out of range");
        assert_eq!(probs.len() % n_experts, 0);
        let t = probs.len() / n_experts;
        if let Some(mask) = mask {
            assert_eq!(t, mask.len(), "mask length != token count");
        }
        let mut expert = Vec::with_capacity(t);
        let mut prob = Vec::with_capacity(t);
        let mut slot = Vec::with_capacity(t);
        let mut counts = vec![0usize; n_experts];
        for tok in 0..t {
            if mask.is_some_and(|m| !m[tok]) {
                expert.push(MASKED);
                prob.push(0.0);
                slot.push(0);
                continue;
            }
            expert.push(pin);
            prob.push(probs[tok * n_experts + pin]);
            slot.push(counts[pin]);
            counts[pin] += 1;
        }
        Routing { n_experts, expert, prob, slot, counts }
    }

    pub fn n_tokens(&self) -> usize {
        self.expert.len()
    }

    /// Gather each expert's token rows from flat activations `[T, M]` into
    /// a dense block `[counts[e], M]` (the scatter data-layout transform of
    /// §5.4, done host-side because blocks cross worker boundaries here).
    pub fn expert_block(&self, ln_h: &[f32], m: usize, e: usize) -> Vec<f32> {
        let mut block = vec![0f32; self.counts[e] * m];
        for (t, &te) in self.expert.iter().enumerate() {
            if te == e {
                let s = self.slot[t];
                block[s * m..(s + 1) * m]
                    .copy_from_slice(&ln_h[t * m..(t + 1) * m]);
            }
        }
        block
    }

    /// Inverse transform: scale expert outputs by gate prob and write them
    /// back in original token order (the gather/un-sort of §5.4).
    /// `expert_outputs[e]` is the unpadded `[counts[e], M]` block.
    pub fn combine(&self, expert_outputs: &[Vec<f32>], m: usize) -> Vec<f32> {
        let t = self.n_tokens();
        let mut out = vec![0f32; t * m];
        for tok in 0..t {
            let e = self.expert[tok];
            if e == MASKED {
                continue; // dead lane: zero expert contribution
            }
            let s = self.slot[tok];
            let block = &expert_outputs[e];
            debug_assert!(s * m + m <= block.len());
            let p = self.prob[tok];
            for (o, &x) in out[tok * m..(tok + 1) * m]
                .iter_mut()
                .zip(&block[s * m..(s + 1) * m])
            {
                *o = p * x;
            }
        }
        out
    }

    /// Pack several experts' blocks back to back (each in slot order) into
    /// `out` — the coalesced per-worker payload of the overlapped EP path.
    /// The result is exactly the concatenation of
    /// [`Routing::expert_block`]`(ln_h, m, e)` for each `e` in `experts`,
    /// built in a single pass over the tokens.  `out` is cleared and
    /// resized, so callers can reuse one buffer across layers.
    pub fn pack_blocks(
        &self,
        ln_h: &[f32],
        m: usize,
        experts: &[usize],
        out: &mut Vec<f32>,
    ) {
        let total: usize = experts.iter().map(|&e| self.counts[e]).sum();
        out.clear();
        out.resize(total * m, 0.0);
        // Row base of each packed expert; usize::MAX = not in this pack.
        let mut base = vec![usize::MAX; self.n_experts];
        let mut acc = 0usize;
        for &e in experts {
            base[e] = acc;
            acc += self.counts[e];
        }
        for (t, &te) in self.expert.iter().enumerate() {
            if te != MASKED && base[te] != usize::MAX {
                let row = base[te] + self.slot[t];
                out[row * m..(row + 1) * m]
                    .copy_from_slice(&ln_h[t * m..(t + 1) * m]);
            }
        }
    }

    /// Pack slot **segments** of several experts' blocks back to back into
    /// `out` — the replica-aware generalization of [`Routing::pack_blocks`].
    /// Each `(expert, slot0, rows)` segment carries the tokens of `expert`
    /// whose slot lies in `[slot0, slot0 + rows)`, placed at
    /// `base + (slot - slot0)` within the segment.  A full-block segment
    /// `(e, 0, counts[e])` packs exactly what [`Routing::pack_blocks`]
    /// packs for `e`; hot-expert replication splits a block into
    /// contiguous slot ranges, one per replica worker.  `out` is cleared
    /// and resized, so callers can reuse one buffer across layers.
    pub fn pack_segments(
        &self,
        ln_h: &[f32],
        m: usize,
        segs: &[(usize, usize, usize)],
        out: &mut Vec<f32>,
    ) {
        let total: usize = segs.iter().map(|&(_, _, rows)| rows).sum();
        out.clear();
        out.resize(total * m, 0.0);
        // Per-expert slot windows: (slot_lo, slot_end, packed row base).
        let mut windows: Vec<Vec<(usize, usize, usize)>> =
            vec![Vec::new(); self.n_experts];
        let mut acc = 0usize;
        for &(e, slot0, rows) in segs {
            windows[e].push((slot0, slot0 + rows, acc));
            acc += rows;
        }
        for (t, &te) in self.expert.iter().enumerate() {
            if te == MASKED || windows[te].is_empty() {
                continue;
            }
            let s = self.slot[t];
            for &(lo, hi, base) in &windows[te] {
                if s >= lo && s < hi {
                    let row = base + (s - lo);
                    out[row * m..(row + 1) * m]
                        .copy_from_slice(&ln_h[t * m..(t + 1) * m]);
                    break;
                }
            }
        }
    }

    /// Inverse of [`Routing::pack_segments`] over coalesced worker replies:
    /// gate-scale each token's expert output and write it back in original
    /// token order (bitwise-identical to [`Routing::combine`] over the
    /// equivalent per-expert blocks — replica outputs are the same weights
    /// applied to the same rows).  `packs` are
    /// `(segments, packed rows)` pairs as returned by the workers, each
    /// segment a `(expert, slot0, rows)` slot range; `out` is cleared and
    /// resized to `[T * m]`.  Every routed `(expert, slot)` must be covered
    /// by exactly one segment — a missing one means a lost or truncated
    /// worker reply, which is an error, never a silent zero contribution.
    pub fn combine_packed(
        &self,
        packs: &[(&[(usize, usize, usize)], &[f32])],
        m: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let t = self.n_tokens();
        out.clear();
        out.resize(t * m, 0.0);
        // Per-expert reply segments: (slot_lo, slot_end, pack idx, base).
        let mut windows: Vec<Vec<(usize, usize, usize, usize)>> =
            vec![Vec::new(); self.n_experts];
        for (pi, (segs, _)) in packs.iter().enumerate() {
            let mut acc = 0usize;
            for &(e, slot0, rows) in segs.iter() {
                windows[e].push((slot0, slot0 + rows, pi, acc));
                acc += rows;
            }
        }
        for tok in 0..t {
            let e = self.expert[tok];
            if e == MASKED {
                continue; // dead lane: stays zero in the combine buffer
            }
            let s = self.slot[tok];
            let seg = windows[e]
                .iter()
                .find(|&&(lo, hi, _, _)| s >= lo && s < hi);
            let Some(&(lo, _, pi, base)) = seg else {
                anyhow::bail!(
                    "expert {e} slot {s} has a routed token but no \
                     covering block in any worker reply"
                );
            };
            let rows = packs[pi].1;
            let row = base + (s - lo);
            let p = self.prob[tok];
            for (o, &x) in out[tok * m..(tok + 1) * m]
                .iter_mut()
                .zip(&rows[row * m..(row + 1) * m])
            {
                *o = p * x;
            }
        }
        Ok(())
    }

    /// [`Routing::pack_segments`] straight into a dispatch payload in the
    /// requested wire dtype (`DSMOE_WIRE_DTYPE`).  `Dtype::F32` wraps the
    /// exact `pack_segments` rows — same bits, no conversion — so the
    /// default wire stays bitwise identical to the uncompressed path;
    /// f16/bf16 narrow the packed rows once here, at the dispatch seam,
    /// halving the payload that crosses the fabric.
    pub fn pack_segments_wire(
        &self,
        ln_h: &[f32],
        m: usize,
        segs: &[(usize, usize, usize)],
        wire: Dtype,
    ) -> anyhow::Result<HostTensor> {
        let mut buf = Vec::new();
        self.pack_segments(ln_h, m, segs, &mut buf);
        let total = buf.len() / m;
        let t = HostTensor::f32(&[total, m], buf);
        if wire == Dtype::F32 { Ok(t) } else { t.convert(wire) }
    }

    /// [`Routing::combine_packed`] over worker replies that may travel in a
    /// compressed wire dtype: f16/bf16 packs are widened to f32 once, f32
    /// packs are borrowed as-is — so with the wire toggle off this is the
    /// same arithmetic on the same bits as `combine_packed`.
    pub fn combine_packed_wire(
        &self,
        packs: &[(&[(usize, usize, usize)], &HostTensor)],
        m: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let mut widened: Vec<Option<Vec<f32>>> = Vec::with_capacity(packs.len());
        for (_, t) in packs {
            widened.push(match t.dtype() {
                Dtype::F32 => None,
                _ => Some(t.to_f32_vec()?),
            });
        }
        let borrowed: Vec<(&[(usize, usize, usize)], &[f32])> = packs
            .iter()
            .zip(&widened)
            .map(|((segs, t), w)| {
                Ok((
                    *segs,
                    match w {
                        Some(v) => v.as_slice(),
                        None => t.as_f32()?,
                    },
                ))
            })
            .collect::<anyhow::Result<_>>()?;
        self.combine_packed(&borrowed, m, out)
    }

    /// Tokens per expert as expert ids (for load stats).
    pub fn assignments(&self) -> &[usize] {
        &self.expert
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;
    use crate::util::rng::Rng;

    fn softmax_rows(t: usize, e: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut probs = vec![0f32; t * e];
        for row in probs.chunks_exact_mut(e) {
            let mut sum = 0f32;
            for v in row.iter_mut() {
                *v = (rng.gauss() as f32).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        probs
    }

    #[test]
    fn slots_are_dense_per_expert() {
        let probs = softmax_rows(32, 4, 7);
        let r = Routing::top1(&probs, 4);
        for e in 0..4 {
            let mut slots: Vec<usize> = (0..32)
                .filter(|&t| r.expert[t] == e)
                .map(|t| r.slot[t])
                .collect();
            slots.sort();
            assert_eq!(slots, (0..r.counts[e]).collect::<Vec<_>>());
        }
        assert_eq!(r.counts.iter().sum::<usize>(), 32);
    }

    #[test]
    fn scatter_combine_roundtrip() {
        // identity experts: combine(scatter(x)) == prob * x
        let t_toks = 16;
        let m = 8;
        let probs = softmax_rows(t_toks, 4, 3);
        let r = Routing::top1(&probs, 4);
        let mut rng = Rng::new(5);
        let ln_h: Vec<f32> = (0..t_toks * m).map(|_| rng.gauss() as f32).collect();
        let blocks: Vec<Vec<f32>> =
            (0..4).map(|e| r.expert_block(&ln_h, m, e)).collect();
        let out = r.combine(&blocks, m);
        for tok in 0..t_toks {
            for i in 0..m {
                let want = r.prob[tok] * ln_h[tok * m + i];
                assert!((out[tok * m + i] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn pack_blocks_concatenates_expert_blocks() {
        let t_toks = 20;
        let m = 4;
        let probs = softmax_rows(t_toks, 5, 13);
        let r = Routing::top1(&probs, 5);
        let mut rng = Rng::new(17);
        let ln_h: Vec<f32> =
            (0..t_toks * m).map(|_| rng.gauss() as f32).collect();
        let mut buf = Vec::new();
        r.pack_blocks(&ln_h, m, &[1, 3], &mut buf);
        let want: Vec<f32> = r
            .expert_block(&ln_h, m, 1)
            .into_iter()
            .chain(r.expert_block(&ln_h, m, 3))
            .collect();
        assert_eq!(buf, want);
        // buffer reuse: a second pack overwrites, not appends
        r.pack_blocks(&ln_h, m, &[0], &mut buf);
        assert_eq!(buf, r.expert_block(&ln_h, m, 0));
    }

    #[test]
    fn combine_packed_matches_per_expert_combine() {
        let t_toks = 24;
        let m = 4;
        let n_e = 6;
        let probs = softmax_rows(t_toks, n_e, 11);
        let r = Routing::top1(&probs, n_e);
        let mut rng = Rng::new(9);
        let ln_h: Vec<f32> =
            (0..t_toks * m).map(|_| rng.gauss() as f32).collect();
        // Two "workers" owning interleaved experts; identity expert FFNs
        // mean the packed reply equals the packed request.
        let groups = [vec![0usize, 2, 4], vec![1, 3, 5]];
        let mut packs_data = Vec::new();
        for g in &groups {
            let mut buf = Vec::new();
            r.pack_blocks(&ln_h, m, g, &mut buf);
            let counts: Vec<(usize, usize, usize)> =
                g.iter().map(|&e| (e, 0, r.counts[e])).collect();
            packs_data.push((counts, buf));
        }
        let packs: Vec<(&[(usize, usize, usize)], &[f32])> = packs_data
            .iter()
            .map(|(c, d)| (c.as_slice(), d.as_slice()))
            .collect();
        let mut out = Vec::new();
        r.combine_packed(&packs, m, &mut out).unwrap();
        let blocks: Vec<Vec<f32>> =
            (0..n_e).map(|e| r.expert_block(&ln_h, m, e)).collect();
        let want = r.combine(&blocks, m);
        assert_eq!(out, want, "packed combine must be bitwise identical");

        // A pack set missing a routed expert is a loud error, not a
        // silent zero contribution.
        let partial: Vec<(&[(usize, usize, usize)], &[f32])> =
            packs[..1].to_vec();
        if r.counts[1] > 0 {
            assert!(r.combine_packed(&partial, m, &mut out).is_err());
        }
    }

    #[test]
    fn pack_segments_full_blocks_match_pack_blocks() {
        let t_toks = 20;
        let m = 4;
        let probs = softmax_rows(t_toks, 5, 13);
        let r = Routing::top1(&probs, 5);
        let mut rng = Rng::new(17);
        let ln_h: Vec<f32> =
            (0..t_toks * m).map(|_| rng.gauss() as f32).collect();
        let mut a = Vec::new();
        r.pack_blocks(&ln_h, m, &[1, 3], &mut a);
        let segs = [(1usize, 0usize, r.counts[1]), (3, 0, r.counts[3])];
        let mut b = Vec::new();
        r.pack_segments(&ln_h, m, &segs, &mut b);
        assert_eq!(a, b, "full-range segments must equal pack_blocks");
    }

    #[test]
    fn replica_split_pack_and_combine_roundtrip() {
        // Split the hottest expert's block across two "replica workers":
        // identity experts mean each packed reply equals its request, and
        // the segment combine must reassemble the exact per-expert
        // combine bit for bit.
        let t_toks = 32;
        let m = 4;
        let n_e = 4;
        let probs = softmax_rows(t_toks, n_e, 23);
        let r = Routing::top1(&probs, n_e);
        let hot = (0..n_e).max_by_key(|&e| r.counts[e]).unwrap();
        let c = r.counts[hot];
        assert!(c >= 2, "seed must route >=2 tokens to the hot expert");
        let lo_rows = c.div_ceil(2);
        // Worker A: first half of the hot expert + every other expert's
        // full block; worker B: second half of the hot expert.
        let mut segs_a: Vec<(usize, usize, usize)> = Vec::new();
        for e in 0..n_e {
            if e == hot {
                segs_a.push((e, 0, lo_rows));
            } else if r.counts[e] > 0 {
                segs_a.push((e, 0, r.counts[e]));
            }
        }
        let segs_b = vec![(hot, lo_rows, c - lo_rows)];
        let mut rng = Rng::new(41);
        let ln_h: Vec<f32> =
            (0..t_toks * m).map(|_| rng.gauss() as f32).collect();
        let mut buf_a = Vec::new();
        let mut buf_b = Vec::new();
        r.pack_segments(&ln_h, m, &segs_a, &mut buf_a);
        r.pack_segments(&ln_h, m, &segs_b, &mut buf_b);
        // The two segment packs carry every routed row exactly once.
        assert_eq!(
            (buf_a.len() + buf_b.len()) / m,
            r.counts.iter().sum::<usize>()
        );
        let packs: Vec<(&[(usize, usize, usize)], &[f32])> = vec![
            (segs_a.as_slice(), buf_a.as_slice()),
            (segs_b.as_slice(), buf_b.as_slice()),
        ];
        let mut out = Vec::new();
        r.combine_packed(&packs, m, &mut out).unwrap();
        let blocks: Vec<Vec<f32>> =
            (0..n_e).map(|e| r.expert_block(&ln_h, m, e)).collect();
        assert_eq!(out, r.combine(&blocks, m), "replica split not bitwise");

        // Dropping the second replica's reply leaves hot-expert slots
        // uncovered: loud error, never a silent zero.
        let partial = vec![(segs_a.as_slice(), buf_a.as_slice())];
        assert!(r.combine_packed(&partial, m, &mut out).is_err());
    }

    #[test]
    fn masked_tokens_take_no_slot_and_send_no_traffic() {
        let t_toks = 16;
        let m = 4;
        let probs = softmax_rows(t_toks, 4, 21);
        // Mask the odd tokens (dead decode lanes).
        let mask: Vec<bool> = (0..t_toks).map(|t| t % 2 == 0).collect();
        let r = Routing::top1_masked(&probs, 4, Some(&mask));
        let full = Routing::top1(&probs, 4);
        assert_eq!(r.counts.iter().sum::<usize>(), t_toks / 2);
        let mut rng = Rng::new(31);
        let ln_h: Vec<f32> =
            (0..t_toks * m).map(|_| rng.gauss() as f32).collect();
        for tok in 0..t_toks {
            if mask[tok] {
                // Live tokens route exactly as in the unmasked case.
                assert_eq!(r.expert[tok], full.expert[tok]);
                assert_eq!(r.prob[tok], full.prob[tok]);
            } else {
                assert_eq!(r.expert[tok], MASKED);
            }
        }
        // Pack/combine round trip: identity experts, masked rows zero.
        let experts: Vec<usize> = (0..4).collect();
        let mut buf = Vec::new();
        r.pack_blocks(&ln_h, m, &experts, &mut buf);
        assert_eq!(buf.len(), (t_toks / 2) * m, "only live rows packed");
        let counts: Vec<(usize, usize, usize)> =
            experts.iter().map(|&e| (e, 0, r.counts[e])).collect();
        let packs: Vec<(&[(usize, usize, usize)], &[f32])> =
            vec![(counts.as_slice(), buf.as_slice())];
        let mut out = Vec::new();
        r.combine_packed(&packs, m, &mut out).unwrap();
        for tok in 0..t_toks {
            for i in 0..m {
                let want = if mask[tok] {
                    r.prob[tok] * ln_h[tok * m + i]
                } else {
                    0.0
                };
                assert!((out[tok * m + i] - want).abs() < 1e-6);
            }
        }
        // The serial-path combine agrees.
        let blocks: Vec<Vec<f32>> =
            (0..4).map(|e| r.expert_block(&ln_h, m, e)).collect();
        assert_eq!(r.combine(&blocks, m), out);
        // An all-live mask is exactly the unmasked routing.
        let all = vec![true; t_toks];
        let ra = Routing::top1_masked(&probs, 4, Some(&all));
        assert_eq!(ra.expert, full.expert);
        assert_eq!(ra.slot, full.slot);
        assert_eq!(ra.counts, full.counts);
    }

    #[test]
    fn wire_pack_and_combine_f32_is_bitwise_f16_is_close() {
        let t_toks = 24;
        let m = 8;
        let n_e = 4;
        let probs = softmax_rows(t_toks, n_e, 29);
        let r = Routing::top1(&probs, n_e);
        let mut rng = Rng::new(43);
        let ln_h: Vec<f32> =
            (0..t_toks * m).map(|_| rng.gauss() as f32).collect();
        let segs: Vec<(usize, usize, usize)> =
            (0..n_e).map(|e| (e, 0, r.counts[e])).collect();
        let mut plain = Vec::new();
        r.pack_segments(&ln_h, m, &segs, &mut plain);

        // f32 wire: same bits in, same bits out.
        let p32 = r.pack_segments_wire(&ln_h, m, &segs, Dtype::F32).unwrap();
        assert_eq!(p32.dtype(), Dtype::F32);
        assert_eq!(p32.as_f32().unwrap(), plain.as_slice());
        let mut out32 = Vec::new();
        r.combine_packed_wire(&[(segs.as_slice(), &p32)], m, &mut out32)
            .unwrap();
        let mut want = Vec::new();
        r.combine_packed(&[(segs.as_slice(), plain.as_slice())], m, &mut want)
            .unwrap();
        assert_eq!(out32, want, "f32 wire must be bitwise identical");

        // f16 wire: half the payload bytes, combine within f16 tolerance.
        let p16 = r.pack_segments_wire(&ln_h, m, &segs, Dtype::F16).unwrap();
        assert_eq!(p16.dtype(), Dtype::F16);
        assert_eq!(p16.byte_len() * 2, p32.byte_len());
        let mut out16 = Vec::new();
        r.combine_packed_wire(&[(segs.as_slice(), &p16)], m, &mut out16)
            .unwrap();
        for (a, b) in out16.iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-3_f32.max(b.abs() * 1e-3),
                "f16 wire combine diverged: {a} vs {b}"
            );
        }
    }

    #[test]
    fn property_no_token_lost_or_duplicated() {
        prop(100, |c| {
            let t = c.usize(1, 64);
            let e = c.usize(1, 16);
            let probs = softmax_rows(t, e, c.seed);
            let r = Routing::top1(&probs, e);
            crate::prop_assert_eq!(r.counts.iter().sum::<usize>(), t);
            crate::prop_assert_eq!(r.expert.len(), t);
            // every (expert, slot) pair unique
            let mut seen = std::collections::HashSet::new();
            for tok in 0..t {
                crate::prop_assert!(
                    seen.insert((r.expert[tok], r.slot[tok])),
                    "duplicate (expert, slot) for token {tok}"
                );
                crate::prop_assert!(
                    r.slot[tok] < r.counts[r.expert[tok]],
                    "slot out of range"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_argmax_selected() {
        prop(50, |c| {
            let t = c.usize(1, 32);
            let e = c.usize(2, 8);
            let probs = softmax_rows(t, e, c.seed ^ 0xABC);
            let r = Routing::top1(&probs, e);
            for tok in 0..t {
                let row = &probs[tok * e..(tok + 1) * e];
                let best = row
                    .iter()
                    .cloned()
                    .fold(f32::NEG_INFINITY, f32::max);
                crate::prop_assert!(
                    (r.prob[tok] - best).abs() < 1e-7,
                    "token {tok}: picked {} not max {}",
                    r.prob[tok],
                    best
                );
            }
            Ok(())
        });
    }
}
