//! Expert placement: which worker owns which experts at which layer.
//!
//! Reproduces the paper's **multi-expert and multi-data parallelism**
//! (§4.1.3): a PR-MoE model has different expert counts per layer, so no
//! single expert-parallel degree fits all layers.  DeepSpeed's solution —
//! per-layer EP degree equal to `min(experts_at_layer, workers)` with the
//! remaining factor as data parallelism — places **exactly
//! `experts/ep_degree` experts per worker group member**, giving zero load
//! imbalance and no per-GPU memory increase.
//!
//! At testbed scale the "workers" are fabric threads; the same structure is
//! evaluated analytically at paper scale by the simulator.

use std::collections::BTreeMap;

use crate::config::ModelConfig;

/// Placement of one MoE layer's experts over `workers` workers.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlacement {
    pub layer: usize,
    pub n_experts: usize,
    /// Expert-parallel degree for this layer (<= workers).
    pub ep_degree: usize,
    /// Data-parallel replication factor for this layer's experts
    /// (workers / ep_degree) — the "multi-data" part of §4.1.3.
    pub dp_degree: usize,
    /// experts_of[w] = expert ids resident on worker w.
    pub experts_of: Vec<Vec<usize>>,
}

impl LayerPlacement {
    /// The paper's scheme: ep = min(E, W); each EP-group worker holds
    /// E/ep experts; the W/ep replicas process different data shards.
    pub fn balanced(layer: usize, n_experts: usize, workers: usize) -> Self {
        assert!(workers > 0 && n_experts > 0);
        let ep_degree = n_experts.min(workers);
        let dp_degree = (workers / ep_degree).max(1);
        let mut experts_of = vec![Vec::new(); workers];
        for e in 0..n_experts {
            // Round-robin keeps |max - min| <= 1 even when ep does not
            // divide the expert count (PR-MoE layers have varying E).
            let owner_in_group = e % ep_degree;
            // replica r of the EP group lives at worker r*ep + owner.
            for r in 0..dp_degree {
                let w = r * ep_degree + owner_in_group;
                if w < workers {
                    experts_of[w].push(e);
                }
            }
        }
        LayerPlacement { layer, n_experts, ep_degree, dp_degree, experts_of }
    }

    /// Worker that owns expert `e` for replica group `replica`, derived
    /// from `experts_of` (not the round-robin arithmetic) so it stays
    /// correct for non-uniform placements after hot-expert replication.
    /// The round-robin home slot wins when it still hosts the expert, so
    /// a balanced placement answers exactly what the old arithmetic did.
    pub fn owner(&self, e: usize, replica: usize) -> usize {
        let r = replica % self.dp_degree;
        let lo = r * self.ep_degree;
        let hi = ((r + 1) * self.ep_degree).min(self.experts_of.len());
        let home = lo + e % self.ep_degree;
        if home < hi && self.experts_of[home].contains(&e) {
            return home;
        }
        (lo..hi)
            .find(|&w| self.experts_of[w].contains(&e))
            .unwrap_or(home)
    }

    /// Every worker currently hosting expert `e`, ascending — the set the
    /// gate may split a hot expert's token block across.  A balanced
    /// placement answers the per-group owners; replication appends more.
    pub fn replicas_of(&self, e: usize) -> Vec<usize> {
        (0..self.experts_of.len())
            .filter(|&w| self.experts_of[w].contains(&e))
            .collect()
    }

    /// Replication factor of expert `e` (1 on a balanced placement with
    /// dp_degree 1; dp-group copies count too — they hold the same
    /// weights and serve the same dispatch splits).
    pub fn replication(&self, e: usize) -> usize {
        self.replicas_of(e).len()
    }

    /// Highest replication factor across this layer's experts — the
    /// `expert_replicas` gauge.
    pub fn max_replication(&self) -> usize {
        (0..self.n_experts).map(|e| self.replication(e)).max().unwrap_or(0)
    }

    /// Host expert `e` on worker `w` too (weights must be shipped by the
    /// caller).  Returns false if `w` already hosts it.
    pub fn add_replica(&mut self, e: usize, w: usize) -> bool {
        assert!(e < self.n_experts && w < self.experts_of.len());
        if self.experts_of[w].contains(&e) {
            return false;
        }
        self.experts_of[w].push(e);
        self.experts_of[w].sort_unstable();
        true
    }

    /// Stop hosting expert `e` on worker `w`.  Refuses (returns false) if
    /// `w` is the expert's last host — an expert must always live
    /// somewhere.  Stale weights left on `w` are harmless.
    pub fn remove_replica(&mut self, e: usize, w: usize) -> bool {
        assert!(e < self.n_experts && w < self.experts_of.len());
        if !self.experts_of[w].contains(&e) || self.replication(e) <= 1 {
            return false;
        }
        self.experts_of[w].retain(|&x| x != e);
        true
    }

    /// Experts whose *only* host is worker `w` (ascending) — the set that
    /// must be re-shipped elsewhere before `w` can be evicted on failover.
    /// Experts with a surviving replica (dp-group copy or hot-expert
    /// replica) need nothing: the copies already hold identical bytes.
    pub fn sole_hosted(&self, w: usize) -> Vec<usize> {
        self.experts_of[w]
            .iter()
            .copied()
            .filter(|&e| self.replication(e) == 1)
            .collect()
    }

    /// Remove worker `w` from this layer entirely (failover: the worker is
    /// dead).  The caller must first re-home every `sole_hosted` expert —
    /// asserted here, because silently losing an expert's last copy would
    /// turn later dispatches into unloaded-expert errors far from the
    /// cause.
    pub fn evict_worker(&mut self, w: usize) {
        assert!(
            self.sole_hosted(w).is_empty(),
            "evicting worker {w} would orphan experts {:?} at layer {}",
            self.sole_hosted(w),
            self.layer
        );
        self.experts_of[w].clear();
    }

    /// Max experts hosted by any single worker (the §4.1.3 balance metric).
    pub fn max_experts_per_worker(&self) -> usize {
        self.experts_of.iter().map(|v| v.len()).max().unwrap_or(0)
    }

    /// Min experts over workers that host anything, derived from
    /// `experts_of` (the old version only inspected replica group 0 and
    /// was wrong for replicated placements).  Workers left empty by a
    /// `workers % ep_degree` remainder don't drag the minimum to zero.
    pub fn min_experts_per_worker(&self) -> usize {
        self.experts_of
            .iter()
            .map(|v| v.len())
            .filter(|&n| n > 0)
            .min()
            .unwrap_or(0)
    }
}

/// Whole-model placement: one LayerPlacement per MoE layer.
#[derive(Debug, Clone)]
pub struct Placement {
    pub workers: usize,
    pub layers: BTreeMap<usize, LayerPlacement>,
}

impl Placement {
    pub fn for_model(cfg: &ModelConfig, workers: usize) -> Self {
        let layers = cfg
            .moe_layers()
            .into_iter()
            .map(|(i, e)| (i, LayerPlacement::balanced(i, e, workers)))
            .collect();
        Placement { workers, layers }
    }

    pub fn layer(&self, i: usize) -> Option<&LayerPlacement> {
        self.layers.get(&i)
    }

    pub fn layer_mut(&mut self, i: usize) -> Option<&mut LayerPlacement> {
        self.layers.get_mut(&i)
    }

    /// Evict worker `w` from every layer (failover).  Same contract as
    /// [`LayerPlacement::evict_worker`]: each layer's sole-hosted experts
    /// must already have been re-homed.
    pub fn evict_worker(&mut self, w: usize) {
        for lp in self.layers.values_mut() {
            lp.evict_worker(w);
        }
    }

    /// All (layer, expert) pairs assigned to worker `w` — what the engine
    /// ships to each fabric worker at startup.
    pub fn worker_manifest(&self, w: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for (layer, lp) in &self.layers {
            for &e in &lp.experts_of[w] {
                out.push((*layer, e));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;

    #[test]
    fn paper_example_multi_degree() {
        // §4.1.3: 128 workers, layers with 32/64/128 experts ->
        // EP {32,64,128} x DP {4,2,1}, exactly one expert per worker.
        for (e, want_ep, want_dp) in [(32, 32, 4), (64, 64, 2), (128, 128, 1)] {
            let lp = LayerPlacement::balanced(0, e, 128);
            assert_eq!(lp.ep_degree, want_ep);
            assert_eq!(lp.dp_degree, want_dp);
            assert_eq!(lp.max_experts_per_worker(), 1);
        }
    }

    #[test]
    fn fewer_workers_than_experts() {
        let lp = LayerPlacement::balanced(1, 8, 4);
        assert_eq!(lp.ep_degree, 4);
        assert_eq!(lp.dp_degree, 1);
        assert_eq!(lp.max_experts_per_worker(), 2);
        // every expert exactly once across the EP group
        let mut all: Vec<usize> =
            lp.experts_of.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn owner_matches_expert_lists() {
        let lp = LayerPlacement::balanced(0, 8, 4);
        for e in 0..8 {
            let w = lp.owner(e, 0);
            assert!(lp.experts_of[w].contains(&e), "expert {e} owner {w}");
        }
    }

    #[test]
    fn replicas_hold_same_expert_sets() {
        let lp = LayerPlacement::balanced(0, 4, 8); // dp=2
        assert_eq!(lp.dp_degree, 2);
        for i in 0..4 {
            assert_eq!(lp.experts_of[i], lp.experts_of[4 + i]);
        }
    }

    #[test]
    fn property_every_expert_exactly_once_per_replica() {
        prop(150, |c| {
            let e = c.usize(1, 64);
            let w = c.usize(1, 64);
            let lp = LayerPlacement::balanced(0, e, w);
            // replica group 0 = workers 0..ep_degree
            let mut seen = vec![0usize; e];
            for worker in 0..lp.ep_degree {
                for &ex in &lp.experts_of[worker] {
                    seen[ex] += 1;
                }
            }
            crate::prop_assert!(
                seen.iter().all(|&c| c == 1),
                "experts not exactly-once: {seen:?} (e={e}, w={w})"
            );
            // near-perfect balance: max-min <= 1 within the EP group
            let diff = lp.max_experts_per_worker() as i64
                - lp.min_experts_per_worker() as i64;
            crate::prop_assert!(diff <= 1, "imbalance {diff} (e={e}, w={w})");
            Ok(())
        });
    }

    #[test]
    fn property_replicated_placement_coherent() {
        // Random add/remove-replica sequences under the rebalancer's own
        // constraint (home-slot workers are never de-replicated): every
        // expert always has a host, `owner(e, 0)` always answers a
        // hosting worker, replica lists stay sorted/deduped, and the
        // derived accessors stay mutually consistent.
        prop(150, |c| {
            let e = c.usize(1, 32);
            let w = c.usize(1, 32);
            let mut lp = LayerPlacement::balanced(0, e, w);
            let ops = c.usize(0, 40);
            for _ in 0..ops {
                let ex = c.usize(0, e - 1);
                let wk = c.usize(0, w - 1);
                let hosted = lp.experts_of[wk].contains(&ex);
                let before = lp.replication(ex);
                if c.bool() {
                    let added = lp.add_replica(ex, wk);
                    crate::prop_assert!(added != hosted);
                    crate::prop_assert!(
                        lp.replication(ex) == before + usize::from(added)
                    );
                } else {
                    if wk % lp.ep_degree == ex % lp.ep_degree {
                        // A home-slot worker: the policy never removes
                        // these (owner(e, r) falls back to them).
                        continue;
                    }
                    let removed = lp.remove_replica(ex, wk);
                    crate::prop_assert!(removed == (hosted && before > 1));
                    crate::prop_assert!(
                        lp.replication(ex) == before - usize::from(removed)
                    );
                }
            }
            for ex in 0..e {
                let reps = lp.replicas_of(ex);
                crate::prop_assert!(
                    !reps.is_empty(),
                    "expert {ex} lost its last host (e={e}, w={w})"
                );
                crate::prop_assert!(
                    reps.windows(2).all(|p| p[0] < p[1]),
                    "replicas_of({ex}) not strictly ascending: {reps:?}"
                );
                crate::prop_assert!(lp.replication(ex) == reps.len());
                let o = lp.owner(ex, 0);
                crate::prop_assert!(
                    lp.experts_of[o].contains(&ex),
                    "owner({ex}, 0) = {o} does not host it (e={e}, w={w})"
                );
                if lp.replication(ex) == 1 {
                    crate::prop_assert!(
                        !lp.remove_replica(ex, reps[0]),
                        "removed expert {ex}'s last host"
                    );
                }
            }
            for (wk, list) in lp.experts_of.iter().enumerate() {
                crate::prop_assert!(
                    list.windows(2).all(|p| p[0] < p[1]),
                    "experts_of[{wk}] not sorted/deduped: {list:?}"
                );
            }
            crate::prop_assert!(
                lp.max_replication()
                    == (0..e).map(|x| lp.replication(x)).max().unwrap()
            );
            crate::prop_assert!(
                lp.min_experts_per_worker() <= lp.max_experts_per_worker()
            );
            crate::prop_assert!(lp.min_experts_per_worker() > 0);
            Ok(())
        });
    }

    #[test]
    fn property_evict_worker_preserves_every_expert() {
        // Failover invariant: after re-homing a victim's sole-hosted
        // experts onto survivors and evicting it, every expert still has
        // at least one host and the victim hosts nothing.
        prop(150, |c| {
            let e = c.usize(1, 32);
            let w = c.usize(2, 16);
            let mut lp = LayerPlacement::balanced(0, e, w);
            let victim = c.usize(0, w - 1);
            for ex in lp.sole_hosted(victim) {
                let target = (0..w)
                    .filter(|&x| x != victim)
                    .min_by_key(|&x| (lp.experts_of[x].len(), x))
                    .unwrap();
                lp.add_replica(ex, target);
            }
            lp.evict_worker(victim);
            crate::prop_assert!(
                lp.experts_of[victim].is_empty(),
                "victim {victim} still hosts experts"
            );
            for ex in 0..e {
                let reps = lp.replicas_of(ex);
                crate::prop_assert!(
                    !reps.is_empty() && !reps.contains(&victim),
                    "expert {ex} hosts {reps:?} after evicting {victim} \
                     (e={e}, w={w})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn evict_refuses_to_orphan_sole_hosted_experts() {
        let mut lp = LayerPlacement::balanced(0, 4, 4); // 1 expert each
        assert_eq!(lp.sole_hosted(2), vec![2]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || lp.evict_worker(2),
        ));
        assert!(r.is_err(), "evicting a sole host must assert");
    }

    #[test]
    fn pr_moe_model_gets_per_layer_degrees() {
        let cfg = crate::config::ModelConfig {
            name: "prmoe-test".into(),
            vocab_size: 512,
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            d_ff: 512,
            max_seq: 64,
            experts_schedule: vec![0, 4, 0, 8],
            residual: true,
            top2: false,
            capacity_factor: 2.0,
            moe_loss_coef: 0.01,
            teacher: None,
            kd_alpha: 1.0,
            num_params: 0,
        };
        let p = Placement::for_model(&cfg, 8);
        assert_eq!(p.layer(1).unwrap().ep_degree, 4);
        assert_eq!(p.layer(1).unwrap().dp_degree, 2);
        assert_eq!(p.layer(3).unwrap().ep_degree, 8);
        assert_eq!(p.layer(3).unwrap().dp_degree, 1);
        // worker 0 hosts one expert from each MoE layer
        let m = p.worker_manifest(0);
        assert_eq!(m.len(), 2);
    }
}
