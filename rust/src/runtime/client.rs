//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! One `Runtime` per thread (the `xla` crate's `PjRtClient` is `Rc`-based
//! and thread-bound).  Programs are compiled lazily and cached by manifest
//! key; `Program::run` validates inputs against the manifest specs so shape
//! bugs surface as errors naming the offending slot rather than opaque XLA
//! failures.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::artifact::{Manifest, ProgramSpec, TensorSpec};
use super::host_tensor::HostTensor;

/// One artifact set, shareable across threads, from which the dense
/// backbone can be materialized on **multiple** thread-bound [`Runtime`]s.
///
/// PJRT objects are thread-bound (`PjRtClient` is `Rc`-based), so a second
/// runtime thread cannot borrow the leader's compiled programs or weight
/// literals.  What *can* be shared is the source of both: the manifest
/// (program specs → HLO files) and the checkpoint tensors (`Send`able
/// [`HostTensor`]s behind an `Arc`).  Each thread that wants its own copy
/// of the dense backbone clones a `SharedArtifacts`, creates its own
/// `Runtime`, and calls [`SharedArtifacts::materialize_dense_params`] —
/// the same artifact set feeds the single-threaded leader and every
/// leader shard without duplicating the host-side weights.
#[derive(Clone)]
pub struct SharedArtifacts {
    manifest: Manifest,
    params: Arc<HashMap<String, HostTensor>>,
}

impl SharedArtifacts {
    pub fn new(
        manifest: Manifest,
        params: HashMap<String, HostTensor>,
    ) -> SharedArtifacts {
        SharedArtifacts { manifest, params: Arc::new(params) }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The checkpoint tensors (host side, shared — never copied per
    /// thread).
    pub fn params(&self) -> &HashMap<String, HostTensor> {
        &self.params
    }

    /// True if `name` is a stacked expert-FFN weight (`layerN.moe.w1` /
    /// `b1` / `w2` / `b2`): those live sliced on the fabric workers, not
    /// on any leader runtime.  The expert *gate* (`moe.gate`) and the
    /// PR-MoE residual branch (`moe.res.*`) are dense leader-side
    /// parameters and are kept.
    pub fn is_expert_param(name: &str) -> bool {
        name.ends_with(".moe.w1")
            || name.ends_with(".moe.b1")
            || name.ends_with(".moe.w2")
            || name.ends_with(".moe.b2")
    }

    /// Materialize every dense (non-expert) checkpoint tensor as an
    /// `xla::Literal` for the calling thread.  Literals are host memory,
    /// but they are not `Send` — each runtime thread builds its own set
    /// from the shared host tensors.
    pub fn materialize_dense_params(
        &self,
    ) -> Result<HashMap<String, xla::Literal>> {
        let mut out = HashMap::with_capacity(self.params.len());
        for (name, t) in self.params.iter() {
            if Self::is_expert_param(name) {
                continue;
            }
            out.insert(
                name.clone(),
                t.to_literal()
                    .with_context(|| format!("materializing param {name}"))?,
            );
        }
        Ok(out)
    }
}

/// Thread-local PJRT CPU runtime with a compiled-program cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Program>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) the program described by `spec`.
    ///
    /// When the manifest records a `sha256` for the entry (schema v2), the
    /// artifact file is re-hashed before compiling — once per process, the
    /// compile cache covers later loads — so a stale or corrupted artifact
    /// fails loudly naming the entry instead of miscompiling.
    pub fn load(&self, spec: &ProgramSpec) -> Result<Rc<Program>> {
        if let Some(p) = self.cache.borrow().get(&spec.key) {
            return Ok(p.clone());
        }
        if let Some(want) = &spec.sha256 {
            let bytes = std::fs::read(&spec.file).with_context(|| {
                format!("reading artifact {:?} of {}", spec.file, spec.key)
            })?;
            let got = crate::util::sha256::hex_digest(&bytes);
            anyhow::ensure!(
                got == *want,
                "artifact integrity check failed for manifest entry \
                 {:?}: {:?} hashes to sha256 {got} but the manifest \
                 records {want} — the file is stale or corrupted; rebuild \
                 the artifacts (`make artifacts`)",
                spec.key,
                spec.file
            );
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.key))?;
        tracing_compile(&spec.key, t0.elapsed());
        let prog = Rc::new(Program {
            spec: spec.clone(),
            exe,
            client: self.client.clone(),
        });
        self.cache.borrow_mut().insert(spec.key.clone(), prog.clone());
        Ok(prog)
    }

    pub fn cached_programs(&self) -> usize {
        self.cache.borrow().len()
    }
}

fn tracing_compile(key: &str, d: std::time::Duration) {
    if std::env::var_os("DSMOE_LOG_COMPILE").is_some() {
        eprintln!("[runtime] compiled {key} in {:?}", d);
    }
}

/// A compiled executable plus its manifest signature.
pub struct Program {
    pub spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

impl Program {
    pub fn key(&self) -> &str {
        &self.spec.key
    }

    fn check_input(&self, i: usize, spec: &TensorSpec, t: &HostTensor) -> Result<()> {
        if t.shape != spec.shape || t.dtype().name() != spec.dtype {
            bail!(
                "program {}: input {} ({}) expects {:?} {} but got {:?} {}",
                self.spec.key, i, spec.name, spec.shape, spec.dtype,
                t.shape, t.dtype()
            );
        }
        Ok(())
    }

    /// Execute with host tensors; returns outputs as host tensors.
    ///
    /// The AOT programs are lowered with `return_tuple=True`, so the PJRT
    /// result is a single tuple buffer that we decompose on the host.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits = self.to_literals(inputs)?;
        let out = self.run_literals(&lits)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    /// Validate + convert inputs to literals (callers that loop can keep
    /// literals across iterations to skip repeated conversion).
    pub fn to_literals(&self, inputs: &[HostTensor]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "program {}: expected {} inputs, got {}",
                self.spec.key, self.spec.inputs.len(), inputs.len()
            );
        }
        inputs
            .iter()
            .enumerate()
            .map(|(i, t)| {
                self.check_input(i, &self.spec.inputs[i], t)?;
                t.to_literal()
            })
            .collect()
    }

    /// Execute with pre-converted literals (hot path).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.run_literal_refs(&refs)
    }

    /// Execute with borrowed literals (avoids moving state tuples around).
    pub fn run_literal_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "program {}: expected {} inputs, got {}",
                self.spec.key, self.spec.inputs.len(), inputs.len()
            );
        }
        // NOTE: we deliberately avoid `PjRtLoadedExecutable::execute`
        // (xla 0.1.6 leaks every input device buffer: xla_rs.cc `execute`
        // does `buffer.release()` and never deletes them — one full input
        // set leaked per call, ~40 MB/step for a training step).  Instead
        // we create the input buffers ourselves (owned `PjRtBuffer`s with a
        // correct Drop) and go through the leak-free `execute_b`.
        let in_bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .enumerate()
            .map(|(i, lit)| {
                self.literal_to_buffer(lit).with_context(|| {
                    format!("uploading input {i} of {}", self.spec.key)
                })
            })
            .collect::<Result<_>>()?;
        let bufs = self
            .exe
            .execute_b(&in_bufs)
            .with_context(|| format!("executing {}", self.spec.key))?;
        let tuple = bufs[0][0]
            .to_literal_sync()
            .context("fetching result tuple")?;
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "program {}: manifest promises {} outputs, executable \
                 returned {}",
                self.spec.key, self.spec.outputs.len(), parts.len()
            );
        }
        Ok(parts)
    }

    /// Upload one literal as an owned device buffer (see the leak note in
    /// `run_literal_refs`).
    fn literal_to_buffer(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let v = lit.to_vec::<f32>()?;
                Ok(self.client.buffer_from_host_buffer(&v, &dims, None)?)
            }
            xla::ElementType::S32 => {
                let v = lit.to_vec::<i32>()?;
                Ok(self.client.buffer_from_host_buffer(&v, &dims, None)?)
            }
            other => anyhow::bail!("unsupported input dtype {other:?}"),
        }
    }

    /// Outputs converted to host tensors with manifest names attached.
    pub fn run_named(&self, inputs: &[HostTensor]) -> Result<Vec<(String, HostTensor)>> {
        let outs = self.run(inputs)?;
        Ok(self
            .spec
            .outputs
            .iter()
            .map(|o| o.name.clone())
            .zip(outs)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;

    fn manifest() -> Option<Manifest> {
        let root = std::path::Path::new("artifacts");
        root.join("manifest.json")
            .exists()
            .then(|| Manifest::load(root).unwrap())
    }

    #[test]
    fn load_and_run_shared_program() {
        let Some(m) = manifest() else { return };
        let rt = Runtime::cpu().unwrap();
        // expert_ffn_m128_f512_c1: y = gelu(x@w1+b1)@w2+b2 with zeros -> 0
        let spec = m.shared_program("expert_ffn_m128_f512_c1").unwrap();
        let prog = rt.load(spec).unwrap();
        let ins = vec![
            HostTensor::zeros_f32(&[1, 128]),
            HostTensor::zeros_f32(&[128, 512]),
            HostTensor::zeros_f32(&[512]),
            HostTensor::zeros_f32(&[512, 128]),
            HostTensor::zeros_f32(&[128]),
        ];
        let out = prog.run(&ins).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![1, 128]);
        assert!(out[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
        // cached on second load
        let again = rt.load(spec).unwrap();
        assert!(Rc::ptr_eq(&prog, &again));
        assert_eq!(rt.cached_programs(), 1);
    }

    #[test]
    fn stale_artifact_sha_fails_loudly() {
        let Some(m) = manifest() else { return };
        let rt = Runtime::cpu().unwrap();
        let mut spec =
            m.shared_program("expert_ffn_m128_f512_c1").unwrap().clone();
        spec.key = "tampered_expert_ffn".into(); // miss the compile cache
        spec.sha256 = Some("0".repeat(64));
        let err = rt.load(&spec).unwrap_err().to_string();
        assert!(err.contains("integrity"), "{err}");
        assert!(err.contains("tampered_expert_ffn"), "{err}");
        // A correct digest loads fine.
        let bytes = std::fs::read(&spec.file).unwrap();
        spec.sha256 = Some(crate::util::sha256::hex_digest(&bytes));
        rt.load(&spec).unwrap();
    }

    #[test]
    fn expert_param_filter_keeps_dense_weights() {
        // Stacked expert weights are worker-side; everything else —
        // including the gate and the PR-MoE residual branch — is dense.
        for expert in ["layer1.moe.w1", "layer3.moe.b1", "layer1.moe.w2",
                       "layer7.moe.b2"] {
            assert!(SharedArtifacts::is_expert_param(expert), "{expert}");
        }
        for dense in ["layer1.moe.gate", "layer1.moe.res.w1",
                      "layer1.moe.res.b2", "layer0.mlp.w1", "tok_emb",
                      "layer2.attn.wq", "lnf.g"] {
            assert!(!SharedArtifacts::is_expert_param(dense), "{dense}");
        }
    }

    #[test]
    fn shared_artifacts_materialize_on_two_threads() {
        let Some(m) = manifest() else { return };
        let mut params = HashMap::new();
        params.insert(
            "tok_emb".to_string(),
            HostTensor::f32(&[2, 2], vec![1., 2., 3., 4.]),
        );
        params.insert(
            "layer0.moe.w1".to_string(),
            HostTensor::zeros_f32(&[2, 2]),
        );
        let arts = SharedArtifacts::new(m, params);
        let here = arts.materialize_dense_params().unwrap();
        assert!(here.contains_key("tok_emb"));
        assert!(!here.contains_key("layer0.moe.w1"));
        // The same artifact set materializes independently on another
        // thread (the leader-shard pattern).
        let arts2 = arts.clone();
        let ok = std::thread::spawn(move || {
            let there = arts2.materialize_dense_params().unwrap();
            there.len() == 1 && there.contains_key("tok_emb")
        })
        .join()
        .unwrap();
        assert!(ok);
    }

    #[test]
    fn shape_validation_errors_name_the_slot() {
        let Some(m) = manifest() else { return };
        let rt = Runtime::cpu().unwrap();
        let spec = m.shared_program("expert_ffn_m128_f512_c1").unwrap();
        let prog = rt.load(spec).unwrap();
        let bad = vec![
            HostTensor::zeros_f32(&[2, 128]), // wrong C
            HostTensor::zeros_f32(&[128, 512]),
            HostTensor::zeros_f32(&[512]),
            HostTensor::zeros_f32(&[512, 128]),
            HostTensor::zeros_f32(&[128]),
        ];
        let err = prog.run(&bad).unwrap_err().to_string();
        assert!(err.contains("input 0"), "{err}");
        let too_few = prog.run(&bad[..3]).unwrap_err().to_string();
        assert!(too_few.contains("expected 5 inputs"), "{too_few}");
    }
}
