//! Host-side tensors: the `Send`-able currency between coordinator threads.
//!
//! PJRT objects (`PjRtClient` is `Rc`-based) are thread-bound, so everything
//! that crosses a channel — activations moving through the all-to-all
//! fabric, checkpoint params, batches — travels as a `HostTensor` and is
//! converted to an `xla::Literal` at the owning thread's edge.

use anyhow::{bail, Result};

/// Supported element types (mirrors the dtypes the manifest emits).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs {} elems", data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Self::f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self::i32(&[], vec![v])
    }

    pub fn nelems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.nelems() * 4
    }

    pub fn dtype(&self) -> &'static str {
        match self.data {
            TensorData::F32(_) => "f32",
            TensorData::I32(_) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is {} not f32", self.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is {} not i32", self.dtype()),
        }
    }

    /// Row-major offset of a multi-index.
    pub fn offset(&self, index: &[usize]) -> usize {
        assert_eq!(index.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in index.iter().zip(&self.shape).enumerate() {
            assert!(ix < dim, "index {index:?} out of shape {:?} at axis {i}",
                    self.shape);
            off = off * dim + ix;
        }
        off
    }

    /// Copy of row `r` of a 2-D f32 tensor.
    pub fn row_f32(&self, r: usize) -> Result<Vec<f32>> {
        let d = self.as_f32()?;
        anyhow::ensure!(self.shape.len() == 2, "need 2-D, got {:?}", self.shape);
        let w = self.shape[1];
        Ok(d[r * w..(r + 1) * w].to_vec())
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshaped(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.nelems());
        self.shape = shape.to_vec();
        self
    }

    // -- Literal conversion (thread-edge) ------------------------------------

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::f32(&dims, lit.to_vec::<f32>()?))
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::i32(&dims, lit.to_vec::<i32>()?))
            }
            other => bail!("unsupported literal dtype {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = HostTensor::f32(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.nelems(), 6);
        assert_eq!(t.offset(&[1, 2]), 5);
        assert_eq!(t.row_f32(1).unwrap(), vec![3., 4., 5.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn dtype_guards() {
        let t = HostTensor::i32(&[2], vec![1, 2]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = HostTensor::scalar_i32(7);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7]);
        assert!(back.shape.is_empty());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = HostTensor::f32(&[4], vec![1., 2., 3., 4.]).reshaped(&[2, 2]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.as_f32().unwrap(), &[1., 2., 3., 4.]);
    }
}
