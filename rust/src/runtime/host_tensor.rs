//! Host-side tensors: the `Send`-able currency between coordinator threads.
//!
//! PJRT objects (`PjRtClient` is `Rc`-based) are thread-bound, so everything
//! that crosses a channel — activations moving through the all-to-all
//! fabric, checkpoint params, batches — travels as a `HostTensor` and is
//! converted to an `xla::Literal` at the owning thread's edge.
//!
//! Besides the compute dtypes (`f32`, `i32`) a `HostTensor` can carry the
//! compressed **wire/storage** dtypes of the expert data path: `f16`/`bf16`
//! activations (`DSMOE_WIRE_DTYPE`) and `bf16`/`i8` expert weights
//! (`DSMOE_EXPERT_DTYPE`).  Compressed tensors never reach a PJRT literal
//! directly — workers widen (or dequantize, for `i8` + per-column scales)
//! to f32 at the thread edge, so the AOT programs stay f32 end to end.

use anyhow::{bail, Result};

/// The shared element-type table of the whole data path: `HostTensor`
/// payloads, the frame codec's on-wire tags ([`Dtype::tag`] /
/// [`Dtype::from_tag`] — encode, decode and the codec tests all use this
/// one table, so a new dtype cannot silently skew between them), byte
/// accounting ([`Dtype::elem_bytes`]) and the manifest capability strings
/// ([`Dtype::name`] / [`Dtype::parse`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    I32,
    F16,
    BF16,
    I8,
}

impl Dtype {
    /// Number of dtypes (bound for per-dtype counter arrays).
    pub const N: usize = 5;

    /// Every dtype, indexed by its wire tag.
    pub const ALL: [Dtype; Dtype::N] =
        [Dtype::F32, Dtype::I32, Dtype::F16, Dtype::BF16, Dtype::I8];

    /// Frame-codec wire tag (stable ABI: 0=f32, 1=i32, 2=f16, 3=bf16, 4=i8).
    pub fn tag(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::I32 => 1,
            Dtype::F16 => 2,
            Dtype::BF16 => 3,
            Dtype::I8 => 4,
        }
    }

    pub fn from_tag(tag: u8) -> Option<Dtype> {
        Dtype::ALL.get(tag as usize).copied()
    }

    pub fn elem_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F16 | Dtype::BF16 => 2,
            Dtype::I8 => 1,
        }
    }

    /// Manifest / env-toggle spelling.
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
            Dtype::F16 => "f16",
            Dtype::BF16 => "bf16",
            Dtype::I8 => "i8",
        }
    }

    pub fn parse(s: &str) -> Option<Dtype> {
        Dtype::ALL.into_iter().find(|d| d.name() == s.trim())
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------- f16/bf16 bits
//
// Manual bit conversions (the offline build has no `half` crate).  Both
// directions round-to-nearest-even; NaNs stay NaNs, overflow saturates to
// infinity (IEEE 754 default behaviour).

/// f32 → IEEE 754 binary16, round-to-nearest-even (subnormals included).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN; force a mantissa bit so a NaN cannot collapse to inf.
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflows even the smallest subnormal
        }
        // Subnormal: shift the 24-bit significand (implicit 1) into the
        // 10-bit field, rounding to nearest even on the dropped bits.
        let m = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rem = m & ((1u32 << shift) - 1);
        let mut v = (m >> shift) as u16;
        if rem > half || (rem == half && (v & 1) == 1) {
            v += 1; // may carry into exp=1: that bit pattern is correct
        }
        return sign | v;
    }
    let mut e = e as u32;
    let mut m = man >> 13;
    let rem = man & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
        m += 1;
        if m == 0x400 {
            m = 0;
            e += 1;
            if e >= 0x1F {
                return sign | 0x7C00;
            }
        }
    }
    sign | ((e as u16) << 10) | (m as u16)
}

/// IEEE 754 binary16 → f32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: normalize into an f32 with a real exponent.
            let mut e = 113u32; // 127 - 14
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3FF) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 → bfloat16, round-to-nearest-even.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep the sign, force a quiet mantissa bit.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    (bits.wrapping_add(round) >> 16) as u16
}

/// bfloat16 → f32 (exact: bf16 is the f32 high half).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Supported element types (mirrors the dtypes the manifest emits plus the
/// compressed wire/storage formats of the expert data path).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// IEEE binary16 bit patterns (wire format for dispatch/combine rows).
    F16(Vec<u16>),
    /// bfloat16 bit patterns (weight-ladder / wire format).
    BF16(Vec<u16>),
    /// Symmetric per-output-channel quantized weights; the f32 column
    /// scales travel as a separate tensor (see
    /// [`HostTensor::quantize_i8_per_col`]).
    I8(Vec<i8>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs {} elems", data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn f16(shape: &[usize], data: Vec<u16>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::F16(data) }
    }

    pub fn bf16(shape: &[usize], data: Vec<u16>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::BF16(data) }
    }

    pub fn i8(shape: &[usize], data: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::I8(data) }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Self::f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self::i32(&[], vec![v])
    }

    pub fn nelems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Payload bytes as counted by the traffic accounting — dtype-aware,
    /// so compressed dispatch/combine and weight-ship payloads report
    /// their true wire size.
    pub fn byte_len(&self) -> usize {
        self.nelems() * self.dtype().elem_bytes()
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
            TensorData::F16(_) => Dtype::F16,
            TensorData::BF16(_) => Dtype::BF16,
            TensorData::I8(_) => Dtype::I8,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is {} not f32", self.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is {} not i32", self.dtype()),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            _ => bail!("tensor is {} not i8", self.dtype()),
        }
    }

    /// Convert between the float dtypes (`f32` ⇄ `f16`/`bf16`; identity
    /// conversions are a clone).  Narrowing rounds to nearest even;
    /// widening is exact.  `i32`/`i8` do not convert here — `i8` needs its
    /// scale tensor ([`HostTensor::dequantize_i8_per_col`]).
    pub fn convert(&self, to: Dtype) -> Result<HostTensor> {
        let from = self.dtype();
        if from == to {
            return Ok(self.clone());
        }
        let data = match (&self.data, to) {
            (TensorData::F32(v), Dtype::F16) => {
                TensorData::F16(v.iter().map(|&x| f32_to_f16(x)).collect())
            }
            (TensorData::F32(v), Dtype::BF16) => {
                TensorData::BF16(v.iter().map(|&x| f32_to_bf16(x)).collect())
            }
            (TensorData::F16(v), Dtype::F32) => {
                TensorData::F32(v.iter().map(|&h| f16_to_f32(h)).collect())
            }
            (TensorData::BF16(v), Dtype::F32) => {
                TensorData::F32(v.iter().map(|&b| bf16_to_f32(b)).collect())
            }
            _ => bail!("no conversion {from} -> {to}"),
        };
        Ok(HostTensor { shape: self.shape.clone(), data })
    }

    /// Float payload widened to f32 (`f32` clones; `f16`/`bf16` widen
    /// exactly).  Integer dtypes are an error.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        match &self.data {
            TensorData::F32(v) => Ok(v.clone()),
            TensorData::F16(v) => Ok(v.iter().map(|&h| f16_to_f32(h)).collect()),
            TensorData::BF16(v) => {
                Ok(v.iter().map(|&b| bf16_to_f32(b)).collect())
            }
            _ => bail!("tensor is {}, not a float dtype", self.dtype()),
        }
    }

    /// Symmetric per-output-channel int8 quantization of a 2-D `[rows,
    /// cols]` f32 matrix: each **column** (the output channel of `x @ W`)
    /// gets scale `max_abs(col) / 127`; values quantize to
    /// `round(x / scale)` clamped to ±127 (the symmetric range — −128 is
    /// never emitted).  Returns the `[rows, cols]` i8 tensor plus the
    /// `[cols]` f32 scale vector.  An all-zero column gets scale 1.0.
    pub fn quantize_i8_per_col(&self) -> Result<(HostTensor, HostTensor)> {
        let d = self.as_f32()?;
        anyhow::ensure!(
            self.shape.len() == 2,
            "per-channel quantization needs a 2-D matrix, got {:?}",
            self.shape
        );
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let mut maxabs = vec![0f32; cols];
        for r in 0..rows {
            for (c, m) in maxabs.iter_mut().enumerate() {
                *m = m.max(d[r * cols + c].abs());
            }
        }
        let scales: Vec<f32> = maxabs
            .iter()
            .map(|&m| if m > 0.0 { m / 127.0 } else { 1.0 })
            .collect();
        let mut q = vec![0i8; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let v = (d[r * cols + c] / scales[c]).round();
                q[r * cols + c] = v.clamp(-127.0, 127.0) as i8;
            }
        }
        Ok((
            HostTensor::i8(&self.shape, q),
            HostTensor::f32(&[cols], scales),
        ))
    }

    /// Inverse of [`HostTensor::quantize_i8_per_col`]: widen a `[rows,
    /// cols]` i8 tensor back to f32 using the `[cols]` per-column scales.
    pub fn dequantize_i8_per_col(
        q: &HostTensor,
        scales: &HostTensor,
    ) -> Result<HostTensor> {
        let qd = q.as_i8()?;
        let s = scales.as_f32()?;
        anyhow::ensure!(
            q.shape.len() == 2 && scales.shape == [q.shape[1]],
            "dequantize: weights {:?} need [cols] scales, got {:?}",
            q.shape,
            scales.shape
        );
        let cols = q.shape[1];
        let data: Vec<f32> = qd
            .iter()
            .enumerate()
            .map(|(i, &v)| v as f32 * s[i % cols])
            .collect();
        Ok(HostTensor::f32(&q.shape, data))
    }

    /// Row-major offset of a multi-index.
    pub fn offset(&self, index: &[usize]) -> usize {
        assert_eq!(index.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in index.iter().zip(&self.shape).enumerate() {
            assert!(ix < dim, "index {index:?} out of shape {:?} at axis {i}",
                    self.shape);
            off = off * dim + ix;
        }
        off
    }

    /// Copy of row `r` of a 2-D f32 tensor.
    pub fn row_f32(&self, r: usize) -> Result<Vec<f32>> {
        let d = self.as_f32()?;
        anyhow::ensure!(self.shape.len() == 2, "need 2-D, got {:?}", self.shape);
        let w = self.shape[1];
        Ok(d[r * w..(r + 1) * w].to_vec())
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshaped(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.nelems());
        self.shape = shape.to_vec();
        self
    }

    // -- Literal conversion (thread-edge) ------------------------------------

    /// Compressed dtypes (`f16`/`bf16`/`i8`) are wire/storage formats and
    /// never cross the literal edge — workers widen or dequantize to f32
    /// first, keeping the AOT programs f32 end to end.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            _ => bail!(
                "cannot materialize a {} tensor as a literal — widen or \
                 dequantize to f32 first",
                self.dtype()
            ),
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::f32(&dims, lit.to_vec::<f32>()?))
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::i32(&dims, lit.to_vec::<i32>()?))
            }
            other => bail!("unsupported literal dtype {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = HostTensor::f32(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.nelems(), 6);
        assert_eq!(t.offset(&[1, 2]), 5);
        assert_eq!(t.row_f32(1).unwrap(), vec![3., 4., 5.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn dtype_guards() {
        let t = HostTensor::i32(&[2], vec![1, 2]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
    }

    #[test]
    fn dtype_table_is_consistent() {
        for (i, d) in Dtype::ALL.into_iter().enumerate() {
            assert_eq!(d.tag() as usize, i);
            assert_eq!(Dtype::from_tag(d.tag()), Some(d));
            assert_eq!(Dtype::parse(d.name()), Some(d));
        }
        assert_eq!(Dtype::from_tag(Dtype::N as u8), None);
        assert_eq!(Dtype::parse("f64"), None);
        assert_eq!(Dtype::F16.elem_bytes(), 2);
        assert_eq!(Dtype::I8.elem_bytes(), 1);
    }

    #[test]
    fn byte_len_is_dtype_aware() {
        assert_eq!(HostTensor::zeros_f32(&[3, 4]).byte_len(), 48);
        assert_eq!(HostTensor::f16(&[3, 4], vec![0; 12]).byte_len(), 24);
        assert_eq!(HostTensor::bf16(&[3, 4], vec![0; 12]).byte_len(), 24);
        assert_eq!(HostTensor::i8(&[3, 4], vec![0; 12]).byte_len(), 12);
    }

    #[test]
    fn f16_roundtrip_exact_cases() {
        // Values exactly representable in binary16 round-trip bit-exactly.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0,
                  1.5, 0.099975586, 6.1035156e-5, 5.9604645e-8] {
            let h = f32_to_f16(v);
            assert_eq!(f16_to_f32(h), v, "{v} did not round-trip");
        }
        // Infinities and NaN.
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)),
                   f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // Overflow saturates to inf; tiny values flush to (signed) zero.
        assert_eq!(f16_to_f32(f32_to_f16(1e9)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1e-9)).to_bits(),
                   (-0.0f32).to_bits());
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16
        // (1 + 2^-10): ties to even → 1.0.
        let tie = 1.0 + (2f32).powi(-11);
        assert_eq!(f16_to_f32(f32_to_f16(tie)), 1.0);
        // Just above the tie rounds up.
        let up = 1.0 + (2f32).powi(-11) + (2f32).powi(-20);
        assert_eq!(f16_to_f32(f32_to_f16(up)), 1.0 + (2f32).powi(-10));
    }

    #[test]
    fn f16_relative_error_bounded() {
        // Relative error of a single f16 round trip is ≤ 2^-11 for
        // normal-range values.
        let mut x = 1e-3f32;
        while x < 1e4 {
            for v in [x, -x] {
                let r = f16_to_f32(f32_to_f16(v));
                assert!(
                    ((r - v) / v).abs() <= 4.9e-4,
                    "{v} -> {r}"
                );
            }
            x *= 1.7;
        }
    }

    #[test]
    fn bf16_roundtrip_and_rounding() {
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.75, -0.015625] {
            let b = f32_to_bf16(v);
            assert_eq!(bf16_to_f32(b), v, "{v} did not round-trip");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        // RNE at the bf16 precision boundary: 1 + 2^-9 is halfway between
        // 1.0 and 1 + 2^-8 (last mantissa bit even) → 1.0.
        let tie = 1.0 + (2f32).powi(-9);
        assert_eq!(bf16_to_f32(f32_to_bf16(tie)), 1.0);
        // bf16 widening is exact: relative error of one round trip ≤ 2^-8.
        for v in [3.14159f32, -1234.5, 7.7e-12] {
            let r = bf16_to_f32(f32_to_bf16(v));
            assert!(((r - v) / v).abs() <= 3.92e-3, "{v} -> {r}");
        }
    }

    #[test]
    fn convert_roundtrips_and_guards() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, -2.5, 0.25, 3.0]);
        for d in [Dtype::F16, Dtype::BF16] {
            let c = t.convert(d).unwrap();
            assert_eq!(c.dtype(), d);
            // These values are exactly representable in both formats.
            assert_eq!(c.convert(Dtype::F32).unwrap(), t);
            assert_eq!(c.to_f32_vec().unwrap(), t.as_f32().unwrap());
        }
        assert_eq!(t.convert(Dtype::F32).unwrap(), t);
        assert!(t.convert(Dtype::I8).is_err());
        assert!(HostTensor::i32(&[1], vec![1]).to_f32_vec().is_err());
    }

    #[test]
    fn i8_per_col_quantization_roundtrip() {
        // Columns with very different ranges: per-column scales keep the
        // relative error bounded in each.
        let t = HostTensor::f32(
            &[3, 2],
            vec![100.0, 0.001, -50.0, -0.0005, 25.0, 0.00075],
        );
        let (q, s) = t.quantize_i8_per_col().unwrap();
        assert_eq!(q.dtype(), Dtype::I8);
        assert_eq!(s.shape, vec![2]);
        let back = HostTensor::dequantize_i8_per_col(&q, &s).unwrap();
        let orig = t.as_f32().unwrap();
        let deq = back.as_f32().unwrap();
        for (a, b) in orig.iter().zip(deq) {
            // Symmetric int8: |err| <= scale/2 = max_abs(col)/254.
            assert!((a - b).abs() <= a.abs().max(1e-12) / 127.0 + 1e-12,
                    "{a} vs {b}");
        }
        // Extremes hit ±127 exactly.
        assert_eq!(q.as_i8().unwrap()[0], 127);
        // All-zero columns quantize to zeros with scale 1.
        let z = HostTensor::zeros_f32(&[2, 3]);
        let (qz, sz) = z.quantize_i8_per_col().unwrap();
        assert!(qz.as_i8().unwrap().iter().all(|&v| v == 0));
        assert!(sz.as_f32().unwrap().iter().all(|&v| v == 1.0));
        let bz = HostTensor::dequantize_i8_per_col(&qz, &sz).unwrap();
        assert_eq!(bz, z);
        // Shape guards are loud.
        assert!(HostTensor::zeros_f32(&[4]).quantize_i8_per_col().is_err());
        assert!(HostTensor::dequantize_i8_per_col(
            &qz,
            &HostTensor::zeros_f32(&[7])
        )
        .is_err());
    }

    #[test]
    fn compressed_tensors_refuse_literals() {
        for t in [
            HostTensor::f16(&[2], vec![0, 0]),
            HostTensor::bf16(&[2], vec![0, 0]),
            HostTensor::i8(&[2], vec![0, 0]),
        ] {
            let err = t.to_literal().unwrap_err().to_string();
            assert!(err.contains("literal"), "{err}");
        }
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = HostTensor::scalar_i32(7);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7]);
        assert!(back.shape.is_empty());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = HostTensor::f32(&[4], vec![1., 2., 3., 4.]).reshaped(&[2, 2]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.as_f32().unwrap(), &[1., 2., 3., 4.]);
    }
}
