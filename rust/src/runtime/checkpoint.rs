//! Checkpoint I/O — the shared format between Python (`aot.py` writes the
//! initial checkpoint) and the Rust training driver (reads, updates,
//! re-writes):
//!
//! * `meta.json` — `{model, step, total_elems, params: [{name, shape,
//!   dtype, offset, nelems}]}`
//! * `params.bin` — all parameters as little-endian f32, concatenated in
//!   `param_specs` order (offsets are element offsets, not bytes).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

use super::host_tensor::HostTensor;

/// A loaded checkpoint: named parameter tensors in ABI order.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub model: String,
    pub step: usize,
    pub names: Vec<String>,
    pub tensors: Vec<HostTensor>,
}

impl Checkpoint {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {:?}/meta.json", dir))?;
        let meta = Json::parse(&meta_text).context("parsing meta.json")?;
        let bin = std::fs::read(dir.join("params.bin"))
            .with_context(|| format!("reading {:?}/params.bin", dir))?;

        let total = meta.req("total_elems")?.as_usize().context("total_elems")?;
        if bin.len() != total * 4 {
            bail!(
                "params.bin is {} bytes, meta promises {} elems ({} bytes)",
                bin.len(), total, total * 4
            );
        }

        let mut names = Vec::new();
        let mut tensors = Vec::new();
        for p in meta.req("params")?.as_arr().context("params")? {
            let name = p.req("name")?.as_str().context("name")?.to_string();
            let shape = p.req("shape")?.usize_vec()?;
            // The .bin layout is f32-only; a narrower on-disk dtype would
            // silently misread as garbage floats, so reject it by name.
            // (Compression happens at expert *ship* time, not on disk.)
            let dtype = p.req("dtype")?.as_str().context("dtype")?;
            if dtype != "f32" {
                bail!(
                    "param {name}: checkpoint dtype {dtype:?} is not \
                     supported — params.bin is an f32 stream; quantized \
                     expert dtypes are produced at ship time from the f32 \
                     master weights (DSMOE_EXPERT_DTYPE)"
                );
            }
            let offset = p.req("offset")?.as_usize().context("offset")?;
            let nelems = p.req("nelems")?.as_usize().context("nelems")?;
            if shape.iter().product::<usize>() != nelems {
                bail!("param {name}: shape {shape:?} != nelems {nelems}");
            }
            let start = offset * 4;
            let end = start + nelems * 4;
            if end > bin.len() {
                bail!("param {name}: range {start}..{end} out of file");
            }
            let data: Vec<f32> = bin[start..end]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            names.push(name);
            tensors.push(HostTensor::f32(&shape, data));
        }

        Ok(Checkpoint {
            model: meta
                .req("model")?
                .as_str()
                .context("model")?
                .to_string(),
            step: meta.req("step")?.as_usize().context("step")?,
            names,
            tensors,
        })
    }

    pub fn save(&self, dir: impl AsRef<Path>) -> Result<PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let mut bin: Vec<u8> = Vec::new();
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for (name, t) in self.names.iter().zip(&self.tensors) {
            let data = t.as_f32()?;
            for v in data {
                bin.extend_from_slice(&v.to_le_bytes());
            }
            entries.push(json::obj(vec![
                ("name", json::s(name)),
                ("shape", json::usizes(&t.shape)),
                ("dtype", json::s("f32")),
                ("offset", json::num(offset as f64)),
                ("nelems", json::num(t.nelems() as f64)),
            ]));
            offset += t.nelems();
        }
        let meta = json::obj(vec![
            ("model", json::s(&self.model)),
            ("step", json::num(self.step as f64)),
            ("total_elems", json::num(offset as f64)),
            ("params", Json::Arr(entries)),
        ]);
        std::fs::write(dir.join("params.bin"), &bin)?;
        std::fs::write(dir.join("meta.json"), meta.to_string())?;
        Ok(dir.to_path_buf())
    }

    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.nelems()).sum()
    }

    pub fn by_name(&self, name: &str) -> Option<&HostTensor> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| &self.tensors[i])
    }

    /// Zero-filled optimizer-state twin (Adam m or v).
    pub fn zeros_like(&self) -> Vec<HostTensor> {
        self.tensors
            .iter()
            .map(|t| HostTensor::zeros_f32(&t.shape))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!(
            "dsmoe-ckpt-test-{}",
            std::process::id()
        ));
        let ck = Checkpoint {
            model: "test".into(),
            step: 7,
            names: vec!["a".into(), "b.w".into()],
            tensors: vec![
                HostTensor::f32(&[2, 2], vec![1., -2., 3.5, 0.25]),
                HostTensor::f32(&[3], vec![9., 8., 7.]),
            ],
        };
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.model, "test");
        assert_eq!(back.step, 7);
        assert_eq!(back.names, ck.names);
        assert_eq!(back.tensors, ck.tensors);
        assert_eq!(back.total_elems(), 7);
        assert_eq!(back.by_name("b.w").unwrap().shape, vec![3]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_bin_detected() {
        let dir = std::env::temp_dir().join(format!(
            "dsmoe-ckpt-corrupt-{}",
            std::process::id()
        ));
        let ck = Checkpoint {
            model: "t".into(),
            step: 0,
            names: vec!["a".into()],
            tensors: vec![HostTensor::f32(&[2], vec![1., 2.])],
        };
        ck.save(&dir).unwrap();
        // truncate params.bin
        std::fs::write(dir.join("params.bin"), [0u8; 4]).unwrap();
        let err = Checkpoint::load(&dir).unwrap_err().to_string();
        assert!(err.contains("bytes"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_f32_checkpoint_dtype_rejected() {
        let dir = std::env::temp_dir().join(format!(
            "dsmoe-ckpt-dtype-{}",
            std::process::id()
        ));
        let ck = Checkpoint {
            model: "t".into(),
            step: 0,
            names: vec!["a".into()],
            tensors: vec![HostTensor::f32(&[2], vec![1., 2.])],
        };
        ck.save(&dir).unwrap();
        let meta = std::fs::read_to_string(dir.join("meta.json")).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            meta.replace("\"dtype\":\"f32\"", "\"dtype\":\"i8\""),
        )
        .unwrap();
        let err = Checkpoint::load(&dir).unwrap_err().to_string();
        assert!(err.contains("param a"), "{err}");
        assert!(err.contains("\"i8\""), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn initial_checkpoints_load_if_built() {
        let root = std::path::Path::new("artifacts/ckpt/moe-s-8");
        if !root.exists() {
            return;
        }
        let ck = Checkpoint::load(root).unwrap();
        assert_eq!(ck.model, "moe-s-8");
        assert_eq!(ck.step, 0);
        // tok_emb first per the ABI
        assert_eq!(ck.names[0], "tok_emb");
        assert!(ck.total_elems() > 1_000_000);
    }
}
