//! PJRT runtime layer: manifest-driven loading and execution of the AOT
//! artifacts produced by `python/compile/aot.py`.
//!
//! * [`artifact::Manifest`] — parses `artifacts/manifest.json` (the ABI).
//! * [`client::Runtime`] / [`client::Program`] — thread-local PJRT CPU
//!   client with a compile cache; spec-validated execution.
//! * [`client::SharedArtifacts`] — one manifest + checkpoint set,
//!   shareable across threads, from which dense weights/programs are
//!   materialized on multiple runtimes (leader + leader shards).
//! * [`host_tensor::HostTensor`] — `Send` host tensors that cross threads.
//! * [`checkpoint::Checkpoint`] — params.bin/meta.json I/O shared with the
//!   Python side.

pub mod artifact;
pub mod checkpoint;
pub mod client;
pub mod host_tensor;

pub use artifact::{
    Capabilities, Manifest, ModelArtifacts, ProgramSpec, Provenance,
    TensorSpec, SCHEMA_VERSION,
};
pub use checkpoint::Checkpoint;
pub use client::{Program, Runtime, SharedArtifacts};
pub use host_tensor::{Dtype, HostTensor, TensorData};
