//! Artifact manifest: the ABI between the Python build path and this
//! runtime.  `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! describes every AOT program — file path, positional input/output specs —
//! plus per-model configs, parameter layouts and checkpoint locations.
//!
//! Since schema v2 the manifest is self-describing and self-checking: a
//! `schema_version` field (absent → v1), a per-program `sha256` digest the
//! runtime verifies before compiling (stale artifacts fail loudly naming
//! the entry), and a `capabilities` block declaring which expert-weight
//! and wire dtypes the artifact set supports — engines query the manifest
//! instead of probing program keys.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

/// Newest manifest schema this runtime understands.  `aot.py` writes the
/// same number; a manifest from a *newer* toolchain fails loudly at load
/// instead of being half-understood.
pub const SCHEMA_VERSION: usize = 2;

/// One tensor slot of a program signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().unwrap_or("").to_string(),
            shape: j.req("shape")?.usize_vec()?,
            dtype: j.req("dtype")?.as_str().unwrap_or("f32").to_string(),
        })
    }

    pub fn nelems(&self) -> usize {
        self.shape.iter().product()
    }

    /// True if this input slot is fed from the model checkpoint
    /// (names are "param:<param name>").
    pub fn is_param(&self) -> bool {
        self.name.starts_with("param:")
    }

    pub fn param_name(&self) -> Option<&str> {
        self.name.strip_prefix("param:")
    }
}

/// One AOT-compiled program.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub key: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Hex SHA-256 of the HLO text file, recorded by `aot.py` (schema v2).
    /// `None` for v1 manifests — integrity is then unchecked, as before.
    pub sha256: Option<String>,
}

impl ProgramSpec {
    fn from_json(key: &str, root: &Path, j: &Json) -> Result<Self> {
        let specs = |field: &str| -> Result<Vec<TensorSpec>> {
            j.req(field)?
                .as_arr()
                .context("specs must be an array")?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ProgramSpec {
            key: key.to_string(),
            file: root.join(
                j.req("file")?.as_str().context("file must be a string")?,
            ),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            sha256: j.get("sha256").and_then(|v| v.as_str()).map(str::to_string),
        })
    }
}

/// Dtype capability flags of an artifact set (manifest `capabilities`,
/// schema v2).  A v1 manifest — no block — defaults to f32-only, so the
/// compression toggles refuse to run against artifacts that predate them
/// instead of guessing.
#[derive(Debug, Clone)]
pub struct Capabilities {
    /// Expert-weight ship dtypes the checkpoint/manifest supports
    /// (`DSMOE_EXPERT_DTYPE` candidates), e.g. `["f32", "bf16", "i8"]`.
    pub expert_dtypes: Vec<String>,
    /// Activation wire dtypes (`DSMOE_WIRE_DTYPE` candidates), e.g.
    /// `["f32", "f16", "bf16"]`.
    pub wire_dtypes: Vec<String>,
}

impl Default for Capabilities {
    fn default() -> Self {
        Capabilities {
            expert_dtypes: vec!["f32".to_string()],
            wire_dtypes: vec!["f32".to_string()],
        }
    }
}

impl Capabilities {
    fn from_json(j: &Json) -> Result<Self> {
        let names = |field: &str| -> Result<Vec<String>> {
            j.req(field)?
                .as_arr()
                .with_context(|| format!("capabilities.{field} must be an array"))?
                .iter()
                .map(|v| {
                    Ok(v.as_str()
                        .with_context(|| {
                            format!("capabilities.{field} entries must be strings")
                        })?
                        .to_string())
                })
                .collect()
        };
        Ok(Capabilities {
            expert_dtypes: names("expert_dtypes")?,
            wire_dtypes: names("wire_dtypes")?,
        })
    }

    pub fn supports_expert_dtype(&self, name: &str) -> bool {
        self.expert_dtypes.iter().any(|d| d == name)
    }

    pub fn supports_wire_dtype(&self, name: &str) -> bool {
        self.wire_dtypes.iter().any(|d| d == name)
    }
}

/// Build provenance stamped into the manifest by `aot.py` (schema v2):
/// a digest of the compiler configuration (model registry, shape
/// ladders, capability flags) and a digest of the compiler sources
/// themselves.  When the block is present, both fields are verified to
/// be well-formed SHA-256 hex on load — a truncated or hand-edited stamp
/// fails loudly instead of silently comparing unequal forever.  Older
/// manifests without the block load fine (`provenance: None`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance {
    /// SHA-256 over the canonicalized compiler configuration.
    pub compiler_config_sha256: String,
    /// SHA-256 over the sorted `python/compile/*.py` sources.
    pub source_digest: String,
}

impl Provenance {
    fn from_json(j: &Json) -> Result<Self> {
        let hex = |field: &str| -> Result<String> {
            let v = j
                .req(field)?
                .as_str()
                .with_context(|| {
                    format!("provenance.{field} must be a string")
                })?
                .to_string();
            anyhow::ensure!(
                v.len() == 64 && v.bytes().all(|b| b.is_ascii_hexdigit()),
                "provenance.{field} must be 64 hex chars (SHA-256), \
                 got {v:?}"
            );
            Ok(v.to_ascii_lowercase())
        };
        Ok(Provenance {
            compiler_config_sha256: hex("compiler_config_sha256")?,
            source_digest: hex("source_digest")?,
        })
    }
}

/// Parameter layout entry (checkpoint ABI).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One model's artifact set.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub config: ModelConfig,
    pub params: Vec<ParamSpec>,
    pub checkpoint_dir: PathBuf,
    pub programs: BTreeMap<String, ProgramSpec>,
    pub train_batch: usize,
    pub train_seq: usize,
    pub eval_batch: usize,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    /// Declared manifest schema (absent field → 1).
    pub schema_version: usize,
    /// Dtype capabilities (f32-only for v1 manifests).
    pub capabilities: Capabilities,
    /// Compiler provenance stamp (absent in pre-stamp manifests).
    pub provenance: Option<Provenance>,
    pub models: BTreeMap<String, ModelArtifacts>,
    pub shared: BTreeMap<String, ProgramSpec>,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`?)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let schema_version = match j.get("schema_version") {
            None => 1, // pre-versioning manifests
            Some(v) => v
                .as_usize()
                .context("schema_version must be a non-negative integer")?,
        };
        anyhow::ensure!(
            schema_version <= SCHEMA_VERSION,
            "manifest {path:?} declares schema_version {schema_version} but \
             this runtime understands at most {SCHEMA_VERSION} — the \
             artifacts were built by a newer toolchain; rebuild them or \
             update the runtime"
        );
        let capabilities = match j.get("capabilities") {
            Some(c) => Capabilities::from_json(c).context("capabilities")?,
            None => Capabilities::default(),
        };
        let provenance = match j.get("provenance") {
            Some(p) => Some(Provenance::from_json(p).context("provenance")?),
            None => None,
        };

        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().context("models")? {
            let config = ModelConfig::from_json(m.req("config")?)
                .with_context(|| format!("config of model {name}"))?;
            let params = m
                .req("params")?
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.req("name")?.as_str().unwrap_or("").to_string(),
                        shape: p.req("shape")?.usize_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let mut programs = BTreeMap::new();
            for (key, pj) in m.req("programs")?.as_obj().context("programs")? {
                programs.insert(
                    key.clone(),
                    ProgramSpec::from_json(key, &root, pj)
                        .with_context(|| format!("program {name}/{key}"))?,
                );
            }
            let geo = m.req("train_geometry")?;
            models.insert(
                name.clone(),
                ModelArtifacts {
                    config,
                    params,
                    checkpoint_dir: root.join(
                        m.req("checkpoint")?.as_str().context("checkpoint")?,
                    ),
                    programs,
                    train_batch: geo.req("batch")?.as_usize().context("batch")?,
                    train_seq: geo.req("seq")?.as_usize().context("seq")?,
                    eval_batch: geo
                        .req("eval_batch")?
                        .as_usize()
                        .context("eval_batch")?,
                },
            );
        }

        let mut shared = BTreeMap::new();
        for (key, pj) in j.req("shared")?.as_obj().context("shared")? {
            shared.insert(
                key.clone(),
                ProgramSpec::from_json(key, &root, pj)
                    .with_context(|| format!("shared program {key}"))?,
            );
        }

        Ok(Manifest {
            root,
            schema_version,
            capabilities,
            provenance,
            models,
            shared,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models.get(name).with_context(|| {
            format!(
                "model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn shared_program(&self, key: &str) -> Result<&ProgramSpec> {
        self.shared
            .get(key)
            .with_context(|| format!("shared program {key:?} not in manifest"))
    }

    /// Shared-program key helpers (must match aot.py naming).
    pub fn key_attn_decode(m: usize, h: usize, b: usize, smax: usize) -> String {
        format!("attn_decode_m{m}_h{h}_b{b}_s{smax}")
    }

    pub fn key_attn_prefill(m: usize, h: usize, b: usize, smax: usize) -> String {
        format!("attn_prefill_m{m}_h{h}_b{b}_s{smax}")
    }

    pub fn key_embed(v: usize, m: usize, b: usize, s: usize) -> String {
        format!("embed_v{v}_m{m}_b{b}_s{s}")
    }

    pub fn key_lm_head(v: usize, m: usize, b: usize) -> String {
        format!("lm_head_v{v}_m{m}_b{b}")
    }

    pub fn key_dense_ffn(m: usize, f: usize, t: usize) -> String {
        format!("dense_ffn_m{m}_f{f}_t{t}")
    }

    pub fn key_gate(m: usize, e: usize, t: usize) -> String {
        format!("gate_m{m}_e{e}_t{t}")
    }

    pub fn key_expert_ffn(m: usize, f: usize, c: usize) -> String {
        format!("expert_ffn_m{m}_f{f}_c{c}")
    }

    pub fn key_residual_branch(m: usize, f: usize, t: usize) -> String {
        format!("residual_branch_m{m}_f{f}_t{t}")
    }

    /// Gather of each lane's last-position row out of a `[B, smax, M]`
    /// prefill activation (LM-head tail, literal-level — no full host
    /// pull).
    pub fn key_gather_last(m: usize, b: usize, smax: usize) -> String {
        format!("gather_last_m{m}_b{b}_s{smax}")
    }

    /// Smallest compiled expert-block capacity >= `need` (aot.py's
    /// EXPERT_BLOCK_SIZES ladder).
    pub fn expert_block_sizes(&self) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .shared
            .keys()
            .filter_map(|k| {
                k.rsplit_once("_c").and_then(|(pre, c)| {
                    pre.starts_with("expert_ffn").then(|| c.parse().ok())?
                })
            })
            .collect();
        sizes.sort();
        sizes.dedup();
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_param_detection() {
        let t = TensorSpec {
            name: "param:layer0.attn.wq".into(),
            shape: vec![8, 8],
            dtype: "f32".into(),
        };
        assert!(t.is_param());
        assert_eq!(t.param_name(), Some("layer0.attn.wq"));
        assert_eq!(t.nelems(), 64);
    }

    #[test]
    fn key_naming_matches_aot() {
        assert_eq!(
            Manifest::key_attn_decode(128, 4, 8, 64),
            "attn_decode_m128_h4_b8_s64"
        );
        assert_eq!(Manifest::key_expert_ffn(128, 512, 16),
                   "expert_ffn_m128_f512_c16");
    }

    #[test]
    fn program_spec_parses_optional_sha256() {
        let with = Json::parse(
            r#"{"file": "p.hlo", "inputs": [], "outputs": [],
                "sha256": "abc123"}"#,
        )
        .unwrap();
        let p = ProgramSpec::from_json("k", Path::new("/a"), &with).unwrap();
        assert_eq!(p.sha256.as_deref(), Some("abc123"));
        assert_eq!(p.file, Path::new("/a/p.hlo"));

        let without =
            Json::parse(r#"{"file": "p.hlo", "inputs": [], "outputs": []}"#)
                .unwrap();
        let p = ProgramSpec::from_json("k", Path::new("/a"), &without).unwrap();
        assert_eq!(p.sha256, None);
    }

    #[test]
    fn capabilities_default_is_f32_only() {
        let c = Capabilities::default();
        assert!(c.supports_expert_dtype("f32"));
        assert!(c.supports_wire_dtype("f32"));
        for compressed in ["bf16", "int8", "f16"] {
            assert!(!c.supports_expert_dtype(compressed), "{compressed}");
            assert!(!c.supports_wire_dtype(compressed), "{compressed}");
        }
    }

    #[test]
    fn capabilities_parse_and_guard() {
        let j = Json::parse(
            r#"{"expert_dtypes": ["f32", "bf16", "int8"],
                "wire_dtypes": ["f32", "f16", "bf16"]}"#,
        )
        .unwrap();
        let c = Capabilities::from_json(&j).unwrap();
        assert!(c.supports_expert_dtype("int8"));
        assert!(c.supports_wire_dtype("f16"));
        assert!(!c.supports_expert_dtype("f16"));

        let bad = Json::parse(r#"{"expert_dtypes": [1], "wire_dtypes": []}"#)
            .unwrap();
        assert!(Capabilities::from_json(&bad).is_err());
    }

    /// Write a throwaway manifest.json and load it.
    fn load_snippet(name: &str, body: &str) -> Result<Manifest> {
        let dir = std::env::temp_dir().join(format!(
            "dsmoe_manifest_test_{name}_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        let r = Manifest::load(&dir);
        let _ = std::fs::remove_dir_all(&dir);
        r
    }

    #[test]
    fn newer_schema_version_fails_loudly() {
        let err = load_snippet(
            "future",
            r#"{"schema_version": 99, "models": {}, "shared": {}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("schema_version 99"), "{err}");
        assert!(err.contains("rebuild"), "{err}");
    }

    #[test]
    fn v1_and_v2_manifests_both_load() {
        // v1: no schema_version, no capabilities → defaults.
        let m =
            load_snippet("v1", r#"{"models": {}, "shared": {}}"#).unwrap();
        assert_eq!(m.schema_version, 1);
        assert!(!m.capabilities.supports_expert_dtype("int8"));

        // v2: declared version + capabilities.
        let m = load_snippet(
            "v2",
            r#"{"schema_version": 2,
                "capabilities": {"expert_dtypes": ["f32", "int8"],
                                 "wire_dtypes": ["f32", "f16"]},
                "models": {}, "shared": {}}"#,
        )
        .unwrap();
        assert_eq!(m.schema_version, 2);
        assert!(m.capabilities.supports_expert_dtype("int8"));
        assert!(m.capabilities.supports_wire_dtype("f16"));
    }

    #[test]
    fn provenance_parses_and_normalizes() {
        let good = "a".repeat(64);
        let m = load_snippet(
            "prov",
            &format!(
                r#"{{"schema_version": 2,
                    "provenance": {{
                      "compiler_config_sha256": "{}",
                      "source_digest": "{}"}},
                    "models": {{}}, "shared": {{}}}}"#,
                good,
                good.to_uppercase(),
            ),
        )
        .unwrap();
        let p = m.provenance.unwrap();
        assert_eq!(p.compiler_config_sha256, good);
        // hex is case-normalized so stamps compare reliably
        assert_eq!(p.source_digest, good);

        // absent block: fine, None
        let m = load_snippet(
            "prov_none",
            r#"{"schema_version": 2, "models": {}, "shared": {}}"#,
        )
        .unwrap();
        assert!(m.provenance.is_none());
    }

    #[test]
    fn malformed_provenance_fails_loudly() {
        // truncated digest
        let err = load_snippet(
            "prov_short",
            r#"{"schema_version": 2,
                "provenance": {"compiler_config_sha256": "abc123",
                               "source_digest": "abc123"},
                "models": {}, "shared": {}}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("64 hex"), "{err:#}");

        // non-hex characters at the right length
        let bad = "z".repeat(64);
        let err = load_snippet(
            "prov_nonhex",
            &format!(
                r#"{{"schema_version": 2,
                    "provenance": {{"compiler_config_sha256": "{bad}",
                                   "source_digest": "{bad}"}},
                    "models": {{}}, "shared": {{}}}}"#,
            ),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("64 hex"), "{err:#}");

        // missing field
        let err = load_snippet(
            "prov_missing",
            r#"{"schema_version": 2,
                "provenance": {"compiler_config_sha256": "00"},
                "models": {}, "shared": {}}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("provenance"), "{err:#}");
    }

    #[test]
    fn manifest_loads_if_built() {
        // Integration-level check; skipped when artifacts are absent.
        let root = std::path::Path::new("artifacts");
        if !root.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(root).unwrap();
        assert!(!m.models.is_empty());
        let ms = m.model("moe-s-8").unwrap();
        assert!(ms.config.is_moe());
        assert!(ms.programs.contains_key("train_step"));
        // every referenced file exists
        for p in ms.programs.values() {
            assert!(p.file.exists(), "missing {:?}", p.file);
        }
    }
}
