//! # ds-moe — DeepSpeed-MoE reproduction
//!
//! A three-layer reproduction of *DeepSpeed-MoE: Advancing Mixture-of-Experts
//! Inference and Training to Power Next-Generation AI Scale* (ICML 2022):
//!
//! * **L1** — Pallas kernels (fused gating, scatter/gather layout transforms,
//!   grouped expert FFN) in `python/compile/kernels/`;
//! * **L2** — the JAX GPT+MoE model family in `python/compile/model.py`,
//!   AOT-lowered to HLO text by `python/compile/aot.py`;
//! * **L3** — this crate: the serving coordinator (routing, batching, expert
//!   parallelism, KV-cache management), the PJRT runtime that executes the
//!   AOT artifacts, the training driver (incl. staged knowledge
//!   distillation), and the A100 cluster performance simulator that
//!   regenerates the paper's Figures 10–15 and Table 3 at paper scale.
//!
//! Python never runs on the request path: after `make artifacts`, the Rust
//! binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod fabric;
pub mod metrics;
pub mod moe;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod tokenizer;
pub mod training;
pub mod util;
