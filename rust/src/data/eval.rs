//! Synthetic zero-shot evaluation suite (Table 2/4/5 analogue).
//!
//! The paper evaluates on LAMBADA / PIQA / BoolQ / RACE-h / TriviaQA / WebQs.
//! Those datasets measure whether models of different architectures reach the
//! same quality; the synthetic analogue preserving that comparison is
//! per-domain held-out completion: given the first `k` tokens of an unseen
//! sequence from domain `d`, predict token `k+1` (top-1 accuracy).  Each
//! domain plays the role of one downstream task — domains differ in
//! transition structure exactly as the paper's tasks differ in skill.

use super::corpus::Corpus;

/// One synthetic task: completion over a single latent domain.
#[derive(Debug, Clone)]
pub struct EvalTask {
    pub name: String,
    pub domain: usize,
    /// (prompt tokens, gold next token) pairs.
    pub items: Vec<(Vec<i32>, i32)>,
}

/// The full suite: one task per domain.
#[derive(Debug, Clone)]
pub struct EvalSuite {
    pub tasks: Vec<EvalTask>,
}

impl EvalSuite {
    /// Build from the corpus' validation split.  `prompt_len` tokens of
    /// context, predict the next.
    pub fn from_corpus(corpus: &Corpus, prompt_len: usize) -> Self {
        let n_domains = corpus.config.n_domains;
        let mut tasks: Vec<EvalTask> = (0..n_domains)
            .map(|d| EvalTask {
                name: format!("domain-{d}"),
                domain: d,
                items: Vec::new(),
            })
            .collect();
        for (seq, &d) in corpus.valid.iter().zip(&corpus.valid_domain) {
            if seq.len() > prompt_len {
                tasks[d]
                    .items
                    .push((seq[..prompt_len].to_vec(), seq[prompt_len]));
            }
        }
        EvalSuite { tasks }
    }

    /// Score a predictor: `predict(prompt) -> token`.  Returns per-task
    /// accuracies plus the mean (the paper reports per-task and averages).
    pub fn score<F: FnMut(&[i32]) -> i32>(
        &self,
        mut predict: F,
    ) -> (Vec<(String, f64)>, f64) {
        let mut per_task = Vec::new();
        for t in &self.tasks {
            if t.items.is_empty() {
                continue;
            }
            let correct = t
                .items
                .iter()
                .filter(|(p, gold)| predict(p) == *gold)
                .count();
            per_task.push((
                t.name.clone(),
                correct as f64 / t.items.len() as f64,
            ));
        }
        let mean = if per_task.is_empty() {
            0.0
        } else {
            per_task.iter().map(|(_, a)| a).sum::<f64>() / per_task.len() as f64
        };
        (per_task, mean)
    }

    /// Score a logits-based predictor: `logits(prompt)` returns the full
    /// next-token distribution (pre-softmax) and the suite accumulates the
    /// gold token's negative log-likelihood.  Returns per-task perplexity
    /// plus the overall perplexity (exp of the mean NLL over every item) —
    /// the quality metric the compression study reports, sensitive to
    /// precision loss that top-1 accuracy can hide.
    pub fn score_nll<F: FnMut(&[i32]) -> Vec<f32>>(
        &self,
        mut logits: F,
    ) -> (Vec<(String, f64)>, f64) {
        let mut per_task = Vec::new();
        let (mut total_nll, mut total_n) = (0.0f64, 0usize);
        for t in &self.tasks {
            if t.items.is_empty() {
                continue;
            }
            let mut nll = 0.0f64;
            for (p, gold) in &t.items {
                let row = logits(p);
                nll += gold_nll(&row, *gold as usize);
            }
            total_nll += nll;
            total_n += t.items.len();
            per_task.push((
                t.name.clone(),
                (nll / t.items.len() as f64).exp(),
            ));
        }
        let ppl = if total_n == 0 {
            1.0
        } else {
            (total_nll / total_n as f64).exp()
        };
        (per_task, ppl)
    }

    pub fn total_items(&self) -> usize {
        self.tasks.iter().map(|t| t.items.len()).sum()
    }
}

/// Negative log-likelihood of token `gold` under `logits` (numerically
/// stable log-softmax in f64: max-shift, then log-sum-exp).
fn gold_nll(logits: &[f32], gold: usize) -> f64 {
    let max = logits.iter().fold(f64::NEG_INFINITY, |m, &v| {
        m.max(v as f64)
    });
    let lse: f64 = logits
        .iter()
        .map(|&v| ((v as f64) - max).exp())
        .sum::<f64>()
        .ln()
        + max;
    lse - logits[gold] as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn tiny_corpus() -> Corpus {
        Corpus::generate(CorpusConfig {
            train_seqs: 32,
            valid_seqs: 64,
            ..Default::default()
        })
    }

    #[test]
    fn suite_covers_all_domains() {
        let c = tiny_corpus();
        let s = EvalSuite::from_corpus(&c, 8);
        assert_eq!(s.tasks.len(), c.config.n_domains);
        assert_eq!(s.total_items(), 64);
        for t in &s.tasks {
            assert_eq!(t.items.len(), 64 / c.config.n_domains);
            for (p, _) in &t.items {
                assert_eq!(p.len(), 8);
            }
        }
    }

    #[test]
    fn perfect_predictor_scores_one() {
        let c = tiny_corpus();
        let s = EvalSuite::from_corpus(&c, 8);
        // Look up the gold answer by matching the prompt in the valid split.
        let (per_task, mean) = s.score(|prompt| {
            c.valid
                .iter()
                .find(|seq| &seq[..8] == prompt)
                .map(|seq| seq[8])
                .unwrap_or(-1)
        });
        assert!(mean > 0.99, "mean {mean}");
        assert!(per_task.iter().all(|(_, a)| *a > 0.99));
    }

    #[test]
    fn nll_scorer_ranks_sharp_above_uniform() {
        let c = tiny_corpus();
        let s = EvalSuite::from_corpus(&c, 8);
        let v = c.config.vocab_size;
        // A predictor that puts high logit mass on the gold token beats a
        // uniform one, and uniform perplexity equals the vocab size.
        let (_, ppl_uniform) = s.score_nll(|_| vec![0.0; v]);
        assert!(
            (ppl_uniform - v as f64).abs() < 1e-6,
            "uniform ppl {ppl_uniform} vs vocab {v}"
        );
        let (per_task, ppl_sharp) = s.score_nll(|prompt| {
            let gold = c
                .valid
                .iter()
                .find(|seq| &seq[..8] == prompt)
                .map(|seq| seq[8])
                .unwrap_or(0);
            let mut row = vec![0.0f32; v];
            row[gold as usize] = 10.0;
            row
        });
        assert!(ppl_sharp < 2.0, "sharp ppl {ppl_sharp}");
        assert!(ppl_sharp < ppl_uniform);
        assert_eq!(per_task.len(), s.tasks.len());
        assert!(per_task.iter().all(|(_, p)| *p >= 1.0));
    }

    #[test]
    fn random_predictor_scores_near_chance() {
        let c = tiny_corpus();
        let s = EvalSuite::from_corpus(&c, 8);
        let mut x = 0i32;
        let (_, mean) = s.score(|_| {
            x = (x + 7) % 512;
            x
        });
        assert!(mean < 0.2, "mean {mean}");
    }
}
