//! Mixture-of-domains Markov corpus generator (see module docs in mod.rs).

use crate::util::rng::{Rng, Zipf};

/// Corpus generation settings.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub vocab_size: usize,
    /// Latent domains, each with its own Markov transition structure.
    pub n_domains: usize,
    /// Tokens per generated sequence (train batches slice these).
    pub seq_len: usize,
    /// Sequences in the training split.
    pub train_seqs: usize,
    /// Sequences in the held-out validation split.
    pub valid_seqs: usize,
    pub seed: u64,
    /// Zipf exponent of the per-domain emission head.
    pub zipf_s: f64,
    /// Sparsity: successors per (domain, token) pair.
    pub branching: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab_size: 512,
            n_domains: 8,
            seq_len: 33, // train geometry: batch rows are [seq+1] tokens
            train_seqs: 4096,
            valid_seqs: 512,
            seed: 20220717, // DeepSpeed-MoE arXiv v1 date
            zipf_s: 1.05,
            branching: 6,
        }
    }
}

/// A generated corpus: token sequences with domain labels.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub config: CorpusConfig,
    pub train: Vec<Vec<i32>>,
    pub valid: Vec<Vec<i32>>,
    /// Domain id of each train/valid sequence (for eval-by-domain).
    pub train_domain: Vec<usize>,
    pub valid_domain: Vec<usize>,
}

/// Per-domain Markov tables: successors[token] = [(next, weight); branching].
struct Domain {
    successors: Vec<Vec<(usize, f64)>>,
    start_tokens: Vec<usize>,
}

impl Corpus {
    pub fn generate(config: CorpusConfig) -> Self {
        assert!(config.vocab_size > 8, "vocab too small");
        let mut rng = Rng::new(config.seed);
        let zipf = Zipf::new(config.vocab_size - 4, config.zipf_s);

        // Reserve ids 0..4 for specials: 0=pad, 1=bos, 2=eos, 3=sep.
        let tok = |z: usize| z + 4;

        let domains: Vec<Domain> = (0..config.n_domains)
            .map(|_| {
                let successors = (0..config.vocab_size)
                    .map(|_| {
                        (0..config.branching)
                            .map(|_| {
                                (tok(zipf.sample(&mut rng)),
                                 0.25 + rng.f64())
                            })
                            .collect()
                    })
                    .collect();
                let start_tokens =
                    (0..8).map(|_| tok(zipf.sample(&mut rng))).collect();
                Domain { successors, start_tokens }
            })
            .collect();

        let gen_split = |n: usize, rng: &mut Rng| {
            let mut seqs = Vec::with_capacity(n);
            let mut doms = Vec::with_capacity(n);
            for i in 0..n {
                let d = i % config.n_domains; // balanced domains
                let domain = &domains[d];
                let mut seq = Vec::with_capacity(config.seq_len);
                seq.push(1i32); // bos
                let mut cur =
                    domain.start_tokens[rng.below(domain.start_tokens.len())];
                while seq.len() < config.seq_len {
                    seq.push(cur as i32);
                    let succ = &domain.successors[cur];
                    let weights: Vec<f64> =
                        succ.iter().map(|&(_, w)| w).collect();
                    cur = succ[rng.weighted(&weights)].0;
                }
                seqs.push(seq);
                doms.push(d);
            }
            (seqs, doms)
        };

        let (train, train_domain) = gen_split(config.train_seqs, &mut rng);
        let (valid, valid_domain) = gen_split(config.valid_seqs, &mut rng);
        Corpus { config, train, valid, train_domain, valid_domain }
    }

    /// Deterministic training batch: `batch` rows of `seq_len` tokens,
    /// flattened row-major, drawn by a seeded schedule over the train split.
    pub fn train_batch(&self, step: usize, batch: usize) -> Vec<i32> {
        let mut rng = Rng::new(self.config.seed ^ (step as u64) << 1);
        let mut out = Vec::with_capacity(batch * self.config.seq_len);
        for _ in 0..batch {
            let idx = rng.below(self.train.len());
            out.extend_from_slice(&self.train[idx]);
        }
        out
    }

    /// Fixed validation batch `i` (no randomness: comparable across runs).
    pub fn valid_batch(&self, i: usize, batch: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * self.config.seq_len);
        for b in 0..batch {
            let idx = (i * batch + b) % self.valid.len();
            out.extend_from_slice(&self.valid[idx]);
        }
        out
    }

    pub fn n_valid_batches(&self, batch: usize) -> usize {
        self.valid.len() / batch
    }

    /// A prompt for serving demos: the first `len` tokens of a valid seq.
    pub fn prompt(&self, i: usize, len: usize) -> Vec<i32> {
        let seq = &self.valid[i % self.valid.len()];
        seq[..len.min(seq.len())].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Corpus::generate(CorpusConfig::default());
        let b = Corpus::generate(CorpusConfig::default());
        assert_eq!(a.train[0], b.train[0]);
        assert_eq!(a.valid[10], b.valid[10]);
    }

    #[test]
    fn shapes_and_ranges() {
        let cfg = CorpusConfig { train_seqs: 64, valid_seqs: 16,
                                 ..Default::default() };
        let c = Corpus::generate(cfg.clone());
        assert_eq!(c.train.len(), 64);
        assert_eq!(c.valid.len(), 16);
        for seq in c.train.iter().chain(&c.valid) {
            assert_eq!(seq.len(), cfg.seq_len);
            assert!(seq.iter().all(|&t| (0..cfg.vocab_size as i32).contains(&t)));
            assert_eq!(seq[0], 1); // bos
        }
    }

    #[test]
    fn domains_have_distinct_statistics() {
        // Bigram distributions must differ across domains, else experts have
        // nothing to specialize on.
        let c = Corpus::generate(CorpusConfig {
            train_seqs: 512, ..Default::default()
        });
        let mut bigrams: Vec<std::collections::HashSet<(i32, i32)>> =
            vec![Default::default(); c.config.n_domains];
        for (seq, &d) in c.train.iter().zip(&c.train_domain) {
            for w in seq.windows(2) {
                bigrams[d].insert((w[0], w[1]));
            }
        }
        let inter: Vec<_> = bigrams[0].intersection(&bigrams[1]).collect();
        let overlap = inter.len() as f64 / bigrams[0].len() as f64;
        assert!(overlap < 0.3, "domains too similar: overlap {overlap:.2}");
    }

    #[test]
    fn batches_are_deterministic_and_sized() {
        let c = Corpus::generate(CorpusConfig {
            train_seqs: 64, valid_seqs: 32, ..Default::default()
        });
        assert_eq!(c.train_batch(3, 4), c.train_batch(3, 4));
        assert_ne!(c.train_batch(3, 4), c.train_batch(4, 4));
        assert_eq!(c.train_batch(0, 4).len(), 4 * c.config.seq_len);
        assert_eq!(c.valid_batch(0, 8), c.valid_batch(0, 8));
        assert_eq!(c.n_valid_batches(8), 4);
    }

    #[test]
    fn prompts_come_from_valid_split() {
        let c = Corpus::generate(CorpusConfig {
            train_seqs: 16, valid_seqs: 8, ..Default::default()
        });
        let p = c.prompt(2, 10);
        assert_eq!(p.len(), 10);
        assert_eq!(p, c.valid[2][..10].to_vec());
    }
}
