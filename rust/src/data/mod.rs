//! Synthetic corpus + batching + evaluation tasks.
//!
//! The paper trains on the 300B-token MT-NLG corpus, which we do not have
//! (DESIGN.md §0 substitution table).  The substitute must preserve the one
//! property the architecture comparisons depend on: **enough latent
//! structure that extra expert capacity helps**.  We therefore generate a
//! mixture-of-domains Markov corpus: `n_domains` first-order Markov chains
//! over a shared Zipfian vocabulary, each with its own transition structure.
//! A model must allocate capacity per domain to predict well — which is
//! exactly the regime where MoE experts specialize (and where a small dense
//! model underfits), reproducing the paper's dense-vs-MoE quality gap
//! qualitatively.
//!
//! The evaluation side mirrors the paper's zero-shot suite with synthetic
//! analogues: per-domain held-out completion accuracy (LAMBADA-style "guess
//! the final token") over sequences the model never saw in training.

pub mod corpus;
pub mod eval;

pub use corpus::{Corpus, CorpusConfig};
pub use eval::{EvalSuite, EvalTask};
