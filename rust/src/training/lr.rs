//! Learning-rate schedule: linear warmup + cosine decay to a floor
//! (Table 1: "LR linear warmup tokens" + "LR cosine decay tokens"; the MoE
//! models use a lower minimum LR and a longer decay horizon than dense).

#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub peak: f64,
    pub min: f64,
    pub warmup_steps: usize,
    pub decay_steps: usize,
}

impl LrSchedule {
    /// LR at 1-based step `t`.
    pub fn at(&self, t: usize) -> f64 {
        if self.warmup_steps > 0 && t <= self.warmup_steps {
            return self.peak * t as f64 / self.warmup_steps as f64;
        }
        let progressed = (t - self.warmup_steps) as f64;
        let horizon = (self.decay_steps.saturating_sub(self.warmup_steps))
            .max(1) as f64;
        let frac = (progressed / horizon).min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * frac).cos());
        self.min + (self.peak - self.min) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> LrSchedule {
        LrSchedule { peak: 1e-3, min: 1e-4, warmup_steps: 10, decay_steps: 100 }
    }

    #[test]
    fn warmup_is_linear() {
        let s = sched();
        assert!((s.at(1) - 1e-4).abs() < 1e-12);
        assert!((s.at(5) - 5e-4).abs() < 1e-12);
        assert!((s.at(10) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn decays_to_min_and_stays() {
        let s = sched();
        assert!((s.at(100) - 1e-4).abs() < 1e-9);
        assert!((s.at(1000) - 1e-4).abs() < 1e-9);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = sched();
        let mut prev = s.at(10);
        for t in 11..=100 {
            let cur = s.at(t);
            assert!(cur <= prev + 1e-12, "step {t}");
            prev = cur;
        }
    }

    #[test]
    fn midpoint_is_halfway() {
        let s = sched();
        // halfway through decay: cos(pi/2)=0 -> (peak+min)/2
        let mid = s.at(55);
        assert!((mid - 5.5e-4).abs() < 1e-5, "mid {mid}");
    }
}
